"""Foundational model layers as pure functions over dict pytrees.

Every layer has an ``init_*`` returning a param pytree and an ``apply``-style
function. No framework (flax/haiku) — plain pytrees keep pjit shardings and
scan-stacking explicit.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

Params = dict


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def dense_init(key, in_dim: int, out_dim: int, dtype, scale: float | None = None):
    scale = scale if scale is not None else in_dim ** -0.5
    return (jax.random.normal(key, (in_dim, out_dim), jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def rmsnorm_init(dim: int) -> Params:
    return {"scale": jnp.ones((dim,), jnp.float32)}


def rmsnorm(params: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * params["scale"]
    return y.astype(dt)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: broadcastable to (..., seq)."""
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)                      # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., None, :]                      # (..., seq, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (swiglu / geglu / gelu / relu_sq)
# ---------------------------------------------------------------------------

def mlp_init(key, d_model: int, d_ff: int, activation: str, dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"w_out": dense_init(k2, d_ff, d_model, dtype)}
    if activation in ("swiglu", "geglu"):
        p["w_gate"] = dense_init(k1, d_model, d_ff, dtype)
        p["w_up"] = dense_init(k3, d_model, d_ff, dtype)
    else:
        p["w_up"] = dense_init(k1, d_model, d_ff, dtype)
    return p


def mlp(params: Params, x: jax.Array, activation: str) -> jax.Array:
    if activation == "swiglu":
        h = jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])
    elif activation == "geglu":
        h = jax.nn.gelu(x @ params["w_gate"], approximate=True) * (x @ params["w_up"])
    elif activation == "gelu":
        h = jax.nn.gelu(x @ params["w_up"], approximate=True)
    elif activation == "relu_sq":
        h = jnp.square(jax.nn.relu(x @ params["w_up"]))
    else:
        raise ValueError(f"unknown activation {activation!r}")
    return h @ params["w_out"]


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------

def embedding_init(key, vocab: int, d_model: int, dtype) -> Params:
    return {"table": (jax.random.normal(key, (vocab, d_model), jnp.float32) * 0.02).astype(dtype)}


def embed(params: Params, tokens: jax.Array) -> jax.Array:
    return jnp.take(params["table"], tokens, axis=0)


def unembed(params: Params, x: jax.Array) -> jax.Array:
    """Logits via the (possibly tied) output table: (..., d) -> (..., vocab)."""
    return x @ params["table"].T


def cross_entropy(logits: jax.Array, labels: jax.Array, mask: jax.Array | None = None):
    """Mean token-level cross entropy; logits (..., V) may be vocab-sharded
    (logsumexp reduces over the sharded axis; SPMD inserts the collective)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
