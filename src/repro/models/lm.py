"""Unified causal LM over every assigned architecture family.

The layer stack is a ``lax.scan`` over stacked per-layer params (one compiled
block body regardless of depth — essential for 95-layer dry-run compiles).
Hybrid archs (zamba2) nest the scan: groups of Mamba2 blocks with one
weight-shared attention block applied per group.

Three entry points:
  forward        — full-sequence logits (training / scoring)
  prefill        — full sequence + returns the decode state (KV caches / SSM
                   states / RWKV states)
  decode_step    — one token against the decode state
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.configs.base import BlockKind, ModelConfig
from repro.models import frontends
from repro.models.attention import (KVCache, attention_decode, attention_init,
                                    attention_prefill)
from repro.models.layers import (Params, cross_entropy, dense_init, embed,
                                 embedding_init, mlp, mlp_init, rmsnorm,
                                 rmsnorm_init, unembed)
from repro.models.mamba2 import (MambaState, init_mamba_state, mamba2_forward,
                                 mamba2_init, mamba2_step)
from repro.models.moe import moe_forward, moe_init
from repro.models.rwkv6 import (RWKVState, init_rwkv_state, rwkv6_channel_mix,
                                rwkv6_init, rwkv6_time_mix)


@dataclasses.dataclass
class RunCtx:
    """Execution-context knobs threaded through the model (static python)."""

    mesh: Optional[Mesh] = None
    dp_axes: Tuple[str, ...] = ("data",)
    tp_axis: str = "model"
    ep_axis: str = "model"
    causal_skip: bool = False          # triangular attention schedule (§Perf)
    attn_p_bf16: bool = False          # bf16 probability tensor (§Perf)
    moe_a2a_int8: bool = False         # quantized MoE dispatch (§Perf)
    attn_impl: str = "xla"             # 'xla' | 'flash' (Pallas fwd kernel)
    remat: bool = True
    attn_chunk: int = 1024
    moe_strategy: str = "auto"
    # logical activation sharder: (x, logical_dims) -> x; identity by default
    shard: Callable[[jax.Array, Tuple[Optional[str], ...]], jax.Array] = (
        lambda x, dims: x)


DEFAULT_CTX = RunCtx()


def _stacked_init(fn, key, n: int):
    return jax.vmap(fn)(jax.random.split(key, n))


# ---------------------------------------------------------------------------
# per-family block init/apply
# ---------------------------------------------------------------------------

def _attn_mlp_block_init(key, cfg: ModelConfig, dtype, d_ff: int) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": rmsnorm_init(cfg.d_model),
        "attn": attention_init(k1, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                               cfg.resolved_head_dim, dtype),
        "ln2": rmsnorm_init(cfg.d_model),
        "mlp": mlp_init(k2, cfg.d_model, d_ff, cfg.mlp_activation, dtype),
    }


def _moe_block_init(key, cfg: ModelConfig, dtype) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": rmsnorm_init(cfg.d_model),
        "attn": attention_init(k1, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                               cfg.resolved_head_dim, dtype),
        "ln2": rmsnorm_init(cfg.d_model),
        "moe": moe_init(k2, cfg, dtype),
    }


def _mamba_block_init(key, cfg: ModelConfig, dtype) -> Params:
    return {"ln": rmsnorm_init(cfg.d_model), "mamba": mamba2_init(key, cfg, dtype)}


def _rwkv_block_init(key, cfg: ModelConfig, dtype) -> Params:
    return {
        "ln1": rmsnorm_init(cfg.d_model),
        "tm": rwkv6_init(key, cfg, dtype),
        "ln2": rmsnorm_init(cfg.d_model),
    }


def init_params(key, cfg: ModelConfig) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    k_emb, k_blocks, k_head, k_shared, k_fe = jax.random.split(key, 5)
    params: Params = {"embed": embedding_init(k_emb, cfg.vocab_size, cfg.d_model, dtype)}

    kind = cfg.block_pattern[0]
    if cfg.shared_attn_every:      # zamba2-style hybrid
        groups = cfg.num_layers // cfg.shared_attn_every
        per_group = cfg.shared_attn_every
        params["blocks"] = _stacked_init(
            lambda k: _stacked_init(lambda kk: _mamba_block_init(kk, cfg, dtype), k, per_group),
            k_blocks, groups)
        params["shared_attn"] = _attn_mlp_block_init(k_shared, cfg, dtype, cfg.d_ff)
    elif kind == BlockKind.ATTENTION:
        params["blocks"] = _stacked_init(
            lambda k: _attn_mlp_block_init(k, cfg, dtype, cfg.d_ff), k_blocks, cfg.num_layers)
    elif kind == BlockKind.MOE:
        params["blocks"] = _stacked_init(
            lambda k: _moe_block_init(k, cfg, dtype), k_blocks, cfg.num_layers)
    elif kind == BlockKind.MAMBA2:
        params["blocks"] = _stacked_init(
            lambda k: _mamba_block_init(k, cfg, dtype), k_blocks, cfg.num_layers)
    elif kind == BlockKind.RWKV6:
        params["blocks"] = _stacked_init(
            lambda k: _rwkv_block_init(k, cfg, dtype), k_blocks, cfg.num_layers)
    else:
        raise ValueError(kind)

    params["final_norm"] = rmsnorm_init(cfg.d_model)
    if not cfg.tie_embeddings:
        params["unembed"] = embedding_init(k_head, cfg.vocab_size, cfg.d_model, dtype)
    if cfg.frontend != "none":
        params["frontend"] = {
            "proj": dense_init(k_fe, frontends.frontend_dim(cfg), cfg.d_model, dtype)}
    return params


def param_count(cfg: ModelConfig, active_only: bool = False) -> int:
    import math
    shapes = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    total = sum(math.prod(l.shape) if l.shape else 1
                for l in jax.tree_util.tree_leaves(shapes))
    if active_only and cfg.moe is not None:
        expert_leaves = jax.tree_util.tree_leaves(
            {k: shapes["blocks"]["moe"][k] for k in ("w_gate", "w_up", "w_out")})
        expert_total = sum(math.prod(l.shape) for l in expert_leaves)
        active_frac = cfg.moe.experts_per_token / cfg.moe.num_experts
        total = total - expert_total + int(expert_total * active_frac)
    return total


# ---------------------------------------------------------------------------
# block apply (full sequence)
# ---------------------------------------------------------------------------

def _apply_attn_mlp(p, cfg, ctx: RunCtx, x, positions, want_cache: bool):
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    if want_cache:
        a, cache = attention_prefill(p["attn"], h, positions, cfg.rope_theta,
                                     chunk=ctx.attn_chunk,
                                     causal_skip=ctx.causal_skip,
                                     p_bf16=ctx.attn_p_bf16,
                                     impl=ctx.attn_impl, return_cache=True)
    else:
        a = attention_prefill(p["attn"], h, positions, cfg.rope_theta,
                              chunk=ctx.attn_chunk, causal_skip=ctx.causal_skip,
                              p_bf16=ctx.attn_p_bf16, impl=ctx.attn_impl)
        cache = None
    x = x + a
    x = ctx.shard(x, ("batch", "seq", None))
    x = x + mlp(p["mlp"], rmsnorm(p["ln2"], x, cfg.norm_eps), cfg.mlp_activation)
    x = ctx.shard(x, ("batch", "seq", None))
    return x, cache


def _apply_moe_block(p, cfg, ctx: RunCtx, x, positions, want_cache: bool):
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    if want_cache:
        a, cache = attention_prefill(p["attn"], h, positions, cfg.rope_theta,
                                     chunk=ctx.attn_chunk,
                                     causal_skip=ctx.causal_skip,
                                     p_bf16=ctx.attn_p_bf16,
                                     impl=ctx.attn_impl, return_cache=True)
    else:
        a = attention_prefill(p["attn"], h, positions, cfg.rope_theta,
                              chunk=ctx.attn_chunk, causal_skip=ctx.causal_skip,
                              p_bf16=ctx.attn_p_bf16, impl=ctx.attn_impl)
        cache = None
    x = x + a
    y, aux = moe_forward(p["moe"], cfg, rmsnorm(p["ln2"], x, cfg.norm_eps),
                         mesh=ctx.mesh, dp_axes=ctx.dp_axes, ep_axis=ctx.ep_axis,
                         strategy=ctx.moe_strategy, a2a_int8=ctx.moe_a2a_int8)
    x = ctx.shard(x + y, ("batch", "seq", None))
    return x, aux, cache


def _apply_mamba_block(p, cfg, ctx: RunCtx, x, want_state: bool = False):
    h = rmsnorm(p["ln"], x, cfg.norm_eps)
    if want_state:
        y, st = mamba2_forward(p["mamba"], cfg, h, return_state=True)
        return ctx.shard(x + y, ("batch", "seq", None)), st
    y = mamba2_forward(p["mamba"], cfg, h)
    return ctx.shard(x + y, ("batch", "seq", None))


def _apply_rwkv_block(p, cfg, ctx: RunCtx, x, want_state: bool = False):
    h_in = rmsnorm(p["ln1"], x, cfg.norm_eps)
    if want_state:
        tm, s_fin, last_t = rwkv6_time_mix(p["tm"], cfg, h_in, None, return_state=True)
        h = x + tm
        c_in = rmsnorm(p["ln2"], h, cfg.norm_eps)
        cm, last_c = rwkv6_channel_mix(p["tm"], cfg, c_in, None, return_state=True)
        out = ctx.shard(h + cm, ("batch", "seq", None))
        return out, RWKVState(wkv=s_fin, shift_t=last_t, shift_c=last_c)
    h = x + rwkv6_time_mix(p["tm"], cfg, h_in)
    out = h + rwkv6_channel_mix(p["tm"], cfg, rmsnorm(p["ln2"], h, cfg.norm_eps))
    return ctx.shard(out, ("batch", "seq", None))


# ---------------------------------------------------------------------------
# forward / prefill
# ---------------------------------------------------------------------------

def _embed_inputs(params, cfg, tokens, prefix_emb):
    x = embed(params["embed"], tokens)
    if cfg.name.startswith("gemma"):
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    if cfg.frontend != "none":
        assert prefix_emb is not None, f"{cfg.name} requires frontend embeddings"
        pre = prefix_emb.astype(x.dtype) @ params["frontend"]["proj"]
        x = jnp.concatenate([pre, x], axis=1)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    return x, positions


def _run_stack(params, cfg: ModelConfig, ctx: RunCtx, x, positions,
               want_cache: bool = False):
    """Returns (hidden, aux_loss, caches-or-None)."""
    kind = cfg.block_pattern[0]
    aux0 = jnp.zeros((), jnp.float32)

    if cfg.shared_attn_every:
        def group_body(carry, p_group):
            x, aux = carry

            def inner(x, p_layer):
                if want_cache:
                    return _apply_mamba_block(p_layer, cfg, ctx, x, want_state=True)
                return _apply_mamba_block(p_layer, cfg, ctx, x), None

            x, msts = jax.lax.scan(inner, x, p_group)
            x2, cache = _apply_attn_mlp(params["shared_attn"], cfg, ctx, x,
                                        positions, want_cache)
            return (x2, aux), (msts, cache)

        group_fn = jax.checkpoint(group_body) if ctx.remat else group_body
        (x, aux), caches = jax.lax.scan(group_fn, (x, aux0), params["blocks"])
        return x, aux, caches

    if kind == BlockKind.ATTENTION:
        def body(carry, p_layer):
            x, aux = carry
            x, cache = _apply_attn_mlp(p_layer, cfg, ctx, x, positions, want_cache)
            return (x, aux), cache
    elif kind == BlockKind.MOE:
        def body(carry, p_layer):
            x, aux = carry
            x, aux_l, cache = _apply_moe_block(p_layer, cfg, ctx, x, positions,
                                               want_cache)
            return (x, aux + aux_l), cache
    elif kind == BlockKind.MAMBA2:
        def body(carry, p_layer):
            x, aux = carry
            if want_cache:
                x, st = _apply_mamba_block(p_layer, cfg, ctx, x, want_state=True)
                return (x, aux), st
            return (_apply_mamba_block(p_layer, cfg, ctx, x), aux), None
    elif kind == BlockKind.RWKV6:
        def body(carry, p_layer):
            x, aux = carry
            if want_cache:
                x, st = _apply_rwkv_block(p_layer, cfg, ctx, x, want_state=True)
                return (x, aux), st
            return (_apply_rwkv_block(p_layer, cfg, ctx, x), aux), None
    else:
        raise ValueError(kind)

    body_fn = jax.checkpoint(body) if ctx.remat else body
    (x, aux), caches = jax.lax.scan(body_fn, (x, aux0), params["blocks"])
    return x, aux, caches


def forward(params, cfg: ModelConfig, tokens, prefix_emb=None,
            ctx: RunCtx = DEFAULT_CTX, return_hidden: bool = False):
    """tokens: (B, S) -> logits (B, S(+P), V)."""
    x, positions = _embed_inputs(params, cfg, tokens, prefix_emb)
    x = ctx.shard(x, ("batch", "seq", None))
    x, aux, _ = _run_stack(params, cfg, ctx, x, positions, want_cache=False)
    h = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = unembed(table, h)
    logits = ctx.shard(logits, ("batch", "seq", "vocab"))
    if return_hidden:
        return logits, aux, h
    return logits, aux


def loss_fn(params, cfg: ModelConfig, batch, ctx: RunCtx = DEFAULT_CTX):
    """batch: {'tokens': (B,S), 'labels': (B,S), optional 'prefix_emb'}."""
    logits, aux = forward(params, cfg, batch["tokens"],
                          batch.get("prefix_emb"), ctx)
    P = logits.shape[1] - batch["labels"].shape[1]
    if P:                                  # drop frontend positions from loss
        logits = logits[:, P:]
    ce = cross_entropy(logits, batch["labels"], batch.get("mask"))
    aux_w = cfg.moe.router_aux_loss if cfg.moe is not None else 0.0
    return ce + aux_w * aux, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# prefill / decode
# ---------------------------------------------------------------------------

def prefill(params, cfg: ModelConfig, tokens, prefix_emb=None,
            ctx: RunCtx = DEFAULT_CTX):
    """Full-sequence forward that also returns the decode state."""
    x, positions = _embed_inputs(params, cfg, tokens, prefix_emb)
    x = ctx.shard(x, ("batch", "seq", None))
    x, aux, caches = _run_stack(params, cfg, ctx, x, positions, want_cache=True)
    if cfg.shared_attn_every:
        caches = {"kv": caches[1], "mamba": caches[0]}
    h = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = unembed(table, h)
    state = {"pos": jnp.full((tokens.shape[0],), x.shape[1], jnp.int32),
             "cache": caches}
    return logits, state


def _keep_active(active, new, old):
    """Select updated state rows only where active (batch is axis 0)."""
    if active is None:
        return new
    return jax.tree_util.tree_map(
        lambda n, o: jnp.where(
            active.reshape((-1,) + (1,) * (n.ndim - 1)), n, o), new, old)


def _decode_attn_mlp(p, cfg, ctx, x, cache: KVCache, pos, active):
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    y, new_cache = attention_decode(p["attn"], h, cache, pos, cfg.rope_theta,
                                    active=active)
    x = x + y
    x = x + mlp(p["mlp"], rmsnorm(p["ln2"], x, cfg.norm_eps), cfg.mlp_activation)
    return x, new_cache


def _decode_moe_block(p, cfg, ctx, x, cache: KVCache, pos, active):
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    y, new_cache = attention_decode(p["attn"], h, cache, pos, cfg.rope_theta,
                                    active=active)
    x = x + y
    y2, _ = moe_forward(p["moe"], cfg, rmsnorm(p["ln2"], x, cfg.norm_eps),
                        mesh=ctx.mesh, dp_axes=ctx.dp_axes, ep_axis=ctx.ep_axis,
                        strategy="allgather" if ctx.mesh is not None else "auto")
    return x + y2, new_cache


def decode_step(params, cfg: ModelConfig, token, state, ctx: RunCtx = DEFAULT_CTX,
                active=None, return_hidden: bool = False):
    """token: (B, 1) int32; state from ``init_decode_state`` or ``prefill``.
    ``pos`` may be per-row; rows with ``active`` False (continuous batching
    free slots) keep their state unchanged.

    Returns (logits (B,1,V), new_state[, hidden])."""
    pos = jnp.broadcast_to(jnp.asarray(state["pos"], jnp.int32),
                           (token.shape[0],))
    x = embed(params["embed"], token)
    if cfg.name.startswith("gemma"):
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    kind = cfg.block_pattern[0]

    if cfg.shared_attn_every:
        def group_body(x, xs):
            p_group, kv_g, m_g = xs

            def inner(x, xs_l):
                p_layer, st_l = xs_l
                h = rmsnorm(p_layer["ln"], x, cfg.norm_eps)
                y, new_st = mamba2_step(p_layer["mamba"], cfg, h, st_l)
                return x + y, _keep_active(active, new_st, st_l)

            x, new_m = jax.lax.scan(inner, x, (p_group, m_g))
            x, new_kv = _decode_attn_mlp(params["shared_attn"], cfg, ctx, x,
                                         kv_g, pos, active)
            return x, (new_kv, new_m)

        x, (new_kv, new_m) = jax.lax.scan(
            group_body, x, (params["blocks"], state["cache"]["kv"],
                            state["cache"]["mamba"]))
        new_cache = {"kv": new_kv, "mamba": new_m}
    elif kind == BlockKind.ATTENTION:
        def body(x, xs):
            p_layer, cache_l = xs
            return _decode_attn_mlp(p_layer, cfg, ctx, x, cache_l, pos, active)

        x, new_cache = jax.lax.scan(body, x, (params["blocks"], state["cache"]))
    elif kind == BlockKind.MOE:
        def body(x, xs):
            p_layer, cache_l = xs
            return _decode_moe_block(p_layer, cfg, ctx, x, cache_l, pos, active)

        x, new_cache = jax.lax.scan(body, x, (params["blocks"], state["cache"]))
    elif kind == BlockKind.RWKV6:
        def body(x, xs):
            p_layer, st_l = xs
            h_in = rmsnorm(p_layer["ln1"], x, cfg.norm_eps)
            tm, s_fin, last_t = rwkv6_time_mix(p_layer["tm"], cfg, h_in, st_l,
                                               return_state=True)
            h = x + tm
            c_in = rmsnorm(p_layer["ln2"], h, cfg.norm_eps)
            cm, last_c = rwkv6_channel_mix(p_layer["tm"], cfg, c_in, st_l,
                                           return_state=True)
            new_st = RWKVState(wkv=s_fin, shift_t=last_t, shift_c=last_c)
            return h + cm, _keep_active(active, new_st, st_l)

        x, new_cache = jax.lax.scan(body, x, (params["blocks"], state["cache"]))
    else:
        raise ValueError(kind)

    h = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = unembed(table, h)
    new_pos = pos + (1 if active is None else active.astype(jnp.int32))
    new_state = {"pos": new_pos, "cache": new_cache}
    if return_hidden:
        return logits, new_state, h
    return logits, new_state


def pad_decode_state(cfg: ModelConfig, state, max_len: int):
    """Grow the KV-cache capacity of a prefill state to ``max_len``."""
    def pad_kv(c: KVCache) -> KVCache:
        def pad(a):
            extra = max_len - a.shape[2]
            if extra <= 0:
                return a
            pad_widths = [(0, 0)] * a.ndim
            pad_widths[2] = (0, extra)
            return jnp.pad(a, pad_widths)
        return KVCache(k=pad(c.k), v=pad(c.v))

    cache = state["cache"]
    if cfg.shared_attn_every:
        cache = {"kv": pad_kv(cache["kv"]), "mamba": cache["mamba"]}
    elif isinstance(cache, KVCache):
        cache = pad_kv(cache)
    return {"pos": state["pos"], "cache": cache}


def init_decode_state(cfg: ModelConfig, batch: int, max_len: int):
    """Zero decode state with capacity ``max_len`` (the dry-run's KV cache)."""
    dt = jnp.dtype(cfg.dtype)
    hd = cfg.resolved_head_dim

    def kv(n_stack):
        shape = (n_stack, batch, max_len, cfg.num_kv_heads, hd)
        return KVCache(k=jnp.zeros(shape, dt), v=jnp.zeros(shape, dt))

    pos0 = jnp.zeros((batch,), jnp.int32)
    if cfg.shared_attn_every:
        groups = cfg.num_layers // cfg.shared_attn_every
        per_group = cfg.shared_attn_every
        ms = init_mamba_state(cfg, batch)
        ms = jax.tree_util.tree_map(
            lambda a: jnp.zeros((groups, per_group) + a.shape, a.dtype), ms)
        return {"pos": pos0, "cache": {"kv": kv(groups), "mamba": ms}}
    kind = cfg.block_pattern[0]
    if kind in (BlockKind.ATTENTION, BlockKind.MOE):
        return {"pos": pos0, "cache": kv(cfg.num_layers)}
    if kind == BlockKind.RWKV6:
        st = init_rwkv_state(cfg, batch)
        st = jax.tree_util.tree_map(
            lambda a: jnp.zeros((cfg.num_layers,) + a.shape, a.dtype), st)
        return {"pos": pos0, "cache": st}
    if kind == BlockKind.MAMBA2:
        ms = init_mamba_state(cfg, batch)
        ms = jax.tree_util.tree_map(
            lambda a: jnp.zeros((cfg.num_layers,) + a.shape, a.dtype), ms)
        return {"pos": pos0, "cache": ms}
    raise ValueError(kind)
