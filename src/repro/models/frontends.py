"""Modality frontend stubs.

Per the task spec, ``[audio]`` / ``[vlm]`` entries cover the transformer
backbone only — the real EnCodec / CLIP-anyres encoders are out of scope and
``input_specs()`` supplies *precomputed* frame/patch embeddings. This module
defines the stub dimensions and deterministic synthetic embedding generators
used by smoke tests and examples.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

# raw embedding width delivered by the (stubbed) modality encoder
_FRONTEND_DIMS = {
    "audio_frames": 128,      # EnCodec latent frame width
    "vision_patches": 1024,   # CLIP-L patch embedding width
}


def frontend_dim(cfg: ModelConfig) -> int:
    if cfg.frontend == "none":
        return 0
    return _FRONTEND_DIMS[cfg.frontend]


def synthetic_prefix(cfg: ModelConfig, batch: int, key=None) -> jax.Array:
    """Deterministic stand-in for precomputed frontend embeddings:
    (batch, frontend_positions, frontend_dim)."""
    if cfg.frontend == "none":
        return None
    key = key if key is not None else jax.random.PRNGKey(17)
    return jax.random.normal(
        key, (batch, cfg.frontend_positions, frontend_dim(cfg)), jnp.float32
    ).astype(jnp.dtype(cfg.dtype))
