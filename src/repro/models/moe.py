"""Mixture-of-Experts FFN with real expert parallelism.

Three execution strategies:

* ``reference`` — loop over experts with masking; exact, used on a single
  device (smoke tests, numerics oracle).
* ``a2a`` — production EP: tokens are sequence-sharded over the expert axis,
  routed entries are exchanged with ``lax.all_to_all`` (dispatch), expert
  FFNs run on their owning shard, and a reverse all-to-all returns outputs
  (DeepSeek/Switch-style, drop policy at static capacity).
* ``allgather`` — decode-friendly: token counts are tiny, so tokens are
  replicated over the expert axis, every shard computes only its local
  experts' assignments, and a psum combines partial outputs.

Expert weights are stored (E, d, ff); at trillion-param scale the caller
shards ff over the data axes (FSDP) and the per-layer gather is inserted by
SPMD when the weights enter the shard_map with an E-only spec.
"""
from __future__ import annotations

import functools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map

from repro.configs.base import ModelConfig
from repro.models.layers import Params, dense_init, mlp, mlp_init


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def moe_init(key, cfg: ModelConfig, dtype) -> Params:
    moe = cfg.moe
    d, ff, E = cfg.d_model, moe.expert_d_ff, moe.num_experts
    ks = jax.random.split(key, 6)
    p = {
        "router": (jax.random.normal(ks[0], (d, E), jnp.float32) * d ** -0.5),
        "w_gate": (jax.random.normal(ks[1], (E, d, ff), jnp.float32) * d ** -0.5).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (E, d, ff), jnp.float32) * d ** -0.5).astype(dtype),
        "w_out": (jax.random.normal(ks[3], (E, ff, d), jnp.float32) * ff ** -0.5).astype(dtype),
    }
    if moe.num_shared_experts:
        p["shared"] = mlp_init(ks[4], d, ff * moe.num_shared_experts, "swiglu", dtype)
    if moe.dense_residual_d_ff:
        p["dense"] = mlp_init(ks[5], d, moe.dense_residual_d_ff, cfg.mlp_activation, dtype)
    return p


def _route(router_w, x_tok, k: int):
    """x_tok: (T, d) -> (weights (T,K) f32, idx (T,K) i32, probs (T,E) f32)."""
    logits = (x_tok.astype(jnp.float32) @ router_w).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, idx = jax.lax.top_k(probs, k)
    weights = weights / jnp.maximum(jnp.sum(weights, axis=-1, keepdims=True), 1e-9)
    return weights, idx, probs


def _aux_loss(probs, idx, num_experts: int) -> jax.Array:
    """Switch-style load-balancing loss (local shard statistics)."""
    T, K = idx.shape
    f = jnp.zeros((num_experts,), jnp.float32).at[idx.reshape(-1)].add(1.0)
    f = f / (T * K)
    p_mean = jnp.mean(probs, axis=0)
    return num_experts * jnp.sum(f * p_mean)


def _expert_ffn(w_gate, w_up, w_out, xbuf):
    """xbuf: (E_loc, C, d) -> (E_loc, C, d)."""
    g = jnp.einsum("ecd,edf->ecf", xbuf, w_gate)
    u = jnp.einsum("ecd,edf->ecf", xbuf, w_up)
    h = (jax.nn.silu(g.astype(jnp.float32)) * u.astype(jnp.float32)).astype(xbuf.dtype)
    return jnp.einsum("ecf,efd->ecd", h, w_out)


def _rank_in_group(group: jax.Array, num_groups: int) -> jax.Array:
    """Stable rank of each element within its group. group: (N,) int in [0,G)."""
    oh = jax.nn.one_hot(group, num_groups, dtype=jnp.int32)      # (N, G)
    return (jnp.cumsum(oh, axis=0) - oh)[jnp.arange(group.shape[0]), group]


# ---------------------------------------------------------------------------
# reference path
# ---------------------------------------------------------------------------

def moe_reference(params: Params, cfg: ModelConfig, x_tok: jax.Array):
    """Exact capacity-free MoE on one device. x_tok: (T, d)."""
    moe = cfg.moe
    weights, idx, probs = _route(params["router"], x_tok, moe.experts_per_token)

    def per_expert(y, e):
        w_e = jnp.sum(jnp.where(idx == e, weights, 0.0), axis=-1)  # (T,)
        g = jax.nn.silu((x_tok @ params["w_gate"][e]).astype(jnp.float32))
        u = (x_tok @ params["w_up"][e]).astype(jnp.float32)
        out = ((g * u).astype(x_tok.dtype)) @ params["w_out"][e]
        return y + w_e[:, None] * out.astype(jnp.float32), None

    y0 = jnp.zeros(x_tok.shape, jnp.float32)
    y, _ = jax.lax.scan(per_expert, y0, jnp.arange(moe.num_experts))
    return y.astype(x_tok.dtype), _aux_loss(probs, idx, moe.num_experts)


# ---------------------------------------------------------------------------
# EP via all-to-all (sequence-sharded tokens)
# ---------------------------------------------------------------------------

def _a2a_quantized(x, ep_axis: str, int8: bool):
    """all_to_all with optional int8 payload (per-slot scales) — halves the
    dispatch bytes vs bf16 (DeepSeek-V3-style quantized dispatch)."""
    if not int8:
        return jax.lax.all_to_all(x, ep_axis, 0, 0, tiled=False)
    scale = jnp.maximum(jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1,
                                keepdims=True), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int8)
    rq = jax.lax.all_to_all(q, ep_axis, 0, 0, tiled=False)
    rs = jax.lax.all_to_all(scale, ep_axis, 0, 0, tiled=False)
    return (rq.astype(jnp.float32) * rs).astype(x.dtype)


def _moe_a2a_local(params, cfg, x_loc, ep_axis: str, n_shards: int,
                   a2a_int8: bool = False):
    """Runs on one shard inside shard_map. x_loc: (T_loc, d)."""
    moe = cfg.moe
    K = moe.experts_per_token
    E = moe.num_experts
    E_loc = E // n_shards
    T_loc, d = x_loc.shape

    weights, idx, probs = _route(params["router"], x_loc, K)
    aux = _aux_loss(probs, idx, E)

    # --- dispatch: pack entries per destination shard -----------------------
    flat_e = idx.reshape(-1)                                   # (T_loc*K,)
    flat_w = weights.reshape(-1)
    flat_tok = jnp.arange(T_loc * K) // K
    dest = flat_e // E_loc                                     # (T_loc*K,)
    c_send = _round_up(max(1, int(moe.capacity_factor * T_loc * K / n_shards)), 8)
    rank = _rank_in_group(dest, n_shards)
    keep = rank < c_send
    rank_c = jnp.where(keep, rank, c_send)                     # OOB -> dropped

    send_x = jnp.zeros((n_shards, c_send, d), x_loc.dtype)
    send_x = send_x.at[dest, rank_c].set(x_loc[flat_tok], mode="drop")
    send_eid = jnp.full((n_shards, c_send), -1, jnp.int32)
    send_eid = send_eid.at[dest, rank_c].set(flat_e, mode="drop")

    recv_x = _a2a_quantized(send_x, ep_axis, a2a_int8)
    recv_eid = jax.lax.all_to_all(send_eid, ep_axis, 0, 0, tiled=False)

    # --- local expert compute ------------------------------------------------
    rx = recv_x.reshape(-1, d)                                 # (n_shards*c_send, d)
    re = recv_eid.reshape(-1)
    valid = re >= 0
    eloc = jnp.where(valid, re % E_loc, 0)
    c_exp = _round_up(max(1, int(moe.capacity_factor * rx.shape[0] / E_loc)), 8)
    erank = _rank_in_group(jnp.where(valid, eloc, E_loc), E_loc + 1)
    ekeep = valid & (erank < c_exp)
    erank_c = jnp.where(ekeep, erank, c_exp)
    xbuf = jnp.zeros((E_loc, c_exp, d), x_loc.dtype)
    xbuf = xbuf.at[eloc, erank_c].set(rx, mode="drop")
    ybuf = _expert_ffn(params["w_gate"], params["w_up"], params["w_out"], xbuf)
    ry = jnp.where(ekeep[:, None], ybuf[eloc, jnp.minimum(erank_c, c_exp - 1)], 0.0)

    # --- return + combine -----------------------------------------------------
    back = _a2a_quantized(ry.reshape(n_shards, c_send, d).astype(x_loc.dtype),
                          ep_axis, a2a_int8)
    y_slot = back[dest, rank_c]                                # (T_loc*K, d)
    y_slot = jnp.where(keep[:, None], y_slot, 0.0)
    out = jnp.zeros((T_loc, d), jnp.float32)
    out = out.at[flat_tok].add(flat_w[:, None] * y_slot.astype(jnp.float32))
    return out.astype(x_loc.dtype), aux


# ---------------------------------------------------------------------------
# EP via token replication + psum (decode)
# ---------------------------------------------------------------------------

def _moe_allgather_local(params, cfg, x_loc, ep_axis: str, n_shards: int):
    """Tokens replicated over ep_axis; each shard computes its local experts
    and partial outputs are psum-combined. x_loc: (T, d)."""
    moe = cfg.moe
    K = moe.experts_per_token
    E = moe.num_experts
    E_loc = E // n_shards
    T, d = x_loc.shape
    shard = jax.lax.axis_index(ep_axis)

    weights, idx, probs = _route(params["router"], x_loc, K)
    aux = _aux_loss(probs, idx, E)

    flat_e = idx.reshape(-1)
    flat_w = weights.reshape(-1)
    flat_tok = jnp.arange(T * K) // K
    mine = (flat_e // E_loc) == shard
    eloc = jnp.where(mine, flat_e % E_loc, E_loc)
    c_exp = _round_up(max(1, int(moe.capacity_factor * T * K / E)), 8)
    rank = _rank_in_group(eloc, E_loc + 1)
    keep = mine & (rank < c_exp)
    rank_c = jnp.where(keep, rank, c_exp)
    xbuf = jnp.zeros((E_loc, c_exp, d), x_loc.dtype)
    xbuf = xbuf.at[eloc, rank_c].set(x_loc[flat_tok], mode="drop")
    ybuf = _expert_ffn(params["w_gate"], params["w_up"], params["w_out"], xbuf)
    y_slot = jnp.where(keep[:, None], ybuf[jnp.minimum(eloc, E_loc - 1),
                                           jnp.minimum(rank_c, c_exp - 1)], 0.0)
    out = jnp.zeros((T, d), jnp.float32)
    out = out.at[flat_tok].add(flat_w[:, None] * y_slot.astype(jnp.float32))
    out = jax.lax.psum(out, ep_axis)
    return out.astype(x_loc.dtype), aux


# ---------------------------------------------------------------------------
# public entry point
# ---------------------------------------------------------------------------

def moe_forward(params: Params, cfg: ModelConfig, x: jax.Array,
                mesh: Mesh | None = None,
                dp_axes: Sequence[str] = ("data",),
                ep_axis: str = "model",
                strategy: str = "auto",
                a2a_int8: bool = False) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (y, aux_loss). Adds shared-expert and dense-residual
    branches per config (these are plain TP-sharded MLPs outside the EP path).
    """
    moe = cfg.moe
    B, S, d = x.shape

    if mesh is None or ep_axis not in mesh.shape or mesh.shape[ep_axis] == 1:
        y_tok, aux = moe_reference(params, cfg, x.reshape(-1, d))
        y = y_tok.reshape(B, S, d)
    else:
        n_shards = mesh.shape[ep_axis]
        if strategy == "auto":
            strategy = "a2a" if S % n_shards == 0 and S >= n_shards else "allgather"
        expert_specs = {
            "router": P(),
            "w_gate": P(ep_axis, None, None),
            "w_up": P(ep_axis, None, None),
            "w_out": P(ep_axis, None, None),
        }
        ep_params = {k: params[k] for k in expert_specs}
        all_axes = tuple(dp_axes) + (ep_axis,)
        if strategy == "a2a":
            fn = functools.partial(_moe_a2a_local, cfg=cfg, ep_axis=ep_axis,
                                   n_shards=n_shards, a2a_int8=a2a_int8)

            def wrapper(p, xs):
                bl, sl, _ = xs.shape
                y_loc, aux_loc = fn(p, x_loc=xs.reshape(-1, d))
                return y_loc.reshape(bl, sl, d), jax.lax.pmean(aux_loc, all_axes)

            mapped = shard_map(
                wrapper, mesh=mesh,
                in_specs=({k: expert_specs[k] for k in ep_params},
                          P(tuple(dp_axes), ep_axis, None)),
                out_specs=(P(tuple(dp_axes), ep_axis, None), P()))
            y, aux = mapped(ep_params, x)
        else:
            fn = functools.partial(_moe_allgather_local, cfg=cfg, ep_axis=ep_axis,
                                   n_shards=n_shards)

            def wrapper(p, xs):
                bl, sl, _ = xs.shape
                y_loc, aux_loc = fn(p, x_loc=xs.reshape(-1, d))
                return y_loc.reshape(bl, sl, d), jax.lax.pmean(aux_loc, all_axes)

            mapped = shard_map(
                wrapper, mesh=mesh,
                in_specs=({k: expert_specs[k] for k in ep_params},
                          P(tuple(dp_axes), None, None)),
                out_specs=(P(tuple(dp_axes), None, None), P()))
            y, aux = mapped(ep_params, x)

    if moe.num_shared_experts:
        y = y + mlp(params["shared"], x, "swiglu")
    if moe.dense_residual_d_ff:
        y = y + mlp(params["dense"], x, cfg.mlp_activation)
    return y, aux
