from repro.models import attention, frontends, layers, lm, mamba2, moe, rwkv6  # noqa: F401
