"""RWKV6 (Finch) block — data-dependent per-channel decay time-mix plus
squared-relu channel-mix.

Per head (hd key channels i, hd value channels j):
    S_t[i,j] = w_t[i] * S_{t-1}[i,j] + k_t[i] v_t[j]
    y_t[j]   = sum_i r_t[i] * (S_{t-1}[i,j] + u[i] k_t[i] v_t[j])
with w_t = exp(-exp(w0 + lora(x))) in (0,1) — the data-dependent decay that
distinguishes Finch from RWKV5.

Chunked evaluation (train/prefill): within a chunk the contribution of step s
to step t>s decays by exp(Lc[t-1] - Lc[s]) per channel (Lc = cumulative log
decay). We materialize the per-channel decay tensor (every exponent <= 0, so
exact and stable) and contract; the carried state handles chunk boundaries.
Decode is the O(1)-state recurrence.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import Params, dense_init


class RWKVState(NamedTuple):
    wkv: jax.Array        # (B, H, hd, hd) f32
    shift_t: jax.Array    # (B, d) last token (time-mix shift)
    shift_c: jax.Array    # (B, d) last token (channel-mix shift)


def _dims(cfg: ModelConfig):
    hd = cfg.rwkv.head_dim
    n_heads = cfg.d_model // hd
    return n_heads, hd


def rwkv6_init(key, cfg: ModelConfig, dtype) -> Params:
    d = cfg.d_model
    n_heads, hd = _dims(cfg)
    ks = jax.random.split(key, 12)
    lora = cfg.rwkv.decay_lora
    glora = cfg.rwkv.gate_lora
    return {
        # time-mix
        "mu": (jax.random.uniform(ks[0], (5, d), jnp.float32)).astype(jnp.float32),
        "w_r": dense_init(ks[1], d, d, dtype),
        "w_k": dense_init(ks[2], d, d, dtype),
        "w_v": dense_init(ks[3], d, d, dtype),
        "w_g": dense_init(ks[4], d, d, dtype),
        "w_o": dense_init(ks[5], d, d, dtype),
        "decay_base": jnp.full((d,), -2.0, jnp.float32),          # w0
        "decay_a": dense_init(ks[6], d, lora, dtype),
        "decay_b": (jax.random.normal(ks[7], (lora, d), jnp.float32) * 0.01).astype(jnp.float32),
        "bonus": jnp.zeros((n_heads, hd), jnp.float32),           # u
        "ln_scale": jnp.ones((n_heads, hd), jnp.float32),
        # channel-mix
        "mu_c": jnp.full((2, d), 0.5, jnp.float32),
        "w_k_cm": dense_init(ks[8], d, cfg.d_ff, dtype),
        "w_v_cm": dense_init(ks[9], cfg.d_ff, d, dtype),
        "w_r_cm": dense_init(ks[10], d, d, dtype),
    }


def _shift(x: jax.Array, last: jax.Array | None):
    """Token shift: (B, S, d) -> previous token's activation."""
    pad = jnp.zeros_like(x[:, :1]) if last is None else last[:, None, :].astype(x.dtype)
    return jnp.concatenate([pad, x[:, :-1]], axis=1)


def _decay(params: Params, xw: jax.Array):
    """Data-dependent per-channel log-decay (<= 0). xw: (B,S,d) -> f32 (B,S,d)."""
    lora = jnp.tanh(xw @ params["decay_a"]).astype(jnp.float32) @ params["decay_b"]
    return -jnp.exp(params["decay_base"] + lora)


def _group_norm(y: jax.Array, scale: jax.Array, eps: float):
    """Per-head RMS norm. y: (B,S,H,hd)."""
    var = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    return y * jax.lax.rsqrt(var + eps) * scale


def _wkv_chunked(r, k, v, logw, bonus, chunk: int):
    """r,k,v: (B,S,H,hd) f32; logw: (B,S,H,hd) <= 0.

    Returns (y (B,S,H,hd) f32, final state (B,H,hd,hd))."""
    B, S, H, hd = r.shape
    L = min(chunk, S)
    S_pad = ((S + L - 1) // L) * L
    if S_pad != S:
        # inert padding: k=0 (no contribution), logw=0 (state preserved)
        pz = lambda a: jnp.pad(a, [(0, 0), (0, S_pad - S)] + [(0, 0)] * (a.ndim - 2))
        r, k, v, logw = pz(r), pz(k), pz(v), pz(logw)
    S_orig, S = S, S_pad
    nc = S // L
    rc = r.reshape(B, nc, L, H, hd).swapaxes(0, 1)
    kc = k.reshape(B, nc, L, H, hd).swapaxes(0, 1)
    vc = v.reshape(B, nc, L, H, hd).swapaxes(0, 1)
    wc = logw.reshape(B, nc, L, H, hd).swapaxes(0, 1)

    tri_lower = (jnp.arange(L)[:, None] > jnp.arange(L)[None, :])   # s < t strict

    def body(S_in, inp):
        r_l, k_l, v_l, w_l = inp                               # (B,L,H,hd)
        lc = jnp.cumsum(w_l, axis=1)                           # (B,L,H,hd) L_t
        # decay from s to t (strict): exp(L_{t-1} - L_s) = exp(L_t - w_t - L_s)
        diff = (lc - w_l)[:, :, None] - lc[:, None, :]         # (B,t,s,H,hd)
        decay = jnp.where(tri_lower[None, :, :, None, None],
                          jnp.exp(jnp.minimum(diff, 0.0)), 0.0)
        # intra-chunk strict-past contribution
        scores = jnp.einsum("bthi,btshi,bshi->bths", r_l, decay, k_l)
        y = jnp.einsum("bths,bshj->bthj", scores, v_l)
        # current-token bonus
        y += jnp.einsum("bthi,hi,bthi,bthj->bthj", r_l, bonus, k_l, v_l)
        # carried state: y_t += sum_i r[t,i] exp(L_{t-1})[i] S_in[i,j]
        rstate = r_l * jnp.exp(lc - w_l)
        y += jnp.einsum("bthi,bhij->bthj", rstate, S_in)
        # state update: S_out = diag(exp(L_L)) S_in + sum_s exp(L_L - L_s) k_s v_s
        rem = jnp.exp(lc[:, -1:] - lc)                         # (B,L,H,hd)
        S_out = jnp.exp(lc[:, -1])[..., None] * S_in + jnp.einsum(
            "bshi,bshj->bhij", rem * k_l, v_l)
        return S_out, y

    S0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    S_fin, yc = jax.lax.scan(body, S0, (rc, kc, vc, wc))
    return yc.swapaxes(0, 1).reshape(B, S, H, hd)[:, :S_orig], S_fin


def _time_mix_inputs(params, cfg, x, last):
    xx = _shift(x, last)
    sx = (xx - x).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    mixed = xf[None] + params["mu"][:, None, None, :] * sx[None]  # (5,B,S,d)
    xr, xk, xv, xw, xg = [m.astype(x.dtype) for m in mixed]
    return xr, xk, xv, xw, xg


def rwkv6_time_mix(params: Params, cfg: ModelConfig, x: jax.Array,
                   state: RWKVState | None = None, return_state: bool = False):
    B, S, d = x.shape
    H, hd = _dims(cfg)
    last = None if state is None else state.shift_t
    xr, xk, xv, xw, xg = _time_mix_inputs(params, cfg, x, last)
    r = (xr @ params["w_r"]).reshape(B, S, H, hd).astype(jnp.float32)
    k = (xk @ params["w_k"]).reshape(B, S, H, hd).astype(jnp.float32)
    v = (xv @ params["w_v"]).reshape(B, S, H, hd).astype(jnp.float32)
    g = jax.nn.silu(xg @ params["w_g"])
    logw = _decay(params, xw).reshape(B, S, H, hd)
    S_in = None if state is None else state.wkv
    if S_in is None:
        y, S_fin = _wkv_chunked(r, k, v, logw, params["bonus"], chunk=64)
    else:
        # continuation path (used by tests): fold carried state step-by-step
        def step(Sc, inp):
            r_t, k_t, v_t, w_t = inp
            y_t = jnp.einsum("bhi,bhij->bhj", r_t, Sc) + (
                jnp.einsum("bhi,hi,bhi,bhj->bhj", r_t, params["bonus"], k_t, v_t))
            Sc = jnp.exp(w_t)[..., None] * Sc + jnp.einsum("bhi,bhj->bhij", k_t, v_t)
            return Sc, y_t
        S_fin, y = jax.lax.scan(
            step, S_in,
            (r.swapaxes(0, 1), k.swapaxes(0, 1), v.swapaxes(0, 1), logw.swapaxes(0, 1)))
        y = y.swapaxes(0, 1)
    y = _group_norm(y, params["ln_scale"], cfg.norm_eps).reshape(B, S, d)
    out = (y.astype(x.dtype) * g) @ params["w_o"]
    if return_state:
        return out, S_fin, x[:, -1]
    return out


def rwkv6_channel_mix(params: Params, cfg: ModelConfig, x: jax.Array,
                      state: RWKVState | None = None, return_state: bool = False):
    last = None if state is None else state.shift_c
    xx = _shift(x, last)
    sx = (xx - x).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    xk = (xf + params["mu_c"][0] * sx).astype(x.dtype)
    xr = (xf + params["mu_c"][1] * sx).astype(x.dtype)
    vv = jnp.square(jax.nn.relu(xk @ params["w_k_cm"])) @ params["w_v_cm"]
    out = jax.nn.sigmoid((xr @ params["w_r_cm"]).astype(jnp.float32)).astype(x.dtype) * vv
    if return_state:
        return out, x[:, -1]
    return out


def init_rwkv_state(cfg: ModelConfig, batch: int) -> RWKVState:
    H, hd = _dims(cfg)
    d = cfg.d_model
    dt = jnp.dtype(cfg.dtype)
    return RWKVState(
        wkv=jnp.zeros((batch, H, hd, hd), jnp.float32),
        shift_t=jnp.zeros((batch, d), dt),
        shift_c=jnp.zeros((batch, d), dt),
    )
