"""Mamba2 (SSD) block — chunked parallel scan for train/prefill, O(1)-state
recurrent step for decode.

State-space recurrence per head h (P channels, N state):
    h_t = a_t * h_{t-1} + dt_t * (B_t outer x_t)      a_t = exp(dt_t * A_h), A_h < 0
    y_t = C_t . h_t + D_h * x_t

The chunked (SSD) algorithm computes, per chunk of length L:
  intra:  Y[t] += sum_{s<=t} (C_t . B_s) exp(l_t - l_s) dt_s x_s
  inter:  Y[t] += exp(l_t) * (C_t . h_in)
  carry:  h_out = exp(l_L) h_in + sum_s exp(l_L - l_s) dt_s (B_s outer x_s)
with l_t the within-chunk cumulative log-decay (computed in f32; every
exponent is <= 0 so the exps are stable).

Projections are kept as separate matrices (w_z/w_x/w_b/w_c/w_dt rather than
one fused in-proj) so each can carry its own TP PartitionSpec with shard
boundaries aligned to its semantic dimension; the depthwise conv is likewise
split per stream (identical math — depthwise convs commute with concat).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import Params, dense_init, rmsnorm, rmsnorm_init


class MambaState(NamedTuple):
    ssm: jax.Array        # (B, H, P, N) f32
    conv_x: jax.Array     # (B, W-1, d_inner) rolling raw inputs
    conv_b: jax.Array     # (B, W-1, N)
    conv_c: jax.Array     # (B, W-1, N)


def _dims(cfg: ModelConfig):
    ssm = cfg.ssm
    d_inner = ssm.expand * cfg.d_model
    n_heads = d_inner // ssm.head_dim
    return d_inner, n_heads


def mamba2_init(key, cfg: ModelConfig, dtype) -> Params:
    ssm = cfg.ssm
    d_inner, n_heads = _dims(cfg)
    ks = jax.random.split(key, 9)
    conv_init = lambda k, c: (jax.random.normal(k, (ssm.conv_width, c), jnp.float32)
                              * ssm.conv_width ** -0.5).astype(dtype)
    return {
        "w_z": dense_init(ks[0], cfg.d_model, d_inner, dtype),
        "w_x": dense_init(ks[1], cfg.d_model, d_inner, dtype),
        "w_b": dense_init(ks[2], cfg.d_model, ssm.state_dim, dtype),
        "w_c": dense_init(ks[3], cfg.d_model, ssm.state_dim, dtype),
        "w_dt": dense_init(ks[4], cfg.d_model, n_heads, dtype),
        "conv_x": conv_init(ks[5], d_inner),
        "conv_b": conv_init(ks[6], ssm.state_dim),
        "conv_c": conv_init(ks[7], ssm.state_dim),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads, dtype=jnp.float32)),
        "d_skip": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "norm": rmsnorm_init(d_inner),
        "w_out": dense_init(ks[8], d_inner, cfg.d_model, dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array, state=None):
    """Depthwise causal conv via shifted adds + silu. x: (B, S, C); w: (W, C).

    ``state``: (B, W-1, C) past raw inputs (decode). Returns (y, new_state)."""
    W = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)                    # (B, S+W-1, C)
    S = x.shape[1]
    y = sum(xp[:, i:i + S, :] * w[i] for i in range(W))
    y = jax.nn.silu(y.astype(jnp.float32)).astype(x.dtype)
    return y, xp[:, -(W - 1):, :]


def _ssd_chunked(x, b_mat, c_mat, dt, a_log, chunk: int):
    """x: (B,S,H,P); b_mat/c_mat: (B,S,N); dt: (B,S,H) f32.

    Returns (y (B,S,H,P) f32, h_final (B,H,P,N) f32)."""
    B, S, H, P = x.shape
    N = b_mat.shape[-1]
    L = min(chunk, S)
    S_pad = ((S + L - 1) // L) * L
    if S_pad != S:
        # pad with inert steps: x=0 (no contribution), dt=0 => decay exp(0)=1
        # (state preserved), so the returned state is exact.
        pz = lambda a: jnp.pad(a, [(0, 0), (0, S_pad - S)] + [(0, 0)] * (a.ndim - 2))
        x, b_mat, c_mat, dt = pz(x), pz(b_mat), pz(c_mat), pz(dt)
    S_orig, S = S, S_pad
    nc = S // L

    a = -jnp.exp(a_log)                                       # (H,) negative
    loga_step = dt * a                                        # (B,S,H) <= 0
    xf = x.astype(jnp.float32)
    bf = b_mat.astype(jnp.float32)
    cf = c_mat.astype(jnp.float32)

    def r(t):  # reshape into chunks
        return t.reshape(t.shape[0], nc, L, *t.shape[2:])

    xc, bc, cc = r(xf), r(bf), r(cf)
    dtc, logc = r(dt), r(loga_step)

    def body(h, inp):
        x_l, b_l, c_l, dt_l, lg = inp                         # (B,L,...)
        l_cum = jnp.cumsum(lg, axis=1)                        # (B,L,H)
        # intra-chunk
        cb = jnp.einsum("bln,bsn->bls", c_l, b_l)             # (B,L,L)
        diff = l_cum[:, :, None, :] - l_cum[:, None, :, :]    # (B,L,L,H) t,s
        mask = (jnp.arange(L)[:, None] >= jnp.arange(L)[None, :])
        decay = jnp.where(mask[None, :, :, None], jnp.exp(jnp.minimum(diff, 0.0)), 0.0)
        scores = cb[:, :, :, None] * decay                    # (B,L,L,H)
        dtx = dt_l[..., None] * x_l                           # (B,L,H,P)
        y = jnp.einsum("blsh,bshp->blhp", scores, dtx)
        # inter-chunk (carried state)
        y += jnp.exp(l_cum)[..., None] * jnp.einsum("bln,bhpn->blhp", c_l, h)
        # state update
        rem = jnp.exp(l_cum[:, -1:, :] - l_cum)               # (B,L,H)
        h_new = jnp.exp(l_cum[:, -1])[:, :, None, None] * h + jnp.einsum(
            "bsh,bsn,bshp->bhpn", rem * dt_l, b_l, x_l)
        return h_new, y

    h0 = jnp.zeros((B, H, P, N), jnp.float32)
    h_final, yc = jax.lax.scan(
        body, h0,
        (xc.swapaxes(0, 1), bc.swapaxes(0, 1), cc.swapaxes(0, 1),
         dtc.swapaxes(0, 1), logc.swapaxes(0, 1)))
    y = yc.swapaxes(0, 1).reshape(B, S, H, P)[:, :S_orig]
    return y, h_final


def _projections(params: Params, x: jax.Array, state: MambaState | None):
    z = x @ params["w_z"]
    x_in = x @ params["w_x"]
    b_in = x @ params["w_b"]
    c_in = x @ params["w_c"]
    dt = x @ params["w_dt"]
    sx = None if state is None else state.conv_x
    sb = None if state is None else state.conv_b
    sc = None if state is None else state.conv_c
    x_ssm, nx = _causal_conv(x_in, params["conv_x"], sx)
    b_mat, nb = _causal_conv(b_in, params["conv_b"], sb)
    c_mat, nc = _causal_conv(c_in, params["conv_c"], sc)
    return z, x_ssm, b_mat, c_mat, dt, (nx, nb, nc)


def mamba2_forward(params: Params, cfg: ModelConfig, x: jax.Array,
                   return_state: bool = False):
    """Full-sequence Mamba2 block. x: (B, S, d_model)."""
    ssm = cfg.ssm
    d_inner, n_heads = _dims(cfg)
    B, S, _ = x.shape
    z, x_ssm, b_mat, c_mat, dt, conv_states = _projections(params, x, None)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    xh = x_ssm.reshape(B, S, n_heads, ssm.head_dim)
    y, h = _ssd_chunked(xh, b_mat, c_mat, dt, params["a_log"], ssm.chunk_size)
    y = y + params["d_skip"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(B, S, d_inner).astype(x.dtype)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = y @ params["w_out"]
    if return_state:
        nx, nb, nc = conv_states
        return out, MambaState(ssm=h, conv_x=nx, conv_b=nb, conv_c=nc)
    return out


def mamba2_step(params: Params, cfg: ModelConfig, x: jax.Array, state: MambaState):
    """Single-token decode. x: (B, 1, d_model) -> (y, new_state)."""
    ssm = cfg.ssm
    d_inner, n_heads = _dims(cfg)
    B = x.shape[0]
    z, x_ssm, b_mat, c_mat, dt, conv_states = _projections(params, x, state)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])[:, 0]  # (B,H)
    xh = x_ssm.reshape(B, n_heads, ssm.head_dim).astype(jnp.float32)
    bf = b_mat[:, 0].astype(jnp.float32)                      # (B,N)
    cf = c_mat[:, 0].astype(jnp.float32)
    a_step = jnp.exp(dt * -jnp.exp(params["a_log"]))          # (B,H)
    h = state.ssm * a_step[..., None, None] + jnp.einsum(
        "bh,bn,bhp->bhpn", dt, bf, xh)
    y = jnp.einsum("bn,bhpn->bhp", cf, h) + params["d_skip"][None, :, None] * xh
    y = y.reshape(B, 1, d_inner).astype(x.dtype)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    nx, nb, nc = conv_states
    return y @ params["w_out"], MambaState(ssm=h, conv_x=nx, conv_b=nb, conv_c=nc)


def init_mamba_state(cfg: ModelConfig, batch: int) -> MambaState:
    ssm = cfg.ssm
    d_inner, n_heads = _dims(cfg)
    dt = jnp.dtype(cfg.dtype)
    w1 = ssm.conv_width - 1
    return MambaState(
        ssm=jnp.zeros((batch, n_heads, ssm.head_dim, ssm.state_dim), jnp.float32),
        conv_x=jnp.zeros((batch, w1, d_inner), dt),
        conv_b=jnp.zeros((batch, w1, ssm.state_dim), dt),
        conv_c=jnp.zeros((batch, w1, ssm.state_dim), dt),
    )
