"""GQA/MQA causal attention with blockwise (online-softmax) prefill and a
KV-cache decode step.

Prefill never materializes the (S, S) score matrix: it streams KV chunks with
a running (max, sum, acc) online softmax — flash attention expressed in XLA.
Two schedules are provided:

* rectangular (baseline): every (q-chunk, kv-chunk) pair is computed and the
  causal mask zeroes the upper triangle — ~2x the useful FLOPs.
* triangular (``causal_skip=True``): a scan over the static list of valid
  (i, j<=i) chunk pairs — exact-FLOP causal attention, the §Perf optimization.

Decode attends one new token against a (possibly sequence-sharded) cache; the
softmax reduction over the sharded length axis is left to the SPMD partitioner
(log-sum-exp merge == flash-decode).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.layers import Params, apply_rope, dense_init

NEG_INF = -1e30


class KVCache(NamedTuple):
    k: jax.Array          # (B, S_max, KV, hd)
    v: jax.Array          # (B, S_max, KV, hd)


def attention_init(key, d_model: int, num_heads: int, num_kv_heads: int,
                   head_dim: int, dtype) -> Params:
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": dense_init(kq, d_model, num_heads * head_dim, dtype).reshape(
            d_model, num_heads, head_dim),
        "wk": dense_init(kk, d_model, num_kv_heads * head_dim, dtype).reshape(
            d_model, num_kv_heads, head_dim),
        "wv": dense_init(kv, d_model, num_kv_heads * head_dim, dtype).reshape(
            d_model, num_kv_heads, head_dim),
        "wo": (dense_init(ko, num_heads * head_dim, d_model, dtype)).reshape(
            num_heads, head_dim, d_model),
    }


def _qkv(params: Params, x: jax.Array, positions: jax.Array, rope_theta: float):
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    q = apply_rope(q, positions, rope_theta)
    k = apply_rope(k, positions, rope_theta)
    return q, k, v


def _grouped_scores(q: jax.Array, k: jax.Array) -> jax.Array:
    """q: (B, Sq, KV, G, hd), k: (B, Sk, KV, hd) -> (B, KV, G, Sq, Sk)."""
    return jnp.einsum("bqhgk,bshk->bhgqs", q, k)


def _grouped_out(p: jax.Array, v: jax.Array) -> jax.Array:
    """p: (B, KV, G, Sq, Sk), v: (B, Sk, KV, hd) -> (B, Sq, KV, G, hd)."""
    return jnp.einsum("bhgqs,bshk->bqhgk", p, v)


def _online_step(carry, k_blk, v_blk, q, mask, p_bf16: bool = False):
    """One online-softmax accumulation step.

    carry: (acc (B,KV,G,Sq,hd) f32, m (B,KV,G,Sq) f32, l (B,KV,G,Sq) f32)
    """
    acc, m, l = carry
    s = _grouped_scores(q, k_blk).astype(jnp.float32)           # (B,KV,G,Sq,Kc)
    s = jnp.where(mask, s, NEG_INF)
    m_new = jnp.maximum(m, jnp.max(s, axis=-1))
    alpha = jnp.exp(m - m_new)
    # guard the fully-masked case (s == m_new == NEG_INF would give exp(0)=1)
    p = jnp.where(s > NEG_INF / 2, jnp.exp(s - m_new[..., None]), 0.0)
    l = l * alpha + jnp.sum(p, axis=-1)
    # §Perf knob: p round-trips HBM between the two matmuls at XLA fusion
    # granularity; storing it in the model dtype halves that dominant traffic
    # while (acc, l) still accumulate in f32.
    p = p.astype(v_blk.dtype) if p_bf16 else p
    pv = _grouped_out(p, v_blk).astype(jnp.float32)
    acc = acc * alpha[..., None] + pv.transpose(0, 2, 3, 1, 4)
    return acc, m_new, l


def blockwise_causal_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                               chunk: int = 1024, causal_skip: bool = False,
                               p_bf16: bool = False) -> jax.Array:
    """q,k,v: (B, S, H|KV, hd) post-rope. Returns (B, S, H, hd).

    Streams KV in ``chunk``-sized blocks with an online softmax; optionally
    skips fully-masked chunk pairs (triangular schedule).
    """
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = hd ** -0.5
    chunk = min(chunk, S)
    S_pad = ((S + chunk - 1) // chunk) * chunk
    if S_pad != S:
        # pad with future positions: causal masking (kpos <= qpos < S) keeps
        # them invisible to every real query; padded q rows are sliced off.
        pz = lambda a: jnp.pad(a, [(0, 0), (0, S_pad - S), (0, 0), (0, 0)])
        q, k, v = pz(q), pz(k), pz(v)
    S_orig, S = S, S_pad
    q = (q * scale).reshape(B, S, KV, G, hd)
    nc = S // chunk

    qc = q.reshape(B, nc, chunk, KV, G, hd)
    kc = k.reshape(B, nc, chunk, KV, hd)
    vc = v.reshape(B, nc, chunk, KV, hd)
    # position indices of each element within a chunk
    pos_in = jnp.arange(chunk)

    def init_carry():
        acc = jnp.zeros((B, KV, G, chunk, hd), jnp.float32)
        m = jnp.full((B, KV, G, chunk), NEG_INF, jnp.float32)
        l = jnp.zeros((B, KV, G, chunk), jnp.float32)
        return acc, m, l

    def finish(acc, m, l):
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        # (B,KV,G,chunk,hd) -> (B,chunk,KV,G,hd)
        return out.transpose(0, 3, 1, 2, 4)

    if not causal_skip:
        # rectangular: for each q chunk scan all kv chunks with causal mask
        def per_q(i, q_i):
            def body(carry, j_kv):
                j, k_j, v_j = j_kv
                qpos = i * chunk + pos_in[:, None]
                kpos = j * chunk + pos_in[None, :]
                mask = (kpos <= qpos)[None, None, None]          # (1,1,1,Sq,Kc)
                return _online_step(carry, k_j, v_j, q_i, mask, p_bf16), None

            (acc, m, l), _ = jax.lax.scan(
                body, init_carry(),
                (jnp.arange(nc), kc.swapaxes(0, 1), vc.swapaxes(0, 1)))
            return finish(acc, m, l)

        out = jax.lax.map(lambda args: per_q(*args),
                          (jnp.arange(nc), qc.swapaxes(0, 1)))
        out = out.swapaxes(0, 1).reshape(B, S, KV, G, hd)[:, :S_orig]
        return out.reshape(B, S_orig, H, hd).astype(v.dtype)

    # triangular: scan over the static (i, j<=i) pair list, carrying the
    # running softmax state of the current q row; flush when j == i.
    pairs_i = jnp.array([i for i in range(nc) for _ in range(i + 1)])
    pairs_j = jnp.array([j for i in range(nc) for j in range(i + 1)])

    out0 = jnp.zeros((nc, B, chunk, KV, G, hd), jnp.float32)

    def body(carry, ij):
        i, j = ij
        acc, m, l, out = carry
        q_i = jax.lax.dynamic_index_in_dim(qc, i, axis=1, keepdims=False)
        k_j = jax.lax.dynamic_index_in_dim(kc, j, axis=1, keepdims=False)
        v_j = jax.lax.dynamic_index_in_dim(vc, j, axis=1, keepdims=False)
        diag = i == j
        qpos = i * chunk + pos_in[:, None]
        kpos = j * chunk + pos_in[None, :]
        mask = (kpos <= qpos)[None, None, None]
        acc, m, l = _online_step((acc, m, l), k_j, v_j, q_i, mask, p_bf16)
        flushed = finish(acc, m, l)
        out = jax.lax.cond(
            diag,
            lambda o: jax.lax.dynamic_update_index_in_dim(o, flushed, i, axis=0),
            lambda o: o, out)
        # reset the carry after a flush
        acc = jnp.where(diag, 0.0, 1.0) * acc
        m = jnp.where(diag, NEG_INF, m)
        l = jnp.where(diag, 0.0, l)
        return (acc, m, l, out), None

    init = (*init_carry(), out0)
    (_, _, _, out), _ = jax.lax.scan(body, init, (pairs_i, pairs_j))
    out = out.swapaxes(0, 1).reshape(B, S, KV, G, hd)[:, :S_orig]
    return out.reshape(B, S_orig, H, hd).astype(v.dtype)


def attention_prefill(params: Params, x: jax.Array, positions: jax.Array,
                      rope_theta: float, chunk: int = 1024,
                      causal_skip: bool = False, p_bf16: bool = False,
                      impl: str = "xla",
                      return_cache: bool = False):
    """Full-sequence causal attention. x: (B, S, d). ``impl``: 'xla'
    (blockwise online-softmax scan) or 'flash' (Pallas kernel — VMEM-resident
    score tiles; forward-only, so serving paths only)."""
    q, k, v = _qkv(params, x, positions, rope_theta)
    if impl == "flash":
        from repro.kernels import ops
        out = ops.flash_attention(q, k, v, bq=min(chunk, 512), bk=min(chunk, 512))
    else:
        out = blockwise_causal_attention(q, k, v, chunk=chunk,
                                         causal_skip=causal_skip, p_bf16=p_bf16)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    if return_cache:
        return y, KVCache(k=k, v=v)
    return y


def attention_decode(params: Params, x: jax.Array, cache: KVCache, pos,
                     rope_theta: float, active: Optional[jax.Array] = None):
    """One-token decode. x: (B, 1, d); cache holds S_max past positions;
    ``pos`` is the new token's index — scalar or per-row (B,) vector
    (continuous batching). Rows with ``active`` False leave the cache
    untouched (their writes land out of bounds and drop).

    Returns (y (B, 1, d), updated cache). The softmax statistics reduce over
    the cache length axis; when that axis is mesh-sharded the partitioner
    emits the log-sum-exp combine (flash-decode).
    """
    B, _, d = x.shape
    S_max = cache.k.shape[1]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    positions = pos[:, None]
    q, k_new, v_new = _qkv(params, x, positions, rope_theta)
    write = pos if active is None else jnp.where(active, pos, S_max)
    # per-row cache insert as a fused select (a bf16 scatter would upcast to
    # f32 on some backends and force a whole-cache convert in the layer loop)
    sel = (jnp.arange(S_max)[None, :] == write[:, None])[:, :, None, None]
    k = jnp.where(sel, k_new.astype(cache.k.dtype), cache.k)
    v = jnp.where(sel, v_new.astype(cache.v.dtype), cache.v)

    KV = k.shape[2]
    H = q.shape[2]
    G = H // KV
    hd = q.shape[3]
    qg = (q * hd ** -0.5).reshape(B, 1, KV, G, hd)
    s = _grouped_scores(qg, k).astype(jnp.float32)            # (B,KV,G,1,S)
    valid = (jnp.arange(S_max)[None, :] <= pos[:, None])[:, None, None, None, :]
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = _grouped_out(p.astype(v.dtype), v)                  # (B,1,KV,G,hd)
    y = jnp.einsum("bshk,hkd->bsd", out.reshape(B, 1, H, hd), params["wo"])
    return y, KVCache(k=k, v=v)
