"""Loop-aware HLO analysis: per-device FLOPs, HBM traffic, and collective
bytes from the compiled (SPMD, per-device) module text.

Why not ``compiled.cost_analysis()``? Two measured facts (see EXPERIMENTS.md
§Dry-run methodology): (1) HloCostAnalysis visits a ``while`` body ONCE —
a scan over 95 layers is undercounted 95x; (2) it has no collective term.

This parser:
  * builds name -> (dtype, dims) for every instruction,
  * per computation, tallies dot FLOPs (2 * numel(out) * prod(contracting
    dims)), fusion-boundary IO bytes (operands + result of each top-level
    op ~= HBM round trips on TPU), and collective operand bytes,
  * expands ``while`` bodies by trip count (recovered from the loop
    condition's comparison constant), ``conditional`` branches at 1x, and
    descends into fusions for FLOPs only (a fusion is one HBM-level op).

All numbers are per device (the SPMD module is the per-device program).
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute", "ragged-all-to-all")

# ops that are free at the HBM level (layout/book-keeping)
_FREE_OPS = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
             "after-all", "partition-id", "replica-id", "iota", "while",
             "conditional", "call", "custom-call"}

# elementwise ops: the CPU backend leaves many of these at top level, but the
# TPU backend fuses them into their producers/consumers — charging them would
# overcount HBM traffic ~50x (measured; see EXPERIMENTS.md). Their operand
# traffic is captured by the producing dot/fusion/reduce ops.
_FUSABLE_OPS = {"convert", "add", "subtract", "multiply", "divide", "select",
                "compare", "maximum", "minimum", "clamp", "broadcast",
                "reshape", "transpose", "negate", "exponential", "log",
                "tanh", "rsqrt", "sqrt", "power", "and", "or", "not", "xor",
                "abs", "sign", "floor", "ceil", "round-nearest-afz",
                "shift-left", "shift-right-logical", "shift-right-arithmetic",
                "logistic", "cosine", "sine", "exponential-minus-one",
                "log-plus-one", "is-finite", "popcnt", "remainder", "atan2",
                "reverse", "rng-bit-generator", "rng", "map", "expm1",
                "log1p"}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_NAME_EQ_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*")
_OPCODE_RE = re.compile(r"\s*([\w\-]+)")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%([\w.\-]+)\s*\(")
_TRIP_RE = re.compile(r'"known_trip_count"\s*:\s*\{\s*"n"\s*:\s*"(\d+)"')
_WHILE_ATTR_RE = re.compile(r"(condition|body)=%?([\w.\-]+)")
_CALLS_ATTR_RE = re.compile(r"calls=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRUEFALSE_RE = re.compile(r"(?:true_computation|false_computation)=%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_LHS_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _parse_def(ln: str):
    """Parse '  %name = <type> opcode(...)' robustly (tuple types may contain
    /*index=N*/ comments, so a pure regex on '=' fails). Returns
    (name, type_str, opcode) or None."""
    m = _NAME_EQ_RE.match(ln)
    if not m:
        return None
    rest = ln[m.end():]
    if rest.startswith("("):
        depth = 0
        end = -1
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        if end < 0:
            return None
        type_str, rest2 = rest[:end + 1], rest[end + 1:]
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        type_str, rest2 = rest[:sp], rest[sp:]
    om = _OPCODE_RE.match(rest2)
    if not om:
        return None
    return m.group(1), type_str, om.group(1)


def _shapes_in(type_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dtype, dims in _SHAPE_RE.findall(type_str):
        out.append((dtype, [int(d) for d in dims.split(",") if d]))
    return out


def _type_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _shapes_in(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dtype]
    return total


def _numel(type_str: str) -> int:
    total = 0
    for _, dims in _shapes_in(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n
    return total


def _split_computations(hlo_text: str) -> Dict[str, List[str]]:
    """Computations start at column 0 with ``%name (`` or ``ENTRY %name (``
    and close with a column-0 ``}``."""
    comps: Dict[str, List[str]] = {}
    cur_name, cur_lines = None, []
    for ln in hlo_text.splitlines():
        if cur_name is None:
            if (ln.startswith("%") or ln.startswith("ENTRY ")) and \
                    ln.rstrip().endswith("{"):
                m = _COMP_HDR_RE.match(ln)
                if m:
                    cur_name, cur_lines = m.group(1), []
        else:
            if ln.startswith("}"):
                comps[cur_name] = cur_lines
                cur_name = None
            else:
                cur_lines.append(ln)
    return comps


def _operand_names(ln: str, opcode: str) -> List[str]:
    paren = ln.find(opcode + "(")
    if paren < 0:
        return []
    args = ln[paren + len(opcode) + 1:]
    depth, buf = 1, []
    for ch in args:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        buf.append(ch)
    args_str = "".join(buf)
    # Newer XLA prints operand types inline ("f32[128,128]{1,0} %name");
    # when %-prefixed names are present, take only those, else the bare
    # dtype/dim tokens would shadow the real operand names.
    named = re.findall(r"%([\w.\-]+)", args_str)
    if named:
        return named
    return re.findall(r"([\w.\-]+)", args_str)


def _trip_count(cond_lines: List[str]) -> int:
    best = 1
    for ln in cond_lines:
        if "compare" in ln or "constant" in ln:
            for c in _CONST_RE.findall(ln):
                best = max(best, int(c))
    return best


_NONCOMPUTE = {"parameter", "constant", "bitcast", "tuple",
               "get-tuple-element", "convert", "broadcast", "reshape", "copy",
               "transpose"}


def _fusion_kind(ln: str, comps, callees) -> str:
    """Classify a fusion via its callee computation: 'convert' when the body
    is conversions/layout only; 'dus:<update_bytes>' when the root is a
    dynamic-update-slice; '' otherwise."""
    for callee in callees:
        lines = comps.get(callee)
        if not lines:
            continue
        opcodes = []
        root_def = None
        for cl in lines:
            d = _parse_def(cl)
            if d:
                opcodes.append(d[2])
                if cl.lstrip().startswith("ROOT"):
                    root_def = (cl, d)
        if opcodes and all(o in _NONCOMPUTE for o in opcodes):
            return "convert"
        if root_def and root_def[1][2] == "dynamic-update-slice":
            ops_ = _operand_names(root_def[0], "dynamic-update-slice")
            if len(ops_) > 1:
                # update operand's type defined inside the callee
                upd_type = None
                for cl in lines:
                    d = _parse_def(cl)
                    if d and d[0] == ops_[1]:
                        upd_type = d[1]
                        break
                if upd_type:
                    return f"dus:{_type_bytes(upd_type)}"
    return ""


class ModuleStats(dict):
    """{'flops', 'io_bytes', 'coll_bytes': {kind: b, 'total': b},
    'coll_counts': {kind: n}} — all per device, loop-expanded."""


def analyze(hlo_text: str) -> ModuleStats:
    comps = _split_computations(hlo_text)

    types: Dict[str, str] = {}
    for lines in comps.values():
        for ln in lines:
            d = _parse_def(ln)
            if d:
                types[d[0]] = d[1]

    def bytes_of(name: str) -> int:
        return _type_bytes(types.get(name, ""))

    local = {}
    for name, lines in comps.items():
        flops = 0.0
        io = 0.0
        coll_b = defaultdict(float)
        coll_c = defaultdict(float)
        loop_children: List[Tuple[float, str]] = []
        branch_children: List[Tuple[float, str]] = []
        fusion_children: List[Tuple[float, str]] = []
        for ln in lines:
            d = _parse_def(ln)
            if not d:
                continue
            out_name, out_type, opcode = d
            base = opcode[:-6] if opcode.endswith("-start") else opcode
            if opcode.endswith("-done"):
                continue
            if base in COLLECTIVES:
                nb = sum(bytes_of(n) for n in _operand_names(ln, opcode))
                if nb == 0:
                    nb = _type_bytes(out_type)
                coll_b[base] += nb
                coll_c[base] += 1
                io += nb + _type_bytes(out_type)
                continue
            if base == "while":
                attrs = dict(_WHILE_ATTR_RE.findall(ln))
                tm = _TRIP_RE.search(ln)    # XLA annotates known_trip_count
                trip = int(tm.group(1)) if tm else _trip_count(
                    comps.get(attrs.get("condition", ""), []))
                if "body" in attrs:
                    loop_children.append((float(trip), attrs["body"]))
                continue
            if base == "conditional":
                for grp in _BRANCH_RE.findall(ln):
                    for n in re.findall(r"%?([\w.\-]+)", grp):
                        branch_children.append((1.0, n))
                for n in _TRUEFALSE_RE.findall(ln):
                    branch_children.append((1.0, n))
                continue
            if base == "dot":
                ops = _operand_names(ln, opcode)
                cdims = _LHS_CDIMS_RE.search(ln)
                csize = 1
                if cdims and ops:
                    lhs_shapes = _shapes_in(types.get(ops[0], ""))
                    if lhs_shapes:
                        _, lhs_dims = lhs_shapes[0]
                        for ci in (int(c) for c in cdims.group(1).split(",") if c):
                            if ci < len(lhs_dims):
                                csize *= lhs_dims[ci]
                flops += 2.0 * _numel(out_type) * csize
                io += _type_bytes(out_type) + sum(bytes_of(n) for n in ops[:2])
                continue
            if base == "fusion":
                for callee in _CALLS_ATTR_RE.findall(ln):
                    fusion_children.append((1.0, callee))
                # producer-once accounting: a fusion's operands were already
                # charged at their producers; only its materialized OUTPUT is
                # new HBM traffic. Two backend-artifact exemptions:
                #  * convert-only fusions (CPU upcasts bf16 params to f32 at
                #    the top level; on TPU these fuse into consumers): free;
                #  * fusions whose root is a dynamic-update-slice (scan-ys
                #    stacking / in-place cache writes): charge the update
                #    slice, not the whole aliased buffer.
                kind = _fusion_kind(ln, comps, _CALLS_ATTR_RE.findall(ln))
                if kind == "convert":
                    continue
                if kind and kind.startswith("dus:"):
                    io += 2 * int(kind.split(":")[1])
                    continue
                io += _type_bytes(out_type)
                continue
            if base == "call":
                for callee in _CALLS_ATTR_RE.findall(ln) or \
                        [n for n in _operand_names(ln, opcode) if n in comps]:
                    loop_children.append((1.0, callee))
                continue
            if base in _FREE_OPS or base in _FUSABLE_OPS:
                continue
            if base in ("slice", "dynamic-slice", "gather"):
                # reads only the sliced/gathered rows, not the whole operand
                io += 2 * _type_bytes(out_type)
                continue
            if base == "dynamic-update-slice":
                # in-place (aliased) update: touches only the update operand
                ops_ = _operand_names(ln, opcode)
                upd = bytes_of(ops_[1]) if len(ops_) > 1 else 0
                io += 2 * upd
                continue
            if base == "scatter":
                ops_ = _operand_names(ln, opcode)
                upd = sum(bytes_of(n) for n in ops_[1:])
                io += 2 * upd
                continue
            # generic top-level op: operands + result round-trip HBM
            io += _type_bytes(out_type) + sum(
                bytes_of(n) for n in _operand_names(ln, opcode))
        local[name] = dict(flops=flops, io=io, coll_b=dict(coll_b),
                           coll_c=dict(coll_c), loops=loop_children,
                           branches=branch_children, fusions=fusion_children)

    memo: Dict[str, dict] = {}

    def expand(name: str, stack=()):
        if name in memo:
            return memo[name]
        if name in stack or name not in local:
            return dict(flops=0.0, io=0.0, coll_b={}, coll_c={})
        loc = local[name]
        flops, io = loc["flops"], loc["io"]
        coll_b = defaultdict(float, loc["coll_b"])
        coll_c = defaultdict(float, loc["coll_c"])
        for mult, child in loc["loops"] + loc["branches"]:
            sub = expand(child, stack + (name,))
            flops += mult * sub["flops"]
            io += mult * sub["io"]
            for k, v in sub["coll_b"].items():
                coll_b[k] += mult * v
            for k, v in sub["coll_c"].items():
                coll_c[k] += mult * v
        for mult, child in loc["fusions"]:
            sub = expand(child, stack + (name,))
            flops += mult * sub["flops"]    # FLOPs only — IO seen at call site
        res = dict(flops=flops, io=io, coll_b=dict(coll_b), coll_c=dict(coll_c))
        memo[name] = res
        return res

    entry = None
    for ln in hlo_text.splitlines():
        if ln.startswith("ENTRY"):
            m = re.match(r"ENTRY\s+%?([\w.\-]+)", ln)
            if m:
                entry = m.group(1)
        if entry:
            break
    if entry is None or entry not in local:
        entry = max(local, key=lambda n: local[n]["flops"] + local[n]["io"]) \
            if local else None
    if entry is None:
        return ModuleStats(flops=0.0, io_bytes=0.0,
                           coll_bytes={"total": 0.0}, coll_counts={})
    res = expand(entry)
    coll_b = dict(res["coll_b"])
    coll_b["total"] = sum(coll_b.values())
    return ModuleStats(flops=res["flops"], io_bytes=res["io"],
                       coll_bytes=coll_b, coll_counts=dict(res["coll_c"]))


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    return analyze(hlo_text)["coll_bytes"]


def collective_counts(hlo_text: str) -> Dict[str, float]:
    return analyze(hlo_text)["coll_counts"]
