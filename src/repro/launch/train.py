"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch gemma-2b \
        --shape train_4k --steps 200 --ckpt-dir /ckpt/run1 [--scaled]

On real hardware this runs under `jax.distributed.initialize()` (one process
per host); in this container use --scaled for a CPU-feasible reduced config
on a (1,1) mesh. The loop is fault-tolerant: auto-resume, async checkpoints,
deterministic data, straggler monitor (see runtime/trainer.py).
"""
import argparse

import jax

from repro import compat
from repro.configs import ALL_ARCHS, TrainConfig, get_config, get_shape, scaled_down
from repro.runtime import trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ALL_ARCHS, required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--scaled", action="store_true",
                    help="reduced config + (1,1) mesh for CPU runs")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--seq-len", type=int, default=None)
    ap.add_argument("--global-batch", type=int, default=None)
    args = ap.parse_args()

    shape = get_shape(args.shape)
    if args.scaled:
        cfg = scaled_down(get_config(args.arch))
        mesh = compat.make_mesh((1, 1), ("data", "model"))
        seq_len = args.seq_len or 128
        global_batch = args.global_batch or 8
    else:
        from repro.launch.mesh import make_production_mesh
        cfg = get_config(args.arch)
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        seq_len = args.seq_len or shape.seq_len
        global_batch = args.global_batch or shape.global_batch

    tc = TrainConfig(total_steps=args.steps, warmup_steps=min(20, args.steps // 10 + 1))
    rep = trainer.train(cfg, tc, mesh, seq_len=seq_len,
                        global_batch=global_batch, ckpt_dir=args.ckpt_dir)
    print(f"final loss {rep.final_loss:.4f} over {rep.steps_done} steps "
          f"(resumed_from={rep.resumed_from})")


if __name__ == "__main__":
    main()
