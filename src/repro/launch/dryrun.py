import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell on
the production mesh of placeholder devices, print memory/cost analysis, and
derive the roofline terms.

The XLA_FLAGS line above is FIRST — before any other import — because jax
locks the device count on first init. Do not set it globally: smoke tests
and benches must see one device.

Usage:
  python -m repro.launch.dryrun --arch gemma-2b --shape decode_32k
  python -m repro.launch.dryrun --all [--multi-pod] [--out experiments/dryrun]
  python -m repro.launch.dryrun --arch kimi-k2-1t-a32b --shape train_4k \
      --causal-skip --tag opt1
"""

import argparse
import json
import time
import traceback

import jax

from repro.configs import (ALL_ARCHS, TrainConfig, get_config, get_shape,
                           runnable_cells, SHAPES, StepKind)
from repro.dist import steps as steps_mod
from repro.launch import hlo, jaxpr_analysis, roofline
from repro.launch.mesh import HBM_BYTES, make_production_mesh
from repro.launch.specs import input_specs


def build_step(cfg, shape, mesh, *, causal_skip=False, zero1=True,
               grad_compression="none", attn_chunk=1024, attn_p_bf16=False,
               microbatches=1, opt_int8=False, exact_retrieval=False,
               pure_dp=False, a2a_int8=False, datastore_scale=1.0,
               attn_impl="xla"):
    """Returns (jitted step, ShapeDtypeStruct args) for this cell."""
    import dataclasses
    if exact_retrieval and cfg.retrieval.enabled:
        cfg = dataclasses.replace(cfg, retrieval=dataclasses.replace(
            cfg.retrieval, local_k=cfg.retrieval.k))
    if datastore_scale != 1.0 and cfg.retrieval.enabled:
        cfg = dataclasses.replace(cfg, retrieval=dataclasses.replace(
            cfg.retrieval,
            datastore_size=int(cfg.retrieval.datastore_size * datastore_scale)))
    tc = TrainConfig(zero1=zero1, grad_compression=grad_compression,
                     microbatches=microbatches, opt_int8=opt_int8)
    args = input_specs(cfg, shape, tc)
    with mesh:
        if shape.step == StepKind.TRAIN:
            step_fn, _, _ = steps_mod.make_train_step(
                cfg, mesh, tc, causal_skip=causal_skip,
                attn_p_bf16=attn_p_bf16, pure_dp=pure_dp,
                moe_a2a_int8=a2a_int8, donate=False)
        elif shape.step == StepKind.PREFILL:
            step_fn, _ = steps_mod.make_prefill_step(
                cfg, mesh, shape.seq_len, causal_skip=causal_skip,
                attn_p_bf16=attn_p_bf16, attn_chunk=attn_chunk,
                attn_impl=attn_impl)
        else:
            step_fn, _, _ = steps_mod.make_serve_step(
                cfg, mesh, shape.seq_len, global_batch=shape.global_batch)
    return step_fn, args


def lower_cell(cfg, shape, mesh, **kw):
    step_fn, args = build_step(cfg, shape, mesh, **kw)
    with mesh:
        return step_fn.lower(*args)


def run_cell(arch: str, shape_name: str, multi_pod: bool = False,
             causal_skip: bool = False, zero1: bool = True,
             grad_compression: str = "none", attn_chunk: int = 1024,
             attn_p_bf16: bool = False, microbatches: int = 1,
             opt_int8: bool = False, exact_retrieval: bool = False,
             pure_dp: bool = False, a2a_int8: bool = False,
             datastore_scale: float = 1.0, attn_impl: str = "xla",
             mesh=None, hlo_path: str = "") -> dict:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    mesh = mesh if mesh is not None else make_production_mesh(multi_pod=multi_pod)
    chips = len(mesh.devices.reshape(-1))
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)

    t0 = time.time()
    step_fn, step_args = build_step(
        cfg, shape, mesh, causal_skip=causal_skip, zero1=zero1,
        grad_compression=grad_compression, attn_chunk=attn_chunk,
        attn_p_bf16=attn_p_bf16, microbatches=microbatches,
        opt_int8=opt_int8, exact_retrieval=exact_retrieval,
        pure_dp=pure_dp, a2a_int8=a2a_int8, datastore_scale=datastore_scale,
        attn_impl=attn_impl)
    with mesh:
        lowered = step_fn.lower(*step_args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    print(mem)                                    # proves it fits
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):  # older jax: list of per-program dicts
        cost = cost[0] if cost else {}
    cost = cost or {}
    print({k: cost[k] for k in ("flops", "bytes accessed") if k in cost})
    mem_stats = {
        "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
        "output_bytes": getattr(mem, "output_size_in_bytes", 0),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
        "alias_bytes": getattr(mem, "alias_size_in_bytes", 0),
    }
    # per-device residency: args are sharded; temp is per-device already
    mem_stats["per_device_bytes"] = (
        (mem_stats["argument_bytes"] - mem_stats["alias_bytes"]) / chips
        + mem_stats["temp_bytes"])
    mem_stats["fits_hbm"] = mem_stats["per_device_bytes"] < HBM_BYTES

    hlo_text = compiled.as_text()
    if hlo_path:
        import gzip
        with gzip.open(hlo_path, "wt") as f:
            f.write(hlo_text)
    # collectives + residency from the compiled HLO; flops + HBM traffic from
    # the jaxpr (dtype-faithful — the CPU backend computes bf16 in f32)
    stats = hlo.analyze(hlo_text)
    with mesh:
        jstats = jaxpr_analysis.analyze_step(step_fn, step_args, chips)
    stats["hlo_flops"] = stats["flops"]
    stats["hlo_io_bytes"] = stats["io_bytes"]
    stats["flops"] = jstats["flops"]
    stats["io_bytes"] = jstats["io_bytes"]
    report = roofline.build_report(
        cfg, shape, mesh_name, chips, stats, memory_stats=mem_stats,
        cost_flops=float(cost.get("flops", 0.0)))
    rec = report.as_dict()
    rec.update(lower_s=t_lower, compile_s=t_compile,
               causal_skip=causal_skip, zero1=zero1,
               grad_compression=grad_compression, attn_chunk=attn_chunk,
               attn_p_bf16=attn_p_bf16, microbatches=microbatches,
               opt_int8=opt_int8, exact_retrieval=exact_retrieval,
               pure_dp=pure_dp, a2a_int8=a2a_int8,
               datastore_scale=datastore_scale, attn_impl=attn_impl,
               multi_pod=multi_pod)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ALL_ARCHS)
    ap.add_argument("--shape", choices=sorted(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--causal-skip", action="store_true")
    ap.add_argument("--attn-p-bf16", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--opt-int8", action="store_true")
    ap.add_argument("--exact-retrieval", action="store_true")
    ap.add_argument("--pure-dp", action="store_true")
    ap.add_argument("--a2a-int8", action="store_true")
    ap.add_argument("--datastore-scale", type=float, default=1.0)
    ap.add_argument("--attn-impl", default="xla", choices=["xla", "flash"])
    ap.add_argument("--no-zero1", action="store_true")
    ap.add_argument("--grad-compression", default="none")
    ap.add_argument("--attn-chunk", type=int, default=1024)
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--tag", default="")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    mesh_tag = "2x16x16" if args.multi_pod else "16x16"

    if args.all:
        cells, skipped = runnable_cells([get_config(a) for a in ALL_ARCHS])
        for a, s, why in skipped:
            print(f"SKIP {a} x {s}: {why}")
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    failures = []
    for arch, shape_name in cells:
        tag = f"{arch}__{shape_name}__{mesh_tag}" + (f"__{args.tag}" if args.tag else "")
        path = os.path.join(args.out, tag + ".json")
        if args.skip_existing and os.path.exists(path):
            print(f"== {tag}: exists, skipping")
            continue
        print(f"== {tag}")
        try:
            rec = run_cell(arch, shape_name, multi_pod=args.multi_pod,
                           causal_skip=args.causal_skip,
                           zero1=not args.no_zero1,
                           grad_compression=args.grad_compression,
                           attn_chunk=args.attn_chunk,
                           attn_p_bf16=args.attn_p_bf16,
                           microbatches=args.microbatches,
                           opt_int8=args.opt_int8,
                           exact_retrieval=args.exact_retrieval,
                           pure_dp=args.pure_dp, a2a_int8=args.a2a_int8,
                           datastore_scale=args.datastore_scale,
                           attn_impl=args.attn_impl, mesh=mesh,
                           hlo_path=os.path.join(args.out, tag + ".hlo.gz"))
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            print(f"   dominant={rec['dominant']} bound={rec['step_time_bound_s']:.4f}s "
                  f"roofline_frac={rec['roofline_frac']:.3f} "
                  f"per_dev={rec['memory_stats']['per_device_bytes']/1e9:.2f}GB "
                  f"compile={rec['compile_s']:.1f}s")
        except Exception as e:  # noqa: BLE001 — record and continue the sweep
            failures.append((tag, repr(e)))
            traceback.print_exc()
            with open(path + ".failed", "w") as f:
                f.write(traceback.format_exc())
    if failures:
        print(f"{len(failures)} FAILURES:")
        for t, e in failures:
            print(" ", t, e)
        raise SystemExit(1)
    print("dry-run complete")


if __name__ == "__main__":
    main()
