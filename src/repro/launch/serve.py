"""Serving launcher: batched decode with kNN-LM retrieval.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --scaled \
        --requests 8 --max-new 16
"""
import argparse

import numpy as np

import jax

from repro import compat
from repro.configs import ALL_ARCHS, get_config, scaled_down
from repro.core import retrieval
from repro.dist import sharding
from repro.models import lm
from repro.runtime import server


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ALL_ARCHS, required=True)
    ap.add_argument("--scaled", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    args = ap.parse_args()

    if args.scaled:
        cfg = scaled_down(get_config(args.arch))
        mesh = compat.make_mesh((1, 1), ("data", "model"))
    else:
        from repro.launch.mesh import make_production_mesh
        cfg = get_config(args.arch)
        mesh = make_production_mesh(multi_pod=args.multi_pod)

    pspecs = sharding.param_specs(cfg, mesh)
    with mesh:
        params = jax.jit(lambda: lm.init_params(jax.random.PRNGKey(0), cfg),
                         out_shardings=sharding.named(mesh, pspecs))()
    store = None
    if cfg.retrieval.enabled:
        n = 4096 if args.scaled else cfg.retrieval.datastore_size
        store = retrieval.synthetic_datastore(cfg, n=n)
        store = jax.device_put(
            store, sharding.named(mesh, sharding.datastore_specs(mesh)))

    srv = server.Server(cfg, mesh, params, max_batch=args.max_batch,
                        max_len=args.max_len, store=store)
    rng = np.random.default_rng(0)
    for uid in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size, size=4).astype(np.int32)
        srv.submit(server.Request(uid=uid, prompt=prompt,
                                  max_new_tokens=args.max_new))
    ticks = srv.run()
    print(f"served {len(srv.done)}/{args.requests} requests in {ticks} ticks; "
          f"throughput {len(srv.done) * args.max_new / max(ticks, 1):.2f} tok/tick")


if __name__ == "__main__":
    main()
