"""Production mesh builders.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state): single-pod (16, 16) over ("data", "model") — 256 chips,
one TPU v5e pod — or multi-pod (2, 16, 16) over ("pod", "data", "model") —
512 chips, where the "pod" axis is the DCN-connected outer data axis.
"""
from __future__ import annotations

import jax

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_host_mesh(shape=(2, 2, 2), axes=("pod", "data", "model")):
    """Small mesh over however many (possibly fake) devices exist — used by
    CI-scale dry-run smoke tests."""
    n = 1
    for s in shape:
        n *= s
    assert len(jax.devices()) >= n, (len(jax.devices()), shape)
    return compat.make_mesh(shape, axes)


# TPU v5e single-chip peaks (roofline constants, see EXPERIMENTS.md §Roofline)
PEAK_FLOPS_BF16 = 197e12       # FLOP/s
HBM_BW = 819e9                 # bytes/s
ICI_BW = 50e9                  # bytes/s per link
HBM_BYTES = 16 * 1024**3       # capacity per chip
