"""ShapeDtypeStruct stand-ins for every model input — weak-type-correct,
shardable, zero allocation. The dry-run lowers against these."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig, StepKind, TrainConfig
from repro.core import retrieval as retrieval_mod
from repro.models import frontends, lm
from repro.optim import optimizer


def _sds(tree):
    return jax.tree_util.tree_map(
        lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), tree)


def param_specs_sds(cfg: ModelConfig):
    return _sds(jax.eval_shape(lambda: lm.init_params(jax.random.PRNGKey(0), cfg)))


def batch_sds(cfg: ModelConfig, shape: ShapeConfig):
    b = {
        "tokens": jax.ShapeDtypeStruct((shape.global_batch, shape.seq_len), jnp.int32),
        "labels": jax.ShapeDtypeStruct((shape.global_batch, shape.seq_len), jnp.int32),
    }
    if cfg.frontend != "none":
        b["prefix_emb"] = jax.ShapeDtypeStruct(
            (shape.global_batch, cfg.frontend_positions, frontends.frontend_dim(cfg)),
            jnp.dtype(cfg.dtype))
    return b


def input_specs(cfg: ModelConfig, shape: ShapeConfig, tc: TrainConfig = TrainConfig()):
    """Returns the tuple of ShapeDtypeStruct args for the step this shape
    lowers (train_step / prefill_step / serve_step)."""
    params = param_specs_sds(cfg)
    if shape.step == StepKind.TRAIN:
        opt = _sds(jax.eval_shape(
            lambda: optimizer.init(
                jax.eval_shape(lambda: lm.init_params(jax.random.PRNGKey(0), cfg)),
                tc)))
        return (params, opt, batch_sds(cfg, shape),
                jax.ShapeDtypeStruct((), jnp.int32))
    if shape.step == StepKind.PREFILL:
        return (params, batch_sds(cfg, shape))
    # decode: one new token against a KV cache of seq_len
    state = _sds(jax.eval_shape(
        lambda: lm.init_decode_state(cfg, shape.global_batch, shape.seq_len)))
    token = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    active = jax.ShapeDtypeStruct((shape.global_batch,), jnp.bool_)
    args = (params, token, state, active)
    if cfg.retrieval.enabled:
        store = _sds(jax.eval_shape(lambda: retrieval_mod.synthetic_datastore(cfg)))
        args = args + (store,)
    return args
