"""Jaxpr-level FLOP / HBM-traffic analysis — the dtype-faithful instrument.

Why not the compiled HLO? The CPU backend computes bf16 models in f32 (every
param upcast, every dot f32) and inserts layout copies — none of which exist
on the TPU target, inflating the memory term ~2x and erasing dtype-level
optimizations (e.g. the bf16 probability tensor) from the accounting. The
jaxpr is backend-free: logical dtypes, exact scan trip counts, and the whole
train step (fwd + bwd + optimizer) after tracing.

Model (mirrors TPU fusion granularity):
  * dot_general: FLOPs = 2 * numel(out) * prod(contracting dims);
    IO = operand bytes + result bytes (weights/activations at logical dtype)
  * slicing ops (gather/dynamic-slice/slice): result bytes only;
    dynamic-update-slice / scatter: 2x update bytes (aliased in place)
  * reductions / cumsum / sort / top_k / conv: operands + result
  * elementwise / layout ops: free (fuse into producers/consumers on TPU)
  * scan: body counted once x length (exact); cond branches at 1x
  * pjit / remat / custom_vjp / shard_map calls: recursed

Shapes are GLOBAL (pre-SPMD): callers divide by the chip count for the
per-device roofline (assumes even sharding of the dominant traffic — true
for batch-sharded activations; replicated small weights are undercounted,
documented in EXPERIMENTS.md).

Collectives are invisible at this level — they come from the compiled-HLO
parser (launch/hlo.py), which is exact for payload bytes.
"""
from __future__ import annotations

import math
from typing import Dict

import jax

# elementwise / layout primitives that fuse away on TPU
_FREE = {
    "add", "sub", "mul", "div", "max", "min", "neg", "exp", "log", "tanh",
    "logistic", "rsqrt", "sqrt", "pow", "integer_pow", "abs", "sign",
    "floor", "ceil", "round", "convert_element_type", "bitcast_convert_type",
    "select_n", "compare", "and", "or", "not", "xor", "eq", "ne", "lt", "le",
    "gt", "ge", "broadcast_in_dim", "reshape", "transpose", "squeeze",
    "expand_dims", "rev", "iota", "clamp", "erf", "erf_inv", "erfc",
    "is_finite", "population_count", "clz", "shift_left",
    "shift_right_logical", "shift_right_arithmetic", "rem", "nextafter",
    "real", "imag", "cos", "sin", "tan", "asin", "acos", "atan", "atan2",
    "sinh", "cosh", "exp2", "log1p", "expm1", "square", "copy",
    "stop_gradient", "device_put", "sharding_constraint", "cumlogsumexp",
    "and_", "or_", "xor_", "not_", "pjit_sharding_constraint", "mul_add",
    "reduce_precision", "platform_index", "axis_index", "partition_id",
}

_RECURSE_PARAMS = ("jaxpr", "call_jaxpr", "body_jaxpr", "cond_jaxpr",
                   "fun_jaxpr", "branches")


def _bytes(v) -> int:
    aval = v.aval
    if not hasattr(aval, "shape"):
        return 0
    return math.prod(aval.shape) * aval.dtype.itemsize if aval.shape else \
        aval.dtype.itemsize


def _numel(v) -> int:
    aval = v.aval
    return math.prod(aval.shape) if getattr(aval, "shape", ()) else 1


def _inner(obj):
    return obj.jaxpr if hasattr(obj, "jaxpr") and hasattr(obj, "consts") else obj


def analyze_jaxpr(jaxpr) -> Dict[str, float]:
    """Returns {'flops', 'io_bytes'} for one (possibly closed) jaxpr —
    whole-program logical totals."""
    jaxpr = _inner(jaxpr)
    flops = 0.0
    io = 0.0
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        if prim == "dot_general":
            dims = eqn.params["dimension_numbers"]
            (lc, _), _ = dims
            lhs = eqn.invars[0].aval
            csize = math.prod(lhs.shape[i] for i in lc) if lc else 1
            flops += 2.0 * _numel(eqn.outvars[0]) * csize
            io += sum(_bytes(v) for v in eqn.invars) + _bytes(eqn.outvars[0])
            continue
        if prim in ("conv_general_dilated",):
            # not used by our models, but count conservatively
            io += sum(_bytes(v) for v in eqn.invars) + _bytes(eqn.outvars[0])
            continue
        if prim == "scan":
            sub = analyze_jaxpr(eqn.params["jaxpr"])
            length = eqn.params["length"]
            flops += length * sub["flops"]
            io += length * sub["io_bytes"]
            continue
        if prim == "while":
            sub_b = analyze_jaxpr(eqn.params["body_jaxpr"])
            flops += sub_b["flops"]      # trip count unknowable here; our
            io += sub_b["io_bytes"]      # models only use scan (annotated)
            continue
        if prim == "cond":
            for br in eqn.params["branches"]:
                sub = analyze_jaxpr(br)
                flops += sub["flops"]
                io += sub["io_bytes"]
            continue
        if prim == "shard_map":
            # the body jaxpr has PER-SHARD shapes and runs once per device:
            # scale back to global-equivalent so the caller's /chips division
            # yields the correct per-device numbers
            mesh = eqn.params.get("mesh")
            mult = 1
            if mesh is not None:
                for s in dict(getattr(mesh, "shape", {})).values():
                    mult *= s
            sub = analyze_jaxpr(eqn.params.get("jaxpr")
                                or eqn.params.get("call_jaxpr"))
            flops += mult * sub["flops"]
            io += mult * sub["io_bytes"]
            continue
        if prim == "pallas_call":
            # kernel boundary == fusion boundary: HBM traffic is the operands
            # + result, except streamed operands re-read once per q-row block.
            # Our flash kernel: grid (B, H, nq, nk) — k/v re-read nq times.
            gm = eqn.params.get("grid_mapping")
            grid = tuple(getattr(gm, "grid", ()) or ())
            io += _bytes(eqn.outvars[0]) + _bytes(eqn.invars[0])
            rr = grid[2] if len(grid) >= 4 else 1
            for v in eqn.invars[1:]:
                io += rr * _bytes(v)
            if len(grid) >= 4:   # flash attention: 4 * B*H*S*S*hd (rect fetch)
                q_aval = eqn.invars[0].aval
                b, h, s, hd = q_aval.shape
                s_k = eqn.invars[1].aval.shape[2]
                flops += 4.0 * b * h * s * s_k * hd * 0.5   # causal skip in-kernel
            continue
        recursed = False
        for key in _RECURSE_PARAMS:
            if key in eqn.params and key != "branches":
                obj = eqn.params[key]
                if obj is None:
                    continue
                sub = analyze_jaxpr(obj)
                flops += sub["flops"]
                io += sub["io_bytes"]
                recursed = True
                break
        if recursed:
            continue
        if prim in ("gather", "dynamic_slice", "slice", "take"):
            io += 2 * _bytes(eqn.outvars[0])
            continue
        if prim in ("dynamic_update_slice",):
            io += 2 * _bytes(eqn.invars[1])
            continue
        if prim == "scatter" or prim.startswith("scatter"):
            upd = _bytes(eqn.invars[2]) if len(eqn.invars) > 2 else 0
            io += 2 * upd
            continue
        if prim in ("reduce_sum", "reduce_max", "reduce_min", "reduce_prod",
                    "reduce_and", "reduce_or", "argmax", "argmin",
                    "reduce_window_sum", "reduce_window_max", "cumsum",
                    "cummax", "cummin", "cumprod", "sort", "top_k",
                    "concatenate", "pad", "select_and_scatter_add"):
            io += sum(_bytes(v) for v in eqn.invars) + sum(
                _bytes(v) for v in eqn.outvars)
            continue
        if prim in _FREE:
            continue
        if prim in ("psum", "all_gather", "reduce_scatter", "all_to_all",
                    "ppermute", "psum_scatter", "pmax", "pmin"):
            # manual collectives (shard_map): counted by the HLO parser too;
            # charge their IO here so memory term sees the payload movement
            io += sum(_bytes(v) for v in eqn.invars)
            continue
        # unknown compute-ish primitive: charge operands + results
        io += sum(_bytes(v) for v in eqn.invars) + sum(
            _bytes(v) for v in eqn.outvars)
    return {"flops": flops, "io_bytes": io}


def analyze_step(step_fn, args, n_devices: int) -> Dict[str, float]:
    """Trace a (jitted) step against ShapeDtypeStruct args and return
    PER-DEVICE {'flops', 'io_bytes'} under even-sharding division."""
    traced = step_fn.trace(*args)
    stats = analyze_jaxpr(traced.jaxpr)
    return {"flops": stats["flops"] / n_devices,
            "io_bytes": stats["io_bytes"] / n_devices}
