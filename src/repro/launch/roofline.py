"""Roofline terms from a compiled dry-run artifact.

All inputs are PER-DEVICE (the SPMD module is the per-device program; our
loop-aware HLO parser in launch/hlo.py supplies flops / HBM bytes /
collective bytes — ``compiled.cost_analysis()`` is both loop-blind and
collective-blind, which we verified empirically; see EXPERIMENTS.md).

  compute    = flops_per_device / 197 TFLOP/s
  memory     = hbm_bytes_per_device / 819 GB/s
  collective = collective_bytes_per_device / 50 GB/s   (1 ICI link charged)

MODEL_FLOPS = 6*N_active*D (train) / 2*N_active*D (forward-only) + the causal
attention term — the useful-compute yardstick; useful_ratio compares it with
chips * flops_per_device.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.configs.base import ModelConfig, ShapeConfig, StepKind
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
from repro.models import lm


def analytic_model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Useful FLOPs for one step of this (arch, shape) cell (whole fleet)."""
    n_active = lm.param_count(cfg, active_only=True)
    B, S = shape.global_batch, shape.seq_len
    hd = cfg.resolved_head_dim
    layers = (cfg.num_layers // cfg.shared_attn_every
              if cfg.shared_attn_every else cfg.num_layers)

    if shape.step == StepKind.TRAIN:
        dense = 2.0 * n_active * B * S
        attn = 4.0 * B * S * S * cfg.num_heads * hd * layers * 0.5 \
            if cfg.num_heads else 0.0
        return 3.0 * (dense + attn)        # fwd + 2x bwd
    if shape.step == StepKind.PREFILL:
        dense = 2.0 * n_active * B * S
        attn = 4.0 * B * S * S * cfg.num_heads * hd * layers * 0.5 \
            if cfg.num_heads else 0.0
        return dense + attn
    # decode: one token per sequence; attention reads the full cache
    dense = 2.0 * n_active * B
    attn = 4.0 * B * S * cfg.num_heads * hd * layers if cfg.num_heads else 0.0
    return dense + attn


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    hbm_bytes_per_device: float
    collective_bytes_per_device: float
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    useful_ratio: float            # MODEL_FLOPS / (chips * flops_per_device)
    roofline_frac: float           # useful work at peak / dominant-term time
    step_time_bound_s: float       # max of the three terms
    collective_detail: Optional[Dict[str, float]] = None
    collective_counts: Optional[Dict[str, float]] = None
    memory_stats: Optional[Dict[str, float]] = None
    cost_analysis_flops: Optional[float] = None

    def as_dict(self):
        return dataclasses.asdict(self)


def build_report(cfg: ModelConfig, shape: ShapeConfig, mesh_name: str,
                 chips: int, stats: Dict, memory_stats=None,
                 cost_flops: Optional[float] = None) -> RooflineReport:
    flops = float(stats["flops"])
    byts = float(stats["io_bytes"])
    coll = stats["coll_bytes"]
    compute_s = flops / PEAK_FLOPS_BF16
    memory_s = byts / HBM_BW
    collective_s = float(coll.get("total", 0.0)) / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    model_flops = analytic_model_flops(cfg, shape)
    useful = model_flops / (chips * flops) if flops else 0.0
    # fraction of roofline: time the useful work needs at peak vs the bound
    ideal_s = model_flops / (chips * PEAK_FLOPS_BF16)
    frac = ideal_s / bound if bound > 0 else 0.0
    return RooflineReport(
        arch=cfg.name, shape=shape.name, mesh=mesh_name, chips=chips,
        flops_per_device=flops, hbm_bytes_per_device=byts,
        collective_bytes_per_device=float(coll.get("total", 0.0)),
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant, model_flops=model_flops, useful_ratio=useful,
        roofline_frac=frac, step_time_bound_s=bound,
        collective_detail={k: v for k, v in coll.items() if k != "total"},
        collective_counts=stats.get("coll_counts"),
        memory_stats=memory_stats, cost_analysis_flops=cost_flops,
    )
