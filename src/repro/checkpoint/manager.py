"""Checkpointing: per-process npz shards, atomic commit, async save,
resume-from-latest, and elastic restore onto a different mesh.

Layout:
  <dir>/step_<n>/proc_<i>.npz     flattened leaves (leaf_00000 ...)
  <dir>/step_<n>/meta.json        step, treedef repr, leaf count
  <dir>/step_<n>/COMMITTED        written last; uncommitted dirs are ignored

Fault-tolerance contract: save is atomic (tmp dir + rename + marker), so a
kill at any point leaves either the previous or the new checkpoint valid.
``restore`` device_puts every leaf with the *target* shardings — restoring
onto a different mesh shape (elastic scale-up/down) is just a different
sharding argument.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import numpy as np

import jax


def _step_dir(root: str, step: int) -> str:
    return os.path.join(root, f"step_{step:08d}")


def _to_savable(arr: np.ndarray) -> np.ndarray:
    """npz cannot serialize ml_dtypes (bfloat16 etc.) — store a uint view."""
    if arr.dtype.kind == "V" or arr.dtype.name in ("bfloat16", "float8_e4m3fn",
                                                   "float8_e5m2"):
        return arr.view(np.uint16 if arr.dtype.itemsize == 2 else np.uint8)
    return arr


def _from_savable(arr: np.ndarray, ref) -> np.ndarray:
    ref_dtype = np.dtype(ref.dtype)
    if arr.dtype != ref_dtype and arr.dtype.kind == "u" and \
            arr.dtype.itemsize == ref_dtype.itemsize:
        return arr.view(ref_dtype).reshape(ref.shape)
    return np.asarray(arr, dtype=ref_dtype).reshape(ref.shape)


class SaveHandle:
    """Handle for an async ``save``. ``result()`` (alias ``join()``) blocks
    until the writer thread finishes and RE-RAISES any exception it hit —
    async save failures must surface at the join point, never vanish with
    the thread."""

    def __init__(self, thread: threading.Thread, errbox: dict):
        self._thread = thread
        self._errbox = errbox

    def done(self) -> bool:
        return not self._thread.is_alive()

    def result(self, timeout: Optional[float] = None) -> None:
        self._thread.join(timeout)
        exc = self._errbox.get("exc")
        if exc is not None:
            raise exc

    # drop-in for callers that treated the return as a bare Thread
    join = result

    def is_alive(self) -> bool:
        return self._thread.is_alive()


def save(root: str, step: int, tree: Any, process_index: int = 0,
         blocking: bool = True,
         fault_hook: Optional[Any] = None) -> Optional[SaveHandle]:
    """Atomically write ``tree`` (pytree of arrays) for ``step``.

    ``fault_hook`` (zero-arg callable) runs mid-write — after the tmp dir
    is populated, before the rename — i.e. at the point a kill leaves an
    orphaned ``step_*.tmp*`` dir and the PREVIOUS committed step intact
    (fault-injection seam; see runtime/faults.py). Non-blocking saves
    return a ``SaveHandle`` whose ``result()``/``join()`` re-raises writer
    exceptions."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    host_leaves = [_to_savable(np.asarray(l)) for l in leaves]

    def _write():
        final = _step_dir(root, step)
        tmp = final + f".tmp{process_index}"
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, f"proc_{process_index}.npz"),
                 **{f"leaf_{i:05d}": l for i, l in enumerate(host_leaves)})
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump({"step": step, "n_leaves": len(host_leaves),
                       "treedef": str(treedef), "time": time.time()}, f)
        if fault_hook is not None:
            fault_hook()
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        with open(os.path.join(final, "COMMITTED"), "w") as f:
            f.write("ok")

    if blocking:
        _write()
        return None
    errbox: dict = {}

    def _guarded_write():
        try:
            _write()
        except BaseException as e:  # noqa: BLE001 — delivered via result()
            errbox["exc"] = e

    t = threading.Thread(target=_guarded_write, daemon=False)
    t.start()
    return SaveHandle(t, errbox)


def latest_step(root: str) -> Optional[int]:
    if not os.path.isdir(root):
        return None
    steps = []
    for name in os.listdir(root):
        if name.startswith("step_") and not name.endswith((".tmp0", ".tmp")):
            path = os.path.join(root, name)
            if os.path.exists(os.path.join(path, "COMMITTED")):
                try:
                    steps.append(int(name.split("_")[1]))
                except ValueError:
                    continue
    return max(steps) if steps else None


def restore(root: str, step: int, like: Any, shardings: Any = None,
            process_index: int = 0, fault_hook: Optional[Any] = None) -> Any:
    """Load ``step`` into the structure of ``like``; device_put with
    ``shardings`` when given (elastic re-shard happens here).
    ``fault_hook`` runs before the read (injection seam)."""
    if fault_hook is not None:
        fault_hook()
    path = os.path.join(_step_dir(root, step), f"proc_{process_index}.npz")
    data = np.load(path)
    leaves, treedef = jax.tree_util.tree_flatten(like)
    loaded = [data[f"leaf_{i:05d}"] for i in range(len(leaves))]
    loaded = [_from_savable(l, ref) for l, ref in zip(loaded, leaves)]
    tree = jax.tree_util.tree_unflatten(treedef, loaded)
    if shardings is not None:
        tree = jax.device_put(tree, shardings)
    return tree


def restore_latest(root: str, like: Any, shardings: Any = None,
                   fault_hook: Optional[Any] = None):
    step = latest_step(root)
    if step is None:
        return None, None
    return step, restore(root, step, like, shardings,
                         fault_hook=fault_hook)


def garbage_collect(root: str, keep: int = 3):
    """Trim to the newest ``keep`` committed steps AND sweep orphaned
    ``step_*.tmp*`` dirs left by crashed/failed saves. A tmp dir is only
    stale — hence removable — when its step does not exceed the newest
    COMMITTED step: anything newer could be an in-flight async save."""
    if not os.path.isdir(root):
        return
    steps = sorted(
        int(n.split("_")[1]) for n in os.listdir(root)
        if n.startswith("step_") and "." not in n
        and os.path.exists(os.path.join(root, n, "COMMITTED")))
    for s in steps[:-keep]:
        shutil.rmtree(_step_dir(root, s), ignore_errors=True)
    newest = steps[-1] if steps else None
    if newest is None:
        return
    for n in os.listdir(root):
        if not (n.startswith("step_") and ".tmp" in n):
            continue
        try:
            s = int(n.split(".")[0].split("_")[1])
        except (IndexError, ValueError):
            continue
        if s <= newest:
            shutil.rmtree(os.path.join(root, n), ignore_errors=True)
