"""Checkpointing: per-process npz shards, atomic commit, async save,
resume-from-latest, and elastic restore onto a different mesh.

Layout:
  <dir>/step_<n>/proc_<i>.npz     flattened leaves (leaf_00000 ...)
  <dir>/step_<n>/meta.json        step, treedef repr, leaf count
  <dir>/step_<n>/COMMITTED        written last; uncommitted dirs are ignored

Fault-tolerance contract: save is atomic (tmp dir + rename + marker), so a
kill at any point leaves either the previous or the new checkpoint valid.
``restore`` device_puts every leaf with the *target* shardings — restoring
onto a different mesh shape (elastic scale-up/down) is just a different
sharding argument.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import numpy as np

import jax


def _step_dir(root: str, step: int) -> str:
    return os.path.join(root, f"step_{step:08d}")


def _to_savable(arr: np.ndarray) -> np.ndarray:
    """npz cannot serialize ml_dtypes (bfloat16 etc.) — store a uint view."""
    if arr.dtype.kind == "V" or arr.dtype.name in ("bfloat16", "float8_e4m3fn",
                                                   "float8_e5m2"):
        return arr.view(np.uint16 if arr.dtype.itemsize == 2 else np.uint8)
    return arr


def _from_savable(arr: np.ndarray, ref) -> np.ndarray:
    ref_dtype = np.dtype(ref.dtype)
    if arr.dtype != ref_dtype and arr.dtype.kind == "u" and \
            arr.dtype.itemsize == ref_dtype.itemsize:
        return arr.view(ref_dtype).reshape(ref.shape)
    return np.asarray(arr, dtype=ref_dtype).reshape(ref.shape)


def save(root: str, step: int, tree: Any, process_index: int = 0,
         blocking: bool = True) -> Optional[threading.Thread]:
    """Atomically write ``tree`` (pytree of arrays) for ``step``."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    host_leaves = [_to_savable(np.asarray(l)) for l in leaves]

    def _write():
        final = _step_dir(root, step)
        tmp = final + f".tmp{process_index}"
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, f"proc_{process_index}.npz"),
                 **{f"leaf_{i:05d}": l for i, l in enumerate(host_leaves)})
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump({"step": step, "n_leaves": len(host_leaves),
                       "treedef": str(treedef), "time": time.time()}, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        with open(os.path.join(final, "COMMITTED"), "w") as f:
            f.write("ok")

    if blocking:
        _write()
        return None
    t = threading.Thread(target=_write, daemon=False)
    t.start()
    return t


def latest_step(root: str) -> Optional[int]:
    if not os.path.isdir(root):
        return None
    steps = []
    for name in os.listdir(root):
        if name.startswith("step_") and not name.endswith((".tmp0", ".tmp")):
            path = os.path.join(root, name)
            if os.path.exists(os.path.join(path, "COMMITTED")):
                try:
                    steps.append(int(name.split("_")[1]))
                except ValueError:
                    continue
    return max(steps) if steps else None


def restore(root: str, step: int, like: Any, shardings: Any = None,
            process_index: int = 0) -> Any:
    """Load ``step`` into the structure of ``like``; device_put with
    ``shardings`` when given (elastic re-shard happens here)."""
    path = os.path.join(_step_dir(root, step), f"proc_{process_index}.npz")
    data = np.load(path)
    leaves, treedef = jax.tree_util.tree_flatten(like)
    loaded = [data[f"leaf_{i:05d}"] for i in range(len(leaves))]
    loaded = [_from_savable(l, ref) for l, ref in zip(loaded, leaves)]
    tree = jax.tree_util.tree_unflatten(treedef, loaded)
    if shardings is not None:
        tree = jax.device_put(tree, shardings)
    return tree


def restore_latest(root: str, like: Any, shardings: Any = None):
    step = latest_step(root)
    if step is None:
        return None, None
    return step, restore(root, step, like, shardings)


def garbage_collect(root: str, keep: int = 3):
    if not os.path.isdir(root):
        return
    steps = sorted(
        int(n.split("_")[1]) for n in os.listdir(root)
        if n.startswith("step_") and "." not in n
        and os.path.exists(os.path.join(root, n, "COMMITTED")))
    for s in steps[:-keep]:
        shutil.rmtree(_step_dir(root, s), ignore_errors=True)
