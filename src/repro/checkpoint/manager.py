"""Checkpointing: per-process npz shards, atomic commit, async save,
resume-from-latest, and elastic restore onto a different mesh.

Layout:
  <dir>/step_<n>/proc_<i>.npz     flattened leaves (leaf_00000 ...)
  <dir>/step_<n>/meta.json        step, treedef repr, leaf count
  <dir>/step_<n>/COMMITTED        written last; uncommitted dirs are ignored

Fault-tolerance contract: save is atomic (tmp dir + rename + marker), so a
kill at any point leaves either the previous or the new checkpoint valid.
``restore`` device_puts every leaf with the *target* shardings — restoring
onto a different mesh shape (elastic scale-up/down) is just a different
sharding argument.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import numpy as np

import jax


def _step_dir(root: str, step: int) -> str:
    return os.path.join(root, f"step_{step:08d}")


def _to_savable(arr: np.ndarray) -> np.ndarray:
    """npz cannot serialize ml_dtypes (bfloat16 etc.) — store a uint view."""
    if arr.dtype.kind == "V" or arr.dtype.name in ("bfloat16", "float8_e4m3fn",
                                                   "float8_e5m2"):
        return arr.view(np.uint16 if arr.dtype.itemsize == 2 else np.uint8)
    return arr


def _from_savable(arr: np.ndarray, ref) -> np.ndarray:
    ref_dtype = np.dtype(ref.dtype)
    if arr.dtype != ref_dtype and arr.dtype.kind == "u" and \
            arr.dtype.itemsize == ref_dtype.itemsize:
        return arr.view(ref_dtype).reshape(ref.shape)
    return np.asarray(arr, dtype=ref_dtype).reshape(ref.shape)


class SaveHandle:
    """Handle for an async ``save``. ``result()`` (alias ``join()``) blocks
    until the writer thread finishes and RE-RAISES any exception it hit —
    async save failures must surface at the join point, never vanish with
    the thread."""

    def __init__(self, thread: threading.Thread, errbox: dict):
        self._thread = thread
        self._errbox = errbox

    def done(self) -> bool:
        return not self._thread.is_alive()

    def result(self, timeout: Optional[float] = None) -> None:
        self._thread.join(timeout)
        exc = self._errbox.get("exc")
        if exc is not None:
            raise exc

    # drop-in for callers that treated the return as a bare Thread
    join = result

    def is_alive(self) -> bool:
        return self._thread.is_alive()


def save(root: str, step: int, tree: Any, process_index: int = 0,
         blocking: bool = True,
         fault_hook: Optional[Any] = None) -> Optional[SaveHandle]:
    """Atomically write ``tree`` (pytree of arrays) for ``step``.

    ``fault_hook`` (zero-arg callable) runs mid-write — after the tmp dir
    is populated, before the rename — i.e. at the point a kill leaves an
    orphaned ``step_*.tmp*`` dir and the PREVIOUS committed step intact
    (fault-injection seam; see runtime/faults.py). Non-blocking saves
    return a ``SaveHandle`` whose ``result()``/``join()`` re-raises writer
    exceptions."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    host_leaves = [_to_savable(np.asarray(l)) for l in leaves]

    def _write():
        final = _step_dir(root, step)
        tmp = final + f".tmp{process_index}"
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, f"proc_{process_index}.npz"),
                 **{f"leaf_{i:05d}": l for i, l in enumerate(host_leaves)})
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump({"step": step, "n_leaves": len(host_leaves),
                       "leaves": [{"shape": list(l.shape),
                                   "dtype": str(l.dtype)}
                                  for l in host_leaves],
                       "treedef": str(treedef), "time": time.time()}, f)
        if fault_hook is not None:
            fault_hook()
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        with open(os.path.join(final, "COMMITTED"), "w") as f:
            f.write("ok")

    if blocking:
        _write()
        return None
    errbox: dict = {}

    def _guarded_write():
        try:
            _write()
        except BaseException as e:  # noqa: BLE001 — delivered via result()
            errbox["exc"] = e

    t = threading.Thread(target=_guarded_write, daemon=False)
    t.start()
    return SaveHandle(t, errbox)


def committed_steps(root: str) -> list:
    """All committed steps, ascending."""
    if not os.path.isdir(root):
        return []
    steps = []
    for name in os.listdir(root):
        if name.startswith("step_") and ".tmp" not in name:
            path = os.path.join(root, name)
            if os.path.exists(os.path.join(path, "COMMITTED")):
                try:
                    steps.append(int(name.split("_")[1]))
                except ValueError:
                    continue
    return sorted(steps)


def latest_step(root: str) -> Optional[int]:
    steps = committed_steps(root)
    return steps[-1] if steps else None


class CheckpointCorrupt(RuntimeError):
    """A committed checkpoint failed verification against its meta.json
    (missing/truncated leaf file, wrong leaf count, or shape drift)."""


def _read_verified_leaves(root: str, step: int, process_index: int,
                          n_expected: Optional[int] = None) -> list:
    """Load a step's leaves, verified against meta.json — restore must
    never trust leaf files blindly: a truncated npz or a shape that
    drifted from what save() recorded raises :class:`CheckpointCorrupt`
    (callers like ``restore_latest`` then fall back to the PREVIOUS
    committed step instead of blowing up mid-serve)."""
    sdir = _step_dir(root, step)
    if not os.path.isdir(sdir):
        # a step that was never written is a caller error, not corruption
        raise FileNotFoundError(sdir)
    try:
        with open(os.path.join(sdir, "meta.json")) as f:
            meta = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        raise CheckpointCorrupt(f"step {step}: unreadable meta.json: {e}")
    n_leaves = meta.get("n_leaves")
    if not isinstance(n_leaves, int):
        raise CheckpointCorrupt(f"step {step}: meta.json lacks n_leaves")
    try:
        data = np.load(os.path.join(sdir, f"proc_{process_index}.npz"))
        loaded = [data[f"leaf_{i:05d}"] for i in range(n_leaves)]
    except Exception as e:  # zipfile/KeyError/OSError: truncated or short
        raise CheckpointCorrupt(f"step {step}: bad leaf file: {e}")
    if n_expected is not None and n_leaves != n_expected:
        raise CheckpointCorrupt(
            f"step {step}: {n_leaves} leaves saved, {n_expected} expected")
    for i, (l, m) in enumerate(zip(loaded, meta.get("leaves") or [])):
        if list(l.shape) != m["shape"] or str(l.dtype) != m["dtype"]:
            raise CheckpointCorrupt(
                f"step {step}: leaf {i} is {l.shape}/{l.dtype}, meta says "
                f"{tuple(m['shape'])}/{m['dtype']}")
    return loaded


def restore(root: str, step: int, like: Any, shardings: Any = None,
            process_index: int = 0, fault_hook: Optional[Any] = None) -> Any:
    """Load ``step`` into the structure of ``like``; device_put with
    ``shardings`` when given (elastic re-shard happens here).
    ``fault_hook`` runs before the read (injection seam). Raises
    :class:`CheckpointCorrupt` when the step fails verification against
    its meta.json."""
    if fault_hook is not None:
        fault_hook()
    leaves, treedef = jax.tree_util.tree_flatten(like)
    loaded = _read_verified_leaves(root, step, process_index,
                                   n_expected=len(leaves))
    loaded = [_from_savable(l, ref) for l, ref in zip(loaded, leaves)]
    tree = jax.tree_util.tree_unflatten(treedef, loaded)
    if shardings is not None:
        tree = jax.device_put(tree, shardings)
    return tree


def restore_latest(root: str, like: Any, shardings: Any = None,
                   fault_hook: Optional[Any] = None):
    """Restore the newest committed step that VERIFIES — a corrupt or
    truncated newest checkpoint falls back to the previous committed step
    (mid-serve robustness: stale data beats a crash), exhausting all of
    them returns (None, None)."""
    last_err = None
    for step in reversed(committed_steps(root)):
        try:
            return step, restore(root, step, like, shardings,
                                 fault_hook=fault_hook)
        except CheckpointCorrupt as e:
            last_err = e
    if last_err is not None:
        import logging
        logging.getLogger(__name__).warning(
            "no verifiable checkpoint under %s (last: %s)", root, last_err)
    return None, None


def restore_latest_arrays(root: str, process_index: int = 0,
                          fault_hook: Optional[Any] = None):
    """Structure-free restore: the newest VERIFIED committed step's leaves
    as a flat list of host arrays, falling back past corrupt steps like
    ``restore_latest``. For state whose shapes change over its lifetime
    (the mutable store's arena grows/shrinks), where no ``like`` template
    can exist ahead of the load; meta.json's recorded shapes/dtypes are
    the verification reference instead."""
    if fault_hook is not None:
        fault_hook()
    for step in reversed(committed_steps(root)):
        try:
            n = json.load(open(os.path.join(_step_dir(root, step),
                                            "meta.json")))["n_leaves"]
            return step, _read_verified_leaves(root, step, process_index,
                                               n_expected=n)
        except (CheckpointCorrupt, OSError, json.JSONDecodeError,
                KeyError):
            continue
    return None, None


def garbage_collect(root: str, keep: int = 3):
    """Trim to the newest ``keep`` committed steps AND sweep orphaned
    ``step_*.tmp*`` dirs left by crashed/failed saves. A tmp dir is only
    stale — hence removable — when its step does not exceed the newest
    COMMITTED step: anything newer could be an in-flight async save."""
    if not os.path.isdir(root):
        return
    steps = sorted(
        int(n.split("_")[1]) for n in os.listdir(root)
        if n.startswith("step_") and "." not in n
        and os.path.exists(os.path.join(root, n, "COMMITTED")))
    for s in steps[:-keep]:
        shutil.rmtree(_step_dir(root, s), ignore_errors=True)
    newest = steps[-1] if steps else None
    if newest is None:
        return
    for n in os.listdir(root):
        if not (n.startswith("step_") and ".tmp" in n):
            continue
        try:
            s = int(n.split(".")[0].split("_")[1])
        except (IndexError, ValueError):
            continue
        if s <= newest:
            shutil.rmtree(os.path.join(root, n), ignore_errors=True)
