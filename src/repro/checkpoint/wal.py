"""Write-ahead intent log for the mutable datastore.

Durability contract: a mutation is ACKNOWLEDGED only after its record is
appended, flushed, and fsynced here — so "acked" means "replayable". The
arena, the epoch, and every snapshot are derived state; a crash at any
point between the fsync and the next snapshot loses nothing that was
acked, because recovery replays the tail of this log on top of the last
committed snapshot (core/mutable.py).

Record framing (little-endian, self-delimiting):

    [u32 magic][u64 seq][u8 kind][u32 payload_len][payload][u32 crc32]

The CRC (zlib.crc32 — stdlib; same family as the xxhash-style arena
checksum, chosen to add no dependency) covers seq..payload. Replay stops
cleanly at the first bad magic, short read, or CRC mismatch — a torn tail
from a crash mid-append truncates to the last whole record instead of
poisoning the log. Records carry opaque payload bytes; the codecs for
append/delete payloads live with the store that owns their schema.

``fault_hook`` runs BEFORE anything is written: an injected fault at the
``wal_append`` site means the record never reached the file, the caller
never acked, and recovery owes the client nothing for it.

Tenant namespaces (core/tenant.py): a multi-tenant arena keeps ONE log
per tenant under ``<root>/tenants/<tenant>/`` (:func:`namespace_root`,
:func:`list_namespaces`), so corruption in one tenant's log can never
poison another's replay — the unit of blast radius is the namespace.
:func:`verify` triages a log before replay: a *torn tail* (partial final
record — the normal crash artifact; nothing parseable follows the bad
frame) recovers normally, while *interior corruption* (a whole valid
record survives past the bad frame, i.e. tolerant replay would silently
drop acked records) marks the namespace for quarantine.
"""
from __future__ import annotations

import os
import struct
import zlib
from typing import Callable, Iterator, List, NamedTuple, Optional

MAGIC = 0x57414C31          # "WAL1"
_HEADER = struct.Struct("<IQBI")    # magic, seq, kind, payload_len
_CRC = struct.Struct("<I")

# record kinds (payload schema owned by core/mutable.py)
APPEND = 1
DELETE = 2
COMPACT_BEGIN = 3
COMPACT_COMMIT = 4
SNAPSHOT = 5

KIND_NAMES = {APPEND: "append", DELETE: "delete",
              COMPACT_BEGIN: "compact_begin",
              COMPACT_COMMIT: "compact_commit", SNAPSHOT: "snapshot"}

# refuse absurd payloads during replay: a corrupt length field must not
# turn into a multi-GiB read before the CRC gets a chance to reject it
MAX_PAYLOAD = 1 << 30


class Record(NamedTuple):
    seq: int
    kind: int
    payload: bytes


class WalCorrupt(RuntimeError):
    """An interior record failed validation (not a clean torn tail)."""


class WriteAheadLog:
    """Append-only intent log. One writer; readers use :func:`replay`."""

    def __init__(self, path: str,
                 fault_hook: Optional[Callable[[], None]] = None):
        self.path = path
        self._fault_hook = fault_hook
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        self._f = open(path, "ab")

    def append(self, kind: int, payload: bytes, seq: int) -> None:
        """Durably append one record (flush + fsync before returning)."""
        if self._fault_hook is not None:
            self._fault_hook()
        crc = zlib.crc32(_HEADER.pack(MAGIC, seq, kind, len(payload))[4:])
        crc = zlib.crc32(payload, crc)
        self._f.write(_HEADER.pack(MAGIC, seq, kind, len(payload)))
        self._f.write(payload)
        self._f.write(_CRC.pack(crc))
        self._f.flush()
        os.fsync(self._f.fileno())

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()

    def __enter__(self) -> "WriteAheadLog":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def iter_records(path: str, strict: bool = False) -> Iterator[Record]:
    """Yield whole records; stop at the torn tail.

    A partial final record (crash mid-append) is normal and silently ends
    iteration. ``strict=True`` raises :class:`WalCorrupt` instead — used
    by audits that want to distinguish "clean tail" from "torn tail":
    iteration position is the byte offset of the first bad frame either
    way."""
    if not os.path.exists(path):
        return
    with open(path, "rb") as f:
        while True:
            head = f.read(_HEADER.size)
            if len(head) == 0:
                return                      # clean end
            if len(head) < _HEADER.size:
                _torn(strict, "short header")
                return
            magic, seq, kind, plen = _HEADER.unpack(head)
            if magic != MAGIC or plen > MAX_PAYLOAD:
                _torn(strict, f"bad magic/length at seq~{seq}")
                return
            payload = f.read(plen)
            tail = f.read(_CRC.size)
            if len(payload) < plen or len(tail) < _CRC.size:
                _torn(strict, "short payload/crc")
                return
            crc = zlib.crc32(head[4:])
            crc = zlib.crc32(payload, crc)
            if _CRC.unpack(tail)[0] != crc:
                _torn(strict, f"crc mismatch at seq {seq}")
                return
            yield Record(seq, kind, payload)


def _torn(strict: bool, what: str) -> None:
    if strict:
        raise WalCorrupt(what)


def replay(path: str, after_seq: int = -1) -> List[Record]:
    """All whole records with ``seq > after_seq``, in log order."""
    return [r for r in iter_records(path) if r.seq > after_seq]


def last_seq(path: str) -> int:
    """Highest seq among whole records, or -1 for an empty/missing log."""
    seq = -1
    for r in iter_records(path):
        seq = max(seq, r.seq)
    return seq


def namespace_root(root: str, name: str) -> str:
    """Filesystem namespace for one tenant's durable state (its own
    ``wal.log`` + ``snap/``) under a multi-tenant root. Names must be
    plain path components — a separator would let one tenant alias
    another's namespace."""
    name = str(name)
    assert name and "/" not in name and "\\" not in name \
        and name not in (".", ".."), f"bad namespace name {name!r}"
    return os.path.join(root, "tenants", name)


def list_namespaces(root: str) -> List[str]:
    """All tenant namespaces under ``root``, sorted (empty when none)."""
    base = os.path.join(root, "tenants")
    if not os.path.isdir(base):
        return []
    return sorted(n for n in os.listdir(base)
                  if os.path.isdir(os.path.join(base, n)))


def verify(path: str) -> dict:
    """Triage a log without replaying it: ``status`` is ``"ok"`` (every
    byte parses), ``"torn_tail"`` (a bad frame with nothing parseable
    after it — the normal crash artifact; tolerant replay recovers every
    whole record), or ``"corrupt"`` (a whole valid record survives PAST
    the bad frame: tolerant replay would silently drop acked records, so
    the namespace must be quarantined instead of replayed). Also returns
    ``records``/``last_seq`` over the clean prefix and ``bad_offset``."""
    if not os.path.exists(path):
        return {"status": "ok", "records": 0, "last_seq": -1,
                "bad_offset": -1}
    with open(path, "rb") as f:
        data = f.read()
    off, n_rec, last = 0, 0, -1

    def _parse_at(pos: int):
        """(seq, end_offset) of a whole valid record at pos, else None."""
        if pos + _HEADER.size > len(data):
            return None
        magic, seq, kind, plen = _HEADER.unpack_from(data, pos)
        if magic != MAGIC or plen > MAX_PAYLOAD:
            return None
        end = pos + _HEADER.size + plen + _CRC.size
        if end > len(data):
            return None
        crc = zlib.crc32(data[pos + 4:pos + _HEADER.size])
        crc = zlib.crc32(data[pos + _HEADER.size:end - _CRC.size], crc)
        if _CRC.unpack_from(data, end - _CRC.size)[0] != crc:
            return None
        return seq, end

    while off < len(data):
        got = _parse_at(off)
        if got is None:
            break
        last, off = got[0], got[1]
        n_rec += 1
    if off >= len(data):
        return {"status": "ok", "records": n_rec, "last_seq": last,
                "bad_offset": -1}
    # bad frame at `off`: corruption iff any whole valid record parses
    # anywhere past it (acked data exists beyond what replay would yield)
    magic_bytes = _HEADER.pack(MAGIC, 0, 0, 0)[:4]
    probe = off + 1
    status = "torn_tail"
    while True:
        probe = data.find(magic_bytes, probe)
        if probe < 0:
            break
        if _parse_at(probe) is not None:
            status = "corrupt"
            break
        probe += 1
    return {"status": status, "records": n_rec, "last_seq": last,
            "bad_offset": off}


def rewrite(path: str, records: List[Record]) -> None:
    """Atomically replace the log with ``records`` (post-snapshot
    truncation: drop everything a committed snapshot already covers).
    Written to a tmp file, fsynced, then renamed over the original."""
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        for r in records:
            crc = zlib.crc32(
                _HEADER.pack(MAGIC, r.seq, r.kind, len(r.payload))[4:])
            crc = zlib.crc32(r.payload, crc)
            f.write(_HEADER.pack(MAGIC, r.seq, r.kind, len(r.payload)))
            f.write(r.payload)
            f.write(_CRC.pack(crc))
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
