"""The paper's primary contribution: binary-code similarity search with
bounded-domain (temporal-sort-analogue) top-k, chunked scans, hierarchical
distributed merge, spatial indexes, and kNN-LM retrieval integration."""
from repro.core import (binary, engine, hierarchy, index, layout, quantize,  # noqa: F401
                        retrieval, topk)
