"""Bounded-domain top-k selection — the TPU-native analogue of the paper's
temporally encoded sort.

On the AP, inverted-Hamming counters race toward threshold d+1 and nearer
vectors *report earlier*: the sort is a counting process over the distance
domain [0, d], finished in O(d) cycles regardless of n. Vectorized, that is
exactly a counting-select:

  1. histogram the distances over their d+1 possible values   (the "race")
  2. a cumulative count locates the k-th smallest radius r*   (the "finish line")
  3. one masked pass emits ids with dist <= r*                (the "reports")

O(n + d) work, no comparison sort, no data-dependent control flow. Ties at
r* are broken by index order (deterministic), matching the AP's report-order
semantics for simultaneous pulses.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def topk_ref(dist: jax.Array, k: int):
    """Sorted-oracle reference. dist: (Q, N) -> (dists (Q,k), ids (Q,k))."""
    order = jnp.argsort(dist, axis=-1, stable=True)[:, :k]
    return jnp.take_along_axis(dist, order, axis=-1), order.astype(jnp.int32)


def counting_topk(dist: jax.Array, k: int, d_max: int):
    """Counting-select top-k over integer distances in [0, d_max].

    dist: (Q, N) int32 -> (dists (Q,k) ascending, ids (Q,k) int32).
    Rows with N < k are padded with (d_max+1, N)."""
    Q, N = dist.shape
    k_eff = min(k, N)
    bins = d_max + 1
    rows = jnp.arange(Q)[:, None]

    # 1. histogram (the temporal race, binned by arrival time = distance)
    hist = jnp.zeros((Q, bins), jnp.int32).at[rows, dist].add(1)
    cum = jnp.cumsum(hist, axis=-1)
    # 2. k-th smallest radius r*: first bin where cum >= k
    r_star = jnp.argmax(cum >= k_eff, axis=-1).astype(jnp.int32)   # (Q,)

    # 3. emit: all ids with dist < r* (they number < k by construction), then
    #    fill the remaining slots with r*-ties in index order
    mask_lt = dist < r_star[:, None]
    mask_tie = dist == r_star[:, None]
    n_lt = jnp.sum(mask_lt, axis=-1, keepdims=True)
    rank_lt = jnp.cumsum(mask_lt.astype(jnp.int32), axis=-1) - 1
    rank_tie = jnp.cumsum(mask_tie.astype(jnp.int32), axis=-1) - 1 + n_lt
    slot = jnp.where(mask_lt, rank_lt,
                     jnp.where(mask_tie & (rank_tie < k), rank_tie, k))
    out_d = jnp.full((Q, k), d_max + 1, dist.dtype).at[rows, slot].set(dist, mode="drop")
    out_i = jnp.full((Q, k), N, jnp.int32).at[rows, slot].set(
        jnp.broadcast_to(jnp.arange(N, dtype=jnp.int32), (Q, N)), mode="drop")
    # final O(k log k) ordering of the k winners
    out_d, out_i = jax.lax.sort_key_val(out_d, out_i, dimension=-1)
    return out_d, out_i


def counting_topk_bisect(dist: jax.Array, k: int, d_max: int):
    """Scatter-free counting select: binary-search the radius r* over the
    bounded domain [0, d_max] with vectorized counts (O(n log d) compares, no
    comparison sort, no scatter — VPU/SIMD-friendly on both TPU and CPU),
    then emit winners by searchsorted on the rank cumsum.

    Same semantics as ``counting_topk`` (ascending, ties by index order)."""
    Q, N = dist.shape
    k_eff = min(k, N)

    # 1. binary search for r* = k-th smallest distance (the "finish line")
    lo = jnp.zeros((Q,), jnp.int32)
    hi = jnp.full((Q,), d_max, jnp.int32)
    for _ in range(max(1, (d_max + 1).bit_length())):
        mid = (lo + hi) // 2
        cnt = jnp.sum(dist <= mid[:, None], axis=1)
        hi = jnp.where(cnt >= k_eff, mid, hi)
        lo = jnp.where(cnt >= k_eff, lo, mid + 1)
    r_star = hi

    # 2. emit: strict-inside ids first, then r*-ties in index order
    mask_lt = dist < r_star[:, None]
    mask_tie = dist == r_star[:, None]
    cum_lt = jnp.cumsum(mask_lt.astype(jnp.int32), axis=1)
    cum_tie = jnp.cumsum(mask_tie.astype(jnp.int32), axis=1)
    n_lt = cum_lt[:, -1]

    slots = jnp.arange(k, dtype=jnp.int32)
    want_lt = slots[None, :] < n_lt[:, None]                   # (Q, k)
    target_lt = jnp.minimum(slots[None, :] + 1, jnp.maximum(n_lt, 1)[:, None])
    target_tie = slots[None, :] + 1 - n_lt[:, None]

    find = jax.vmap(lambda c, t: jnp.searchsorted(c, t, side="left"))
    pos_lt = find(cum_lt, target_lt)
    pos_tie = find(cum_tie, jnp.maximum(target_tie, 1))
    pos = jnp.where(want_lt, pos_lt, pos_tie).astype(jnp.int32)
    valid = slots[None, :] < jnp.minimum(
        n_lt + cum_tie[:, -1], jnp.asarray(k_eff))[:, None]
    pos_c = jnp.minimum(pos, N - 1)
    out_d = jnp.where(valid, jnp.take_along_axis(dist, pos_c, axis=1), d_max + 1)
    out_i = jnp.where(valid, pos_c, N)
    # final O(k log k) ordering (stable: equal distances stay in index order)
    out_d, out_i = jax.lax.sort_key_val(out_d, out_i.astype(jnp.int32),
                                        dimension=-1)
    return out_d, out_i


def composite_topk(dist: jax.Array, k: int, d_max: int):
    """Exact top-k via one float ``lax.top_k`` over the composite key
    dist*N + idx (lexicographic; ties by index order — identical semantics
    to the counting selects). Requires (d_max+1)*N < 2^24 so the key is
    exactly representable in f32; falls back to the bisection counting
    select above that. This is XLA's fast selection path and the engine's
    default; ``counting_topk``/``counting_topk_bisect`` remain the
    paper-faithful bounded-domain primitives (and the Pallas two-pass
    path on TPU)."""
    Q, N = dist.shape
    if (d_max + 1) * N >= (1 << 24):
        return counting_topk_bisect(dist, k, d_max)
    k_eff = min(k, N)
    idx = jnp.arange(N, dtype=jnp.int32)
    key = (dist.astype(jnp.float32) * N + idx).astype(jnp.float32)
    neg_key, _ = jax.lax.top_k(-key, k_eff)
    key_k = (-neg_key).astype(jnp.int32)
    out_d = key_k // N
    out_i = key_k % N
    if k_eff < k:
        pad_d = jnp.full((Q, k - k_eff), d_max + 1, out_d.dtype)
        pad_i = jnp.full((Q, k - k_eff), N, jnp.int32)
        out_d = jnp.concatenate([out_d, pad_d], axis=1)
        out_i = jnp.concatenate([out_i, pad_i], axis=1)
    return out_d, out_i


def merge_topk(d1, i1, d2, i2, k: int):
    """Merge two sorted top-k candidate sets (the chunked-scan /
    "partial reconfiguration" merge — O(k), not O(n))."""
    d = jnp.concatenate([d1, d2], axis=-1)
    i = jnp.concatenate([i1, i2], axis=-1)
    d, i = jax.lax.sort_key_val(d, i, dimension=-1)
    return d[..., :k], i[..., :k]


def bucketed_topk(values: jax.Array, k: int, n_bins: int = 256):
    """Approximate top-k of *float* values via the same counting-select,
    after quantizing each row onto n_bins buckets (used to demonstrate the
    primitive on unbounded domains, e.g. MoE router logits).

    Returns (values (Q,k) descending, ids). Exact when k-th and (k+1)-th
    values land in different buckets."""
    lo = jnp.min(values, axis=-1, keepdims=True)
    hi = jnp.max(values, axis=-1, keepdims=True)
    # invert so that "largest value" -> "smallest bucket"
    q = ((hi - values) / jnp.maximum(hi - lo, 1e-9) * (n_bins - 1)).astype(jnp.int32)
    _, ids = counting_topk(q, k, n_bins - 1)
    vals = jnp.take_along_axis(values, ids, axis=-1)
    order = jnp.argsort(-vals, axis=-1, stable=True)
    return jnp.take_along_axis(vals, order, axis=-1), jnp.take_along_axis(ids, order, axis=-1)
