"""Multi-tenant datastore: many mutable stores packed into ONE physical
arena, searched by ONE fused kernel pair, isolated everywhere else.

The AP answers "millions of users" by pointing many small automata at one
shared data stream; TPU-KNN's economics are the same — the win is one
kernel launch serving the whole batch, not one launch per user. Our
analogue packs every tenant's installed epoch into one bn-tile-aligned
codes array and turns tenancy into a *block mask*: the query blocks of
tenant ``t`` enable exactly the grid tiles of ``t``'s region, so a
mixed-tenant batch runs through the UNCHANGED two-pass kernels
(kernels/topk_select.py) and each query's top-k is taken over its own
tenant's rows only — bit-identical to searching that tenant's
``MutableStore`` alone (pinned in tests/test_tenant.py).

Exactness under packing (the pad-row accounting)
------------------------------------------------
A region is its tenant's epoch rows followed by ``cap - n`` pad rows of
all-ones codes, so regions stay bn-aligned without touching the kernels'
``n_valid`` contract (n_valid is a global row *suffix*; interior pads are
not). Pads are instead corrected exactly on the host between the two
passes. Both kernels clamp every distance to ``bins - 1``, so a pad row's
distance to query ``q`` is the known scalar

    b_pad(q) = min(32*W - popcount(q), bins - 1)

and the per-query histogram is corrected by subtracting the region's pad
count at that one bin before the radius derivation
(``ops._radius_from_cum``). In pass 2 pads DO emit, but they sit at the
region tail — after every real row in scan order — so real below-radius
rows occupy slots ``[0, n_lt)`` and real ties start at the tie base
``n_lt + p_lt`` exactly; a slot budget of ``k + max_pad`` plus a gather
that skips the pad-occupied slot ranges reconstructs the per-tenant slot
sequence, and the same stable sort as ``ops._finalize_slots`` finishes
the contract.

Blast radius
------------
Each tenant is a full :class:`~repro.core.mutable.MutableStore` under its
own WAL namespace (``wal.namespace_root``): its own intent log, its own
snapshots, its own fault sites (``site@tenant``). ``recover()`` triages
every namespace with ``wal.verify`` first — interior corruption (acked
records stranded past a bad frame) quarantines THAT tenant and no other;
a torn tail recovers normally; transient recovery faults retry bounded.
A quarantined tenant is excluded from packing, admission, and search;
every healthy tenant keeps serving.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Dict, List, Mapping, NamedTuple, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.checkpoint import wal as wal_mod
from repro.core import mutable as mutable_mod
from repro.runtime import faults as faults_mod

HEALTHY = "healthy"
QUARANTINED = "quarantined"


class TenantQuarantined(RuntimeError):
    """The addressed tenant is quarantined (its data is intact on disk but
    its namespace failed verification or recovery)."""

    def __init__(self, tid: str, error: Optional[str] = None):
        super().__init__(f"tenant {tid!r} is quarantined: {error}")
        self.tid = tid


class TenantQuota(NamedTuple):
    """Per-tenant admission limits; ``None`` = unlimited. ``max_rows``
    bounds live rows, ``max_pending`` bounds acked-but-unsearchable
    backlog, ``max_mutations_per_tick`` is the fair-share rate the server
    enforces per scheduling tick."""

    max_rows: Optional[int] = None
    max_pending: Optional[int] = None
    max_mutations_per_tick: Optional[int] = None


@dataclasses.dataclass
class Tenant:
    tid: str
    store: Optional[mutable_mod.MutableStore]
    quota: TenantQuota
    status: str = HEALTHY
    error: Optional[str] = None


class PackedEpoch(NamedTuple):
    """One immutable packed view over every healthy tenant's installed
    epoch. ``regions[tid] = (start, n_real, cap)`` with ``start``/``cap``
    bn-multiples; rows ``[start + n_real, start + cap)`` are all-ones
    pads with ``ext_ids == -1``."""

    seq: int
    codes: jnp.ndarray                      # (N, W) uint32, bn-aligned
    ext_ids: np.ndarray                     # (N,) int64; -1 on pad rows
    regions: Dict[str, Tuple[int, int, int]]
    tenant_epochs: Dict[str, int]           # tid -> packed store epoch seq
    bn: int

    @property
    def n(self) -> int:
        return int(self.ext_ids.shape[0])


def _round_up(n: int, m: int) -> int:
    return -(-n // m) * m


class TenantArena:
    """Pack N tenants into one arena; search them in one kernel pair.

    ``bn`` is FIXED at construction: region boundaries are bn-tile
    boundaries, and a tuning-derived bn (which drifts with Q and N) would
    silently misalign them — the mask would leak rows across tenants.
    ``store_kw`` forwards to every tenant's ``MutableStore``
    (slack_frac/min_slack/max_pending/...)."""

    def __init__(self, d: int, *, root: Optional[str] = None, bn: int = 128,
                 fault_injector=None,
                 default_quota: TenantQuota = TenantQuota(),
                 **store_kw):
        self.d = d
        self.W = (d + 31) // 32
        self.root = root
        self.bn = bn
        self.faults = fault_injector
        self.default_quota = default_quota
        self.store_kw = dict(store_kw)
        self.tenants: Dict[str, Tenant] = {}
        self._packed: Optional[PackedEpoch] = None
        self._packed_counter = 0

    # -- tenant lifecycle ---------------------------------------------------

    def create_tenant(self, tid: str, codes=None, ids=None, values=None,
                      quota: Optional[TenantQuota] = None) -> Tenant:
        """Bootstrap a tenant (empty when ``codes`` is None) under its own
        WAL namespace with tenant-scoped fault sites."""
        assert tid not in self.tenants, f"tenant {tid!r} exists"
        codes = (np.zeros((0, self.W), np.uint32) if codes is None
                 else np.atleast_2d(np.asarray(codes, np.uint32)))
        assert codes.shape[1] == self.W, (codes.shape, self.W)
        root = (wal_mod.namespace_root(self.root, tid)
                if self.root is not None else None)
        store = mutable_mod.MutableStore.create(
            codes, self.d, ids=ids, values=values, root=root,
            fault_injector=self.faults, fault_scope=tid, **self.store_kw)
        t = Tenant(tid=tid, store=store,
                   quota=quota if quota is not None else self.default_quota)
        self.tenants[t.tid] = t
        return t

    def tenant(self, tid: str) -> Tenant:
        return self.tenants[tid]

    def healthy_tids(self) -> List[str]:
        return sorted(t.tid for t in self.tenants.values()
                      if t.status == HEALTHY)

    def _healthy(self, tid: str) -> Tenant:
        t = self.tenants[tid]
        if t.status != HEALTHY:
            raise TenantQuarantined(tid, t.error)
        return t

    def quarantine(self, tid: str, error: str) -> None:
        """Degrade one tenant: drop it from packing/admission/search. Its
        on-disk namespace is left untouched for offline repair."""
        t = self.tenants.get(tid)
        if t is None:
            t = Tenant(tid=tid, store=None, quota=self.default_quota)
            self.tenants[tid] = t
        if t.store is not None:
            t.store.close()
            t.store = None
        t.status = QUARANTINED
        t.error = error

    # -- admission ----------------------------------------------------------

    def admission_check(self, tid: str, n: int = 1) -> Optional[str]:
        """Why an ``n``-row append to ``tid`` must be shed, or None.
        Reasons, most to least absolute: ``quarantined`` (no store),
        ``quota_exceeded`` (would cross the tenant's row ceiling — a
        caller-visible limit, retrying is pointless until deletes land),
        ``backlog_full`` (compaction or pending backlog is saturated —
        transient, retry later). Rate limits are the server's, not ours:
        they need tick state."""
        t = self.tenants[tid]
        if t.status != HEALTHY:
            return "quarantined"
        q = t.quota
        if q.max_rows is not None and t.store.n_live + n > q.max_rows:
            return "quota_exceeded"
        if t.store.backlog_full:
            return "backlog_full"
        if (q.max_pending is not None
                and t.store.pending_mutations + n > q.max_pending):
            return "backlog_full"
        return None

    def append(self, tid: str, codes, ids=None, values=None) -> np.ndarray:
        return self._healthy(tid).store.append(codes, ids=ids, values=values)

    def delete(self, tid: str, ids) -> int:
        return self._healthy(tid).store.delete(ids)

    # -- packing ------------------------------------------------------------

    def pack(self, force: bool = False) -> PackedEpoch:
        """(Re)build the packed view over every healthy tenant's INSTALLED
        epoch. Cached: a repack happens only when some tenant installed a
        new epoch or the healthy set changed — otherwise the previous
        packed arrays (already on device) are reused as-is."""
        current = {}
        for tid in self.healthy_tids():
            ep = self.tenants[tid].store.epoch
            assert ep is not None, f"tenant {tid!r} has no epoch (flush?)"
            current[tid] = ep.seq
        if (not force and self._packed is not None
                and self._packed.tenant_epochs == current):
            return self._packed
        parts_c: List[np.ndarray] = []
        parts_e: List[np.ndarray] = []
        regions: Dict[str, Tuple[int, int, int]] = {}
        off = 0
        for tid in sorted(current):
            ep = self.tenants[tid].store.epoch
            n_t = ep.n
            cap = _round_up(n_t, self.bn)
            if n_t:
                parts_c.append(np.asarray(ep.layout.codes, np.uint32))
                parts_e.append(np.asarray(ep.store_ids, np.int64))
            pad = cap - n_t
            if pad:
                # all-ones pads: distance to ANY query is the closed-form
                # b_pad(q) the search epilogue corrects for
                parts_c.append(np.full((pad, self.W), 0xFFFFFFFF,
                                       np.uint32))
                parts_e.append(np.full(pad, -1, np.int64))
            regions[tid] = (off, n_t, cap)
            off += cap
        codes = (np.concatenate(parts_c) if parts_c
                 else np.zeros((0, self.W), np.uint32))
        ext = (np.concatenate(parts_e) if parts_e
               else np.zeros((0,), np.int64))
        self._packed_counter += 1
        self._packed = PackedEpoch(seq=self._packed_counter,
                                   codes=jnp.asarray(codes),
                                   ext_ids=ext, regions=regions,
                                   tenant_epochs=current, bn=self.bn)
        return self._packed

    # -- search -------------------------------------------------------------

    def search(self, queries: Mapping[str, np.ndarray], k: int
               ) -> Dict[str, Tuple[np.ndarray, np.ndarray]]:
        """Mixed-tenant batch through ONE hist + ONE emit ``pallas_call``.

        ``queries``: tid -> (Qt, W) packed queries. Returns tid ->
        (dists (Qt, k) int32 ascending, ext_ids (Qt, k) int64, -1 in
        sentinel slots) — bit-identical to each tenant's own
        ``MutableStore.search`` on the same epoch."""
        from repro.kernels import ops

        for tid in queries:
            self._healthy(tid)                  # raises for quarantined
        ep = self.pack()
        out: Dict[str, Tuple[np.ndarray, np.ndarray]] = {}
        tids = [t for t in sorted(queries)
                if np.asarray(queries[t]).shape[0] > 0]
        for tid in sorted(queries):
            if tid not in tids:
                out[tid] = (np.zeros((0, k), np.int32),
                            np.zeros((0, k), np.int64))
        if not tids:
            return out
        N, bins = ep.n, self.d + 1
        k_k = min(k, N)
        if k_k == 0:                            # every region is empty
            for tid in tids:
                qt = np.asarray(queries[tid]).shape[0]
                out[tid] = (np.full((qt, k), bins, np.int32),
                            np.full((qt, k), -1, np.int64))
            return out
        W = self.W
        lanes = max(bins, k_k)
        q_raw = sum(np.asarray(queries[t]).shape[0] for t in tids)
        bq, bn, sub, _, n_pad = ops.topk_geometry(q_raw, N, W, lanes,
                                                  None, self.bn, None)
        assert bn == self.bn and n_pad == N, (bn, self.bn, n_pad, N)

        # group queries per tenant, each group padded to a bq multiple so
        # no query block straddles tenants (mask rows are per-block)
        rows_c: List[np.ndarray] = []
        spans: Dict[str, Tuple[int, int]] = {}  # tid -> (row0, Qt)
        mask_rows: List[np.ndarray] = []
        n_nblocks = N // bn
        qp_total = 0
        for tid in tids:
            qt_codes = np.atleast_2d(np.asarray(queries[tid], np.uint32))
            qt = qt_codes.shape[0]
            g = _round_up(qt, bq)
            spans[tid] = (qp_total, qt)
            rows_c.append(qt_codes)
            if g > qt:
                rows_c.append(np.zeros((g - qt, W), np.uint32))
            start, _, cap = ep.regions[tid]
            row = np.zeros(n_nblocks, np.int32)
            row[start // bn:(start + cap) // bn] = 1
            mask_rows.extend([row] * (g // bq))
            qp_total += g
        q_all = np.concatenate(rows_c)
        mask = jnp.asarray(np.stack(mask_rows)) if n_nblocks else (
            jnp.zeros((qp_total // bq, 0), np.int32))
        qp = jnp.asarray(q_all, jnp.int32)
        xp = ep.codes.astype(jnp.int32)
        nv = jnp.asarray(N, jnp.int32)
        interp = ops._interpret()

        # per-row pad accounting: P = the row's tenant's pad count, b_pad =
        # clamped distance from the row's query to an all-ones pad row
        real = np.zeros(qp_total, bool)
        P_np = np.zeros(qp_total, np.int32)
        for tid in tids:
            row0, qt = spans[tid]
            _, n_t, cap = ep.regions[tid]
            real[row0:row0 + qt] = True
            g = _round_up(qt, bq)
            P_np[row0:row0 + g] = cap - n_t
        P = jnp.asarray(P_np)
        pop = jnp.sum(jax.lax.population_count(qp.view(jnp.int32)
                                               if qp.dtype != jnp.int32
                                               else qp), axis=1)
        b_pad = jnp.minimum(32 * W - pop, bins - 1).astype(jnp.int32)
        real_j = jnp.asarray(real)

        hist, block_min = ops.hamming_hist_pallas(
            qp, xp, bins, nv, block_mask=mask, bq=bq, bn=bn, sub=sub,
            interpret=interp)
        hist = hist.at[jnp.arange(qp_total), b_pad].add(-P)
        cum = jnp.cumsum(hist, axis=-1)
        _, r_star, n_lt, n_emit = ops._radius_from_cum(cum, k_k)
        p_lt = P * (b_pad < r_star).astype(jnp.int32)
        # pad query rows emit nothing (and never raise the block-max-r*
        # bound); the tie base skips the pad-occupied below-radius slots
        r_p = jnp.where(real_j, r_star, -1).astype(jnp.int32)
        tie_base = jnp.where(real_j, n_lt + p_lt, 0).astype(jnp.int32)
        P_max = int(max(ep.regions[t][2] - ep.regions[t][1] for t in tids))
        k_e = k_k + P_max
        out_d, out_i = ops.hamming_emit_pallas(
            qp, xp, r_p, tie_base, bins, k_e, nv, block_min=block_min,
            block_mask=mask, bq=bq, bn=bn, sub=sub, interpret=interp)

        # reconstruct the per-tenant slot sequence: real below-radius rows
        # sit at [0, n_lt) (pads trail them in scan order), real ties at
        # [tie_base, tie_base + ...); then the standard sentinel+sort
        j = jnp.arange(k_k, dtype=jnp.int32)[None, :]
        src = jnp.where(j < n_lt[:, None], j,
                        tie_base[:, None] + (j - n_lt[:, None]))
        src = jnp.clip(src, 0, k_e - 1)
        dd = jnp.take_along_axis(out_d, src, axis=1)
        ii = jnp.take_along_axis(out_i, src, axis=1)
        live = j < n_emit[:, None]
        dd = jnp.where(live, dd, bins)
        ii = jnp.where(live, ii, N)
        dd, ii = jax.lax.sort_key_val(dd, ii, dimension=-1)
        dd_np, ii_np = np.asarray(dd), np.asarray(ii)
        if k_k < k:
            dd_np = np.concatenate(
                [dd_np, np.full((qp_total, k - k_k), bins, np.int32)], 1)
            ii_np = np.concatenate(
                [ii_np, np.full((qp_total, k - k_k), N, np.int32)], 1)
        valid = (ii_np < N) & (dd_np <= self.d)
        ext = np.where(valid,
                       ep.ext_ids[np.clip(ii_np, 0, max(N - 1, 0))], -1)
        for tid in tids:
            row0, qt = spans[tid]
            out[tid] = (dd_np[row0:row0 + qt].astype(np.int32),
                        ext[row0:row0 + qt].astype(np.int64))
        return out

    # -- maintenance / durability -------------------------------------------

    def maintain(self, compact_budget: int = 1, flush: bool = True) -> dict:
        """One cooperative maintenance step: compact the neediest tenants
        (at most ``compact_budget`` — quota-aware fair-share: the deepest
        backlog goes first), then flush + repack. Per-tenant transient
        faults are contained: a tenant whose compact/flush crashes keeps
        its previous epoch and every other tenant proceeds."""
        report = {"compacted": [], "failed": {}}
        pending = sorted(
            (t for t in self.tenants.values()
             if t.status == HEALTHY and t.store.needs_compact),
            key=lambda t: -t.store.pending_mutations)
        for t in pending[:max(compact_budget, 0)]:
            try:
                t.store.maybe_compact()
                report["compacted"].append(t.tid)
            except faults_mod.TRANSIENT as e:
                report["failed"][t.tid] = repr(e)
        if flush:
            for tid in self.healthy_tids():
                t = self.tenants[tid]
                try:
                    t.store.flush()
                except faults_mod.TRANSIENT as e:
                    report["failed"][tid] = repr(e)
            self.pack()
        return report

    def snapshot(self) -> Dict[str, int]:
        """Snapshot every healthy tenant (each under its own namespace);
        transient per-tenant failures are contained and reported."""
        steps: Dict[str, int] = {}
        for tid in self.healthy_tids():
            try:
                steps[tid] = self.tenants[tid].store.snapshot()
            except faults_mod.TRANSIENT:
                steps[tid] = -1
        return steps

    @classmethod
    def recover(cls, d: int, root: str, *, fault_injector=None,
                default_quota: TenantQuota = TenantQuota(),
                quotas: Optional[Mapping[str, TenantQuota]] = None,
                bn: int = 128, recover_retries: int = 32,
                **store_kw) -> "TenantArena":
        """Bring every namespace under ``root`` up independently.

        Triage ladder per tenant: (1) ``wal.verify`` — interior corruption
        (acked records stranded past a bad frame) quarantines the tenant
        outright, a torn tail is a normal crash artifact; (2)
        ``MutableStore.recover`` with bounded retries on transient faults;
        (3) any non-transient error (or retry exhaustion) quarantines.
        Healthy tenants come up no matter how many neighbours are sick —
        the arena itself never fails to recover. Quotas are config, not
        durable state: pass them back in via ``quotas``."""
        arena = cls(d, root=root, bn=bn, fault_injector=fault_injector,
                    default_quota=default_quota, **store_kw)
        quotas = dict(quotas or {})
        for tid in wal_mod.list_namespaces(root):
            ns = wal_mod.namespace_root(root, tid)
            quota = quotas.get(tid, default_quota)
            v = wal_mod.verify(os.path.join(ns, "wal.log"))
            if v["status"] == "corrupt":
                arena.quarantine(
                    tid, f"wal interior corruption at byte "
                         f"{v['bad_offset']} (after seq {v['last_seq']})")
                arena.tenants[tid].quota = quota
                continue
            store = None
            err = None
            for _ in range(max(recover_retries, 1)):
                try:
                    store = mutable_mod.MutableStore.recover(
                        ns, fault_injector=fault_injector,
                        fault_scope=tid, **store_kw)
                    break
                except faults_mod.TRANSIENT as e:
                    err = e
                except Exception as e:          # non-transient: quarantine
                    err = e
                    break
            if store is None:
                arena.quarantine(tid, repr(err))
                arena.tenants[tid].quota = quota
            else:
                arena.tenants[tid] = Tenant(tid=tid, store=store,
                                            quota=quota)
        if arena.healthy_tids():
            arena.pack()
        return arena

    # -- introspection ------------------------------------------------------

    def stats(self) -> dict:
        per = {}
        for tid in sorted(self.tenants):
            t = self.tenants[tid]
            row = {"status": t.status, "error": t.error,
                   "quota_rows": t.quota.max_rows}
            if t.store is not None:
                row.update(t.store.stats())
            per[tid] = row
        packed = self._packed
        return {"tenants": per,
                "n_tenants": len(self.tenants),
                "n_quarantined": sum(
                    1 for t in self.tenants.values()
                    if t.status == QUARANTINED),
                "packed_seq": packed.seq if packed else 0,
                "packed_rows": packed.n if packed else 0,
                "packed_pad_rows": (sum(
                    cap - n for (_, n, cap) in packed.regions.values())
                    if packed else 0)}

    def close(self) -> None:
        for t in self.tenants.values():
            if t.store is not None:
                t.store.close()
