"""Crash-safe mutable datastore: online append/delete over the bucket
arena, epoch-swapped searchable snapshots, write-ahead intent logging, and
an integrity audit.

Why epochs instead of in-place tombstone masking
------------------------------------------------
The fused kernels can exactly exclude exactly two shapes of rows with the
EXISTING machinery: whole tiles (``block_mask``) and a global row suffix
(``n_valid``). An interior tombstone is neither — no sentinel code can
guarantee a maximal distance to every query, and over-fetching k+T then
post-filtering breaks the tie-order determinism every equivalence test
pins. So mutation and search are split:

* the **arena** (``layout.Arena``, host numpy) absorbs mutations in place:
  appends fill per-bucket slack reserved at build time (``slack_frac``),
  deletes tombstone in place (``ids[slot] = -1`` — surviving rows never
  move);
* ``flush()`` gathers the live rows into a dense **epoch** — a
  ``BucketLayout`` with identity perm over exactly the live rows — and
  installs it atomically (readers pin the epoch object for the duration of
  a search; an installed epoch is immutable). Tombstones and slack are
  *expressed to the kernels* the only exact way possible: they are simply
  not in the dense arrays, and the epoch's bucket ``starts`` drive the
  same ``block_mask`` probing, while any pad the kernels add is masked by
  the existing ``n_valid`` contract — zero kernel changes.

Because (a) appends carry strictly increasing external ids, (b) deletes
never move survivors, and (c) compaction is a stable re-scatter keyed by
the arena's FROZEN hamming-prefix bit positions, the live rows of any
epoch sit in ascending-external-id order within each bucket — exactly the
order ``layout.build_arena`` produces from scratch. A mutated store's
epoch is therefore bit-identical to a from-scratch rebuild of the same
logical contents (pinned by tests/test_mutable_store.py).

Durability: every mutation is appended to the WAL (checkpoint/wal.py) and
fsynced BEFORE it touches the arena or is acknowledged; snapshots
(checkpoint/manager.py) bound replay length, and ``recover()`` = last
committed snapshot + WAL tail replay + ``flush()`` + ``audit()``. Fault
sites: ``wal_append`` (before the record is written — a fired fault means
"never acked, never durable"), ``compact_build`` (before the rebuilt
arena is swapped in), ``epoch_install`` (before the new epoch is swapped
in); a crash at any of them loses no acknowledged mutation.
"""
from __future__ import annotations

import os
import struct
import zlib
from typing import List, NamedTuple, Optional, Tuple

import numpy as np

import jax.numpy as jnp

from repro.checkpoint import manager as ckpt
from repro.checkpoint import wal as wal_mod
from repro.core import layout as layout_mod
from repro.core.layout import Arena, BucketLayout


class AuditError(RuntimeError):
    """An arena/epoch invariant failed verification."""


class StoreFull(RuntimeError):
    """Append could not be placed and deferred compaction is backlogged."""


class Epoch(NamedTuple):
    """One immutable searchable snapshot. ``layout.perm`` is the identity:
    epoch positions ARE the ids the kernels report, and ``store_ids``
    translates them to external ids. Readers that captured this object
    keep a complete, consistent view no matter what the store does next."""

    seq: int                # monotonically increasing install counter
    applied_seq: int        # highest WAL seq folded into this epoch
    layout: BucketLayout    # dense live rows, identity perm/inv
    store_ids: np.ndarray   # (n,) int64: epoch position -> external id
    values: jnp.ndarray     # (n,) int32 aligned with layout.codes
    checksum: int           # crc32 over the dense host arrays

    @property
    def n(self) -> int:
        return self.store_ids.shape[0]


# -- WAL payload codecs (schema owned here, framing owned by wal.py) --------

def _encode_append(ids: np.ndarray, values: np.ndarray,
                   codes: np.ndarray) -> bytes:
    n, w = codes.shape
    return (struct.pack("<II", n, w) + ids.astype("<i8").tobytes()
            + values.astype("<i4").tobytes()
            + codes.astype("<u4").tobytes())


def _decode_append(payload: bytes):
    n, w = struct.unpack_from("<II", payload)
    off = 8
    ids = np.frombuffer(payload, "<i8", n, off).copy()
    off += 8 * n
    values = np.frombuffer(payload, "<i4", n, off).copy()
    off += 4 * n
    codes = np.frombuffer(payload, "<u4", n * w, off).reshape(n, w).copy()
    return ids, values, codes


def _encode_delete(ids: np.ndarray) -> bytes:
    return struct.pack("<I", ids.shape[0]) + ids.astype("<i8").tobytes()


def _decode_delete(payload: bytes) -> np.ndarray:
    (n,) = struct.unpack_from("<I", payload)
    return np.frombuffer(payload, "<i8", n, 4).copy()


def _epoch_checksum(codes: np.ndarray, ids: np.ndarray, values: np.ndarray,
                    starts: np.ndarray) -> int:
    c = zlib.crc32(np.ascontiguousarray(codes).tobytes())
    c = zlib.crc32(np.ascontiguousarray(ids).tobytes(), c)
    c = zlib.crc32(np.ascontiguousarray(values).tobytes(), c)
    return zlib.crc32(np.ascontiguousarray(starts).tobytes(), c)


_META_FIELDS = 5  # d, applied_seq, next_id, epoch_seq, has_itq


class MutableStore:
    """Online append/delete/flush over a slack arena with WAL durability.

    ``root=None`` runs purely in memory (no WAL, no snapshots — unit-test
    mode); with a root, ``<root>/wal.log`` is the intent log and
    ``<root>/snap`` holds manager-committed snapshots. ``fault_injector``
    (runtime/faults.py) arms the three sites documented in the module
    docstring. Mutations are visible to ``search``/``datastore_view`` only
    after ``flush()`` — acknowledged-durable and searchable are distinct
    states, exactly as in an LSM memtable."""

    def __init__(self, arena: Arena, *, root: Optional[str] = None,
                 itq=None, fault_injector=None,
                 tombstone_frac: float = 0.25, slack_frac: float = 0.5,
                 min_slack: int = 8, max_pending: int = 1024,
                 fault_scope: Optional[str] = None,
                 _recovering: bool = False):
        self.arena = arena
        self.root = root
        self.itq = itq
        self.faults = fault_injector
        # tenant-scoped fault attribution: every site this store arms is
        # keyed "<site>@<scope>" so a multi-tenant soak can poison (and
        # count) one tenant's faults without touching its neighbours
        self.fault_scope = fault_scope
        self.tombstone_frac = tombstone_frac
        self.slack_frac = slack_frac
        self.min_slack = min_slack
        self.max_pending = max_pending
        self._wal: Optional[wal_mod.WriteAheadLog] = None
        if root is not None:
            hook = (fault_injector.hook("wal_append", fault_scope)
                    if fault_injector is not None else None)
            self._wal = wal_mod.WriteAheadLog(self.wal_path, fault_hook=hook)
        self._id_map = {}           # external id -> arena slot
        self._n_live = 0
        self._rebuild_id_map()
        self._overflow: List[Tuple[int, int, np.ndarray]] = []
        self._applied_seq = -1
        self._next_seq = 0
        self._next_id = (int(self.arena.ids.max()) + 1
                         if self._n_live else 0)
        self._epoch: Optional[Epoch] = None
        self._epoch_seq = 0
        self._dirty = 0             # mutations since the installed epoch
        # buckets mutated since the installed epoch; None = the previous
        # epoch cannot seed an incremental gather (startup, post-compact)
        self._dirty_buckets: Optional[set] = None
        self._epoch_host = None     # (codes, ids, values, starts) host copy
        self._need_compact = False
        self.counters = {"appended": 0, "deleted": 0, "flushes": 0,
                         "compactions": 0, "audits": 0, "wal_records": 0,
                         "bucket_gathers": 0, "incremental_flushes": 0}
        if not _recovering:
            if root is not None:
                self.snapshot()     # recovery base covering bootstrap rows
            self.flush()

    # -- construction -------------------------------------------------------

    @classmethod
    def create(cls, codes, d: int, *, ids=None, values=None,
               n_buckets: Optional[int] = None, root: Optional[str] = None,
               **kw) -> "MutableStore":
        """Bootstrap from dense rows (codes id-ascending; ids default to
        0..n-1). The bootstrap rows are covered by the initial snapshot,
        not the WAL."""
        codes = np.asarray(codes, np.uint32)
        ids = (np.arange(codes.shape[0], dtype=np.int64) if ids is None
               else np.asarray(ids, np.int64))
        slack = kw.get("slack_frac", 0.5)
        mins = kw.get("min_slack", 8)
        arena = layout_mod.build_arena(
            codes, d, ids=ids, values=values, n_buckets=n_buckets,
            slack_frac=slack, min_slack=mins)
        return cls(arena, root=root, **kw)

    @property
    def wal_path(self) -> str:
        assert self.root is not None
        return os.path.join(self.root, "wal.log")

    @property
    def snap_root(self) -> str:
        assert self.root is not None
        return os.path.join(self.root, "snap")

    @property
    def d(self) -> int:
        return self.arena.d

    @property
    def n_live(self) -> int:
        return self._n_live + len(self._overflow)

    @property
    def epoch(self) -> Optional[Epoch]:
        return self._epoch

    @property
    def epoch_seq(self) -> int:
        return self._epoch.seq if self._epoch is not None else -1

    @property
    def pending_mutations(self) -> int:
        """Mutations acked-durable but not yet searchable: the compaction
        backlog plus everything since the last flush."""
        return len(self._overflow) + self._dirty

    @property
    def backlog_full(self) -> bool:
        """Admission-control signal: compaction has fallen behind. The
        server sheds appends while this holds (Server.submit_append)."""
        return len(self._overflow) >= self.max_pending

    @property
    def needs_compact(self) -> bool:
        if self._overflow or self._need_compact:
            return True
        used = int(self.arena.n_used.sum())
        return used > 0 and (used - self._n_live) / used > self.tombstone_frac

    def _rebuild_id_map(self):
        a = self.arena
        self._id_map = {}
        for b in range(a.n_buckets):
            s = int(a.cap_starts[b])
            for slot in range(s, s + int(a.n_used[b])):
                if a.ids[slot] >= 0:
                    self._id_map[int(a.ids[slot])] = slot
        self._n_live = len(self._id_map)

    # -- WAL ----------------------------------------------------------------

    def _log(self, kind: int, payload: bytes) -> int:
        seq = self._next_seq
        if self._wal is not None:
            self._wal.append(kind, payload, seq)   # fault site: wal_append
        self._next_seq = seq + 1
        self.counters["wal_records"] += 1
        return seq

    # -- mutations ----------------------------------------------------------

    def append(self, codes, ids=None, values=None) -> np.ndarray:
        """Durably append rows; returns their external ids. The WAL record
        lands (fsynced) before the arena changes — when this returns, the
        rows survive any crash; they become searchable at the next flush.
        Ids must be fresh and strictly greater than every id ever used
        (auto-assigned when omitted) — the bit-identity ordering contract.
        """
        codes = np.atleast_2d(np.asarray(codes, np.uint32))
        n = codes.shape[0]
        if ids is None:
            ids = np.arange(self._next_id, self._next_id + n, dtype=np.int64)
        else:
            ids = np.atleast_1d(np.asarray(ids, np.int64))
            assert ids.shape == (n,)
            assert np.all(np.diff(ids) > 0) if n > 1 else True
            assert int(ids[0]) >= self._next_id, \
                f"append ids must exceed every prior id (< {self._next_id})"
        values = (np.zeros(n, np.int32) if values is None
                  else np.atleast_1d(np.asarray(values, np.int32)))
        seq = self._log(wal_mod.APPEND, _encode_append(ids, values, codes))
        self._apply_append(ids, values, codes)
        self._applied_seq = seq
        self.counters["appended"] += n
        return ids

    def _apply_append(self, ids, values, codes):
        a = self.arena
        assign = layout_mod.hamming_key_host(codes, a.positions)
        for i in range(ids.shape[0]):
            b = int(assign[i])
            used = int(a.n_used[b])
            cap = int(a.cap_starts[b + 1] - a.cap_starts[b])
            if used < cap:
                slot = int(a.cap_starts[b]) + used
                a.codes[slot] = codes[i]
                a.ids[slot] = int(ids[i])
                a.values[slot] = int(values[i])
                a.n_used[b] = used + 1
                self._id_map[int(ids[i])] = slot
                self._n_live += 1
                if self._dirty_buckets is not None:
                    self._dirty_buckets.add(b)
            else:
                # bucket slack exhausted: defer to compaction (the row is
                # already durable in the WAL; backpressure is the caller's
                # admission decision via `backlog_full`)
                self._overflow.append((int(ids[i]), int(values[i]),
                                       codes[i].copy()))
                self._need_compact = True
        self._next_id = max(self._next_id, int(ids[-1]) + 1)
        self._dirty += ids.shape[0]

    def delete(self, ids) -> int:
        """Durably delete; returns how many ids were actually present.
        Deletes tombstone in place — survivors never move, so epoch order
        (and with it bit-identity to a rebuild) is preserved."""
        ids = np.atleast_1d(np.asarray(ids, np.int64))
        seq = self._log(wal_mod.DELETE, _encode_delete(ids))
        hit = self._apply_delete(ids)
        self._applied_seq = seq
        self.counters["deleted"] += hit
        return hit

    def _apply_delete(self, ids) -> int:
        hit = 0
        overflow_ids = None
        for i in ids:
            slot = self._id_map.pop(int(i), None)
            if slot is not None:
                self.arena.ids[slot] = -1
                self._n_live -= 1
                hit += 1
                if self._dirty_buckets is not None:
                    b = int(np.searchsorted(self.arena.cap_starts, slot,
                                            side="right")) - 1
                    self._dirty_buckets.add(b)
            else:
                if overflow_ids is None:
                    overflow_ids = {t[0] for t in self._overflow}
                if int(i) in overflow_ids:
                    self._overflow = [t for t in self._overflow
                                      if t[0] != int(i)]
                    overflow_ids.discard(int(i))
                    hit += 1
        if hit:
            self._dirty += hit
        return hit

    # -- compaction / epoch install -----------------------------------------

    def _live_rows(self):
        """All live rows (arena + overflow) sorted by external id."""
        a = self.arena
        mask = a.live_mask()
        ids = a.ids[mask]
        codes = a.codes[mask]
        values = a.values[mask]
        if self._overflow:
            o_ids = np.array([t[0] for t in self._overflow], np.int64)
            o_vals = np.array([t[1] for t in self._overflow], np.int32)
            o_codes = np.stack([t[2] for t in self._overflow])
            ids = np.concatenate([ids, o_ids])
            values = np.concatenate([values, o_vals])
            codes = np.concatenate([codes, o_codes])
        order = np.argsort(ids, kind="stable")
        return codes[order], ids[order], values[order]

    def compact(self) -> None:
        """Re-cluster into a fresh arena (frozen key positions, fresh
        slack), folding the overflow backlog in and dropping tombstones.
        Crash-safe: the fault site fires before the swap, so a crash
        leaves the old arena intact and every mutation still in the WAL."""
        if self.faults is not None:
            self.faults.check("compact_build", self.fault_scope)
        self._log(wal_mod.COMPACT_BEGIN, b"")
        codes, ids, values = self._live_rows()
        arena = layout_mod.build_arena(
            codes, self.d, ids=ids, values=values,
            positions=self.arena.positions, slack_frac=self.slack_frac,
            min_slack=self.min_slack)
        # the commit record "applies" trivially (compaction is derived
        # state), so it advances applied_seq like any mutation
        self._applied_seq = self._log(wal_mod.COMPACT_COMMIT, b"")
        self.arena = arena
        self._overflow = []
        self._need_compact = False
        self._rebuild_id_map()
        self.counters["compactions"] += 1
        self._dirty += 1            # the epoch no longer matches the arena
        self._dirty_buckets = None  # every bucket moved: next flush is full

    def maybe_compact(self) -> bool:
        """Cooperative background compaction: the server calls this once
        per tick; it runs only when needed."""
        if self.needs_compact:
            self.compact()
            return True
        return False

    def flush(self) -> Epoch:
        """Install a fresh epoch covering every acknowledged mutation.
        Folds the compaction backlog first, so after any flush the epoch
        IS the store's full logical contents. Readers holding the previous
        epoch keep a complete consistent view (epoch pinning)."""
        if self.needs_compact:
            self.compact()
        if self._epoch is not None and self._dirty == 0:
            return self._epoch
        a = self.arena
        incremental = (self._dirty_buckets is not None
                       and self._epoch_host is not None
                       and a.n_buckets > 0)
        if incremental:
            # re-gather ONLY buckets mutated since the last epoch; clean
            # buckets are sliced straight out of the previous epoch's host
            # arrays. Bit-identical to the full gather because the frozen
            # key positions confine every mutation to its own bucket, so a
            # clean bucket's dense rows cannot have changed.
            p_codes, p_ids, p_values, p_starts = self._epoch_host
            parts_c, parts_i, parts_v = [], [], []
            counts = np.zeros(a.n_buckets, np.int64)
            for b in range(a.n_buckets):
                if b in self._dirty_buckets:
                    s, used = int(a.cap_starts[b]), int(a.n_used[b])
                    seg_ids = a.ids[s:s + used]
                    m = seg_ids >= 0
                    parts_c.append(a.codes[s:s + used][m])
                    parts_i.append(seg_ids[m])
                    parts_v.append(a.values[s:s + used][m])
                else:
                    lo, hi = int(p_starts[b]), int(p_starts[b + 1])
                    parts_c.append(p_codes[lo:hi])
                    parts_i.append(p_ids[lo:hi])
                    parts_v.append(p_values[lo:hi])
                counts[b] = parts_i[-1].shape[0]
            codes = np.ascontiguousarray(np.concatenate(parts_c))
            ids = np.ascontiguousarray(np.concatenate(parts_i))
            values = np.ascontiguousarray(np.concatenate(parts_v))
            self.counters["bucket_gathers"] += len(self._dirty_buckets)
            self.counters["incremental_flushes"] += 1
        else:
            mask = a.live_mask()
            codes = np.ascontiguousarray(a.codes[mask])
            ids = np.ascontiguousarray(a.ids[mask])
            values = np.ascontiguousarray(a.values[mask])
            # per-bucket live counts -> dense bucket starts
            counts = np.array(
                [int(np.count_nonzero(
                    mask[int(a.cap_starts[b]):int(a.cap_starts[b + 1])]))
                 for b in range(a.n_buckets)], np.int64)
            self.counters["bucket_gathers"] += a.n_buckets
        starts = np.zeros(a.n_buckets + 1, np.int64)
        np.cumsum(counts, out=starts[1:])
        starts = starts.astype(np.int32)   # what the layout (and the
        checksum = _epoch_checksum(codes, ids, values, starts)  # audit) sees
        n = codes.shape[0]
        ident = jnp.arange(n, dtype=jnp.int32)
        layout = BucketLayout(codes=jnp.asarray(codes), perm=ident,
                              inv=ident,
                              starts=jnp.asarray(starts, jnp.int32))
        if self.faults is not None:
            # crash -> old epoch holds (and the dirty set keeps
            # accumulating, so the retried flush gathers everything owed)
            self.faults.check("epoch_install", self.fault_scope)
        self._epoch_seq += 1
        self._epoch = Epoch(seq=self._epoch_seq,
                            applied_seq=self._applied_seq, layout=layout,
                            store_ids=ids, values=jnp.asarray(values),
                            checksum=checksum)
        self._dirty = 0
        self._dirty_buckets = set()
        self._epoch_host = (codes, ids, values, starts)
        self.counters["flushes"] += 1
        return self._epoch

    # -- search -------------------------------------------------------------

    def search(self, q_packed, k: int):
        """Top-k over the installed epoch (pinned for the whole call).
        Returns (dists, external ids), sentinel slots -> -1."""
        ep = self._epoch
        assert ep is not None, "flush() before searching"
        if ep.n == 0:
            # an empty epoch has no layout to plan over; the kernel-path
            # sentinel contract (dist bins, id -1) applies verbatim
            q = np.atleast_2d(np.asarray(q_packed)).shape[0]
            return (np.full((q, k), self.d + 1, np.int32),
                    np.full((q, k), -1, np.int64))
        from repro.core import engine as engine_mod
        eng = engine_mod.KNNEngine.from_epoch(ep, self.d)
        dists, pos = eng.search(q_packed, k)
        dists = np.asarray(dists)
        pos = np.asarray(pos)
        # surplus slots (k > live rows) carry sentinel distance bins and a
        # clipped position — the distance, not the position, marks them
        valid = (pos >= 0) & (dists <= self.d)
        ext = np.where(valid,
                       ep.store_ids[np.clip(pos, 0, max(ep.n - 1, 0))]
                       if ep.n else -1, -1)
        return np.asarray(dists), ext

    def datastore_view(self, itq=None):
        """The installed epoch as a retrieval.DataStore: identity-perm
        layout, values aligned to epoch positions, and the arena's FROZEN
        key positions carried along so degraded probing keys queries the
        way the arena was actually bucketed."""
        from repro.core import retrieval as retrieval_mod
        ep = self._epoch
        assert ep is not None, "flush() before taking a view"
        itq = itq if itq is not None else self.itq
        assert itq is not None, "datastore_view needs ITQ params"
        return retrieval_mod.DataStore(
            codes=ep.layout.codes, values=ep.values, itq=itq,
            layout=ep.layout,
            key_positions=jnp.asarray(self.arena.positions))

    # -- durability ---------------------------------------------------------

    def snapshot(self) -> int:
        """Write a committed snapshot of the full mutation state (arena +
        overflow via pre-fold) and truncate the WAL to the records it does
        not cover. Returns the snapshot step."""
        assert self.root is not None, "in-memory store has no snapshots"
        a = self.arena
        meta = np.array([self.d, self._applied_seq, self._next_id,
                         self._epoch_seq, int(self.itq is not None)],
                        np.int64)
        leaves = [a.codes, a.ids, a.values, a.cap_starts, a.n_used,
                  a.positions, meta]
        if self._overflow:
            o_ids = np.array([t[0] for t in self._overflow], np.int64)
            o_vals = np.array([t[1] for t in self._overflow], np.int32)
            o_codes = np.stack([t[2] for t in self._overflow])
        else:
            o_ids = np.zeros(0, np.int64)
            o_vals = np.zeros(0, np.int32)
            o_codes = np.zeros((0, a.codes.shape[1]), np.uint32)
        leaves += [o_ids, o_vals, o_codes]
        if self.itq is not None:
            leaves += [np.asarray(x) for x in
                       (self.itq.mean, self.itq.proj, self.itq.rot)]
        step = self._applied_seq + 1
        hook = (self.faults.hook("ckpt_save", self.fault_scope)
                if self.faults is not None else None)
        ckpt.save(self.snap_root, step, leaves, blocking=True,
                  fault_hook=hook)
        ckpt.garbage_collect(self.snap_root, keep=2)
        if self._wal is not None:
            # rewrite() replaces the inode — reopen so later appends land
            # in the truncated log, not the unlinked file
            self._wal.close()
            wal_mod.rewrite(self.wal_path, wal_mod.replay(
                self.wal_path, after_seq=self._applied_seq))
            hook = (self.faults.hook("wal_append", self.fault_scope)
                    if self.faults is not None else None)
            self._wal = wal_mod.WriteAheadLog(self.wal_path,
                                              fault_hook=hook)
        return step

    @classmethod
    def recover(cls, root: str, *, fault_injector=None,
                **kw) -> "MutableStore":
        """Last committed snapshot + WAL tail replay + flush + audit.
        Corrupt/truncated snapshots fall back to the previous committed
        step (checkpoint.manager), whose longer WAL tail then replays —
        either way no acknowledged mutation is lost."""
        from repro.core import quantize
        snap_root = os.path.join(root, "snap")
        step, leaves = ckpt.restore_latest_arrays(snap_root)
        if leaves is None:
            raise FileNotFoundError(f"no committed snapshot under {root}")
        (codes, ids, values, cap_starts, n_used, positions, meta,
         o_ids, o_vals, o_codes) = leaves[:10]
        d, applied_seq, next_id, epoch_seq, has_itq = (int(x) for x in meta)
        itq = None
        if has_itq:
            mean, proj, rot = leaves[10:13]
            itq = quantize.ITQParams(mean=jnp.asarray(mean),
                                     proj=jnp.asarray(proj),
                                     rot=jnp.asarray(rot))
        arena = Arena(codes=np.asarray(codes, np.uint32),
                      ids=np.asarray(ids, np.int64),
                      values=np.asarray(values, np.int32),
                      cap_starts=np.asarray(cap_starts, np.int64),
                      n_used=np.asarray(n_used, np.int64),
                      positions=np.asarray(positions, np.int32), d=d)
        store = cls(arena, root=root, itq=itq,
                    fault_injector=fault_injector, _recovering=True, **kw)
        store._applied_seq = applied_seq
        store._next_id = next_id
        store._epoch_seq = epoch_seq
        for i in range(o_ids.shape[0]):
            store._overflow.append((int(o_ids[i]), int(o_vals[i]),
                                    np.asarray(o_codes[i], np.uint32)))
        if store._overflow:
            store._need_compact = True
        # replay the WAL tail the snapshot does not cover
        max_seq = applied_seq
        for rec in wal_mod.replay(store.wal_path, after_seq=applied_seq):
            if rec.kind == wal_mod.APPEND:
                a_ids, a_vals, a_codes = _decode_append(rec.payload)
                fresh = np.array([i not in store._id_map
                                  for i in a_ids.tolist()])
                if fresh.all():
                    store._apply_append(a_ids, a_vals, a_codes)
                elif fresh.any():   # partial overlap cannot happen, but
                    store._apply_append(a_ids[fresh], a_vals[fresh],
                                        a_codes[fresh])
            elif rec.kind == wal_mod.DELETE:
                store._apply_delete(_decode_delete(rec.payload))
            # COMPACT_*/SNAPSHOT are informational: compaction is a pure
            # function of arena state, so replaying mutations reproduces
            # the logical contents and any needed compaction re-triggers
            max_seq = max(max_seq, rec.seq)
        store._applied_seq = max_seq
        store._next_seq = max_seq + 1
        store.flush()
        store.audit()
        return store

    # -- integrity ----------------------------------------------------------

    def audit(self, strict: bool = True) -> dict:
        """Verify arena + epoch + WAL invariants; raises AuditError (or
        returns the report with ``ok=False`` when ``strict=False``).
        Run after every recovery and periodically by the server."""
        problems: List[str] = []
        a = self.arena
        if not np.all(np.diff(a.cap_starts) >= 0) or int(a.cap_starts[0]):
            problems.append("cap_starts not monotonic from 0")
        caps = np.diff(a.cap_starts)
        if np.any(a.n_used < 0) or np.any(a.n_used > caps):
            problems.append("n_used out of [0, capacity]")
        if (np.unique(a.positions).size != a.positions.size
                or np.any(a.positions < 0) or np.any(a.positions >= a.d)):
            problems.append("key positions not unique in [0, d)")
        live_ids: List[int] = []
        for b in range(a.n_buckets):
            s, used = int(a.cap_starts[b]), int(a.n_used[b])
            seg = a.ids[s:s + used]
            if np.any(a.ids[s + used:int(a.cap_starts[b + 1])] >= 0):
                problems.append(f"bucket {b}: live id in slack region")
            seg_live = seg[seg >= 0]
            if seg_live.size > 1 and not np.all(np.diff(seg_live) > 0):
                problems.append(f"bucket {b}: live ids not ascending")
            if seg_live.size:
                keys = layout_mod.hamming_key_host(
                    a.codes[s:s + used][seg >= 0], a.positions)
                if np.any(keys != b):
                    problems.append(f"bucket {b}: row keyed elsewhere")
            live_ids.extend(int(i) for i in seg_live)
        if len(set(live_ids)) != len(live_ids):
            problems.append("duplicate live external ids")
        if len(live_ids) != self._n_live or set(live_ids) != set(self._id_map):
            problems.append("id_map inconsistent with arena")
        ep = self._epoch
        if ep is not None:
            st = np.asarray(ep.layout.starts)
            if not np.all(np.diff(st) >= 0) or int(st[0]) != 0:
                problems.append("epoch starts not monotonic from 0")
            perm = np.asarray(ep.layout.perm)
            inv = np.asarray(ep.layout.inv)
            if not (np.array_equal(perm[inv], np.arange(ep.n))
                    and np.array_equal(inv[perm], np.arange(ep.n))):
                problems.append("epoch perm/inv round-trip failed")
            got = _epoch_checksum(np.asarray(ep.layout.codes),
                                  ep.store_ids, np.asarray(ep.values), st)
            if got != ep.checksum:
                problems.append("epoch checksum mismatch")
            if int(st[-1]) != ep.n:
                problems.append("epoch starts[-1] != epoch rows")
            if self._dirty == 0 and not self._overflow:
                # a clean store's epoch must be exactly the live rows
                if ep.n != self._n_live:
                    problems.append("clean epoch row count != arena live")
                elif not set(int(i) for i in ep.store_ids) == set(
                        self._id_map):
                    problems.append("clean epoch ids != arena live ids")
        if self._wal is not None:
            disk_seq = wal_mod.last_seq(self.wal_path)
            if disk_seq > self._applied_seq:
                problems.append("WAL holds records beyond applied_seq")
        self.counters["audits"] += 1
        report = {"ok": not problems, "problems": problems,
                  "n_live": self._n_live, "epoch_seq": self.epoch_seq,
                  "tombstones": self.arena.n_tombstones}
        if strict and problems:
            raise AuditError("; ".join(problems))
        return report

    # -- stats --------------------------------------------------------------

    def stats(self) -> dict:
        a = self.arena
        used = int(a.n_used.sum())
        return {
            "n_live": self.n_live,
            "capacity": a.capacity,
            "tombstones": used - self._n_live,
            "tombstone_frac": (used - self._n_live) / max(used, 1),
            "pending_mutations": self.pending_mutations,
            "overflow": len(self._overflow),
            "epoch_seq": self.epoch_seq,
            "applied_seq": self._applied_seq,
            **self.counters,
        }

    def close(self):
        if self._wal is not None:
            self._wal.close()
