"""QueryPlan IR: one planner and one executor behind every search path.

The paper's AP pipeline is explicitly staged — route the query macro, race
the Hamming counters, report winners through the temporal top-k. This
reproduction grew the equivalent stages as ad-hoc knobs (``select=``,
``use_layout=``, ``chunk``, gather-vs-masked, sharded-vs-local) whose
resolution logic was duplicated across ``core/engine.py``,
``core/retrieval.py`` and ``core/index.py`` — and subtly inconsistent
(``KNNEngine.search`` tested the literal string ``"fused"`` before
resolving ``"auto"``, silently dropping the layout). This module makes the
plan a first-class object instead:

* **IR** — a :class:`QueryPlan` of four typed stages:
  :class:`ProbeStage` (index traversal), :class:`CandidateStage` (how the
  candidate set is restricted: full scan, per-tile block mask, or gathered
  id lists — and which physical layout the scan streams),
  :class:`SelectStage` (the top-k select path + its scan granularity), and
  :class:`MergeStage` (the sharded hierarchical top-k' merge).
* **Planner** — ``plan_local`` / ``plan_sharded`` / ``plan_index`` inspect
  :class:`StoreStats` (N, d, W, query batch, layout presence, index kind,
  shard count, backend) and emit a plan; ``resolve_select`` is THE place
  ``"auto"`` becomes a concrete path. Legacy forced knobs route through the
  same functions as forced-plan overrides (``parse_force`` /
  ``RetrievalConfig.force_plan``) and stay bit-identical.
* **Executor** — :func:`execute` runs a plan over concrete arrays. The
  stage bodies are the former ``engine.search_chunked`` /
  ``engine.search_sharded`` / ``index._scan_candidates`` code moved here
  verbatim, so every legacy entry point is a thin plan-builder with
  bit-identical results (pinned by ``tests/test_plan.py``).
* **Explain** — ``QueryPlan.explain()`` returns a JSON-able summary
  (stages, chosen kernels, block geometry + cost hints from
  ``kernels/tuning.py``, predicted pruning, decision reason);
  ``explain_str()`` renders it for humans, ``compact()`` is a one-token
  form safe for benchmark ``derived`` fields.
* **Decision table** — ``python -m repro.core.plan --table`` dumps the
  planner's rules as a markdown table over canonical scenarios; DESIGN.md
  embeds the generated table and ``--check-design`` fails on drift (CI's
  plan-smoke step).

Every future scaling PR (async batching, caching, multi-backend) extends
this by adding a stage or a planner rule, not another knob.
"""
from __future__ import annotations

import argparse
import dataclasses
import difflib
import json
import sys
import warnings
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.core import binary, layout as layout_mod, topk

DEFAULT_CHUNK = 1 << 16

# concrete select paths the IR can name; "auto" is a REQUEST that
# resolve_select turns into one of these ("composite" is the old literal
# "auto": XLA top_k over the f32 composite key; "approx" is the
# compute-bound MXU partial-reduce tier — opt-in, never an "auto" target,
# exact only at recall_target=1.0)
SELECT_PATHS = ("composite", "counting", "bisect", "fused", "fused_scan",
                "approx")
# accepted request aliases -> IR path ("auto" resolves by rule instead)
_SELECT_ALIASES = {"auto": "auto", "composite": "composite",
                   "counting": "counting", "bisect": "bisect",
                   "fused": "fused", "fused_scan": "fused_scan",
                   "approx": "approx"}


class DistanceMethod:
    XOR = "xor"          # bit-packed popcount (VPU; 32x less HBM traffic)
    MXU = "mxu"          # +/-1 bf16 matmul (systolic array)
    PALLAS = "pallas"    # fused Pallas kernel (kernels/hamming.py)


# ---------------------------------------------------------------------------
# the IR
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ProbeStage:
    """Index traversal: which buckets/leaves feed the candidate stage."""

    kind: str = "none"          # none | kmeans | lsh | kdtree
    nprobe: int = 0             # probed buckets per query (kmeans)
    n_tables: int = 0           # hash tables probed (lsh)


@dataclasses.dataclass(frozen=True)
class CandidateStage:
    """How the candidate set is restricted, and over which physical layout.

    ``kind``: "full" scans every row; "block_mask" turns probed buckets
    into the fused kernels' per-tile enable mask (core/layout.py);
    "gather" materializes per-query candidate-id lists and scans those.
    ``layout``: "none" streams insertion order; "prebuilt" streams a
    BucketLayout's reordered codes (winners map back through the
    permutation); "local_sort" re-sorts per call/shard by a static Hamming
    key (trace-friendly, runs inside shard_map).
    """

    kind: str = "full"          # full | block_mask | gather
    layout: str = "none"        # none | prebuilt | local_sort


@dataclasses.dataclass(frozen=True)
class SelectStage:
    """The top-k select path (see the generated decision table)."""

    path: str = "composite"     # one of SELECT_PATHS
    method: str = DistanceMethod.XOR  # distance method, materializing paths
    chunk: int = DEFAULT_CHUNK  # scan granularity (ignored by "fused")
    recall_target: float = 1.0  # approx tier only: sizes the per-block L
                                # via the analytical bound; 1.0 = exact


@dataclasses.dataclass(frozen=True)
class MergeStage:
    """The sharded merge stage.

    ``strategy`` (sharded plans): "hist_merge" is the distributed counting
    select — per-shard pass-1 histograms ``psum`` into ONE global race,
    each shard emits into disjoint slots of the global (Q, k) output
    (exact, O(Q·bins) cross-device traffic, fused select only);
    "hist_tree" is the SAME distributed counting select with the psums
    reduced hierarchically (``ops._tree_psum``): an intra-host group psum
    then ``fanout``-wide inter-host tree rounds — bit-identical results
    (integer addition is associative), tree-shaped traffic for many-host
    meshes; "concat_sort" is the legacy hierarchical merge — every shard
    reports its local top-k', the gathered (n_shards·k') candidates are
    sorted and cut (O(n_shards·Q·k') traffic; k_local < k makes it the
    statistical reduction of core/hierarchy.py).
    """

    kind: str = "none"          # none | sharded
    k_local: int = 0            # per-shard k' (k_local == k is exact)
    axes: Tuple[str, ...] = ()
    reorder_local: bool = False  # per-shard local_sort before the scan
    strategy: str = ""          # sharded: hist_merge | hist_tree | concat_sort
    fanout: int = 0             # hist_tree group width (0 = flat psum)


# the histogram-racing merge family: flat and tree-reduced distributed
# counting select — interchangeable everywhere the planner asks "is this
# merge exact by construction" (they differ only in psum schedule)
HIST_STRATEGIES = ("hist_merge", "hist_tree")


@dataclasses.dataclass(frozen=True)
class StoreStats:
    """What the planner inspects — static facts about one search call."""

    n: int                      # datastore rows
    d: int                      # code bits
    w: int                      # packed words per code
    q: int                      # query batch size
    k: int = 0                  # requested neighbors (informational)
    has_layout: bool = False    # a prebuilt BucketLayout exists
    mean_bucket_rows: int = 0   # layout bucket size (mask geometry hint)
    n_buckets: int = 0
    index: str = "none"         # none | kmeans | lsh | kdtree
    n_shards: int = 1
    backend: str = ""           # "" -> jax.default_backend() at explain time


def stats_for(n: int, d: int, w: int, q: int, *,
              layout: Optional[layout_mod.BucketLayout] = None,
              n_buckets: Optional[int] = None, **kw) -> StoreStats:
    """StoreStats from counts; THE place layout fields are derived, so a
    new planner-consulted field is threaded exactly once (stats_of,
    index._index_stats and retrieval.plan_for_store all funnel here).
    ``n_buckets`` overrides the layout's (e.g. an index's centroid count)."""
    if n_buckets is None:
        n_buckets = layout.n_buckets if layout is not None else 0
    return StoreStats(
        n=n, d=d, w=w, q=q, has_layout=layout is not None,
        mean_bucket_rows=layout.mean_bucket_rows if layout is not None else 0,
        n_buckets=n_buckets, **kw)


def stats_of(codes: jax.Array, q_packed: jax.Array, d: int,
             layout: Optional[layout_mod.BucketLayout] = None,
             **kw) -> StoreStats:
    """StoreStats from concrete arrays (shapes are static under jit)."""
    return stats_for(codes.shape[0], d, codes.shape[1], q_packed.shape[0],
                     layout=layout, **kw)


@dataclasses.dataclass(frozen=True)
class QueryPlan:
    """One search, fully decided: Probe -> Candidates -> Select -> Merge."""

    probe: ProbeStage
    candidates: CandidateStage
    select: SelectStage
    merge: MergeStage
    n: int
    d: int
    w: int
    q: int
    k: int
    n_shards: int = 1
    mean_bucket_rows: int = 0   # mask-geometry hint (block_mask plans)
    backend: str = ""
    reason: str = ""            # why the planner chose this / fallback note

    # -- summaries ---------------------------------------------------------

    def compact(self) -> str:
        """One token, safe for benchmark ``derived`` fields (no , ; =)."""
        p = self.probe.kind
        if self.probe.nprobe:
            p += f"@{self.probe.nprobe}"
        c = self.candidates.kind
        if self.candidates.layout != "none":
            c += f"+{self.candidates.layout}"
        s = self.select.path
        if s == "approx":
            s += f"@r{self.select.recall_target:g}"
        m = self.merge.kind
        if self.merge.kind == "sharded":
            m = self.merge.strategy or "sharded"
            if m == "hist_tree":
                m += f"@f{self.merge.fanout}"
            elif m != "hist_merge":
                m += f"@k{self.merge.k_local}"
        return f"probe:{p}|cand:{c}|select:{s}|merge:{m}"

    def _kernels(self) -> Tuple[str, ...]:
        if self.candidates.kind == "gather":
            return ("xor+popcount gather", "topk.counting_topk")
        path = self.select.path
        if path == "approx":
            ks = ("approx_select.bit_planes (+/-1 int8)",
                  "lax.dot_general int8->int32 Hamming-as-matmul (MXU)",
                  "approx_select partial-reduce top-L + lexicographic "
                  "sort merge")
            if self.merge.kind == "sharded":
                if self.merge.strategy == "hist_tree":
                    ks += (("approx_select.approx_topk_sharded (pool-hist "
                            "tree psum + disjoint-slot output tree psum, "
                            f"fanout={self.merge.fanout})"),)
                elif self.merge.strategy == "hist_merge":
                    ks += ("approx_select.approx_topk_sharded (pool-hist "
                           "psum + disjoint-slot output psum)",)
                else:
                    ks += ("all_gather k'-per-shard + sort_key_val cut",)
            return ks
        if path in ("fused", "fused_scan"):
            ks = ("kernels.topk_select.hamming_hist_pallas",
                  "kernels.topk_select.hamming_emit_pallas")
            if path == "fused_scan":
                ks += ("lax.scan + topk.merge_topk",)
        else:
            dist = {"xor": "binary.hamming_xor", "mxu": "binary.hamming_mxu",
                    "pallas": "kernels.hamming.hamming_distance_pallas"}[
                        self.select.method]
            sel = {"composite": "topk.composite_topk (lax.top_k)",
                   "counting": "topk.counting_topk",
                   "bisect": "topk.counting_topk_bisect"}[path]
            ks = (dist, sel, "lax.scan + topk.merge_topk")
        if self.merge.kind == "sharded":
            if self.merge.strategy == "hist_tree":
                ks += (("ops.hamming_topk_sharded (hist tree psum + "
                        "disjoint-slot output tree psum, "
                        f"fanout={self.merge.fanout})"),)
            elif self.merge.strategy == "hist_merge":
                ks += ("ops.hamming_topk_sharded (hist psum + disjoint-slot "
                       "output psum)",)
            else:
                ks += ("all_gather k'-per-shard + sort_key_val cut",)
        return ks

    def _predicted_pruning(self) -> str:
        if self.select.path == "approx":
            if self.candidates.kind == "block_mask":
                return ("per-query block mask gates the score matmul; the "
                        "partial reduce keeps L candidates per enabled block")
            return ("partial reduce: only n_blocks*L candidates leave the "
                    "score matmul (the analytical recall bound sizes L)")
        if self.candidates.kind == "block_mask":
            return ("pass 1 skips every tile outside the probed buckets; "
                    "pass 2 composes the mask with the block-min bound")
        if self.candidates.kind == "gather":
            return "candidate lists bound the scan; no kernel-side pruning"
        if self.select.path not in ("fused", "fused_scan"):
            return "none (materializing path)"
        if self.candidates.layout != "none":
            return ("block-min pruning over bucket-clustered tiles "
                    "(bites even on uniform data)")
        return "block-min pruning only where the data layout has locality"

    def geometry(self) -> dict:
        """Block geometry + cost hints the kernels will run under — computed
        by the SAME heuristic the kernels consult (kernels/tuning.py), so
        the summary is exact, not advisory. Sharded plans additionally
        carry a ``merge`` sub-dict (``tuning.shard_hints``): shard geometry
        and the predicted cross-device merge traffic of BOTH strategies."""
        from repro.kernels import tuning

        backend = self.backend or jax.default_backend()
        g = self._geometry_base(backend)
        if self.merge.kind == "sharded":
            g["merge"] = tuning.shard_hints(
                self.q, self.k, self.d + 1, max(self.n_shards, 1),
                k_local=self.merge.k_local,
                strategy=self.merge.strategy or "concat_sort",
                fanout=self.merge.fanout)
        return g

    def _geometry_base(self, backend: str) -> dict:
        from repro.kernels import tuning

        if self.candidates.kind == "gather":
            cap = self.probe.nprobe or 1
            return {"kind": "gather", "cand_width_hint": cap}
        if self.select.path == "approx":
            from repro.kernels import approx_select

            n_sh = max(self.n_shards, 1) if self.merge.kind == "sharded" \
                else 1
            n_eff = max(self.n // n_sh, 1)
            bn = tuning.approx_blocks(self.q, n_eff, self.w, backend=backend)
            bn = max(min(bn, n_eff), 1)
            n_blocks = -(-n_eff // bn)
            k_k = max(min(self.k, self.n), 1)
            rt = self.select.recall_target
            # the recall bound covers the GLOBAL pool on sharded plans
            l = max(min(approx_select.l_for_recall(
                k_k, n_blocks * n_sh, bn, rt), bn), 1)
            # one int8 matmul scores everything: 2*Q*N*d MACs over
            # (Q+N)*d plane bytes — compute-bound by construction
            flops = 2 * self.q * self.n * self.d
            plane_bytes = (self.q + self.n) * self.d
            return {
                "kind": "approx", "bn": bn, "n_blocks": n_blocks,
                "l_per_block": l, "cand_per_query": n_blocks * l,
                "recall_target": rt,
                "predicted_recall": round(approx_select.expected_recall(
                    k_k, n_blocks * n_sh, l), 6),
                "scores_flops": flops, "plane_bytes": plane_bytes,
                "flops_per_byte": round(flops / max(plane_bytes, 1), 2),
                "hint_source": tuning.hint_source(
                    backend, "approx", self.q, n_eff, self.w, 1),
            }
        if self.select.path not in ("fused", "fused_scan"):
            # mirror the executor's resolution exactly (falsy -> default)
            eff = min(self.select.chunk or DEFAULT_CHUNK, self.n)
            if self.select.path == "composite":
                eff = _auto_chunk(eff, self.d)
            return dict(kind="scan", chunk=eff,
                        n_chunks=-(-self.n // max(eff, 1)),
                        **tuning.cost_hints(self.q, self.n, self.w,
                                            self.d + 1, path=self.select.path,
                                            chunk=eff, backend=backend))
        n_eff = self.n if self.merge.kind == "none" else (
            self.n // max(self.n_shards, 1))
        k_eff = (self.merge.k_local
                 if (self.merge.kind == "sharded"
                     and self.merge.strategy != "hist_merge") else self.k)
        hints = tuning.cost_hints(
            self.q, max(n_eff, 1), self.w,
            max(self.d + 1, min(k_eff, max(n_eff, 1))),
            path=self.select.path,
            chunk=((self.select.chunk or DEFAULT_CHUNK)
                   if self.select.path == "fused_scan" else 0),
            bucket_rows=(self.mean_bucket_rows
                         if self.candidates.kind == "block_mask" else 0),
            backend=backend)
        return dict(kind=self.select.path, **hints)

    def explain(self) -> dict:
        """JSON-able plan summary: stages, kernels, geometry, prediction."""
        return {
            "shape": {"n": self.n, "d": self.d, "w": self.w, "q": self.q,
                      "k": self.k},
            "stages": {
                "probe": dataclasses.asdict(self.probe),
                "candidates": dataclasses.asdict(self.candidates),
                "select": dataclasses.asdict(self.select),
                "merge": dataclasses.asdict(self.merge),
            },
            "kernels": list(self._kernels()),
            "geometry": self.geometry(),
            "predicted_pruning": self._predicted_pruning(),
            "reason": self.reason,
            "compact": self.compact(),
        }

    def explain_str(self) -> str:
        e = self.explain()
        geo = dict(e["geometry"])
        merge = geo.pop("merge", None)
        g = ", ".join(f"{k}={v}" for k, v in geo.items())
        lines = [
            f"QueryPlan[{self.compact()}]",
            f"  shape: N={self.n} d={self.d} W={self.w} Q={self.q} k={self.k}",
            f"  kernels: {'; '.join(e['kernels'])}",
            f"  geometry: {g}",
        ]
        if merge is not None:
            lines.append(
                f"  merge: {merge['strategy']} over {merge['n_shards']} "
                f"shards, predicted traffic {merge['merge_bytes']} B "
                f"(hist_merge {merge['hist_merge_bytes']} B vs concat_sort "
                f"{merge['concat_sort_bytes']} B)")
            if merge["strategy"] == "hist_tree":
                lines.append(
                    f"  merge levels: fanout={merge['fanout']} "
                    f"levels={merge['tree_levels']} — intra "
                    f"{merge['hist_tree_intra_bytes']} B, inter "
                    f"{merge['hist_tree_inter_bytes']} B")
        lines += [
            f"  pruning: {e['predicted_pruning']}",
            f"  reason: {self.reason}",
        ]
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# legacy-knob deprecation (forced-plan overrides)
# ---------------------------------------------------------------------------

_WARNED: set = set()


def _warn_legacy(api: str, knob: str, value) -> None:
    """Once-per-process deprecation nudge: the knob still works (it is a
    forced-plan override through the planner, bit-identical), but new code
    should say what it means via the plan API / RetrievalConfig.force_plan."""
    key = (api, knob, str(value))
    if key in _WARNED:
        return
    _WARNED.add(key)
    warnings.warn(
        f"{api}({knob}={value!r}) is a legacy forced-path knob; it now "
        f"routes through repro.core.plan as a forced-plan override "
        f"(bit-identical). Prefer the plan API or "
        f"RetrievalConfig.force_plan.", DeprecationWarning, stacklevel=3)


def parse_force(spec: str) -> dict:
    """Parse a forced-plan override string: comma-separated ``key=value``
    pairs, e.g. ``"select=fused_scan,chunk=4096,layout=off"``. Keys:
    select, method, chunk, layout (off|prebuilt|local_sort), k_local,
    reorder_local (0/1), candidates (full|block_mask|gather),
    merge (hist_merge|hist_tree|concat_sort — sharded plans only),
    fanout (hist_tree group width)."""
    out = {}
    for part in filter(None, (p.strip() for p in spec.split(","))):
        key, eq, val = part.partition("=")
        if not eq:
            raise ValueError(f"force_plan entry {part!r} is not key=value")
        out[key.strip()] = val.strip()
    return out


def _apply_force(plan: QueryPlan, force) -> QueryPlan:
    if not force:
        return plan
    f = parse_force(force) if isinstance(force, str) else dict(force)
    sel, cand, merge = plan.select, plan.candidates, plan.merge
    reason = plan.reason
    if "select" in f:
        path = _SELECT_ALIASES.get(f["select"], f["select"])
        if path == "auto" or path not in SELECT_PATHS:
            raise ValueError(f"force_plan select={f['select']!r}")
        if cand.kind == "block_mask" and path not in ("fused", "approx"):
            # the masked candidate stage runs the fused kernels or the
            # approx partial reduce (both consume the per-tile mask); any
            # other select cannot — record the drop instead of lying
            reason += f"; forced select={path} ignored (block_mask runs fused)"
        else:
            sel = dataclasses.replace(sel, path=path)
            reason += f"; forced select={path}"
    if "method" in f:
        sel = dataclasses.replace(sel, method=f["method"])
    if "chunk" in f:
        sel = dataclasses.replace(sel, chunk=int(f["chunk"]))
    if "recall_target" in f:
        rt = float(f["recall_target"])
        if not 0.0 < rt <= 1.0:
            raise ValueError(f"force_plan recall_target={f['recall_target']!r}"
                             f" (must be in (0, 1])")
        if sel.path == "approx":
            sel = dataclasses.replace(sel, recall_target=rt)
            reason += f"; forced recall_target={rt:g}"
        else:
            reason += (f"; forced recall_target ignored "
                       f"(select={sel.path} is exact)")
    if "layout" in f:
        lay = {"off": "none", "on": "prebuilt"}.get(f["layout"], f["layout"])
        if lay not in ("none", "prebuilt", "local_sort"):
            raise ValueError(f"force_plan layout={f['layout']!r}")
        if cand.kind == "block_mask":
            # the masked stage streams the layout by construction; to drop
            # it force candidates=gather instead
            reason += "; forced layout ignored (block_mask streams it)"
        else:
            cand = dataclasses.replace(cand, layout=lay)
            reason = _scrub_layout_notes(reason) + f"; forced layout={lay}"
    if "candidates" in f:
        ck = f["candidates"]
        if ck not in ("full", "block_mask", "gather"):
            raise ValueError(f"force_plan candidates={ck!r}")
        if cand.kind == "block_mask" and ck == "gather":
            # the one honored transition: index call sites build gather
            # operands whenever the plan says gather (= use_layout=False)
            cand = dataclasses.replace(cand, kind="gather", layout="none")
            sel = dataclasses.replace(sel, path="counting")
            reason += "; forced candidates=gather"
        elif ck != cand.kind:
            # any other rebinding needs operands the call site did not
            # build (a mask needs a layout, gather needs id lists) —
            # record the drop instead of crashing in the executor
            reason += (f"; forced candidates={ck} ignored "
                       f"(no operands for it on a {cand.kind} plan)")
    if "k_local" in f:
        if merge.kind == "sharded":
            merge = dataclasses.replace(merge, k_local=int(f["k_local"]))
            if merge.k_local < plan.k and merge.strategy in HIST_STRATEGIES:
                # the hist family is exact by construction; k' < k asked for
                # the statistical reduction, which only the concat merge runs
                demoted = merge.strategy
                merge = dataclasses.replace(merge, strategy="concat_sort",
                                            fanout=0)
                reason += (f"; {demoted} demoted to concat_sort "
                           "(k_local < k is the statistical reduction)")
        else:
            # inapplicable != unknown: record the drop instead of silently
            # letting the user believe the reduction applied
            reason += "; forced k_local ignored (local plan has no merge)"
    if "reorder_local" in f:
        if merge.kind == "sharded":
            rl = f["reorder_local"] not in ("0", "false", "off")
            merge = dataclasses.replace(merge, reorder_local=rl)
            cand = dataclasses.replace(cand,
                                       layout="local_sort" if rl else "none")
        else:
            reason += "; forced reorder_local ignored (local plan)"
    if "merge" in f:
        mv = f["merge"]
        if mv not in HIST_STRATEGIES + ("concat_sort",):
            raise ValueError(f"force_plan merge={mv!r}")
        if merge.kind != "sharded":
            reason += "; forced merge ignored (local plan has no merge)"
        elif mv in HIST_STRATEGIES and sel.path not in ("fused", "approx"):
            reason += (f"; forced merge={mv} ignored "
                       "(needs the fused or approx select)")
        elif mv in HIST_STRATEGIES and merge.k_local < plan.k:
            reason += (f"; forced merge={mv} ignored "
                       "(k_local < k is the statistical concat merge)")
        elif mv != merge.strategy:
            merge = dataclasses.replace(merge, strategy=mv)
            if mv != "hist_tree":
                merge = dataclasses.replace(merge, fanout=0)
            reason += f"; forced merge={mv}"
    if "fanout" in f:
        fv = int(f["fanout"])
        if merge.kind == "sharded" and merge.strategy == "hist_tree":
            if fv < 2:
                raise ValueError(f"force_plan fanout={fv} (hist_tree needs "
                                 f"fanout >= 2)")
            merge = dataclasses.replace(merge, fanout=fv)
            reason += f"; forced fanout={fv}"
        else:
            reason += ("; forced fanout ignored (only hist_tree merges "
                       "have one)")
    unknown = set(f) - {"select", "method", "chunk", "layout", "candidates",
                        "k_local", "reorder_local", "merge", "recall_target",
                        "fanout"}
    if unknown:
        raise ValueError(f"unknown force_plan keys: {sorted(unknown)}")
    # re-enforce the planner's invariants the overrides may have broken:
    # the hist family races histograms — of per-shard rows (fused) or
    # per-shard candidate pools (approx); any other forced select demotes
    # the sharded merge back to the concat/sort fallback
    if (merge.strategy in HIST_STRATEGIES
            and sel.path not in ("fused", "approx")):
        demoted = merge.strategy
        merge = dataclasses.replace(merge, strategy="concat_sort", fanout=0)
        reason += (f"; {demoted} demoted to concat_sort "
                   f"(select={sel.path} cannot race histograms)")
    # a hist_tree merge always carries a concrete fanout (the executor and
    # shard_hints both consume it); default from the tuning heuristic
    if merge.strategy == "hist_tree" and merge.fanout < 2:
        from repro.kernels import tuning as _tuning
        merge = dataclasses.replace(
            merge, fanout=_tuning.merge_fanout(max(plan.n_shards, 1)) or 2)
    # only the fused/approx selects consume a layout (materializing selects
    # must scan the original order, or tie ids drift from the legacy paths)
    if (cand.kind == "full" and sel.path not in ("fused", "approx")
            and cand.layout != "none"):
        cand = dataclasses.replace(cand, layout="none")
        if merge.reorder_local:
            merge = dataclasses.replace(merge, reorder_local=False)
        reason = (_scrub_layout_notes(reason)
                  + f"; layout dropped (select={sel.path} never consumes one)")
    return dataclasses.replace(plan, select=sel, candidates=cand,
                               merge=merge, reason=reason)


def _scrub_layout_notes(reason: str) -> str:
    """Remove the planner's layout notes from a reason string whose layout
    decision an override just replaced — the plan must not self-contradict
    ('streams the prebuilt BucketLayout; forced layout=none')."""
    for note in ("; streams the prebuilt BucketLayout",
                 "; per-call local_sort (no prebuilt layout)",
                 "; per-shard local_sort before the scan"):
        reason = reason.replace(note, "")
    return reason


# ---------------------------------------------------------------------------
# the planner
# ---------------------------------------------------------------------------

def resolve_select(select: Optional[str], stats: StoreStats,
                   layout_policy: str = "auto") -> Tuple[str, str]:
    """THE select-resolution rule — every entry point funnels through here.

    ``"auto"`` becomes "fused" whenever a layout is available (prebuilt on
    the store/engine) or demanded by config (``layout_policy="require"``):
    only the fused kernels consume a layout, and resolving AFTER the layout
    check was the bug that silently dropped reordering+pruning. Without a
    layout, "auto" stays on the composite-key path (XLA's native top_k —
    the best materializing path, and the historical default). Any concrete
    name is a forced path, passed through untouched.
    Returns (path, reason)."""
    req = "auto" if select is None else select
    if req not in _SELECT_ALIASES:
        raise ValueError(
            f"unknown select {select!r}; known: auto|{'|'.join(SELECT_PATHS)}")
    req = _SELECT_ALIASES[req]
    if req != "auto":
        return req, f"forced select={req}"
    if stats.has_layout:
        return "fused", ("auto->fused: prebuilt layout present, block-min "
                         "pruning + permutation mapping apply")
    if layout_policy == "require":
        return "fused", ("auto->fused: config demands a layout; only the "
                         "fused select consumes one")
    return "composite", ("auto->composite: no layout; XLA top_k over the "
                         "f32 composite key is the best materializing path")


def _resolve_layout(path: str, stats: StoreStats, layout_policy: str
                    ) -> Tuple[str, str]:
    """Which physical layout the full-scan candidate stage streams."""
    if path not in ("fused", "approx") or layout_policy == "off":
        return "none", ""
    if stats.has_layout:
        return "prebuilt", "streams the prebuilt BucketLayout"
    if layout_policy == "require":
        # honor the config, but not silently: this re-sorts the WHOLE
        # datastore on every call (trace) — usually dwarfing the fused
        # search it accelerates
        warnings.warn(
            "layout required but no prebuilt layout exists: re-sorting the "
            "datastore per call; prebuild it (KNNEngine.with_layout / "
            "build_datastore(..., layout=...)) to amortize", stacklevel=4)
        return "local_sort", "per-call local_sort (no prebuilt layout)"
    return "none", ""


def plan_local(stats: StoreStats, k: int, select: Optional[str] = "auto",
               method: str = DistanceMethod.XOR, chunk: int = DEFAULT_CHUNK,
               layout_policy: str = "auto", recall_target: float = 1.0,
               force=None) -> QueryPlan:
    """Plan a single-device full scan (the ``search_chunked`` /
    ``KNNEngine.search`` / local ``knn_logits`` shape).

    ``layout_policy``: "auto" uses a prebuilt layout when present; "require"
    (config said ``layout != "none"``) falls back to a per-call local_sort;
    "off" never streams a layout (the legacy ``use_layout=False``).
    ``recall_target``: the approx tier's knob (ignored by exact selects)."""
    path, reason = resolve_select(select, stats, layout_policy)
    lay, lay_note = _resolve_layout(path, stats, layout_policy)
    if lay_note:
        reason += "; " + lay_note
    if path == "approx" and recall_target >= 1.0:
        reason += "; recall_target=1 keeps the full block (exact pool)"
    plan = QueryPlan(
        probe=ProbeStage(), candidates=CandidateStage(kind="full", layout=lay),
        select=SelectStage(path=path, method=method, chunk=chunk,
                           recall_target=recall_target),
        merge=MergeStage(), n=stats.n, d=stats.d, w=stats.w, q=stats.q, k=k,
        mean_bucket_rows=stats.mean_bucket_rows,
        backend=stats.backend, reason=reason)
    return _apply_force(plan, force)


def plan_sharded(stats: StoreStats, k: int, axes: Sequence[str],
                 k_local: Optional[int] = None, select: Optional[str] = "auto",
                 method: str = DistanceMethod.XOR, chunk: int = DEFAULT_CHUNK,
                 reorder_local: bool = False, layout_policy: str = "auto",
                 merge: Optional[str] = None, uneven: bool = False,
                 recall_target: float = 1.0, fanout: int = 0,
                 force=None) -> QueryPlan:
    """Plan a mesh-sharded search.

    Merge strategy: the default for an exact sharded search (k_local == k)
    is the **distributed counting select** (``hist_merge``): per-shard
    pass-1 histograms ``psum`` into one global per-query r*, each shard
    emits into disjoint slots of the global output — no per-shard top-k
    materialization, no concat/sort, O(Q·bins) cross-device counts instead
    of O(n_shards·Q·k) candidates. Because it races histograms it needs
    the fused select, so sharded ``"auto"`` now resolves to "fused";
    ``merge="concat_sort"`` forces the legacy hierarchical merge, and
    k_local < k (the statistical reduction of core/hierarchy.py, inexact
    by design) always takes it. Past 8 shards auto upgrades the flat psum
    to ``"hist_tree"`` — the SAME counting select with the histogram and
    output reductions tree-scheduled (``ops._tree_psum``, fanout from
    ``tuning.merge_fanout`` unless ``fanout`` pins it) — bit-identical
    results, per-hop traffic bounded by the fanout instead of the shard
    count; ``merge="hist_tree"`` forces it at any shard count. A prebuilt
    GLOBAL layout cannot follow the
    shard slicing, so the only layout option is the per-shard
    ``local_sort`` — taken when the caller asks (``reorder_local``) or
    config demands a layout, and only for the fused path (no other select
    consumes it); it composes with either merge strategy.

    ``uneven=True`` declares that the executor will receive per-shard
    ``shard_n_valid`` counts (shards padded to a common slice): only the
    two-pass kernels mask that padding exactly, so "auto" resolves to
    "fused" whatever the merge strategy."""
    k_local = k if k_local is None else k_local
    req = "auto" if select is None else select
    if (_SELECT_ALIASES.get(req) == "auto"
            and (uneven or (k_local >= k and merge != "concat_sort"))):
        # sharded auto lands on the fused kernels: the hist_merge "merge"
        # IS a histogram psum only they produce, and per-shard n_valid
        # padding is only masked exactly inside them
        path = "fused"
        reason = ("auto->fused: sharded store, the hist_merge distributed "
                  "counting select races per-shard histograms through one "
                  "psum") if (k_local >= k and merge != "concat_sort") else (
            "auto->fused: per-shard n_valid (uneven shards) is masked "
            "exactly only inside the two-pass kernels")
    else:
        path, reason = resolve_select(select, stats, layout_policy)
    want_rl = reorder_local or layout_policy == "require"
    rl = want_rl and path in ("fused", "approx")
    if want_rl and not rl:
        reason += "; reorder_local ignored (only the fused select consumes it)"
    elif rl:
        reason += "; per-shard local_sort before the scan"
    if k_local < k:
        reason += f"; statistical reduction k'={k_local} (inexact, bounded)"
    # the hist family races histograms of rows (fused) or candidate pools
    # (approx) — both produce the psum-able (Q, bins) counts; past 8
    # shards the flat psum upgrades to the tree schedule (same sums)
    n_sh = max(stats.n_shards, 1)
    if path in ("fused", "approx") and k_local >= k:
        strategy = "hist_tree" if n_sh > 8 else "hist_merge"
    else:
        strategy = "concat_sort"
    auto_strategy = strategy
    if merge is not None:
        if merge not in HIST_STRATEGIES + ("concat_sort",):
            raise ValueError(f"unknown merge strategy {merge!r}; "
                             f"known: hist_merge|hist_tree|concat_sort")
        if merge in HIST_STRATEGIES and strategy == "concat_sort":
            reason += (f"; merge={merge} ignored ("
                       + ("k_local < k is the statistical concat merge"
                          if k_local < k else "needs the fused or approx "
                          "select") + ")")
        elif merge != strategy:
            strategy = merge
            reason += f"; forced merge={merge}"
    if strategy == "hist_tree" and strategy == auto_strategy:
        reason += (f"; hist_tree over {n_sh} shards (per-hop traffic "
                   f"bounded by the fanout, not the shard count)")
    eff_fanout = 0
    if strategy == "hist_tree":
        from repro.kernels import tuning as _tuning
        eff_fanout = fanout if fanout >= 2 else (_tuning.merge_fanout(n_sh)
                                                 or 2)
    elif fanout:
        reason += "; fanout ignored (only hist_tree merges have one)"
    plan = QueryPlan(
        probe=ProbeStage(),
        candidates=CandidateStage(kind="full",
                                  layout="local_sort" if rl else "none"),
        select=SelectStage(path=path, method=method, chunk=chunk,
                           recall_target=recall_target),
        merge=MergeStage(kind="sharded", k_local=k_local, axes=tuple(axes),
                         reorder_local=rl, strategy=strategy,
                         fanout=eff_fanout),
        n=stats.n, d=stats.d, w=stats.w, q=stats.q, k=k,
        n_shards=max(stats.n_shards, 1), backend=stats.backend, reason=reason)
    return _apply_force(plan, force)


def plan_index(stats: StoreStats, k: int, kind: str, nprobe: int = 0,
               n_tables: int = 0, use_layout: Optional[bool] = None,
               select: Optional[str] = None, recall_target: float = 1.0,
               force=None) -> QueryPlan:
    """Plan an index-probed search (kmeans/lsh/kdtree traversal feeds the
    candidate stage). Default: bucket-contiguous indexes drive the MASKED
    fused kernels (probed buckets -> per-tile enable mask, no gathered
    (Q, C, W) tensor, full buckets so recall >= gather); indexes built with
    ``reorder=False`` — and the host-traversed kd-trees, whose leaves are
    not layout-contiguous — fall back to the gather scan."""
    if use_layout is None:
        use_layout = stats.has_layout and kind != "kdtree"
    if use_layout:
        assert stats.has_layout, "index built with reorder=False"
        cand = CandidateStage(kind="block_mask", layout="prebuilt")
        if select == "approx":
            sel = SelectStage(path="approx", chunk=0,
                              recall_target=recall_target)
            reason = ("masked approx tier over the bucket-contiguous "
                      "layout: probed buckets gate the score matmul at "
                      "per-query block granularity")
        else:
            sel = SelectStage(path="fused", chunk=0)
            reason = ("masked fused kernels over the bucket-contiguous "
                      "layout: probed buckets become the pass-1 enable mask")
    else:
        cand = CandidateStage(kind="gather", layout="none")
        sel = SelectStage(path="counting", chunk=0)
        reason = ("gather scan: candidate id lists -> xor+popcount + "
                  "counting select"
                  + ("" if stats.has_layout or kind == "kdtree"
                     else " (index has no layout)"))
    plan = QueryPlan(
        probe=ProbeStage(kind=kind, nprobe=nprobe, n_tables=n_tables),
        candidates=cand, select=sel, merge=MergeStage(),
        n=stats.n, d=stats.d, w=stats.w, q=stats.q, k=k,
        mean_bucket_rows=stats.mean_bucket_rows,
        backend=stats.backend, reason=reason)
    return _apply_force(plan, force)


# ---------------------------------------------------------------------------
# the executor
# ---------------------------------------------------------------------------

def _distances(q_packed: jax.Array, chunk_codes: jax.Array, d: int,
               method: str) -> jax.Array:
    if method == DistanceMethod.XOR:
        return binary.hamming_xor(q_packed, chunk_codes)
    if method == DistanceMethod.MXU:
        qb = binary.unpack_bits(q_packed, d)
        xb = binary.unpack_bits(chunk_codes, d)
        # bf16 hits the MXU on TPU; CPU has no native bf16 — use f32 there
        dt = jnp.bfloat16 if jax.default_backend() == "tpu" else jnp.float32
        return binary.hamming_mxu(qb, xb, d, dtype=dt)
    if method == DistanceMethod.PALLAS:
        from repro.kernels import ops
        return ops.hamming_distance(q_packed, chunk_codes)
    raise ValueError(method)


def _auto_chunk(chunk: int, d: int) -> int:
    """Composite-key representability guard — the composite select only.

    ``topk.composite_topk`` ranks by the f32 key ``dist * chunk + idx``,
    which is exact only while (d + 1) * chunk < 2^24 (f32 mantissa).
    Shrinking the chunk keeps the path on XLA's fast ``top_k`` instead of
    its bisect fallback — a performance choice, not a correctness one. The
    other selects never build the key and are bit-identical at ANY chunk
    size, so they scan at the caller's chunk unmodified."""
    if (d + 1) * chunk < (1 << 24):
        return chunk
    return max(1024, ((1 << 24) // (d + 1)) // 1024 * 1024)


def _scan_select(codes_packed: jax.Array, q_packed: jax.Array, k: int,
                 plan: QueryPlan, id_offset: jax.Array | int = 0
                 ) -> Tuple[jax.Array, jax.Array]:
    """The full-scan select stage (former ``engine.search_chunked`` body).

    codes: (N, W) uint32, q: (Q, W); returns (dists (Q, k) ascending,
    global ids (Q, k)). All select paths are bit-identical at any chunk."""
    sel = plan.select
    N, W = codes_packed.shape
    Q = q_packed.shape[0]
    d = plan.d

    if sel.path == "fused":
        from repro.kernels import ops

        bd, bi = ops.hamming_topk(q_packed, codes_packed, k, d + 1)
        return bd, bi + id_offset

    if sel.path == "approx":
        from repro.kernels import approx_select

        bd, bi = approx_select.approx_topk(
            q_packed, codes_packed, k, d + 1,
            recall_target=sel.recall_target)
        return bd, bi + id_offset

    chunk = min(sel.chunk or DEFAULT_CHUNK, N)
    if sel.path == "composite":
        chunk = _auto_chunk(chunk, d)
    n_chunks = (N + chunk - 1) // chunk
    if N % chunk:
        pad = n_chunks * chunk - N
        # pad with all-ones codes at max distance; ids beyond N are masked by
        # their distance landing at the back of the merge (the fused kernels
        # mask them exactly via n_valid instead)
        codes_packed = jnp.pad(codes_packed, ((0, pad), (0, 0)),
                               constant_values=jnp.uint32(0xFFFFFFFF))
    chunks = codes_packed.reshape(n_chunks, chunk, W)

    if sel.path == "fused_scan":
        from repro.kernels import ops

        def body(carry, xs):
            best_d, best_i = carry
            ci, codes_c = xs
            n_valid = jnp.clip(N - ci * chunk, 0, chunk)
            cd, cidx = ops.hamming_topk(q_packed, codes_c, min(k, chunk),
                                        d + 1, n_valid=n_valid)
            best_d, best_i = topk.merge_topk(best_d, best_i, cd,
                                             cidx + ci * chunk, k)
            return (best_d, best_i), None
    else:
        select_fn = {"composite": topk.composite_topk,
                     "counting": topk.counting_topk,
                     "bisect": topk.counting_topk_bisect}[sel.path]

        def body(carry, xs):
            best_d, best_i = carry
            ci, codes_c = xs
            dist = _distances(q_packed, codes_c, d, sel.method)
            # padding rows (global id >= N) must rank strictly last — their
            # all-ones codes can otherwise tie or beat real rows
            gids = ci * chunk + jnp.arange(chunk)
            dist = jnp.where(gids[None, :] < N, jnp.minimum(dist, d), d + 1)
            cd, cidx = select_fn(dist, min(k, chunk), d + 1)
            cids = cidx + ci * chunk
            best_d, best_i = topk.merge_topk(best_d, best_i, cd, cids, k)
            return (best_d, best_i), None

    init = (jnp.full((Q, k), d + 1, jnp.int32), jnp.full((Q, k), N, jnp.int32))
    (bd, bi), _ = jax.lax.scan(body, init, (jnp.arange(n_chunks), chunks))
    return bd, bi + id_offset


def gather_scan(codes: jax.Array, q_packed: jax.Array, cand: jax.Array,
                k: int, d: int) -> Tuple[jax.Array, jax.Array]:
    """Brute-force scan of per-query candidate lists (the gather stage).

    codes: (N, W); cand: (Q, C) int32 with -1 padding -> (dists, ids)."""
    safe = jnp.maximum(cand, 0)
    cand_codes = codes[safe]                                  # (Q, C, W)
    x = jax.lax.bitwise_xor(q_packed[:, None, :], cand_codes)
    dist = jnp.sum(jax.lax.population_count(x).astype(jnp.int32), axis=-1)
    dist = jnp.where(cand < 0, d + 1, dist)
    dd, ii = topk.counting_topk(dist, k, d + 1)
    ids = jnp.take_along_axis(cand, jnp.minimum(ii, cand.shape[1] - 1), axis=-1)
    ids = jnp.where(dd > d, -1, ids)
    return dd, ids


def _execute_sharded(plan: QueryPlan, q_packed: jax.Array, codes: jax.Array,
                     mesh: Mesh, shard_n_valid=None, shard_participate=None
                     ) -> Tuple[jax.Array, jax.Array]:
    """The sharded merge stage.

    ``strategy in HIST_STRATEGIES``: the distributed counting select
    (``ops.hamming_topk_sharded``) — per-shard pass-1 histograms psum into
    one global r*, each shard's pass 2 scatters into disjoint slots of the
    global (Q, k) output ("hist_tree" reduces those psums through the
    ``fanout``-wide tree schedule, bit-identically). Exact; composes with
    the per-shard local_sort layout.  Otherwise the legacy hierarchical
    merge (the former ``engine.search_sharded`` body): per-shard local
    top-k', all-gather of (k' dists, ids) per shard, one sorted cut.

    ``shard_n_valid``: optional (n_shards,) per-shard valid-row counts for
    uneven shards padded to a common slice size (fused select only; ids
    are reported in the UNPADDED global space — bit-identical to a
    single-device search over the concatenation of the valid rows).

    ``shard_participate``: optional (n_shards,) 0/1 mask — shard fault
    tolerance. A zero (dead) shard contributes no rows: its n_valid is
    zeroed inside the kernels and ids renumber over the survivors, so the
    result is bit-identical to a from-scratch search over a store holding
    only the surviving shards' valid rows (hist-family strategies only;
    composes with ``shard_n_valid``)."""
    axes = plan.merge.axes
    k, k_local = plan.k, plan.merge.k_local
    n_dev = 1
    for a in axes:
        n_dev *= mesh.shape[a]
    N = codes.shape[0]
    n_loc = N // n_dev
    hist_fam = plan.merge.strategy in HIST_STRATEGIES
    tree_fanout = (plan.merge.fanout
                   if plan.merge.strategy == "hist_tree" else 0)
    nv_all = None
    if shard_n_valid is not None:
        nv_all = jnp.asarray(shard_n_valid, jnp.int32)
        assert nv_all.shape == (n_dev,), (nv_all.shape, n_dev)
        if plan.select.path not in ("fused", "approx"):
            # only the two-pass kernels and the approx partial reduce mask
            # per-shard padding exactly (by global row id); refuse up front
            # rather than silently running a select the plan did not promise
            raise ValueError(
                f"shard_n_valid (uneven shards) needs the fused or approx "
                f"select; this plan resolved select={plan.select.path!r} — "
                f"leave select='auto' (plan_sharded resolves it to 'fused' "
                f"when shard_n_valid is coming) or force select='fused'")
    part_all = None
    if shard_participate is not None:
        part_all = jnp.asarray(shard_participate, jnp.int32)
        assert part_all.shape == (n_dev,), (part_all.shape, n_dev)
        if not hist_fam:
            # the concat merge all-gathers fixed per-shard candidate lists;
            # it has no slot renumbering to exclude a shard exactly
            raise ValueError(
                f"shard_participate (degraded search) needs a hist-family "
                f"merge; this plan resolved "
                f"merge={plan.merge.strategy!r} — leave merge unset or "
                f"force merge='hist_merge'/'hist_tree'")

    def local(codes_loc, q):
        from repro.kernels import ops

        # flat shard index over the sharding axes
        flat = jnp.zeros((), jnp.int32)
        for a in axes:
            flat = flat * mesh.shape[a] + jax.lax.axis_index(a)
        nv = ib = nt = None
        if nv_all is not None:
            nv = nv_all[flat]
            if part_all is None:
                csum = jnp.cumsum(nv_all)
                ib, nt = csum[flat] - nv, csum[-1]
            else:
                # the kernels renumber over the masked counts; hand them
                # the replicated masked scan instead of gathering it
                nv_eff = nv_all * part_all
                csum = jnp.cumsum(nv_eff)
                ib, nt = csum[flat] - nv_eff[flat], csum[-1]
        perm_l = None
        codes_l = codes_loc
        if plan.candidates.layout == "local_sort":
            codes_l, perm_l = layout_mod.local_sort(codes_loc, plan.d,
                                                    n_valid=nv)
        approx = plan.select.path == "approx"
        if hist_fam:
            if approx:
                from repro.kernels import approx_select

                return approx_select.approx_topk_sharded(
                    q, codes_l, k, plan.d + 1, axes, n_shards=n_dev,
                    recall_target=plan.select.recall_target,
                    n_valid=nv, id_base=ib, n_total=nt, perm=perm_l,
                    participate=part_all, tree_fanout=tree_fanout)
            return ops.hamming_topk_sharded(
                q, codes_l, k, plan.d + 1, axes, n_shards=n_dev,
                n_valid=nv, id_base=ib, n_total=nt, perm=perm_l,
                participate=part_all, tree_fanout=tree_fanout)
        if nv is not None:
            # uneven shards on the legacy merge: mask padding in-kernel,
            # report ids in the unpadded global space, sentinels at the
            # global total so the sorted cut ranks them last everywhere
            if approx:
                from repro.kernels import approx_select

                ld, li = approx_select.approx_topk(
                    q, codes_l, k_local, plan.d + 1,
                    recall_target=plan.select.recall_target, n_valid=nv)
            else:
                ld, li = ops.hamming_topk(q, codes_l, k_local, plan.d + 1,
                                          n_valid=nv)
            if perm_l is not None:
                li = jnp.where(li < nv,
                               perm_l[jnp.minimum(li, n_loc - 1)], li)
            li = jnp.where(li < nv, li + ib, nt)
        elif perm_l is not None:
            ld, li = _scan_select(codes_l, q, k_local, plan)
            # local positions -> local ids -> global ids; local sentinels
            # (pos == n_loc) become this shard's global sentinel, exactly
            # like the unordered path
            li = layout_mod.to_original_ids(perm_l, li) + flat * n_loc
        else:
            ld, li = _scan_select(codes_l, q, k_local, plan,
                                  id_offset=flat * n_loc)
        # hierarchical merge: gather only k' candidates per shard
        gd = jax.lax.all_gather(ld, axes, tiled=False)   # (n_dev, Q, k')
        gi = jax.lax.all_gather(li, axes, tiled=False)
        gd = jnp.moveaxis(gd, 0, 1).reshape(q.shape[0], n_dev * k_local)
        gi = jnp.moveaxis(gi, 0, 1).reshape(q.shape[0], n_dev * k_local)
        sd, order = jax.lax.sort_key_val(gd, gi, dimension=-1)
        if n_dev * k_local < k:
            # fewer gathered candidates than requested: pad to the (Q, k)
            # contract with (d+1, sentinel) instead of silently returning
            # a narrower array; the id sentinel follows the result's id
            # space — the unpadded valid total on uneven shards, N else
            pad = k - n_dev * k_local
            sent = nt if nt is not None else jnp.int32(N)
            sd = jnp.concatenate(
                [sd, jnp.full((q.shape[0], pad), plan.d + 1, jnp.int32)],
                axis=1)
            order = jnp.concatenate(
                [order, jnp.broadcast_to(sent, (q.shape[0], pad))
                 .astype(jnp.int32)], axis=1)
        return sd[:, :k], order[:, :k]

    mapped = shard_map(
        local, mesh=mesh,
        in_specs=(P(axes, None), P(None, None)),
        out_specs=(P(None, None), P(None, None)))
    return mapped(codes, q_packed)


def execute(plan: QueryPlan, q_packed: jax.Array, *,
            codes: Optional[jax.Array] = None,
            layout: Optional[layout_mod.BucketLayout] = None,
            probe: Optional[jax.Array] = None,
            cand_ids: Optional[jax.Array] = None,
            cand: Optional[jax.Array] = None,
            mesh: Optional[Mesh] = None,
            id_offset: jax.Array | int = 0,
            shard_n_valid=None,
            shard_participate=None,
            return_stats: bool = False):
    """Run a plan over concrete operands.

    Operand contract per stage: sharded merge needs ``codes`` + ``mesh``
    (+ optional ``shard_n_valid`` (n_shards,) valid-row counts for uneven
    shards padded to a common slice, and/or ``shard_participate``
    (n_shards,) 0/1 liveness — dead shards' rows are excluded exactly,
    hist-family merges only); block_mask candidates need
    ``layout`` (+ ``probe`` bucket ids and/or ``cand_ids`` original ids,
    core/layout.py semantics); gather candidates need ``codes`` + ``cand``
    ((Q, C) int32, -1 padded); full scans need ``codes`` (plus ``layout``
    when the plan streams a prebuilt one). ``return_stats`` (masked plans
    only) appends the pruning telemetry."""
    if plan.merge.kind == "sharded":
        assert mesh is not None and codes is not None
        return _execute_sharded(plan, q_packed, codes, mesh,
                                shard_n_valid=shard_n_valid,
                                shard_participate=shard_participate)
    if plan.candidates.kind == "block_mask":
        assert layout is not None
        if plan.select.path == "approx":
            from repro.kernels import approx_select

            assert not return_stats, \
                "pruning stats only exist on the fused masked path"
            return approx_select.masked_approx_topk(
                layout, q_packed, plan.k, plan.d, probe=probe,
                cand_ids=cand_ids,
                recall_target=plan.select.recall_target)
        return layout_mod.masked_topk(layout, q_packed, plan.k, plan.d,
                                      probe=probe, cand_ids=cand_ids,
                                      return_stats=return_stats)
    assert not return_stats, "stats only exist on the masked path"
    if plan.candidates.kind == "gather":
        assert codes is not None and cand is not None
        return gather_scan(codes, q_packed, cand, plan.k, plan.d)
    if plan.candidates.layout == "prebuilt":
        assert layout is not None
        dd, ii = _scan_select(layout.codes, q_packed, plan.k, plan)
        return dd, layout_mod.to_original_ids(layout.perm, ii)
    if plan.candidates.layout == "local_sort":
        assert codes is not None
        codes_l, perm = layout_mod.local_sort(codes, plan.d)
        dd, ii = _scan_select(codes_l, q_packed, plan.k, plan)
        return dd, layout_mod.to_original_ids(perm, ii)
    assert codes is not None
    return _scan_select(codes, q_packed, plan.k, plan, id_offset=id_offset)


# ---------------------------------------------------------------------------
# the generated decision table (DESIGN.md embeds this; CI checks drift)
# ---------------------------------------------------------------------------

TABLE_BEGIN = "<!-- BEGIN GENERATED PLANNER TABLE (python -m repro.core.plan --table) -->"
TABLE_END = "<!-- END GENERATED PLANNER TABLE -->"


def _table_scenarios():
    """Canonical scenario cells: every planner rule appears at least once.
    Fixed shapes + backend="cpu" so the table is machine-independent."""
    flat = StoreStats(n=1 << 17, d=128, w=4, q=256, backend="cpu")
    lay = dataclasses.replace(flat, has_layout=True, mean_bucket_rows=256,
                              n_buckets=512)
    k = 16
    with warnings.catch_warnings():
        # the local_sort fallback warns by design; the table just records it
        warnings.simplefilter("ignore")
        return _scenario_rows(flat, lay, k)


def _scenario_rows(flat, lay, k):
    return [
        ("full scan / auto / no layout", plan_local(flat, k)),
        ("full scan / auto / prebuilt layout", plan_local(lay, k)),
        ("full scan / auto / config demands layout, none prebuilt",
         plan_local(flat, k, layout_policy="require")),
        ("forced counting (paper-faithful reference)",
         plan_local(flat, k, select="counting")),
        ("forced bisect (large (d+1)*N, scatter-free)",
         plan_local(flat, k, select="bisect")),
        ("forced fused / no layout",
         plan_local(flat, k, select="fused")),
        ("forced fused_scan (datastore exceeds one invocation)",
         plan_local(flat, k, select="fused_scan")),
        ("forced approx / recall_target=0.9 (MXU partial-reduce tier)",
         plan_local(flat, k, select="approx", recall_target=0.9)),
        ("forced approx / recall_target=1.0 (exact pool, bit-identical "
         "to fused)",
         plan_local(flat, k, select="approx")),
        ("forced-plan override: layout off on a layout engine",
         plan_local(lay, k, force="layout=off")),
        ("IVF probe / bucket-contiguous layout",
         plan_index(dataclasses.replace(lay, index="kmeans"), k,
                    kind="kmeans", nprobe=2)),
        ("IVF probe / approx select over the masked layout",
         plan_index(dataclasses.replace(lay, index="kmeans"), k,
                    kind="kmeans", nprobe=2, select="approx",
                    recall_target=0.95)),
        ("IVF probe / reorder=False (gather fallback)",
         plan_index(dataclasses.replace(flat, index="kmeans"), k,
                    kind="kmeans", nprobe=2, use_layout=False)),
        ("LSH probe / 4 tables / table-0-contiguous layout",
         plan_index(dataclasses.replace(lay, index="lsh"), k, kind="lsh",
                    n_tables=4)),
        ("kd-tree forest (host traversal)",
         plan_index(dataclasses.replace(flat, index="kdtree"), k,
                    kind="kdtree")),
        ("sharded / auto / exact (k_local=k): distributed counting select",
         plan_sharded(dataclasses.replace(flat, n_shards=8), k,
                      axes=("data",))),
        ("sharded / approx: hist_merge over per-shard candidate pools",
         plan_sharded(dataclasses.replace(flat, n_shards=8), k,
                      axes=("data",), select="approx", recall_target=0.95)),
        ("sharded / forced concat_sort merge (legacy fallback)",
         plan_sharded(dataclasses.replace(flat, n_shards=8), k,
                      axes=("data",), merge="concat_sort")),
        ("sharded / 64 shards: auto upgrades to the hierarchical tree "
         "merge",
         plan_sharded(dataclasses.replace(flat, n_shards=64), k,
                      axes=("data",))),
        ("sharded / forced hist_tree fanout=4 at 8 shards",
         plan_sharded(dataclasses.replace(flat, n_shards=8), k,
                      axes=("data",), merge="hist_tree", fanout=4)),
        ("shard loss: degraded-but-exact answer over the survivors",
         dataclasses.replace(
             plan_sharded(dataclasses.replace(flat, n_shards=8), k,
                          axes=("data",)),
             reason="shard fault tolerance: a dead shard is excluded via "
                    "the participation mask (shard_participate) — its "
                    "n_valid is zeroed inside the kernels and id bases "
                    "renumber over the masked scan, so the answer is "
                    "bit-identical to a from-scratch search over only the "
                    "surviving rows; every response carries a "
                    "CoverageReport (per-query coverage_frac + dead-shard "
                    "list, dist/health.py), and row-range replicas "
                    "(dist/sharding.ReplicaMap) restore full coverage "
                    "when a primary dies")),
        ("sharded / exact + reorder_local (hist_merge over sorted shards)",
         plan_sharded(dataclasses.replace(flat, n_shards=8), k,
                      axes=("data",), reorder_local=True)),
        ("sharded / fused / statistical reduction + reorder_local",
         plan_sharded(dataclasses.replace(flat, n_shards=8), k,
                      axes=("data",), k_local=4, select="fused",
                      reorder_local=True)),
        ("sharded / reorder_local with a non-fused select (ignored)",
         plan_sharded(dataclasses.replace(flat, n_shards=8), k,
                      axes=("data",), select="counting",
                      reorder_local=True)),
        ("serving degradation rung: hamming-prefix probe, reduced nprobe",
         plan_index(lay, k, kind="hamming_prefix", nprobe=8)),
        ("serving degradation rung: approx tier before retrieval_off",
         dataclasses.replace(
             plan_local(flat, k, select="approx", recall_target=0.9,
                        layout_policy="off"),
             reason="degradation ladder: when masked probing is exhausted "
                    "the server downshifts to the compute-bound approx "
                    "tier (bounded recall loss, recall_target=0.9) before "
                    "dropping retrieval entirely")),
        ("mutable store: search over one installed epoch",
         dataclasses.replace(
             plan_local(lay, k),
             reason="epoch pinning: the mutable store's flush() installs "
                    "a dense, identity-perm BucketLayout of exactly the "
                    "live rows (slack + tombstones trimmed at install), "
                    "so the planner sees an ordinary prebuilt layout and "
                    "every rule above applies unchanged — readers keep "
                    "the pinned epoch for the whole search")),
        ("tenant arena: mixed-tenant batch over one packed epoch",
         dataclasses.replace(
             plan_local(lay, k),
             reason="tenant packing: every tenant's epoch concatenates "
                    "into one bn-aligned codes array and tenancy becomes "
                    "a per-query-block mask over the region's tiles, so "
                    "a mixed-tenant batch runs ONE fused hist+emit pair "
                    "with zero kernel changes; all-ones pad rows keep "
                    "regions aligned and are corrected exactly on the "
                    "host (b_pad histogram subtraction + tie-base shift) "
                    "— bit-identical to per-tenant searches")),
    ]


def decision_table() -> str:
    """The planner's rules, rendered as a markdown table over the canonical
    scenarios. This is what DESIGN.md embeds and CI diff-checks."""
    def cand_cell(p):
        c = p.candidates.kind
        return c if p.candidates.layout == "none" else \
            f"{c} ({p.candidates.layout})"

    def sel_cell(p):
        s = p.select.path
        if p.candidates.kind == "gather":
            return f"{s} over gathered candidates"
        if s in ("composite", "counting", "bisect"):
            s += f" / {p.select.method}, chunked"
        elif s == "fused_scan":
            s += ", chunked"
        elif s == "approx":
            s += (f" rt={p.select.recall_target:g}, MXU matmul + "
                  f"partial reduce")
        else:
            s += ", single-shot"
        return s

    def merge_cell(p):
        if p.merge.kind == "none":
            return "none"
        if p.merge.strategy == "hist_tree":
            m = (f"hist_tree fanout={p.merge.fanout} (exact, tree psum "
                 f"of histograms)")
        elif p.merge.strategy == "hist_merge":
            m = "hist_merge (exact, psum of histograms)"
        else:
            m = f"concat_sort k'={p.merge.k_local}"
        if p.merge.reorder_local:
            m += ", reorder_local"
        return m

    lines = [
        "| scenario | probe | candidates | select | merge | why |",
        "|---|---|---|---|---|---|",
    ]
    for label, p in _table_scenarios():
        probe = p.probe.kind + (f" nprobe={p.probe.nprobe}"
                                if p.probe.nprobe else "")
        lines.append(
            f"| {label} | {probe} | {cand_cell(p)} | {sel_cell(p)} | "
            f"{merge_cell(p)} | {p.reason} |")
    return "\n".join(lines)


def extract_design_table(text: str) -> Optional[str]:
    """The generated table committed inside DESIGN.md, or None."""
    try:
        start = text.index(TABLE_BEGIN) + len(TABLE_BEGIN)
        end = text.index(TABLE_END)
    except ValueError:
        return None
    return text[start:end].strip()


def check_design(path: str) -> int:
    """0 if DESIGN.md's embedded table matches the planner's rules."""
    with open(path) as f:
        committed = extract_design_table(f.read())
    current = decision_table()
    if committed is None:
        print(f"{path}: no generated planner table "
              f"(markers {TABLE_BEGIN!r} .. {TABLE_END!r})", file=sys.stderr)
        return 1
    if committed == current:
        print(f"{path}: planner decision table up to date")
        return 0
    print(f"{path}: planner decision table DRIFTED from the planner's "
          f"rules — regenerate with `python -m repro.core.plan --table`:",
          file=sys.stderr)
    sys.stderr.writelines(difflib.unified_diff(
        committed.splitlines(keepends=True),
        current.splitlines(keepends=True),
        fromfile="DESIGN.md", tofile="planner"))
    print(file=sys.stderr)
    return 1


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.core.plan",
        description="QueryPlan planner introspection")
    ap.add_argument("--table", action="store_true",
                    help="print the generated decision table (markdown)")
    ap.add_argument("--json", action="store_true",
                    help="print every scenario's full explain() as JSON")
    ap.add_argument("--check-design", metavar="PATH",
                    help="verify PATH's embedded table matches the planner")
    args = ap.parse_args(argv)
    if args.check_design:
        return check_design(args.check_design)
    if args.json:
        print(json.dumps({label: p.explain()
                          for label, p in _table_scenarios()}, indent=1))
        return 0
    print(decision_table())
    return 0


if __name__ == "__main__":
    # `python -m repro.core.plan` first imports the repro.core package,
    # whose __init__ already loaded this file as repro.core.plan — delegate
    # to that canonical module object so exactly one copy of the IR
    # classes and _WARNED state is ever live (CI avoids even the cosmetic
    # runpy double-import warning by invoking main() via `python -c`)
    from repro.core import plan as _canonical
    raise SystemExit(_canonical.main())
