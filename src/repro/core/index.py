"""Spatial indexing structures (paper §3.4): hierarchical k-means (IVF),
LSH tables, and randomized kd-trees.

As in the paper, index *traversal* is factored out of the scan engine: it
selects candidate buckets, and the engine scans them. Since the layout
subsystem (core/layout.py) landed, bucket-contiguous indexes default to
the **masked fused path**: the builder physically reorders the codes by
bucket, traversal translates probed buckets into grid-block ranges, and
the two-pass Pallas kernels scan ONLY the enabled tiles — no gathered
(Q, C, W) candidate tensor, no bucket-capacity truncation (the layout
holds every member; the capped ``buckets`` table survives for the legacy
gather path and for mask building from multi-table candidates). The
gather scan (``_scan_candidates``) remains as the reference path and for
the host-traversed kd-trees. kd-tree construction/traversal run on the
host (numpy), exactly the paper's host/accelerator split; k-means and LSH
traversals are cheap dense ops and run on device.

Masked-path semantics vs gather (see layout.py): the candidate set is the
probed buckets rounded OUTWARD to data-block boundaries, unioned over each
query block — a superset, so recall never drops; ties at equal distance
break by layout position instead of candidate-list order.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import binary, layout as layout_mod, plan as plan_mod

# the gather-stage executor moved into the planner/executor module; kept
# under its historical name for tests and host-traversed callers
_scan_candidates = plan_mod.gather_scan


def _index_stats(codes: jax.Array, d: int, layout, n_queries: int, k: int,
                 kind: str, n_buckets: int = 0) -> plan_mod.StoreStats:
    """StoreStats for an index-probed search (shared by every index kind)."""
    return plan_mod.stats_for(codes.shape[0], d, codes.shape[1], n_queries,
                              layout=layout, n_buckets=n_buckets, k=k,
                              index=kind)


def _pad_buckets(assign: np.ndarray, n_buckets: int, cap: int) -> np.ndarray:
    """assign: (N,) bucket of each id -> (n_buckets, cap) int32, -1 padded."""
    table = np.full((n_buckets, cap), -1, np.int32)
    fill = np.zeros(n_buckets, np.int64)
    for i, b in enumerate(assign):
        if fill[b] < cap:
            table[b, fill[b]] = i
            fill[b] += 1
    return table


def hamming_prefix_probe(q_codes: jax.Array, positions: jax.Array,
                         n_buckets: int, nprobe: int, d: int) -> jax.Array:
    """(Q, W) packed queries -> (Q, nprobe) hamming-prefix bucket ids,
    nearest first.

    The centroid-free probe: a bucket's id IS its key bit pattern
    (``layout.hamming_prefix_assign``), so probe ranking is the Hamming
    distance between the query's key bits and each bucket id — no table to
    consult. Shared by the serving degradation ladder (retrieval) and the
    mutable store's epoch probing; ``positions`` must be the positions the
    layout was actually bucketed by (frozen ones for mutable stores)."""
    bits = positions.shape[0]
    qb = binary.unpack_bits(q_codes, d)[:, positions].astype(jnp.int32)
    bucket_bits = (jnp.arange(n_buckets, dtype=jnp.int32)[:, None]
                   >> jnp.arange(bits, dtype=jnp.int32)[None, :]) & 1
    dist = jnp.sum(qb[:, None, :] != bucket_bits[None, :, :], axis=-1)
    _, probe = jax.lax.top_k(-dist, min(nprobe, n_buckets))
    return probe.astype(jnp.int32)


def _dedup_candidates(cand: jax.Array) -> jax.Array:
    """Mask repeated ids in a (Q, C) candidate list to -1 (padding).

    Multi-table indexes emit the same id from several tables; left in, one
    near neighbor occupies several top-k slots and silently evicts real
    neighbors. Keeps the FIRST occurrence, so the surviving tie order is
    unchanged. O(C log C) per row (sort + adjacent compare), no C^2
    pairwise blow-up."""
    rows = jnp.arange(cand.shape[0])[:, None]
    # stable sort by value: among equals, the earliest list position wins
    order = jnp.argsort(cand, axis=-1, stable=True)
    sc = jnp.take_along_axis(cand, order, axis=-1)
    dup_sorted = jnp.concatenate(
        [jnp.zeros_like(sc[:, :1], dtype=bool),
         (sc[:, 1:] == sc[:, :-1]) & (sc[:, 1:] >= 0)], axis=-1)
    dup = jnp.zeros_like(dup_sorted).at[rows, order].set(dup_sorted)
    return jnp.where(dup, -1, cand)


# ---------------------------------------------------------------------------
# hierarchical k-means (IVF)
# ---------------------------------------------------------------------------

class KMeansIndex(NamedTuple):
    centroids: jax.Array    # (C, dim) f32
    buckets: jax.Array      # (C, cap) int32, -1 padded
    codes: jax.Array        # (N, W) packed
    d: int
    layout: Optional[layout_mod.BucketLayout] = None  # cluster-contiguous


def kmeans_build(data: jax.Array, codes: jax.Array, d: int, n_clusters: int,
                 iters: int = 10, capacity_factor: float = 2.0,
                 key=None, reorder: bool = True) -> KMeansIndex:
    """``reorder=True`` (default) also builds the cluster-contiguous layout
    so ``kmeans_search`` drives the masked fused kernels; ``reorder=False``
    keeps the gather-only index (e.g. when the codes array is shared and
    must not be duplicated)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    data = data.astype(jnp.float32)
    n = data.shape[0]
    init_idx = jax.random.choice(key, n, (n_clusters,), replace=False)
    cent = data[init_idx]

    def step(cent, _):
        d2 = (jnp.sum(data**2, 1)[:, None] - 2 * data @ cent.T
              + jnp.sum(cent**2, 1)[None])
        a = jnp.argmin(d2, axis=1)
        one = jax.nn.one_hot(a, n_clusters, dtype=jnp.float32)
        counts = jnp.maximum(one.sum(0), 1.0)
        return (one.T @ data) / counts[:, None], None

    cent, _ = jax.lax.scan(step, cent, None, length=iters)
    d2 = (jnp.sum(data**2, 1)[:, None] - 2 * data @ cent.T + jnp.sum(cent**2, 1)[None])
    assign = np.asarray(jnp.argmin(d2, axis=1))
    cap = int(np.ceil(capacity_factor * n / n_clusters))
    table = _pad_buckets(assign, n_clusters, cap)
    lay = (layout_mod.reorder_by_assignment(codes, assign, n_clusters)
           if reorder else None)
    return KMeansIndex(centroids=cent, buckets=jnp.asarray(table), codes=codes,
                       d=d, layout=lay)


def kmeans_plan(index: KMeansIndex, n_queries: int, k: int, nprobe: int = 1,
                use_layout: bool | None = None) -> plan_mod.QueryPlan:
    """The QueryPlan a ``kmeans_search`` with these arguments executes."""
    stats = _index_stats(index.codes, index.d, index.layout, n_queries, k,
                         "kmeans", n_buckets=index.centroids.shape[0])
    return plan_mod.plan_index(stats, k, kind="kmeans", nprobe=nprobe,
                               use_layout=use_layout)


def kmeans_search(index: KMeansIndex, queries: jax.Array, q_packed: jax.Array,
                  k: int, nprobe: int = 1, use_layout: bool | None = None,
                  return_stats: bool = False):
    """Traverse: nearest nprobe centroids (a distance calc per node, as the
    paper notes for k-means indexes); then scan the union of buckets.

    The planner (``kmeans_plan``) picks the candidate stage: with a layout
    (the default build), the probed buckets become an enable mask over the
    reordered codes and the masked fused kernels scan only those tiles —
    ``nprobe`` is a real throughput knob, not a gather width, and buckets
    are scanned in FULL (no capacity truncation). ``use_layout=False`` is
    the legacy forced-gather override (also the planner's fallback when the
    index has no layout); ``return_stats`` (masked path only) appends the
    kernel pruning telemetry."""
    if use_layout is not None:
        plan_mod._warn_legacy("kmeans_search", "use_layout", use_layout)
    q = queries.astype(jnp.float32)
    cent = index.centroids
    d2 = (jnp.sum(q**2, 1)[:, None] - 2 * q @ cent.T + jnp.sum(cent**2, 1)[None])
    _, probe = jax.lax.top_k(-d2, nprobe)                     # (Q, nprobe)
    p = kmeans_plan(index, q.shape[0], k, nprobe=nprobe, use_layout=use_layout)
    if p.candidates.kind == "block_mask":
        return plan_mod.execute(p, q_packed, layout=index.layout, probe=probe,
                                return_stats=return_stats)
    cand = index.buckets[probe].reshape(q.shape[0], -1)       # (Q, nprobe*cap)
    return plan_mod.execute(p, q_packed, codes=index.codes, cand=cand,
                            return_stats=return_stats)


# ---------------------------------------------------------------------------
# LSH tables (bit-sampling over the binary codes)
# ---------------------------------------------------------------------------

class LSHIndex(NamedTuple):
    bit_ids: jax.Array      # (T, b) which code bits form each table's key
    buckets: jax.Array      # (T, 2^b, cap) int32, -1 padded
    codes: jax.Array        # (N, W)
    d: int
    layout: Optional[layout_mod.BucketLayout] = None  # table-0-contiguous


def _hash_codes(codes_bits: jax.Array, bit_ids: jax.Array) -> jax.Array:
    """codes_bits: (N, d) {0,1}; bit_ids: (T, b) -> keys (T, N) int32."""
    sel = codes_bits[:, bit_ids]                              # (N, T, b)
    weights = (1 << jnp.arange(bit_ids.shape[1], dtype=jnp.int32))
    return jnp.sum(sel.astype(jnp.int32) * weights, axis=-1).T


def lsh_build(codes: jax.Array, d: int, n_tables: int = 4, bits_per_table: int = 12,
              capacity_factor: float = 4.0, key=None,
              reorder: bool = True) -> LSHIndex:
    key = key if key is not None else jax.random.PRNGKey(1)
    n = codes.shape[0]
    assert bits_per_table <= d, (bits_per_table, d)
    # sample bits WITHOUT replacement per table: a duplicate bit id would
    # hash on fewer than b distinct bits and silently lose key entropy
    bit_ids = jnp.stack([
        jax.random.choice(kt, d, (bits_per_table,), replace=False)
        for kt in jax.random.split(key, n_tables)]).astype(jnp.int32)
    keys = np.asarray(_hash_codes(binary.unpack_bits(codes, d), bit_ids))
    n_buckets = 1 << bits_per_table
    cap = int(np.ceil(capacity_factor * n / n_buckets))
    tables = np.stack([_pad_buckets(keys[t], n_buckets, cap)
                       for t in range(n_tables)])
    # only ONE table can be layout-contiguous; cluster by table 0's key —
    # its probes become block RANGES, the other tables' members enable the
    # blocks that hold them (layout.position_block_mask)
    lay = (layout_mod.reorder_by_assignment(codes, keys[0], n_buckets)
           if reorder else None)
    return LSHIndex(bit_ids=bit_ids, buckets=jnp.asarray(tables), codes=codes,
                    d=d, layout=lay)


def lsh_plan(index: LSHIndex, n_queries: int, k: int,
             use_layout: bool | None = None) -> plan_mod.QueryPlan:
    """The QueryPlan an ``lsh_search`` with these arguments executes."""
    stats = _index_stats(index.codes, index.d, index.layout, n_queries, k,
                         "lsh", n_buckets=index.buckets.shape[1])
    return plan_mod.plan_index(stats, k, kind="lsh",
                               n_tables=index.bit_ids.shape[0],
                               use_layout=use_layout)


def lsh_search(index: LSHIndex, q_packed: jax.Array, k: int,
               use_layout: bool | None = None, return_stats: bool = False):
    """Probe one bucket per table, then select over the union.

    Masked path (the planner's default when the index has a layout):
    table 0's bucket is a contiguous block range of the reordered codes;
    tables 1..T-1 contribute their (capped) members by position, enabling
    the blocks that hold them. Duplicates across tables cost nothing —
    every enabled row is scanned exactly once, so the dedup problem of the
    gather path cannot occur by construction. Gather path: candidate lists
    are deduped (``_dedup_candidates``) so a multi-table repeat cannot
    occupy several top-k slots."""
    if use_layout is not None:
        plan_mod._warn_legacy("lsh_search", "use_layout", use_layout)
    q_bits = binary.unpack_bits(q_packed, index.d)
    keys = _hash_codes(q_bits, index.bit_ids)                 # (T, Q)
    T = index.bit_ids.shape[0]
    p = lsh_plan(index, q_packed.shape[0], k, use_layout=use_layout)
    if p.candidates.kind == "block_mask":
        others = jnp.concatenate(
            [index.buckets[t][keys[t]] for t in range(1, T)],
            axis=-1) if T > 1 else None                       # (Q, (T-1)*cap)
        return plan_mod.execute(p, q_packed, layout=index.layout,
                                probe=keys[0][:, None], cand_ids=others,
                                return_stats=return_stats)
    cand = jnp.concatenate(
        [index.buckets[t][keys[t]] for t in range(T)], axis=-1)  # (Q, T*cap)
    return plan_mod.execute(p, q_packed, codes=index.codes,
                            cand=_dedup_candidates(cand),
                            return_stats=return_stats)


# ---------------------------------------------------------------------------
# randomized kd-trees (host build + host traversal, device scan)
# ---------------------------------------------------------------------------

class KDTreeIndex:
    """Forest of randomized kd-trees over the float vectors. Median splits on
    a dim sampled from the top-variance dims (FLANN-style)."""

    def __init__(self, data: np.ndarray, codes, d: int, n_trees: int = 4,
                 leaf_size: int = 512, top_dims: int = 8, seed: int = 0):
        self.codes = codes
        self.d = d
        self.data = np.asarray(data, np.float32)
        self.rng = np.random.default_rng(seed)
        variances = self.data.var(axis=0)
        self.top_dims = np.argsort(-variances)[:top_dims]
        self.leaf_size = leaf_size
        self.trees = [self._build(np.arange(len(self.data))) for _ in range(n_trees)]

    def _build(self, ids: np.ndarray):
        if len(ids) <= self.leaf_size:
            return ("leaf", ids.astype(np.int32))
        dim = int(self.rng.choice(self.top_dims))
        vals = self.data[ids, dim]
        median = float(np.median(vals))
        left = ids[vals <= median]
        right = ids[vals > median]
        if len(left) == 0 or len(right) == 0:          # degenerate split
            return ("leaf", ids.astype(np.int32))
        return ("node", dim, median, self._build(left), self._build(right))

    def _traverse(self, node, q: np.ndarray) -> np.ndarray:
        while node[0] == "node":
            _, dim, median, l, r = node
            node = l if q[dim] <= median else r
        return node[1]

    def search(self, queries: np.ndarray, q_packed, k: int):
        """Host traversal per tree -> device scan of the candidate union."""
        queries = np.asarray(queries, np.float32)
        cap = self.leaf_size * len(self.trees)
        cand = np.full((len(queries), cap), -1, np.int32)
        for qi, q in enumerate(queries):
            ids = np.unique(np.concatenate(
                [self._traverse(t, q) for t in self.trees]))[:cap]
            cand[qi, :len(ids)] = ids
        stats = _index_stats(self.codes, self.d, None, len(queries), k,
                             "kdtree")
        p = plan_mod.plan_index(stats, k, kind="kdtree",
                                n_tables=len(self.trees))
        return plan_mod.execute(p, q_packed, codes=self.codes,
                                cand=jnp.asarray(cand))
