"""Binary quantization: ITQ (the paper's offline pipeline) + LSH codes.

ITQ (Gong & Lazebnik, CVPR'11): PCA to ``bits`` dims, then alternate
  B = sign(V R)          (discretize)
  R = U W^T  from  svd(V^T B) = U S W^T   (orthogonal Procrustes)
minimizing ||B - V R||_F over rotations.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class ITQParams(NamedTuple):
    mean: jax.Array       # (dim,)
    proj: jax.Array       # (dim, bits)  PCA
    rot: jax.Array        # (bits, bits) learned rotation


def itq_train(x: jax.Array, bits: int, iters: int = 30, key=None) -> ITQParams:
    """x: (n, dim) f32. Returns encode params."""
    key = key if key is not None else jax.random.PRNGKey(0)
    x = x.astype(jnp.float32)
    mean = jnp.mean(x, axis=0)
    xc = x - mean
    # PCA via SVD of the (dim, dim) covariance
    cov = (xc.T @ xc) / x.shape[0]
    _, _, vt = jnp.linalg.svd(cov, full_matrices=False)
    proj = vt[:bits].T                                        # (dim, bits)
    v = xc @ proj                                             # (n, bits)
    r0, _ = jnp.linalg.qr(jax.random.normal(key, (bits, bits), jnp.float32))

    def step(r, _):
        b = jnp.sign(v @ r)
        u, _, wt = jnp.linalg.svd(v.T @ b, full_matrices=False)
        return u @ wt, None

    rot, _ = jax.lax.scan(step, r0, None, length=iters)
    return ITQParams(mean=mean, proj=proj, rot=rot)


def itq_encode(x: jax.Array, p: ITQParams) -> jax.Array:
    """x: (..., dim) -> bits (..., code_bits) uint8 in {0,1}."""
    return (itq_project(x, p) > 0).astype(jnp.uint8)


def itq_project(x: jax.Array, p: ITQParams) -> jax.Array:
    """The CONTINUOUS rotated projection itq_encode signs: (..., dim) ->
    (..., code_bits) f32. The approx tier's asymmetric scoring path keeps
    queries at this float precision against the datastore's ±1 bit planes
    (kernels/approx_select.asymmetric_topk) — better ranking fidelity than
    query-side sign quantization at identical datastore bytes."""
    return (x.astype(jnp.float32) - p.mean) @ p.proj @ p.rot


def itq_objective(x: jax.Array, p: ITQParams) -> jax.Array:
    """Quantization loss ||B - VR||_F^2 / n (monotone under training)."""
    v = (x.astype(jnp.float32) - p.mean) @ p.proj
    vr = v @ p.rot
    b = jnp.sign(vr)
    return jnp.mean(jnp.sum(jnp.square(b - vr), axis=-1))


class LSHParams(NamedTuple):
    proj: jax.Array       # (dim, bits) gaussian hyperplanes


def lsh_train(dim: int, bits: int, key=None) -> LSHParams:
    key = key if key is not None else jax.random.PRNGKey(0)
    return LSHParams(proj=jax.random.normal(key, (dim, bits), jnp.float32))


def lsh_encode(x: jax.Array, p: LSHParams) -> jax.Array:
    return (x.astype(jnp.float32) @ p.proj > 0).astype(jnp.uint8)
