"""kNN-LM retrieval: the paper's similarity-search engine as a first-class
serving feature of every backbone.

The datastore maps binary-quantized hidden states -> next-token ids
(Khandelwal et al.-style). At decode time the current hidden state is ITQ-
encoded, searched against the mesh-sharded datastore (Hamming kNN — the
paper's engine), and the neighbor distribution is interpolated with the LM
softmax.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.configs.base import ModelConfig, RetrievalConfig
from repro.core import binary, layout as layout_mod, plan as plan_mod, quantize


class DataStore(NamedTuple):
    codes: jax.Array        # (N, W) uint32 packed ITQ codes of hidden states
    values: jax.Array       # (N,) int32 next-token ids
    itq: quantize.ITQParams
    # optional bucket-clustered reorder of codes (core/layout.py): the
    # single-device fused select streams layout.codes and maps winners back
    # to original ids, so `values` never needs reordering
    layout: Optional[layout_mod.BucketLayout] = None
    # the hamming-prefix key bit positions the layout was bucketed by,
    # when the builder FROZE them (mutable stores must: re-deriving the
    # "most balanced" bits from mutated codes drifts away from how the
    # arena is actually bucketed, silently mis-aiming every degraded
    # probe). None -> probe_key_positions recomputes them, which is exact
    # for one-shot static builds.
    key_positions: Optional[jax.Array] = None


def _maybe_layout(codes: jax.Array, code_bits: int, rcfg_layout: str,
                  layout_buckets: int) -> Optional[layout_mod.BucketLayout]:
    if rcfg_layout == "none":
        return None
    assert rcfg_layout == "hamming_prefix", rcfg_layout
    return layout_mod.build_layout(codes, code_bits,
                                   n_buckets=layout_buckets or None)


def build_datastore(hidden: jax.Array, next_tokens: jax.Array, code_bits: int,
                    itq_iters: int = 20, key=None, layout: str = "none",
                    layout_buckets: int = 0) -> DataStore:
    """hidden: (N, d_model) f32; next_tokens: (N,) int32. ``layout``/
    ``layout_buckets`` follow RetrievalConfig's fields of the same name."""
    itq = quantize.itq_train(hidden, code_bits, iters=itq_iters, key=key)
    codes = binary.pack_bits(quantize.itq_encode(hidden, itq))
    return DataStore(codes=codes, values=next_tokens.astype(jnp.int32),
                     itq=itq,
                     layout=_maybe_layout(codes, code_bits, layout,
                                          layout_buckets))


def synthetic_datastore(cfg: ModelConfig, n: Optional[int] = None, key=None) -> DataStore:
    """Deterministic random datastore sized per the arch's RetrievalConfig
    (used by serve_step dry-runs and benchmarks)."""
    r = cfg.retrieval
    n = n if n is not None else r.datastore_size
    key = key if key is not None else jax.random.PRNGKey(3)
    k1, k2 = jax.random.split(key)
    W = binary.padded_words(r.code_bits)
    codes = jax.random.randint(k1, (n, W), 0, 2**31 - 1, jnp.int32).astype(jnp.uint32)
    values = jax.random.randint(k2, (n,), 0, cfg.vocab_size, jnp.int32)
    itq = quantize.ITQParams(
        mean=jnp.zeros((cfg.d_model,), jnp.float32),
        proj=jnp.eye(cfg.d_model, r.code_bits, dtype=jnp.float32),
        rot=jnp.eye(r.code_bits, dtype=jnp.float32))
    return DataStore(codes=codes, values=values, itq=itq,
                     layout=_maybe_layout(codes, r.code_bits, r.layout,
                                          r.layout_buckets))


def plan_for_store(store: DataStore, rcfg: RetrievalConfig, q: int,
                   mesh: Optional[Mesh] = None, axes: Sequence[str] = (),
                   method: str = "xor", select: Optional[str] = None,
                   recall_target: Optional[float] = None
                   ) -> plan_mod.QueryPlan:
    """The QueryPlan ``knn_logits`` executes against this store.

    Select precedence: explicit ``select`` argument > ``rcfg.plan`` (when
    not "auto") > ``rcfg.select``; ``rcfg.force_plan`` overrides apply
    last. ``rcfg.layout != "none"`` demands a layout (``layout_policy=
    "require"``): the planner streams the prebuilt store layout when one
    exists, else falls back to a per-call re-sort (with a warning —
    prebuild via ``build_datastore(..., layout=...)`` to amortize).
    Sharded, a prebuilt GLOBAL layout cannot follow the shard slicing, so
    the planner only opts into per-shard re-sorting when the config asks —
    a prebuilt store layout alone never opts the decode hot path into that
    cost. Exact sharded serving (``rcfg.local_k >= rcfg.k``) rides the
    hist_merge distributed counting select — O(Q·bins) cross-device counts
    instead of O(shards·Q·k) gathered candidates; ``local_k < k`` keeps
    the statistical concat/sort reduction. The runtime server logs this
    plan (merge strategy and predicted traffic included) per store at
    startup."""
    if select is None:
        select = rcfg.plan if rcfg.plan != "auto" else rcfg.select
    if recall_target is None:
        recall_target = rcfg.recall_target
    policy = "require" if rcfg.layout != "none" else "auto"
    n, w = store.codes.shape
    if mesh is not None and axes:
        n_dev = 1
        for a in axes:
            n_dev *= mesh.shape[a]
        # a prebuilt GLOBAL layout cannot follow the shard slicing, so the
        # sharded stats deliberately omit it (layout_policy still carries
        # the config's demand, satisfied per shard via local_sort)
        stats = plan_mod.stats_for(n, rcfg.code_bits, w, q, k=rcfg.k,
                                   n_shards=n_dev)
        return plan_mod.plan_sharded(
            stats, rcfg.k, axes=tuple(axes), k_local=rcfg.local_k,
            select=select, method=method, chunk=rcfg.chunk_size,
            layout_policy=policy, recall_target=recall_target,
            force=rcfg.force_plan)
    stats = plan_mod.stats_for(n, rcfg.code_bits, w, q, k=rcfg.k,
                               layout=store.layout)
    return plan_mod.plan_local(
        stats, rcfg.k, select=select, method=method, chunk=rcfg.chunk_size,
        layout_policy=policy, recall_target=recall_target,
        force=rcfg.force_plan)


def log_store_plan(store: DataStore, rcfg: RetrievalConfig, q: int,
                   logger, mesh: Optional[Mesh] = None,
                   axes: Sequence[str] = ()) -> plan_mod.QueryPlan:
    """Resolve and log the store's QueryPlan (serving-side ``explain()``).

    The runtime server calls this once per store at startup; pass the
    mesh/axes the serve step will search with so the logged plan is the
    one decode actually runs (without them it is the store's LOCAL plan).
    Sharded plans additionally log the merge strategy and its predicted
    cross-device traffic (tuning.shard_hints via plan.geometry())."""
    p = plan_for_store(store, rcfg, q, mesh=mesh, axes=axes)
    logger.info("retrieval store: %d entries, active plan %s",
                store.codes.shape[0], p.compact())
    if p.merge.kind == "sharded":
        m = p.geometry()["merge"]
        logger.info(
            "retrieval shard merge: %s over %d shards, predicted merge "
            "traffic %d B/batch (hist_merge %d B vs concat_sort %d B)",
            m["strategy"], m["n_shards"], m["merge_bytes"],
            m["hist_merge_bytes"], m["concat_sort_bytes"])
    logger.debug("retrieval plan detail:\n%s", p.explain_str())
    return p


def probe_key_positions(store: DataStore,
                        rcfg: RetrievalConfig) -> Optional[jax.Array]:
    """The hamming-prefix key-bit positions of ``store.layout``.

    ``build_layout``'s pure-Hamming fallback keys buckets by the
    ``log2(n_buckets)`` most balanced bit positions — a deterministic
    function of the codes, so recomputing the selection here reproduces
    the exact bucket ids the layout was clustered by. Returns None when
    the store has no layout or a non-power-of-two bucket count (i.e. a
    layout whose assignment did not come from the hamming-prefix key, such
    as an external k-means assign): degraded probing is unavailable there.
    """
    lay = store.layout
    if lay is None:
        return None
    if store.key_positions is not None:
        return store.key_positions     # frozen at build (mutable stores)
    bits = lay.n_buckets.bit_length() - 1
    if (1 << bits) != lay.n_buckets:
        return None
    _, positions = layout_mod.hamming_prefix_assign(store.codes,
                                                    rcfg.code_bits, bits)
    return positions


def degraded_plan_for_store(store: DataStore, rcfg: RetrievalConfig, q: int,
                            nprobe: int) -> plan_mod.QueryPlan:
    """The reduced-nprobe masked plan a degradation rung serves with:
    hamming-prefix key probing feeds the block-mask fused kernels, same
    shape as an IVF probe but with no float centroids."""
    stats = plan_mod.stats_for(store.codes.shape[0], rcfg.code_bits,
                               store.codes.shape[1], q, k=rcfg.k,
                               layout=store.layout)
    return plan_mod.plan_index(stats, rcfg.k, kind="hamming_prefix",
                               nprobe=nprobe)


def _bucket_probe(q_codes: jax.Array, positions: jax.Array, n_buckets: int,
                  nprobe: int, d: int) -> jax.Array:
    """(Q, W) packed queries -> (Q, nprobe) bucket ids, nearest first.
    Thin alias for :func:`index.hamming_prefix_probe` — the probe ranking
    is index policy, shared with the mutable store's degraded path."""
    from repro.core import index as index_mod
    return index_mod.hamming_prefix_probe(q_codes, positions, n_buckets,
                                          nprobe, d)


def knn_logits(store: DataStore, hidden: jax.Array, rcfg: RetrievalConfig,
               vocab: int, mesh: Optional[Mesh] = None,
               axes: Sequence[str] = (), method: str = "xor",
               temperature: float = 8.0,
               select: Optional[str] = None,
               recall_target: Optional[float] = None,
               nprobe: int = 0,
               probe_positions: Optional[jax.Array] = None) -> jax.Array:
    """hidden: (Q, d_model) -> neighbor log-distribution (Q, vocab).

    A thin plan-builder: ``plan_for_store`` resolves the select path,
    layout usage and sharded merge from the store's stats and the config
    (``rcfg.plan`` / ``rcfg.force_plan``; the ``select`` argument is a
    legacy per-call forced override), and ``plan.execute`` runs the staged
    search. "fused" streams the whole datastore through one two-pass
    Pallas invocation without ever materializing distances —
    ``rcfg.chunk_size`` only granulates the materializing/'fused_scan'
    scans. Inspect the decision with ``plan_for_store(...).explain()``.

    ``nprobe > 0`` with ``probe_positions`` (``probe_key_positions``)
    switches to the DEGRADED masked search the serving ladder downshifts
    to: only the ``nprobe`` nearest hamming-prefix buckets are scanned.
    ``recall_target`` overrides ``rcfg.recall_target`` for the approx tier
    (the ladder's approx rung serves at a degraded target)."""
    q_codes = binary.pack_bits(quantize.itq_encode(hidden, store.itq))
    if nprobe > 0 and store.layout is not None and probe_positions is not None:
        p = degraded_plan_for_store(store, rcfg, hidden.shape[0], nprobe)
        probe = _bucket_probe(q_codes, probe_positions,
                              store.layout.n_buckets, nprobe, rcfg.code_bits)
        dists, ids = plan_mod.execute(p, q_codes, layout=store.layout,
                                      probe=probe)
    else:
        p = plan_for_store(store, rcfg, hidden.shape[0], mesh=mesh,
                           axes=axes, method=method, select=select,
                           recall_target=recall_target)
        if p.merge.kind == "sharded":
            dists, ids = plan_mod.execute(p, q_codes, codes=store.codes,
                                          mesh=mesh)
        else:
            dists, ids = plan_mod.execute(p, q_codes, codes=store.codes,
                                          layout=store.layout)
    n = store.values.shape[0]
    # fewer than k valid neighbors -> the engine pads with sentinels
    # (full scans: dist = d+1, id >= N; masked probes: id = -1): they must
    # not receive softmax weight or vote for values[N-1]; mask them out of
    # the neighbor distribution (an all-invalid row degenerates to p = 0
    # and hits the log floor below)
    valid = (ids >= 0) & (ids < n) & (dists <= rcfg.code_bits)   # (Q, k)
    neighbor_tokens = store.values[jnp.clip(ids, 0, n - 1)]      # (Q, k)
    w = jax.nn.softmax(
        jnp.where(valid, -dists.astype(jnp.float32) / temperature, -jnp.inf),
        axis=-1)
    w = jnp.where(valid, w, 0.0)
    p = jnp.zeros((hidden.shape[0], vocab), jnp.float32)
    p = p.at[jnp.arange(hidden.shape[0])[:, None], neighbor_tokens].add(w)
    return jnp.log(jnp.maximum(p, 1e-9))


def interpolate(lm_logits: jax.Array, knn_log_probs: jax.Array,
                lam: float) -> jax.Array:
    """log((1-lam) softmax(lm) + lam exp(knn_log_probs))."""
    lm_logp = jax.nn.log_softmax(lm_logits.astype(jnp.float32), axis=-1)
    return jnp.logaddexp(lm_logp + jnp.log1p(-lam), knn_log_probs + jnp.log(lam))
