"""The kNN engine: chunked Hamming scan + bounded-domain top-k, single-device
and mesh-distributed.

Structure mirrors the paper's system:

* the materializing selects scan one *chunk* of codes per step == one AP
  board configuration; the ``lax.scan`` over chunks with an O(k) running
  merge is "partial reconfiguration" at zero swap cost (§3.3);
* ``select="fused"`` configures the WHOLE datastore at once, as the AP
  does before a race (§3.3): one two-pass Pallas invocation owns all of N
  — no scan, no merge, no per-chunk host roundtrips — with block-min
  pruning skipping pass-2 tiles that provably hold no winner
  (kernels/topk_select.py). ``chunk`` is a no-op for it (kernel tiling
  comes from kernels/tuning.py); ``select="fused_scan"`` keeps the chunked
  variant for datastores too large to address in one invocation;
* the mesh-sharded datastore == macro-level parallelism across boards;
* the distributed merge reports only each shard's local top-k'
  (``k_local``) == statistical activation reduction (§6.3); with
  ``k_local == k`` the result is exact.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.core import binary, layout as layout_mod, topk


class DistanceMethod:
    XOR = "xor"          # bit-packed popcount (VPU; 32x less HBM traffic)
    MXU = "mxu"          # +/-1 bf16 matmul (systolic array)
    PALLAS = "pallas"    # fused Pallas kernel (kernels/hamming.py)


def _distances(q_packed: jax.Array, chunk_codes: jax.Array, d: int,
               method: str) -> jax.Array:
    if method == DistanceMethod.XOR:
        return binary.hamming_xor(q_packed, chunk_codes)
    if method == DistanceMethod.MXU:
        qb = binary.unpack_bits(q_packed, d)
        xb = binary.unpack_bits(chunk_codes, d)
        # bf16 hits the MXU on TPU; CPU has no native bf16 — use f32 there
        dt = jnp.bfloat16 if jax.default_backend() == "tpu" else jnp.float32
        return binary.hamming_mxu(qb, xb, d, dtype=dt)
    if method == DistanceMethod.PALLAS:
        from repro.kernels import ops
        return ops.hamming_distance(q_packed, chunk_codes)
    raise ValueError(method)


def _auto_chunk(chunk: int, d: int) -> int:
    """Composite-key representability guard — the *auto* select only.

    ``topk.composite_topk`` ranks by the f32 key ``dist * chunk + idx``,
    which is exact only while (d + 1) * chunk < 2^24 (f32 mantissa).
    Shrinking the chunk keeps auto on XLA's fast ``top_k`` path instead of
    its bisect fallback — a performance choice, not a correctness one. The
    other selects never build the key and are bit-identical at ANY chunk
    size, so they scan at the caller's chunk unmodified."""
    if (d + 1) * chunk < (1 << 24):
        return chunk
    return max(1024, ((1 << 24) // (d + 1)) // 1024 * 1024)


def search_chunked(codes_packed: jax.Array, q_packed: jax.Array, k: int,
                   d: int, chunk: int = 1 << 16,
                   method: str = DistanceMethod.XOR,
                   id_offset: jax.Array | int = 0,
                   select: str = "auto") -> Tuple[jax.Array, jax.Array]:
    """Search the datastore. codes: (N, W) uint32, q: (Q, W).

    ``select``: 'auto' (composite-key fast path), 'counting' (histogram
    counting select), 'bisect' (scatter-free counting select), 'fused'
    (single-shot two-pass Pallas counting select: ONE hist + ONE emit
    ``pallas_call`` own the entire datastore — no ``lax.scan``, no
    ``merge_topk``, no (Q, N) distance matrix — with block-min pruning in
    pass 2; orthogonal to ``method``, which it ignores), or 'fused_scan'
    (the chunk-scanned variant of 'fused', for datastores that exceed what
    one invocation should address, e.g. codes paged in from host memory).
    All five produce bit-identical results at any chunk size; ``chunk``
    only sets the scan granularity of the materializing/'fused_scan' paths
    ('fused' streams the whole datastore and tiles via kernels/tuning.py).
    'auto' additionally shrinks its own chunk to keep its composite key
    f32-representable (see ``_auto_chunk``).
    Returns (dists (Q,k) ascending, global ids (Q,k))."""
    N, W = codes_packed.shape
    Q = q_packed.shape[0]

    if select == "fused":
        from repro.kernels import ops

        bd, bi = ops.hamming_topk(q_packed, codes_packed, k, d + 1)
        return bd, bi + id_offset

    chunk = min(chunk, N)
    if select == "auto":
        chunk = _auto_chunk(chunk, d)
    n_chunks = (N + chunk - 1) // chunk
    if N % chunk:
        pad = n_chunks * chunk - N
        # pad with all-ones codes at max distance; ids beyond N are masked by
        # their distance landing at the back of the merge (the fused kernels
        # mask them exactly via n_valid instead)
        codes_packed = jnp.pad(codes_packed, ((0, pad), (0, 0)),
                               constant_values=jnp.uint32(0xFFFFFFFF))
    chunks = codes_packed.reshape(n_chunks, chunk, W)

    if select == "fused_scan":
        from repro.kernels import ops

        def body(carry, xs):
            best_d, best_i = carry
            ci, codes_c = xs
            n_valid = jnp.clip(N - ci * chunk, 0, chunk)
            cd, cidx = ops.hamming_topk(q_packed, codes_c, min(k, chunk),
                                        d + 1, n_valid=n_valid)
            best_d, best_i = topk.merge_topk(best_d, best_i, cd,
                                             cidx + ci * chunk, k)
            return (best_d, best_i), None
    else:
        select_fn = {"auto": topk.composite_topk,
                     "counting": topk.counting_topk,
                     "bisect": topk.counting_topk_bisect}[select]

        def body(carry, xs):
            best_d, best_i = carry
            ci, codes_c = xs
            dist = _distances(q_packed, codes_c, d, method)
            # padding rows (global id >= N) must rank strictly last — their
            # all-ones codes can otherwise tie or beat real rows
            gids = ci * chunk + jnp.arange(chunk)
            dist = jnp.where(gids[None, :] < N, jnp.minimum(dist, d), d + 1)
            cd, cidx = select_fn(dist, min(k, chunk), d + 1)
            cids = cidx + ci * chunk
            best_d, best_i = topk.merge_topk(best_d, best_i, cd, cids, k)
            return (best_d, best_i), None

    init = (jnp.full((Q, k), d + 1, jnp.int32), jnp.full((Q, k), N, jnp.int32))
    (bd, bi), _ = jax.lax.scan(body, init, (jnp.arange(n_chunks), chunks))
    return bd, bi + id_offset


class KNNEngine(NamedTuple):
    """Immutable engine state (a pytree — jit/shard friendly).

    ``layout``: optional bucket-clustered physical reorder of ``codes``
    (core/layout.py). The fused select then streams the REORDERED codes —
    similar codes share grid tiles, so block-min pruning bites even on
    uniform data — and maps winners back to original ids; every other
    select scans the original order. Build one with ``with_layout()``.
    """

    codes: jax.Array          # (N, W) uint32 packed
    d: int                    # code bits
    layout: Optional[layout_mod.BucketLayout] = None

    @property
    def n(self) -> int:
        return self.codes.shape[0]

    def with_layout(self, n_buckets: int | None = None,
                    assign: jax.Array | None = None) -> "KNNEngine":
        """Engine with a bucket-clustered layout: by explicit bucket
        ``assign`` (e.g. IVF cluster ids) or the pure-Hamming prefix
        fallback (no float vectors needed)."""
        lay = layout_mod.build_layout(self.codes, self.d,
                                      n_buckets=n_buckets, assign=assign)
        return self._replace(layout=lay)

    def search(self, q_packed: jax.Array, k: int, chunk: int = 1 << 16,
               method: str = DistanceMethod.XOR, select: str = "auto"):
        if select == "fused" and self.layout is not None:
            dd, ii = search_chunked(self.layout.codes, q_packed, k, self.d,
                                    chunk, method, select=select)
            return dd, layout_mod.to_original_ids(self.layout.perm, ii)
        return search_chunked(self.codes, q_packed, k, self.d, chunk, method,
                              select=select)


# ---------------------------------------------------------------------------
# distributed search (hierarchical top-k == statistical activation reduction)
# ---------------------------------------------------------------------------

def search_sharded(codes_packed: jax.Array, q_packed: jax.Array, k: int, d: int,
                   mesh: Mesh, axes: Sequence[str], k_local: Optional[int] = None,
                   chunk: int = 1 << 16, method: str = DistanceMethod.XOR,
                   select: str = "auto", reorder_local: bool = False):
    """Datastore sharded over ``axes`` (cardinality sharding); queries
    replicated. Each shard reports its local top-k' and the merge runs over
    the gathered (devices * k') candidates. With ``select="fused"`` every
    shard runs the single-shot two-pass select over its whole local slice
    (one hist + one emit invocation per shard, block-min pruning included).

    ``reorder_local=True`` (fused only): each shard bucket-clusters its OWN
    slice by a static Hamming key before the scan (``layout.local_sort`` —
    trace-friendly, runs inside shard_map) and maps winners back to global
    ids, so block-min pruning bites per shard even on uniform data. The
    sort is recomputed per call; amortize by building the layout at
    placement time (KNNEngine.with_layout) when the datastore is static.

    k_local < k trades exactness for an m/k' collective-bandwidth reduction
    with the accuracy model of core/hierarchy.py; k_local=None means k (exact).
    """
    k_local = k if k_local is None else k_local
    axes = tuple(axes)
    n_dev = 1
    for a in axes:
        n_dev *= mesh.shape[a]
    N = codes_packed.shape[0]
    n_loc = N // n_dev

    def local(codes_loc, q):
        # flat shard index over the sharding axes
        flat = jnp.zeros((), jnp.int32)
        for a in axes:
            flat = flat * mesh.shape[a] + jax.lax.axis_index(a)
        if reorder_local and select == "fused":
            codes_l, perm_l = layout_mod.local_sort(codes_loc, d)
            ld, li = search_chunked(codes_l, q, k_local, d, chunk, method,
                                    select=select)
            # local positions -> local ids -> global ids; local sentinels
            # (pos == n_loc) become this shard's global sentinel, exactly
            # like the unordered path
            li = layout_mod.to_original_ids(perm_l, li) + flat * n_loc
        else:
            ld, li = search_chunked(codes_loc, q, k_local, d, chunk, method,
                                    id_offset=flat * n_loc, select=select)
        # hierarchical merge: gather only k' candidates per shard
        gd = jax.lax.all_gather(ld, axes, tiled=False)   # (n_dev, Q, k')
        gi = jax.lax.all_gather(li, axes, tiled=False)
        gd = jnp.moveaxis(gd, 0, 1).reshape(q.shape[0], n_dev * k_local)
        gi = jnp.moveaxis(gi, 0, 1).reshape(q.shape[0], n_dev * k_local)
        sd, order = jax.lax.sort_key_val(gd, gi, dimension=-1)
        return sd[:, :k], order[:, :k]

    mapped = shard_map(
        local, mesh=mesh,
        in_specs=(P(axes, None), P(None, None)),
        out_specs=(P(None, None), P(None, None)))
    return mapped(codes_packed, q_packed)


def shard_datastore(codes_packed: jax.Array, mesh: Mesh, axes: Sequence[str]):
    """Place a packed datastore sharded over the given mesh axes."""
    sharding = NamedSharding(mesh, P(tuple(axes), None))
    return jax.device_put(codes_packed, sharding)
