"""The kNN engine: every search path is a thin plan-builder over the
QueryPlan IR (core/plan.py) — the planner resolves the stages, the
executor runs them.

Structure mirrors the paper's system:

* the materializing selects scan one *chunk* of codes per step == one AP
  board configuration; the ``lax.scan`` over chunks with an O(k) running
  merge is "partial reconfiguration" at zero swap cost (§3.3);
* ``select="fused"`` configures the WHOLE datastore at once, as the AP
  does before a race (§3.3): one two-pass Pallas invocation owns all of N
  — no scan, no merge, no per-chunk host roundtrips — with block-min
  pruning skipping pass-2 tiles that provably hold no winner
  (kernels/topk_select.py). ``chunk`` is a no-op for it (kernel tiling
  comes from kernels/tuning.py); ``select="fused_scan"`` keeps the chunked
  variant for datastores too large to address in one invocation;
* the mesh-sharded datastore == macro-level parallelism across boards;
* the exact distributed merge is the paper's counting select writ large:
  per-rank counters are ADDITIVE partial histograms, so shards psum their
  (Q, bins) counts into one global race and emit winners into disjoint
  output slots (``merge="hist_merge"``, kernels/ops.py) — no per-shard
  top-k, no concat/sort;
* the legacy merge reports only each shard's local top-k' (``k_local``)
  == statistical activation reduction (§6.3); with ``k_local == k`` it is
  exact but moves O(shards*Q*k) candidates — kept as the
  ``merge="concat_sort"`` fallback and as THE path for k_local < k.

The decision logic — how ``select="auto"`` resolves, when a layout is
streamed, when the sharded path reorders per shard — lives in
``core/plan.py`` only; the legacy ``select=`` knob survives as a forced-
plan override through the same planner (bit-identical, deprecation-nudged;
see ``QueryPlan.explain()`` for what any call will actually run).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import layout as layout_mod, plan as plan_mod

# re-exported: the distance-method enum and composite-chunk guard moved to
# the planner with the rest of the policy, but remain part of this module's
# public surface
DistanceMethod = plan_mod.DistanceMethod
_auto_chunk = plan_mod._auto_chunk


def search_chunked(codes_packed: jax.Array, q_packed: jax.Array, k: int,
                   d: int, chunk: int = plan_mod.DEFAULT_CHUNK,
                   method: str = DistanceMethod.XOR,
                   id_offset: jax.Array | int = 0,
                   select: str = "auto") -> Tuple[jax.Array, jax.Array]:
    """Search the datastore. codes: (N, W) uint32, q: (Q, W).

    ``select``: 'auto' (planner-resolved; with no layout in sight it lands
    on the composite-key fast path), or a forced path: 'counting'
    (histogram counting select), 'bisect' (scatter-free counting select),
    'fused' (single-shot two-pass Pallas counting select with block-min
    pruning; orthogonal to ``method``, which it ignores), 'fused_scan'
    (the chunk-scanned variant of 'fused', for datastores that exceed what
    one invocation should address, e.g. codes paged in from host memory).
    All paths produce bit-identical results at any chunk size; ``chunk``
    only sets the scan granularity of the materializing/'fused_scan' paths
    (see the generated decision table in DESIGN.md).
    Returns (dists (Q,k) ascending, global ids (Q,k))."""
    if select != "auto":
        plan_mod._warn_legacy("search_chunked", "select", select)
    p = plan_mod.plan_local(plan_mod.stats_of(codes_packed, q_packed, d),
                            k, select=select, method=method, chunk=chunk)
    return plan_mod.execute(p, q_packed, codes=codes_packed,
                            id_offset=id_offset)


class KNNEngine(NamedTuple):
    """Immutable engine state (a pytree — jit/shard friendly).

    ``layout``: optional bucket-clustered physical reorder of ``codes``
    (core/layout.py). Any select that RESOLVES to the fused path then
    streams the REORDERED codes — similar codes share grid tiles, so
    block-min pruning bites even on uniform data — and maps winners back
    to original ids; the materializing selects scan the original order.
    Build one with ``with_layout()``; inspect what a search will run with
    ``query_plan(...).explain_str()``.
    """

    codes: jax.Array          # (N, W) uint32 packed
    d: int                    # code bits
    layout: Optional[layout_mod.BucketLayout] = None

    @property
    def n(self) -> int:
        return self.codes.shape[0]

    @classmethod
    def from_epoch(cls, epoch, d: int) -> "KNNEngine":
        """Engine pinned to one installed epoch of a mutable store
        (core/mutable.py). The epoch's dense codes ARE the layout's codes
        (identity perm), so this engine keeps serving a complete,
        consistent snapshot no matter how the store mutates afterwards —
        grab a new engine from a newer epoch to see newer data."""
        return cls(codes=epoch.layout.codes, d=d, layout=epoch.layout)

    def with_layout(self, n_buckets: int | None = None,
                    assign: jax.Array | None = None) -> "KNNEngine":
        """Engine with a bucket-clustered layout: by explicit bucket
        ``assign`` (e.g. IVF cluster ids) or the pure-Hamming prefix
        fallback (no float vectors needed)."""
        lay = layout_mod.build_layout(self.codes, self.d,
                                      n_buckets=n_buckets, assign=assign)
        return self._replace(layout=lay)

    def query_plan(self, q_packed: jax.Array, k: int,
                   chunk: int = plan_mod.DEFAULT_CHUNK,
                   method: str = DistanceMethod.XOR, select: str = "auto",
                   force=None) -> plan_mod.QueryPlan:
        """The QueryPlan ``search`` will execute for these arguments —
        ``select`` is resolved FIRST, so an ``"auto"`` that lands on the
        fused path sees the layout (the former literal-string check lost
        it)."""
        stats = plan_mod.stats_of(self.codes, q_packed, self.d,
                                  layout=self.layout)
        return plan_mod.plan_local(stats, k, select=select, method=method,
                                   chunk=chunk, force=force)

    def search(self, q_packed: jax.Array, k: int,
               chunk: int = plan_mod.DEFAULT_CHUNK,
               method: str = DistanceMethod.XOR, select: str = "auto"):
        if select != "auto":
            plan_mod._warn_legacy("KNNEngine.search", "select", select)
        p = self.query_plan(q_packed, k, chunk=chunk, method=method,
                            select=select)
        return plan_mod.execute(p, q_packed, codes=self.codes,
                                layout=self.layout)


# ---------------------------------------------------------------------------
# distributed search (hierarchical top-k == statistical activation reduction)
# ---------------------------------------------------------------------------

def search_sharded(codes_packed: jax.Array, q_packed: jax.Array, k: int, d: int,
                   mesh: Mesh, axes: Sequence[str], k_local: Optional[int] = None,
                   chunk: int = plan_mod.DEFAULT_CHUNK,
                   method: str = DistanceMethod.XOR,
                   select: str = "auto", reorder_local: bool = False,
                   merge: Optional[str] = None, fanout: int = 0,
                   shard_n_valid=None, shard_participate=None):
    """Datastore sharded over ``axes`` (cardinality sharding); queries
    replicated. A thin plan-builder: the planner decides the merge
    strategy, the executor runs it.

    The exact default (k_local == k) is the **distributed counting
    select** (``merge="hist_merge"``): per-shard pass-1 histograms are
    additive partial histograms of one global race, so a single ``psum``
    of the tiny (Q, bins) counts yields ONE global per-query radius r*;
    each shard then runs pass 2 over its own slice with slot bases from an
    exclusive scan of per-shard below-r*/tie counts and scatters its
    winners into disjoint slots of the global (Q, k) output via a final
    psum. No per-shard top-k materializes and nothing is concat/sorted on
    the host — cross-device traffic is O(Q·bins) counts instead of
    O(shards·Q·k) candidates, which makes ``nshards`` a throughput knob
    rather than a merge-cost tax. ``merge="concat_sort"`` forces the
    legacy hierarchical merge (each shard reports its local top-k', one
    gathered sort); k_local < k always takes it — that is the statistical
    reduction of core/hierarchy.py (inexact, bounded), k_local=None means
    k (exact).

    ``reorder_local=True`` (fused only — the planner drops it otherwise):
    each shard bucket-clusters its OWN slice by a static Hamming key before
    the scan (``layout.local_sort`` — trace-friendly, runs inside
    shard_map) and maps winners back to global ids, so block-min pruning
    bites per shard even on uniform data; it composes with either merge
    strategy. The sort is recomputed per call; amortize by building the
    layout at placement time (KNNEngine.with_layout) when the datastore is
    static.

    ``shard_n_valid``: optional (n_shards,) valid-row counts for UNEVEN
    shards padded to a common slice size (fused select only). Results are
    bit-identical to a single-device search over the concatenation of the
    valid rows, including when k exceeds one shard's valid rows.

    ``merge="hist_tree"`` (auto past 8 shards) runs the SAME counting
    select with the histogram/output psums tree-scheduled at ``fanout``
    (default from ``tuning.merge_fanout``) — bit-identical, hierarchical
    traffic. ``shard_participate``: optional (n_shards,) 0/1 liveness
    mask (hist-family merges only) — dead shards' rows are excluded
    exactly and ids renumber over the survivors, the degraded-but-exact
    answer of the shard-fault-tolerance layer.
    """
    if select != "auto":
        plan_mod._warn_legacy("search_sharded", "select", select)
    axes = tuple(axes)
    n_dev = 1
    for a in axes:
        n_dev *= mesh.shape[a]
    stats = plan_mod.stats_of(codes_packed, q_packed, d, n_shards=n_dev)
    p = plan_mod.plan_sharded(stats, k, axes=axes, k_local=k_local,
                              select=select, method=method, chunk=chunk,
                              reorder_local=reorder_local, merge=merge,
                              fanout=fanout,
                              uneven=shard_n_valid is not None)
    return plan_mod.execute(p, q_packed, codes=codes_packed, mesh=mesh,
                            shard_n_valid=shard_n_valid,
                            shard_participate=shard_participate)


def shard_datastore(codes_packed: jax.Array, mesh: Mesh, axes: Sequence[str]):
    """Place a packed datastore sharded over the given mesh axes."""
    sharding = NamedSharding(mesh, P(tuple(axes), None))
    return jax.device_put(codes_packed, sharding)
