"""Statistical activation reduction (paper §6.3) — accuracy model.

The AP groups m Hamming/sorting-macro pairs and reports only the local top-k'
per group, cutting report bandwidth by m/k'. The result is exact iff no group
holds more than k' of the true global top-k. We reproduce the paper's Fig. 11
model analytically and by Monte Carlo.

On our side of the analogy the "group" is one device's datastore shard and
the "report bandwidth" is the all-gather payload of the distributed top-k
merge: bytes drop from O(n) to O(devices * k').
"""
from __future__ import annotations

import math

import numpy as np


def binomial_tail(k: int, r_groups: int, kprime: int) -> float:
    """P(one group holds > k' of the k global winners), winners i.i.d.
    uniform over R groups (Binomial(k, 1/R) tail)."""
    p = 1.0 / r_groups
    tail = 0.0
    for j in range(kprime + 1, k + 1):
        tail += math.comb(k, j) * p**j * (1 - p) ** (k - j)
    return tail


def failure_bound(k: int, r_groups: int, kprime: int) -> float:
    """Union bound on P(global top-k not fully recovered)."""
    return min(1.0, r_groups * binomial_tail(k, r_groups, kprime))


def failure_exact_mc(k: int, r_groups: int, kprime: int, trials: int = 10000,
                     seed: int = 0) -> float:
    """Monte Carlo estimate of the exact failure probability.

    Batched bincount (each trial's groups offset into its own id range)
    instead of a Python loop of per-trial bincounts — same draws, same
    estimate, ~trials-fold fewer interpreter round-trips. Batches are
    capped so the counts matrix stays O(batch * r_groups), not
    O(trials * r_groups)."""
    rng = np.random.default_rng(seed)
    groups = rng.integers(0, r_groups, size=(trials, k))
    batch = max(1, min(trials, (1 << 22) // max(r_groups, 1)))
    fails = 0
    for t0 in range(0, trials, batch):
        g = groups[t0:t0 + batch]
        b = g.shape[0]
        offsets = np.arange(b, dtype=np.int64)[:, None] * r_groups
        counts = np.bincount((g + offsets).ravel(),
                             minlength=b * r_groups).reshape(b, r_groups)
        fails += int(np.sum(counts.max(axis=1) > kprime))
    return fails / trials


def bandwidth_reduction(m: int, kprime: int) -> float:
    """Paper's m/k' report-bandwidth reduction factor."""
    return m / kprime


def recommended_kprime(k: int, r_groups: int, max_failure: float = 0.01) -> int:
    """Smallest k' with failure bound below the target."""
    for kprime in range(1, k + 1):
        if failure_bound(k, r_groups, kprime) <= max_failure:
            return kprime
    return k
