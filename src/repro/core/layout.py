"""Layout-aware datastore: bucket-clustered physical reordering of the
packed codes, and the translation from probed index buckets to the fused
kernels' per-(query-block, data-block) enable mask.

The paper's indexing structures (§3.4) exist to *skip most of the
datastore*; PR 2's block-min pruning can only skip tiles that happen to be
provably loser-only, which on uniform data is nothing. The lever, as
TPU-KNN (Chern et al., 2022) makes explicit for TPUs and NCAM (Lee et al.,
2016) for near-data engines, is **data layout**: physically reorder the
codes so that similar codes share grid tiles. Then

* a full fused scan prunes even on uniform data — each tile now holds one
  bucket's worth of mutually-near codes, so most tiles' min distance to a
  query block clears the block-min bound;
* index traversal drives the kernels directly: a probed bucket is a
  contiguous run of rows, i.e. a run of grid tiles, i.e. a rectangle of
  ones in the enable mask — no gathered (Q, C, W) candidate tensor ever
  materializes (the retired ``index._scan_candidates`` path).

A :class:`BucketLayout` carries the reordered codes plus the permutation
and its inverse, so every search path still returns ORIGINAL ids; the
reorder is invisible to callers except for tie order (ties at equal
distance break by layout position, not original id — the same
"report-order" freedom every candidate-list scan already has).

Masking semantics (the index contract, identical to ``_scan_candidates``):
a disabled tile is simply outside the candidate set. The mask granularity
is the grid tile, so probed buckets are rounded OUTWARD to tile
boundaries — the masked candidate set is a *superset* of the probed
buckets, never a subset: recall can only improve on the gather path.
Queries within one query block share the union of their probes (one mask
row per query block); keep query batches locality-sorted for the tightest
masks. ``kernels/tuning.py::layout_blocks`` aligns the data-block size to
the bucket size so one block rarely straddles buckets.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import binary


class BucketLayout(NamedTuple):
    """Bucket-contiguous physical layout of a packed datastore (a pytree).

    ``codes[pos] == original_codes[perm[pos]]``; bucket ``b`` occupies the
    contiguous row range ``[starts[b], starts[b+1])`` of ``codes``.
    """

    codes: jax.Array        # (N, W) uint32, reordered bucket-contiguous
    perm: jax.Array         # (N,) int32: perm[pos] = original id
    inv: jax.Array          # (N,) int32: inv[original id] = pos
    starts: jax.Array       # (B+1,) int32 bucket offsets into codes

    @property
    def n(self) -> int:
        return self.codes.shape[0]

    @property
    def n_buckets(self) -> int:
        return self.starts.shape[0] - 1

    @property
    def mean_bucket_rows(self) -> int:
        return max(1, self.n // max(self.n_buckets, 1))


def invert_permutation(perm: jax.Array) -> jax.Array:
    """O(N) scatter inverse — ``inv[perm[pos]] = pos`` — instead of a
    second O(N log N) ``argsort``. One definition for every permutation in
    this module (prebuilt layouts AND the per-shard reorder on the
    distributed path), so inverse semantics cannot drift."""
    n = perm.shape[0]
    return jnp.zeros((n,), jnp.int32).at[perm].set(
        jnp.arange(n, dtype=jnp.int32))


def reorder_by_assignment(codes: jax.Array, assign: jax.Array,
                          n_buckets: int) -> BucketLayout:
    """Physically cluster ``codes`` by bucket id. assign: (N,) int32 in
    [0, n_buckets). Stable: within a bucket, original id order survives."""
    assign = jnp.asarray(assign, jnp.int32)
    perm = jnp.argsort(assign, stable=True).astype(jnp.int32)
    inv = invert_permutation(perm)
    counts = jnp.bincount(assign, length=n_buckets)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(counts).astype(jnp.int32)])
    return BucketLayout(codes=codes[perm], perm=perm, inv=inv, starts=starts)


def hamming_prefix_assign(codes: jax.Array, d: int, bits: int,
                          positions: jax.Array | None = None
                          ) -> Tuple[jax.Array, jax.Array]:
    """Pure-Hamming bucketing — no float vectors required.

    Greedily picks the ``bits`` most *balanced* bit positions (empirical
    mean closest to 1/2: maximum key entropy, hence the evenest buckets an
    axis-aligned key can give) and groups codes by that LSH key: codes
    sharing the key form one of 2^bits buckets, and two codes in one bucket
    agree on all selected bits, i.e. are Hamming-near on the key subspace.
    Pass ``positions`` to reuse a previous selection (e.g. to key queries
    the same way the datastore was keyed).

    Returns (assign (N,) int32 in [0, 2^bits), positions (bits,) int32)."""
    b = binary.unpack_bits(codes, d)                       # (N, d)
    if positions is None:
        means = jnp.mean(b.astype(jnp.float32), axis=0)
        positions = jnp.argsort(jnp.abs(means - 0.5),
                                stable=True)[:bits].astype(jnp.int32)
    sel = b[:, positions].astype(jnp.int32)                # (N, bits)
    weights = (1 << jnp.arange(positions.shape[0], dtype=jnp.int32))
    return jnp.sum(sel * weights, axis=-1), positions


def default_bits(n: int) -> int:
    """Heuristic key width for the Hamming fallback: ~256 rows per bucket,
    clamped to [1, 12] (4096 buckets is plenty for any mask)."""
    return max(1, min(12, int(np.log2(max(n // 256, 2)))))


def build_layout(codes: jax.Array, d: int, n_buckets: int | None = None,
                 assign: jax.Array | None = None) -> BucketLayout:
    """Build a bucket-clustered layout. With ``assign`` (e.g. k-means/IVF
    cluster ids) the reorder follows the index's own buckets (``n_buckets``
    defaults to max(assign) + 1); without, the pure-Hamming prefix fallback
    buckets by LSH key — no float vectors. Build-time (host) only."""
    if assign is None:
        bits = (n_buckets - 1).bit_length() if n_buckets else (
            default_bits(codes.shape[0]))
        assign, _ = hamming_prefix_assign(codes, d, bits)
        n_buckets = 1 << bits
    else:
        hi = int(jnp.max(assign)) + 1
        n_buckets = hi if n_buckets is None else n_buckets
        # an out-of-range bucket id would fall off `starts` and its rows
        # would silently vanish from every masked probe — refuse instead
        assert hi <= n_buckets, f"assign ids reach {hi - 1} >= {n_buckets}"
        assert int(jnp.min(assign)) >= 0, "negative bucket id"
    return reorder_by_assignment(codes, assign, n_buckets)


def local_sort(codes: jax.Array, d: int, bits: int | None = None,
               n_valid: jax.Array | None = None):
    """Trace-friendly reorder for sharded shards: key by ``bits`` evenly
    spaced code bits (static positions — no data-dependent selection, so it
    runs under jit/shard_map) and stable-sort. Returns (codes_sorted, perm)
    with perm[pos] = local id. No bucket table: shards use the reorder for
    full-scan block-min pruning only, not for masked probing.

    ``n_valid``: rows at local id >= n_valid are padding (uneven shards on
    the distributed path) — their sort key is forced past every real key,
    so they stay pinned at positions [n_valid, n) and the kernels' mask-by-
    position contract (``gid < n_valid``) keeps holding after the sort."""
    n = codes.shape[0]
    bits = bits if bits is not None else default_bits(n)
    bits = max(1, min(bits, d))
    positions = jnp.arange(bits, dtype=jnp.int32) * (d // bits)
    b = binary.unpack_bits(codes, d)[:, positions].astype(jnp.int32)
    key = jnp.sum(b * (1 << jnp.arange(bits, dtype=jnp.int32)), axis=-1)
    if n_valid is not None:
        key = jnp.where(jnp.arange(n) < jnp.asarray(n_valid, jnp.int32),
                        key, jnp.int32(1) << 30)
    perm = jnp.argsort(key, stable=True).astype(jnp.int32)
    return codes[perm], perm


def to_original_ids(perm: jax.Array, ids: jax.Array) -> jax.Array:
    """Map layout positions to original ids through ``perm``; sentinel rows
    (position >= N, the engine's pad contract) pass through unchanged. The
    clamp-then-gather keeps the sentinel from indexing out of bounds."""
    n = perm.shape[0]
    return jnp.where(ids < n, perm[jnp.minimum(ids, n - 1)], ids)


def original_ids(layout: BucketLayout, dists: jax.Array, ids: jax.Array,
                 d: int) -> jax.Array:
    """Map kernel-space positions back to original ids; sentinel slots
    (dist > d or position >= N) become -1, the candidate-scan contract."""
    n = layout.n
    real = (ids < n) & (dists <= d)
    return jnp.where(real, to_original_ids(layout.perm, ids), -1)


# ---------------------------------------------------------------------------
# probed buckets -> grid enable mask
# ---------------------------------------------------------------------------

def probe_block_mask(layout: BucketLayout, probe: jax.Array, bq: int, bn: int,
                     n_qblocks: int, n_nblocks: int) -> jax.Array:
    """Translate per-query probed bucket ids into the kernels' enable mask.

    probe: (Q, P) int32 bucket ids (duplicates fine). A data block is
    enabled for a query block iff any query in the block probes a bucket
    overlapping it; bucket ranges round OUTWARD to block boundaries (the
    superset contract above). Empty buckets enable nothing. Returns
    (n_qblocks, n_nblocks) int32; rows of query padding enable nothing."""
    q = probe.shape[0]
    lo = layout.starts[probe]                              # (Q, P)
    hi = layout.starts[probe + 1]                          # exclusive
    first = lo // bn
    last = jnp.maximum(hi - 1, lo) // bn                   # inclusive
    live = (hi > lo).astype(jnp.int32)                     # empty -> no-op
    # interval scatter (+1 at first, -1 past last) + running sum instead of
    # a (Q, P, n_nblocks) broadcast: O(Q*P + Q*n_nblocks) on the hot path
    rows = jnp.arange(q)[:, None]
    inc = jnp.zeros((q, n_nblocks + 1), jnp.int32)
    inc = inc.at[rows, first].add(live).at[rows, last + 1].add(-live)
    qmask = jnp.cumsum(inc[:, :n_nblocks], axis=1) > 0     # (Q, nblk)
    qmask = jnp.pad(qmask, ((0, n_qblocks * bq - q), (0, 0)))
    return jnp.any(qmask.reshape(n_qblocks, bq, n_nblocks),
                   axis=1).astype(jnp.int32)


def position_block_mask(layout: BucketLayout, cand: jax.Array, bq: int,
                        bn: int, n_qblocks: int, n_nblocks: int) -> jax.Array:
    """Enable mask from explicit candidate ids (multi-table indexes whose
    extra tables cannot all be layout-contiguous, e.g. LSH tables 1..T-1).

    cand: (Q, C) int32 ORIGINAL ids, -1 padded. Each candidate enables the
    data block holding its reordered position — an id-level gather plus a
    scatter into the tiny mask, not the retired (Q, C, W) code gather."""
    return position_block_mask_from_inv(layout.inv, cand, bq, bn,
                                        n_qblocks, n_nblocks)


def position_block_mask_from_inv(inv: jax.Array, cand: jax.Array, bq: int,
                                 bn: int, n_qblocks: int, n_nblocks: int
                                 ) -> jax.Array:
    """The id->position mask body, keyed by a bare inverse permutation —
    the per-shard hook on the distributed path: a shard that reordered its
    slice with ``local_sort`` has only (codes, perm), so the caller builds
    ``invert_permutation(perm)`` (the O(N) scatter inverse) and maps local
    candidate ids to sorted positions without a BucketLayout."""
    q = cand.shape[0]
    pos = inv[jnp.maximum(cand, 0)]                        # (Q, C)
    blk = jnp.where(cand >= 0, pos // bn, n_nblocks)       # pad -> dropped
    qmask = jnp.zeros((q, n_nblocks), jnp.int32).at[
        jnp.arange(q)[:, None], blk].max(1, mode="drop")
    qmask = jnp.pad(qmask, ((0, n_qblocks * bq - q), (0, 0)))
    return jnp.max(qmask.reshape(n_qblocks, bq, n_nblocks), axis=1)


# ---------------------------------------------------------------------------
# the index-driven fused select
# ---------------------------------------------------------------------------

def masked_topk(layout: BucketLayout, q_packed: jax.Array, k: int, d: int,
                probe: jax.Array | None = None,
                cand_ids: jax.Array | None = None,
                bq: int | None = None, bn: int | None = None,
                sub: int | None = None, return_stats: bool = False):
    """Index-probed top-k straight through the fused kernel pair.

    Exactly one of ``probe`` ((Q, P) bucket ids) / ``cand_ids`` ((Q, C)
    original ids, -1 padded) selects the candidate set; both may be given
    (union). ``None``/``None`` degrades to an unmasked full scan (still
    layout-reordered, so block-min pruning bites).

    Returns (dists, ids[, stats]): (Q, k) ascending, ORIGINAL ids, -1 in
    sentinel slots — the same contract as ``index._scan_candidates`` over
    the rows the mask enables. Block sizes default to
    ``tuning.layout_blocks`` (bn aligned to the mean bucket size)."""
    from repro.kernels import ops, tuning

    Q, W = q_packed.shape
    n = layout.n
    bins = d + 1
    lanes = max(bins, min(k, n))
    if bn is None and (probe is not None or cand_ids is not None):
        _, bn, _ = tuning.layout_blocks(Q, n, W, lanes,
                                        layout.mean_bucket_rows)
    bq, bn, sub, q_pad, n_pad = ops.topk_geometry(Q, n, W, lanes, bq, bn, sub)
    n_qblocks, n_nblocks = q_pad // bq, n_pad // bn

    mask = None
    if probe is not None:
        mask = probe_block_mask(layout, probe, bq, bn, n_qblocks, n_nblocks)
    if cand_ids is not None:
        pmask = position_block_mask(layout, cand_ids, bq, bn, n_qblocks,
                                    n_nblocks)
        mask = pmask if mask is None else jnp.maximum(mask, pmask)

    out = ops.hamming_topk(q_packed, layout.codes, k, bins,
                           block_mask=mask, bq=bq, bn=bn, sub=sub,
                           return_stats=return_stats)
    dd, ii = out[0], out[1]
    ids = original_ids(layout, dd, ii, d)
    return (dd, ids, out[2]) if return_stats else (dd, ids)


def enabled_positions(layout: BucketLayout, mask_row: np.ndarray, bn: int
                      ) -> np.ndarray:
    """Host helper (tests/benchmarks): the reordered row positions a mask
    row enables, ascending — i.e. the exact candidate set, in the exact
    scan order, of every query in that query block."""
    mask_row = np.asarray(mask_row)
    pos = [np.arange(j * bn, min((j + 1) * bn, layout.n))
           for j in np.flatnonzero(mask_row)]
    return (np.concatenate(pos) if pos
            else np.zeros((0,), np.int64)).astype(np.int32)


# ---------------------------------------------------------------------------
# mutable arena: bucket regions with reserved slack (core/mutable.py)
# ---------------------------------------------------------------------------

class Arena(NamedTuple):
    """Host-side bucket arena with per-bucket spare slack for online
    inserts (the mutable face of :class:`BucketLayout`; core/mutable.py).

    Bucket ``b`` OWNS the capacity region ``[cap_starts[b],
    cap_starts[b+1])``; its first ``n_used[b]`` slots are occupied — live
    rows interleaved with tombstones (``ids == -1``) — and the rest is
    slack reserved at build time via ``slack_frac``. Appends fill slack in
    place; deletes tombstone in place (positions of surviving rows never
    move, which is what keeps the within-bucket ascending-id order — the
    invariant that makes an installed epoch bit-identical to a rebuild).
    All arrays are numpy: this is the mutation side, never what kernels
    stream — searches run against the dense epoch ``core/mutable.py``
    gathers from the live rows."""

    codes: np.ndarray       # (cap, W) uint32
    ids: np.ndarray         # (cap,) int64 external ids; -1 = dead/slack
    values: np.ndarray      # (cap,) int32 payload (e.g. next-token ids)
    cap_starts: np.ndarray  # (B+1,) int64 capacity offsets
    n_used: np.ndarray      # (B,) int64 occupied prefix per bucket
    positions: np.ndarray   # (bits,) int32 FIXED hamming-prefix key bits
    d: int                  # code bits

    @property
    def n_buckets(self) -> int:
        return self.cap_starts.shape[0] - 1

    @property
    def capacity(self) -> int:
        return int(self.cap_starts[-1])

    def live_mask(self) -> np.ndarray:
        """(cap,) bool: occupied AND not tombstoned."""
        used = np.zeros(self.capacity, bool)
        for b in range(self.n_buckets):
            s = int(self.cap_starts[b])
            used[s:s + int(self.n_used[b])] = True
        return used & (self.ids >= 0)

    @property
    def n_live(self) -> int:
        return int(np.count_nonzero(self.live_mask()))

    @property
    def n_tombstones(self) -> int:
        return int(self.n_used.sum()) - self.n_live


def hamming_key_host(codes: np.ndarray, positions: np.ndarray) -> np.ndarray:
    """Numpy mirror of :func:`hamming_prefix_assign`'s keying for FIXED
    ``positions`` — the online-insert hot path must not re-derive the key
    bits (re-derivation drifts as data drifts, and a drifted key would
    silently re-bucket existing rows). Bit ``p`` lives at word ``p // 32``,
    bit ``p % 32`` (binary.pack_bits convention)."""
    codes = np.asarray(codes, np.uint32)
    positions = np.asarray(positions, np.int64)
    bits = (codes[:, positions // 32] >> (positions % 32).astype(np.uint32))
    bits = (bits & 1).astype(np.int64)                     # (N, nbits)
    return bits @ (np.int64(1) << np.arange(positions.shape[0],
                                            dtype=np.int64))


def bucket_capacities(counts: np.ndarray, slack_frac: float,
                      min_slack: int) -> np.ndarray:
    """Per-bucket capacity = live count + reserved slack. Every bucket —
    including an empty one — gets at least ``min_slack`` spare slots, so a
    fresh arena can always absorb appends into ANY bucket before the next
    compaction rebalances."""
    counts = np.asarray(counts, np.int64)
    slack = np.maximum(np.ceil(counts * slack_frac).astype(np.int64),
                       min_slack)
    return counts + slack


def build_arena(codes: np.ndarray, d: int, *, ids: np.ndarray,
                values: Optional[np.ndarray] = None,
                n_buckets: int | None = None,
                positions: Optional[np.ndarray] = None,
                slack_frac: float = 0.5, min_slack: int = 8) -> Arena:
    """Build a slack-reserving arena from dense rows (the mutable analogue
    of :func:`build_layout`; the ``slack_frac`` knob is THE build-time
    reservation for online appends).

    ``positions=None`` derives the hamming-prefix key bits from ``codes``
    once (the same greedy balanced selection ``build_layout`` uses) and
    stores them in the arena: every later insert and every compaction keys
    by these frozen positions, so bucket assignment is a pure function of
    a row's code for the arena's whole lifetime. Rows must arrive in
    ascending external-id order (asserted): the arena's bit-identity
    contract leans on within-bucket id order."""
    codes = np.asarray(codes, np.uint32)
    ids = np.asarray(ids, np.int64)
    assert codes.ndim == 2 and ids.shape == (codes.shape[0],)
    if ids.size:
        assert np.all(np.diff(ids) > 0), "arena rows must be id-ascending"
        assert int(ids[0]) >= 0
    values = (np.zeros(ids.shape, np.int32) if values is None
              else np.asarray(values, np.int32))
    if positions is None:
        bits = (n_buckets - 1).bit_length() if n_buckets else (
            default_bits(max(codes.shape[0], 1)))
        _, pos = hamming_prefix_assign(jnp.asarray(codes), d, bits)
        positions = np.asarray(pos, np.int32)
    else:
        positions = np.asarray(positions, np.int32)
    B = 1 << positions.shape[0]
    assign = hamming_key_host(codes, positions)
    counts = np.bincount(assign, minlength=B).astype(np.int64)
    caps = bucket_capacities(counts, slack_frac, min_slack)
    cap_starts = np.zeros(B + 1, np.int64)
    np.cumsum(caps, out=cap_starts[1:])
    W = codes.shape[1]
    a_codes = np.zeros((int(cap_starts[-1]), W), np.uint32)
    a_ids = np.full(int(cap_starts[-1]), -1, np.int64)
    a_values = np.zeros(int(cap_starts[-1]), np.int32)
    # stable scatter: within a bucket, input (ascending-id) order survives
    if codes.shape[0]:
        order = np.argsort(assign, kind="stable")
        srt = assign[order]
        dense_starts = np.concatenate(
            ([0], np.cumsum(counts)))                       # (B+1,)
        rank = np.arange(order.shape[0]) - dense_starts[srt]
        slots = cap_starts[srt] + rank
        a_codes[slots] = codes[order]
        a_ids[slots] = ids[order]
        a_values[slots] = values[order]
    return Arena(codes=a_codes, ids=a_ids, values=a_values,
                 cap_starts=cap_starts, n_used=counts.copy(),
                 positions=positions, d=d)
