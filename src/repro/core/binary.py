"""Binary codes: packing and Hamming distance.

The paper encodes one dataset vector per NFA "Hamming macro". On TPU the
analogous resource decision is *how the bits hit the memory hierarchy*:

* ``hamming_xor``  — bit-packed uint32 lanes, XOR + popcount on the VPU.
  32x less HBM traffic than any float representation; the memory-roofline
  winner for cardinality-bound scans. (This is the paper's "vector packing"
  insight, which failed on the AP for routability reasons but is a strict
  win here — see DESIGN.md.)
* ``hamming_mxu``  — +/-1 encoding, distance = (d - q.x)/2 via a bf16 matmul
  with f32 accumulation. Exact for d <= 2^24; turns the scan into systolic
  MXU work; the compute-roofline winner when codes are already resident.

Both agree bit-for-bit with ``hamming_ref``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

WORD = 32


def padded_words(d: int) -> int:
    return (d + WORD - 1) // WORD


def pack_bits(bits: jax.Array) -> jax.Array:
    """bits: (..., d) in {0,1} -> packed (..., ceil(d/32)) uint32."""
    d = bits.shape[-1]
    W = padded_words(d)
    pad = W * WORD - d
    if pad:
        bits = jnp.pad(bits, [(0, 0)] * (bits.ndim - 1) + [(0, pad)])
    b = bits.reshape(*bits.shape[:-1], W, WORD).astype(jnp.uint32)
    weights = (jnp.uint32(1) << jnp.arange(WORD, dtype=jnp.uint32))
    return jnp.sum(b * weights, axis=-1, dtype=jnp.uint32)


def unpack_bits(packed: jax.Array, d: int) -> jax.Array:
    """packed: (..., W) uint32 -> (..., d) uint8 in {0,1}."""
    shifts = jnp.arange(WORD, dtype=jnp.uint32)
    bits = (packed[..., None] >> shifts) & jnp.uint32(1)
    return bits.reshape(*packed.shape[:-1], packed.shape[-1] * WORD)[..., :d].astype(jnp.uint8)


def hamming_ref(q_bits: jax.Array, x_bits: jax.Array) -> jax.Array:
    """Oracle: q_bits (Q, d), x_bits (N, d) in {0,1} -> (Q, N) int32."""
    diff = q_bits[:, None, :].astype(jnp.int32) != x_bits[None, :, :].astype(jnp.int32)
    return jnp.sum(diff, axis=-1, dtype=jnp.int32)


def hamming_xor(q_packed: jax.Array, x_packed: jax.Array) -> jax.Array:
    """Bit-packed XOR+popcount. q: (Q, W) uint32, x: (N, W) -> (Q, N) int32."""
    x = jax.lax.bitwise_xor(q_packed[:, None, :], x_packed[None, :, :])
    return jnp.sum(jax.lax.population_count(x).astype(jnp.int32), axis=-1)


def hamming_mxu(q_bits: jax.Array, x_bits: jax.Array, d: int | None = None,
                dtype=jnp.bfloat16) -> jax.Array:
    """MXU path: distance = (d - <2q-1, 2x-1>) / 2, f32-accumulated matmul.

    q_bits: (Q, d), x_bits: (N, d) in {0,1} -> (Q, N) int32 (exact)."""
    d = d if d is not None else q_bits.shape[-1]
    qs = (2 * q_bits.astype(jnp.int8) - 1).astype(dtype)
    xs = (2 * x_bits.astype(jnp.int8) - 1).astype(dtype)
    dot = jax.lax.dot_general(qs, xs, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)
    return ((d - dot) * 0.5).astype(jnp.int32)
