"""Small JAX version-compat shims (jax>=0.8 renamed a few knobs)."""
from __future__ import annotations

import inspect

import jax

try:
    from jax import shard_map as _shard_map
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map

_CHECK_KW = ("check_vma" if "check_vma" in inspect.signature(_shard_map).parameters
             else "check_rep")


def shard_map(f, *, mesh, in_specs, out_specs, check=False):
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **{_CHECK_KW: check})


def make_mesh(shape, axis_names):
    try:
        return jax.make_mesh(
            shape, axis_names,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axis_names))
    except (TypeError, AttributeError):  # pragma: no cover - older jax
        # older jax: make_mesh lacks axis_types / jax.sharding.AxisType absent
        return jax.make_mesh(shape, axis_names)
