"""kimi-k2-1t-a32b — trillion-parameter MoE, 384 experts top-8
[arXiv:2501.kimi2; unverified, paper-table config].

All layers MoE with one always-on shared expert (DeepSeek-V3-style); spec
fields per assignment: 61L, d_model=7168, 64H GQA kv=8, per-expert d_ff=2048,
vocab=163840. Expert weights are FSDP-sharded over the data axes (the only
way 2 TB of bf16 expert weights fit 512x16GB chips).
"""
from repro.configs.base import (BlockKind, ModelConfig, MoEConfig,
                                RetrievalConfig, register)


@register("kimi-k2-1t-a32b")
def kimi_k2() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-1t-a32b",
        family="moe",
        num_layers=61,
        d_model=7168,
        num_heads=64,
        num_kv_heads=8,
        d_ff=2048,               # per-expert hidden dim
        vocab_size=163840,
        head_dim=112,
        mlp_activation="swiglu",
        block_pattern=(BlockKind.MOE,),
        moe=MoEConfig(
            num_experts=384,
            experts_per_token=8,
            expert_d_ff=2048,
            num_shared_experts=1,
            router_aux_loss=0.001,
            capacity_factor=1.25,
        ),
        retrieval=RetrievalConfig(enabled=True),
    )
