"""Config registry: importing this package registers every assigned arch."""
from repro.configs.base import (BlockKind, ModelConfig, MoEConfig,
                                RetrievalConfig, RWKVConfig, ShapeConfig,
                                SSMConfig, StepKind, TrainConfig, get_config,
                                list_archs, register, scaled_down)
from repro.configs.shapes import (SHAPES, get_shape, runnable_cells,
                                  shape_applicable)

# arch registrations (import side effects)
from repro.configs import (arctic_480b, deepseek_67b, gemma_2b, granite_20b,  # noqa: F401
                           internlm2_20b, kimi_k2, llava_next_mistral_7b,
                           musicgen_medium, rwkv6_1p6b, zamba2_2p7b)

ALL_ARCHS = list_archs()

__all__ = [
    "ALL_ARCHS", "BlockKind", "ModelConfig", "MoEConfig", "RetrievalConfig",
    "RWKVConfig", "SHAPES", "ShapeConfig", "SSMConfig", "StepKind",
    "TrainConfig", "get_config", "get_shape", "list_archs", "register",
    "runnable_cells", "scaled_down", "shape_applicable",
]
