"""llava-next-mistral-7b — mistral-7b backbone with anyres vision tiles
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified].

Backbone only per task spec: the CLIP/anyres frontend is a stub;
``input_specs()`` supplies 576 precomputed patch embeddings (one 24x24 tile)
prepended to the token sequence.
"""
from repro.configs.base import BlockKind, ModelConfig, RetrievalConfig, register


@register("llava-next-mistral-7b")
def llava_next_mistral_7b() -> ModelConfig:
    return ModelConfig(
        name="llava-next-mistral-7b",
        family="vlm",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        d_ff=14336,
        vocab_size=32000,
        head_dim=128,
        mlp_activation="swiglu",
        rope_theta=1_000_000.0,
        block_pattern=(BlockKind.ATTENTION,),
        frontend="vision_patches",
        frontend_positions=576,
        retrieval=RetrievalConfig(enabled=True),
    )
