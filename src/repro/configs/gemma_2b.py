"""gemma-2b — dense MQA transformer, GeGLU, head_dim=256 [arXiv:2403.08295; hf]."""
from repro.configs.base import BlockKind, ModelConfig, RetrievalConfig, register


@register("gemma-2b")
def gemma_2b() -> ModelConfig:
    return ModelConfig(
        name="gemma-2b",
        family="dense",
        num_layers=18,
        d_model=2048,
        num_heads=8,
        num_kv_heads=1,          # MQA on the 2b variant
        d_ff=16384,
        vocab_size=256000,
        head_dim=256,
        mlp_activation="geglu",
        tie_embeddings=True,
        block_pattern=(BlockKind.ATTENTION,),
        retrieval=RetrievalConfig(enabled=True),
    )
