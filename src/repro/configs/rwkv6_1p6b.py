"""rwkv6-1.6b (Finch) — attention-free RNN with data-dependent decay
[arXiv:2404.05892; unverified]."""
from repro.configs.base import (BlockKind, ModelConfig, RetrievalConfig,
                                RWKVConfig, register)


@register("rwkv6-1.6b")
def rwkv6_1p6b() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-1.6b",
        family="ssm",
        num_layers=24,
        d_model=2048,
        num_heads=0,             # attention-free
        num_kv_heads=0,
        d_ff=7168,
        vocab_size=65536,
        mlp_activation="relu_sq",  # rwkv channel-mix uses squared relu
        block_pattern=(BlockKind.RWKV6,),
        rwkv=RWKVConfig(head_dim=64, decay_lora=64, gate_lora=64),
        retrieval=RetrievalConfig(enabled=True),
    )
