"""Assigned input-shape cells (identical for every LM-family arch)."""
from __future__ import annotations

from repro.configs.base import ModelConfig, ShapeConfig, StepKind

TRAIN_4K = ShapeConfig("train_4k", seq_len=4096, global_batch=256, step=StepKind.TRAIN)
PREFILL_32K = ShapeConfig("prefill_32k", seq_len=32768, global_batch=32, step=StepKind.PREFILL)
DECODE_32K = ShapeConfig("decode_32k", seq_len=32768, global_batch=128, step=StepKind.DECODE)
LONG_500K = ShapeConfig("long_500k", seq_len=524288, global_batch=1, step=StepKind.DECODE)

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; known: {sorted(SHAPES)}")
    return SHAPES[name]


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    """long_500k runs only for sub-quadratic (SSM/hybrid) archs per spec."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False
    return True


def runnable_cells(cfgs):
    """All (arch, shape) cells that are runnable, plus the skip list."""
    run, skipped = [], []
    for cfg in cfgs:
        for shape in SHAPES.values():
            if shape_applicable(cfg, shape):
                run.append((cfg.name, shape.name))
            else:
                skipped.append((cfg.name, shape.name, "full-attention arch; long_500k requires sub-quadratic attention"))
    return run, skipped
