"""Config dataclasses + registry for the repro framework.

A ModelConfig fully describes one architecture from the assigned pool; a
ShapeConfig describes one (seq_len, global_batch, step-kind) workload cell.
Configs are plain frozen dataclasses so they hash, print, and diff cleanly and
can be used as jit static args.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Callable, Dict, Optional, Tuple


class BlockKind(str, enum.Enum):
    """Kind of a single residual block in the layer stack."""

    ATTENTION = "attention"        # full (GQA/MQA) causal attention + MLP
    MAMBA2 = "mamba2"              # Mamba2 SSD block
    RWKV6 = "rwkv6"                # RWKV6 time-mix + channel-mix
    MOE = "moe"                    # attention + MoE FFN (optional dense residual)


class StepKind(str, enum.Enum):
    TRAIN = "train"                # train_step: fwd+bwd+opt over (batch, seq)
    PREFILL = "prefill"            # serve prefill: fwd building the KV cache
    DECODE = "decode"              # serve decode: one token against a KV cache


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    experts_per_token: int
    # d_ff of each expert (may differ from the dense d_ff)
    expert_d_ff: int
    # dense residual MLP run in parallel with the experts (arctic-style)
    dense_residual_d_ff: int = 0
    # shared expert always active (deepseek/kimi-style)
    num_shared_experts: int = 0
    router_aux_loss: float = 0.01
    # capacity factor for dense one-hot dispatch accounting
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba2 SSD parameters."""

    state_dim: int = 64            # N: per-head SSM state size
    head_dim: int = 64             # P: channels per SSM head
    expand: int = 2                # d_inner = expand * d_model
    chunk_size: int = 128          # SSD chunk length
    conv_width: int = 4


@dataclasses.dataclass(frozen=True)
class RWKVConfig:
    head_dim: int = 64
    # decay LoRA rank for data-dependent decay (Finch)
    decay_lora: int = 64
    gate_lora: int = 64


@dataclasses.dataclass(frozen=True)
class RetrievalConfig:
    """kNN-LM / retrieval integration (the paper's technique at serve time)."""

    enabled: bool = False
    code_bits: int = 256           # binary code width d (Hamming space)
    datastore_size: int = 1 << 20  # number of entries in the datastore
    k: int = 16                    # neighbors
    local_k: int = 4               # k' for hierarchical (statistical) reduction
    interpolation: float = 0.25    # lambda for kNN-LM mixing
    # per-device scan chunk ("board capacity") for the MATERIALIZING selects
    # and "fused_scan" only — the single-shot "fused" path streams the whole
    # datastore in one invocation and tiles via kernels/tuning.py, so this
    # is a no-op for it
    chunk_size: int = 1 << 16
    # top-k select path: "auto" | "counting" | "bisect" | "fused" |
    # "fused_scan" (see the generated decision table in DESIGN.md);
    # orthogonal to the distance method. Legacy twin of ``plan`` below —
    # both route through core/plan.py's planner ("auto" lets it resolve)
    select: str = "auto"
    # physical datastore layout (core/layout.py): "none" keeps insertion
    # order; "hamming_prefix" bucket-clusters the packed codes at build
    # time so the fused select's block-min pruning bites even on uniform
    # data (single-device: a prebuilt layout on the DataStore; sharded:
    # each shard re-sorts its local slice per call). Only the "fused"
    # select consumes it — with any other select the prebuilt copy is
    # idle memory, so pair layout != "none" with select="fused" (or a
    # per-call select override)
    layout: str = "none"
    # bucket count for the layout ("hamming_prefix" rounds up to a power
    # of two); 0 -> heuristic (~256 rows per bucket, layout.default_bits)
    layout_buckets: int = 0
    # query planning (core/plan.py): "auto" lets the planner resolve the
    # select/layout/merge stages from datastore stats; any concrete select
    # path name ("composite" | "counting" | "bisect" | "fused" |
    # "fused_scan") forces that stage through the same planner. Takes
    # precedence over the legacy ``select`` field when not "auto".
    plan: str = "auto"
    # fine-grained forced-plan overrides applied after planning, e.g.
    # "select=fused_scan,chunk=4096,layout=off" (see plan.parse_force);
    # "" applies none. The escape hatch that replaces ad-hoc knobs.
    force_plan: str = ""
    # approx tier only (select/plan = "approx"): expected recall@k floor
    # the analytical bound sizes the per-block candidate count L for;
    # 1.0 keeps the full block — exact, bit-identical to "fused". Exact
    # selects ignore it.
    recall_target: float = 1.0


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | hybrid | ssm | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int                 # query heads (0 for attention-free)
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // num_heads
    # activation: "swiglu" | "geglu" | "gelu"
    mlp_activation: str = "swiglu"
    norm_eps: float = 1e-5
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    # layer layout: function idx -> BlockKind, via pattern list repeated
    block_pattern: Tuple[BlockKind, ...] = (BlockKind.ATTENTION,)
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rwkv: Optional[RWKVConfig] = None
    retrieval: RetrievalConfig = RetrievalConfig()
    # modality frontend stub: "none" | "audio_frames" | "vision_patches"
    frontend: str = "none"
    # frontend embedding slots prepended to the token sequence (stub provides
    # precomputed embeddings of this many positions)
    frontend_positions: int = 0
    dtype: str = "bfloat16"
    # zamba2-style shared attention block applied every N blocks (0 = off)
    shared_attn_every: int = 0

    def block_kind(self, layer_idx: int) -> BlockKind:
        return self.block_pattern[layer_idx % len(self.block_pattern)]

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        if self.num_heads:
            return self.d_model // self.num_heads
        return 0

    @property
    def attention_free(self) -> bool:
        return all(k in (BlockKind.MAMBA2, BlockKind.RWKV6) for k in self.block_pattern) and (
            self.shared_attn_every == 0
        )

    @property
    def sub_quadratic(self) -> bool:
        """True when the arch can serve 500k-token contexts (SSM/hybrid)."""
        return any(k in (BlockKind.MAMBA2, BlockKind.RWKV6) for k in self.block_pattern)

    def param_count(self) -> int:
        """Analytic parameter count (matches the constructed pytree)."""
        from repro.models import lm  # local import to avoid cycles

        return lm.param_count(self)

    def active_param_count(self) -> int:
        from repro.models import lm

        return lm.param_count(self, active_only=True)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    step: StepKind

    @property
    def is_decode(self) -> bool:
        return self.step == StepKind.DECODE


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    b1: float = 0.9
    b2: float = 0.95
    zero1: bool = True             # shard optimizer state along data axis
    remat: bool = True             # activation checkpointing over the scan
    grad_compression: str = "none"  # none | int8_ef
    microbatches: int = 1          # gradient accumulation (activation memory /M)
    opt_int8: bool = False         # 8-bit Adam moments (blockwise quantized)
    seed: int = 0


_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register(name: str):
    def deco(fn: Callable[[], ModelConfig]):
        _REGISTRY[name] = fn
        return fn

    return deco


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_archs():
    return sorted(_REGISTRY)


def scaled_down(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Reduced config of the same family for CPU smoke tests."""
    reduced = dict(
        num_layers=min(cfg.num_layers, 2 if cfg.shared_attn_every == 0 else 4),
        d_model=128,
        num_heads=min(cfg.num_heads, 4) if cfg.num_heads else 0,
        num_kv_heads=min(cfg.num_kv_heads, 2) if cfg.num_kv_heads else 0,
        d_ff=256,
        vocab_size=512,
        head_dim=32 if cfg.head_dim else 0,
        shared_attn_every=min(cfg.shared_attn_every, 2) if cfg.shared_attn_every else 0,
    )
    if cfg.num_kv_heads == 1:       # preserve MQA structure
        reduced["num_kv_heads"] = 1
    if cfg.moe is not None:
        reduced["moe"] = dataclasses.replace(
            cfg.moe,
            num_experts=8,
            experts_per_token=min(cfg.moe.experts_per_token, 2),
            expert_d_ff=128,
            dense_residual_d_ff=128 if cfg.moe.dense_residual_d_ff else 0,
            num_shared_experts=min(cfg.moe.num_shared_experts, 1),
        )
    if cfg.ssm is not None:
        reduced["ssm"] = dataclasses.replace(
            cfg.ssm, state_dim=16, head_dim=16, chunk_size=32)
    if cfg.rwkv is not None:
        reduced["rwkv"] = dataclasses.replace(
            cfg.rwkv, head_dim=32, decay_lora=16, gate_lora=16)
    if cfg.retrieval.enabled:
        reduced["retrieval"] = dataclasses.replace(
            cfg.retrieval, code_bits=64, datastore_size=2048, chunk_size=512)
    reduced.update(overrides)
    return dataclasses.replace(cfg, **reduced)
