"""zamba2-2.7b — hybrid Mamba2 backbone + shared attention blocks
[arXiv:2411.15242; hf].

54 Mamba2 blocks; a single weight-shared (attention + MLP) block is applied
every `shared_attn_every` Mamba2 blocks (Zamba2's shared transformer block).
"""
from repro.configs.base import (BlockKind, ModelConfig, RetrievalConfig,
                                SSMConfig, register)


@register("zamba2-2.7b")
def zamba2_2p7b() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b",
        family="hybrid",
        num_layers=54,
        d_model=2560,
        num_heads=32,
        num_kv_heads=32,
        d_ff=10240,
        vocab_size=32000,
        head_dim=80,
        mlp_activation="gelu",
        block_pattern=(BlockKind.MAMBA2,),
        shared_attn_every=6,
        ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, chunk_size=128),
        retrieval=RetrievalConfig(enabled=True),
    )
