"""granite-20b — dense llama-arch MQA code model [arXiv:2405.04324; hf]."""
from repro.configs.base import BlockKind, ModelConfig, RetrievalConfig, register


@register("granite-20b")
def granite_20b() -> ModelConfig:
    return ModelConfig(
        name="granite-20b",
        family="dense",
        num_layers=52,
        d_model=6144,
        num_heads=48,
        num_kv_heads=1,          # MQA
        d_ff=24576,
        vocab_size=49152,
        head_dim=128,
        # GPT-BigCode-style 2-matrix MLP (a swiglu MLP at this d_ff would be
        # 28B, off the 20B nameplate)
        mlp_activation="gelu",
        block_pattern=(BlockKind.ATTENTION,),
        retrieval=RetrievalConfig(enabled=True),
    )
