"""deepseek-67b — dense llama-arch GQA transformer [arXiv:2401.02954; hf]."""
from repro.configs.base import BlockKind, ModelConfig, RetrievalConfig, register


@register("deepseek-67b")
def deepseek_67b() -> ModelConfig:
    return ModelConfig(
        name="deepseek-67b",
        family="dense",
        num_layers=95,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        d_ff=22016,
        vocab_size=102400,
        head_dim=128,
        mlp_activation="swiglu",
        rope_theta=10000.0,
        block_pattern=(BlockKind.ATTENTION,),
        retrieval=RetrievalConfig(enabled=True),
    )
