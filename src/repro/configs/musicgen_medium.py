"""musicgen-medium — decoder-only transformer over EnCodec tokens
[arXiv:2306.05284; hf].

Backbone only per task spec: the EnCodec/text-conditioning frontend is a stub;
``input_specs()`` supplies 64 precomputed conditioning-frame embeddings that
are prepended to the audio-token sequence.
"""
from repro.configs.base import BlockKind, ModelConfig, RetrievalConfig, register


@register("musicgen-medium")
def musicgen_medium() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium",
        family="audio",
        num_layers=48,
        d_model=1536,
        num_heads=24,
        num_kv_heads=24,         # full MHA
        d_ff=6144,
        vocab_size=2048,         # EnCodec codebook
        head_dim=64,
        mlp_activation="gelu",
        block_pattern=(BlockKind.ATTENTION,),
        frontend="audio_frames",
        frontend_positions=64,
        retrieval=RetrievalConfig(enabled=True),
    )
