"""arctic-480b — 128-expert top-2 MoE with a dense residual MLP
[hf:Snowflake/snowflake-arctic-base; hf]."""
from repro.configs.base import (BlockKind, ModelConfig, MoEConfig,
                                RetrievalConfig, register)


@register("arctic-480b")
def arctic_480b() -> ModelConfig:
    return ModelConfig(
        name="arctic-480b",
        family="moe",
        num_layers=35,
        d_model=7168,
        num_heads=56,
        num_kv_heads=8,
        d_ff=4864,               # per-expert hidden dim
        vocab_size=32000,
        head_dim=128,
        mlp_activation="swiglu",
        block_pattern=(BlockKind.MOE,),
        moe=MoEConfig(
            num_experts=128,
            experts_per_token=2,
            expert_d_ff=4864,
            dense_residual_d_ff=4864,   # arctic's dense-MoE hybrid residual
            router_aux_loss=0.001,
            capacity_factor=1.25,
        ),
        retrieval=RetrievalConfig(enabled=True),
    )
