"""AdamW with warmup+cosine schedule, global-norm clipping, and optional
int8 gradient compression with error feedback — all as pure pytree ops so
every state leaf can carry a ZeRO-1 PartitionSpec.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


class AdamState(NamedTuple):
    mu: dict          # first moments (f32 — or int8 q with opt_int8)
    nu: dict          # second moments
    count: jax.Array  # step counter
    ef: Optional[dict] = None   # error-feedback residual (grad compression)
    mu_scale: Optional[dict] = None   # per-tensor f32 scales (opt_int8)
    nu_scale: Optional[dict] = None


def _blocks(shape):
    """Blockwise-quantization layout: blocks of 128 along the last dim when
    divisible, else one block per row. Returns (n_blocks, block)."""
    if not shape:
        return 1, 1
    last = shape[-1]
    block = 128 if last % 128 == 0 else last
    return last // block, block


def _q8(x: jax.Array):
    """Symmetric BLOCKWISE int8 quantization -> (q, scale). Per-tensor scales
    diverge on real models (nu spans orders of magnitude); blockwise is the
    bitsandbytes-style fix."""
    shape = x.shape
    nb, block = _blocks(shape)
    xr = x.reshape(shape[:-1] + (nb, block)) if shape else x.reshape(1, 1)
    scale = jnp.maximum(jnp.max(jnp.abs(xr), axis=-1, keepdims=True),
                        1e-20) / 127.0
    q = jnp.clip(jnp.round(xr / scale), -127, 127).astype(jnp.int8)
    return q.reshape(shape), scale.squeeze(-1)


def _dq8(q: jax.Array, scale: jax.Array) -> jax.Array:
    shape = q.shape
    nb, block = _blocks(shape)
    qr = q.reshape(shape[:-1] + (nb, block)) if shape else q.reshape(1, 1)
    out = qr.astype(jnp.float32) * scale[..., None]
    return out.reshape(shape)


def schedule(tc: TrainConfig, step: jax.Array) -> jax.Array:
    """Linear warmup then cosine decay to 10%."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(tc.warmup_steps, 1)
    progress = jnp.clip((step - tc.warmup_steps)
                        / jnp.maximum(tc.total_steps - tc.warmup_steps, 1), 0.0, 1.0)
    cosine = 0.1 + 0.45 * (1 + jnp.cos(jnp.pi * progress))
    return tc.learning_rate * jnp.where(step < tc.warmup_steps, warm, cosine)


def init(params, tc: TrainConfig) -> AdamState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    if tc.opt_int8:
        zq = lambda p: jnp.zeros(p.shape, jnp.int8)

        def zs(p):
            nb, _ = _blocks(p.shape)
            return jnp.zeros(p.shape[:-1] + (nb,) if p.shape else (1, 1),
                             jnp.float32)

        return AdamState(
            mu=jax.tree_util.tree_map(zq, params),
            nu=jax.tree_util.tree_map(zq, params),
            count=jnp.zeros((), jnp.int32),
            ef=None,
            mu_scale=jax.tree_util.tree_map(zs, params),
            nu_scale=jax.tree_util.tree_map(zs, params),
        )
    state = AdamState(
        mu=jax.tree_util.tree_map(zeros, params),
        nu=jax.tree_util.tree_map(zeros, params),
        count=jnp.zeros((), jnp.int32),
        ef=(jax.tree_util.tree_map(zeros, params)
            if tc.grad_compression == "int8_ef" else None),
    )
    return state


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(l.astype(jnp.float32)))
              for l in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), norm


def compress_int8(g: jax.Array, ef: jax.Array):
    """Symmetric int8 quantization with error feedback: the all-reduce moves
    1/4 the bytes; the residual re-enters next step (convergence-preserving)."""
    gf = g.astype(jnp.float32) + ef
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return deq, gf - deq


def update(grads, state: AdamState, params, tc: TrainConfig, step: jax.Array):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    if tc.grad_compression == "int8_ef" and state.ef is not None:
        pairs = jax.tree_util.tree_map(compress_int8, grads, state.ef)
        grads = jax.tree_util.tree_map(lambda pr: pr[0], pairs,
                                       is_leaf=lambda x: isinstance(x, tuple))
        new_ef = jax.tree_util.tree_map(lambda pr: pr[1], pairs,
                                        is_leaf=lambda x: isinstance(x, tuple))
    else:
        new_ef = state.ef

    grads, gnorm = clip_by_global_norm(grads, tc.grad_clip)
    count = state.count + 1
    lr = schedule(tc, step)
    b1, b2 = tc.b1, tc.b2
    bc1 = 1 - b1 ** count.astype(jnp.float32)
    bc2 = 1 - b2 ** count.astype(jnp.float32)

    def core(p, gf, m, v):
        m = b1 * m + (1 - b1) * gf
        v = b2 * v + (1 - b2) * jnp.square(gf)
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + 1e-8)
        if p.ndim >= 2:                     # decoupled weight decay on matrices
            delta = delta + tc.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    is_tup = lambda x: isinstance(x, tuple)
    if tc.opt_int8:
        # 8-bit Adam: moments stored int8 + blockwise scales (4x less HBM
        # residency and traffic — the 1T-param fit enabler). nu is quantized
        # in sqrt space (halves its dynamic range in log scale).
        def upd(p, g, mq, ms, vq, vs):
            v_prev = jnp.square(_dq8(vq, vs))
            newp, m, v = core(p, g.astype(jnp.float32), _dq8(mq, ms), v_prev)
            mq2, ms2 = _q8(m)
            vq2, vs2 = _q8(jnp.sqrt(v))
            return newp, mq2, ms2, vq2, vs2

        out = jax.tree_util.tree_map(upd, params, grads, state.mu,
                                     state.mu_scale, state.nu, state.nu_scale)
        pick = lambda i: jax.tree_util.tree_map(lambda t: t[i], out, is_leaf=is_tup)
        new_state = AdamState(mu=pick(1), nu=pick(3), count=count, ef=new_ef,
                              mu_scale=pick(2), nu_scale=pick(4))
        return pick(0), new_state, {"grad_norm": gnorm, "lr": lr}

    out = jax.tree_util.tree_map(
        lambda p, g, m, v: core(p, g.astype(jnp.float32), m, v),
        params, grads, state.mu, state.nu)
    new_params = jax.tree_util.tree_map(lambda t: t[0], out, is_leaf=is_tup)
    new_mu = jax.tree_util.tree_map(lambda t: t[1], out, is_leaf=is_tup)
    new_nu = jax.tree_util.tree_map(lambda t: t[2], out, is_leaf=is_tup)
    new_state = AdamState(mu=new_mu, nu=new_nu, count=count, ef=new_ef)
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
