"""Deterministic synthetic LM data pipeline, host-sharded and prefetching.

Determinism-by-step is the fault-tolerance primitive: batch(step) is a pure
function of (seed, step, host slice), so any host can recompute any batch —
resume after preemption replays the exact stream, and straggler work-stealing
needs no data-state handoff.

The generator produces Zipf-ish token streams with short-range structure
(repeated n-grams) so that tiny-model training loss visibly decreases.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    ngram_repeat: int = 8          # structure: periodic n-gram echo


def _host_slice(global_batch: int, process_index: int, process_count: int):
    per = global_batch // process_count
    return process_index * per, per


def make_batch(dc: DataConfig, step: int, process_index: int = 0,
               process_count: int = 1) -> dict:
    """Pure function of (config, step, host): {'tokens','labels'} numpy."""
    start, per = _host_slice(dc.global_batch, process_index, process_count)
    rng = np.random.default_rng(
        np.random.SeedSequence([dc.seed, step, start]))
    # Zipf marginal clipped to vocab
    base = rng.zipf(dc.zipf_a, size=(per, dc.seq_len + 1)) % dc.vocab_size
    # inject learnable short-range structure: echo of lag `ngram_repeat`
    lag = dc.ngram_repeat
    echo_mask = rng.random((per, dc.seq_len + 1)) < 0.5
    base[:, lag:] = np.where(echo_mask[:, lag:], base[:, :-lag], base[:, lag:])
    tokens = base[:, :-1].astype(np.int32)
    labels = base[:, 1:].astype(np.int32)
    return {"tokens": tokens, "labels": labels}


class Prefetcher:
    """Background-thread prefetch of the deterministic stream."""

    def __init__(self, dc: DataConfig, start_step: int = 0, depth: int = 2,
                 process_index: int = 0, process_count: int = 1):
        self.dc = dc
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._pi, self._pc = process_index, process_count
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        step = self._step
        while not self._stop.is_set():
            batch = make_batch(self.dc, step, self._pi, self._pc)
            while not self._stop.is_set():
                try:
                    self.q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator:
        while True:
            yield self.q.get()

    def stop(self):
        self._stop.set()


def data_config_for(cfg: ModelConfig, seq_len: int, global_batch: int,
                    seed: int = 0) -> DataConfig:
    return DataConfig(vocab_size=cfg.vocab_size, seq_len=seq_len,
                      global_batch=global_batch, seed=seed)
