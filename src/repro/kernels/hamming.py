"""Pallas TPU kernel: bit-packed Hamming distance (XOR + popcount).

This is the paper's compute phase (the "Hamming macros") as a VPU kernel.
The dataset codes stream HBM->VMEM in (BN, W) tiles; each grid cell computes
a (BQ, BN) distance tile entirely in VMEM. Bit-packing gives 32x less HBM
traffic than any float layout — the memory-roofline win that makes the
cardinality scan bandwidth-optimal (see DESIGN.md "vector packing").

Popcount uses ``lax.population_count`` (a native VPU op on TPU). Block
shapes are MXU/VPU aligned: BQ multiple of 8 (sublane), BN multiple of 128
(lane). W (= code_bits/32, <= 8 for 256-bit codes) is kept whole per tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hamming_kernel(q_ref, x_ref, out_ref):
    q = q_ref[...]                                 # (BQ, W) int32
    x = x_ref[...]                                 # (BN, W) int32
    xor = jax.lax.bitwise_xor(q[:, None, :], x[None, :, :])   # (BQ, BN, W)
    pc = jax.lax.population_count(xor).astype(jnp.int32)
    out_ref[...] = jnp.sum(pc, axis=-1)


@functools.partial(jax.jit, static_argnames=("bq", "bn", "interpret"))
def hamming_distance_pallas(q_packed: jax.Array, x_packed: jax.Array,
                            bq: int = 128, bn: int = 512,
                            interpret: bool = False) -> jax.Array:
    """q: (Q, W), x: (N, W) packed int32/uint32 -> (Q, N) int32.

    Q % bq == 0 and N % bn == 0 (ops.py pads)."""
    Q, W = q_packed.shape
    N, _ = x_packed.shape
    bq, bn = min(bq, Q), min(bn, N)
    assert Q % bq == 0 and N % bn == 0, (Q, N, bq, bn)
    q32 = q_packed.astype(jnp.int32) if q_packed.dtype != jnp.int32 else q_packed
    x32 = x_packed.astype(jnp.int32) if x_packed.dtype != jnp.int32 else x_packed

    grid = (Q // bq, N // bn)
    return pl.pallas_call(
        _hamming_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, W), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, W), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bq, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Q, N), jnp.int32),
        interpret=interpret,
    )(q32, x32)
