"""Pure-jnp oracles for every Pallas kernel (tests assert_allclose against
these, and they serve as the XLA fallback path)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def hamming_distance_ref(q_packed: jax.Array, x_packed: jax.Array) -> jax.Array:
    """q: (Q, W) uint32/int32 packed codes; x: (N, W) -> (Q, N) int32."""
    x = jax.lax.bitwise_xor(q_packed[:, None, :], x_packed[None, :, :])
    return jnp.sum(jax.lax.population_count(x).astype(jnp.int32), axis=-1)


def hamming_hist_ref(q_packed: jax.Array, x_packed: jax.Array,
                     bins: int) -> jax.Array:
    """Distance histogram over the bounded domain [0, bins) — pass 1 of the
    temporal-sort-analogue counting select. -> (Q, bins) int32."""
    dist = hamming_distance_ref(q_packed, x_packed)
    Q = dist.shape[0]
    return jnp.zeros((Q, bins), jnp.int32).at[
        jnp.arange(Q)[:, None], jnp.minimum(dist, bins - 1)].add(1)


def bitpack_ref(bits: jax.Array) -> jax.Array:
    """bits: (N, d) {0,1}, d % 32 == 0 -> (N, d//32) int32 (bit i of word w
    is dim w*32+i)."""
    n, d = bits.shape
    b = bits.reshape(n, d // 32, 32).astype(jnp.uint32)
    weights = (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32))
    return jnp.sum(b * weights, axis=-1, dtype=jnp.uint32).astype(jnp.int32)
