"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) kernels execute with ``interpret=True`` — the kernel
body runs faithfully in Python/XLA for correctness validation; on TPU the
same calls compile to Mosaic. Shapes are padded to block multiples here so
the kernels stay assert-simple; padded dataset rows are masked exactly
inside the kernels by the ``n_valid`` scalar. Block shapes come from the
shared heuristic in kernels/tuning.py unless explicitly overridden.

``hamming_topk`` is the engine's single-shot fused select: one hist + one
emit ``pallas_call`` over the WHOLE datastore for any N, with the pass-1
block-min summary pruning pass-2 tiles that cannot hold a winner.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref, tuning
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.hamming import hamming_distance_pallas
from repro.kernels.topk_select import hamming_emit_pallas, hamming_hist_pallas


def _interpret() -> bool:
    return jax.default_backend() == "cpu"


def _round_up(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


def _pad_rows(a: jax.Array, target: int, fill: int = 0) -> jax.Array:
    pad = target - a.shape[0]
    if pad:
        a = jnp.pad(a, ((0, pad), (0, 0)), constant_values=fill)
    return a


def hamming_distance(q_packed: jax.Array, x_packed: jax.Array,
                     bq: int | None = None,
                     bn: int | None = None) -> jax.Array:
    """(Q, W) x (N, W) packed -> (Q, N) int32 (Pallas on TPU, interpreted on
    CPU). Arbitrary Q/N; padding handled here."""
    Q, W = q_packed.shape
    N = x_packed.shape[0]
    hbq, hbn = tuning.distance_blocks(Q, N, W)
    bq, bn = bq or hbq, bn or hbn
    qp = _pad_rows(q_packed, _round_up(Q, bq))
    xp = _pad_rows(x_packed, _round_up(N, bn))
    out = hamming_distance_pallas(qp, xp, bq=bq, bn=bn, interpret=_interpret())
    return out[:Q, :N]


def topk_geometry(Q: int, N: int, W: int, lanes: int,
                  bq: int | None = None, bn: int | None = None,
                  sub: int | None = None, backend: str | None = None):
    """The padded grid geometry ``hamming_topk`` will run under:
    (bq, bn, sub, q_pad, n_pad). ``lanes = max(bins, min(k, N))``.

    Exposed so layout-aware callers (core/layout.py) can build a
    (q_pad//bq, n_pad//bn) block mask that tiles EXACTLY like the kernels —
    any drift between this and the internal prologue is a shape error, not
    a silent mis-mask. ``backend`` pins the heuristic to a named backend
    (planner/table introspection); None uses the runtime default."""
    hbq, hbn, hsub = tuning.topk_blocks(Q, N, W, lanes, backend=backend)
    bq, bn, sub = bq or hbq, bn or hbn, sub or hsub
    sub = min(sub, bn)
    return bq, bn, sub, _round_up(Q, bq), _round_up(N, bn)


def _topk_blocked(q_packed: jax.Array, x_packed: jax.Array, lanes: int,
                  bq: int | None, bn: int | None, sub: int | None):
    """Shared pad-to-blocks prologue for the two-pass kernels."""
    Q, W = q_packed.shape
    N = x_packed.shape[0]
    bq, bn, sub, q_pad, n_pad = topk_geometry(Q, N, W, lanes, bq, bn, sub)
    qp = _pad_rows(q_packed.astype(jnp.int32), q_pad)
    xp = _pad_rows(x_packed.astype(jnp.int32), n_pad)
    return qp, xp, bq, bn, sub


def hamming_hist(q_packed: jax.Array, x_packed: jax.Array, bins: int,
                 n_valid: jax.Array | int | None = None,
                 bq: int | None = None, bn: int | None = None,
                 sub: int | None = None) -> jax.Array:
    """Fused distance+histogram: (Q, W) x (N, W) -> (Q, bins) int32.

    Pass 1 of the two-pass counting select. Rows with global id >= n_valid
    (default: all N rows valid) — including the block-alignment padding added
    here — are masked exactly inside the kernel. (The kernel's second
    output, the block-min pruning summary, is an implementation detail of
    ``hamming_topk`` and is dropped here.)"""
    Q, N = q_packed.shape[0], x_packed.shape[0]
    qp, xp, bq, bn, sub = _topk_blocked(q_packed, x_packed, bins, bq, bn, sub)
    nv = jnp.asarray(N if n_valid is None else n_valid, jnp.int32)
    hist, _ = hamming_hist_pallas(qp, xp, bins, nv, bq=bq, bn=bn, sub=sub,
                                  interpret=_interpret())
    return hist[:Q]


def hamming_topk(q_packed: jax.Array, x_packed: jax.Array, k: int, bins: int,
                 n_valid: jax.Array | int | None = None,
                 block_mask: jax.Array | None = None,
                 bq: int | None = None, bn: int | None = None,
                 sub: int | None = None, return_stats: bool = False):
    """Single-shot fused two-pass top-k over the WHOLE datastore:
    (Q, W) x (N, W) -> (dists (Q, k), ids (Q, k)).

    The engine's high-throughput select, one hist + one emit ``pallas_call``
    for any N (the Pallas grid streams the N dimension; arbitrary N is
    padded to a block multiple here and masked exactly in-kernel): pass 1
    histograms distances into [0, bins) (clamped at bins-1; pass bins > max
    distance for exactness) and emits the (Q/bq, N/bn) block-min pruning
    summary, pass 2 re-streams the codes and emits the winners, skipping
    every (query-block, data-block) tile whose summary proves it holds no
    winner. Only (Q, bins), the tiny summary, and (Q, k) ever leave the
    kernels — the (Q, N) distance matrix is never materialized. Semantics
    match ``topk.counting_topk`` on the clamped distances: ascending, ties
    broken by index order, rows beyond min(k, n_valid) padded with
    (bins, N). Rows with global id >= n_valid are excluded exactly.

    ``block_mask``: optional (q_pad//bq, n_pad//bn) int32 enable mask over
    the grid tiles (geometry from ``topk_geometry``): a zero tile is
    outside the candidate set — pass 1 skips it outright and every query's
    top-k is taken over the enabled rows only, the index-probing contract
    of core/layout.py. Queries whose candidate count falls below k get
    (bins, N) sentinels in the surplus slots, exactly like n_valid < k.

    ``return_stats=True`` additionally returns a dict with the pruning
    telemetry: ``blocks_total`` (python int, grid tiles per pass),
    ``p1_blocks_skipped`` (traced int32, tiles the enable mask excluded
    from pass 1), ``blocks_skipped`` (traced int32, tiles pass 2 pruned —
    mask composed with the block-min guard; padding-only tiles included,
    they always prune), and ``block_min`` (the summary itself).
    """
    Q, N = q_packed.shape[0], x_packed.shape[0]
    k_k = min(k, N)
    if k_k == 0:
        out = (jnp.full((Q, k), bins, jnp.int32),
               jnp.full((Q, k), N, jnp.int32))
        if return_stats:
            return out + ({"blocks_total": 0,
                           "blocks_skipped": jnp.int32(0),
                           "p1_blocks_skipped": jnp.int32(0),
                           "block_min": jnp.zeros((0, 0), jnp.int32)},)
        return out
    qp, xp, bq, bn, sub = _topk_blocked(q_packed, x_packed,
                                        max(bins, k_k), bq, bn, sub)
    nv = jnp.asarray(N if n_valid is None else n_valid, jnp.int32)
    interp = _interpret()

    # pass 1: the race -> per-query radius r*, the counts below it, and the
    # block-min summary pass 2 prunes with
    hist, block_min = hamming_hist_pallas(qp, xp, bins, nv,
                                          block_mask=block_mask,
                                          bq=bq, bn=bn, sub=sub,
                                          interpret=interp)
    hist = hist[:Q]
    cum = jnp.cumsum(hist, axis=-1)
    # per-query candidate count: n_valid when unmasked, the enabled-row
    # count under a block mask — k_eff must follow it or candidates with
    # dist > 0 would be dropped whenever a query sees fewer than k rows
    k_eff = jnp.minimum(k_k, cum[:, -1])                             # (Q,)
    r_star = jnp.argmax(cum >= k_eff[:, None], axis=-1).astype(jnp.int32)
    gather = lambda c, i: jnp.take_along_axis(c, i[:, None], axis=-1)[:, 0]
    n_lt = jnp.where(r_star > 0, gather(cum, jnp.maximum(r_star - 1, 0)), 0)
    n_emit = jnp.minimum(gather(cum, r_star), k_eff)

    # pass 2: the reports — padded query rows get r*=-1 so they emit nothing
    q_pad = qp.shape[0] - Q
    r_p = jnp.pad(r_star, (0, q_pad), constant_values=-1)
    nlt_p = jnp.pad(n_lt, (0, q_pad))
    out_d, out_i = hamming_emit_pallas(qp, xp, r_p, nlt_p, bins, k_k, nv,
                                       block_min=block_min,
                                       block_mask=block_mask,
                                       bq=bq, bn=bn, sub=sub,
                                       interpret=interp)
    out_d, out_i = out_d[:Q], out_i[:Q]

    # untouched slots -> (bins, N) sentinels, then one O(k log k) sort per row
    live = jnp.arange(k_k, dtype=jnp.int32)[None, :] < n_emit[:, None]
    out_d = jnp.where(live, out_d, bins)
    out_i = jnp.where(live, out_i, N)
    out_d, out_i = jax.lax.sort_key_val(out_d, out_i, dimension=-1)
    if k_k < k:
        out_d = jnp.pad(out_d, ((0, 0), (0, k - k_k)), constant_values=bins)
        out_i = jnp.pad(out_i, ((0, 0), (0, k - k_k)), constant_values=N)
    if return_stats:
        # mirror the kernels' guards: pass 1 skips mask-disabled tiles;
        # pass 2 skips a tile iff it is disabled OR its min valid distance
        # exceeds every r* in its query block (disabled tiles summarize to
        # bins, so the bound alone would already skip them — keep the
        # explicit composition anyway, it is the contract)
        enabled = (jnp.ones_like(block_min) if block_mask is None
                   else block_mask.astype(jnp.int32)) != 0
        max_r_b = jnp.max(r_p.reshape(-1, bq), axis=1)        # (Q_pad/bq,)
        skipped = (~enabled) | (block_min > max_r_b[:, None])
        return out_d, out_i, {"blocks_total": int(block_min.size),
                              "blocks_skipped": jnp.sum(skipped),
                              "p1_blocks_skipped": jnp.sum(~enabled),
                              "block_min": block_min}
    return out_d, out_i


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    bq: int = 512, bk: int = 512) -> jax.Array:
    """Causal flash-attention forward. q: (B, S, H, hd); k, v: (B, S, KV, hd)
    -> (B, S, H, hd). Pads S to a block multiple (future positions are
    causally invisible); transposes to the kernel's (B, H, S, hd) layout."""
    B, S, H, hd = q.shape
    blk = max(bq, bk)
    s_pad = _round_up(S, blk)
    if s_pad != S:
        pz = lambda a: jnp.pad(a, ((0, 0), (0, s_pad - S), (0, 0), (0, 0)))
        q, k, v = pz(q), pz(k), pz(v)
    out = flash_attention_fwd(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), bq=min(bq, s_pad), bk=min(bk, s_pad),
        interpret=_interpret())
    return out.transpose(0, 2, 1, 3)[:, :S]


__all__ = ["flash_attention", "hamming_distance", "hamming_hist",
           "hamming_topk", "ref", "topk_geometry", "tuning"]
