"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) kernels execute with ``interpret=True`` — the kernel
body runs faithfully in Python/XLA for correctness validation; on TPU the
same calls compile to Mosaic. Shapes are padded to block multiples here so
the kernels stay assert-simple.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.hamming import hamming_distance_pallas
from repro.kernels.topk_select import hamming_hist_pallas


def _interpret() -> bool:
    return jax.default_backend() == "cpu"


def _round_up(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


def _pad_rows(a: jax.Array, target: int, fill: int = 0) -> jax.Array:
    pad = target - a.shape[0]
    if pad:
        a = jnp.pad(a, ((0, pad), (0, 0)), constant_values=fill)
    return a


def hamming_distance(q_packed: jax.Array, x_packed: jax.Array,
                     bq: int = 128, bn: int = 512) -> jax.Array:
    """(Q, W) x (N, W) packed -> (Q, N) int32 (Pallas on TPU, interpreted on
    CPU). Arbitrary Q/N; padding handled here."""
    Q, N = q_packed.shape[0], x_packed.shape[0]
    bq = min(bq, _round_up(Q, 8))
    bn = min(bn, _round_up(N, 128))
    qp = _pad_rows(q_packed, _round_up(Q, bq))
    xp = _pad_rows(x_packed, _round_up(N, bn))
    out = hamming_distance_pallas(qp, xp, bq=bq, bn=bn, interpret=_interpret())
    return out[:Q, :N]


def hamming_hist(q_packed: jax.Array, x_packed: jax.Array, bins: int,
                 bq: int = 64, bn: int = 1024, sub: int = 64) -> jax.Array:
    """Fused distance+histogram: (Q, W) x (N, W) -> (Q, bins) int32.

    Padded dataset rows are all-ones codes; their spurious counts in the
    clamp bin (bins-1) are subtracted before returning."""
    Q, N = q_packed.shape[0], x_packed.shape[0]
    bq = min(bq, _round_up(Q, 8))
    bn = min(bn, _round_up(N, sub))
    sub = min(sub, bn)
    qp = _pad_rows(q_packed, _round_up(Q, bq))
    n_padded = _round_up(N, bn)
    xp = _pad_rows(x_packed.astype(jnp.int32), n_padded, fill=-1)
    hist = hamming_hist_pallas(qp, xp, bins, bq=bq, bn=bn, sub=sub,
                               interpret=_interpret())
    hist = hist[:Q]
    if n_padded != N:
        # exact correction: subtract the pad rows' contribution (tiny block)
        hist = hist - ref.hamming_hist_ref(q_packed.astype(jnp.int32), xp[N:], bins)
    return hist


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    bq: int = 512, bk: int = 512) -> jax.Array:
    """Causal flash-attention forward. q: (B, S, H, hd); k, v: (B, S, KV, hd)
    -> (B, S, H, hd). Pads S to a block multiple (future positions are
    causally invisible); transposes to the kernel's (B, H, S, hd) layout."""
    B, S, H, hd = q.shape
    blk = max(bq, bk)
    s_pad = _round_up(S, blk)
    if s_pad != S:
        pz = lambda a: jnp.pad(a, ((0, 0), (0, s_pad - S), (0, 0), (0, 0)))
        q, k, v = pz(q), pz(k), pz(v)
    out = flash_attention_fwd(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), bq=min(bq, s_pad), bk=min(bk, s_pad),
        interpret=_interpret())
    return out.transpose(0, 2, 1, 3)[:, :S]


__all__ = ["flash_attention", "hamming_distance", "hamming_hist", "ref"]
