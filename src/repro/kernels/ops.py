"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) kernels execute with ``interpret=True`` — the kernel
body runs faithfully in Python/XLA for correctness validation; on TPU the
same calls compile to Mosaic. Shapes are padded to block multiples here so
the kernels stay assert-simple; padded dataset rows are masked exactly
inside the kernels by the ``n_valid`` scalar. Block shapes come from the
shared heuristic in kernels/tuning.py unless explicitly overridden.

``hamming_topk`` is the engine's single-shot fused select: one hist + one
emit ``pallas_call`` over the WHOLE datastore for any N, with the pass-1
block-min summary pruning pass-2 tiles that cannot hold a winner.

``hamming_topk_sharded`` is the same two-pass select distributed across a
device mesh (call it INSIDE ``shard_map``): the paper's counters are
additive partial histograms, so one ``psum`` of the tiny (Q, bins) counts
yields ONE global per-query radius r*, and each shard then emits its
winners into disjoint slots of the global (Q, k) output — no per-shard
top-k materialization, no host concat/sort merge.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref, tuning
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.hamming import hamming_distance_pallas
from repro.kernels.topk_select import hamming_emit_pallas, hamming_hist_pallas


def _interpret() -> bool:
    return jax.default_backend() == "cpu"


def _round_up(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


def _pad_rows(a: jax.Array, target: int, fill: int = 0) -> jax.Array:
    pad = target - a.shape[0]
    if pad:
        a = jnp.pad(a, ((0, pad), (0, 0)), constant_values=fill)
    return a


def hamming_distance(q_packed: jax.Array, x_packed: jax.Array,
                     bq: int | None = None,
                     bn: int | None = None) -> jax.Array:
    """(Q, W) x (N, W) packed -> (Q, N) int32 (Pallas on TPU, interpreted on
    CPU). Arbitrary Q/N; padding handled here."""
    Q, W = q_packed.shape
    N = x_packed.shape[0]
    hbq, hbn = tuning.distance_blocks(Q, N, W)
    bq, bn = bq or hbq, bn or hbn
    qp = _pad_rows(q_packed, _round_up(Q, bq))
    xp = _pad_rows(x_packed, _round_up(N, bn))
    out = hamming_distance_pallas(qp, xp, bq=bq, bn=bn, interpret=_interpret())
    return out[:Q, :N]


def topk_geometry(Q: int, N: int, W: int, lanes: int,
                  bq: int | None = None, bn: int | None = None,
                  sub: int | None = None, backend: str | None = None):
    """The padded grid geometry ``hamming_topk`` will run under:
    (bq, bn, sub, q_pad, n_pad). ``lanes = max(bins, min(k, N))``.

    Exposed so layout-aware callers (core/layout.py) can build a
    (q_pad//bq, n_pad//bn) block mask that tiles EXACTLY like the kernels —
    any drift between this and the internal prologue is a shape error, not
    a silent mis-mask. ``backend`` pins the heuristic to a named backend
    (planner/table introspection); None uses the runtime default."""
    hbq, hbn, hsub = tuning.topk_blocks(Q, N, W, lanes, backend=backend)
    bq, bn, sub = bq or hbq, bn or hbn, sub or hsub
    sub = min(sub, bn)
    return bq, bn, sub, _round_up(Q, bq), _round_up(N, bn)


def _topk_blocked(q_packed: jax.Array, x_packed: jax.Array, lanes: int,
                  bq: int | None, bn: int | None, sub: int | None):
    """Shared pad-to-blocks prologue for the two-pass kernels."""
    Q, W = q_packed.shape
    N = x_packed.shape[0]
    bq, bn, sub, q_pad, n_pad = topk_geometry(Q, N, W, lanes, bq, bn, sub)
    qp = _pad_rows(q_packed.astype(jnp.int32), q_pad)
    xp = _pad_rows(x_packed.astype(jnp.int32), n_pad)
    return qp, xp, bq, bn, sub


def hamming_hist(q_packed: jax.Array, x_packed: jax.Array, bins: int,
                 n_valid: jax.Array | int | None = None,
                 bq: int | None = None, bn: int | None = None,
                 sub: int | None = None) -> jax.Array:
    """Fused distance+histogram: (Q, W) x (N, W) -> (Q, bins) int32.

    Pass 1 of the two-pass counting select. Rows with global id >= n_valid
    (default: all N rows valid) — including the block-alignment padding added
    here — are masked exactly inside the kernel. (The kernel's second
    output, the block-min pruning summary, is an implementation detail of
    ``hamming_topk`` and is dropped here.)"""
    Q, N = q_packed.shape[0], x_packed.shape[0]
    qp, xp, bq, bn, sub = _topk_blocked(q_packed, x_packed, bins, bq, bn, sub)
    nv = jnp.asarray(N if n_valid is None else n_valid, jnp.int32)
    hist, _ = hamming_hist_pallas(qp, xp, bins, nv, bq=bq, bn=bn, sub=sub,
                                  interpret=_interpret())
    return hist[:Q]


def _radius_from_cum(cum: jax.Array, k_k: int):
    """The counting select's "finish line": from a cumulative histogram,
    the per-query effective k, k-th-smallest radius r*, strict-below count
    and emit count. ONE definition — the single-device and distributed
    selects must derive the radius identically or they diverge."""
    k_eff = jnp.minimum(k_k, cum[:, -1])                             # (Q,)
    r_star = jnp.argmax(cum >= k_eff[:, None], axis=-1).astype(jnp.int32)
    gather = lambda c, i: jnp.take_along_axis(c, i[:, None], axis=-1)[:, 0]
    n_lt = jnp.where(r_star > 0, gather(cum, jnp.maximum(r_star - 1, 0)), 0)
    n_emit = jnp.minimum(gather(cum, r_star), k_eff)
    return k_eff, r_star, n_lt, n_emit


def _tree_psum(x: jax.Array, axes, fanout: int) -> jax.Array:
    """Hierarchical all-reduce: a plain psum over the trailing (intra-host)
    axes, then rounds of ``fanout``-wide grouped psums over the leading
    axis. Integer addition is associative and commutative, so the result
    is bit-identical to ``jax.lax.psum(x, axes)`` — the tree only changes
    WHICH partial sums materialize: O(log_f S) rounds of f-wide group
    reductions instead of one S-wide reduction, the inter-host half of the
    hist_tree merge strategy.

    Round structure over the leading axis (size S): at stride s (starting
    1), indices {b + off + j*s : j < f} form one group — f representatives
    of f consecutive already-reduced spans — and exchange via f-1 rotation
    ``ppermute``s so after the round every index holds the sum of its span
    of s*f consecutive elements. Rounds run while s*f divides S; a final
    group round over the surviving S//s spans closes any
    non-power-of-``fanout`` remainder. (Rotation ppermutes rather than
    ``psum(axis_index_groups=...)`` because shard_map supports the
    former; the sums are identical either way.)"""
    axes = tuple(axes)
    if len(axes) > 1:
        x = jax.lax.psum(x, axes[1:])
    a = axes[0]
    size = jax.lax.psum(1, a)          # static: python int, the axis size

    def group_round(x, s, f):
        y = x
        for r in range(1, f):
            perm = [(b + off + j * s, b + off + ((j + r) % f) * s)
                    for b in range(0, size, s * f)
                    for off in range(s) for j in range(f)]
            y = y + jax.lax.ppermute(x, a, perm)
        return y

    s = 1
    while s * fanout <= size and size % (s * fanout) == 0:
        x = group_round(x, s, fanout)
        s *= fanout
    if s < size:
        x = group_round(x, s, size // s)
    return x


def _finalize_slots(out_d: jax.Array, out_i: jax.Array, n_emit: jax.Array,
                    k: int, k_k: int, bins: int, sentinel_id):
    """Slot-ordered emit output -> the select contract: untouched slots
    become (bins, sentinel_id), one O(k log k) sort per row orders the
    winners (stable: ties keep slot order), columns beyond k_k pad with
    the same sentinels. Shared by the local and distributed epilogues."""
    Q = out_d.shape[0]
    live = jnp.arange(k_k, dtype=jnp.int32)[None, :] < n_emit[:, None]
    out_d = jnp.where(live, out_d, bins)
    out_i = jnp.where(live, out_i, sentinel_id)
    out_d, out_i = jax.lax.sort_key_val(out_d, out_i, dimension=-1)
    if k_k < k:
        out_d = jnp.concatenate(
            [out_d, jnp.full((Q, k - k_k), bins, jnp.int32)], axis=1)
        out_i = jnp.concatenate(
            [out_i, jnp.broadcast_to(jnp.asarray(sentinel_id, jnp.int32),
                                     (Q, k - k_k))], axis=1)
    return out_d, out_i


def hamming_topk(q_packed: jax.Array, x_packed: jax.Array, k: int, bins: int,
                 n_valid: jax.Array | int | None = None,
                 block_mask: jax.Array | None = None,
                 bq: int | None = None, bn: int | None = None,
                 sub: int | None = None, return_stats: bool = False):
    """Single-shot fused two-pass top-k over the WHOLE datastore:
    (Q, W) x (N, W) -> (dists (Q, k), ids (Q, k)).

    The engine's high-throughput select, one hist + one emit ``pallas_call``
    for any N (the Pallas grid streams the N dimension; arbitrary N is
    padded to a block multiple here and masked exactly in-kernel): pass 1
    histograms distances into [0, bins) (clamped at bins-1; pass bins > max
    distance for exactness) and emits the (Q/bq, N/bn) block-min pruning
    summary, pass 2 re-streams the codes and emits the winners, skipping
    every (query-block, data-block) tile whose summary proves it holds no
    winner. Only (Q, bins), the tiny summary, and (Q, k) ever leave the
    kernels — the (Q, N) distance matrix is never materialized. Semantics
    match ``topk.counting_topk`` on the clamped distances: ascending, ties
    broken by index order, rows beyond min(k, n_valid) padded with
    (bins, N). Rows with global id >= n_valid are excluded exactly.

    ``block_mask``: optional (q_pad//bq, n_pad//bn) int32 enable mask over
    the grid tiles (geometry from ``topk_geometry``): a zero tile is
    outside the candidate set — pass 1 skips it outright and every query's
    top-k is taken over the enabled rows only, the index-probing contract
    of core/layout.py. Queries whose candidate count falls below k get
    (bins, N) sentinels in the surplus slots, exactly like n_valid < k.

    ``return_stats=True`` additionally returns a dict with the pruning
    telemetry: ``blocks_total`` (python int, grid tiles per pass),
    ``p1_blocks_skipped`` (traced int32, tiles the enable mask excluded
    from pass 1), ``blocks_skipped`` (traced int32, tiles pass 2 pruned —
    mask composed with the block-min guard; padding-only tiles included,
    they always prune), and ``block_min`` (the summary itself).
    """
    Q, N = q_packed.shape[0], x_packed.shape[0]
    k_k = min(k, N)
    if k_k == 0:
        out = (jnp.full((Q, k), bins, jnp.int32),
               jnp.full((Q, k), N, jnp.int32))
        if return_stats:
            return out + ({"blocks_total": 0,
                           "blocks_skipped": jnp.int32(0),
                           "p1_blocks_skipped": jnp.int32(0),
                           "block_min": jnp.zeros((0, 0), jnp.int32)},)
        return out
    qp, xp, bq, bn, sub = _topk_blocked(q_packed, x_packed,
                                        max(bins, k_k), bq, bn, sub)
    nv = jnp.asarray(N if n_valid is None else n_valid, jnp.int32)
    interp = _interpret()

    # pass 1: the race -> per-query radius r*, the counts below it, and the
    # block-min summary pass 2 prunes with
    hist, block_min = hamming_hist_pallas(qp, xp, bins, nv,
                                          block_mask=block_mask,
                                          bq=bq, bn=bn, sub=sub,
                                          interpret=interp)
    hist = hist[:Q]
    cum = jnp.cumsum(hist, axis=-1)
    # per-query candidate count: n_valid when unmasked, the enabled-row
    # count under a block mask — k_eff must follow it or candidates with
    # dist > 0 would be dropped whenever a query sees fewer than k rows
    _, r_star, n_lt, n_emit = _radius_from_cum(cum, k_k)

    # pass 2: the reports — padded query rows get r*=-1 so they emit nothing
    q_pad = qp.shape[0] - Q
    r_p = jnp.pad(r_star, (0, q_pad), constant_values=-1)
    nlt_p = jnp.pad(n_lt, (0, q_pad))
    out_d, out_i = hamming_emit_pallas(qp, xp, r_p, nlt_p, bins, k_k, nv,
                                       block_min=block_min,
                                       block_mask=block_mask,
                                       bq=bq, bn=bn, sub=sub,
                                       interpret=interp)
    out_d, out_i = out_d[:Q], out_i[:Q]

    # untouched slots -> (bins, N) sentinels, then one O(k log k) sort per row
    out_d, out_i = _finalize_slots(out_d, out_i, n_emit, k, k_k, bins, N)
    if return_stats:
        # mirror the kernels' guards: pass 1 skips mask-disabled tiles;
        # pass 2 skips a tile iff it is disabled OR its min valid distance
        # exceeds every r* in its query block (disabled tiles summarize to
        # bins, so the bound alone would already skip them — keep the
        # explicit composition anyway, it is the contract)
        enabled = (jnp.ones_like(block_min) if block_mask is None
                   else block_mask.astype(jnp.int32)) != 0
        max_r_b = jnp.max(r_p.reshape(-1, bq), axis=1)        # (Q_pad/bq,)
        skipped = (~enabled) | (block_min > max_r_b[:, None])
        return out_d, out_i, {"blocks_total": int(block_min.size),
                              "blocks_skipped": jnp.sum(skipped),
                              "p1_blocks_skipped": jnp.sum(~enabled),
                              "block_min": block_min}
    return out_d, out_i


def hamming_topk_sharded(q_packed: jax.Array, x_local: jax.Array, k: int,
                         bins: int, axis_names, *, n_shards: int,
                         n_valid: jax.Array | None = None,
                         id_base: jax.Array | None = None,
                         n_total: jax.Array | int | None = None,
                         perm: jax.Array | None = None,
                         block_mask: jax.Array | None = None,
                         participate: jax.Array | None = None,
                         tree_fanout: int = 0,
                         bq: int | None = None, bn: int | None = None,
                         sub: int | None = None):
    """Distributed counting select — the sharded fused top-k WITHOUT a
    concat/sort merge. Call INSIDE ``shard_map``; collectives run over
    ``axis_names`` (``n_shards`` = product of their sizes).

    q: (Q, W) replicated; x_local: (n_loc, W), this shard's slice. The
    result (dists (Q, k), ids (Q, k)) is replicated and bit-identical to
    ``hamming_topk`` over the concatenation of every shard's valid rows
    (under ``perm`` the DISTANCES keep that guarantee but ties at the r*
    cut are picked in layout-position order — the same report-order
    freedom every layout-streaming path has, core/layout.py):

    1. each shard runs pass 1 over its slice — its (Q, bins) histogram is
       a PARTIAL histogram of the global race (counters are additive);
    2. one ``psum`` merges them; the global r*, below-count n_lt and
       emit count derive exactly as in the single-device select;
    3. each shard derives its own below-r*/tie counts from its LOCAL
       histogram; one tiny (Q, 2)-per-shard all-gather turns them into
       exclusive-scan slot bases, so every shard owns a disjoint slice of
       the global (Q, k) slot space (without ``perm``, ids stay in global
       index order — shard slices are contiguous id ranges — so tie
       semantics match the single-device kernel bit-for-bit, including
       the first-(k - n_lt) global tie cut; with ``perm``, in-shard tie
       order follows layout positions instead);
    4. each shard runs pass 2 locally (block-min pruning and the enable
       mask compose as usual) with ``slot_base``/``id_base`` from step 3,
       and a final ``psum`` assembles the disjoint slots.

    Cross-device traffic is O(Q·bins) histogram counts + O(Q·n_shards)
    base counts + the O(Q·k) output — never O(n_shards·Q·k) candidates.

    ``n_valid``: this shard's valid-row count (rows beyond it are padding;
    uneven shards pad to a common n_loc). ``id_base``/``n_total``: this
    shard's exclusive prefix of valid rows and the global valid total —
    derived via a scalar all-gather when None (even shards need neither:
    they default to shard_index * n_loc and n_shards * n_loc). ``perm``:
    (n_loc,) local layout permutation (``layout.local_sort``) — winners
    are emitted as layout positions and mapped back to local ids on this
    shard's owned slots before the output psum. ``block_mask``: this
    shard's (Q_pad/bq, n_loc_pad/bn) enable mask (core/layout.py
    semantics; r* then derives from the globally-merged MASKED histogram).

    ``participate``: optional (n_shards,) replicated 0/1 mask in flat-shard
    order — the fault-tolerance hook. A shard with participate == 0 (dead)
    contributes NO rows: its n_valid is zeroed, and id bases / n_total
    derive from the exclusive scan of the MASKED per-shard counts, so ids
    renumber exactly as a store rebuilt from only the surviving shards'
    rows. The result is therefore bit-identical (dists AND ids, including
    tie cuts and the all-dead n_total == 0 edge) to ``hamming_topk`` over
    that surviving-rows store. Do not combine with explicit ``id_base`` /
    ``n_total`` unless they already account for the mask.

    ``tree_fanout``: 0 (default) reduces histograms and outputs with one
    flat psum (strategy "hist_merge"); >= 2 switches both to the
    hierarchical ``_tree_psum`` schedule (strategy "hist_tree") —
    bit-identical results, tree-shaped traffic.
    """
    axes = tuple(axis_names)
    Q, W = q_packed.shape
    n_loc = x_local.shape[0]
    k_k = min(k, n_shards * n_loc)
    if k_k == 0:
        return (jnp.full((Q, k), bins, jnp.int32),
                jnp.full((Q, k), 0, jnp.int32))

    # flat shard index over the collective axes (row-major, like the mesh)
    flat = jnp.zeros((), jnp.int32)
    for a in axes:
        flat = flat * jax.lax.psum(jnp.int32(1), a) + jax.lax.axis_index(a)

    part = None
    if participate is not None:
        part = jnp.asarray(participate, jnp.int32).reshape(n_shards)
    if n_valid is None:
        if part is None:
            nv = jnp.int32(n_loc)
            ib = ((flat * n_loc).astype(jnp.int32)
                  if id_base is None else id_base)
            nt = n_shards * n_loc if n_total is None else n_total
        else:
            # participation is replicated, so the masked per-shard counts —
            # and their exclusive scan — need no gather at all
            nv_all = part * jnp.int32(n_loc)                   # (n_shards,)
            nv = nv_all[flat]
            csum = jnp.cumsum(nv_all)
            ib = csum[flat] - nv_all[flat] if id_base is None else id_base
            nt = csum[-1] if n_total is None else n_total
    else:
        nv = jnp.asarray(n_valid, jnp.int32).reshape(())
        if part is not None:
            nv = nv * part[flat]
        ib, nt = id_base, n_total
        if ib is None or nt is None:
            nv_all = jax.lax.all_gather(nv, axes, tiled=False)
            nv_all = nv_all.reshape(n_shards)
            csum = jnp.cumsum(nv_all)
            ib = csum[flat] - nv_all[flat] if ib is None else ib
            nt = csum[-1] if nt is None else nt
    ib = jnp.asarray(ib, jnp.int32)
    nt = jnp.asarray(nt, jnp.int32)
    psum = ((lambda v: _tree_psum(v, axes, tree_fanout))
            if tree_fanout >= 2 else (lambda v: jax.lax.psum(v, axes)))

    qp, xp, bq, bn, sub = _topk_blocked(q_packed, x_local,
                                        max(bins, k_k), bq, bn, sub)
    interp = _interpret()

    # pass 1 locally, then merge the partial histograms: ONE global race
    hist, block_min = hamming_hist_pallas(qp, xp, bins, nv,
                                          block_mask=block_mask,
                                          bq=bq, bn=bn, sub=sub,
                                          interpret=interp)
    hist_loc = hist[:Q]
    hist_glob = psum(hist_loc)
    cum_g = jnp.cumsum(hist_glob, axis=-1)
    gather = lambda c, i: jnp.take_along_axis(c, i[:, None], axis=-1)[:, 0]
    _, r_star, n_lt, n_emit = _radius_from_cum(cum_g, k_k)

    # per-shard below-r*/tie counts from the LOCAL histogram; exclusive
    # scan over the shard order = global-index-order slot bases
    cum_l = jnp.cumsum(hist_loc, axis=-1)
    l_lt = jnp.where(r_star > 0, gather(cum_l, jnp.maximum(r_star - 1, 0)), 0)
    l_tie = gather(hist_loc, r_star)
    counts = jnp.stack([l_lt, l_tie], axis=-1)                       # (Q, 2)
    g_counts = jax.lax.all_gather(counts, axes, tiled=False)
    g_counts = g_counts.reshape(n_shards, Q, 2)
    before = (jnp.arange(n_shards, dtype=jnp.int32) < flat)[:, None]
    base_lt = jnp.sum(jnp.where(before, g_counts[:, :, 0], 0), axis=0)
    base_tie = n_lt + jnp.sum(jnp.where(before, g_counts[:, :, 1], 0), axis=0)

    # pass 2 locally: this shard's winners scatter straight into its
    # disjoint global slots (padded query rows carry r* = -1: no emission)
    q_pad = qp.shape[0] - Q
    r_p = jnp.pad(r_star, (0, q_pad), constant_values=-1)
    sb_p = jnp.pad(base_lt, (0, q_pad))
    tb_p = jnp.pad(base_tie, (0, q_pad))
    od, oi = hamming_emit_pallas(qp, xp, r_p, tb_p, bins, k_k, nv,
                                 block_min=block_min, block_mask=block_mask,
                                 slot_base=sb_p,
                                 id_base=None if perm is not None else ib,
                                 bq=bq, bn=bn, sub=sub, interpret=interp)
    od, oi = od[:Q], oi[:Q]
    if perm is not None:
        # winners were emitted as layout positions: map them back to local
        # ids on the slots THIS shard owns, zero elsewhere, so the psum
        # below still assembles disjoint ranges
        iota = jnp.arange(k_k, dtype=jnp.int32)[None, :]
        owned = (((iota >= base_lt[:, None])
                  & (iota < (base_lt + l_lt)[:, None]))
                 | ((iota >= base_tie[:, None])
                    & (iota < (base_tie + l_tie)[:, None])))
        perm = jnp.asarray(perm, jnp.int32)
        mapped = perm[jnp.minimum(oi, n_loc - 1)] + ib
        oi = jnp.where(owned, mapped, 0)
        od = jnp.where(owned, od, 0)

    od = psum(od)
    oi = psum(oi)

    # untouched slots -> (bins, n_total) sentinels, one O(k log k) sort
    return _finalize_slots(od, oi, n_emit, k, k_k, bins, nt)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    bq: int = 512, bk: int = 512) -> jax.Array:
    """Causal flash-attention forward. q: (B, S, H, hd); k, v: (B, S, KV, hd)
    -> (B, S, H, hd). Pads S to a block multiple (future positions are
    causally invisible); transposes to the kernel's (B, H, S, hd) layout."""
    B, S, H, hd = q.shape
    blk = max(bq, bk)
    s_pad = _round_up(S, blk)
    if s_pad != S:
        pz = lambda a: jnp.pad(a, ((0, 0), (0, s_pad - S), (0, 0), (0, 0)))
        q, k, v = pz(q), pz(k), pz(v)
    out = flash_attention_fwd(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), bq=min(bq, s_pad), bk=min(bk, s_pad),
        interpret=_interpret())
    return out.transpose(0, 2, 1, 3)[:, :S]


__all__ = ["flash_attention", "hamming_distance", "hamming_hist",
           "hamming_topk", "hamming_topk_sharded", "ref", "topk_geometry",
           "tuning"]
