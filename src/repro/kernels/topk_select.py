"""Pallas TPU kernels: the fused two-pass counting select (temporal sort).

The paper's AP engine never materializes distances: inverted-Hamming
counters race toward a threshold and nearer vectors *report earlier*, so the
sort is a counting process over the bounded domain [0, d]. These two kernels
are that pipeline on TPU — the (Q, N) distance matrix never exists in HBM:

* **pass 1** (``hamming_hist_pallas``, the "race"): stream (BN, W) code
  tiles HBM->VMEM, XOR+popcount against the query tile, and accumulate a
  per-query distance histogram. Only (Q, bins) counts leave the kernel —
  the same reduction the AP performs by keeping counters next to the
  Hamming macros.
* **pass 2** (``hamming_emit_pallas``, the "reports"): re-stream the SAME
  tiles, recompute distances in VMEM (recompute is ~free; the scan is
  bandwidth-bound), and scatter the winners straight into their output
  slot: ids with dist < r* in index order first, then dist == r* ties in
  index order, where r* is the per-query k-th-smallest radius derived from
  the pass-1 histogram. Only (Q, k) ids/dists leave the kernel.

HBM traffic drops from O(Q*N*4) bytes of distances to O(Q*(bins+k)) — the
codes themselves are read twice, which for W words of codes vs N ints of
distances is a win whenever 2*W < 4*Q words, i.e. always for batched queries.

Both kernels take the valid-row count ``n_valid`` as a scalar (SMEM) so
padded dataset rows — block-alignment padding here, chunk padding in the
engine's scan — are masked exactly, by global row id, inside the kernel.

Grid is (Q/BQ, N/BN) with the N dimension innermost; output tiles map to
the same block for every j and are revisited: initialized at j == 0,
accumulated thereafter. Running per-query emit counts for pass 2 are carried
across j in a VMEM scratch. The (BQ, sub, lanes) one-hot intermediates are
kept small by an inner fori over BN/sub sub-tiles (block shapes from
kernels/tuning.py).

The grid owns the WHOLE datastore in one invocation (kernels/ops.py pads N
to a block multiple; the engine no longer chunk-scans this path), which
enables **block-min pruning**: pass 1 additionally emits a tiny
(Q/BQ, N/BN) int32 summary — the minimum valid distance in each
(query-block, data-block) tile. Pass 2 compares each tile's summary entry
against the widest winning radius max(r*) of its query block and wraps the
entire recompute+emit body in ``pl.when(block_min <= max(r*))``: a tile
that provably holds no winner costs one SMEM scalar compare instead of a
re-streamed XOR/popcount/scatter. On clustered or sorted datastores most
pass-2 tiles skip. Skipping is exact — the emit counters only ever advance
on winners, so an all-loser tile leaves every carried count and output slot
untouched.

Both kernels additionally take a per-(query-block, data-block) **enable
mask** of the same (Q/BQ, N/BN) shape (one SMEM scalar per tile, all-ones
when the caller passes none). A disabled tile is *outside the candidate
set* — the index-probing contract of core/layout.py: pass 1 skips it
outright (it contributes nothing to any histogram and summarizes to
``bins``, so every query's r* is computed over the enabled rows only),
and pass 2 composes the mask with the block-min bound. Because r* derives
from the masked histogram, skipping disabled tiles in pass 2 is exact in
the same sense as the block-min skip: no enabled (q, x) pair is ever
dropped, disabled pairs were never candidates.

The emit pass finally takes two **sharding hooks** — the paper's counters
are additive partial histograms, so the same two kernels serve the
distributed counting select (kernels/ops.py::hamming_topk_sharded) when a
datastore spans several devices: ``slot_base`` (per-query initial value of
the carried below-r* emit counter — this shard's exclusive-scan base into
the global (Q, k) output) and ``id_base`` (a scalar added to every emitted
row id, so winners leave the kernel carrying GLOBAL ids while untouched
slots stay zero and a cross-device ``psum`` assembles the disjoint slot
ranges without any gather/sort of candidates). Both default to zero, which
is exactly the single-device behaviour.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _tile_dist(q, xs, bins: int):
    """(BQ, W) x (sub, W) int32 packed -> (BQ, sub) clamped distances."""
    xor = jax.lax.bitwise_xor(q[:, None, :], xs[None, :, :])
    dist = jnp.sum(jax.lax.population_count(xor).astype(jnp.int32), axis=-1)
    return jnp.minimum(dist, bins - 1)


# ---------------------------------------------------------------------------
# pass 1: fused distance + histogram (the "race")
# ---------------------------------------------------------------------------

def _hist_kernel(nv_ref, en_ref, q_ref, x_ref, hist_ref, bmin_ref, *,
                 bins: int, sub: int, bn: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        hist_ref[...] = jnp.zeros_like(hist_ref)

    # a disabled tile is outside the candidate set: it contributes nothing
    # to the histogram and summarizes to bins, so pass 2 skips it too
    bmin_ref[0, 0] = jnp.int32(bins)

    @pl.when(en_ref[0, 0] != 0)
    def _work():
        n_valid = nv_ref[0]
        q = q_ref[...]                              # (BQ, W)
        x = x_ref[...]                              # (BN, W)
        bq = q.shape[0]
        bin_iota = jax.lax.broadcasted_iota(jnp.int32, (1, 1, bins), 2)
        base = j * bn

        def body(s, carry):
            acc, bmin = carry
            xs = jax.lax.dynamic_slice_in_dim(x, s * sub, sub, axis=0)
            dist = _tile_dist(q, xs, bins)
            gid = base + s * sub + jax.lax.broadcasted_iota(
                jnp.int32, (1, sub), 1)
            valid = gid < n_valid                                  # (1, sub)
            onehot = (dist[:, :, None] == bin_iota) & valid[:, :, None]
            acc = acc + jnp.sum(onehot.astype(jnp.int32), axis=1)
            # invalid (padding) rows report bins: a fully-padded tile
            # summarizes to bins > any possible r*, so pass 2 always skips it
            bmin = jnp.minimum(bmin, jnp.min(jnp.where(valid, dist, bins)))
            return acc, bmin

        acc, bmin = jax.lax.fori_loop(
            0, bn // sub, body,
            (jnp.zeros((bq, bins), jnp.int32), jnp.int32(bins)))
        hist_ref[...] += acc
        bmin_ref[0, 0] = bmin


@functools.partial(jax.jit, static_argnames=("bins", "bq", "bn", "sub",
                                             "interpret"))
def hamming_hist_pallas(q_packed: jax.Array, x_packed: jax.Array, bins: int,
                        n_valid: jax.Array | None = None,
                        block_mask: jax.Array | None = None,
                        bq: int = 64, bn: int = 1024, sub: int = 64,
                        interpret: bool = False):
    """q: (Q, W), x: (N, W) -> (hist (Q, bins) int32,
    block_min (Q/bq, N/bn) int32).

    ``hist`` is the per-query distance histogram; ``block_min`` is the
    minimum valid distance within each (query-block, data-block) grid tile
    (bins where a tile holds no valid row) — the pruning summary pass 2
    consumes. Rows with global id >= n_valid (default N) are excluded
    exactly from both outputs. ``block_mask``: (Q/bq, N/bn) int32 enable
    mask (None = all tiles enabled); a zero tile is skipped outright — its
    rows are outside the candidate set, so they are excluded from the
    histogram and its summary entry is bins."""
    Q, W = q_packed.shape
    N, _ = x_packed.shape
    bq, bn = min(bq, Q), min(bn, N)
    sub = min(sub, bn)
    assert Q % bq == 0 and N % bn == 0 and bn % sub == 0, (Q, N, bq, bn, sub)
    q32 = q_packed.astype(jnp.int32) if q_packed.dtype != jnp.int32 else q_packed
    x32 = x_packed.astype(jnp.int32) if x_packed.dtype != jnp.int32 else x_packed
    nv = jnp.full((1,), N, jnp.int32) if n_valid is None else (
        jnp.asarray(n_valid, jnp.int32).reshape(1))
    en = (jnp.ones((Q // bq, N // bn), jnp.int32) if block_mask is None
          else block_mask.astype(jnp.int32))
    assert en.shape == (Q // bq, N // bn), (en.shape, Q // bq, N // bn)

    grid = (Q // bq, N // bn)
    return pl.pallas_call(
        functools.partial(_hist_kernel, bins=bins, sub=sub, bn=bn),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1), lambda i, j: (i, j),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((bq, W), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, W), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bq, bins), lambda i, j: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j),
                         memory_space=pltpu.SMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Q, bins), jnp.int32),
            jax.ShapeDtypeStruct((Q // bq, N // bn), jnp.int32),
        ],
        interpret=interpret,
    )(nv, en, q32, x32)


# ---------------------------------------------------------------------------
# pass 2: re-stream + emit winners (the "reports")
# ---------------------------------------------------------------------------

def _emit_kernel(nv_ref, ib_ref, en_ref, bm_ref, q_ref, x_ref, r_ref,
                 nlt_ref, sb_ref, outd_ref, outi_ref, cnt_ref, *, bins: int,
                 k: int, sub: int, bn: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        outd_ref[...] = jnp.zeros_like(outd_ref)
        outi_ref[...] = jnp.zeros_like(outi_ref)
        # the carried below-r* counter starts at this shard's slot base
        # (zero single-device): emitted winners land in [base, base+n_lt_loc)
        cnt_ref[:, 0:1] = sb_ref[...]
        cnt_ref[:, 1:2] = jnp.zeros_like(cnt_ref[:, 1:2])

    r_star = r_ref[...]                             # (BQ, 1)

    # block-min pruning composed with the enable mask: if the tile is
    # outside the candidate set, or the nearest valid row in it is farther
    # than the widest winning radius of any query in the block, no (q, x)
    # pair here can emit — skip the re-stream entirely. Padded query rows
    # carry r* = -1 and never raise the bound; skipping leaves the carried
    # emit counts and all output slots untouched, so the skip is exact.
    @pl.when((en_ref[0, 0] != 0) & (bm_ref[0, 0] <= jnp.max(r_star)))
    def _work():
        n_valid = nv_ref[0]
        id_base = ib_ref[0]
        q = q_ref[...]                              # (BQ, W)
        x = x_ref[...]                              # (BN, W)
        n_lt_total = nlt_ref[...]                   # (BQ, 1)
        bq = q.shape[0]
        slot_iota = jax.lax.broadcasted_iota(jnp.int32, (1, 1, k), 2)
        base = j * bn

        def body(s, carry):
            cnt_lt, cnt_tie, od, oi = carry
            xs = jax.lax.dynamic_slice_in_dim(x, s * sub, sub, axis=0)
            dist = _tile_dist(q, xs, bins)                         # (BQ, sub)
            gid = base + s * sub + jax.lax.broadcasted_iota(
                jnp.int32, (1, sub), 1)
            valid = gid < n_valid                                  # (1, sub)
            is_lt = valid & (dist < r_star)
            is_tie = valid & (dist == r_star)
            # slot of each winner: ids with dist < r* pack first (their
            # global count is < k by construction of r*), r*-ties fill the
            # remainder in index order; overflow ties land at slot k and
            # match no output lane
            rank_lt = cnt_lt + jnp.cumsum(is_lt.astype(jnp.int32), axis=1) - 1
            rank_tie = (n_lt_total + cnt_tie
                        + jnp.cumsum(is_tie.astype(jnp.int32), axis=1) - 1)
            slot = jnp.where(is_lt, rank_lt, jnp.where(is_tie, rank_tie, k))
            slot = jnp.minimum(slot, k)
            onehot = (slot[:, :, None] == slot_iota).astype(jnp.int32)
            od = od + jnp.sum(onehot * dist[:, :, None], axis=1)
            oi = oi + jnp.sum(onehot * (gid + id_base)[:, :, None], axis=1)
            cnt_lt = cnt_lt + jnp.sum(is_lt.astype(jnp.int32), axis=1,
                                      keepdims=True)
            cnt_tie = cnt_tie + jnp.sum(is_tie.astype(jnp.int32), axis=1,
                                        keepdims=True)
            return cnt_lt, cnt_tie, od, oi

        init = (cnt_ref[:, 0:1], cnt_ref[:, 1:2],
                jnp.zeros((bq, k), jnp.int32), jnp.zeros((bq, k), jnp.int32))
        cnt_lt, cnt_tie, od, oi = jax.lax.fori_loop(0, bn // sub, body, init)
        outd_ref[...] += od
        outi_ref[...] += oi
        cnt_ref[:, 0:1] = cnt_lt
        cnt_ref[:, 1:2] = cnt_tie


@functools.partial(jax.jit, static_argnames=("bins", "k", "bq", "bn", "sub",
                                             "interpret"))
def hamming_emit_pallas(q_packed: jax.Array, x_packed: jax.Array,
                        r_star: jax.Array, n_lt: jax.Array, bins: int, k: int,
                        n_valid: jax.Array | None = None,
                        block_min: jax.Array | None = None,
                        block_mask: jax.Array | None = None,
                        slot_base: jax.Array | None = None,
                        id_base: jax.Array | None = None,
                        bq: int = 64, bn: int = 1024, sub: int = 64,
                        interpret: bool = False):
    """Emit the top-k winners given the pass-1 radius.

    q: (Q, W), x: (N, W); r_star/n_lt: (Q,) int32 — per-query k-th-smallest
    radius and count of rows with dist < r* (both from the pass-1 histogram).
    ``block_min``: the (Q/bq, N/bn) int32 pruning summary from
    ``hamming_hist_pallas`` — tiles whose min distance exceeds every r* in
    their query block are skipped without recomputing a single distance.
    None disables pruning (an all-zeros summary: every tile runs).
    ``block_mask``: the same enable mask pass 1 ran under (None = all
    enabled) — disabled tiles are outside the candidate set and never
    emit. The two guards compose; pass the SAME mask to both passes.

    Sharding hooks (ops.py::hamming_topk_sharded): ``slot_base`` (Q,) int32
    is the initial value of the carried below-r* counter — this shard's
    exclusive-scan base into the global slot space (None = zeros); on the
    distributed path ``n_lt`` likewise carries the shard's TIE slot base
    (global n_lt plus the tie exclusive scan) rather than the raw global
    count. ``id_base`` is a scalar added to every emitted row id (None = 0)
    so winners leave with global ids while untouched slots stay zero.

    Returns (dists (Q, k), ids (Q, k)) int32, slot-ordered (NOT distance
    sorted): slots [0, n_lt) hold dist < r* rows in index order, subsequent
    slots hold r*-ties in index order; untouched slots are 0 — the caller
    masks slots >= n_emitted and sorts (kernels/ops.py::hamming_topk)."""
    Q, W = q_packed.shape
    N, _ = x_packed.shape
    bq, bn = min(bq, Q), min(bn, N)
    sub = min(sub, bn)
    assert Q % bq == 0 and N % bn == 0 and bn % sub == 0, (Q, N, bq, bn, sub)
    q32 = q_packed.astype(jnp.int32) if q_packed.dtype != jnp.int32 else q_packed
    x32 = x_packed.astype(jnp.int32) if x_packed.dtype != jnp.int32 else x_packed
    nv = jnp.full((1,), N, jnp.int32) if n_valid is None else (
        jnp.asarray(n_valid, jnp.int32).reshape(1))
    ib = (jnp.zeros((1,), jnp.int32) if id_base is None
          else jnp.asarray(id_base, jnp.int32).reshape(1))
    bm = (jnp.zeros((Q // bq, N // bn), jnp.int32) if block_min is None
          else block_min.astype(jnp.int32))
    assert bm.shape == (Q // bq, N // bn), (bm.shape, Q // bq, N // bn)
    en = (jnp.ones((Q // bq, N // bn), jnp.int32) if block_mask is None
          else block_mask.astype(jnp.int32))
    assert en.shape == (Q // bq, N // bn), (en.shape, Q // bq, N // bn)
    r2 = r_star.astype(jnp.int32).reshape(Q, 1)
    nlt2 = n_lt.astype(jnp.int32).reshape(Q, 1)
    sb2 = (jnp.zeros((Q, 1), jnp.int32) if slot_base is None
           else slot_base.astype(jnp.int32).reshape(Q, 1))

    grid = (Q // bq, N // bn)
    return pl.pallas_call(
        functools.partial(_emit_kernel, bins=bins, k=k, sub=sub, bn=bn),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1), lambda i, j: (i, j),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1), lambda i, j: (i, j),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((bq, W), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, W), lambda i, j: (j, 0)),
            pl.BlockSpec((bq, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, 1), lambda i, j: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bq, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Q, k), jnp.int32),
            jax.ShapeDtypeStruct((Q, k), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((bq, 2), jnp.int32)],
        interpret=interpret,
    )(nv, ib, en, bm, q32, x32, r2, nlt2, sb2)
