"""Pallas TPU kernel: fused Hamming-distance + bounded-domain histogram.

Pass 1 of the two-pass counting select (the temporal sort's "race"): for
each query, count how many dataset codes land at each distance in [0, bins).
Fusing the XOR/popcount with the histogram means the (Q, N) distance matrix
never exists in HBM — only the (Q, bins) counts leave the kernel, the same
reduction the AP performs by keeping counters next to the Hamming macros.

Grid is (Q/BQ, N/BN); the output tile is revisited across the N dimension
(same index_map block for every j) and accumulated in VMEM — initialize at
j == 0, add thereafter. The (BQ, sub, bins) one-hot intermediate is kept
small by an inner fori over BN/sub sub-tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hist_kernel(q_ref, x_ref, hist_ref, *, bins: int, sub: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        hist_ref[...] = jnp.zeros_like(hist_ref)

    q = q_ref[...]                                  # (BQ, W)
    x = x_ref[...]                                  # (BN, W)
    bn = x.shape[0]
    bq = q.shape[0]
    bin_iota = jax.lax.broadcasted_iota(jnp.int32, (1, 1, bins), 2)

    def body(s, acc):
        xs = jax.lax.dynamic_slice_in_dim(x, s * sub, sub, axis=0)
        xor = jax.lax.bitwise_xor(q[:, None, :], xs[None, :, :])
        dist = jnp.sum(jax.lax.population_count(xor).astype(jnp.int32), axis=-1)
        dist = jnp.minimum(dist, bins - 1)
        onehot = (dist[:, :, None] == bin_iota).astype(jnp.int32)  # (BQ,sub,bins)
        return acc + jnp.sum(onehot, axis=1)

    acc = jax.lax.fori_loop(0, bn // sub, body,
                            jnp.zeros((bq, bins), jnp.int32))
    hist_ref[...] += acc


@functools.partial(jax.jit, static_argnames=("bins", "bq", "bn", "sub", "interpret"))
def hamming_hist_pallas(q_packed: jax.Array, x_packed: jax.Array, bins: int,
                        bq: int = 64, bn: int = 1024, sub: int = 64,
                        interpret: bool = False) -> jax.Array:
    """q: (Q, W), x: (N, W) -> (Q, bins) int32 distance histogram."""
    Q, W = q_packed.shape
    N, _ = x_packed.shape
    bq, bn = min(bq, Q), min(bn, N)
    sub = min(sub, bn)
    assert Q % bq == 0 and N % bn == 0 and bn % sub == 0, (Q, N, bq, bn, sub)
    q32 = q_packed.astype(jnp.int32) if q_packed.dtype != jnp.int32 else q_packed
    x32 = x_packed.astype(jnp.int32) if x_packed.dtype != jnp.int32 else x_packed

    grid = (Q // bq, N // bn)
    return pl.pallas_call(
        functools.partial(_hist_kernel, bins=bins, sub=sub),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, W), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, W), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bq, bins), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Q, bins), jnp.int32),
        interpret=interpret,
    )(q32, x32)
