"""Pallas TPU kernel: causal flash-attention forward (GQA).

The §Perf analysis (EXPERIMENTS.md) shows prefill is bound by the
probability-tensor HBM round trips of the XLA blockwise path. This kernel
keeps the (bq, bk) score/probability tiles in VMEM: HBM traffic collapses to
q + o + the S/bq-fold streaming re-read of k/v — the classic flash trade.

Layout: q (B, H, S, hd); k, v (B, KV, S, hd); grid (B, H, nq, nk) with the
output block revisited along nk and the online-softmax state (acc, m, l)
carried in VMEM scratch. Causal blocks with j > i are masked (compute is
skipped via pl.when; the rectangular fetch remains — block-sparse grid
pruning is the follow-up). GQA: the k/v index map sends q-head h to kv-head
h // G.

Validated in interpret mode against the XLA blockwise oracle
(tests/test_kernels.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  bq: int, bk: int, scale: float):
    i = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # compute only blocks intersecting the causal triangle
    @pl.when(j * bk < (i + 1) * bq)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)                  # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = q @ k.T                                          # (bq, bk)
        qpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.where(s > NEG_INF / 2, jnp.exp(s - m_new[:, None]), 0.0)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + p @ v
        m_ref[...] = m_new

    @pl.when(j == pl.num_programs(3) - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bq", "bk", "interpret"))
def flash_attention_fwd(q: jax.Array, k: jax.Array, v: jax.Array,
                        bq: int = 512, bk: int = 512,
                        interpret: bool = False) -> jax.Array:
    """q: (B, H, S, hd); k, v: (B, KV, S, hd) -> (B, H, S, hd).

    S % bq == 0 and S % bk == 0 (ops.py pads)."""
    B, H, S, hd = q.shape
    KV = k.shape[1]
    G = H // KV
    bq, bk = min(bq, S), min(bk, S)
    assert S % bq == 0 and S % bk == 0, (S, bq, bk)
    grid = (B, H, S // bq, S // bk)
    scale = hd ** -0.5

    return pl.pallas_call(
        functools.partial(_flash_kernel, bq=bq, bk=bk, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),     # acc
            pltpu.VMEM((bq,), jnp.float32),        # m (running max)
            pltpu.VMEM((bq,), jnp.float32),        # l (running sum)
        ],
        interpret=interpret,
    )(q, k, v)
