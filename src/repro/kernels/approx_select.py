"""Approximate peak-FLOP/s tier: MXU Hamming-as-matmul scoring + bucketed
partial-reduce top-k with an analytical recall bound (TPU-KNN, PAPERS.md).

The exact counting select is bandwidth-shaped — both passes stream every
code word, so throughput pins to HBM, not compute. This tier trades a
bounded amount of recall for compute-bound throughput:

* **Scoring** — packed codes are bit-sliced into ±1 int8 planes so Hamming
  distance becomes ONE matmul on the systolic array:
  ``dist = (d - Q_planes @ D_planes^T) / 2`` via ``lax.dot_general`` with
  ``preferred_element_type=int32`` (the TPU int8 MXU path; exact integer
  distances, no popcount). An alternate asymmetric path keeps the query as
  a FLOAT projection (``quantize.itq_project``) against the datastore's ±1
  planes — better ranking fidelity for non-binary stores at the same
  datastore bytes.
* **Partial-reduce select** — the (Q, N) score matrix is never held: a
  scan over ``bn``-row data blocks reduces each (Q, bn) score tile to its
  top ``L`` candidates, and only the (Q, n_blocks·L) pool is merged (one
  lexicographic (dist, id) sort — exactly ``counting_topk``'s ascending /
  ties-by-index contract). ``L`` is sized from the TPU-KNN analytical
  bound: under a uniform arrangement the i-th best item survives iff fewer
  than L of the i better items share its block, so
  ``E[recall@k] = mean_i P[Binom(i, 1/n_blocks) < L]`` — ``recall_target``
  inverts that. ``recall_target=1.0`` keeps L = bn (the pool is every
  row): bit-identical to the fused select by construction.
* **Sharded merge** — ``approx_topk_sharded`` merges per-shard candidate
  pools hist_merge-style: each shard histograms its pool's distances, one
  ``psum`` derives the global radius r*, and winners scatter into disjoint
  slots of the replicated (Q, k) output — O(Q·bins) counts + O(Q·k)
  output across devices, never O(shards·pool) candidates.

Block geometry comes from ``tuning.approx_blocks`` (measured autotune
cache with seeded defaults, like the exact tier).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import binary, topk
from repro.kernels import tuning


# ---------------------------------------------------------------------------
# the analytical recall bound
# ---------------------------------------------------------------------------

def expected_recall(k: int, n_blocks: int, l: int) -> float:
    """E[recall@k] keeping the best ``l`` of each of ``n_blocks`` equal
    data blocks, under the TPU-KNN uniform-arrangement model: the i-th
    best item (i = 0..k-1) is kept iff fewer than ``l`` of the i better
    items land in its block — a binomial tail at p = 1/n_blocks. Host
    math, exact."""
    k = max(int(k), 1)
    l = int(l)
    if l <= 0:
        return 0.0
    n_blocks = max(int(n_blocks), 1)
    if n_blocks == 1:
        return min(l, k) / k
    p = 1.0 / n_blocks
    total = 0.0
    for i in range(k):
        surv = 0.0
        for j in range(min(l, i + 1)):
            surv += math.comb(i, j) * p ** j * (1.0 - p) ** (i - j)
        total += min(surv, 1.0)
    return total / k


def l_for_recall(k: int, n_blocks: int, block_rows: int,
                 recall_target: float) -> int:
    """Smallest per-block candidate count L whose analytical expected
    recall meets ``recall_target``. ``recall_target >= 1`` returns the
    full block (the pool is every row — exact, bit-identical to the fused
    counting select); L never needs to exceed k (at L = k the bound is
    exactly 1)."""
    block_rows = max(int(block_rows), 1)
    if recall_target >= 1.0:
        return block_rows
    l = 1
    cap = min(max(int(k), 1), block_rows)
    while l < cap and expected_recall(k, n_blocks, l) < recall_target:
        l += 1
    return l


# ---------------------------------------------------------------------------
# MXU scoring: bit-sliced planes
# ---------------------------------------------------------------------------

def bit_planes(packed: jax.Array, d: int, signed: bool = True) -> jax.Array:
    """Bit-slice packed codes into int8 planes: (..., W) uint32 ->
    (..., d) int8 in {-1, +1} (``signed``) or {0, 1}."""
    bits = binary.unpack_bits(packed, d).astype(jnp.int8)
    return (2 * bits - 1).astype(jnp.int8) if signed else bits


def hamming_scores_planes(q_planes: jax.Array, x_planes: jax.Array,
                          d: int) -> jax.Array:
    """Hamming distance as one int8 matmul: q (Q, d) ±1, x (N, d) ±1 ->
    (Q, N) int32, exact. ``<±q, ±x> = d - 2·hamming``, and the int32
    accumulation (``preferred_element_type``) keeps it exact for any d the
    planes can hold."""
    dot = jax.lax.dot_general(q_planes, x_planes, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.int32)
    return (d - dot) >> 1


def asymmetric_scores(v: jax.Array, x_planes: jax.Array) -> jax.Array:
    """Asymmetric float/int8 scoring for non-binary stores: the query stays
    the CONTINUOUS rotated projection (``quantize.itq_project`` — never
    sign-quantized), scored against the datastore's ±1 planes. Returns
    (Q, N) f32 inner products, descending = nearest; only the queries keep
    float precision, the datastore stays at 1 bit/dim."""
    dt = jnp.bfloat16 if jax.default_backend() == "tpu" else jnp.float32
    return jax.lax.dot_general(v.astype(dt), x_planes.astype(dt),
                               (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)


# ---------------------------------------------------------------------------
# the bucketed partial-reduce select
# ---------------------------------------------------------------------------

def _pool(q_packed: jax.Array, x_packed: jax.Array, bins: int, bn: int,
          l: int, n_valid, block_mask: Optional[jax.Array]
          ) -> Tuple[jax.Array, jax.Array]:
    """The per-block partial reduce: scan ``bn``-row blocks, score each on
    the MXU, keep the best ``l`` per block. Returns the candidate pool
    (dists (Q, n_blocks·l) int32 in [0, bins], ``bins`` = invalid;
    positions (Q, n_blocks·l) int32, invalid slots hold N). ``block_mask``
    is an optional per-query (Q, n_blocks) enable mask — a zero block
    contributes only sentinels for that query."""
    N, W = x_packed.shape
    Q = q_packed.shape[0]
    d = bins - 1
    n_blocks = -(-N // bn)
    n_pad = n_blocks * bn
    planes = bit_planes(x_packed, d)                       # (N, d) int8
    if n_pad != N:
        planes = jnp.pad(planes, ((0, n_pad - N), (0, 0)))
    xb = planes.reshape(n_blocks, bn, d)
    qpl = bit_planes(q_packed, d)                          # (Q, d) int8
    nv = jnp.asarray(N if n_valid is None else n_valid, jnp.int32)
    bm = None
    if block_mask is not None:
        bm = jnp.asarray(block_mask).astype(jnp.int32).T   # (n_blocks, Q)
        assert bm.shape == (n_blocks, Q), (bm.shape, (n_blocks, Q))

    def body(_, xs):
        bi, xblk = xs[0], xs[1]
        dist = jnp.minimum(hamming_scores_planes(qpl, xblk, d), bins - 1)
        gid = bi * bn + jnp.arange(bn, dtype=jnp.int32)
        ok = gid[None, :] < nv
        if bm is not None:
            ok = ok & (xs[2] > 0)[:, None]
        dist = jnp.where(ok, dist, bins)
        # ties by in-block index order (composite key), exactly like the
        # counting selects — global order is restored at the merge
        dd, ii = topk.composite_topk(dist, l, bins)
        pos = jnp.where(dd < bins, bi * bn + ii, N)
        return None, (dd, pos)

    xs = (jnp.arange(n_blocks, dtype=jnp.int32), xb)
    if bm is not None:
        xs = xs + (bm,)
    _, (dd, pos) = jax.lax.scan(body, None, xs)
    dd = jnp.moveaxis(dd, 0, 1).reshape(Q, n_blocks * l)
    pos = jnp.moveaxis(pos, 0, 1).reshape(Q, n_blocks * l)
    return dd, pos


def approx_topk(q_packed: jax.Array, x_packed: jax.Array, k: int, bins: int,
                *, recall_target: float = 1.0,
                n_valid: jax.Array | int | None = None,
                block_mask: Optional[jax.Array] = None,
                bn: Optional[int] = None, l: Optional[int] = None,
                backend: str | None = None
                ) -> Tuple[jax.Array, jax.Array]:
    """Bucketed partial-reduce approximate top-k.

    q: (Q, W) uint32, x: (N, W) -> (dists (Q, k) ascending, positions
    (Q, k)) with ``ops.hamming_topk``'s exact contract: distances clamped
    to bins-1, ties broken by index order, rows beyond min(k, n_valid)
    padded with (bins, N). The candidate pool keeps the best
    ``l = l_for_recall(k, n_blocks, bn, recall_target)`` rows of every
    ``bn``-row block; at ``recall_target=1.0`` the pool is every row and
    the result is bit-identical to the fused/counting selects.

    ``block_mask``: optional per-query (Q, ceil(N/bn)) enable mask (the
    probed-layout contract at the approx tier's granularity)."""
    N, W = x_packed.shape
    Q = q_packed.shape[0]
    k_k = min(k, N)
    if k_k <= 0:
        return (jnp.full((Q, k), bins, jnp.int32),
                jnp.full((Q, k), N, jnp.int32))
    if bn is None:
        bn = tuning.approx_blocks(Q, N, W, backend=backend)
    bn = max(min(int(bn), N + (-N) % 8 if N >= 8 else N), 1)
    n_blocks = -(-N // bn)
    if l is None:
        l = l_for_recall(k_k, n_blocks, bn, recall_target)
    l = max(min(int(l), bn), 1)

    dd, pos = _pool(q_packed, x_packed, bins, bn, l, n_valid, block_mask)
    # exact merge of the pool: one lexicographic (dist, id) sort == the
    # counting selects' ascending / ties-by-index order; sentinels
    # (bins, N) sort last by construction
    dd, pos = jax.lax.sort((dd, pos), dimension=-1, num_keys=2)
    C = dd.shape[1]
    if C < k:
        dd = jnp.concatenate(
            [dd, jnp.full((Q, k - C), bins, jnp.int32)], axis=1)
        pos = jnp.concatenate(
            [pos, jnp.full((Q, k - C), N, jnp.int32)], axis=1)
    return dd[:, :k], pos[:, :k]


def masked_approx_topk(layout, q_packed: jax.Array, k: int, d: int,
                       probe: Optional[jax.Array] = None,
                       cand_ids: Optional[jax.Array] = None,
                       recall_target: float = 1.0,
                       bn: Optional[int] = None
                       ) -> Tuple[jax.Array, jax.Array]:
    """Index-probed approximate select over a bucket-clustered layout.

    Same candidate contract as ``layout_mod.masked_topk`` — probed bucket
    ids / original candidate ids become a block enable mask over the
    reordered codes — but at the approx tier's granularity: the mask is
    PER QUERY (bq = 1, finer than the fused kernels' bq-grouped rows) at
    ``bn = tuning.approx_blocks`` resolution, and the masked blocks feed
    the partial-reduce select instead of the two-pass kernels. Returns
    (dists, ORIGINAL ids) with -1 in sentinel slots."""
    from repro.core import layout as layout_mod

    Q, W = q_packed.shape
    n = layout.n
    bins = d + 1
    if bn is None:
        bn = tuning.approx_blocks(Q, n, W)
    bn = max(min(int(bn), n), 1)
    n_blocks = -(-n // bn)
    mask = None
    if probe is not None:
        mask = layout_mod.probe_block_mask(layout, probe, 1, bn, Q, n_blocks)
    if cand_ids is not None:
        pmask = layout_mod.position_block_mask(layout, cand_ids, 1, bn,
                                               Q, n_blocks)
        mask = pmask if mask is None else jnp.maximum(mask, pmask)
    dd, pos = approx_topk(q_packed, layout.codes, k, bins,
                          recall_target=recall_target, bn=bn,
                          block_mask=mask)
    return dd, layout_mod.original_ids(layout, dd, pos, d)


# ---------------------------------------------------------------------------
# the sharded hist_merge-style candidate merge
# ---------------------------------------------------------------------------

def approx_topk_sharded(q_packed: jax.Array, x_local: jax.Array, k: int,
                        bins: int, axis_names, *, n_shards: int,
                        recall_target: float = 1.0,
                        n_valid: jax.Array | None = None,
                        id_base: jax.Array | None = None,
                        n_total: jax.Array | int | None = None,
                        perm: jax.Array | None = None,
                        participate: jax.Array | None = None,
                        tree_fanout: int = 0,
                        bn: Optional[int] = None
                        ) -> Tuple[jax.Array, jax.Array]:
    """Distributed approximate select — hist_merge over per-shard candidate
    POOLS instead of per-shard rows. Call INSIDE ``shard_map``; collectives
    run over ``axis_names``.

    Per shard: the partial reduce shrinks the local slice to n_blocks·L
    candidates (L sized from the GLOBAL pool's block count, so the recall
    bound covers the whole sharded store). Merge, exactly like
    ``ops.hamming_topk_sharded``: (1) each shard histograms its pool's
    distances — a partial histogram of the global candidate race; (2) one
    ``psum`` merges them and the global radius r*, below-count and emit
    count derive via the SAME ``_radius_from_cum``; (3) a (Q, 2)-per-shard
    all-gather turns local below/tie counts into exclusive-scan slot bases;
    (4) winners scatter into disjoint slots of the replicated (Q, k)
    output and one ``psum`` assembles it. Cross-device traffic is
    O(Q·bins) + O(Q·n_shards) + O(Q·k) — never the pooled candidates.

    At ``recall_target=1.0`` the pool is every row: bit-identical to
    ``ops.hamming_topk_sharded`` / the single-device fused select.
    ``n_valid``/``id_base``/``n_total``: the uneven-shard contract of
    ``ops.hamming_topk_sharded``. ``perm``: this shard's local layout
    permutation (winners report original local ids; in-shard tie order
    then follows (dist, original id), the usual layout report-order
    freedom). ``participate``/``tree_fanout``: the fault-tolerance and
    hierarchical-merge contracts of ``ops.hamming_topk_sharded`` — a
    dead shard's pool is emptied and ids renumber over the survivors;
    fanout >= 2 reduces the pool histograms and outputs through
    ``ops._tree_psum`` (bit-identical sums)."""
    from repro.kernels import ops

    axes = tuple(axis_names)
    Q, W = q_packed.shape
    n_loc = x_local.shape[0]
    k_k = min(k, n_shards * n_loc)
    if k_k <= 0:
        return (jnp.full((Q, k), bins, jnp.int32),
                jnp.full((Q, k), 0, jnp.int32))

    flat = jnp.zeros((), jnp.int32)
    for a in axes:
        flat = flat * jax.lax.psum(jnp.int32(1), a) + jax.lax.axis_index(a)
    part = None
    if participate is not None:
        part = jnp.asarray(participate, jnp.int32).reshape(n_shards)
    if n_valid is None:
        if part is None:
            nv = jnp.int32(n_loc)
            ib = ((flat * n_loc).astype(jnp.int32)
                  if id_base is None else id_base)
            nt = n_shards * n_loc if n_total is None else n_total
        else:
            nv_all = part * jnp.int32(n_loc)
            nv = nv_all[flat]
            csum = jnp.cumsum(nv_all)
            ib = csum[flat] - nv_all[flat] if id_base is None else id_base
            nt = csum[-1] if n_total is None else n_total
    else:
        nv = jnp.asarray(n_valid, jnp.int32).reshape(())
        if part is not None:
            nv = nv * part[flat]
        ib, nt = id_base, n_total
        if ib is None or nt is None:
            nv_all = jax.lax.all_gather(nv, axes, tiled=False)
            nv_all = nv_all.reshape(n_shards)
            csum = jnp.cumsum(nv_all)
            ib = csum[flat] - nv_all[flat] if ib is None else ib
            nt = csum[-1] if nt is None else nt
    ib = jnp.asarray(ib, jnp.int32)
    nt = jnp.asarray(nt, jnp.int32)
    psum = ((lambda v: ops._tree_psum(v, axes, tree_fanout))
            if tree_fanout >= 2 else (lambda v: jax.lax.psum(v, axes)))

    if bn is None:
        bn = tuning.approx_blocks(Q, n_loc, W)
    bn = max(min(int(bn), n_loc), 1)
    n_blocks = -(-n_loc // bn)
    l = max(min(l_for_recall(k_k, n_shards * n_blocks, bn, recall_target),
                bn), 1)

    # local pool: distances + GLOBAL ids (sentinels at the global total)
    dd, pos = _pool(q_packed, x_local, bins, bn, l, nv, None)
    if perm is not None:
        perm = jnp.asarray(perm, jnp.int32)
        pos = jnp.where(pos < n_loc, perm[jnp.minimum(pos, n_loc - 1)], pos)
    gid = jnp.where(dd < bins, pos + ib, nt)

    # (1)+(2): the candidate-pool histogram race, merged through one psum
    rows = jnp.arange(Q)[:, None]
    hist_loc = jnp.zeros((Q, bins), jnp.int32).at[
        rows, jnp.clip(dd, 0, bins - 1)].add((dd < bins).astype(jnp.int32))
    hist_glob = psum(hist_loc)
    cum_g = jnp.cumsum(hist_glob, axis=-1)
    _, r_star, n_lt, n_emit = ops._radius_from_cum(cum_g, k_k)

    # (3): exclusive-scan slot bases from the tiny (Q, 2) per-shard counts
    gather = lambda c, i: jnp.take_along_axis(c, i[:, None], axis=-1)[:, 0]
    cum_l = jnp.cumsum(hist_loc, axis=-1)
    l_lt = jnp.where(r_star > 0, gather(cum_l, jnp.maximum(r_star - 1, 0)), 0)
    l_tie = gather(hist_loc, r_star)
    counts = jnp.stack([l_lt, l_tie], axis=-1)
    g_counts = jax.lax.all_gather(counts, axes, tiled=False)
    g_counts = g_counts.reshape(n_shards, Q, 2)
    before = (jnp.arange(n_shards, dtype=jnp.int32) < flat)[:, None]
    base_lt = jnp.sum(jnp.where(before, g_counts[:, :, 0], 0), axis=0)
    base_tie = n_lt + jnp.sum(jnp.where(before, g_counts[:, :, 1], 0), axis=0)

    # (4): emit in (dist, id) order into this shard's disjoint slots; the
    # +1 offset makes 0 the "untouched" marker the psum preserves
    sd, si = jax.lax.sort((dd, gid), dimension=-1, num_keys=2)
    lt = sd < r_star[:, None]
    tie = sd == r_star[:, None]
    rank_lt = jnp.cumsum(lt.astype(jnp.int32), axis=-1) - 1
    rank_tie = jnp.cumsum(tie.astype(jnp.int32), axis=-1) - 1
    slot = jnp.where(lt, base_lt[:, None] + rank_lt,
                     jnp.where(tie, base_tie[:, None] + rank_tie, k_k))
    slot = jnp.where(slot < k_k, slot, k_k)                 # drop overflow
    od = jnp.zeros((Q, k_k), jnp.int32).at[rows, slot].add(
        jnp.where(slot < k_k, sd + 1, 0), mode="drop")
    oi = jnp.zeros((Q, k_k), jnp.int32).at[rows, slot].add(
        jnp.where(slot < k_k, si + 1, 0), mode="drop")
    od = psum(od) - 1
    oi = psum(oi) - 1
    return ops._finalize_slots(od, oi, n_emit, k, k_k, bins, nt)


# ---------------------------------------------------------------------------
# asymmetric top-k (non-binary stores)
# ---------------------------------------------------------------------------

def asymmetric_topk(v: jax.Array, x_packed: jax.Array, k: int, d: int, *,
                    recall_target: float = 1.0, bn: Optional[int] = None
                    ) -> Tuple[jax.Array, jax.Array]:
    """Approximate top-k by MAXIMUM asymmetric score: the float query
    projection v (Q, d) against packed ±1 codes. Same partial-reduce shape
    as ``approx_topk`` but over float scores (per-block ``lax.top_k``,
    final exact top-k over the pool). Returns (scores (Q, k) descending,
    ids (Q, k)); at recall_target=1.0 equals the exact argmax ranking up
    to float ties."""
    N, W = x_packed.shape
    Q = v.shape[0]
    k_k = min(k, N)
    if bn is None:
        bn = tuning.approx_blocks(Q, N, W)
    bn = max(min(int(bn), N), 1)
    n_blocks = -(-N // bn)
    l = max(min(l_for_recall(k_k, n_blocks, bn, recall_target), bn), 1)

    n_pad = n_blocks * bn
    planes = bit_planes(x_packed, d)
    if n_pad != N:
        planes = jnp.pad(planes, ((0, n_pad - N), (0, 0)))
    xb = planes.reshape(n_blocks, bn, d)
    neg_inf = jnp.float32(-jnp.inf)

    def body(_, xs):
        bi, xblk = xs
        s = asymmetric_scores(v, xblk)                      # (Q, bn) f32
        gid = bi * bn + jnp.arange(bn, dtype=jnp.int32)
        s = jnp.where(gid[None, :] < N, s, neg_inf)
        sv, si = jax.lax.top_k(s, l)
        return None, (sv, jnp.where(sv > neg_inf, bi * bn + si, N))

    _, (sv, si) = jax.lax.scan(
        body, None, (jnp.arange(n_blocks, dtype=jnp.int32), xb))
    sv = jnp.moveaxis(sv, 0, 1).reshape(Q, n_blocks * l)
    si = jnp.moveaxis(si, 0, 1).reshape(Q, n_blocks * l)
    out_v, oi = jax.lax.top_k(sv, k_k)
    out_i = jnp.take_along_axis(si, oi, axis=-1)
    if k_k < k:
        out_v = jnp.concatenate(
            [out_v, jnp.full((Q, k - k_k), neg_inf)], axis=1)
        out_i = jnp.concatenate(
            [out_i, jnp.full((Q, k - k_k), N, jnp.int32)], axis=1)
    return out_v, out_i


__all__ = ["approx_topk", "approx_topk_sharded", "asymmetric_scores",
           "asymmetric_topk", "bit_planes", "expected_recall",
           "hamming_scores_planes", "l_for_recall", "masked_approx_topk"]
