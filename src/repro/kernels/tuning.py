"""Block-shape heuristics + the measured autotune cache (see DESIGN.md).

One table instead of per-call-site hardcoded defaults: both passes of the
fused top-k (``hamming_hist_pallas`` / ``hamming_emit_pallas``), the
approximate partial-reduce select (``kernels/approx_select.py``) and the
materializing distance kernel ask here for their block shapes given the
problem shape and backend.

Resolution order is **measured beats default**: every lookup first consults
the :class:`AutotuneCache` — a small JSON-on-disk store of per-(backend,
kind, geometry-bucket) timings written by :func:`measure` — and only falls
back to the static heuristics below when no measurement exists. The static
heuristics ARE the seeded defaults: with an empty cache every shape is a
pure function of the inputs, so tests and CI stay deterministic (nothing
here ever times code implicitly; ``measure`` runs only when a caller
explicitly invokes it, and accepts an injectable timer so even the
measuring path is testable without wall-clock assertions).
``cost_hints`` reports which side won as ``hint_source`` ("measured" |
"default"), which ``QueryPlan.explain()`` surfaces.

The governing budget on TPU is VMEM: each grid cell holds the code tiles
(bq + bn) * W words plus the kernels' widest intermediate — the
(bq, sub, lanes) one-hot used for the histogram scatter / slot scatter,
where ``lanes`` is `bins` for pass 1 and `k` for pass 2. We size ``sub`` so
that intermediate stays under ~2 MiB, keep bq a sublane multiple (8) and bn
a lane multiple (128), and stream the dataset in the largest bn that still
double-buffers. On CPU the kernels run interpreted (the grid lowers to an
XLA loop), so smaller tiles bound trace size instead of VMEM.

Since the fused select went single-shot (one Pallas grid owns ALL of N —
no engine-side chunk scan), the heuristic is also grid-wide aware: N/bn is
both the grid's streaming extent and the second dimension of the pass-1
block-min pruning summary ((Q/bq, N/bn) int32, one SMEM scalar per grid
cell). For large N we grow bn toward the code-tile VMEM budget so the
summary footprint and per-query-block grid length stay bounded instead of
scaling linearly with the datastore.
"""
from __future__ import annotations

import json
import os
import time

import jax

_SUBLANE = 8
_LANE = 128
# per-cell budget for the (bq, sub, lanes) int32 one-hot intermediate.
# CPU runs interpreted: no VMEM to respect, and runtime scales with the
# number of in-kernel iterations, so a fatter budget (bigger sub, fewer
# fori steps) is strictly faster there.
_ONEHOT_BYTES = {"tpu": 2 << 20, "cpu": 4 << 20, "gpu": 1 << 20}
# single-shot grids: cap the N-block count (summary second dim / grid
# extent per query block) by growing bn, up to this (bn, W) int32 code-tile
# VMEM budget. On TPU the grid is a hardware loop, so the cap only bounds
# the summary; interpreted (CPU) the grid UNROLLS into the program, so the
# cap is much tighter there — the in-cell fori over bn/sub stays rolled,
# making a big bn the cheap direction.
_MAX_N_BLOCKS = {"tpu": 1024, "cpu": 16, "gpu": 1024}
_CODE_TILE_BYTES = {"tpu": 4 << 20, "cpu": 1 << 20, "gpu": 2 << 20}


def _round_up(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


def _round_down(n: int, m: int) -> int:
    return max(m, n // m * m)


# ---------------------------------------------------------------------------
# the measured autotune cache
# ---------------------------------------------------------------------------

def _pow2_bucket(n: int) -> int:
    """Geometry bucketing for cache keys: round up to a power of two, so
    one measurement covers the whole bucket instead of every exact shape."""
    n = max(int(n), 1)
    return 1 << (n - 1).bit_length()


class AutotuneCache:
    """Per-(backend, kind, geometry-bucket) measured block shapes.

    Entries live in one JSON file (``path``; default from the
    ``REPRO_AUTOTUNE_CACHE`` env var, empty -> in-memory only) shaped
    ``{key: {"bq":…,"bn":…,"sub":…,"us":…}}``. A corrupt or missing file
    degrades to an empty cache — defaults always work. Lookups sanitize
    entries back onto the kernels' tiling constraints (bq/sub sublane
    multiples, bn a sub multiple) so a hand-edited or stale file can bias
    performance but never produce an invalid grid."""

    def __init__(self, path: str | None = None):
        self.path = (os.environ.get("REPRO_AUTOTUNE_CACHE", "")
                     if path is None else path)
        self._entries: dict[str, dict] = {}
        self._loaded = False

    # -- persistence -------------------------------------------------------

    def _load(self) -> None:
        if self._loaded:
            return
        self._loaded = True
        if not self.path or not os.path.exists(self.path):
            return
        try:
            with open(self.path) as f:
                data = json.load(f)
            if isinstance(data, dict):
                self._entries.update(
                    {k: v for k, v in data.items() if isinstance(v, dict)})
        except (OSError, ValueError):
            pass                     # corrupt cache == empty cache

    def save(self) -> None:
        if not self.path:
            return
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(self._entries, f, indent=1, sort_keys=True)
        os.replace(tmp, self.path)

    # -- lookup ------------------------------------------------------------

    @staticmethod
    def key(backend: str, kind: str, Q: int, N: int, W: int,
            lanes: int) -> str:
        return (f"{backend}/{kind}/q{_pow2_bucket(Q)}"
                f"n{_pow2_bucket(N)}w{max(int(W), 1)}l{_pow2_bucket(lanes)}")

    def get(self, backend: str, kind: str, Q: int, N: int, W: int,
            lanes: int) -> dict | None:
        self._load()
        return self._entries.get(self.key(backend, kind, Q, N, W, lanes))

    def put(self, backend: str, kind: str, Q: int, N: int, W: int,
            lanes: int, entry: dict, persist: bool = True) -> None:
        self._load()
        self._entries[self.key(backend, kind, Q, N, W, lanes)] = dict(entry)
        if persist:
            self.save()

    def clear(self) -> None:
        self._entries.clear()
        self._loaded = True

    def __len__(self) -> int:
        self._load()
        return len(self._entries)


_CACHE = AutotuneCache()


def autotune_cache() -> AutotuneCache:
    return _CACHE


def configure(path: str | None = None) -> AutotuneCache:
    """Rebind the process-wide cache (tests point it at a tmp file; ""
    keeps it purely in-memory). Returns the new cache."""
    global _CACHE
    _CACHE = AutotuneCache("" if path is None else path)
    return _CACHE


def _sane_topk_entry(entry: dict, N: int) -> tuple[int, int, int] | None:
    """Sanitize a measured (bq, bn, sub) back onto the kernels' tiling
    constraints; None when the entry is not a usable shape."""
    try:
        bq, bn, sub = int(entry["bq"]), int(entry["bn"]), int(entry["sub"])
    except (KeyError, TypeError, ValueError):
        return None
    if min(bq, bn, sub) <= 0:
        return None
    bq = _round_up(bq, _SUBLANE)
    sub = min(_round_up(sub, _SUBLANE), 256)
    bn = _round_up(bn, sub)
    return bq, bn, sub


def hint_source(backend: str, kind: str, Q: int, N: int, W: int,
                lanes: int) -> str:
    """"measured" when the cache holds a usable entry for this geometry
    bucket, else "default" (the static heuristics)."""
    ent = _CACHE.get(backend, kind, Q, N, W, lanes)
    if kind == "topk":
        return "measured" if (ent is not None
                              and _sane_topk_entry(ent, N)) else "default"
    return "measured" if (ent is not None and ent.get("bn")) else "default"


def measure(runner, candidates, *, backend: str, kind: str, Q: int, N: int,
            W: int, lanes: int, reps: int = 3, timer=None,
            persist: bool = True) -> dict:
    """Time ``runner(candidate)`` over ``candidates`` and cache the winner.

    ``runner`` executes one kernel call for a candidate shape (the caller
    blocks on the result); ``timer`` defaults to ``time.perf_counter`` and
    is injectable so tests measure with a fake clock — deterministic, no
    wall-time assertions. Each candidate gets one warm-up call (compile)
    plus ``reps`` timed calls; the best median wins. Returns the cached
    entry. Nothing in this module calls ``measure`` implicitly."""
    timer = time.perf_counter if timer is None else timer
    best = None
    for cand in candidates:
        try:
            runner(cand)                       # warm-up / compile
            times = []
            for _ in range(max(reps, 1)):
                t0 = timer()
                runner(cand)
                times.append(timer() - t0)
            us = sorted(times)[len(times) // 2] * 1e6
        except Exception:                      # noqa: BLE001 — an invalid
            continue                           # candidate just loses
        if best is None or us < best[0]:
            best = (us, cand)
    if best is None:
        raise ValueError("no candidate shape ran successfully")
    us, cand = best
    entry = dict(cand)
    entry["us"] = round(us, 3)
    _CACHE.put(backend, kind, Q, N, W, lanes, entry, persist=persist)
    return entry


def topk_candidates(Q: int, N: int, W: int, lanes: int,
                    backend: str | None = None) -> list[dict]:
    """Candidate (bq, bn, sub) shapes for ``measure`` around the static
    heuristic: the default itself plus halved/doubled bn and sub variants,
    sanitized and deduplicated."""
    backend = backend or jax.default_backend()
    bq, bn, sub = _topk_blocks_default(Q, N, W, lanes, backend)
    raw = [(bq, bn, sub), (bq, bn * 2, sub), (bq, max(bn // 2, sub), sub),
           (bq, bn, max(sub // 2, _SUBLANE)),
           (max(bq // 2, _SUBLANE), bn, sub)]
    out, seen = [], set()
    for cand in raw:
        ok = _sane_topk_entry(dict(zip(("bq", "bn", "sub"), cand)), N)
        if ok and ok not in seen:
            seen.add(ok)
            out.append(dict(zip(("bq", "bn", "sub"), ok)))
    return out


def topk_blocks(Q: int, N: int, W: int, lanes: int,
                backend: str | None = None) -> tuple[int, int, int]:
    """(bq, bn, sub) for the two-pass counting-select kernels.

    ``lanes`` is the width of the per-element one-hot scatter: ``bins`` for
    the histogram pass, ``k`` for the emit pass. Both passes should be given
    the SAME (bq, bn, sub) (use lanes=max(bins, k)) so they stream the
    dataset in identical tiles — required for the block-min summary, whose
    (Q/bq, N/bn) tiling must mean the same tiles in both passes.

    A measured :class:`AutotuneCache` entry for this (backend, geometry
    bucket) overrides the static heuristic; with an empty cache the result
    is the deterministic seeded default below.
    """
    backend = backend or jax.default_backend()
    ent = _CACHE.get(backend, "topk", Q, N, W, lanes)
    if ent is not None:
        sane = _sane_topk_entry(ent, N)
        if sane is not None:
            return sane
    return _topk_blocks_default(Q, N, W, lanes, backend)


def _topk_blocks_default(Q: int, N: int, W: int, lanes: int,
                         backend: str) -> tuple[int, int, int]:
    """The static VMEM heuristic — the cache's seeded default."""
    budget = _ONEHOT_BYTES.get(backend, 1 << 20)

    bq = min(_round_up(Q, _SUBLANE), 64 if backend == "tpu" else 32)
    # one-hot (bq, sub, lanes) int32 under budget; sub a sublane multiple
    sub = _round_down(budget // (4 * bq * max(lanes, 1)), _SUBLANE)
    sub = min(sub, 256)
    # extreme lanes (bins or k in the thousands): the sublane floor on sub
    # would silently bust the budget — shrink bq instead (it only amortizes
    # the revisited output block). The (8, 8, lanes) floor is the hard
    # minimum tile.
    while bq > _SUBLANE and 4 * bq * sub * max(lanes, 1) > budget:
        bq = _round_down(bq // 2, _SUBLANE)
    # stream the dataset in big tiles: amortize the revisited output block
    bn_cap = 2048 if backend == "tpu" else 512
    bn = min(_round_up(N, sub), _round_down(bn_cap, sub))
    # single-shot whole-datastore grid: once N/bn exceeds the block cap the
    # pruning summary and grid length dominate — grow bn (still a multiple
    # of sub) until the block count is bounded or the code tile hits its
    # VMEM budget
    max_blocks = _MAX_N_BLOCKS.get(backend, 64)
    if N > bn * max_blocks:
        want = _round_up(-(-N // max_blocks), sub)
        cap = _round_down(_CODE_TILE_BYTES.get(backend, 1 << 20)
                          // (4 * max(W, 1)), sub)
        bn = max(bn, min(want, cap))
    return bq, bn, sub


def layout_blocks(Q: int, N: int, W: int, lanes: int, bucket_rows: int,
                  backend: str | None = None) -> tuple[int, int, int]:
    """(bq, bn, sub) for the MASKED select over a bucket-clustered layout
    (core/layout.py).

    Same VMEM heuristic as ``topk_blocks``, but bn is additionally pulled
    toward the bucket size (rounded up to a sub multiple — "round buckets
    up to tile multiples"): the enable mask's granularity is the data
    block, and a block much larger than a bucket drags several neighbor
    buckets into every probe's candidate set, while a block much smaller
    just grows the (tiny) mask. Overrides ``topk_blocks``'s large-N bn
    growth when the two fight — mask resolution beats summary compactness
    on the probed path (the mask IS the point there)."""
    bq, bn, sub = topk_blocks(Q, N, W, lanes, backend=backend)
    if bucket_rows and bucket_rows > 0:
        bn = max(sub, min(bn, _round_up(bucket_rows, sub)))
    return bq, bn, sub


def approx_blocks(Q: int, N: int, W: int,
                  backend: str | None = None) -> int:
    """Data-block rows ``bn`` for the approximate partial-reduce select
    (``kernels/approx_select.py``): each block's (Q, bn) MXU score tile is
    reduced to L candidates before the merge. Bigger blocks mean fewer,
    larger matmuls (and a higher recall at the same L — fewer chances for
    true neighbors to collide); smaller blocks bound the score tile. The
    seeded default targets ~32 blocks with a lane-aligned floor; a measured
    cache entry (kind="approx") overrides it."""
    backend = backend or jax.default_backend()
    ent = _CACHE.get(backend, "approx", Q, N, W, 1)
    if ent is not None:
        try:
            bn = int(ent["bn"])
        except (KeyError, TypeError, ValueError):
            bn = 0
        if bn > 0:
            return min(_round_up(bn, _LANE), 1 << 16)
    bn = _round_up(max(-(-max(N, 1) // 32), _LANE), _LANE)
    return min(bn, 8192)


def cost_hints(Q: int, N: int, W: int, lanes: int, *, path: str = "fused",
               chunk: int = 0, bucket_rows: int = 0,
               backend: str | None = None) -> dict:
    """Geometry + predicted per-call footprints for ``QueryPlan.explain()``.

    Computed by the SAME heuristics the kernels consult (``topk_blocks`` /
    ``layout_blocks`` / ``distance_blocks``), so the summary is exact for
    the fused paths, and policy stays here rather than in the planner.
    Byte counts are per query batch: ``codes_bytes_streamed`` is HBM->VMEM
    code traffic (fused reads the codes once per pass per query block),
    ``onehot_bytes`` is the widest in-kernel intermediate the VMEM budget
    sized, ``summary_bytes`` the pass-1 block-min pruning table."""
    backend = backend or jax.default_backend()
    if path in ("fused", "fused_scan"):
        n_eff = min(chunk, N) if (path == "fused_scan" and chunk) else N
        if bucket_rows:
            bq, bn, sub = layout_blocks(Q, n_eff, W, lanes, bucket_rows,
                                        backend=backend)
        else:
            bq, bn, sub = topk_blocks(Q, n_eff, W, lanes, backend=backend)
        q_pad, n_pad = _round_up(Q, bq), _round_up(n_eff, bn)
        grid = (q_pad // bq, n_pad // bn)
        hints = {
            "bq": bq, "bn": bn, "sub": sub, "grid": list(grid),
            "codes_bytes_streamed": 2 * 4 * W * n_pad * grid[0],
            "onehot_bytes": 4 * bq * sub * max(lanes, 1),
            "summary_bytes": 4 * grid[0] * grid[1],
            "hist_bytes": 4 * Q * max(lanes, 1),
            "hint_source": hint_source(backend, "topk", Q, n_eff, W, lanes),
        }
        if path == "fused_scan":
            hints["n_scan_steps"] = -(-N // max(n_eff, 1))
        return hints
    # materializing paths: the (Q, chunk) distance tile is the cost
    c = min(chunk or N, N)
    return {
        "codes_bytes_streamed": 4 * W * N,
        "distance_tile_bytes": 4 * Q * c,
        "distance_total_bytes": 4 * Q * N,
        "hint_source": "default",
    }


def merge_fanout(n_shards: int) -> int:
    """Default hist_tree group width: roughly sqrt(n_shards) rounded to a
    power of two, so the intra-host (level-0) and inter-host (tree) halves
    of the merge carry balanced group sizes. Below 4 shards a tree cannot
    beat the flat psum — return 0 (flat)."""
    if n_shards < 4:
        return 0
    f = 2
    while f * f < n_shards:
        f *= 2
    return f


def tree_levels(n_shards: int, fanout: int) -> int:
    """Number of reduction rounds ``ops._tree_psum`` runs for this shard
    count and fanout (divisible rounds + the remainder round). Mirrors the
    kernel's loop exactly so ``shard_hints`` predicts the real schedule."""
    if fanout < 2 or n_shards < 2:
        return 1 if n_shards > 1 else 0
    levels, s = 0, 1
    while s * fanout <= n_shards and n_shards % (s * fanout) == 0:
        levels += 1
        s *= fanout
    if s < n_shards:
        levels += 1
    return levels


def shard_hints(Q: int, k: int, bins: int, n_shards: int, *,
                k_local: int | None = None,
                strategy: str = "hist_merge",
                fanout: int = 0) -> dict:
    """Shard geometry + predicted CROSS-DEVICE merge traffic per query
    batch, for ``QueryPlan.explain()`` on sharded plans.

    ``hist_merge`` (the distributed counting select) moves exactly three
    tiny tensors between devices: the (Q, bins) int32 partial-histogram
    psum, the (Q, 2)-per-shard slot-base all-gather, and the (Q, k) x2
    disjoint-slot output psum — O(Q·bins), independent of n_shards·k.
    ``hist_tree`` moves the SAME tensors but reduces them hierarchically:
    level 0 is the intra-host group psum, the remaining ``tree_levels - 1``
    rounds are the inter-host tree — per-hop traffic shrinks from one
    n_shards-wide reduction to ``fanout``-wide exchanges, reported split
    into ``hist_tree_intra_bytes`` / ``hist_tree_inter_bytes``.
    ``concat_sort`` (the legacy hierarchical merge) all-gathers every
    shard's (k' dists, k' ids): O(n_shards·Q·k') candidate bytes. All are
    reported so the ratios are inspectable whatever the plan chose."""
    k_local = k if (k_local is None or k_local <= 0) else k_local
    hist_psum = 4 * Q * bins
    counts_gather = 2 * 4 * Q * n_shards
    output_psum = 2 * 4 * Q * k
    hist_total = hist_psum + counts_gather + output_psum
    concat_total = 2 * 4 * Q * k_local * n_shards
    eff_fanout = fanout if fanout >= 2 else (merge_fanout(n_shards) or 2)
    levels = max(tree_levels(n_shards, eff_fanout), 1)
    per_level = hist_psum + output_psum
    tree_intra = per_level
    tree_inter = (levels - 1) * per_level
    tree_total = tree_intra + tree_inter + counts_gather
    return {
        "n_shards": n_shards,
        "strategy": strategy,
        "merge_bytes": (concat_total if strategy == "concat_sort"
                        else tree_total if strategy == "hist_tree"
                        else hist_total),
        "hist_merge_bytes": hist_total,
        "hist_psum_bytes": hist_psum,
        "counts_gather_bytes": counts_gather,
        "output_psum_bytes": output_psum,
        "concat_sort_bytes": concat_total,
        "fanout": eff_fanout if strategy == "hist_tree" else fanout,
        "tree_levels": levels,
        "hist_tree_intra_bytes": tree_intra,
        "hist_tree_inter_bytes": tree_inter,
        "hist_tree_bytes": tree_total,
    }


def distance_blocks(Q: int, N: int, W: int,
                    backend: str | None = None) -> tuple[int, int]:
    """(bq, bn) for the materializing (Q, N) distance kernel: the (bq, bn)
    int32 output tile plus the (bq, bn, W) xor intermediate dominate."""
    # same tile on every backend for now: on TPU it fits the (bq, bn, W) xor
    # intermediate comfortably in VMEM; interpreted, it only bounds trace
    # length. Split per backend here when the TPU numbers diverge.
    bq, bn = 128, 512
    bq = min(bq, _round_up(Q, _SUBLANE))
    bn = min(bn, _round_up(N, _LANE))
    return bq, bn
