"""Block-shape heuristics shared by the Hamming kernels (see DESIGN.md).

One table instead of per-call-site hardcoded defaults: both passes of the
fused top-k (``hamming_hist_pallas`` / ``hamming_emit_pallas``) and the
materializing distance kernel ask here for (bq, bn, sub) given the problem
shape and backend.

The governing budget on TPU is VMEM: each grid cell holds the code tiles
(bq + bn) * W words plus the kernels' widest intermediate — the
(bq, sub, lanes) one-hot used for the histogram scatter / slot scatter,
where ``lanes`` is `bins` for pass 1 and `k` for pass 2. We size ``sub`` so
that intermediate stays under ~2 MiB, keep bq a sublane multiple (8) and bn
a lane multiple (128), and stream the dataset in the largest bn that still
double-buffers. On CPU the kernels run interpreted (the grid lowers to an
XLA loop), so smaller tiles bound trace size instead of VMEM.
"""
from __future__ import annotations

import jax

_SUBLANE = 8
_LANE = 128
# per-cell budget for the (bq, sub, lanes) int32 one-hot intermediate
_ONEHOT_BYTES = {"tpu": 2 << 20, "cpu": 1 << 20, "gpu": 1 << 20}


def _round_up(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


def _round_down(n: int, m: int) -> int:
    return max(m, n // m * m)


def topk_blocks(Q: int, N: int, W: int, lanes: int,
                backend: str | None = None) -> tuple[int, int, int]:
    """(bq, bn, sub) for the two-pass counting-select kernels.

    ``lanes`` is the width of the per-element one-hot scatter: ``bins`` for
    the histogram pass, ``k`` for the emit pass. Both passes should be given
    the SAME (bq, bn, sub) (use lanes=max(bins, k)) so they stream the
    dataset in identical tiles.
    """
    backend = backend or jax.default_backend()
    budget = _ONEHOT_BYTES.get(backend, 1 << 20)

    bq = min(_round_up(Q, _SUBLANE), 64 if backend == "tpu" else 32)
    # one-hot (bq, sub, lanes) int32 under budget; sub a sublane multiple
    sub = _round_down(budget // (4 * bq * max(lanes, 1)), _SUBLANE)
    sub = min(sub, 256)
    # stream the dataset in big tiles: amortize the revisited output block
    bn_cap = 2048 if backend == "tpu" else 512
    bn = min(_round_up(N, sub), _round_down(bn_cap, sub))
    return bq, bn, sub


def distance_blocks(Q: int, N: int, W: int,
                    backend: str | None = None) -> tuple[int, int]:
    """(bq, bn) for the materializing (Q, N) distance kernel: the (bq, bn)
    int32 output tile plus the (bq, bn, W) xor intermediate dominate."""
    # same tile on every backend for now: on TPU it fits the (bq, bn, W) xor
    # intermediate comfortably in VMEM; interpreted, it only bounds trace
    # length. Split per backend here when the TPU numbers diverge.
    bq, bn = 128, 512
    bq = min(bq, _round_up(Q, _SUBLANE))
    bn = min(bn, _round_up(N, _LANE))
    return bq, bn
