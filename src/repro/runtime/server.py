"""Batched serving runtime: continuous batching over a fixed slot pool with
kNN-LM retrieval (the paper's engine) in the decode loop.

Requests enter a waiting queue; free slots admit them by replaying the
prompt through the decode step with a one-hot ``active`` mask (per-row
positions make the shared cache sound); each ``tick`` then decodes one token
for every live slot. Static shapes throughout — the TPU-friendly analogue of
continuous batching.
"""
from __future__ import annotations

import dataclasses
import logging
from typing import List, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import retrieval as retrieval_mod
from repro.dist import sharding, steps as steps_mod
from repro.models import lm

log = logging.getLogger(__name__)


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray          # (P,) int32
    max_new_tokens: int
    out_tokens: Optional[list] = None


class Server:
    def __init__(self, cfg: ModelConfig, mesh, params, *, max_batch: int,
                 max_len: int, store=None, shard_axes=()):
        self.cfg, self.mesh, self.params = cfg, mesh, params
        self.max_batch, self.max_len = max_batch, max_len
        self.store = store
        self.with_retrieval = cfg.retrieval.enabled and store is not None
        # resolve and log the retrieval QueryPlan once per store at startup
        # (retrieval.log_store_plan). ``shard_axes``: the mesh axes the
        # serve step searches the datastore over — with them the logged
        # plan is the SHARDED plan decode actually runs, including the
        # merge strategy (hist_merge vs concat_sort) and its predicted
        # cross-device traffic; without them it is the store's LOCAL plan.
        self.retrieval_plan = None
        if self.with_retrieval:
            self.retrieval_plan = retrieval_mod.log_store_plan(
                store, cfg.retrieval, q=max_batch, logger=log,
                mesh=mesh if shard_axes else None,
                axes=tuple(shard_axes))
        self.serve_fn, _, self.sspecs = steps_mod.make_serve_step(
            cfg, mesh, max_len, with_retrieval=self.with_retrieval)
        with mesh:
            self.state = jax.jit(
                lambda: lm.init_decode_state(cfg, max_batch, max_len),
                out_shardings=sharding.named(mesh, self.sspecs))()
        self.slots: List[Optional[Request]] = [None] * max_batch
        self.last_token = np.zeros((max_batch, 1), np.int32)
        self.waiting: List[Request] = []
        self.done: List[Request] = []
        self.ticks = 0

    def _step(self, token: np.ndarray, active: np.ndarray):
        args = (self.params, jnp.asarray(token), self.state,
                jnp.asarray(active))
        if self.with_retrieval:
            args = args + (self.store,)
        with self.mesh:
            logits, self.state = self.serve_fn(*args)
        return np.asarray(logits.astype(jnp.float32))[:, 0, :]

    def _admit(self, slot: int, req: Request):
        """Replay the prompt through the decode path for one slot."""
        req.out_tokens = []
        self.slots[slot] = req
        active = np.zeros(self.max_batch, bool)
        active[slot] = True
        tok = np.zeros((self.max_batch, 1), np.int32)
        logits = None
        for t in req.prompt:
            tok[slot, 0] = int(t)
            logits = self._step(tok, active)
        self.last_token[slot, 0] = int(np.argmax(logits[slot]))

    def submit(self, req: Request):
        self.waiting.append(req)

    def tick(self) -> bool:
        for i in range(self.max_batch):
            if self.slots[i] is None and self.waiting:
                self._admit(i, self.waiting.pop(0))
        active = np.array([s is not None for s in self.slots])
        if not active.any():
            return False
        # guard capacity
        pos = np.asarray(self.state["pos"])
        active &= pos < self.max_len - 1
        logits = self._step(self.last_token, active)
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            if not active[i]:
                self.done.append(req)
                self.slots[i] = None
                continue
            nxt = int(np.argmax(logits[i]))
            req.out_tokens.append(nxt)
            self.last_token[i, 0] = nxt
            if len(req.out_tokens) >= req.max_new_tokens:
                self.done.append(req)
                self.slots[i] = None
        self.ticks += 1
        return True

    def run(self, max_ticks: int = 1000) -> int:
        while (self.waiting or any(s is not None for s in self.slots)) \
                and self.ticks < max_ticks:
            if not self.tick():
                break
        return self.ticks
