"""Hardened batched serving runtime: continuous batching over a fixed slot
pool with kNN-LM retrieval (the paper's engine) in the decode loop, plus the
production controls a long-lived server needs — admission control with an
explicit shed policy, per-request deadlines, a graceful plan-degradation
ladder, and fault-tolerant retrieval with a last-good datastore snapshot.

Requests enter a bounded waiting queue (submissions beyond ``max_queue``
are SHED immediately — better an explicit reject than unbounded latency);
free slots admit them by replaying the prompt through the decode step with
a one-hot ``active`` mask (per-row positions make the shared cache sound);
each ``tick`` then decodes one token for every live slot. Requests that
outlive ``deadline_ticks`` are evicted from the queue or their slot with a
``timed_out`` status instead of occupying capacity forever. Static shapes
throughout — the TPU-friendly analogue of continuous batching.

Degradation ladder (``DegradationPolicy``): under pressure (queue depth /
per-tick latency EWMA) the server downshifts the retrieval QueryPlan one
rung at a time —

    rung 0: full exact plan          (bit-identical to the bare server)
    rung 1..m: masked hamming-prefix probe at decreasing nprobe
               (requires a power-of-two bucket layout on the store)
    approx rungs: the MXU partial-reduce tier at recall_target 0.95,
               0.9, 0.8 — the approx recall floor ADAPTS to observed
               deadline pressure (EWMA walks it down, cooldown back up)
    last rung: retrieval-off decode  (LM softmax only)

— re-logging the active plan on every transition and recovering one rung
per ``cooldown_ticks`` of calm. Injected/real transient search failures
retry with bounded backoff, then try restoring the datastore from its
last-good snapshot, then — with a shard-fault-tolerance layer attached
(``shard_search``, dist/search.py) — the SHARD-LOSS rung: serve a
degraded-but-exact view of only the covered rows (honest coverage in
``stats()["shards"]``) before finally failing over to retrieval-off.
``_after_tick`` drives the shard layer's background re-replication and
swaps the full store back the moment coverage returns to 1.0.

Mutable stores (core/mutable.py) attach directly: the server serves one
installed epoch per view, runs cooperative compaction + flush + periodic
``audit()`` in ``_after_tick``, and admits online ``submit_append``/
``submit_delete`` with shed-on-backpressure when compaction falls behind
(``mutations_shed``/``pending_mutations`` in ``stats()``).

A multi-tenant arena (core/tenant.py) attaches via ``tenants``: the same
submit calls take a ``tenant=`` and walk the per-tenant shed ladder —
``quarantined`` (namespace failed verification/recovery), ``rate_limited``
(the tenant burned its ``max_mutations_per_tick`` fair share this tick —
a saturating tenant throttles ITSELF, it cannot starve a quiet one),
``quota_exceeded`` (row ceiling; retrying is pointless until deletes
land), ``backlog_full`` (transient compaction pressure; retry later).
``_after_tick`` runs quota-aware cooperative maintenance across tenants
and per-tenant counters land under ``stats()["tenants"]``.
"""
from __future__ import annotations

import collections
import dataclasses
import logging
import time
from typing import Deque, Dict, List, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.checkpoint import manager as ckpt
from repro.configs.base import ModelConfig
from repro.core import retrieval as retrieval_mod
from repro.core import tenant as tenant_mod
from repro.dist import sharding, steps as steps_mod
from repro.models import lm
from repro.runtime import faults as faults_mod

log = logging.getLogger(__name__)

QUEUED, ACTIVE, DONE, SHED, TIMED_OUT = (
    "queued", "active", "done", "shed", "timed_out")


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray          # (P,) int32
    max_new_tokens: int
    out_tokens: Optional[list] = None
    # ticks after submission before the request is evicted (queue OR slot)
    # with status "timed_out"; None = no deadline
    deadline_ticks: Optional[int] = None
    status: str = QUEUED
    finish_reason: str = ""     # complete | capacity | deadline | queue_full
    submit_tick: int = -1
    admit_tick: int = -1
    finish_tick: int = -1

    @property
    def queue_ticks(self) -> Optional[int]:
        if self.submit_tick < 0:
            return None
        end = self.admit_tick if self.admit_tick >= 0 else self.finish_tick
        return None if end < 0 else end - self.submit_tick


@dataclasses.dataclass(frozen=True)
class Rung:
    name: str
    retrieval: bool
    nprobe: int = 0             # 0 with retrieval -> the full exact plan
    select: str = ""            # "" -> the config's plan; "approx" -> the
                                # compute-bound MXU partial-reduce tier
    recall_target: float = 1.0  # approx rung only: degraded recall floor


@dataclasses.dataclass
class DegradationPolicy:
    """Pressure controller for the plan ladder.

    Downshifts one rung the moment queue depth reaches ``queue_high`` or
    the per-tick latency EWMA exceeds ``tick_high_s``; upshifts one rung
    after ``cooldown_ticks`` consecutive calm ticks (queue at or below
    ``queue_low`` and EWMA back under the high-water mark). One rung per
    tick in either direction — load spikes walk the ladder, they don't
    teleport past the cheap rungs.
    """

    queue_high: int = 8
    queue_low: int = 1
    tick_high_s: float = float("inf")
    alpha: float = 0.25         # EWMA smoothing
    cooldown_ticks: int = 8
    ewma_s: Optional[float] = None
    _calm: int = 0

    def update(self, rung: int, n_rungs: int, queue_depth: int,
               tick_s: float) -> int:
        self.ewma_s = tick_s if self.ewma_s is None else (
            self.alpha * tick_s + (1.0 - self.alpha) * self.ewma_s)
        pressured = (queue_depth >= self.queue_high
                     or self.ewma_s > self.tick_high_s)
        if pressured:
            self._calm = 0
            return min(rung + 1, n_rungs - 1)
        calm = (queue_depth <= self.queue_low
                and self.ewma_s <= self.tick_high_s)
        if not calm:
            self._calm = 0
            return rung
        if rung > 0:
            self._calm += 1
            if self._calm >= self.cooldown_ticks:
                self._calm = 0
                return rung - 1
        return rung


class Server:
    def __init__(self, cfg: ModelConfig, mesh, params, *, max_batch: int,
                 max_len: int, store=None, shard_axes=(),
                 max_queue: Optional[int] = None,
                 default_deadline_ticks: Optional[int] = None,
                 degradation: Optional[DegradationPolicy] = None,
                 fault_injector: Optional[faults_mod.FaultInjector] = None,
                 search_retries: int = 2, retry_backoff_s: float = 1e-3,
                 snapshot_dir: Optional[str] = None,
                 snapshot_every: Optional[int] = None,
                 audit_every: Optional[int] = None,
                 mutate_flush_every: int = 4,
                 tenants: Optional[tenant_mod.TenantArena] = None,
                 shard_search=None):
        self.cfg, self.mesh, self.params = cfg, mesh, params
        self.max_batch, self.max_len = max_batch, max_len
        # a MutableStore (core/mutable.py) serves through its installed
        # epoch: ``self.store`` is always a plain DataStore VIEW of one
        # epoch (refreshed in _after_tick when a newer epoch installs), so
        # the decode path never observes a half-mutated arena
        self.mstore = None
        self._store_epoch = -1
        if store is not None and hasattr(store, "datastore_view"):
            self.mstore = store
            store = store.datastore_view()
            self._store_epoch = self.mstore.epoch_seq
        self.store = store
        self.audit_every = audit_every
        self.mutate_flush_every = mutate_flush_every
        self.tenants = tenants
        self.tenant_counters: Dict[str, collections.Counter] = (
            collections.defaultdict(collections.Counter))
        self._tenant_tick_mut: Dict[str, int] = {}
        self.with_retrieval = cfg.retrieval.enabled and store is not None
        # shard-fault-tolerance layer (dist/search.FaultTolerantSearch over
        # the SAME corpus): when attached, the server tracks its coverage —
        # a dead shard swaps in a degraded store VIEW of only the covered
        # rows (the shard-loss rung of the failover ladder), maintenance
        # re-replicates in the background, and recovery swaps the full
        # store back. The view search is exact over the surviving rows;
        # coverage is surfaced in stats()["shards"], never silently lost.
        self.shard_search = shard_search
        self._full_store = store
        self._shard_cov_sig = None
        self._shard_view_cache: Dict[tuple, object] = {}
        if shard_search is not None:
            if store is None:
                raise ValueError("shard_search needs a datastore to shadow")
            n_store = int(store.codes.shape[0])
            if shard_search.map.total_rows != n_store:
                raise ValueError(
                    f"shard_search covers {shard_search.map.total_rows} "
                    f"rows but the store has {n_store}")
            self._shard_cov_sig = shard_search.covered_ranges()
        self.max_queue = max_queue
        self.default_deadline_ticks = default_deadline_ticks
        self.policy = degradation
        self.faults = fault_injector
        self.search_retries = search_retries
        self.retry_backoff_s = retry_backoff_s
        self.snapshot_dir = snapshot_dir
        self.snapshot_every = snapshot_every
        # resolve and log the retrieval QueryPlan once per store at startup
        # (retrieval.log_store_plan). ``shard_axes``: the mesh axes the
        # serve step searches the datastore over — with them the logged
        # plan is the SHARDED plan decode actually runs, including the
        # merge strategy (hist_merge vs concat_sort) and its predicted
        # cross-device traffic; without them it is the store's LOCAL plan.
        self.retrieval_plan = None
        if self.with_retrieval:
            self.retrieval_plan = retrieval_mod.log_store_plan(
                store, cfg.retrieval, q=max_batch, logger=log,
                mesh=mesh if shard_axes else None,
                axes=tuple(shard_axes))
        self.rungs = self._build_ladder()
        self.rung = 0
        self._fns: Dict[Rung, object] = {}
        _, _, self.sspecs = steps_mod.make_serve_step(
            cfg, mesh, max_len, with_retrieval=self.with_retrieval)
        self._rung_fn(self.rungs[0])      # compile path for the top rung
        with mesh:
            self.state = jax.jit(
                lambda: lm.init_decode_state(cfg, max_batch, max_len),
                out_shardings=sharding.named(mesh, self.sspecs))()
        self.slots: List[Optional[Request]] = [None] * max_batch
        self.last_token = np.zeros((max_batch, 1), np.int32)
        self.waiting: Deque[Request] = collections.deque()
        self.done: List[Request] = []
        self.shed: List[Request] = []
        self.timed_out: List[Request] = []
        self.ticks = 0
        self.transitions: List[tuple] = []   # (tick, from, to, why)
        self.counters = collections.Counter()
        self.tick_s: List[float] = []
        self.token_lat_s: List[float] = []
        self.queue_wait_ticks: List[int] = []
        if (self.with_retrieval and snapshot_dir is not None
                and self.mstore is None):
            # last-good snapshot baseline: written before serving starts,
            # so a corrupted store always has something to fall back to
            # (a MutableStore snapshots into its own root at create time)
            ckpt.save(snapshot_dir, 0, self.store, blocking=True)
            self.counters["snapshot_saves"] += 1

    # -- degradation ladder -----------------------------------------------

    def _build_ladder(self) -> List[Rung]:
        if not self.with_retrieval:
            return [Rung("decode", False, 0)]
        rungs = [Rung("exact", True, 0)]
        self._probe_positions = None
        if self.policy is not None and self.store.layout is not None:
            self._probe_positions = retrieval_mod.probe_key_positions(
                self.store, self.cfg.retrieval)
            if self._probe_positions is not None:
                B = self.store.layout.n_buckets
                nprobes = sorted({max(1, B // 4), max(1, B // 16)},
                                 reverse=True)
                rungs += [Rung(f"probe{n}", True, n)
                          for n in nprobes if n < B]
        if self.policy is not None:
            # the last rungs that still retrieve: the compute-bound approx
            # tier at a bounded recall loss — cheaper than any masked probe
            # (no candidate re-streaming, one matmul + tiny pool merge) but
            # still a real neighbor distribution, so load has more stops
            # before retrieval quality drops to zero. THREE rungs at
            # decreasing recall_target: the policy's EWMA pressure walks
            # rt 0.95 -> 0.9 -> 0.8 one rung per pressured tick and the
            # cooldown walks it back — the approx tier's recall floor
            # adapts to observed deadline pressure instead of being pinned
            rungs += [Rung(f"approx_rt{int(rt * 100)}", True, 0,
                           select="approx", recall_target=rt)
                      for rt in (0.95, 0.9, 0.8)]
        rungs.append(Rung("retrieval_off", False, 0))
        return rungs

    def _rung_fn(self, r: Rung):
        if r not in self._fns:
            fn, _, _ = steps_mod.make_serve_step(
                self.cfg, self.mesh, self.max_len,
                with_retrieval=r.retrieval, nprobe=r.nprobe,
                probe_positions=(self._probe_positions if r.nprobe else None),
                select=r.select or None,
                recall_target=(r.recall_target if r.select == "approx"
                               else None))
            self._fns[r] = fn
        return self._fns[r]

    def _rung_plan_str(self, r: Rung) -> str:
        if not r.retrieval:
            return "retrieval_off"
        if r.select == "approx":
            return retrieval_mod.plan_for_store(
                self.store, self.cfg.retrieval, self.max_batch,
                select="approx", recall_target=r.recall_target).compact()
        if r.nprobe:
            return retrieval_mod.degraded_plan_for_store(
                self.store, self.cfg.retrieval, self.max_batch,
                r.nprobe).compact()
        return (self.retrieval_plan.compact()
                if self.retrieval_plan is not None else "exact")

    def _set_rung(self, idx: int, why: str):
        if idx == self.rung:
            return
        old, new = self.rungs[self.rung], self.rungs[idx]
        self.rung = idx
        self.transitions.append((self.ticks, old.name, new.name, why))
        self.counters["transitions"] += 1
        log.info("degradation: %s -> %s (%s); active plan %s",
                 old.name, new.name, why, self._rung_plan_str(new))

    # -- the decode step (guarded) ----------------------------------------

    def _step(self, token: np.ndarray, active: np.ndarray, r: Rung):
        if r.nprobe and self.store is not self._full_store:
            # masked-probe fns are compiled against the FULL store's bucket
            # layout; a shard-degraded view has no layout — serve the view
            # through the exact plan instead of a mis-aimed probe
            r = self.rungs[0]
        fn = self._rung_fn(r)
        args = (self.params, jnp.asarray(token), self.state,
                jnp.asarray(active))
        if r.retrieval:
            args = args + (self.store,)
        with self.mesh:
            logits, self.state = fn(*args)
        return np.asarray(logits.astype(jnp.float32))[:, 0, :]

    def _guarded_step(self, token: np.ndarray, active: np.ndarray):
        """One decode step at the current rung with the failure ladder:
        bounded retry-with-backoff -> last-good snapshot restore ->
        retrieval-off failover. The injector's check sits BEFORE the jitted
        call, so a failed attempt never half-advanced the decode state."""
        r = self.rungs[self.rung]
        inj = self.faults

        def attempt():
            if inj is not None and r.retrieval:
                inj.check("store_search")
            return self._step(token, active, r)

        def count_retry(_e, _attempt):
            self.counters["search_retries"] += 1

        try:
            return faults_mod.retry_call(
                attempt, retries=self.search_retries,
                backoff_s=self.retry_backoff_s, on_retry=count_retry)
        except faults_mod.TRANSIENT:
            self.counters["search_failures"] += 1
        if self.snapshot_dir is not None and self._restore_store_snapshot():
            try:
                if inj is not None:
                    inj.check("store_search")
                return self._step(token, active, r)
            except faults_mod.TRANSIENT:
                self.counters["search_failures"] += 1
        # shard-loss rung: if the shard layer says part of the fleet is
        # gone, serve the degraded-but-exact surviving-rows view before
        # giving up on retrieval entirely — a partial answer with honest
        # coverage beats no retrieval at all
        if self.shard_search is not None and self._refresh_shard_view():
            try:
                if inj is not None:
                    inj.check("store_search")
                out = self._step(token, active, r)
                self.counters["shard_failover_ticks"] += 1
                return out
            except faults_mod.TRANSIENT:
                self.counters["search_failures"] += 1
        # the search is unavailable this tick: decode without retrieval
        # rather than stalling every slot; the policy walks back up once
        # the store recovers
        self.counters["failover_ticks"] += 1
        self._set_rung(len(self.rungs) - 1, "search failover")
        return self._step(token, active, self.rungs[self.rung])

    def _restore_store_snapshot(self) -> bool:
        if self.mstore is not None:
            # an installed epoch is immutable — there is no mid-process
            # corruption to roll back; durability lives in the store's own
            # WAL + snapshots and is exercised by process-level recovery
            # (MutableStore.recover), not the serve loop
            return False
        inj = self.faults

        def load():
            if inj is not None:
                inj.check("ckpt_restore")
            return ckpt.restore_latest(self.snapshot_dir, self.store)

        try:
            step, tree = faults_mod.retry_call(
                load, retries=self.search_retries,
                backoff_s=self.retry_backoff_s)
        except faults_mod.TRANSIENT:
            self.counters["snapshot_restore_failures"] += 1
            return False
        if tree is None:
            return False
        self.store = tree
        if self.shard_search is not None:
            # the snapshot is the FULL store; re-sync the shard view to
            # current coverage on the next refresh
            self._full_store = tree
            self._shard_view_cache.clear()
            self._shard_cov_sig = None
        self.counters["snapshot_restores"] += 1
        log.info("datastore restored from snapshot step %s", step)
        return True

    def _refresh_shard_view(self) -> bool:
        """Sync ``self.store`` to the shard layer's current coverage:
        full store when every range is covered, else a degraded VIEW of
        only the covered rows (original row order, no layout — exact plan).
        Views are cached per coverage signature so a flapping shard never
        rebuilds the same view twice. Returns True iff the store swapped."""
        sig = self.shard_search.covered_ranges()
        if sig == self._shard_cov_sig:
            return False
        self._shard_cov_sig = sig
        cov = self.shard_search.coverage()
        if cov.complete:
            self.store = self._full_store
            self.counters["shard_recoveries"] += 1
            log.info("shard coverage restored: serving the full store "
                     "(%d rows)", cov.total_rows)
            return True
        view = self._shard_view_cache.get(sig)
        if view is None:
            m = self.shard_search.covered_row_ids()
            view = self._full_store._replace(
                codes=jnp.asarray(np.asarray(self._full_store.codes)[m]),
                values=jnp.asarray(np.asarray(self._full_store.values)[m]),
                layout=None, key_positions=None)
            self._shard_view_cache[sig] = view
        self.store = view
        self.counters["shard_losses"] += 1
        log.info("shard loss: serving degraded store view %s "
                 "(coverage %.3f, dead=%s)", sig, cov.coverage_frac,
                 list(cov.dead_shards))
        return True

    def _save_store_snapshot(self):
        if self.mstore is not None:
            if self.mstore.root is None:
                return
            try:
                self.mstore.snapshot()
                self.counters["snapshot_saves"] += 1
            except faults_mod.TRANSIENT:
                self.counters["snapshot_save_failures"] += 1
            return
        hook = self.faults.hook("ckpt_save") if self.faults else None
        try:
            ckpt.save(self.snapshot_dir, self.ticks, self.store,
                      blocking=True, fault_hook=hook)
            self.counters["snapshot_saves"] += 1
            # sweeps crashed .tmp dirs along with old committed steps
            ckpt.garbage_collect(self.snapshot_dir, keep=2)
        except faults_mod.TRANSIENT:
            self.counters["snapshot_save_failures"] += 1

    # -- mutation admission (mutable stores) --------------------------------

    def _tenant_shed_reason(self, tid: str, n: int,
                            is_append: bool) -> Optional[str]:
        """The per-tenant admission ladder, most to least absolute:
        quarantined -> rate_limited -> quota_exceeded -> backlog_full.
        Deletes skip the capacity reasons — they relieve pressure, and
        shedding them would wedge a tenant at its quota forever."""
        t = self.tenants.tenants[tid]
        if t.status != tenant_mod.HEALTHY:
            return "quarantined"
        lim = t.quota.max_mutations_per_tick
        if lim is not None and self._tenant_tick_mut.get(tid, 0) + n > lim:
            return "rate_limited"
        return self.tenants.admission_check(tid, n) if is_append else None

    def _tenant_mutate(self, tid: str, n: int, is_append: bool, fn) -> bool:
        tc = self.tenant_counters[tid]
        reason = self._tenant_shed_reason(tid, n, is_append)
        if reason is not None:
            tc["mutations_shed"] += n
            tc["shed_" + reason] += n
            self.counters["mutations_shed"] += n
            return False
        try:
            fn()
        except faults_mod.TRANSIENT:
            tc["mutation_failures"] += 1
            self.counters["mutation_failures"] += 1
            return False
        self._tenant_tick_mut[tid] = self._tenant_tick_mut.get(tid, 0) + n
        tc["mutations_applied"] += n
        self.counters["mutations_applied"] += n
        return True

    def tenant_search(self, queries, k: int):
        """Mixed-tenant batched search through the packed arena (one fused
        kernel pair for the whole batch), with the same bounded retry the
        decode-path search gets."""
        assert self.tenants is not None, "no tenant arena attached"

        def attempt():
            if self.faults is not None:
                self.faults.check("store_search")
            return self.tenants.search(queries, k)

        try:
            res = faults_mod.retry_call(attempt, retries=self.search_retries,
                                        backoff_s=self.retry_backoff_s)
        except faults_mod.TRANSIENT:
            self.counters["search_failures"] += 1
            raise
        for tid in queries:
            self.tenant_counters[tid]["searches"] += 1
        return res

    def submit_append(self, codes, values=None, tenant=None) -> bool:
        """Admit an online append to the mutable store. SHED (False) when
        compaction has fallen behind — the store's acked-durable backlog
        is bounded, so admission backpressure is the only honest answer
        (surfaced as ``mutations_shed`` in stats()). False also means NOT
        acknowledged: a WAL fault before the fsync sheds rather than acks.
        With ``tenant``, admission walks the per-tenant ladder
        (``_tenant_shed_reason``) against that tenant's quota instead.
        """
        n = int(np.atleast_2d(np.asarray(codes)).shape[0])
        if tenant is not None:
            return self._tenant_mutate(
                tenant, n, True,
                lambda: self.tenants.append(tenant, codes, values=values))
        assert self.mstore is not None, "no mutable store attached"
        if self.mstore.backlog_full:
            self.counters["mutations_shed"] += n
            return False
        try:
            self.mstore.append(codes, values=values)
        except faults_mod.TRANSIENT:
            self.counters["mutation_failures"] += 1
            return False
        self.counters["mutations_applied"] += n
        return True

    def submit_delete(self, ids, tenant=None) -> bool:
        n = int(np.atleast_1d(np.asarray(ids)).shape[0])
        if tenant is not None:
            return self._tenant_mutate(
                tenant, n, False,
                lambda: self.tenants.delete(tenant, ids))
        assert self.mstore is not None, "no mutable store attached"
        if self.mstore.backlog_full:
            self.counters["mutations_shed"] += n
            return False
        try:
            self.mstore.delete(ids)
        except faults_mod.TRANSIENT:
            self.counters["mutation_failures"] += 1
            return False
        self.counters["mutations_applied"] += n
        return True

    def _store_maintenance(self):
        """Per-tick mutable-store lifecycle: cooperative compaction, epoch
        install for pending mutations, view refresh, periodic audit. Every
        step is fault-guarded — an injected crash retries next tick."""
        m = self.mstore
        try:
            if m.maybe_compact():
                self.counters["compactions"] += 1
        except faults_mod.TRANSIENT:
            self.counters["compact_failures"] += 1
        if (m.pending_mutations
                and self.ticks % self.mutate_flush_every == 0):
            try:
                m.flush()
            except faults_mod.TRANSIENT:
                self.counters["flush_failures"] += 1
        if m.epoch_seq != self._store_epoch:
            self._store_epoch = m.epoch_seq
            self.store = m.datastore_view()
        if self.audit_every and self.ticks % self.audit_every == 0:
            self.counters["audits"] += 1
            report = m.audit(strict=False)
            if not report["ok"]:
                self.counters["audit_failures"] += 1
                log.error("store audit FAILED: %s", report["problems"])

    def _tenant_maintenance(self):
        """Per-tick multi-tenant lifecycle: refresh every tenant's rate
        budget, run quota-aware cooperative maintenance (deepest backlog
        compacts first, bounded per tick so one churning tenant cannot
        monopolize the maintenance budget), periodic snapshots per
        namespace. Per-tenant failures are contained by the arena."""
        self._tenant_tick_mut = {}
        rep = self.tenants.maintain(
            compact_budget=1,
            flush=(self.ticks % self.mutate_flush_every == 0))
        self.counters["compactions"] += len(rep["compacted"])
        for tid in rep["failed"]:
            self.tenant_counters[tid]["maintenance_failures"] += 1
        if (self.snapshot_every and self.tenants.root is not None
                and self.ticks % self.snapshot_every == 0):
            for tid, step in self.tenants.snapshot().items():
                if step < 0:
                    self.tenant_counters[tid]["snapshot_save_failures"] += 1
                    self.counters["snapshot_save_failures"] += 1
                else:
                    self.counters["snapshot_saves"] += 1

    # -- admission / eviction ---------------------------------------------

    def submit(self, req: Request) -> bool:
        """Returns False when the request was shed at the door."""
        req.submit_tick = self.ticks
        self.counters["submitted"] += 1
        if req.deadline_ticks is None:
            req.deadline_ticks = self.default_deadline_ticks
        if self.max_queue is not None and len(self.waiting) >= self.max_queue:
            req.status, req.finish_reason = SHED, "queue_full"
            req.finish_tick = self.ticks
            self.shed.append(req)
            self.counters["shed"] += 1
            return False
        req.status = QUEUED
        self.waiting.append(req)
        return True

    def _admit(self, slot: int, req: Request):
        """Replay the prompt through the decode path for one slot."""
        req.out_tokens = []
        req.status, req.admit_tick = ACTIVE, self.ticks
        if req.queue_ticks is not None:
            self.queue_wait_ticks.append(req.queue_ticks)
        self.slots[slot] = req
        # a reused slot must restart at position 0 — the retiring request
        # left its row's ``pos`` at wherever it stopped, and the per-row
        # position is what makes the shared cache sound (stale rows beyond
        # ``pos`` are masked by position, so no cache wipe is needed)
        pos = jnp.broadcast_to(jnp.asarray(self.state["pos"], jnp.int32),
                               (self.max_batch,))
        self.state = dict(self.state, pos=pos.at[slot].set(0))
        active = np.zeros(self.max_batch, bool)
        active[slot] = True
        tok = np.zeros((self.max_batch, 1), np.int32)
        # an empty prompt replays a single BOS/zero token: the decode step
        # still needs one forward to produce first-token logits, and
        # ``logits`` must never stay None (np.argmax(None) crash)
        prompt = req.prompt if len(req.prompt) else np.zeros((1,), np.int32)
        logits = None
        for t in prompt:
            tok[slot, 0] = int(t)
            logits = self._guarded_step(tok, active)
        self.last_token[slot, 0] = int(np.argmax(logits[slot]))

    def _expired(self, req: Request) -> bool:
        return (req.deadline_ticks is not None
                and self.ticks - req.submit_tick >= req.deadline_ticks)

    def _retire(self, slot: int, status: str, reason: str):
        req = self.slots[slot]
        self.slots[slot] = None
        req.status, req.finish_reason = status, reason
        req.finish_tick = self.ticks
        (self.done if status == DONE else self.timed_out).append(req)
        self.counters[status] += 1

    def _evict_expired(self):
        if self.waiting:
            still: Deque[Request] = collections.deque()
            for req in self.waiting:
                if self._expired(req):
                    req.status, req.finish_reason = TIMED_OUT, "deadline"
                    req.finish_tick = self.ticks
                    self.timed_out.append(req)
                    self.counters[TIMED_OUT] += 1
                else:
                    still.append(req)
            self.waiting = still
        for i, req in enumerate(self.slots):
            if req is not None and self._expired(req):
                self._retire(i, TIMED_OUT, "deadline")

    # -- the serving loop --------------------------------------------------

    def tick(self) -> bool:
        """One serving tick. Always advances the clock (deadlines are
        measured in ticks); returns True iff any decode work happened."""
        t0 = time.perf_counter()
        self._evict_expired()
        for i in range(self.max_batch):
            if self.slots[i] is None and self.waiting:
                self._admit(i, self.waiting.popleft())
        occupied = np.array([s is not None for s in self.slots])
        if not occupied.any():
            self.ticks += 1
            self._after_tick(time.perf_counter() - t0, worked=False)
            return False
        # guard capacity: rows at max_len - 1 retire without decoding
        pos = np.asarray(self.state["pos"])
        active = occupied & (pos < self.max_len - 1)
        capped = occupied & ~active
        logits = self._guarded_step(self.last_token, active) \
            if active.any() else None
        for i in np.where(capped)[0]:
            self._retire(int(i), DONE, "capacity")
        emitted = 0
        if logits is not None:
            for i, req in enumerate(self.slots):
                if req is None or not active[i]:
                    continue
                nxt = int(np.argmax(logits[i]))
                req.out_tokens.append(nxt)
                emitted += 1
                self.last_token[i, 0] = nxt
                if len(req.out_tokens) >= req.max_new_tokens:
                    self._retire(i, DONE, "complete")
        self.ticks += 1
        dt = time.perf_counter() - t0
        if emitted:
            self.token_lat_s.extend([dt / emitted] * emitted)
        self._after_tick(dt, worked=True)
        return True

    def _after_tick(self, dt: float, worked: bool):
        self.counters["ticks"] += 1
        if worked:
            self.counters["work_ticks"] += 1
            self.tick_s.append(dt)
            if self.rung > 0:
                self.counters["degraded_ticks"] += 1
        if self.mstore is not None:
            self._store_maintenance()
        if self.tenants is not None:
            self._tenant_maintenance()
        if self.shard_search is not None:
            # bounded background re-replication + recovery promotion, then
            # keep the serving view in lockstep with coverage (a revived
            # fleet swaps the full store back in without waiting for a
            # search failure to notice)
            m = self.shard_search.maintain(budget=1)
            self.counters["shard_rebuilt_ranges"] += m["copied"]
            self._refresh_shard_view()
            if self.store is not self._full_store:
                self.counters["shard_degraded_ticks"] += 1
        if self.policy is not None and len(self.rungs) > 1:
            new = self.policy.update(self.rung, len(self.rungs),
                                     len(self.waiting), dt)
            if new != self.rung:
                why = (f"queue={len(self.waiting)} "
                       f"ewma={self.policy.ewma_s * 1e3:.1f}ms")
                self._set_rung(new, why)
        if (self.snapshot_dir is not None and self.snapshot_every
                and self.with_retrieval
                and self.ticks % self.snapshot_every == 0):
            self._save_store_snapshot()

    @property
    def has_work(self) -> bool:
        return bool(self.waiting) or any(s is not None for s in self.slots)

    def run(self, max_ticks: int = 1000) -> int:
        while self.has_work and self.ticks < max_ticks:
            if not self.tick():
                break
        return self.ticks

    # -- SLO accounting ----------------------------------------------------

    def stats(self) -> dict:
        """Outcome counters + latency percentiles; ``lost`` MUST be 0 —
        every submitted request is done, shed, timed out, or still in
        flight."""
        c = self.counters
        in_flight = sum(s is not None for s in self.slots) + len(self.waiting)

        def pct(xs, q):
            return float(np.percentile(np.asarray(xs), q)) if len(xs) else 0.0

        work = max(c["work_ticks"], 1)
        return {
            "submitted": c["submitted"],
            "done": c["done"],
            "shed": c["shed"],
            "timed_out": c["timed_out"],
            "in_flight": in_flight,
            "lost": (c["submitted"] - c["done"] - c["shed"] - c["timed_out"]
                     - in_flight),
            "ticks": self.ticks,
            "work_ticks": c["work_ticks"],
            "degraded_ticks": c["degraded_ticks"],
            "degraded_frac": c["degraded_ticks"] / work,
            "shed_frac": c["shed"] / max(c["submitted"], 1),
            "timeout_frac": c["timed_out"] / max(c["submitted"], 1),
            "transitions": c["transitions"],
            "search_retries": c["search_retries"],
            "search_failures": c["search_failures"],
            "failover_ticks": c["failover_ticks"],
            "snapshot_saves": c["snapshot_saves"],
            "snapshot_save_failures": c["snapshot_save_failures"],
            "snapshot_restores": c["snapshot_restores"],
            "snapshot_restore_failures": c["snapshot_restore_failures"],
            "p50_token_s": pct(self.token_lat_s, 50),
            "p99_token_s": pct(self.token_lat_s, 99),
            "p50_queue_ticks": pct(self.queue_wait_ticks, 50),
            "p99_queue_ticks": pct(self.queue_wait_ticks, 99),
            "mean_tick_s": float(np.mean(self.tick_s)) if self.tick_s else 0.0,
            "rung": self.rungs[self.rung].name,
            # mutable-store surface (zeros for static stores)
            "mutations_applied": c["mutations_applied"],
            "mutations_shed": c["mutations_shed"],
            "mutation_failures": c["mutation_failures"],
            "pending_mutations": (self.mstore.pending_mutations
                                  if self.mstore is not None else 0),
            "store_epoch": (self.mstore.epoch_seq
                            if self.mstore is not None else -1),
            "compactions": c["compactions"],
            "compact_failures": c["compact_failures"],
            "flush_failures": c["flush_failures"],
            "audits": c["audits"],
            "audit_failures": c["audit_failures"],
            **self._shard_stats(),
            **self._tenant_stats(),
        }

    def _shard_stats(self) -> dict:
        if self.shard_search is None:
            return {}
        cov = self.shard_search.coverage()
        return {"shards": self.shard_search.stats(),
                "coverage_frac": cov.coverage_frac,
                "shard_losses": self.counters["shard_losses"],
                "shard_recoveries": self.counters["shard_recoveries"],
                "shard_degraded_ticks": self.counters["shard_degraded_ticks"],
                "shard_failover_ticks": self.counters["shard_failover_ticks"],
                "shard_rebuilt_ranges": self.counters["shard_rebuilt_ranges"]}

    def _tenant_stats(self) -> dict:
        if self.tenants is None:
            return {}
        t = self.tenants.stats()
        per = t["tenants"]
        for tid, row in per.items():
            row.update(self.tenant_counters.get(tid, {}))
        return {"tenants": per,
                "n_tenants": t["n_tenants"],
                "n_quarantined": t["n_quarantined"],
                "packed_seq": t["packed_seq"],
                "packed_rows": t["packed_rows"]}
