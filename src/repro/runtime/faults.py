"""Seeded fault injection and bounded retry for the serving/checkpoint path.

The injector is probability-per-call and fully seeded: a soak run with the
same seed injects the same fault sequence, so "survives 500 ticks at
p=0.05" is a reproducible pin, not a flake. Sites are plain strings — the
server uses ``store_search`` around the retrieval step and
``ckpt_save``/``ckpt_restore`` through the checkpoint manager's
``fault_hook`` seam; the mutable datastore (core/mutable.py) adds
``wal_append`` (before the intent-log write — a fired fault means the
mutation was never acked), ``compact_build`` (before the rebuilt arena is
swapped in), and ``epoch_install`` (before a fresh epoch is swapped in).
The shard-fault-tolerance layer (dist/search.py) adds ``shard_hist``
(before a unit's pass-1 histogram), ``shard_emit`` (before a unit's
pass-2 winner emission) and ``merge_psum`` (before each hierarchical
host-merge round) — all scoped per unit via ``site@unit`` so a soak can
kill exactly one shard's calls while the fleet runs the base rate.

Multi-tenant scoping (core/tenant.py): a site may be scoped to one tenant
as ``"<site>@<tenant>"`` (:func:`site_key`). ``check(site, tenant=...)``
looks the scoped key up first and falls back to the base site's
probability, so a soak can poison exactly one tenant's WAL writes while
every other tenant runs the shared base rate — and the per-site counters
are kept under the scoped key, so blast-radius assertions can attribute
every fired fault to the tenant it hit.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, Mapping, Optional, Tuple


class InjectedFault(RuntimeError):
    """A fault raised by the injector (always transient by construction)."""

    def __init__(self, site: str):
        super().__init__(f"injected fault at {site!r}")
        self.site = site


# Exception classes the retry loops treat as transient. Anything else is a
# real bug and must propagate — retrying around it would hide it.
TRANSIENT = (InjectedFault, TimeoutError, ConnectionError)


def site_key(site: str, tenant: Optional[str] = None) -> str:
    """Canonical key for a (site, tenant) pair: ``site`` bare, or
    ``site@tenant`` when scoped to one tenant of a multi-tenant arena."""
    return site if tenant is None else f"{site}@{tenant}"


class FaultInjector:
    """Seeded probability-per-call fault injector.

    ``p`` maps site -> probability a call at that site raises
    ``InjectedFault``; ``stall`` maps site -> (probability, seconds) a call
    sleeps before proceeding (a slow store, not a dead one). Counters per
    site (``calls``/``fired``/``stalled``) let tests assert faults actually
    exercised the path under test.
    """

    def __init__(self, seed: int = 0,
                 p: Optional[Mapping[str, float]] = None,
                 stall: Optional[Mapping[str, Tuple[float, float]]] = None,
                 sleep: Callable[[float], None] = time.sleep):
        import numpy as np
        self._rng = np.random.default_rng(seed)
        self.p: Dict[str, float] = dict(p or {})
        self.stall: Dict[str, Tuple[float, float]] = dict(stall or {})
        self._sleep = sleep
        self.calls: Dict[str, int] = {}
        self.fired: Dict[str, int] = {}
        self.stalled: Dict[str, int] = {}

    def check(self, site: str, tenant: Optional[str] = None) -> None:
        """Maybe stall, maybe raise — call at the top of a faultable op.

        With ``tenant``, the scoped ``site@tenant`` probability wins when
        configured, else the base site's rate applies; counters always land
        under the scoped key so fired faults stay attributable."""
        key = site_key(site, tenant)
        self.calls[key] = self.calls.get(key, 0) + 1
        sp = self.stall.get(key, self.stall.get(site) if tenant else None)
        if sp is not None and self._rng.random() < sp[0]:
            self.stalled[key] = self.stalled.get(key, 0) + 1
            self._sleep(sp[1])
        prob = self.p.get(key, self.p.get(site, 0.0) if tenant else 0.0)
        if self._rng.random() < prob:
            self.fired[key] = self.fired.get(key, 0) + 1
            raise InjectedFault(key)

    def hook(self, site: str,
             tenant: Optional[str] = None) -> Callable[[], None]:
        """Zero-arg adapter for ``fault_hook`` seams (checkpoint manager)."""
        return lambda: self.check(site, tenant)


def retry_call(fn: Callable, *, retries: int = 2, backoff_s: float = 1e-3,
               max_backoff_s: float = 0.05, transient=TRANSIENT,
               on_retry: Optional[Callable] = None,
               sleep: Callable[[float], None] = time.sleep,
               jitter: str = "full", rng=None,
               deadline_s: Optional[float] = None,
               clock: Callable[[], float] = time.monotonic):
    """Call ``fn()`` with up to ``retries`` retries on transient errors;
    the last error re-raises.

    Backoff is FULL-JITTERED by default: attempt ``i`` sleeps
    ``U(0, min(max_backoff_s, backoff_s * 2**i))`` — the exponential
    envelope caps at ``max_backoff_s`` (the max-delay cap) and the uniform
    draw decorrelates the many slots that all hit the same recovering
    store at once; plain synchronized doubling would have every retry
    stampede it on the same schedule. ``jitter="none"`` keeps the legacy
    deterministic doubling (still capped). ``rng`` seeds the draws (an int
    or a numpy Generator) so fault soaks stay reproducible.

    ``deadline_s`` is the caller's REMAINING request budget, measured on
    ``clock`` from entry: every backoff sleep is clamped to the budget
    left after the failing attempt, and once the budget is exhausted the
    next transient error re-raises immediately instead of sleeping — the
    retry envelope can never push a request past its deadline. (Attempts
    themselves are not interrupted; the budget bounds the sleep schedule,
    which is what backoff adds on top of the caller's own work.)"""
    assert jitter in ("full", "none"), jitter
    if jitter == "full":
        import numpy as np
        if not hasattr(rng, "uniform"):
            rng = np.random.default_rng(rng)
    t0 = clock() if deadline_s is not None else 0.0
    delay = min(backoff_s, max_backoff_s)
    for attempt in range(retries + 1):
        try:
            return fn()
        except transient as e:
            if attempt == retries:
                raise
            want = rng.uniform(0.0, delay) if jitter == "full" else delay
            if deadline_s is not None:
                remaining = deadline_s - (clock() - t0)
                if remaining <= 0.0:
                    raise
                want = min(want, remaining)
            if on_retry is not None:
                on_retry(e, attempt)
            sleep(want)
            delay = min(delay * 2.0, max_backoff_s)
