"""Fault-tolerant training loop.

Features (the large-scale-runnability contract):
* auto-resume from the latest committed checkpoint (params, opt state, step);
* periodic async checkpointing + final checkpoint on exception/SIGTERM;
* deterministic-by-step data (any host can recompute any batch — restart or
  work-steal without data-state handoff);
* straggler monitor: EWMA of step time, flags steps > ``straggler_factor`` x
  the running mean (on real multi-host this feeds the rebalance/eviction
  policy; here it logs and counts);
* preemption simulation hook for tests (``preempt_at``).
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint import manager as ckpt
from repro.configs.base import ModelConfig, TrainConfig
from repro.data import pipeline
from repro.dist import sharding, steps as steps_mod
from repro.models import lm
from repro.optim import optimizer


@dataclasses.dataclass
class TrainerReport:
    steps_done: int
    final_loss: float
    resumed_from: Optional[int]
    straggler_steps: int
    step_times: list


class PreemptionError(RuntimeError):
    pass


def train(cfg: ModelConfig, tc: TrainConfig, mesh, *, seq_len: int,
          global_batch: int, ckpt_dir: Optional[str] = None,
          ckpt_every: int = 50, log_every: int = 10,
          straggler_factor: float = 3.0,
          preempt_at: Optional[int] = None,
          on_metrics: Optional[Callable] = None) -> TrainerReport:
    step_fn, pspecs, ospecs = steps_mod.make_train_step(cfg, mesh, tc)
    p_sh = sharding.named(mesh, pspecs)
    o_sh = sharding.named(mesh, ospecs)

    with mesh:
        params = jax.jit(
            lambda: lm.init_params(jax.random.PRNGKey(tc.seed), cfg),
            out_shardings=p_sh)()
        opt_state = jax.jit(lambda p: optimizer.init(p, tc),
                            out_shardings=o_sh)(params)

    start_step, resumed_from = 0, None
    if ckpt_dir is not None:
        latest = ckpt.latest_step(ckpt_dir)
        if latest is not None:
            params = ckpt.restore(ckpt_dir, latest, params, p_sh)
            opt_state = ckpt.restore(ckpt_dir + "/opt", latest, opt_state, o_sh)
            start_step, resumed_from = latest, latest

    dc = pipeline.data_config_for(cfg, seq_len, global_batch, tc.seed)
    ewma, stragglers, times = None, 0, []
    save_thread = None
    final_loss = float("nan")
    interrupted = {"flag": False}

    def _sigterm(*_):
        interrupted["flag"] = True

    old_handler = signal.signal(signal.SIGTERM, _sigterm)
    step = start_step
    try:
        with mesh:
            while step < tc.total_steps:
                if preempt_at is not None and step == preempt_at:
                    raise PreemptionError(f"simulated preemption at {step}")
                batch_np = pipeline.make_batch(dc, step)
                batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
                t0 = time.perf_counter()
                params, opt_state, metrics = step_fn(
                    params, opt_state, batch, jnp.asarray(step))
                final_loss = float(metrics["loss"])
                dt = time.perf_counter() - t0
                times.append(dt)
                if ewma is not None and dt > straggler_factor * ewma:
                    stragglers += 1
                    print(f"[straggler] step {step}: {dt:.3f}s vs EWMA {ewma:.3f}s")
                ewma = dt if ewma is None else 0.9 * ewma + 0.1 * dt
                if on_metrics is not None:
                    on_metrics(step, metrics)
                if log_every and step % log_every == 0:
                    print(f"step {step}: loss={final_loss:.4f} "
                          f"gnorm={float(metrics['grad_norm']):.3f} {dt*1e3:.0f}ms")
                step += 1
                if ckpt_dir is not None and step % ckpt_every == 0:
                    if save_thread is not None:
                        save_thread.join()
                    ckpt.save(ckpt_dir, step, params, blocking=True)
                    save_thread = ckpt.save(ckpt_dir + "/opt", step, opt_state,
                                            blocking=False)
                if interrupted["flag"]:
                    raise PreemptionError("SIGTERM")
    except PreemptionError:
        if ckpt_dir is not None:
            if save_thread is not None:
                save_thread.join()
            ckpt.save(ckpt_dir, step, params, blocking=True)
            ckpt.save(ckpt_dir + "/opt", step, opt_state, blocking=True)
        raise
    finally:
        signal.signal(signal.SIGTERM, old_handler)
        if save_thread is not None:
            save_thread.join()

    if ckpt_dir is not None:
        ckpt.save(ckpt_dir, step, params, blocking=True)
        ckpt.save(ckpt_dir + "/opt", step, opt_state, blocking=True)
        ckpt.garbage_collect(ckpt_dir)
    return TrainerReport(steps_done=step - start_step, final_loss=final_loss,
                         resumed_from=resumed_from, straggler_steps=stragglers,
                         step_times=times)
