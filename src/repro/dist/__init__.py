"""Distributed execution layer: sharding-spec derivation and the jitted
train/prefill/serve step builders every runtime component goes through."""
