"""PartitionSpec derivation for params, optimizer/decode state and the
retrieval datastore.

Specs are replicated (``P()``) by default: on the CPU test meshes every
axis has size 1, and the compiler is free to re-layout under jit. The
datastore is the one operand with a real distribution story — the sharded
search path re-shards it explicitly via ``engine.shard_datastore`` /
``plan_sharded``, so ``datastore_specs`` only has to hand ``device_put`` a
structure-matching spec tree. Model/optimizer tensor parallelism rides the
same seam when a non-trivial mesh shows up: swap the leaf specs here and
every caller (trainer, server, dry-run) inherits them.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core import quantize, retrieval as retrieval_mod
from repro.models import lm


def _is_spec(x) -> bool:
    return isinstance(x, P)


def replicated_like(tree: Any) -> Any:
    """A pytree of ``P()`` (fully replicated) specs matching ``tree``."""
    return jax.tree_util.tree_map(lambda _: P(), tree)


def named(mesh, specs: Any) -> Any:
    """PartitionSpec pytree -> NamedSharding pytree on ``mesh``.

    ``PartitionSpec`` subclasses tuple, so the map must treat specs as
    leaves — otherwise tree_map would recurse into them.
    """
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs, is_leaf=_is_spec)


def param_specs(cfg: ModelConfig, mesh=None) -> Any:
    """Specs for ``lm.init_params(cfg)`` (structure from eval_shape)."""
    shapes = jax.eval_shape(
        lambda: lm.init_params(jax.random.PRNGKey(0), cfg))
    return replicated_like(shapes)


def decode_state_specs(cfg: ModelConfig, mesh=None) -> Any:
    """Specs for ``lm.init_decode_state`` (structure is batch/len-free)."""
    shapes = jax.eval_shape(lambda: lm.init_decode_state(cfg, 1, 2))
    return replicated_like(shapes)


def datastore_specs(mesh=None, store=None) -> Any:
    """Specs matching a ``retrieval.DataStore`` pytree.

    Without ``store``, assumes the common ``layout=None`` store (the shape
    every arch config builds by default); pass the concrete store to match
    a layout-carrying structure.
    """
    if store is not None:
        return replicated_like(store)
    return retrieval_mod.DataStore(
        codes=P(), values=P(),
        itq=quantize.ITQParams(mean=P(), proj=P(), rot=P()),
        layout=None)
