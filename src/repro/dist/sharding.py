"""PartitionSpec derivation for params, optimizer/decode state and the
retrieval datastore.

Specs are replicated (``P()``) by default: on the CPU test meshes every
axis has size 1, and the compiler is free to re-layout under jit. The
datastore is the one operand with a real distribution story — the sharded
search path re-shards it explicitly via ``engine.shard_datastore`` /
``plan_sharded``, so ``datastore_specs`` only has to hand ``device_put`` a
structure-matching spec tree. Model/optimizer tensor parallelism rides the
same seam when a non-trivial mesh shows up: swap the leaf specs here and
every caller (trainer, server, dry-run) inherits them.
Row-range replication (``ReplicaMap``) lives here too: the pure placement
arithmetic of the shard-fault-tolerance layer — which unit holds which
contiguous global row range, at replication factor R, and who serves /
re-replicates what when units die. dist/search.py executes the placement;
this class only decides it (host-side, dependency-free, fully testable).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core import quantize, retrieval as retrieval_mod
from repro.models import lm


def _is_spec(x) -> bool:
    return isinstance(x, P)


def replicated_like(tree: Any) -> Any:
    """A pytree of ``P()`` (fully replicated) specs matching ``tree``."""
    return jax.tree_util.tree_map(lambda _: P(), tree)


def named(mesh, specs: Any) -> Any:
    """PartitionSpec pytree -> NamedSharding pytree on ``mesh``.

    ``PartitionSpec`` subclasses tuple, so the map must treat specs as
    leaves — otherwise tree_map would recurse into them.
    """
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs, is_leaf=_is_spec)


def param_specs(cfg: ModelConfig, mesh=None) -> Any:
    """Specs for ``lm.init_params(cfg)`` (structure from eval_shape)."""
    shapes = jax.eval_shape(
        lambda: lm.init_params(jax.random.PRNGKey(0), cfg))
    return replicated_like(shapes)


def decode_state_specs(cfg: ModelConfig, mesh=None) -> Any:
    """Specs for ``lm.init_decode_state`` (structure is batch/len-free)."""
    shapes = jax.eval_shape(lambda: lm.init_decode_state(cfg, 1, 2))
    return replicated_like(shapes)


def datastore_specs(mesh=None, store=None) -> Any:
    """Specs matching a ``retrieval.DataStore`` pytree.

    Without ``store``, assumes the common ``layout=None`` store (the shape
    every arch config builds by default); pass the concrete store to match
    a layout-carrying structure.
    """
    if store is not None:
        return replicated_like(store)
    return retrieval_mod.DataStore(
        codes=P(), values=P(),
        itq=quantize.ITQParams(mean=P(), proj=P(), rot=P()),
        layout=None)


# ---------------------------------------------------------------------------
# row-range replication placement (shard fault tolerance)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ReplicaMap:
    """Who holds which contiguous global row range, at factor R.

    The global row space [0, sum(counts)) splits into ``len(counts)``
    contiguous ranges — range i is the PRIMARY of unit i. At replication
    factor R, range i is additionally held by the next R-1 units in ring
    order (``units[(i + j) % n]``), the classic chained placement: any
    single-unit loss leaves every range with R-1 surviving holders, and R
    consecutive losses are needed to lose data.

    Everything here is pure placement arithmetic over an ``alive`` set —
    no I/O, no arrays — so dist/search.py (execution) and the tests
    (properties) consume the same single source of truth:

    - ``owner(i, alive)``: the unit that SERVES range i — the first alive
      holder in ring order, primary-first, so a healthy fleet serves every
      range from its primary (replicas are pure standby capacity).
    - ``assignment(alive)``: range index -> serving unit, covered only.
    - ``uncovered(alive)``: ranges with NO alive holder — these rows drop
      out of coverage (the CoverageReport names the lost primaries).
    - ``rebuild_targets(alive)``: the background re-replication work list
      — (range, source, target) triples restoring factor R among the
      alive units, fewest-held-ranges targets first (balance).
    """

    counts: Tuple[int, ...]
    units: Tuple[str, ...]
    factor: int = 1

    def __post_init__(self):
        if len(self.counts) != len(self.units):
            raise ValueError(f"{len(self.counts)} ranges vs "
                             f"{len(self.units)} units")
        if not 1 <= self.factor <= max(len(self.units), 1):
            raise ValueError(f"replication factor {self.factor} needs "
                             f"1 <= R <= n_units ({len(self.units)})")
        if any(c < 0 for c in self.counts):
            raise ValueError(f"negative range size in {self.counts}")
        object.__setattr__(self, "counts", tuple(int(c) for c in self.counts))
        object.__setattr__(self, "units", tuple(str(u) for u in self.units))

    @property
    def n_units(self) -> int:
        return len(self.units)

    @property
    def total_rows(self) -> int:
        return sum(self.counts)

    def range_bounds(self, i: int) -> Tuple[int, int]:
        """Range i's [start, stop) in the global row space."""
        start = sum(self.counts[:i])
        return start, start + self.counts[i]

    def holders(self, i: int) -> Tuple[str, ...]:
        """Units holding a copy of range i, primary first (ring order)."""
        n = self.n_units
        return tuple(self.units[(i + j) % n] for j in range(self.factor))

    def held_by(self, unit: str) -> Tuple[int, ...]:
        """Range indices ``unit`` holds (primary or replica)."""
        return tuple(i for i in range(self.n_units)
                     if unit in self.holders(i))

    def _live(self, i: int, alive_set: set,
              held: Optional[Dict[str, set]]) -> List[str]:
        """Alive units actually holding a copy of range i, ring order.
        ``held`` (unit -> set of range indices it REALLY has) overrides
        the nominal placement — a revived-empty unit nominally holds its
        ring ranges but possesses none until re-replication refills it."""
        return [u for u in self.holders(i)
                if u in alive_set and (held is None or i in held.get(u, ()))]

    def owner(self, i: int, alive: Sequence[str],
              held: Optional[Dict[str, set]] = None) -> Optional[str]:
        """The unit serving range i given the alive set (primary-first
        failover), or None when every holder is gone."""
        live = self._live(i, set(alive), held)
        return live[0] if live else None

    def assignment(self, alive: Sequence[str],
                   held: Optional[Dict[str, set]] = None) -> Dict[int, str]:
        """range index -> serving unit, for every range still covered."""
        alive_set = set(alive)
        out: Dict[int, str] = {}
        for i in range(self.n_units):
            live = self._live(i, alive_set, held)
            if live:
                out[i] = live[0]
        return out

    def uncovered(self, alive: Sequence[str],
                  held: Optional[Dict[str, set]] = None) -> List[int]:
        """Ranges with no alive holder: their rows drop out of coverage."""
        alive_set = set(alive)
        return [i for i in range(self.n_units)
                if not self._live(i, alive_set, held)]

    def covered_rows(self, alive: Sequence[str],
                     held: Optional[Dict[str, set]] = None) -> int:
        gone = set(self.uncovered(alive, held))
        return sum(c for i, c in enumerate(self.counts) if i not in gone)

    def rebuild_targets(self, alive: Sequence[str],
                        held: Optional[Dict[str, set]] = None
                        ) -> List[Tuple[int, str, str]]:
        """The re-replication work list: for every range with fewer than
        ``factor`` ALIVE copies (and at least one — lost ranges cannot be
        rebuilt from thin air), (range, alive source, alive target) triples
        that restore the factor. Nominal holders refill first (a revived
        unit gets its own ranges back), then fewest-copies-first targets
        so a refill never hot-spots one donor."""
        alive_set = set(alive)
        holds: Dict[str, set] = {
            u: (set(held.get(u, ())) if held is not None
                else set(self.held_by(u)))
            for u in alive_set}
        work: List[Tuple[int, str, str]] = []
        for i in range(self.n_units):
            live = [u for u in self.holders(i)
                    if u in alive_set and i in holds[u]]
            if not live or len(live) >= self.factor:
                continue
            need = self.factor - len(live)
            src = live[0]
            nominal = set(self.holders(i))
            candidates = sorted(
                (u for u in alive_set if i not in holds[u]),
                key=lambda u: (0 if u in nominal else 1, len(holds[u]), u))
            for tgt in candidates[:need]:
                holds[tgt].add(i)
                work.append((i, src, tgt))
        return work
