"""Jitted step builders: the one place train/prefill/serve computations are
assembled and compiled.

Every builder returns the jitted step plus the PartitionSpec trees its
operands live under (``sharding`` module semantics). The serve builder is
memoized per (cfg, mesh, max_len, retrieval-variant): the hardened server
keeps several degradation rungs alive at once (full exact plan, masked
probe at reduced nprobe, retrieval-off) and failover must not recompile a
rung it already has.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, TrainConfig
from repro.core import retrieval as retrieval_mod
from repro.dist import sharding
from repro.models import lm
from repro.optim import optimizer


def dp_axes(mesh) -> Tuple[str, ...]:
    """Every mesh axis except the tensor/expert axis is data parallel."""
    return tuple(a for a in mesh.axis_names if a != "model")


# ---------------------------------------------------------------------------
# train
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, mesh, tc: TrainConfig, *,
                    causal_skip: bool = False, attn_p_bf16: bool = False,
                    pure_dp: bool = False, moe_a2a_int8: bool = False,
                    donate: bool = True):
    """Returns (step_fn, param_specs, opt_specs).

    ``step_fn(params, opt_state, batch, step) -> (params, opt_state,
    metrics)`` with metrics at least {loss, ce, aux, grad_norm, lr}.
    ``pure_dp`` drops the mesh from the model context (reference MoE path,
    no expert parallelism). ``tc.microbatches > 1`` accumulates gradients
    over a scan (activation memory / M).
    """
    ctx = lm.RunCtx(mesh=None if pure_dp else mesh, dp_axes=dp_axes(mesh),
                    causal_skip=causal_skip, attn_p_bf16=attn_p_bf16,
                    moe_a2a_int8=moe_a2a_int8, remat=tc.remat)
    micro = max(int(tc.microbatches), 1)

    def loss(params, batch):
        return lm.loss_fn(params, cfg, batch, ctx)

    grad_fn = jax.value_and_grad(loss, has_aux=True)

    def step(params, opt_state, batch, step_idx):
        if micro > 1:
            def split(x):
                return x.reshape((micro, x.shape[0] // micro) + x.shape[1:])
            mb = jax.tree_util.tree_map(split, batch)

            def body(carry, b):
                (lval, aux), grads = grad_fn(params, b)
                acc = jax.tree_util.tree_map(jnp.add, carry[0], grads)
                return (acc, carry[1] + lval,
                        jax.tree_util.tree_map(jnp.add, carry[2], aux)), None

            zero_g = jax.tree_util.tree_map(jnp.zeros_like, params)
            zero_a = {"ce": jnp.float32(0.0), "aux": jnp.float32(0.0)}
            (grads, lsum, asum), _ = jax.lax.scan(
                body, (zero_g, jnp.float32(0.0), zero_a), mb)
            grads = jax.tree_util.tree_map(lambda g: g / micro, grads)
            lval = lsum / micro
            aux = jax.tree_util.tree_map(lambda a: a / micro, asum)
        else:
            (lval, aux), grads = grad_fn(params, batch)
        new_params, new_opt, om = optimizer.update(
            grads, opt_state, params, tc, step_idx)
        metrics = dict(aux)
        metrics.update(om)
        metrics["loss"] = lval
        return new_params, new_opt, metrics

    pspecs = sharding.param_specs(cfg, mesh)
    oshapes = jax.eval_shape(
        lambda: optimizer.init(
            jax.eval_shape(lambda: lm.init_params(jax.random.PRNGKey(0), cfg)),
            tc))
    ospecs = sharding.replicated_like(oshapes)
    step_fn = jax.jit(step, donate_argnums=(0, 1) if donate else ())
    return step_fn, pspecs, ospecs


# ---------------------------------------------------------------------------
# prefill
# ---------------------------------------------------------------------------

def make_prefill_step(cfg: ModelConfig, mesh, seq_len: int, *,
                      causal_skip: bool = False, attn_p_bf16: bool = False,
                      attn_chunk: int = 1024, attn_impl: str = "xla"):
    """Returns (prefill_fn, param_specs); ``prefill_fn(params, batch) ->
    (logits, decode_state)`` over the full prompt."""
    ctx = lm.RunCtx(mesh=mesh, dp_axes=dp_axes(mesh),
                    causal_skip=causal_skip, attn_p_bf16=attn_p_bf16,
                    attn_chunk=attn_chunk, attn_impl=attn_impl)

    def prefill_fn(params, batch):
        return lm.prefill(params, cfg, batch["tokens"],
                          batch.get("prefix_emb"), ctx)

    return jax.jit(prefill_fn), sharding.param_specs(cfg, mesh)


# ---------------------------------------------------------------------------
# per-unit search steps (host-orchestrated fault-tolerant search)
# ---------------------------------------------------------------------------

# (bins, k) -> (hist_fn, topk_fn). dist/search.py calls one jitted hist and
# one jitted top-k per SURVIVING unit per query; units die and fail over
# mid-stream, so the callables must be shared across units and never
# rebuilt on the failover path (jit itself re-specializes per range shape,
# and equal-shape ranges share one executable).
_UNIT_STEP_CACHE: dict = {}


def unit_search_steps(bins: int, k: int):
    """Memoized jitted per-unit callables for dist/search.py:
    ``hist(q, x) -> (Q, bins)`` partial histogram and ``topk(q, x) ->
    (dists, ids)`` local top-k over ONE unit's row range."""
    key = (int(bins), int(k))
    hit = _UNIT_STEP_CACHE.get(key)
    if hit is not None:
        return hit
    from repro.kernels import ops

    hist = jax.jit(lambda q, x: ops.hamming_hist(q, x, key[0]))
    topk = jax.jit(lambda q, x: ops.hamming_topk(q, x, key[1], key[0]))
    out = (hist, topk)
    _UNIT_STEP_CACHE[key] = out
    return out


# ---------------------------------------------------------------------------
# serve
# ---------------------------------------------------------------------------

# (cfg, mesh, max_len, with_retrieval, nprobe, id(probe_positions)) ->
# (serve_fn, pspecs, sspecs). Degradation rung switches and failover paths
# re-request builders mid-serve; the cache makes that free.
_SERVE_CACHE: dict = {}


def make_serve_step(cfg: ModelConfig, mesh, max_len: int, *,
                    with_retrieval: Optional[bool] = None,
                    global_batch: Optional[int] = None,
                    nprobe: int = 0, probe_positions=None,
                    select: Optional[str] = None,
                    recall_target: Optional[float] = None):
    """Returns (serve_fn, param_specs, state_specs).

    ``serve_fn(params, token (B,1), state, active (B,)[, store]) ->
    (logits (B,1,V) f32, new_state)`` — one decode step for every active
    slot; the store argument exists iff retrieval is on. ``nprobe > 0``
    (with the store's hamming-prefix ``probe_positions``) builds the
    DEGRADED serving variant: masked IVF-style probe over the layout at
    reduced nprobe instead of the full exact plan; ``select="approx"`` +
    ``recall_target`` builds the APPROX rung — the compute-bound MXU
    partial-reduce tier at a bounded recall loss. ``global_batch`` is
    accepted for dry-run symmetry; shapes come from the operands.
    """
    if with_retrieval is None:
        with_retrieval = cfg.retrieval.enabled
    key = None
    try:
        key = (cfg, mesh, int(max_len), bool(with_retrieval), int(nprobe),
               id(probe_positions) if probe_positions is not None else None,
               select,
               float(recall_target) if recall_target is not None else None)
        if key in _SERVE_CACHE:
            return _SERVE_CACHE[key]
    except TypeError:            # unhashable cfg/mesh: skip memoization
        key = None

    ctx = lm.RunCtx(mesh=mesh, dp_axes=dp_axes(mesh))
    rcfg = cfg.retrieval

    if with_retrieval:
        def serve_fn_py(params, token, state, active, store):
            logits, new_state, hidden = lm.decode_step(
                params, cfg, token, state, ctx, active=active,
                return_hidden=True)
            knn = retrieval_mod.knn_logits(
                store, hidden[:, 0, :], rcfg, cfg.vocab_size,
                select=select, recall_target=recall_target,
                nprobe=nprobe, probe_positions=probe_positions)
            mixed = retrieval_mod.interpolate(logits[:, 0, :], knn,
                                              rcfg.interpolation)
            return mixed[:, None, :], new_state
    else:
        def serve_fn_py(params, token, state, active):
            logits, new_state = lm.decode_step(
                params, cfg, token, state, ctx, active=active)
            return logits.astype(jnp.float32), new_state

    pspecs = sharding.param_specs(cfg, mesh)
    sspecs = sharding.decode_state_specs(cfg, mesh)
    out = (jax.jit(serve_fn_py), pspecs, sspecs)
    if key is not None:
        _SERVE_CACHE[key] = out
    return out
