"""Host-orchestrated shard-fault-tolerant distributed search.

The SPMD path (``ops.hamming_topk_sharded`` under shard_map) assumes every
participant answers every collective — the right model for one healthy
mesh, the wrong one for a fleet of independent near-data units (the
paper's AP ranks, Pohoiki Springs' ~100k cores) where units stall, die
and come back mid-stream. This module runs the SAME two-pass counting
select with the host as the merge fabric, so any unit can drop out
between any two steps:

1. **hist** — every covered row range runs pass 1 on its serving unit
   (``dist/steps.unit_search_steps``; fault site ``shard_hist``,
   per-call deadline -> ``HealthRegistry.observe``). A failed unit fails
   over to the next replica holder of the same range (``ReplicaMap``,
   primary-first ring order); a range with no live holder drops out of
   coverage.
2. **merge** — the partial histograms reduce hierarchically on the host
   in ``fanout``-wide rounds (site ``merge_psum``, retried under the
   request's remaining deadline via ``faults.retry_call``): the
   hist_tree schedule, host edition. Integer sums -> any grouping is
   bit-identical to the flat sum.
3. **radius** — ONE global per-query r* via ``ops._radius_from_cum``,
   the same definition every other select uses.
4. **emit** — each covered range reports its local top-min(k, n_range)
   (site ``shard_emit``, same failover). Any global winner inside a
   range is inside that range's local top-k, so this is lossless.
5. **assemble** — candidates filter to dist <= r*, sort lexicographically
   by (dist, original global id) and cut at k_eff; surplus slots pad
   with (bins, total_rows) sentinels.

The answer is **degraded but exact**: bit-identical distances — and ids
equal through the canonical covered-row id map — to a from-scratch
``ops.hamming_topk`` over exactly the surviving rows, and every response
carries a ``CoverageReport`` saying precisely what was searched. If
coverage shrinks between hist and emit (a range lost its last holder
mid-query) the query RESTARTS over the new surviving set — the merged
radius of a larger store is not valid for a smaller one — bounded by the
unit count, so a request is never lost and never silently under-reported.

Replication (factor R) is ``dist/sharding.ReplicaMap``'s chained
placement; ``maintain()`` does bounded background re-replication (restore
factor R among the living, refill revived-empty units, promote
``recovering -> healthy`` when a unit's nominal ranges are back).
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.dist import steps as steps_mod
from repro.dist.health import CoverageReport, HealthRegistry
from repro.dist.sharding import ReplicaMap
from repro.runtime import faults as faults_mod

_ID_BITS = 32          # (dist << 32 | gid) sort keys; gid < 2**31 always


class _CoverageChanged(Exception):
    """A range lost its last holder mid-query: restart over the new set."""


def _even_counts(n_rows: int, n_units: int) -> List[int]:
    base, rem = divmod(n_rows, n_units)
    return [base + (1 if i < rem else 0) for i in range(n_units)]


class FaultTolerantSearch:
    """Shard-fault-tolerant k-NN over one packed code store.

    ``codes_packed``: (N, W) packed codes; rows split into ``n_units``
    contiguous primary ranges (uneven allowed via ``counts``), replicated
    at ``factor`` by ``ReplicaMap``'s ring placement. ``injector``: the
    seeded ``FaultInjector`` whose ``shard_hist``/``shard_emit``/
    ``merge_psum`` sites (scoped ``site@unit``) this layer honors.
    ``fanout``: host merge-tree width (0 -> ``tuning.merge_fanout``).
    """

    def __init__(self, codes_packed, d: int, *, n_units: int = 4,
                 counts: Optional[Sequence[int]] = None,
                 factor: int = 1,
                 registry: Optional[HealthRegistry] = None,
                 injector: Optional[faults_mod.FaultInjector] = None,
                 fanout: int = 0,
                 deadline_s: float = 0.25,
                 clock: Callable[[], float] = time.perf_counter):
        codes = np.asarray(codes_packed)
        if counts is None:
            counts = _even_counts(codes.shape[0], n_units)
        if sum(counts) != codes.shape[0]:
            raise ValueError(f"counts {counts} do not cover "
                             f"{codes.shape[0]} rows")
        units = [f"unit{i}" for i in range(len(counts))]
        self.d = int(d)
        self.bins = self.d + 1
        self.map = ReplicaMap(tuple(counts), tuple(units), factor=factor)
        self.registry = registry or HealthRegistry(units,
                                                   deadline_s=deadline_s)
        self.injector = injector
        self.clock = clock
        if fanout < 2:
            from repro.kernels import tuning
            fanout = tuning.merge_fanout(len(units)) or 2
        self.fanout = int(fanout)
        # nominal placement -> actual possession: every holder gets a
        # device copy of each range it holds (the replica IS the failover)
        self._data: Dict[str, Dict[int, jax.Array]] = {u: {} for u in units}
        self._held: Dict[str, set] = {u: set() for u in units}
        for i in range(self.map.n_units):
            lo, hi = self.map.range_bounds(i)
            block = jax.numpy.asarray(codes[lo:hi])
            for u in self.map.holders(i):
                self._data[u][i] = block
                self._held[u].add(i)
        self.counters = {"failovers": 0, "restarts": 0, "rebuilt_ranges": 0,
                         "searches": 0, "degraded_searches": 0}

    # -- fault plumbing ----------------------------------------------------

    def _check(self, site: str, unit: str) -> None:
        if self.injector is not None:
            self.injector.check(site, unit)

    def _call_unit(self, site: str, range_idx: int, fn_for,
                   serving: set) -> Optional[Tuple[str, object]]:
        """Run ``fn_for(unit)`` on the range's serving holder, failing over
        through the replica chain as the registry declares units dead.
        Every attempt is deadline-timed into the registry — persistent
        failures walk a unit healthy -> suspect -> dead, which is exactly
        what reroutes the range to its next holder. Returns (unit, result)
        or None when no live holder remains (coverage change)."""
        tried_dead = set()
        while True:
            unit = self.map.owner(range_idx, serving - tried_dead,
                                  held=self._held)
            if unit is None:
                return None
            while True:
                t0 = self.clock()
                try:
                    self._check(site, unit)
                    out = fn_for(unit)
                    self.registry.observe(unit, True, self.clock() - t0)
                    return unit, out
                except faults_mod.TRANSIENT:
                    state = self.registry.observe(unit, False,
                                                  self.clock() - t0)
                    if state not in ("healthy", "suspect"):
                        # the registry gave up on this unit: fail the
                        # range over to its next live holder
                        tried_dead.add(unit)
                        self.counters["failovers"] += 1
                        break
                    # still serving (below dead_after): retry in place

    # -- the five steps ----------------------------------------------------

    def _merge_hists(self, hists: List[np.ndarray],
                     deadline_left: Optional[float]) -> np.ndarray:
        """Host edition of the hist_tree reduction: ``fanout``-wide rounds
        of integer sums, each round's group guarded by the ``merge_psum``
        site and retried inside the remaining request deadline."""
        level = 0
        while len(hists) > 1:
            nxt = []
            for g0 in range(0, len(hists), self.fanout):
                group = hists[g0:g0 + self.fanout]

                def merge_group(level=level, g0=g0, group=group):
                    self._check("merge_psum", f"l{level}g{g0}")
                    return sum(group[1:], group[0].copy())

                nxt.append(faults_mod.retry_call(
                    merge_group, retries=4, backoff_s=1e-4,
                    deadline_s=deadline_left, sleep=lambda s: None))
            hists = nxt
            level += 1
        return hists[0]

    def search(self, q_packed, k: int,
               deadline_s: Optional[float] = None
               ) -> Tuple[np.ndarray, np.ndarray, CoverageReport]:
        """(dists (Q, k), ids (Q, k) in the ORIGINAL global row space,
        CoverageReport). Exact over the covered rows; ids of excluded rows
        never appear; surplus slots carry (bins, total_rows) sentinels."""
        from repro.kernels import ops

        q = jax.numpy.asarray(q_packed)
        Q = q.shape[0]
        t_start = self.clock()
        self.counters["searches"] += 1

        def left() -> Optional[float]:
            if deadline_s is None:
                return None
            return max(deadline_s - (self.clock() - t_start), 0.0)

        for _restart in range(self.map.n_units + 1):
            try:
                return self._search_once(ops, q, Q, int(k), left)
            except _CoverageChanged:
                self.counters["restarts"] += 1
                continue
        raise RuntimeError("coverage changed more times than there are "
                           "units — registry is thrashing")

    def _search_once(self, ops, q, Q: int, k: int, left):
        serving = set(self.registry.serving())
        assignment = self.map.assignment(serving, held=self._held)
        covered = sorted(assignment)
        covered_total = sum(self.map.counts[i] for i in covered)
        report = CoverageReport(
            covered_rows=covered_total, total_rows=self.map.total_rows,
            dead_shards=tuple(sorted(self.registry.not_serving())))
        if not report.complete:
            self.counters["degraded_searches"] += 1
        if covered_total == 0 or k == 0:
            return (np.full((Q, k), self.bins, np.int32),
                    np.full((Q, k), self.map.total_rows, np.int32), report)
        k_k = min(k, covered_total)

        # 1. per-range pass-1 histograms on the serving holders
        hists = []
        for i in covered:
            hist_fn, _ = steps_mod.unit_search_steps(self.bins, k_k)
            got = self._call_unit(
                "shard_hist", i,
                lambda u, i=i, f=hist_fn: np.asarray(f(q, self._data[u][i])),
                serving)
            if got is None:
                raise _CoverageChanged(f"range {i} lost during hist")
            hists.append(got[1].astype(np.int64))

        # 2.+3. hierarchical host merge -> the ONE global radius
        hist_glob = self._merge_hists(hists, left())
        cum = np.cumsum(hist_glob, axis=-1)
        k_eff, r_star, n_lt, n_emit = (
            np.asarray(v) for v in ops._radius_from_cum(cum, k_k))

        # 4. per-range emit: local top-min(k, n_range) in original gids
        cand_d, cand_g = [], []
        for i in covered:
            k_loc = min(k, self.map.counts[i])
            _, topk_fn = steps_mod.unit_search_steps(self.bins, k_loc)
            got = self._call_unit(
                "shard_emit", i,
                lambda u, i=i, f=topk_fn: tuple(
                    np.asarray(a) for a in f(q, self._data[u][i])),
                serving)
            if got is None:
                raise _CoverageChanged(f"range {i} lost during emit")
            ld, li = got[1]
            cand_d.append(ld)
            cand_g.append(li + self.map.range_bounds(i)[0])

        # 5. host assembly: filter to r*, (dist, gid)-lexicographic cut
        d_all = np.concatenate(cand_d, axis=1).astype(np.int64)
        g_all = np.concatenate(cand_g, axis=1).astype(np.int64)
        keep = d_all <= r_star[:, None]
        key = np.where(keep, (d_all << _ID_BITS) | g_all,
                       np.iinfo(np.int64).max)
        key.sort(axis=1)
        key = key[:, :k_k]
        out_d = (key >> _ID_BITS).astype(np.int32)
        out_g = (key & ((np.int64(1) << _ID_BITS) - 1)).astype(np.int32)
        live = np.arange(k_k, dtype=np.int32)[None, :] < n_emit[:, None]
        out_d = np.where(live, out_d, self.bins).astype(np.int32)
        out_g = np.where(live, out_g, self.map.total_rows).astype(np.int32)
        if k_k < k:
            pad_d = np.full((Q, k - k_k), self.bins, np.int32)
            pad_g = np.full((Q, k - k_k), self.map.total_rows, np.int32)
            out_d = np.concatenate([out_d, pad_d], axis=1)
            out_g = np.concatenate([out_g, pad_g], axis=1)
        report = CoverageReport(
            covered_rows=sum(self.map.counts[i] for i in covered),
            total_rows=self.map.total_rows,
            dead_shards=tuple(sorted(self.registry.not_serving())))
        return out_d, out_g, report

    # -- lifecycle ---------------------------------------------------------

    def kill(self, unit: str) -> None:
        """Hard-kill mid-stream: the unit stops serving NOW. Its device
        copies stay addressable (a warm corpse) so a later warm revive or
        an anti-entropy rebuild can copy from it only after revive."""
        self.registry.kill(unit)

    def revive(self, unit: str, with_data: bool = True) -> None:
        """The unit process is back: dead -> recovering. ``with_data=False``
        models a cold replacement (disk gone) — possession resets and
        ``maintain()`` must refill every range before it serves again."""
        if not with_data:
            self._data[unit] = {}
            self._held[unit] = set()
        self.registry.revive(unit)

    def coverage(self) -> CoverageReport:
        """What a search issued right now would cover."""
        serving = set(self.registry.serving())
        return CoverageReport(
            covered_rows=self.map.covered_rows(serving, held=self._held),
            total_rows=self.map.total_rows,
            dead_shards=tuple(sorted(self.registry.not_serving())))

    def covered_ranges(self) -> Tuple[int, ...]:
        """Range indices a search issued right now would cover (sorted) —
        the coverage SIGNATURE the server keys its degraded store view by."""
        serving = set(self.registry.serving())
        return tuple(sorted(self.map.assignment(serving, held=self._held)))

    def covered_row_ids(self) -> np.ndarray:
        """Original global row ids currently covered, ascending — exactly
        the rows a degraded answer searches (and the reference oracle's
        ``covered_row_ids`` argument)."""
        ranges = self.covered_ranges()
        if not ranges:
            return np.empty(0, np.int64)
        return np.concatenate([np.arange(*self.map.range_bounds(i))
                               for i in ranges]).astype(np.int64)

    def maintain(self, budget: Optional[int] = None) -> dict:
        """One bounded background-maintenance pass: re-replicate
        under-replicated ranges among the living (recovering units refill
        their nominal ranges first), then promote any recovering unit
        whose nominal set is whole. ``budget`` caps range copies per call
        so maintenance never starves serving."""
        alive = set(self.registry.serving()) | {
            u for u in self.map.units
            if self.registry.state(u) == "recovering"}
        work = self.map.rebuild_targets(alive, held=self._held)
        copied = 0
        for i, src, tgt in work:
            if budget is not None and copied >= budget:
                break
            self._data[tgt][i] = self._data[src][i]
            self._held[tgt].add(i)
            copied += 1
        self.counters["rebuilt_ranges"] += copied
        recovered = []
        for u in self.map.units:
            if (self.registry.state(u) == "recovering"
                    and set(self.map.held_by(u)) <= self._held[u]):
                self.registry.mark_recovered(u)
                recovered.append(u)
        return {"copied": copied, "pending": len(work) - copied,
                "recovered": recovered,
                "coverage_frac": self.coverage().coverage_frac}

    def stats(self) -> dict:
        cov = self.coverage()
        return {
            "registry": self.registry.snapshot(),
            "replication": {
                "factor": self.map.factor,
                "n_units": self.map.n_units,
                "fanout": self.fanout,
                "held": {u: sorted(h) for u, h in self._held.items()},
                "under_replicated": len(self.map.rebuild_targets(
                    set(self.registry.serving()), held=self._held)),
            },
            "coverage": cov.as_dict(),
            "counters": dict(self.counters),
        }


def reference_over_covered(codes_packed, q_packed, k: int, d: int,
                           covered_row_ids: np.ndarray
                           ) -> Tuple[np.ndarray, np.ndarray]:
    """The from-scratch oracle a degraded answer must match bit-for-bit:
    ``ops.hamming_topk`` over ONLY the covered rows, with winners mapped
    back to original global ids and sentinels at the original total.
    Tests and the kill-shard soak both call this — one oracle, no drift."""
    from repro.kernels import ops

    codes = np.asarray(codes_packed)
    m = np.asarray(covered_row_ids, np.int64)
    total = codes.shape[0]
    Q = np.asarray(q_packed).shape[0]
    if m.size == 0:
        return (np.full((Q, k), d + 1, np.int32),
                np.full((Q, k), total, np.int32))
    rd, ri = ops.hamming_topk(jax.numpy.asarray(q_packed),
                              jax.numpy.asarray(codes[m]), k, d + 1)
    rd, ri = np.asarray(rd), np.asarray(ri)
    ids = np.where(ri < m.size, m[np.minimum(ri, max(m.size - 1, 0))], total)
    return rd.astype(np.int32), ids.astype(np.int32)


__all__ = ["FaultTolerantSearch", "reference_over_covered"]
