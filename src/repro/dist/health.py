"""Shard-health registry + coverage accounting for distributed search.

The paper's AP ranks — and the Pohoiki Springs-style fleets the ROADMAP
scales toward — are physically independent search units; at production
scale individual units stall, die and come back. This module is the
bookkeeping half of the fault-tolerance layer: a tiny, dependency-free
state machine per shard (healthy -> suspect -> dead -> recovering) driven
by per-call deadlines, and the ``CoverageReport`` every degraded answer
carries so callers know EXACTLY what was searched (the answer itself stays
bit-identical to a from-scratch search over the surviving rows — the
participation-mask contract of ``ops.hamming_topk_sharded`` and the host
orchestrator in dist/search.py).

State machine (per shard):

- ``healthy``: serving. A failure (exception, injected fault, or latency
  over ``deadline_s``) moves to ``suspect`` after ``suspect_after``
  consecutive failures.
- ``suspect``: still serving (its rows still count toward coverage), but
  one more success restores ``healthy`` while reaching ``dead_after``
  consecutive failures declares it ``dead``.
- ``dead``: excluded from every search (participation mask zero; its
  primary row ranges fail over to replicas or drop out of coverage).
  ``revive()`` — the unit came back empty — moves to ``recovering``.
- ``recovering``: not serving yet; background re-replication refills it
  and ``mark_recovered()`` (or ``recover_probes`` consecutive successful
  probes) restores ``healthy``.

``kill()`` force-marks ``dead`` immediately (the bench's mid-stream
kill switch and the server's shard-loss rung both use it).
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, Iterable, List, Tuple

HEALTHY = "healthy"
SUSPECT = "suspect"
DEAD = "dead"
RECOVERING = "recovering"

STATES = (HEALTHY, SUSPECT, DEAD, RECOVERING)


@dataclasses.dataclass
class ShardHealth:
    """One shard's view: current state + the counters that drive it."""

    state: str = HEALTHY
    consec_failures: int = 0
    consec_successes: int = 0
    failures: int = 0
    successes: int = 0
    deadline_misses: int = 0
    last_latency_s: float = 0.0


@dataclasses.dataclass(frozen=True)
class CoverageReport:
    """What one answer actually searched.

    ``coverage_frac`` applies to every query in the batch (the whole batch
    races over the same surviving rows), so a response's per-query
    coverage IS this fraction; ``dead_shards`` names the units whose rows
    were excluded. ``covered_rows == total_rows`` (frac 1.0) is the
    healthy fast path. The contract: the degraded answer is bit-identical
    to a from-scratch search over exactly ``covered_rows`` rows — coverage
    is never silently under- (or over-) reported."""

    covered_rows: int
    total_rows: int
    dead_shards: Tuple[str, ...] = ()

    @property
    def coverage_frac(self) -> float:
        if self.total_rows <= 0:
            return 1.0 if not self.dead_shards else 0.0
        return self.covered_rows / self.total_rows

    @property
    def complete(self) -> bool:
        return self.covered_rows == self.total_rows

    def as_dict(self) -> dict:
        return {"covered_rows": int(self.covered_rows),
                "total_rows": int(self.total_rows),
                "coverage_frac": float(self.coverage_frac),
                "dead_shards": list(self.dead_shards)}


class HealthRegistry:
    """Deadline-driven shard state machine; thread-safe (the server's tick
    loop observes from worker threads while ``stats()`` snapshots)."""

    def __init__(self, units: Iterable[str], *, deadline_s: float = 0.05,
                 suspect_after: int = 1, dead_after: int = 3,
                 recover_probes: int = 2):
        if suspect_after < 1 or dead_after < suspect_after:
            raise ValueError(f"need 1 <= suspect_after <= dead_after, got "
                             f"{suspect_after}/{dead_after}")
        self.deadline_s = float(deadline_s)
        self.suspect_after = int(suspect_after)
        self.dead_after = int(dead_after)
        self.recover_probes = int(recover_probes)
        self._lock = threading.Lock()
        self._shards: Dict[str, ShardHealth] = {
            str(u): ShardHealth() for u in units}
        self.transitions: List[Tuple[str, str, str]] = []

    # -- bookkeeping -------------------------------------------------------

    def _get(self, unit: str) -> ShardHealth:
        try:
            return self._shards[unit]
        except KeyError:
            raise KeyError(f"unknown shard {unit!r}; known: "
                           f"{sorted(self._shards)}") from None

    def _move(self, unit: str, h: ShardHealth, to: str) -> None:
        if h.state != to:
            self.transitions.append((unit, h.state, to))
            h.state = to

    # -- observations ------------------------------------------------------

    def observe(self, unit: str, ok: bool, latency_s: float = 0.0) -> str:
        """Record one call against ``unit``; returns the new state.
        ``ok=True`` with ``latency_s`` over the deadline counts as a
        FAILURE — a stalled shard is as gone as a crashed one."""
        with self._lock:
            h = self._get(unit)
            h.last_latency_s = float(latency_s)
            missed = ok and latency_s > self.deadline_s
            if missed:
                h.deadline_misses += 1
            if ok and not missed:
                h.successes += 1
                h.consec_successes += 1
                h.consec_failures = 0
                if h.state == SUSPECT:
                    self._move(unit, h, HEALTHY)
                elif (h.state == RECOVERING
                      and h.consec_successes >= self.recover_probes):
                    self._move(unit, h, HEALTHY)
            else:
                h.failures += 1
                h.consec_failures += 1
                h.consec_successes = 0
                if h.state == RECOVERING:
                    self._move(unit, h, DEAD)
                elif h.state in (HEALTHY, SUSPECT):
                    if h.consec_failures >= self.dead_after:
                        self._move(unit, h, DEAD)
                    elif h.consec_failures >= self.suspect_after:
                        self._move(unit, h, SUSPECT)
            return h.state

    def kill(self, unit: str) -> None:
        """Force-mark dead NOW (mid-stream kill / operator action)."""
        with self._lock:
            h = self._get(unit)
            self._move(unit, h, DEAD)
            h.consec_successes = 0

    def revive(self, unit: str) -> None:
        """The unit process is back — EMPTY. It must re-replicate before
        its rows count again: dead -> recovering."""
        with self._lock:
            h = self._get(unit)
            if h.state == DEAD:
                self._move(unit, h, RECOVERING)
                h.consec_failures = 0
                h.consec_successes = 0

    def mark_recovered(self, unit: str) -> None:
        """Re-replication refilled the unit: recovering -> healthy."""
        with self._lock:
            h = self._get(unit)
            if h.state == RECOVERING:
                self._move(unit, h, HEALTHY)
                h.consec_failures = 0

    # -- queries -----------------------------------------------------------

    def state(self, unit: str) -> str:
        with self._lock:
            return self._get(unit).state

    def serving(self) -> List[str]:
        """Units whose rows count toward coverage (healthy + suspect —
        a suspect shard still answers; only dead/recovering are out)."""
        with self._lock:
            return [u for u, h in self._shards.items()
                    if h.state in (HEALTHY, SUSPECT)]

    def dead(self) -> List[str]:
        with self._lock:
            return [u for u, h in self._shards.items() if h.state == DEAD]

    def not_serving(self) -> List[str]:
        with self._lock:
            return [u for u, h in self._shards.items()
                    if h.state in (DEAD, RECOVERING)]

    def snapshot(self) -> dict:
        """``stats()["shards"]`` surface: per-unit state + counters."""
        with self._lock:
            return {
                "deadline_s": self.deadline_s,
                "states": {u: h.state for u, h in self._shards.items()},
                "counters": {u: {"failures": h.failures,
                                 "successes": h.successes,
                                 "deadline_misses": h.deadline_misses,
                                 "consec_failures": h.consec_failures}
                             for u, h in self._shards.items()},
                "n_serving": sum(h.state in (HEALTHY, SUSPECT)
                                 for h in self._shards.values()),
                "n_dead": sum(h.state == DEAD
                              for h in self._shards.values()),
                "n_recovering": sum(h.state == RECOVERING
                                    for h in self._shards.values()),
                "transitions": list(self.transitions[-32:]),
            }


__all__ = ["CoverageReport", "DEAD", "HEALTHY", "HealthRegistry",
           "RECOVERING", "STATES", "SUSPECT", "ShardHealth"]
