"""Mixed-tenant churn + fault soak over the packed tenant arena
(core/tenant.py), plus a packed-batch vs per-tenant search latency pair.

N tenants with skewed sizes share one ``TenantArena`` (disjoint external
id ranges so cross-tenant leakage is detectable). The soak drives a mix
of append/delete/search/maintain/snapshot ops with base-rate faults armed
at the store sites; any fault that escapes containment (a mutation crash)
abandons the whole in-memory arena and re-runs ``TenantArena.recover``.
Midway, ONE tenant is deliberately poisoned: an interior record of its
WAL is bit-flipped while the arena is closed — recovery must quarantine
exactly that tenant and bring every other tenant up with zero
acked-mutation loss, zero phantoms, and zero unavailability.

Standalone CLI (what CI's tenant-soak-smoke job runs):
    PYTHONPATH=src python benchmarks/bench_tenant.py \
        --ops 400 --tenants 4 --fault-p 0.02 --json BENCH_tenant.json
Exit code is non-zero if the poisoned tenant fails to quarantine, any
HEALTHY tenant loses an acked mutation / grows a phantom / becomes
unavailable, any result crosses tenants, or the packed mixed-tenant batch
diverges from per-tenant searches — the blast-radius invariants.

Also registered in benchmarks/run.py (tag ``tenant``).
"""
import argparse
import json
import os
import sys
import tempfile
import time

import numpy as np

ID_STRIDE = 10_000_000          # disjoint per-tenant external id ranges


def _mk_codes(rng, n: int, d: int) -> np.ndarray:
    return rng.integers(0, 2 ** 32, size=(n, d // 32), dtype=np.uint32)


def _epoch_model(store):
    ep = store.epoch
    ids = np.asarray(ep.store_ids)
    codes = np.asarray(ep.layout.codes)
    values = np.asarray(ep.values)
    return {int(ids[i]): (codes[i].tobytes(), int(values[i]))
            for i in range(ids.shape[0])}


def _reconcile(store, model, in_doubt, report):
    """Post-recovery ledger check for ONE tenant (see bench_mutate)."""
    got = _epoch_model(store)
    if in_doubt is not None:
        kind, payload = in_doubt
        if kind == "append":
            for ext_id, code, val in payload:
                if ext_id in got:
                    model[ext_id] = (code, val)
        elif kind == "delete":
            for ext_id in payload:
                if ext_id not in got:
                    model.pop(ext_id, None)
    for ext_id, row in model.items():
        if ext_id not in got:
            report["lost_acks"] += 1
        elif got[ext_id] != row:
            report["corrupt_rows"] += 1
    for ext_id in got:
        if ext_id not in model:
            report["phantoms"] += 1
    return set(got)


def _recover_arena(d, root, inj, bn, store_kw, quotas):
    """TenantArena.recover already retries transient per-tenant faults
    bounded (quarantining only on exhaustion), so one call suffices."""
    from repro.core import tenant as tenant_mod
    return tenant_mod.TenantArena.recover(
        d, root, fault_injector=inj, quotas=quotas, bn=bn, **store_kw)


def soak(*, ops: int = 400, tenants: int = 4, fault_p: float = 0.02,
         seed: int = 0, d: int = 64) -> dict:
    """Run the mixed-tenant soak; ``ok`` is True iff every blast-radius
    invariant held (poisoned tenant quarantined, healthy tenants lossless
    and available, packed search bit-identical and tenant-pure)."""
    from repro.checkpoint import wal as wal_mod
    from repro.core import tenant as tenant_mod
    from repro.runtime import faults as faults_mod

    rng = np.random.default_rng(seed)
    inj = faults_mod.FaultInjector(
        seed=seed + 1, p={"wal_append": fault_p, "compact_build": fault_p,
                          "epoch_install": fault_p})
    store_kw = dict(slack_frac=0.15, min_slack=2, tombstone_frac=0.1,
                    max_pending=256)
    # skewed sizes: one big tenant, a long tail of small ones
    sizes = [max(8, 256 >> (2 * i)) for i in range(tenants)]
    tids = [f"t{i}" for i in range(tenants)]
    poison = tids[min(1, tenants - 1)]
    quotas = {tid: tenant_mod.TenantQuota(max_rows=4 * sizes[i] + 64)
              for i, tid in enumerate(tids)}
    report = {"ops": 0, "crashes": 0, "recoveries": 0, "lost_acks": 0,
              "phantoms": 0, "corrupt_rows": 0, "stale_search_hits": 0,
              "cross_tenant_hits": 0, "healthy_unavailable": 0,
              "quarantined_rejections": 0, "maintenance_failures": 0,
              "appends": 0, "deletes": 0, "searches": 0, "maintains": 0,
              "snapshots": 0, "sheds": {}, "sizes": dict(zip(tids, sizes)),
              "poisoned": poison}

    with tempfile.TemporaryDirectory() as root:
        ar = tenant_mod.TenantArena(
            d, root=root, bn=64, fault_injector=inj, **store_kw)
        models, visible = {}, {}
        for i, tid in enumerate(tids):
            codes = _mk_codes(rng, sizes[i], d)
            ids = ID_STRIDE * i + np.arange(sizes[i], dtype=np.int64)
            ar.create_tenant(tid, codes, ids=ids,
                             values=np.arange(sizes[i], dtype=np.int32),
                             quota=quotas[tid])
            models[tid] = {int(ids[j]): (codes[j].tobytes(), j)
                           for j in range(sizes[i])}
            visible[tid] = set(models[tid])
        poisoned_now = False

        def crash_recover(in_doubt_tid, in_doubt):
            nonlocal ar
            report["crashes"] += 1
            ar.close()
            ar = _recover_arena(d, root, inj, 64, store_kw, quotas)
            report["recoveries"] += 1
            for tid in tids:
                t = ar.tenant(tid)
                if t.status != tenant_mod.HEALTHY:
                    if not (poisoned_now and tid == poison):
                        report["healthy_unavailable"] += 1
                    continue
                _reconcile(t.store, models[tid],
                           in_doubt if tid == in_doubt_tid else None,
                           report)
                visible[tid] = set(_epoch_model(t.store))

        def healthy_pool():
            return [t for t in tids if not (poisoned_now and t == poison)]

        for step in range(ops):
            report["ops"] += 1
            if step == ops // 2 and not poisoned_now:
                # ---- the poison step: interior WAL corruption ----------
                saved = dict(inj.p)
                inj.p.clear()       # the two set-up appends must ack
                for _ in range(2):
                    c = _mk_codes(rng, 1, d)
                    off_before = os.path.getsize(os.path.join(
                        wal_mod.namespace_root(root, poison), "wal.log"))
                    ar.append(poison, c)
                    if _ == 0:
                        first_rec_off = off_before
                inj.p.update(saved)
                ar.close()
                wal_path = os.path.join(
                    wal_mod.namespace_root(root, poison), "wal.log")
                with open(wal_path, "r+b") as f:    # flip a payload bit of
                    f.seek(first_rec_off + wal_mod._HEADER.size)  # record 1
                    b = f.read(1)                   # of the final two ->
                    f.seek(-1, os.SEEK_CUR)         # interior corruption
                    f.write(bytes([b[0] ^ 0x08]))
                assert wal_mod.verify(wal_path)["status"] == "corrupt"
                ar = _recover_arena(d, root, inj, 64, store_kw, quotas)
                report["recoveries"] += 1
                assert ar.tenant(poison).status == tenant_mod.QUARANTINED, \
                    "poisoned tenant failed to quarantine"
                poisoned_now = True
                for tid in tids:
                    if tid == poison:
                        continue
                    if ar.tenant(tid).status != tenant_mod.HEALTHY:
                        report["healthy_unavailable"] += 1
                        continue
                    _reconcile(ar.tenant(tid).store, models[tid], None,
                               report)
                    visible[tid] = set(_epoch_model(ar.tenant(tid).store))
                continue

            # occasionally poke the quarantined tenant: it must reject
            # crisply, never crash the arena or touch its neighbours
            if poisoned_now and rng.random() < 0.05:
                try:
                    ar.append(poison, _mk_codes(rng, 1, d))
                    report["healthy_unavailable"] += 0  # unreachable ack
                except tenant_mod.TenantQuarantined:
                    report["quarantined_rejections"] += 1
                continue

            tid = str(rng.choice(healthy_pool()))
            model = models[tid]
            op = rng.choice(["append", "delete", "search", "maintain",
                             "snapshot"], p=[0.36, 0.22, 0.20, 0.18, 0.04])
            in_doubt = None
            try:
                if op == "append":
                    n = int(rng.poisson(2)) + 1
                    reason = ar.admission_check(tid, n)
                    if reason is not None:
                        report["sheds"][reason] = (
                            report["sheds"].get(reason, 0) + n)
                        continue
                    codes = _mk_codes(rng, n, d)
                    vals = rng.integers(0, 1 << 20, n).astype(np.int32)
                    nid = ar.tenant(tid).store._next_id
                    in_doubt = ("append", [
                        (nid + i, codes[i].tobytes(), int(vals[i]))
                        for i in range(n)])
                    ids = ar.append(tid, codes, values=vals)
                    for i, ext in enumerate(ids):
                        model[int(ext)] = (codes[i].tobytes(), int(vals[i]))
                    report["appends"] += n
                elif op == "delete":
                    if not model:
                        continue
                    n = min(int(rng.poisson(2)) + 1, len(model))
                    victims = sorted(int(v) for v in rng.choice(
                        np.fromiter(model, np.int64), n, replace=False))
                    in_doubt = ("delete", victims)
                    ar.delete(tid, np.asarray(victims, np.int64))
                    for v in victims:
                        del model[v]
                    report["deletes"] += n
                elif op == "search":
                    qs = {t: _mk_codes(rng, 3, d)
                          for t in ar.healthy_tids()}
                    res = ar.search(qs, k=8)
                    for t, (_dd, ee) in res.items():
                        lo, hi = (ID_STRIDE * tids.index(t),
                                  ID_STRIDE * (tids.index(t) + 1))
                        for e in np.asarray(ee).ravel():
                            e = int(e)
                            if e < 0:
                                continue
                            if not lo <= e < hi:
                                report["cross_tenant_hits"] += 1
                            elif e not in visible[t]:
                                report["stale_search_hits"] += 1
                    report["searches"] += 1
                elif op == "maintain":
                    rep = ar.maintain(compact_budget=2)
                    report["maintenance_failures"] += len(rep["failed"])
                    for t in ar.healthy_tids():
                        if t not in rep["failed"]:
                            visible[t] = set(models[t])
                    report["maintains"] += 1
                elif op == "snapshot":
                    ar.snapshot()       # per-tenant failures contained
                    report["snapshots"] += 1
            except faults_mod.InjectedFault:
                crash_recover(tid, in_doubt)
            except tenant_mod.TenantQuarantined:
                report["healthy_unavailable"] += 1

        # ---- final: cold crash, recover, verify every invariant ----------
        ar.close()
        ar = _recover_arena(d, root, None, 64, store_kw, quotas)
        report["recoveries"] += 1
        assert poisoned_now
        report["poison_quarantined"] = (
            ar.tenant(poison).status == tenant_mod.QUARANTINED)
        for tid in tids:
            if tid == poison:
                continue
            if ar.tenant(tid).status != tenant_mod.HEALTHY:
                report["healthy_unavailable"] += 1
                continue
            _reconcile(ar.tenant(tid).store, models[tid], None, report)

        # packed mixed-tenant batch vs per-tenant searches: bit-identical,
        # and timed both ways (the tentpole's one-kernel-launch claim)
        healthy = ar.healthy_tids()
        qs = {t: _mk_codes(rng, 8, d) for t in healthy}
        packed = ar.search(qs, k=8)
        identical = True
        for t in healthy:
            sd, se = ar.tenant(t).store.search(qs[t], k=8)
            dd, ee = packed[t]
            identical &= bool(np.array_equal(np.asarray(dd), np.asarray(sd))
                              and np.array_equal(np.asarray(ee),
                                                 np.asarray(se)))
        report["bit_identical"] = identical

        def _t(fn, iters=5):
            fn()
            t0 = time.perf_counter()
            for _ in range(iters):
                fn()
            return (time.perf_counter() - t0) / iters * 1e6

        report["us_packed_batch"] = _t(lambda: ar.search(qs, k=8))
        report["us_per_tenant_calls"] = _t(
            lambda: [ar.tenant(t).store.search(qs[t], k=8)
                     for t in healthy])
        report["n_healthy"] = len(healthy)
        report["fired"] = dict(inj.fired)
        report["arena"] = {k: v for k, v in ar.stats().items()
                          if k != "tenants"}
        ar.close()

    report["ok"] = (report["poison_quarantined"]
                    and report["lost_acks"] == 0
                    and report["phantoms"] == 0
                    and report["corrupt_rows"] == 0
                    and report["stale_search_hits"] == 0
                    and report["cross_tenant_hits"] == 0
                    and report["healthy_unavailable"] == 0
                    and report["bit_identical"])
    return report


def run(report):
    """benchmarks/run.py hook — reduced-scale soak; the invariants must
    hold even at smoke scale."""
    s = soak(ops=80, tenants=3, fault_p=0.02, seed=0)
    assert s["ok"], f"tenant soak invariants broken: {s}"
    report(f"tenant_soak,{s['us_packed_batch']:.1f},"
           f"tenants={len(s['sizes'])};crashes={s['crashes']};"
           f"lost_acks={s['lost_acks']};cross_tenant={s['cross_tenant_hits']};"
           f"quarantined={s['poisoned']};bit_identical={s['bit_identical']}")
    report(f"tenant_per_tenant_calls,{s['us_per_tenant_calls']:.1f},"
           f"n_healthy={s['n_healthy']};k=8;q_per_tenant=8")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--ops", type=int, default=400)
    ap.add_argument("--tenants", type=int, default=4)
    ap.add_argument("--fault-p", type=float, default=0.02)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--d", type=int, default=64)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write BENCH_tenant.json-style output to PATH")
    args = ap.parse_args()

    rep = soak(ops=args.ops, tenants=args.tenants, fault_p=args.fault_p,
               seed=args.seed, d=args.d)
    print(f"soak: ops={rep['ops']} crashes={rep['crashes']} "
          f"recoveries={rep['recoveries']} lost_acks={rep['lost_acks']} "
          f"phantoms={rep['phantoms']} cross_tenant={rep['cross_tenant_hits']} "
          f"healthy_unavailable={rep['healthy_unavailable']} "
          f"poison_quarantined={rep['poison_quarantined']} "
          f"bit_identical={rep['bit_identical']} "
          f"us_packed={rep['us_packed_batch']:.1f} "
          f"us_solo={rep['us_per_tenant_calls']:.1f}", flush=True)
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"bench": "tenant", "ops": args.ops,
                       "tenants": args.tenants, "fault_p": args.fault_p,
                       "seed": args.seed, "soak": rep}, f, indent=1)
        print(f"wrote soak report to {args.json}", file=sys.stderr)
    if not rep["ok"]:
        print("TENANT SOAK FAILED: quarantine missed, a healthy tenant "
              "lost data or availability, or packing broke bit-identity",
              file=sys.stderr)
        raise SystemExit(1)
    print("soak ok: poisoned tenant quarantined, healthy tenants lossless "
          "and available", file=sys.stderr)


if __name__ == "__main__":
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    main()
