"""Paper Fig. 4 (run-time across platforms): engine throughput for a
small (1024, fits one 'board') and large (2^17, needs chunked streaming)
dataset, across distance paths. The fp32 L2 scan is the von-Neumann
baseline; speedup-over-it is the paper's headline metric (52.6x on AP Gen1
vs multicore).

The 'large' set is 2^17 (the paper's 2^20 scaled 8x down for CPU wall time;
throughput/vector is the comparable quantity).
"""
import functools

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.util import row, time_jit, time_sharded_merge_pair
from repro.core import binary, engine, layout, plan as plan_mod
from repro.kernels import ops


def _dataset(n, d, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d)).astype(np.float32)
    bits = (x > 0).astype(np.uint8)
    return jnp.asarray(x), jnp.asarray(bits)


def _clustered_dataset(n, d, n_near=64, seed=2):
    """Sorted/clustered codes: a small near-cluster that owns the top-k,
    the rest far from the (all-zeros) queries — the block-min summary
    should prune nearly every pass-2 block."""
    rng = np.random.default_rng(seed)
    near = (rng.random((n_near, d)) < 0.05).astype(np.uint8)
    far = (rng.random((n - n_near, d)) < 0.9).astype(np.uint8)
    return jnp.asarray(np.concatenate([near, far]))


@functools.partial(jax.jit, static_argnames=("k",))
def _l2_scan(x, q, k):
    d2 = (jnp.sum(q**2, 1)[:, None] - 2 * q @ x.T + jnp.sum(x**2, 1)[None])
    return jax.lax.top_k(-d2, k)


def run(report):
    d, k, n_q = 128, 10, 256
    for label, n in [("small_1k", 1024), ("large_128k", 1 << 17)]:
        x_f32, x_bits = _dataset(n, d)
        q_f32, q_bits = _dataset(n_q, d, seed=1)
        xp, qp = binary.pack_bits(x_bits), binary.pack_bits(q_bits)

        us = time_jit(lambda: _l2_scan(x_f32, q_f32, k))
        base = us
        report(row(f"fig4/{label}/fp32_l2_scan", us, f"qps={n_q/us*1e6:.0f}"))

        search = jax.jit(functools.partial(
            engine.search_chunked, k=k, d=d, chunk=1 << 16, method="mxu"))
        us = time_jit(lambda: search(xp, qp))
        report(row(f"fig4/{label}/hamming_mxu", us,
                   f"qps={n_q/us*1e6:.0f};speedup_vs_fp32={base/us:.2f}x"))

        search_x = jax.jit(functools.partial(
            engine.search_chunked, k=k, d=d, chunk=1 << 16, method="xor"))
        us = time_jit(lambda: search_x(xp, qp))
        xor_us, xor_q = us, n_q
        report(row(f"fig4/{label}/hamming_xor_packed", us,
                   f"qps={n_q/us*1e6:.0f};speedup_vs_fp32={base/us:.2f}x"))

        # fused two-pass counting select: the (Q, N) distance matrix never
        # exists in HBM. On CPU the Pallas kernels run *interpreted*, so
        # us/call here is a correctness-path proxy, not the TPU number —
        # shrink the query batch on the large set to bound wall time, and
        # re-time the materialized-XOR path at the same batch so
        # speedup_vs_xor is an apples-to-apples pair. The single-shot path
        # (select="fused": one hist + one emit pallas_call over all of N)
        # and the chunk-scanned variant (select="fused_scan": lax.scan +
        # O(k) merge per chunk) are timed as a PAIR at a chunk that forces
        # several scan steps, so speedup_vs_scan isolates the scan
        # overhead the single-shot path removed.
        interp = jax.default_backend() != "tpu"
        nq_f = min(n_q, 32) if (interp and n > 4096) else n_q
        qf = qp[:nq_f]
        wu, it = (1, 3) if interp else (2, 5)
        if nq_f != xor_q:
            xor_us = time_jit(lambda: search_x(xp, qf), warmup=wu, iters=it)
        scan_chunk = max(256, n // 8)          # >= 4 scan steps on every set
        search_fs = jax.jit(functools.partial(
            engine.search_chunked, k=k, d=d, chunk=scan_chunk,
            select="fused_scan"))
        scan_us = time_jit(lambda: search_fs(xp, qf), warmup=wu, iters=it)
        plan_fs = plan_mod.plan_local(plan_mod.stats_of(xp, qf, d), k,
                                      select="fused_scan", chunk=scan_chunk)
        report(row(f"fig4/{label}/fused_scan_topk", scan_us,
                   f"qps={nq_f/scan_us*1e6:.0f};"
                   f"speedup_vs_xor={xor_us/scan_us:.2f}x;"
                   f"chunk={scan_chunk};n_q={nq_f};interpreted={int(interp)};"
                   f"plan={plan_fs.compact()}"))
        search_f = jax.jit(functools.partial(
            engine.search_chunked, k=k, d=d, select="fused"))
        us = time_jit(lambda: search_f(xp, qf), warmup=wu, iters=it)
        plan_f = plan_mod.plan_local(plan_mod.stats_of(xp, qf, d), k,
                                     select="fused")
        report(row(f"fig4/{label}/fused_topk", us,
                   f"qps={nq_f/us*1e6:.0f};speedup_vs_xor={xor_us/us:.2f}x;"
                   f"speedup_vs_scan={scan_us/us:.2f}x;"
                   f"n_q={nq_f};interpreted={int(interp)};"
                   f"plan={plan_f.compact()}"))

    # block-min pruning on a clustered datastore: the single-shot pass 2
    # skips every (query-block, data-block) tile whose min distance exceeds
    # the block's widest winning radius — report the skipped fraction and
    # the paired single-shot vs chunk-scanned timing on the same data.
    n_c, nq_c = 1 << 15, 16
    xp_c = binary.pack_bits(_clustered_dataset(n_c, d))
    qp_c = binary.pack_bits(jnp.zeros((nq_c, d), jnp.uint8))
    interp = jax.default_backend() != "tpu"
    wu, it = (1, 3) if interp else (2, 5)
    _, _, stats = ops.hamming_topk(qp_c, xp_c, k, d + 1, return_stats=True)
    pruned = float(jax.device_get(stats["blocks_skipped"]))
    frac = pruned / max(stats["blocks_total"], 1)
    search_f = jax.jit(functools.partial(
        engine.search_chunked, k=k, d=d, select="fused"))
    us = time_jit(lambda: search_f(xp_c, qp_c), warmup=wu, iters=it)
    search_fs = jax.jit(functools.partial(
        engine.search_chunked, k=k, d=d, chunk=n_c // 8, select="fused_scan"))
    scan_us = time_jit(lambda: search_fs(xp_c, qp_c), warmup=wu, iters=it)
    report(row("fig4/clustered_32k/fused_prune", us,
               f"qps={nq_c/us*1e6:.0f};pruned_frac={frac:.3f};"
               f"blocks_total={stats['blocks_total']};"
               f"speedup_vs_scan={scan_us/us:.2f}x;"
               f"n_q={nq_c};interpreted={int(interp)}"))

    # layout-aware pruning on UNIFORM data (core/layout.py): the paired
    # rows are the PR's claim — unordered uniform prunes ~nothing, the
    # bucket-clustered reorder of the SAME codes prunes, and a masked
    # index probe (nprobe < n_buckets) skips most pass-1 blocks outright.
    # pruned_frac_p1 = tiles the enable mask excluded from pass 1;
    # pruned_frac_p2 = tiles pass 2 skipped (mask composed with block-min).
    d_u, n_u, nq_u, k_u = 128, 1 << 14, 8, 16
    rng = np.random.default_rng(5)
    xb_u = rng.integers(0, 2, (n_u, d_u)).astype(np.uint8)
    center = rng.integers(0, 2, d_u)
    qb_u = (center[None] ^ (rng.random((nq_u, d_u)) < 0.03)).astype(np.uint8)
    xp_u = binary.pack_bits(jnp.asarray(xb_u))
    qp_u = binary.pack_bits(jnp.asarray(qb_u))
    lay = layout.build_layout(xp_u, d_u, n_buckets=16)
    geom = dict(bq=8, bn=512, sub=256)

    def fracs(stats):
        tot = max(stats["blocks_total"], 1)
        return (float(jax.device_get(stats["p1_blocks_skipped"])) / tot,
                float(jax.device_get(stats["blocks_skipped"])) / tot)

    _, _, s_u = ops.hamming_topk(qp_u, xp_u, k_u, d_u + 1,
                                 return_stats=True, **geom)
    p1_u, p2_u = fracs(s_u)
    topk_u = jax.jit(functools.partial(ops.hamming_topk, k=k_u,
                                       bins=d_u + 1, **geom))
    us_u = time_jit(lambda: topk_u(qp_u, xp_u), warmup=wu, iters=it)
    report(row("fig4/uniform_16k/fused_unordered", us_u,
               f"qps={nq_u/us_u*1e6:.0f};pruned_frac_p1={p1_u:.3f};"
               f"pruned_frac_p2={p2_u:.3f};n_q={nq_u};"
               f"interpreted={int(interp)}"))

    _, _, s_r = ops.hamming_topk(qp_u, lay.codes, k_u, d_u + 1,
                                 return_stats=True, **geom)
    p1_r, p2_r = fracs(s_r)
    us_r = time_jit(lambda: topk_u(qp_u, lay.codes), warmup=wu, iters=it)
    report(row("fig4/uniform_16k/fused_reordered", us_r,
               f"qps={nq_u/us_r*1e6:.0f};pruned_frac_p1={p1_r:.3f};"
               f"pruned_frac_p2={p2_r:.3f};"
               f"speedup_vs_unordered={us_u/us_r:.2f}x;n_q={nq_u};"
               f"interpreted={int(interp)}"))

    # masked probe of the reordered store: each query probes its own
    # Hamming-prefix bucket plus a neighbor (nprobe=2 of 16)
    bits = (lay.n_buckets - 1).bit_length()
    _, posx = layout.hamming_prefix_assign(xp_u, d_u, bits)
    aq, _ = layout.hamming_prefix_assign(qp_u, d_u, bits, posx)
    probe = jnp.stack([aq, (aq + 1) % lay.n_buckets], axis=1)
    _, _, s_m = layout.masked_topk(lay, qp_u, k_u, d_u, probe=probe,
                                   return_stats=True)
    p1_m, p2_m = fracs(s_m)
    masked = jax.jit(functools.partial(layout.masked_topk, lay, k=k_u,
                                       d=d_u))
    us_m = time_jit(lambda: masked(qp_u, probe=probe), warmup=wu, iters=it)
    report(row("fig4/uniform_16k/masked_probe_np2", us_m,
               f"qps={nq_u/us_m*1e6:.0f};pruned_frac_p1={p1_m:.3f};"
               f"pruned_frac_p2={p2_m:.3f};nprobe=2;"
               f"speedup_vs_full={us_r/us_m:.2f}x;n_q={nq_u};"
               f"interpreted={int(interp)}"))

    # planner-chosen vs forced-path pair: the same engine state searched
    # through the planner (select="auto" resolves to fused over the
    # prebuilt layout) and through the forced legacy path (fused over the
    # UNORDERED codes — what the pre-planner engine silently ran). A
    # planner-decision regression shows up as this ratio drifting < 1.
    eng_l = engine.KNNEngine(codes=xp_u, d=d_u, layout=lay)
    p_auto = eng_l.query_plan(qp_u, k_u)
    auto_fn = jax.jit(functools.partial(eng_l.search, k=k_u))
    us_auto = time_jit(lambda: auto_fn(qp_u), warmup=wu, iters=it)
    forced_fn = jax.jit(functools.partial(
        engine.search_chunked, k=k_u, d=d_u, select="fused"))
    us_forced = time_jit(lambda: forced_fn(xp_u, qp_u), warmup=wu, iters=it)
    report(row("fig4/uniform_16k/planner_vs_forced", us_auto,
               f"plan={p_auto.compact()};forced=fused_unordered;"
               f"speedup_vs_forced={us_forced/us_auto:.2f}x;n_q={nq_u};"
               f"interpreted={int(interp)}"))

    # distributed counting select vs the legacy concat/sort merge: the
    # SHARDED pair. Both plans run the same per-shard fused kernels; only
    # the merge differs — hist_merge psums (Q, bins) histograms and
    # scatters winners into disjoint output slots, concat_sort gathers and
    # sorts shards*k candidates. On a plain checkout the mesh is (1,) (the
    # collectives degenerate but the code path is real); CI's sharded job
    # re-runs fig4/fig5 with 4 fake host devices for the true shard count.
    n_s, nq_s, k_s = 1 << 14, 16, 16
    _, xb_s = _dataset(n_s, d, seed=7)
    xp_s = binary.pack_bits(xb_s)
    qp_s = binary.pack_bits(_dataset(nq_s, d, seed=8)[1])
    us_h, us_c, p_h, p_c, n_dev = time_sharded_merge_pair(
        xp_s, qp_s, k_s, d, warmup=wu, iters=it)
    m_h, m_c = p_h.geometry()["merge"], p_c.geometry()["merge"]
    report(row("fig4/sharded_16k/hist_merge", us_h,
               f"qps={nq_s/us_h*1e6:.0f};nshards={n_dev};"
               f"merge_bytes={m_h['merge_bytes']};"
               f"speedup_vs_concat={us_c/us_h:.2f}x;n_q={nq_s};"
               f"interpreted={int(interp)};plan={p_h.compact()}"))
    report(row("fig4/sharded_16k/concat_merge", us_c,
               f"qps={nq_s/us_c*1e6:.0f};nshards={n_dev};"
               f"merge_bytes={m_c['merge_bytes']};n_q={nq_s};"
               f"interpreted={int(interp)};plan={p_c.compact()}"))
