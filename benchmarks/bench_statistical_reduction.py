"""Paper Fig. 11 (statistical activation reduction): recall vs report-
bandwidth reduction for (k, k', m) sweeps — empirical group simulation (the
paper's methodology: random vectors, 100 trials) overlaid with our analytic
union bound."""
import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.util import row
from repro.core import binary, engine, hierarchy, topk


def _empirical_recall(n, m, k, kprime, trials=20, seed=0):
    rng = np.random.default_rng(seed)
    d = 64
    hits, needed = 0, 0
    for t in range(trials):
        bits = jnp.asarray(rng.integers(0, 2, (n, d)), jnp.uint8)
        qbits = jnp.asarray(rng.integers(0, 2, (1, d)), jnp.uint8)
        xp, qp = binary.pack_bits(bits), binary.pack_bits(qbits)
        exact_d, exact_i = engine.search_chunked(xp, qp, k, d)
        # local top-k' per group of m, then global merge (the reduction)
        groups = xp.reshape(n // m, m, -1)
        cand_d, cand_i = [], []
        for g in range(n // m):
            ld, li = engine.search_chunked(groups[g], qp, kprime, d)
            cand_d.append(ld)
            cand_i.append(li + g * m)
        cd = jnp.concatenate(cand_d, 1)
        ci = jnp.concatenate(cand_i, 1)
        sd, si = jax.lax.sort_key_val(cd, ci, dimension=-1)
        si = si[:, :k]
        hits += int(jnp.sum(jnp.any(si[0][:, None] == exact_i[0][None, :], 0)))
        needed += k
    return hits / needed


def run(report):
    n = 4096
    for k, kprime, m in [(16, 2, 512), (16, 4, 512), (16, 8, 512),
                         (4, 1, 256), (4, 2, 256)]:
        rec = _empirical_recall(n, m, k, kprime, trials=10)
        bound = hierarchy.failure_bound(k, n // m, kprime)
        bw = hierarchy.bandwidth_reduction(m, kprime)
        report(row(f"fig11/k{k}_kp{kprime}_m{m}", 0.0,
                   f"recall={rec:.4f};analytic_fail_bound={bound:.4f};"
                   f"bandwidth_reduction={bw:.0f}x"))
