"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Run:
    PYTHONPATH=src python -m benchmarks.run [--only fig4] [--json PATH]

``--json PATH`` additionally writes the rows machine-readable (list of
{name, us_per_call, derived:{...}} objects) so the perf trajectory is
diffable across PRs; CI names these BENCH_<tag>.json.
"""
import argparse
import json
import sys
import traceback

from benchmarks import (bench_approx, bench_compounding, bench_energy_proxy,
                        bench_indexing, bench_mutate, bench_packing,
                        bench_serve, bench_shardfault,
                        bench_statistical_reduction, bench_tenant,
                        bench_throughput, bench_workloads)

BENCHES = [
    ("fig4", bench_throughput),
    ("fig5", bench_indexing),
    ("approx", bench_approx),
    ("fig6", bench_energy_proxy),
    ("table2", bench_workloads),
    ("fig8", bench_packing),
    ("fig11", bench_statistical_reduction),
    ("fig15", bench_compounding),
    ("serve", bench_serve),
    ("mutate", bench_mutate),
    ("tenant", bench_tenant),
    ("shardfault", bench_shardfault),
]


def _parse_row(line: str) -> dict:
    """'name,123.4,qps=10;speedup=2.0x' -> structured record. Lines that
    don't follow the row() shape are kept raw rather than failing the run."""
    try:
        name, us, derived = line.split(",", 2)
        fields = {}
        for part in filter(None, derived.split(";")):
            key, _, val = part.partition("=")
            try:
                fields[key] = float(val.rstrip("x"))
            except ValueError:
                fields[key] = val
        return {"name": name, "us_per_call": float(us), "derived": fields}
    except ValueError:
        return {"raw": line}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows as a JSON list to PATH")
    args = ap.parse_args()

    rows = []

    def report(line: str) -> None:
        print(line, flush=True)
        if args.json:
            rows.append(_parse_row(line))

    print("name,us_per_call,derived")
    failed = []
    for tag, mod in BENCHES:
        if args.only and args.only not in tag:
            continue
        try:
            mod.run(report)
        except Exception:  # noqa: BLE001
            failed.append(tag)
            traceback.print_exc()
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=1)
        print(f"wrote {len(rows)} rows to {args.json}", file=sys.stderr)
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
