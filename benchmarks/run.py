"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Run:
    PYTHONPATH=src python -m benchmarks.run [--only fig4]
"""
import argparse
import sys
import traceback

from benchmarks import (bench_compounding, bench_energy_proxy, bench_indexing,
                        bench_packing, bench_statistical_reduction,
                        bench_throughput, bench_workloads)

BENCHES = [
    ("fig4", bench_throughput),
    ("fig5", bench_indexing),
    ("fig6", bench_energy_proxy),
    ("table2", bench_workloads),
    ("fig8", bench_packing),
    ("fig11", bench_statistical_reduction),
    ("fig15", bench_compounding),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    print("name,us_per_call,derived")
    failed = []
    for tag, mod in BENCHES:
        if args.only and args.only not in tag:
            continue
        try:
            mod.run(print)
        except Exception:  # noqa: BLE001
            failed.append(tag)
            traceback.print_exc()
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
