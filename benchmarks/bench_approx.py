"""The approximate peak-FLOP/s tier (DESIGN.md §7).

Two row families:

* ``fig4`` companions — ``approx_vs_fused`` at recall_target in
  {0.9, 0.99, 1.0} against the exact fused single-shot select on the same
  geometry. On CPU the fused Pallas kernels run *interpreted* while the
  approx tier is pure XLA (dot_general + sorts), so us/call ratios here
  overstate the TPU gap — rows carry ``interpreted=`` like the fig4 rows
  and the honest cross-platform quantity is the planner-reported
  arithmetic intensity (``flops_per_byte``). ``recall=`` is the MEASURED
  distance recall against the exact top-k on the same data (an approx hit
  counts when its distance is within the exact k-th), so the bound's
  prediction is auditable next to the knob.

* ``fig5`` companion — the matched-recall pair: approx full scan vs the
  masked IVF probe whose nprobe lands closest to the approx tier's
  measured recall. Same data, same k; the pair is the paper's
  quality-vs-time tradeoff with both axes measured.
"""
import functools

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.util import row, time_jit
from repro.core import binary, index, plan as plan_mod
from repro.kernels import ops


def _dataset(n, d, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray((rng.random((n, d)) < 0.5).astype(np.uint8))


def _recall(approx_d, exact_d, k):
    """Distance recall: fraction of approx results within the exact k-th
    distance (tie robust)."""
    kth = np.asarray(exact_d)[:, k - 1:k]
    return float((np.asarray(approx_d) <= kth).mean())


def run(report):
    d, k = 128, 10
    interp = jax.default_backend() != "tpu"
    wu, it = (1, 3) if interp else (2, 5)

    for label, n, n_q in [("64k", 1 << 16, 64), ("256k", 1 << 18, 32)]:
        xp = binary.pack_bits(_dataset(n, d))
        qp = binary.pack_bits(_dataset(n_q, d, seed=1))
        stats = plan_mod.stats_of(xp, qp, d)
        exact_d, _ = ops.hamming_topk(qp, xp, k, d + 1)

        p_f = plan_mod.plan_local(stats, k, select="fused")
        f_fn = jax.jit(functools.partial(plan_mod.execute, p_f, codes=xp))
        f_us = time_jit(lambda: f_fn(qp), warmup=wu, iters=it)
        report(row(f"approx/{label}/fused_exact", f_us,
                   f"qps={n_q/f_us*1e6:.0f};recall=1.000;n_q={n_q};"
                   f"interpreted={int(interp)};plan={p_f.compact()}"))

        for rt in (0.9, 0.99, 1.0):
            p_a = plan_mod.plan_local(stats, k, select="approx",
                                      recall_target=rt)
            g = p_a.explain()["geometry"]
            a_fn = jax.jit(functools.partial(plan_mod.execute, p_a,
                                             codes=xp))
            a_us = time_jit(lambda: a_fn(qp), warmup=wu, iters=it)
            rec = _recall(a_fn(qp)[0], exact_d, k)
            report(row(
                f"approx/{label}/approx_rt{rt:g}", a_us,
                f"qps={n_q/a_us*1e6:.0f};recall={rec:.3f};"
                f"predicted_recall={g['predicted_recall']:.3f};"
                f"speedup_vs_fused={f_us/a_us:.2f}x;"
                f"l_per_block={g['l_per_block']};n_blocks={g['n_blocks']};"
                f"flops_per_byte={g['flops_per_byte']:.0f};n_q={n_q};"
                f"interpreted={int(interp)};plan={p_a.compact()}"))

    # fig5 companion: matched-recall approx vs masked IVF probe
    n, n_q, rt = 1 << 16, 32, 0.9
    rng = np.random.default_rng(2)
    x = rng.normal(size=(n, d)).astype(np.float32)
    q = rng.normal(size=(n_q, d)).astype(np.float32)
    xp = binary.pack_bits(jnp.asarray((x > 0).astype(np.uint8)))
    qp = binary.pack_bits(jnp.asarray((q > 0).astype(np.uint8)))
    exact_d, _ = ops.hamming_topk(qp, xp, k, d + 1)
    stats = plan_mod.stats_of(xp, qp, d)

    p_a = plan_mod.plan_local(stats, k, select="approx", recall_target=rt)
    a_fn = jax.jit(functools.partial(plan_mod.execute, p_a, codes=xp))
    a_us = time_jit(lambda: a_fn(qp), warmup=wu, iters=it)
    a_rec = _recall(a_fn(qp)[0], exact_d, k)

    # masked IVF at the nprobe whose measured recall lands closest to the
    # approx tier's — that pair is the matched-recall comparison.
    xf, qf = jnp.asarray(x), jnp.asarray(q)
    idx = index.kmeans_build(xf, xp, d, 64, iters=5)
    best = None
    for nprobe in (2, 4, 8, 16):
        dd, _ = index.kmeans_search(idx, qf, qp, k, nprobe=nprobe)
        rec = _recall(dd, exact_d, k)
        if best is None or abs(rec - a_rec) < abs(best[1] - a_rec):
            best = (nprobe, rec)
    nprobe, ivf_rec = best
    ivf_fn = jax.jit(functools.partial(index.kmeans_search, idx, qf, qp, k,
                                       nprobe=nprobe))
    ivf_us = time_jit(lambda: ivf_fn(), warmup=wu, iters=it)
    p_i = index.kmeans_plan(idx, n_q, k, nprobe=nprobe)
    report(row(f"approx/matched_recall/approx_rt{rt:g}", a_us,
               f"qps={n_q/a_us*1e6:.0f};recall={a_rec:.3f};n_q={n_q};"
               f"interpreted={int(interp)};plan={p_a.compact()}"))
    report(row(f"approx/matched_recall/ivf_nprobe{nprobe}", ivf_us,
               f"qps={n_q/ivf_us*1e6:.0f};recall={ivf_rec:.3f};"
               f"speedup_vs_approx={a_us/ivf_us:.2f}x;n_q={n_q};"
               f"interpreted={int(interp)};plan={p_i.compact()}"))
