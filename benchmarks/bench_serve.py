"""Open-loop serving soak bench: Poisson arrivals at configurable rates
against the hardened server, measuring the SLO surface (p50/p99 token
latency, shed/timeout/degraded fractions) AND the sustained throughput
curve (qps / tokens-per-second over wall clock) under/at/over capacity,
across varying datastore sizes and an optional multi-tenant mutation mix,
with optional fault injection.

Standalone CLI (what CI's serve-soak-smoke job runs):
    PYTHONPATH=src python benchmarks/bench_serve.py \
        --ticks 200 --inject-faults --json BENCH_serve.json
Exit code is non-zero if ANY request is lost (neither done, shed, nor
timed out) — that is the invariant the soak exists to pin.

Also registered in benchmarks/run.py (tag ``serve``) with a short preset.
"""
import argparse
import dataclasses
import json
import sys
import tempfile
import time

import numpy as np

ID_STRIDE = 10_000_000          # disjoint per-tenant external id ranges


def _tiny_cfg(datastore_size: int = 512):
    from repro.configs import get_config, scaled_down
    cfg = scaled_down(get_config("gemma-2b"), d_model=64, d_ff=128,
                      vocab_size=256)
    return dataclasses.replace(cfg, retrieval=dataclasses.replace(
        cfg.retrieval, datastore_size=datastore_size, code_bits=64, k=8,
        chunk_size=512))


def _build(cfg):
    import jax
    from repro import compat
    from repro.core import retrieval
    from repro.models import lm
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    store = retrieval.synthetic_datastore(cfg)
    return mesh, params, store


def _mk_tenant_arena(d: int, n_tenants: int, seed: int):
    """A small in-memory multi-tenant arena with skewed sizes (one big
    tenant, a tail of small ones) for the mixed-mutation soak rows."""
    from repro.core import tenant as tenant_mod
    rng = np.random.default_rng(seed)
    ar = tenant_mod.TenantArena(d, bn=64, slack_frac=0.2, min_slack=4,
                                max_pending=256)
    sizes = [max(8, 128 >> (2 * i)) for i in range(n_tenants)]
    for i in range(n_tenants):
        codes = rng.integers(0, 2 ** 32, (sizes[i], d // 32),
                             dtype=np.uint32)
        ids = ID_STRIDE * i + np.arange(sizes[i], dtype=np.int64)
        ar.create_tenant(f"t{i}", codes, ids=ids,
                         values=np.arange(sizes[i], dtype=np.int32))
    return ar


def run_rate(cfg, mesh, params, store, *, rate: float, ticks: int,
             seed: int = 0, inject: bool = False, deadline: int = 50,
             max_queue: int = 8, max_batch: int = 4, max_len: int = 24,
             max_new_tokens: int = 8, snapshot_dir=None,
             tenant_mix=None) -> dict:
    """Drive one open-loop run: Poisson(rate) arrivals per tick for 70% of
    ``ticks``, then drain (deadlines bound the drain). ``tenant_mix``
    ({tenant -> submission probability per tick}) attaches a multi-tenant
    arena and drives a skewed append mix alongside the query load."""
    from repro.runtime import faults as faults_mod, server as server_mod
    inj = None
    if inject:
        inj = faults_mod.FaultInjector(
            seed=seed + 1, p={"store_search": 0.05, "ckpt_save": 0.05,
                              "ckpt_restore": 0.05})
    arena = None
    if tenant_mix:
        arena = _mk_tenant_arena(cfg.retrieval.code_bits,
                                 len(tenant_mix), seed)
    srv = server_mod.Server(
        cfg, mesh, params, max_batch=max_batch, max_len=max_len, store=store,
        max_queue=max_queue, default_deadline_ticks=deadline,
        degradation=server_mod.DegradationPolicy(queue_high=3, queue_low=1,
                                                 cooldown_ticks=4),
        fault_injector=inj, snapshot_dir=snapshot_dir if inject else None,
        snapshot_every=10 if inject else None, tenants=arena)
    rng = np.random.default_rng(seed)
    uid = 0
    mut_uid = 0
    arrive_until = int(ticks * 0.7)
    t_wall = time.perf_counter()
    for t in range(ticks):
        if t < arrive_until:
            for _ in range(rng.poisson(rate)):
                plen = int(rng.integers(1, 4))
                srv.submit(server_mod.Request(
                    uid=uid,
                    prompt=rng.integers(0, cfg.vocab_size, plen).astype(
                        np.int32),
                    max_new_tokens=max_new_tokens))
                uid += 1
            if tenant_mix:
                for i, (tid, p) in enumerate(sorted(tenant_mix.items())):
                    if rng.random() < p:
                        w = cfg.retrieval.code_bits // 32
                        codes = rng.integers(0, 2 ** 32, (1, w),
                                             dtype=np.uint32)
                        srv.submit_append(
                            codes, values=np.array([mut_uid % 256],
                                                   np.int32),
                            tenant=tid)
                        mut_uid += 1
        srv.tick()
    guard = ticks + deadline + 100
    while srv.has_work and srv.ticks < guard:
        srv.tick()
    wall = time.perf_counter() - t_wall
    s = srv.stats()
    s["rate"] = rate
    s["inject_faults"] = inject
    s["store_n"] = int(store.codes.shape[0])
    s["tenant_mix"] = dict(tenant_mix) if tenant_mix else None
    # the sustained-throughput surface: requests and tokens per wall
    # second over the WHOLE run, drain included — the QPS curve a capacity
    # plan reads, not just the survival booleans
    s["wall_s"] = wall
    s["qps_sustained"] = s["done"] / max(wall, 1e-9)
    s["tokens_per_s"] = len(srv.token_lat_s) / max(wall, 1e-9)
    return s


def sweep(rates=(0.2, 0.6, 2.0), ticks: int = 300, inject: bool = False,
          seed: int = 0, store_sizes=(512,), tenant_mix: bool = False
          ) -> list:
    """Arrival-rate rows (under / at / over the slot-pool capacity,
    ~0.5 req/tick at max_batch=4) crossed with datastore sizes, plus —
    with ``tenant_mix`` — a skewed multi-tenant mutation mix at the
    middle rate: the sustained QPS curve over store scale and tenancy."""
    rows = []
    for size in store_sizes:
        cfg = _tiny_cfg(datastore_size=size)
        mesh, params, store = _build(cfg)
        with tempfile.TemporaryDirectory() as tmp:
            for rate in rates:
                rows.append(run_rate(cfg, mesh, params, store, rate=rate,
                                     ticks=ticks, seed=seed, inject=inject,
                                     snapshot_dir=tmp))
            if tenant_mix:
                mix = {"t0": 0.5, "t1": 0.2, "t2": 0.1}
                rows.append(run_rate(
                    cfg, mesh, params, store, rate=rates[len(rates) // 2],
                    ticks=ticks, seed=seed, inject=inject,
                    snapshot_dir=tmp, tenant_mix=mix))
    return rows


def _row_line(s: dict) -> str:
    derived = (f"rate={s['rate']};store_n={s['store_n']};"
               f"submitted={s['submitted']};"
               f"done={s['done']};lost={s['lost']};"
               f"qps={s['qps_sustained']:.2f};"
               f"tokens_per_s={s['tokens_per_s']:.1f};"
               f"p50_token_ms={s['p50_token_s'] * 1e3:.2f};"
               f"p99_token_ms={s['p99_token_s'] * 1e3:.2f};"
               f"shed_frac={s['shed_frac']:.3f};"
               f"timeout_frac={s['timeout_frac']:.3f};"
               f"degraded_frac={s['degraded_frac']:.3f};"
               f"transitions={s['transitions']};"
               f"search_retries={s['search_retries']}")
    if s.get("tenant_mix"):
        derived += f";tenants={len(s['tenant_mix'])}"
    name = f"serve_r{s['rate']:g}_n{s['store_n']}"
    if s.get("tenant_mix"):
        name += "_mix"
    if s["inject_faults"]:
        name += "_faults"
    return f"{name},{s['mean_tick_s'] * 1e6:.1f},{derived}"


def run(report):
    """benchmarks/run.py hook — short clean sweep (no fault injection,
    timing-pure), one store size."""
    for s in sweep(rates=(0.2, 0.6, 2.0), ticks=120, inject=False):
        report(_row_line(s))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--ticks", type=int, default=300)
    ap.add_argument("--rates", default="0.2,0.6,2.0",
                    help="comma-separated arrivals/tick (under/at/over)")
    ap.add_argument("--store-sizes", default="512,2048",
                    help="comma-separated datastore sizes to sweep")
    ap.add_argument("--tenant-mix", action="store_true",
                    help="add a skewed multi-tenant mutation-mix row per "
                         "store size")
    ap.add_argument("--inject-faults", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write BENCH_serve.json-style output to PATH")
    args = ap.parse_args()

    rates = tuple(float(r) for r in args.rates.split(","))
    sizes = tuple(int(n) for n in args.store_sizes.split(","))
    rows = sweep(rates=rates, ticks=args.ticks, inject=args.inject_faults,
                 seed=args.seed, store_sizes=sizes,
                 tenant_mix=args.tenant_mix)
    print("name,us_per_call,derived")
    for s in rows:
        print(_row_line(s), flush=True)
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"bench": "serve", "config": "gemma-2b(tiny)",
                       "ticks": args.ticks,
                       "store_sizes": list(sizes),
                       "tenant_mix": args.tenant_mix,
                       "inject_faults": args.inject_faults,
                       "rows": rows}, f, indent=1)
        print(f"wrote {len(rows)} rows to {args.json}", file=sys.stderr)

    lost = sum(s["lost"] for s in rows)
    if lost:
        print(f"LOST REQUESTS: {lost} — the no-lost-request invariant is "
              "broken", file=sys.stderr)
        raise SystemExit(1)
    print("no lost requests", file=sys.stderr)


if __name__ == "__main__":
    import os
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    main()
