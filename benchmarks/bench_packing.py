"""Paper Fig. 8 (vector packing microbenchmark): on the AP, packing
*increased* utilization due to routing pressure. On TPU there is no routing
fabric: bit-packing is a strict win. We measure the same 8-vector x
{32,64,128}-dim microbenchmark plus at-scale bytes/runtime."""
import functools

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.util import row, time_jit
from repro.core import binary


def run(report):
    rng = np.random.default_rng(0)
    # paper's microbenchmark: 8 vectors, 32/64/128 dims — resource analogue
    for d in (32, 64, 128):
        bits = jnp.asarray(rng.integers(0, 2, (8, d)), jnp.uint8)
        unpacked_bytes = bits.size * 1          # uint8 per dim
        packed_bytes = binary.pack_bits(bits).size * 4
        report(row(f"fig8/micro_d{d}", 0.0,
                   f"unpacked_B={unpacked_bytes};packed_B={packed_bytes};"
                   f"ratio={unpacked_bytes/packed_bytes:.1f}x"))

    # at scale: distance scan over packed vs unpacked representations
    n, d, n_q = 1 << 16, 128, 128
    bits = jnp.asarray(rng.integers(0, 2, (n, d)), jnp.uint8)
    qbits = jnp.asarray(rng.integers(0, 2, (n_q, d)), jnp.uint8)
    xp, qp = binary.pack_bits(bits), binary.pack_bits(qbits)

    unpacked = jax.jit(lambda q, x: binary.hamming_mxu(q, x, d))
    us_u = time_jit(lambda: unpacked(qbits, bits))
    packed = jax.jit(binary.hamming_xor)
    us_p = time_jit(lambda: packed(qp, xp))
    report(row("fig8/scan_unpacked_mxu", us_u,
               f"HBM_B={n*d*2}"))
    report(row("fig8/scan_packed_xor", us_p,
               f"HBM_B={n*d//8};bytes_saved={16.0:.0f}x;"
               f"paper_conclusion_inverted=true"))
