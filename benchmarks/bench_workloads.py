"""Paper Table 2 workloads end-to-end: kNN-WordEmbed (d=64, k=2),
kNN-SIFT (d=128, k=4), kNN-TagSpace (d=256, k=16); 4096 queries (as in the
paper) against 64k vectors."""
import functools

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.util import row, time_jit
from repro.core import binary, engine

WORKLOADS = [("kNN-WordEmbed", 64, 2), ("kNN-SIFT", 128, 4),
             ("kNN-TagSpace", 256, 16)]


def run(report):
    n, n_q = 1 << 16, 4096
    rng = np.random.default_rng(0)
    for name, d, k in WORKLOADS:
        bits = jnp.asarray(rng.integers(0, 2, (n, d)), jnp.uint8)
        qbits = jnp.asarray(rng.integers(0, 2, (n_q, d)), jnp.uint8)
        xp, qp = binary.pack_bits(bits), binary.pack_bits(qbits)
        search = jax.jit(functools.partial(
            engine.search_chunked, k=k, d=d, chunk=1 << 16, method="mxu"))
        us = time_jit(lambda: search(xp, qp), warmup=1, iters=3)
        report(row(f"table2/{name}", us,
                   f"d={d};k={k};qps={n_q/us*1e6:.0f};"
                   f"Mcmp_per_s={n*n_q/us:.0f}"))
