"""Kill-shards-mid-soak harness for the shard-fault-tolerance layer
(dist/search.py + dist/health.py + dist/sharding.ReplicaMap).

A seeded query stream runs against a 4-unit FaultTolerantSearch while
units are hard-killed mid-stream at a configurable probability per tick
(and revived/re-replicated in the background), with the low-rate
``shard_hist``/``shard_emit``/``merge_psum`` injected faults on top.
EVERY answer is checked against the from-scratch reference over exactly
the rows its CoverageReport claims were searched — the two invariants the
soak exists to pin:

1. zero lost requests: every query returns an answer, degraded or not;
2. coverage is never silently mis-reported: the answer is bit-identical
   (dists AND ids) to ``ops.hamming_topk`` over precisely
   ``covered_rows`` rows, never fewer, never more.

Separate scenario rows pin the rest of the acceptance surface: with
replication factor 2 a double-kill degrades exactly one range and
coverage returns to 1.0 after re-replication; the hierarchical host merge
is bit-identical across fanouts (tree == flat); and an SPMD subprocess
(4 fake devices) pins hist_tree == hist_merge == single-device reference
through the jitted ``engine.search_sharded`` path.

Standalone CLI (what CI's shardfault-soak-smoke job runs):
    PYTHONPATH=src python benchmarks/bench_shardfault.py \
        --ticks 150 --kill-p 0.05 --json BENCH_shardfault.json
Exit code is non-zero if any invariant breaks. Also registered in
benchmarks/run.py (tag ``shardfault``) with a short, SPMD-free preset.
"""
import argparse
import json
import os
import subprocess
import sys
import time

import numpy as np

COUNTS = (300, 512, 11, 201)     # deliberately uneven: unit2 is tiny
D = 64


def _corpus(seed: int):
    rng = np.random.default_rng(seed)
    codes = rng.integers(0, 2 ** 32, (sum(COUNTS), D // 32), dtype=np.uint32)
    return rng, codes


def kill_soak(*, ticks: int, kill_p: float, revive_p: float, factor: int,
              seed: int = 0, k: int = 16, q_batch: int = 4,
              fault_p: float = 0.01) -> dict:
    """The mid-stream kill soak: returns the verified stats row."""
    from repro.dist.search import FaultTolerantSearch, reference_over_covered
    from repro.runtime import faults as faults_mod

    rng, codes = _corpus(seed)
    inj = faults_mod.FaultInjector(
        seed=seed + 1, p={"shard_hist": fault_p, "shard_emit": fault_p,
                          "merge_psum": fault_p})
    # generous per-call deadline: the soak's kills are explicit; the
    # deadline-driven suspect/dead walk is pinned in tests/test_shard_faults
    fts = FaultTolerantSearch(codes, D, counts=list(COUNTS), factor=factor,
                              injector=inj, deadline_s=5.0)
    row = {"ticks": ticks, "kill_p": kill_p, "revive_p": revive_p,
           "factor": factor, "submitted": 0, "answered": 0, "lost": 0,
           "mismatches": 0, "coverage_misreports": 0, "degraded_answers": 0,
           "kills": 0, "revives": 0, "coverage_min": 1.0}
    t0 = time.perf_counter()
    for _t in range(ticks):
        if rng.random() < kill_p:
            serving = sorted(fts.registry.serving())
            if serving:
                fts.kill(serving[int(rng.integers(len(serving)))])
                row["kills"] += 1
        if rng.random() < revive_p:
            dead = sorted(fts.registry.dead())
            if dead:
                # factor>1 can refill a cold (wiped) unit from replicas;
                # factor 1 has no second copy, so revive warm
                cold = factor > 1 and bool(rng.integers(2))
                fts.revive(dead[int(rng.integers(len(dead)))],
                           with_data=not cold)
                row["revives"] += 1
        q = rng.integers(0, 2 ** 32, (q_batch, D // 32), dtype=np.uint32)
        row["submitted"] += 1
        try:
            dd, ii, rep = fts.search(q, k)
        except Exception:  # noqa: BLE001 — a lost request is the failure
            row["lost"] += 1
            continue
        row["answered"] += 1
        m = fts.covered_row_ids()
        if rep.covered_rows != m.size:
            row["coverage_misreports"] += 1
        rd, ri = reference_over_covered(codes, q, k, D, m)
        if not (np.array_equal(dd, rd) and np.array_equal(ii, ri)):
            row["mismatches"] += 1
        if not rep.complete:
            row["degraded_answers"] += 1
        row["coverage_min"] = min(row["coverage_min"], rep.coverage_frac)
        fts.maintain(budget=1)
    wall = time.perf_counter() - t0
    row.update(fts.counters)
    row["wall_s"] = wall
    row["qps"] = row["answered"] / max(wall, 1e-9)
    row["injected"] = {s: n for s, n in inj.fired.items()}
    row["ok"] = (row["lost"] == 0 and row["mismatches"] == 0
                 and row["coverage_misreports"] == 0)
    return row


def replication_scenario(seed: int = 0, k: int = 16) -> dict:
    """R=2 acceptance row: a double-kill loses exactly one range
    (degraded-but-exact), and re-replication returns coverage to 1.0."""
    from repro.dist.search import FaultTolerantSearch, reference_over_covered

    rng, codes = _corpus(seed)
    q = rng.integers(0, 2 ** 32, (5, D // 32), dtype=np.uint32)
    N = codes.shape[0]
    fts = FaultTolerantSearch(codes, D, counts=list(COUNTS), factor=2,
                              deadline_s=5.0)
    row = {"factor": 2, "ok": True}

    # one kill: the replica serves, coverage stays 1.0
    fts.kill("unit1")
    dd, ii, rep = fts.search(q, k)
    rd, ri = reference_over_covered(codes, q, k, D, np.arange(N))
    row["single_kill_exact"] = bool(np.array_equal(dd, rd)
                                    and np.array_equal(ii, ri))
    row["single_kill_coverage"] = rep.coverage_frac

    # second kill takes range 1's last holder: degraded, still exact
    fts.kill("unit2")
    dd, ii, rep = fts.search(q, k)
    m = fts.covered_row_ids()
    rd, ri = reference_over_covered(codes, q, k, D, m)
    row["double_kill_exact"] = bool(np.array_equal(dd, rd)
                                    and np.array_equal(ii, ri))
    row["double_kill_coverage"] = rep.coverage_frac
    row["double_kill_dead"] = list(rep.dead_shards)

    # warm revive + background re-replication: coverage returns to 1.0
    fts.revive("unit1", with_data=True)
    m1 = fts.maintain()
    dd, ii, rep = fts.search(q, k)
    rd, ri = reference_over_covered(codes, q, k, D, np.arange(N))
    row["recovered_exact"] = bool(np.array_equal(dd, rd)
                                  and np.array_equal(ii, ri))
    row["recovered_coverage"] = rep.coverage_frac
    row["rebuilt_ranges"] = m1["copied"]
    row["ok"] = (row["single_kill_exact"] and row["double_kill_exact"]
                 and row["single_kill_coverage"] == 1.0
                 and abs(row["double_kill_coverage"]
                         - (N - COUNTS[1]) / N) < 1e-9
                 and row["recovered_exact"]
                 and row["recovered_coverage"] == 1.0)
    return row


def merge_identity(seed: int = 0, k: int = 16) -> dict:
    """Healthy fleet: the hierarchical host merge is bit-identical across
    every fanout (tree schedules == the flat single-group sum)."""
    from repro.dist.search import FaultTolerantSearch, reference_over_covered

    rng, codes = _corpus(seed)
    q = rng.integers(0, 2 ** 32, (5, D // 32), dtype=np.uint32)
    rd, ri = reference_over_covered(codes, q, k, D,
                                    np.arange(codes.shape[0]))
    row = {"fanouts": [], "ok": True}
    for fanout in (2, 3, 4):     # 4 units: fanout 4 IS the flat merge
        fts = FaultTolerantSearch(codes, D, counts=list(COUNTS),
                                  fanout=fanout, deadline_s=5.0)
        dd, ii, rep = fts.search(q, k)
        same = bool(np.array_equal(dd, rd) and np.array_equal(ii, ri)
                    and rep.complete)
        row["fanouts"].append({"fanout": fanout, "identical": same})
        row["ok"] = row["ok"] and same
    return row


_SPMD_SCRIPT = """
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.core import binary, engine
from repro.kernels import ops
rng = np.random.default_rng(11)
d, N, Q, k = 64, 2048, 8, 16
xp = binary.pack_bits(jnp.asarray(rng.integers(0, 2, (N, d)), jnp.uint8))
qp = binary.pack_bits(jnp.asarray(rng.integers(0, 2, (Q, d)), jnp.uint8))
mesh = Mesh(np.array(jax.devices()).reshape(4), ("data",))
rd, ri = ops.hamming_topk(qp, xp, k, d + 1)
with mesh:
    hd, hi = engine.search_sharded(xp, qp, k, d, mesh, ("data",))
    td, ti = engine.search_sharded(xp, qp, k, d, mesh, ("data",),
                                   merge="hist_tree", fanout=2)
assert (hd == rd).all() and (hi == ri).all(), "hist_merge != reference"
assert (td == hd).all() and (ti == hi).all(), "hist_tree != hist_merge"
import warnings
part = jnp.asarray(np.array([1, 0, 1, 1], np.int32))
surv = jnp.asarray(np.concatenate([np.asarray(xp)[:512],
                                   np.asarray(xp)[1024:]]))
rd2, ri2 = ops.hamming_topk(qp, surv, k, d + 1)
with mesh, warnings.catch_warnings():
    warnings.simplefilter("ignore")
    md, mi = engine.search_sharded(xp, qp, k, d, mesh, ("data",),
                                   merge="hist_tree", fanout=2,
                                   shard_participate=part)
assert (md == rd2).all() and (mi == ri2).all(), "masked tree != rebuild"
print("SPMD_OK")
"""


def spmd_identity() -> dict:
    """hist_tree == hist_merge == single-device reference through the
    jitted SPMD path, in a 4-fake-device subprocess."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4")
    env["JAX_PLATFORMS"] = "cpu"
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.abspath(src), env.get("PYTHONPATH", "")])
    proc = subprocess.run([sys.executable, "-c", _SPMD_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    ok = proc.returncode == 0 and "SPMD_OK" in proc.stdout
    row = {"ok": ok}
    if not ok:
        row["stdout"] = proc.stdout[-2000:]
        row["stderr"] = proc.stderr[-2000:]
    return row


def _report_rows(rows: dict, report) -> None:
    for name, r in rows.items():
        if name.startswith("soak"):
            derived = (f"ok={r['ok']};kills={r['kills']};"
                       f"revives={r['revives']};lost={r['lost']};"
                       f"mismatches={r['mismatches']};"
                       f"degraded_answers={r['degraded_answers']};"
                       f"coverage_min={r['coverage_min']:.3f};"
                       f"failovers={r['failovers']};qps={r['qps']:.1f}")
            us = r["wall_s"] * 1e6 / max(r["answered"], 1)
        else:
            derived = f"ok={r['ok']}"
            us = 0.0
        report(f"shardfault_{name},{us:.1f},{derived}")


def run(report):
    """benchmarks/run.py hook — short preset, host-level only (the SPMD
    subprocess row is CI's standalone invocation)."""
    rows = {
        "soak_r1": kill_soak(ticks=40, kill_p=0.05, revive_p=0.15, factor=1),
        "soak_r2": kill_soak(ticks=40, kill_p=0.05, revive_p=0.15, factor=2),
        "replication": replication_scenario(),
        "merge_identity": merge_identity(),
    }
    _report_rows(rows, report)
    if not all(r["ok"] for r in rows.values()):
        raise RuntimeError("shardfault invariants violated")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--ticks", type=int, default=150)
    ap.add_argument("--kill-p", type=float, default=0.05)
    ap.add_argument("--revive-p", type=float, default=0.15)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-spmd", action="store_true",
                    help="skip the 4-fake-device subprocess identity row")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write BENCH_shardfault.json-style output to PATH")
    args = ap.parse_args()

    rows = {
        "soak_r1": kill_soak(ticks=args.ticks, kill_p=args.kill_p,
                             revive_p=args.revive_p, factor=1,
                             seed=args.seed),
        "soak_r2": kill_soak(ticks=args.ticks, kill_p=args.kill_p,
                             revive_p=args.revive_p, factor=2,
                             seed=args.seed),
        "replication": replication_scenario(seed=args.seed),
        "merge_identity": merge_identity(seed=args.seed),
    }
    if not args.no_spmd:
        rows["spmd_identity"] = spmd_identity()

    print("name,us_per_call,derived")
    _report_rows(rows, lambda line: print(line, flush=True))
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"bench": "shardfault", "counts": list(COUNTS),
                       "ticks": args.ticks, "kill_p": args.kill_p,
                       "rows": rows}, f, indent=1)
        print(f"wrote {len(rows)} rows to {args.json}", file=sys.stderr)

    bad = [n for n, r in rows.items() if not r["ok"]]
    if bad:
        print(f"SHARD-FAULT INVARIANTS VIOLATED: {bad}", file=sys.stderr)
        raise SystemExit(1)
    print("all shard-fault invariants held", file=sys.stderr)


if __name__ == "__main__":
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    main()
