"""Benchmark timing helpers."""
from __future__ import annotations

import time

import jax


def time_jit(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall time in microseconds of a (jitted) call."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def row(name: str, us: float, derived: str) -> str:
    return f"{name},{us:.1f},{derived}"


def time_sharded_merge_pair(codes, queries, k: int, d: int, *,
                            warmup: int = 1, iters: int = 3):
    """Shared harness for the sharded hist-vs-concat merge pair (fig4 and
    fig5 both report it): build a power-of-two mesh over the local devices
    (a 1-device checkout degenerates to (1,); CI's sharded job runs with 4
    fake host devices), plan the exact sharded search both ways — the
    hist_merge distributed counting select vs the forced concat/sort merge
    over the same fused per-shard kernels — and time both.

    Returns (us_hist, us_concat, plan_hist, plan_concat, n_dev)."""
    import numpy as np

    from jax.sharding import Mesh
    from repro.core import plan as plan_mod

    devs = jax.devices()
    n_dev = 1 << (len(devs).bit_length() - 1)      # largest power of two
    mesh = Mesh(np.array(devs[:n_dev]).reshape(n_dev), ("data",))
    stats = plan_mod.stats_for(codes.shape[0], d, codes.shape[1],
                               queries.shape[0], n_shards=n_dev)
    p_h = plan_mod.plan_sharded(stats, k, axes=("data",))
    p_c = plan_mod.plan_sharded(stats, k, axes=("data",),
                                select="fused", merge="concat_sort")
    with mesh:
        h_fn = jax.jit(lambda c, q: plan_mod.execute(p_h, q, codes=c,
                                                     mesh=mesh))
        us_h = time_jit(lambda: h_fn(codes, queries), warmup=warmup,
                        iters=iters)
        c_fn = jax.jit(lambda c, q: plan_mod.execute(p_c, q, codes=c,
                                                     mesh=mesh))
        us_c = time_jit(lambda: c_fn(codes, queries), warmup=warmup,
                        iters=iters)
    return us_h, us_c, p_h, p_c, n_dev
