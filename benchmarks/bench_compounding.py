"""Paper Fig. 15 (compounding the mutually orthogonal optimizations):
baseline fp32 scan -> +binary (MXU) -> +bit packing -> +counting-select
(temporal sort) -> +chunked streaming merge. Cumulative speedup per stage,
the TPU analogue of the paper's tech-scaling/decomposition/packing stack."""
import functools

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.util import row, time_jit
from repro.core import binary, engine, topk


def run(report):
    n, d, k, n_q = 1 << 17, 128, 16, 128
    rng = np.random.default_rng(0)
    x = rng.normal(size=(n, d)).astype(np.float32)
    bits = jnp.asarray((x > 0).astype(np.uint8))
    q = jnp.asarray(x[:n_q])
    qbits = bits[:n_q]
    xp, qp = binary.pack_bits(bits), binary.pack_bits(qbits)
    x_j = jnp.asarray(x)

    @jax.jit
    def stage0(xf, qf):          # fp32 L2 + full sort
        d2 = (jnp.sum(qf**2, 1)[:, None] - 2 * qf @ xf.T + jnp.sum(xf**2, 1)[None])
        return jnp.sort(d2, axis=1)[:, :k]

    @jax.jit
    def stage1(xb, qb):          # binary codes on MXU + full sort
        return jnp.sort(binary.hamming_mxu(qb, xb, d), axis=1)[:, :k]

    @jax.jit
    def stage2(xpk, qpk):        # + bit packing (32x smaller operands)
        return jnp.sort(binary.hamming_xor(qpk, xpk), axis=1)[:, :k]

    @jax.jit
    def stage3(xpk, qpk):        # + counting-select (temporal sort analogue)
        return topk.counting_topk_bisect(binary.hamming_xor(qpk, xpk), k, d)

    stage4 = jax.jit(functools.partial(  # + chunked streaming merge
        engine.search_chunked, k=k, d=d, chunk=1 << 14, method="xor",
        select="bisect"))

    stage5 = jax.jit(functools.partial(  # + composite-key fast select
        engine.search_chunked, k=k, d=d, chunk=1 << 14, method="xor",
        select="auto"))

    base = time_jit(lambda: stage0(x_j, q))
    report(row("fig15/0_fp32_fullsort", base, "cum=1.00x"))
    for name, fn, args in [
        ("1_binary_mxu", stage1, (bits, qbits)),
        ("2_bit_packed", stage2, (xp, qp)),
        ("3_counting_select", stage3, (xp, qp)),
        ("4_chunked_stream", stage4, (xp, qp)),
        ("5_fast_select", stage5, (xp, qp)),
    ]:
        us = time_jit(lambda fn=fn, args=args: fn(*args))
        report(row(f"fig15/{name}", us, f"cum={base/us:.2f}x"))
