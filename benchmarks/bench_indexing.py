"""Paper Fig. 5 (spatial indexing techniques vs linear): IVF/k-means, LSH,
randomized kd-trees vs the linear scan — run time + recall@10. Bucket sizes
follow the paper's rule (bucket ~= one board/chunk capacity)."""
import functools

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.util import row, time_jit, time_sharded_merge_pair
from repro.core import binary, engine, index


def run(report):
    d, k, n, n_q = 64, 10, 1 << 15, 128
    rng = np.random.default_rng(0)
    centers = rng.normal(size=(32, d)) * 4
    which = rng.integers(0, 32, n)
    x = (centers[which] + rng.normal(size=(n, d))).astype(np.float32)
    bits = jnp.asarray((x > 0).astype(np.uint8))
    codes = binary.pack_bits(bits)
    # locality-sorted query batch over a hot working set (8 of the 32
    # clusters): grouped queries are how a masked probe keeps its
    # per-query-block union tight — and how decode-time batches
    # (consecutive hidden states of a few active sequences) actually arrive
    hot = np.flatnonzero(which < 8)[:n_q]
    qsel = hot[np.argsort(which[hot], kind="stable")]
    q = x[qsel]
    q_codes = binary.pack_bits(bits[qsel])

    exact_d, exact_i = engine.search_chunked(codes, q_codes, k, d)

    def recall(ids):
        return float(jnp.mean(jnp.any(jnp.asarray(ids)[:, :, None] ==
                                      exact_i[:, None, :], axis=1)))

    lin = jax.jit(functools.partial(engine.search_chunked, k=k, d=d))
    us = time_jit(lambda: lin(codes, q_codes))
    base = us
    report(row("fig5/linear", us, "recall=1.000;rel=1.00x"))

    # gather-IVF vs masked-fused-IVF at MATCHED nprobe: same traversal, same
    # probed buckets; the masked path streams only the enabled grid tiles
    # through the fused kernels (p1_skip = fraction of pass-1 tiles never
    # touched) instead of gathering a (Q, C, W) candidate tensor
    km = index.kmeans_build(jnp.asarray(x), codes, d, 32, iters=8)
    km_gather = jax.jit(lambda qq, qc: index.kmeans_search(
        km, qq, qc, k, nprobe=2, use_layout=False))
    _, ids = km_gather(jnp.asarray(q), q_codes)
    us = time_jit(lambda: km_gather(jnp.asarray(q), q_codes))
    plan_g = index.kmeans_plan(km, n_q, k, nprobe=2, use_layout=False)
    report(row("fig5/kmeans_ivf_gather", us,
               f"recall={recall(ids):.3f};rel={base/us:.2f}x;nprobe=2;"
               f"plan={plan_g.compact()}"))

    km_masked = jax.jit(lambda qq, qc: index.kmeans_search(
        km, qq, qc, k, nprobe=2))
    _, ids_m = km_masked(jnp.asarray(q), q_codes)
    _, _, stats = index.kmeans_search(km, jnp.asarray(q), q_codes, k,
                                      nprobe=2, return_stats=True)
    p1_skip = (float(jax.device_get(stats["p1_blocks_skipped"]))
               / max(stats["blocks_total"], 1))
    us_m = time_jit(lambda: km_masked(jnp.asarray(q), q_codes))
    interp = int(jax.default_backend() != "tpu")
    plan_m = index.kmeans_plan(km, n_q, k, nprobe=2)
    report(row("fig5/kmeans_ivf_masked", us_m,
               f"recall={recall(ids_m):.3f};rel={base/us_m:.2f}x;nprobe=2;"
               f"p1_skip={p1_skip:.3f};speedup_vs_gather={us/us_m:.2f}x;"
               f"interpreted={interp};plan={plan_m.compact()}"))

    # planner-chosen (masked) vs forced (gather) pair on identical probes:
    # the planner's default must not regress against the forced legacy path
    report(row("fig5/kmeans_planner_vs_forced", us_m,
               f"plan={plan_m.compact()};forced=gather;"
               f"speedup_vs_forced={us/us_m:.2f}x;nprobe=2;"
               f"interpreted={interp}"))

    lsh = index.lsh_build(codes, d, n_tables=4, bits_per_table=8)
    lsh_search = jax.jit(lambda qc: index.lsh_search(lsh, qc, k))
    _, ids = lsh_search(q_codes)
    us = time_jit(lambda: lsh_search(q_codes))
    report(row("fig5/lsh", us,
               f"recall={recall(ids):.3f};rel={base/us:.2f}x;"
               f"plan={index.lsh_plan(lsh, n_q, k).compact()}"))

    kt = index.KDTreeIndex(x, codes, d, n_trees=4, leaf_size=512)
    _, ids = kt.search(q, q_codes, k)
    us = time_jit(lambda: kt.search(q, q_codes, k))  # includes host traversal
    report(row("fig5/kdtree", us, f"recall={recall(ids):.3f};rel={base/us:.2f}x"))

    # sharded merge pair (paper's cross-chip scaling claim): the same
    # datastore spread over the device mesh, searched exactly through the
    # hist_merge distributed counting select vs the legacy concat/sort
    # merge. Both are exact (recall 1.0 by construction) — the pair
    # isolates the merge cost; merge_bytes is the planner's predicted
    # cross-device traffic (tuning.shard_hints). Run under
    # XLA_FLAGS=--xla_force_host_platform_device_count=4 (CI's sharded
    # job) for a real shard count; a 1-device checkout degenerates to (1,).
    interp = int(jax.default_backend() != "tpu")
    wu, it = (1, 3) if interp else (2, 5)
    nq_s = min(n_q, 16) if interp else n_q
    qp_s = q_codes[:nq_s]
    us_h, us_c, p_h, p_c, n_dev = time_sharded_merge_pair(
        codes, qp_s, k, d, warmup=wu, iters=it)
    m_h, m_c = p_h.geometry()["merge"], p_c.geometry()["merge"]
    report(row("fig5/sharded_hist_merge", us_h,
               f"recall=1.000;nshards={n_dev};"
               f"merge_bytes={m_h['merge_bytes']};"
               f"speedup_vs_concat={us_c/us_h:.2f}x;n_q={nq_s};"
               f"interpreted={interp};plan={p_h.compact()}"))
    report(row("fig5/sharded_concat_merge", us_c,
               f"recall=1.000;nshards={n_dev};"
               f"merge_bytes={m_c['merge_bytes']};n_q={nq_s};"
               f"interpreted={interp};plan={p_c.compact()}"))
