"""Fault-injected churn soak over the crash-safe mutable datastore
(core/mutable.py), plus a paired static-vs-churned search latency row.

The soak drives a Poisson mix of append/delete/search/flush/snapshot ops
against a ``MutableStore`` with faults armed (p per call, default 0.05) at
the three sites — ``wal_append``, ``compact_build``, ``epoch_install``.
Every fired fault is treated as a CRASH: the in-memory store is abandoned
and ``MutableStore.recover()`` rebuilds it from the last committed
snapshot + WAL tail. An acked-mutation ledger (external id -> (code,
value)) is checked against the recovered state after every crash and at
the end; the final state must also be bit-identical to a from-scratch
``build_arena`` rebuild of the same logical rows.

Standalone CLI (what CI's mutate-soak-smoke job runs):
    PYTHONPATH=src python benchmarks/bench_mutate.py \
        --ops 600 --fault-p 0.05 --json BENCH_mutate.json
Exit code is non-zero on ANY lost acknowledged mutation, phantom row,
failed audit, or bit-identity break — those are the invariants the soak
exists to pin.

Also registered in benchmarks/run.py (tag ``mutate``) with a short,
fault-free preset that reports the static-vs-churned pair.
"""
import argparse
import json
import sys
import tempfile
import time

import numpy as np


def _mk_codes(rng, n: int, d: int) -> np.ndarray:
    return rng.integers(0, 2 ** 32, size=(n, d // 32), dtype=np.uint32)


def _recover(root, inj, **kw):
    """Recovery is idempotent, so a fault DURING recovery is just another
    crash — retry. The injector stays armed so recovery itself is
    exercised under faults; after many consecutive crashes (vanishingly
    unlikely at p=0.05) fall back to a clean recovery and flag it."""
    from repro.core import mutable
    from repro.runtime import faults as faults_mod
    for _ in range(64):
        try:
            return mutable.MutableStore.recover(
                root, fault_injector=inj, **kw), True
        except faults_mod.InjectedFault:
            continue
    return mutable.MutableStore.recover(root, fault_injector=None, **kw), False


def _epoch_state(store):
    """(ids, codes, values) of the installed epoch as host arrays."""
    ep = store.epoch
    return (np.asarray(ep.store_ids), np.asarray(ep.layout.codes),
            np.asarray(ep.values))


def _reconcile(store, model, in_doubt, report):
    """After a crash-recovery: every acked mutation must be present in the
    recovered state; in-doubt ops (the single op that raised) are resolved
    to whatever the recovered truth says."""
    ids, codes, values = _epoch_state(store)
    got = {int(ids[i]): (codes[i].tobytes(), int(values[i]))
           for i in range(ids.shape[0])}
    if in_doubt is not None:
        kind, payload = in_doubt
        if kind == "append":
            for ext_id, code, val in payload:
                if ext_id in got:
                    model[ext_id] = (code, val)
                    report["in_doubt_applied"] += 1
                else:
                    report["in_doubt_dropped"] += 1
        elif kind == "delete":
            for ext_id in payload:
                if ext_id not in got and ext_id in model:
                    del model[ext_id]
                    report["in_doubt_applied"] += 1
                else:
                    report["in_doubt_dropped"] += 1
        # flush/compact/snapshot in-doubt: derived state only, no ledger
        # change either way
    for ext_id, (code, val) in model.items():
        if ext_id not in got:
            report["lost_acks"] += 1
        elif got[ext_id] != (code, val):
            report["corrupt_rows"] += 1
    for ext_id in got:
        if ext_id not in model:
            report["phantoms"] += 1
    return set(got)


def soak(*, ops: int = 600, fault_p: float = 0.05, seed: int = 0,
         d: int = 64, n0: int = 256) -> dict:
    """Run the churn soak; returns a report dict (see keys below).
    ``ok`` is True iff no acked mutation was lost, no phantom/corrupt row
    appeared, and every audit passed."""
    from repro.core import layout as layout_mod
    from repro.core import mutable
    from repro.runtime import faults as faults_mod

    rng = np.random.default_rng(seed)
    inj = faults_mod.FaultInjector(
        seed=seed + 1, p={"wal_append": fault_p, "compact_build": fault_p,
                          "epoch_install": fault_p})
    store_kw = dict(slack_frac=0.15, min_slack=2, tombstone_frac=0.1,
                    max_pending=256)
    report = {"ops": 0, "crashes": 0, "recoveries": 0, "audits": 0,
              "lost_acks": 0, "phantoms": 0, "corrupt_rows": 0,
              "in_doubt_applied": 0, "in_doubt_dropped": 0,
              "appends": 0, "deletes": 0, "searches": 0, "flushes": 0,
              "snapshots": 0, "stale_search_hits": 0,
              "clean_recovery_fallback": 0}

    with tempfile.TemporaryDirectory() as root:
        codes0 = _mk_codes(rng, n0, d)
        store = mutable.MutableStore.create(
            codes0, d, values=np.arange(n0, dtype=np.int32), root=root,
            fault_injector=inj, **store_kw)
        model = {int(i): (codes0[i].tobytes(), i) for i in range(n0)}
        # ids searchable in the CURRENT epoch = model as of the last flush
        visible = set(model)

        for _ in range(ops):
            report["ops"] += 1
            op = rng.choice(["append", "delete", "search", "flush",
                             "snapshot"], p=[0.40, 0.25, 0.17, 0.15, 0.03])
            in_doubt = None
            try:
                if op == "append":
                    n = int(rng.poisson(3)) + 1
                    codes = _mk_codes(rng, n, d)
                    vals = rng.integers(0, 1 << 20, n).astype(np.int32)
                    in_doubt = ("append", [
                        (int(store._next_id) + i, codes[i].tobytes(),
                         int(vals[i])) for i in range(n)])
                    ids = store.append(codes, values=vals)
                    for i, ext in enumerate(ids):
                        model[int(ext)] = (codes[i].tobytes(), int(vals[i]))
                    report["appends"] += n
                elif op == "delete":
                    if not model:
                        continue
                    n = min(int(rng.poisson(2)) + 1, len(model))
                    victims = sorted(int(v) for v in rng.choice(
                        np.fromiter(model, np.int64), n, replace=False))
                    in_doubt = ("delete", victims)
                    store.delete(np.asarray(victims, np.int64))
                    for v in victims:
                        del model[v]
                    report["deletes"] += n
                elif op == "search":
                    q = _mk_codes(rng, 4, d)
                    _, ext = store.search(q, k=8)
                    bad = [int(e) for e in np.asarray(ext).ravel()
                           if int(e) >= 0 and int(e) not in visible]
                    report["stale_search_hits"] += len(bad)
                    report["searches"] += 1
                elif op == "flush":
                    in_doubt = ("flush", None)
                    store.flush()
                    visible = set(model)
                    report["flushes"] += 1
                elif op == "snapshot":
                    in_doubt = ("snapshot", None)
                    store.snapshot()
                    report["snapshots"] += 1
            except faults_mod.InjectedFault:
                report["crashes"] += 1
                store.close()       # crash: abandon all in-memory state
                (store, clean) = _recover(root, inj, **store_kw)
                if not clean:
                    report["clean_recovery_fallback"] += 1
                report["recoveries"] += 1
                # recover() already ran a strict audit; run one more
                # explicitly so the report counts it
                store.audit()
                report["audits"] += 1
                visible = _reconcile(store, model, in_doubt, report)

        # final: crash once more, recover cold, verify the full ledger
        store.close()
        store, _ = _recover(root, None, **store_kw)
        store.audit()
        report["audits"] += 1
        report["recoveries"] += 1
        _reconcile(store, model, None, report)

        # bit-identity: the recovered epoch must equal a from-scratch
        # build_arena over the same logical rows with the frozen key bits
        store.compact()
        ep = store.flush()
        live = sorted(model)
        m_ids = np.asarray(live, np.int64)
        m_codes = np.stack([np.frombuffer(model[i][0], np.uint32)
                            for i in live]) if live else \
            np.zeros((0, d // 32), np.uint32)
        m_vals = np.asarray([model[i][1] for i in live], np.int32)
        ref = mutable.MutableStore(layout_mod.build_arena(
            m_codes, d, ids=m_ids, values=m_vals,
            positions=store.arena.positions,
            slack_frac=store_kw["slack_frac"],
            min_slack=store_kw["min_slack"]))
        ep_ref = ref.flush()
        report["bit_identical"] = bool(
            np.array_equal(np.asarray(ep.layout.codes),
                           np.asarray(ep_ref.layout.codes))
            and np.array_equal(np.asarray(ep.store_ids),
                               np.asarray(ep_ref.store_ids))
            and np.array_equal(np.asarray(ep.values),
                               np.asarray(ep_ref.values))
            and np.array_equal(np.asarray(ep.layout.starts),
                               np.asarray(ep_ref.layout.starts)))
        q = _mk_codes(rng, 8, d)
        d1, i1 = store.search(q, k=8)
        d2, i2 = ref.search(q, k=8)
        report["search_identical"] = bool(np.array_equal(d1, d2)
                                          and np.array_equal(i1, i2))
        report["n_live_final"] = len(model)
        report["fired"] = dict(inj.fired)
        report["fault_calls"] = dict(inj.calls)
        report["store"] = store.stats()
        store.close()

    report["ok"] = (report["lost_acks"] == 0 and report["phantoms"] == 0
                    and report["corrupt_rows"] == 0
                    and report["stale_search_hits"] == 0
                    and report["bit_identical"]
                    and report["search_identical"])
    return report


# -- paired static-vs-churned latency row (fig4-style) ----------------------

def _brute_topk(codes: np.ndarray, q: np.ndarray, k: int) -> np.ndarray:
    """Exact hamming top-k id sets via numpy popcount (ground truth)."""
    x = np.bitwise_xor(codes[None, :, :], q[:, None, :])
    dist = np.unpackbits(x.view(np.uint8), axis=-1).sum(-1)
    return np.argsort(dist, kind="stable", axis=-1)[:, :k]


def _time_search(store, q, k: int, iters: int = 5) -> float:
    store.search(q, k)                      # warm (trace/compile)
    t0 = time.perf_counter()
    for _ in range(iters):
        store.search(q, k)
    return (time.perf_counter() - t0) / iters * 1e6


def churn_pair(*, n: int = 2048, d: int = 64, churn: float = 0.2,
               k: int = 16, q_n: int = 16, seed: int = 0):
    """Two rows: search over a static arena vs the same store after
    ``churn`` fraction deletes + equal-size appends (compacted +
    flushed). Both run the identical plan over their installed epoch;
    recall vs exact hamming ground truth is reported so the latency
    comparison is at matched quality."""
    from repro.core import mutable
    rng = np.random.default_rng(seed)
    codes = _mk_codes(rng, n, d)
    q = _mk_codes(rng, q_n, d)
    store = mutable.MutableStore.create(codes, d, slack_frac=0.5)

    rows = []

    def _row(name, st):
        us = _time_search(st, q, k)
        ids_live, codes_live, _ = _epoch_state(st)
        truth = ids_live[_brute_topk(codes_live, q, k)]
        _, got = st.search(q, k)
        rec = np.mean([len(set(truth[i]) & set(int(e) for e in got[i]))
                       for i in range(q_n)]) / k
        rows.append(f"{name},{us:.1f},n_live={st.n_live};k={k};"
                    f"recall={rec:.3f};epoch_seq={st.epoch_seq}")

    _row(f"mutate_static_n{n}", store)
    n_churn = int(n * churn)
    victims = np.sort(rng.choice(n, n_churn, replace=False)).astype(np.int64)
    store.delete(victims)
    store.append(_mk_codes(rng, n_churn, d))
    store.compact()
    store.flush()
    _row(f"mutate_churn{int(churn * 100)}_n{n}", store)
    return rows


def run(report):
    """benchmarks/run.py hook — fault-free static-vs-churned pair plus a
    tiny smoke soak (must hold its invariants even here)."""
    for line in churn_pair(n=1024, d=64, churn=0.2, k=16, q_n=8):
        report(line)
    s = soak(ops=60, fault_p=0.05, seed=0, n0=128)
    assert s["ok"], f"mutate soak invariants broken: {s}"
    report(f"mutate_soak,{0.0:.1f},ops={s['ops']};crashes={s['crashes']};"
           f"lost_acks={s['lost_acks']};phantoms={s['phantoms']};"
           f"n_live={s['n_live_final']};bit_identical={s['bit_identical']}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--ops", type=int, default=600)
    ap.add_argument("--fault-p", type=float, default=0.05)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--n0", type=int, default=256)
    ap.add_argument("--d", type=int, default=64)
    ap.add_argument("--skip-pair", action="store_true",
                    help="soak only (faster CI smoke)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write BENCH_mutate.json-style output to PATH")
    args = ap.parse_args()

    rep = soak(ops=args.ops, fault_p=args.fault_p, seed=args.seed,
               d=args.d, n0=args.n0)
    pair = [] if args.skip_pair else churn_pair(d=args.d, seed=args.seed)
    print("name,us_per_call,derived")
    for line in pair:
        print(line, flush=True)
    print(f"soak: ops={rep['ops']} crashes={rep['crashes']} "
          f"recoveries={rep['recoveries']} lost_acks={rep['lost_acks']} "
          f"phantoms={rep['phantoms']} corrupt={rep['corrupt_rows']} "
          f"stale={rep['stale_search_hits']} "
          f"bit_identical={rep['bit_identical']} "
          f"search_identical={rep['search_identical']} "
          f"fired={rep['fired']}", flush=True)
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"bench": "mutate", "ops": args.ops,
                       "fault_p": args.fault_p, "seed": args.seed,
                       "soak": rep, "pair_rows": pair}, f, indent=1)
        print(f"wrote soak report to {args.json}", file=sys.stderr)
    if not rep["ok"]:
        print("MUTATE SOAK FAILED: an acked mutation was lost, a phantom/"
              "corrupt row appeared, or bit-identity broke", file=sys.stderr)
        raise SystemExit(1)
    print("soak ok: zero acked-mutation loss, all audits passed",
          file=sys.stderr)


if __name__ == "__main__":
    import os
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    main()
