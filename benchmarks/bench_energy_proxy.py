"""Paper Fig. 6 (energy efficiency): no power meter exists in this
container, so we report the DERIVED energy proxy
    E = bytes_moved * e_byte + flops * e_flop
with e_byte = 30 pJ/B (HBM access) and e_flop = 0.3 pJ (bf16 MAC @7nm class)
— labeled clearly as a proxy. The paper's qualitative claim (binary codes +
near-memory reduction give order-of-magnitude energy wins over fp32
scanning) is what the ratio tests."""
import jax.numpy as jnp

from benchmarks.util import row

E_BYTE = 30e-12
E_FLOP = 0.3e-12


def _energy(n, d, bytes_per_dim, flops_per_dim, n_q):
    byts = n * d * bytes_per_dim * n_q
    flops = n * d * flops_per_dim * n_q
    return byts * E_BYTE + flops * E_FLOP


def run(report):
    n, d, n_q = 1 << 20, 128, 1
    fp32 = _energy(n, d, 4.0, 2.0, n_q)
    mxu = _energy(n, d, 2.0, 2.0, n_q)          # bf16 +/-1 codes
    packed = _energy(n, d, 1 / 8, 2.0, n_q)     # 1 bit/dim + popcount work
    report(row("fig6/fp32_scan", 0.0, f"J_per_query={fp32:.3e};rel=1.00x"))
    report(row("fig6/hamming_mxu", 0.0,
               f"J_per_query={mxu:.3e};rel={fp32/mxu:.1f}x"))
    report(row("fig6/hamming_packed", 0.0,
               f"J_per_query={packed:.3e};rel={fp32/packed:.1f}x"))
    # hierarchical reporting: result bytes out of the device drop n/k' fold
    full_report = n * 4 * E_BYTE
    kprime_report = 16 * 8 * E_BYTE
    report(row("fig6/statistical_reduction_report", 0.0,
               f"rel={full_report/kprime_report:.0f}x_fewer_report_joules"))

    # the approx tier, from the SAME geometry the planner reports
    # (explain()["geometry"]): int8 plane bytes + MXU MAC energy. It moves
    # 8x the packed bytes but the paper-relevant ratio is vs the fp32 scan
    # it replaces in the serving ladder — and the candidate-pool traffic
    # (n_blocks*l per query instead of n) is what the partial reduce
    # deletes from the select stage.
    from repro.core import plan as plan_mod
    g = plan_mod.plan_local(
        plan_mod.StoreStats(n=n, d=d, w=d // 32, q=n_q, backend="cpu"),
        10, select="approx", recall_target=0.9).explain()["geometry"]
    approx = g["plane_bytes"] * E_BYTE + g["scores_flops"] / 2 * E_FLOP
    report(row("fig6/approx_mxu_planes", 0.0,
               f"J_per_query={approx/n_q:.3e};rel={fp32/(approx/n_q):.1f}x;"
               f"cand_per_query={g['cand_per_query']};"
               f"flops_per_byte={g['flops_per_byte']:.0f}"))
