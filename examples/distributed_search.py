"""Distributed similarity search across a (fake) multi-device mesh — the
paper's system end-to-end at cluster shape:

* datastore sharded over every mesh axis (macro-level parallelism),
* per-shard chunked scans (partial reconfiguration),
* the exact distributed counting select (k' = k: per-shard histograms
  psum into one global race — merge:hist_merge, O(Q*bins) traffic), and
* the hierarchical top-k' concat merge (statistical activation reduction)
  with the recall/bandwidth trade swept live for k' < k.

Run (sets its own fake-device flag, like the dry-run):
    PYTHONPATH=src python examples/distributed_search.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro import compat  # noqa: E402
from repro.core import binary, engine, hierarchy, plan as plan_mod  # noqa: E402


def main():
    mesh = compat.make_mesh((2, 2, 2), ("pod", "data", "model"))
    axes = ("pod", "data", "model")
    n_dev = 8
    d, n, q, k = 128, 1 << 16, 32, 16
    rng = np.random.default_rng(0)
    bits = jnp.asarray(rng.integers(0, 2, (n, d)), jnp.uint8)
    qbits = jnp.asarray(rng.integers(0, 2, (q, d)), jnp.uint8)
    codes = binary.pack_bits(bits)
    q_codes = binary.pack_bits(qbits)

    exact_d, exact_i = engine.search_chunked(codes, q_codes, k, d)
    sharded = engine.shard_datastore(codes, mesh, axes)
    print(f"datastore: {n} x {d}b codes sharded over {n_dev} devices "
          f"({codes.nbytes // n_dev} B/device)")

    print(f"{'k_prime':>8} {'recall@16':>10} {'merge bytes/q':>14} "
          f"{'reduction':>10} {'analytic fail bound':>20}  merge")
    for k_local in (16, 8, 4, 2, 1):
        stats = plan_mod.stats_for(n, d, codes.shape[1], q, n_shards=n_dev)
        p = plan_mod.plan_sharded(stats, k, axes=axes, k_local=k_local)
        with mesh:
            sd, si = jax.jit(lambda c, qq, kl=k_local: engine.search_sharded(
                c, qq, k, d, mesh, axes, k_local=kl))(sharded, q_codes)
        recall = float(jnp.mean(jnp.any(
            si[:, :, None] == exact_i[:, None, :], axis=1)))
        # the planner's predicted cross-device merge traffic: hist_merge
        # psums O(Q*bins) counts at k'=k, the concat merge gathers
        # O(n_dev*k') candidate pairs per query as k' shrinks
        payload = p.geometry()["merge"]["merge_bytes"] // q
        reduction = (n // n_dev) / k_local     # the paper's m / k'
        bound = hierarchy.failure_bound(k, n_dev, k_local)
        print(f"{k_local:>8} {recall:>10.3f} {payload:>12} B "
              f"{reduction:>9.0f}x {bound:>20.4f}  "
              f"{p.merge.strategy}")
    print("k'=k is exact (the hist_merge distributed counting select); "
          "the paper's Fig. 11 trade appears as k' shrinks.")


if __name__ == "__main__":
    main()
