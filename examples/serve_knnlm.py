"""End-to-end serving driver (the paper's kind is inference): a reduced
gemma-family model serves batched requests with kNN-LM retrieval against a
datastore built from the model's own hidden states.

    PYTHONPATH=src python examples/serve_knnlm.py
"""
import numpy as np

import jax
import jax.numpy as jnp

from repro import compat
from repro.configs import get_config, scaled_down
from repro.core import retrieval
from repro.dist import sharding
from repro.models import lm
from repro.runtime import server


def main():
    cfg = scaled_down(get_config("gemma-2b"), d_model=128, d_ff=256,
                      vocab_size=512, num_layers=4)
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    pspecs = sharding.param_specs(cfg, mesh)
    with mesh:
        params = jax.jit(lambda: lm.init_params(jax.random.PRNGKey(0), cfg),
                         out_shardings=sharding.named(mesh, pspecs))()

    # build the datastore from the model's hidden states over a corpus
    corpus = jax.random.randint(jax.random.PRNGKey(1), (16, 128), 0,
                                cfg.vocab_size)
    _, _, hidden = lm.forward(params, cfg, corpus, return_hidden=True)
    h = hidden[:, :-1].reshape(-1, cfg.d_model).astype(jnp.float32)
    next_tok = corpus[:, 1:].reshape(-1)
    store = retrieval.build_datastore(h, next_tok, cfg.retrieval.code_bits,
                                      itq_iters=8)
    store = jax.device_put(store, sharding.named(
        mesh, sharding.datastore_specs(mesh)))
    print(f"datastore: {store.codes.shape[0]} entries, "
          f"{cfg.retrieval.code_bits}-bit codes")

    # hardened server: bounded queue, per-request deadlines, and a
    # degradation ladder that downshifts retrieval under pressure
    srv = server.Server(cfg, mesh, params, max_batch=4, max_len=96,
                        store=store, max_queue=16,
                        default_deadline_ticks=200,
                        degradation=server.DegradationPolicy())
    prompts = [np.asarray(corpus[i, :8]) for i in range(6)]
    for uid, p in enumerate(prompts):
        admitted = srv.submit(server.Request(uid=uid, prompt=p,
                                             max_new_tokens=12))
        assert admitted, f"request {uid} shed at submit (queue full)"
    ticks = srv.run()
    print(f"served {len(srv.done)} requests in {ticks} decode ticks "
          f"(continuous batching over 4 slots)")
    for req in srv.done[:3]:
        print(f"  req {req.uid}: prompt {req.prompt.tolist()} -> "
              f"{req.out_tokens}")
    s = srv.stats()
    print(f"SLO: p50 token {s['p50_token_s'] * 1e3:.2f} ms, "
          f"p99 token {s['p99_token_s'] * 1e3:.2f} ms, "
          f"shed {s['shed']}, timed out {s['timed_out']}, "
          f"degraded frac {s['degraded_frac']:.2f}, lost {s['lost']}")


if __name__ == "__main__":
    main()
