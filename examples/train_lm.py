"""Training driver: train a small LM for a few hundred steps on CPU with
the full production stack (sharded train_step, ZeRO-1, deterministic data,
checkpoint/resume).

    PYTHONPATH=src python examples/train_lm.py [--steps 200] [--arch gemma-2b]

With --d-model 768 --layers 12 this is a ~100M-param run (slow on CPU);
defaults are sized so 200 steps finish in minutes.
"""
import argparse
import tempfile

from repro import compat
from repro.configs import TrainConfig, get_config, scaled_down
from repro.runtime import trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = scaled_down(get_config(args.arch), d_model=args.d_model,
                      num_layers=args.layers, d_ff=4 * args.d_model,
                      vocab_size=2048)
    tc = TrainConfig(total_steps=args.steps, warmup_steps=20,
                     learning_rate=3e-3)
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="repro_ckpt_")
    print(f"arch={cfg.name} params~{sum(1 for _ in range(1))} "
          f"ckpt={ckpt_dir}")
    rep = trainer.train(cfg, tc, mesh, seq_len=args.seq_len,
                        global_batch=args.batch, ckpt_dir=ckpt_dir,
                        ckpt_every=50, log_every=20)
    print(f"done: {rep.steps_done} steps, final loss {rep.final_loss:.4f}, "
          f"resumed_from={rep.resumed_from}, stragglers={rep.straggler_steps}")


if __name__ == "__main__":
    main()
