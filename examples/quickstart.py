"""Quickstart: build a binary-code similarity index and search it.

    PYTHONPATH=src python examples/quickstart.py

Covers the paper's full single-node pipeline: ITQ quantization (offline),
bit packing, a planner-built Hamming top-k (the QueryPlan IR of
core/plan.py decides the select path and prints its ``explain()``), and an
IVF index whose probes drive the masked fused kernels.
"""
import numpy as np

import jax
import jax.numpy as jnp

from repro.core import binary, engine, index, quantize


def main():
    rng = np.random.default_rng(0)
    n, d_feat, bits, k = 50_000, 128, 128, 10
    print(f"dataset: {n} x {d_feat} float features -> {bits}-bit ITQ codes")

    # synthetic features with low-rank structure (stands in for SIFT/embeddings)
    z = rng.normal(size=(n, 16)).astype(np.float32)
    w = rng.normal(size=(16, d_feat)).astype(np.float32)
    feats = jnp.asarray(z @ w + 0.1 * rng.normal(size=(n, d_feat)))

    # 1. offline: train ITQ, encode, pack
    itq = quantize.itq_train(feats[:10_000], bits, iters=20)
    codes = binary.pack_bits(quantize.itq_encode(feats, itq))
    print(f"packed codes: {codes.shape} uint32 "
          f"({codes.size * 4 / feats.size / 4:.3f}x the float bytes)")

    # 2. exact search through the query planner: the engine builds a
    # QueryPlan (core/plan.py) from the datastore stats and executes it —
    # explain() shows exactly what will run before any kernel launches
    queries = feats[:8]
    q_codes = binary.pack_bits(quantize.itq_encode(queries, itq))
    eng = engine.KNNEngine(codes=codes, d=bits)
    print("\nfull-scan plan:")
    print(eng.query_plan(q_codes, k, chunk=1 << 14).explain_str())
    dists, ids = eng.search(q_codes, k, chunk=1 << 14)
    print("query 0 neighbors:", ids[0].tolist())
    print("query 0 distances:", dists[0].tolist())

    # ground truth in float space for recall
    d2 = jnp.sum((queries[:, None] - feats[None]) ** 2, -1)
    exact = jnp.argsort(d2, axis=1)[:, :k]
    recall = float(jnp.mean(jnp.any(ids[:, :, None] == exact[:, None, :], 1)))
    print(f"recall@{k} vs float ground truth: {recall:.3f}")

    # 3. approximate: IVF (hierarchical k-means). The build bucket-clusters
    # the codes (core/layout.py); probed buckets become an enable mask over
    # the fused kernels' grid, so un-probed tiles are never streamed at all
    ivf = index.kmeans_build(feats, codes, bits, n_clusters=64, iters=8)
    print("\nIVF probe plan:")
    print(index.kmeans_plan(ivf, queries.shape[0], k, nprobe=4).explain_str())
    _, ivf_ids, stats = index.kmeans_search(ivf, queries, q_codes, k,
                                            nprobe=4, return_stats=True)
    recall_ivf = float(jnp.mean(jnp.any(
        jnp.asarray(ivf_ids)[:, :, None] == exact[:, None, :], 1)))
    skipped = int(stats["p1_blocks_skipped"])
    print(f"IVF nprobe=4 recall@{k}: {recall_ivf:.3f} "
          f"(masked fused scan skipped {skipped}/{stats['blocks_total']} "
          f"pass-1 blocks)")


if __name__ == "__main__":
    main()
