"""Build the EXPERIMENTS.md roofline tables from experiments/dryrun/*.json."""
import glob
import json
import os
import sys


def load(mesh_tag, tag_filter=""):
    recs = []
    for path in sorted(glob.glob("experiments/dryrun/*.json")):
        name = os.path.basename(path)[:-5]
        parts = name.split("__")
        if parts[2] != mesh_tag:
            continue
        if (len(parts) > 3) != bool(tag_filter):
            continue
        if tag_filter and parts[3] != tag_filter:
            continue
        recs.append(json.load(open(path)))
    return recs


SHAPE_ORDER = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}


def table(recs):
    rows = []
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant | "
           "GB/dev | fits | model TFLOP | useful | roofline frac |")
    sep = "|" + "---|" * 11
    rows.append(hdr)
    rows.append(sep)
    recs = sorted(recs, key=lambda r: (r["arch"], SHAPE_ORDER.get(r["shape"], 9)))
    for r in recs:
        m = r.get("memory_stats") or {}
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} | "
            f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | {r['dominant']} | "
            f"{m.get('per_device_bytes', 0)/1e9:.1f} | "
            f"{'Y' if m.get('fits_hbm') else 'N'} | "
            f"{r['model_flops']/1e12:.0f} | {r['useful_ratio']:.3f} | "
            f"{r['roofline_frac']:.4f} |")
    return "\n".join(rows)


if __name__ == "__main__":
    mesh = sys.argv[1] if len(sys.argv) > 1 else "16x16"
    tag = sys.argv[2] if len(sys.argv) > 2 else ""
    print(table(load(mesh, tag)))
