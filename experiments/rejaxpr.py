"""Patch existing dry-run records with jaxpr-level flops/io (trace only, no
recompile; collectives/residency kept from the compiled-HLO analysis).

Run: PYTHONPATH=src python experiments/rejaxpr.py
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

import glob  # noqa: E402
import json  # noqa: E402
import sys  # noqa: E402

sys.path.insert(0, "src")
from repro.configs import get_config, get_shape  # noqa: E402
from repro.launch import jaxpr_analysis, roofline  # noqa: E402
from repro.launch.dryrun import build_step  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402


def main():
    meshes = {"16x16": make_production_mesh(),
              "2x16x16": make_production_mesh(multi_pod=True)}
    n = 0
    for path in sorted(glob.glob("experiments/dryrun/*.json")):
        rec = json.load(open(path))
        mesh = meshes[rec["mesh"]]
        chips = rec["chips"]
        cfg = get_config(rec["arch"])
        shape = get_shape(rec["shape"])
        step_fn, args = build_step(
            cfg, shape, mesh,
            causal_skip=rec.get("causal_skip", False),
            zero1=rec.get("zero1", True),
            grad_compression=rec.get("grad_compression", "none"),
            attn_chunk=rec.get("attn_chunk", 1024),
            attn_p_bf16=rec.get("attn_p_bf16", False),
            microbatches=rec.get("microbatches", 1),
            opt_int8=rec.get("opt_int8", False),
            exact_retrieval=rec.get("exact_retrieval", False),
            pure_dp=rec.get("pure_dp", False),
            a2a_int8=rec.get("a2a_int8", False),
            datastore_scale=rec.get("datastore_scale", 1.0))
        with mesh:
            jstats = jaxpr_analysis.analyze_step(step_fn, args, chips)
        stats = {
            "flops": jstats["flops"],
            "io_bytes": jstats["io_bytes"],
            "coll_bytes": dict(rec.get("collective_detail") or {},
                               total=rec["collective_bytes_per_device"]),
            "coll_counts": rec.get("collective_counts"),
        }
        rep = roofline.build_report(cfg, shape, rec["mesh"], chips, stats,
                                    memory_stats=rec.get("memory_stats"),
                                    cost_flops=rec.get("cost_analysis_flops"))
        new = rep.as_dict()
        for k in ("lower_s", "compile_s", "causal_skip", "zero1",
                  "grad_compression", "attn_chunk", "attn_p_bf16",
                  "microbatches", "opt_int8", "exact_retrieval", "pure_dp",
                  "a2a_int8", "datastore_scale", "multi_pod"):
            if k in rec:
                new[k] = rec[k]
        json.dump(new, open(path, "w"), indent=1)
        n += 1
        print(f"{os.path.basename(path)[:-5]}: mem_s {rec['memory_s']:.3f} -> "
              f"{new['memory_s']:.3f}, comp_s {rec['compute_s']:.3f} -> "
              f"{new['compute_s']:.3f}")
    print(f"patched {n}")


if __name__ == "__main__":
    main()
