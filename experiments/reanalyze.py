"""Rebuild dry-run records from cached .hlo.gz (parser iterations without
recompiling). Usage: PYTHONPATH=src python experiments/reanalyze.py"""
import glob
import gzip
import json
import os
import sys

sys.path.insert(0, "src")
from repro.configs import get_config, get_shape  # noqa: E402
from repro.launch import hlo, roofline  # noqa: E402


def main():
    n = 0
    for path in sorted(glob.glob("experiments/dryrun/*.json")):
        hlo_path = path[:-5] + ".hlo.gz"
        if not os.path.exists(hlo_path):
            continue
        rec = json.load(open(path))
        with gzip.open(hlo_path, "rt") as f:
            stats = hlo.analyze(f.read())
        cfg = get_config(rec["arch"])
        shape = get_shape(rec["shape"])
        rep = roofline.build_report(cfg, shape, rec["mesh"], rec["chips"],
                                    stats, memory_stats=rec.get("memory_stats"),
                                    cost_flops=rec.get("cost_analysis_flops"))
        new = rep.as_dict()
        for k in ("lower_s", "compile_s", "causal_skip", "zero1",
                  "grad_compression", "attn_chunk", "attn_p_bf16",
                  "microbatches", "multi_pod"):
            if k in rec:
                new[k] = rec[k]
        json.dump(new, open(path, "w"), indent=1)
        n += 1
    print(f"re-analyzed {n} records")


if __name__ == "__main__":
    main()
