"""Crash-safe mutable datastore (core/mutable.py): bit-identity of a
churned store to a from-scratch rebuild, crash-at-every-fault-site
recovery with zero acked-mutation loss, torn-WAL tolerance, epoch
pinning, slack/tombstone lifecycle, audit detection, and the server's
online mutation admission."""
import dataclasses
import os

import numpy as np
import pytest

import jax

from repro.checkpoint import wal as wal_mod
from repro.core import layout as layout_mod
from repro.core import mutable
from repro.runtime import faults as faults_mod

D = 64
W = 2


def _codes(rng, n):
    return rng.integers(0, 2 ** 32, size=(n, W), dtype=np.uint32)


def _mk(rng, n=192, root=None, inj=None, **kw):
    codes = _codes(rng, n)
    st = mutable.MutableStore.create(
        codes, D, values=np.arange(n, dtype=np.int32), root=root,
        fault_injector=inj, **kw)
    return st, codes


def _logical(st):
    """(ids, codes, values) of the installed epoch as host arrays."""
    ep = st.epoch
    return (np.asarray(ep.store_ids), np.asarray(ep.layout.codes),
            np.asarray(ep.values))


def _churn(st, rng, rounds=3, app=24, dele=10):
    """Deterministic append/delete mix; returns the id->(code,value) model."""
    model = {int(i): (np.asarray(st.arena.codes[st._id_map[int(i)]]).copy(),
                      int(st.arena.values[st._id_map[int(i)]]))
             for i in st._id_map}
    for _ in range(rounds):
        c = _codes(rng, app)
        v = rng.integers(0, 1 << 20, app).astype(np.int32)
        ids = st.append(c, values=v)
        for j, ext in enumerate(ids):
            model[int(ext)] = (c[j], int(v[j]))
        victims = sorted(int(x) for x in rng.choice(
            np.fromiter(model, np.int64), dele, replace=False))
        st.delete(np.asarray(victims, np.int64))
        for x in victims:
            del model[x]
    return model


def _assert_matches_model(st, model):
    ids, codes, values = _logical(st)
    assert set(int(i) for i in ids) == set(model)
    for i in range(ids.shape[0]):
        code, val = model[int(ids[i])]
        assert np.array_equal(codes[i], code)
        assert int(values[i]) == val


# ---------------------------------------------------------------------------
# bit-identity to a from-scratch rebuild (the central invariant)
# ---------------------------------------------------------------------------

def test_bit_identity_to_rebuild_after_churn():
    rng = np.random.default_rng(0)
    st, _ = _mk(rng)
    model = _churn(st, rng)
    st.compact()
    ep = st.flush()

    live = sorted(model)
    ref = mutable.MutableStore(layout_mod.build_arena(
        np.stack([model[i][0] for i in live]), D,
        ids=np.asarray(live, np.int64),
        values=np.asarray([model[i][1] for i in live], np.int32),
        positions=st.arena.positions))
    ep_ref = ref.flush()

    # the mutated store's epoch IS the rebuild, bit for bit
    assert np.array_equal(np.asarray(ep.layout.codes),
                          np.asarray(ep_ref.layout.codes))
    assert np.array_equal(np.asarray(ep.store_ids),
                          np.asarray(ep_ref.store_ids))
    assert np.array_equal(np.asarray(ep.values), np.asarray(ep_ref.values))
    assert np.array_equal(np.asarray(ep.layout.starts),
                          np.asarray(ep_ref.layout.starts))
    # and so are its search results (dists AND ids)
    q = _codes(rng, 8)
    d1, i1 = st.search(q, k=9)
    d2, i2 = ref.search(q, k=9)
    assert np.array_equal(d1, d2) and np.array_equal(i1, i2)
    st.audit()
    ref.audit()


def test_epoch_pinning_and_flush_visibility():
    rng = np.random.default_rng(1)
    st, codes0 = _mk(rng, n=64)
    ep1 = st.epoch
    ids_new = st.append(_codes(rng, 8))
    st.delete(np.asarray([0, 1], np.int64))
    # mutations are NOT visible until flush: the installed epoch is the
    # same immutable object a reader may have pinned mid-search
    assert st.epoch is ep1
    _, ext = st.search(_codes(rng, 2), k=4)
    assert all(int(e) < 64 for e in ext.ravel() if int(e) >= 0)

    ep2 = st.flush()
    assert ep2 is not ep1 and ep2.seq == ep1.seq + 1
    assert ep2.n == 64 + 8 - 2
    assert set(int(i) for i in ids_new) <= set(int(i) for i in ep2.store_ids)
    # the pinned epoch is untouched — its checksum still verifies
    got = mutable._epoch_checksum(
        np.asarray(ep1.layout.codes), ep1.store_ids,
        np.asarray(ep1.values), np.asarray(ep1.layout.starts))
    assert got == ep1.checksum and ep1.n == 64


# ---------------------------------------------------------------------------
# slack / tombstone lifecycle
# ---------------------------------------------------------------------------

def test_slack_exhaustion_overflows_then_flush_folds():
    rng = np.random.default_rng(2)
    # zero slack: every append must defer to the compaction backlog
    st, _ = _mk(rng, n=64, slack_frac=0.0, min_slack=0, max_pending=16)
    assert st.arena.capacity == 64
    st.append(_codes(rng, 12))
    assert len(st._overflow) == 12 and st.needs_compact
    assert st.pending_mutations >= 12 and not st.backlog_full
    st.append(_codes(rng, 8))
    assert st.backlog_full          # >= max_pending: admission must shed
    ep = st.flush()                 # folds the backlog via compaction
    assert st.n_live == 84 and not st._overflow and not st.backlog_full
    assert ep.n == 84
    st.audit()


def test_tombstone_threshold_triggers_compaction():
    rng = np.random.default_rng(3)
    st, _ = _mk(rng, n=100, tombstone_frac=0.1)
    st.delete(np.arange(0, 30, dtype=np.int64))
    assert st.arena.n_tombstones == 30 and st.needs_compact
    assert st.maybe_compact() and not st.maybe_compact()
    assert st.arena.n_tombstones == 0 and st.n_live == 70
    assert st.counters["compactions"] == 1
    st.flush()
    st.audit()


# ---------------------------------------------------------------------------
# incremental flush: dirty-bucket tracking replaces the O(N) host gather
# ---------------------------------------------------------------------------

def test_incremental_flush_gathers_only_dirty_buckets():
    rng = np.random.default_rng(30)
    st, _ = _mk(rng, n=256, min_slack=8, n_buckets=16)
    nb = st.arena.n_buckets
    assert nb == 16                     # the locality claim needs buckets
    base = st.counters["bucket_gathers"]
    # a localized mutation: 2 appends + 1 delete touch at most 3 buckets
    st.append(_codes(rng, 2))
    st.delete(np.asarray([5], np.int64))
    st.flush()
    assert st.counters["incremental_flushes"] == 1
    assert st.counters["bucket_gathers"] - base <= 3 < nb
    st.audit()


def test_incremental_flush_epoch_bit_identical_to_full_gather():
    rng = np.random.default_rng(31)
    st, _ = _mk(rng, n=192)
    model = _churn(st, rng, rounds=2, app=12, dele=6)
    ep = st.flush()                     # incremental (no compaction churn)
    assert st.counters["incremental_flushes"] >= 1
    live = sorted(model)
    ref = mutable.MutableStore(layout_mod.build_arena(
        np.stack([model[i][0] for i in live]), D,
        ids=np.asarray(live, np.int64),
        values=np.asarray([model[i][1] for i in live], np.int32),
        positions=st.arena.positions))
    ep_ref = ref.flush()                # full gather of the same contents
    assert np.array_equal(np.asarray(ep.layout.codes),
                          np.asarray(ep_ref.layout.codes))
    assert np.array_equal(np.asarray(ep.store_ids),
                          np.asarray(ep_ref.store_ids))
    assert np.array_equal(np.asarray(ep.values), np.asarray(ep_ref.values))
    assert np.array_equal(np.asarray(ep.layout.starts),
                          np.asarray(ep_ref.layout.starts))
    assert ep.checksum == ep_ref.checksum
    st.audit()


def test_clean_flush_reuses_epoch_and_compaction_forces_full_gather():
    rng = np.random.default_rng(32)
    st, _ = _mk(rng, n=128)
    ep = st.flush()
    base = st.counters["bucket_gathers"]
    assert st.flush() is ep             # clean: no gather at all
    assert st.counters["bucket_gathers"] == base
    st.delete(np.asarray([3], np.int64))
    st.compact()                        # every row may move: incremental
    st.flush()                          # seeding would be unsound
    assert st.counters["bucket_gathers"] - base == st.arena.n_buckets
    st.audit()


# ---------------------------------------------------------------------------
# crash at each fault site -> recovery loses no acked mutation
# ---------------------------------------------------------------------------

def _crash_env(tmp_path, seed):
    rng = np.random.default_rng(seed)
    inj = faults_mod.FaultInjector(seed=seed, p={})
    st, codes0 = _mk(rng, n=96, root=str(tmp_path), inj=inj)
    model = {int(i): (codes0[i], i) for i in range(96)}
    return rng, inj, st, model


def test_crash_at_wal_append_mutation_never_acked(tmp_path):
    rng, inj, st, model = _crash_env(tmp_path, 10)
    inj.p["wal_append"] = 1.0
    with pytest.raises(faults_mod.InjectedFault):
        st.append(_codes(rng, 4))
    with pytest.raises(faults_mod.InjectedFault):
        st.delete(np.asarray([0], np.int64))
    st.close()
    rec = mutable.MutableStore.recover(str(tmp_path))
    # the fault fires BEFORE the record is written: nothing lost, nothing
    # phantom — recovered state is exactly the pre-crash acked state
    _assert_matches_model(rec, model)
    rec.close()


def test_crash_at_epoch_install_keeps_acked_appends(tmp_path):
    rng, inj, st, model = _crash_env(tmp_path, 11)
    c = _codes(rng, 6)
    ids = st.append(c)                    # acked + durable
    for j, ext in enumerate(ids):
        model[int(ext)] = (c[j], 0)
    ep_before = st.epoch
    inj.p["epoch_install"] = 1.0
    with pytest.raises(faults_mod.InjectedFault):
        st.flush()
    assert st.epoch is ep_before          # old epoch still serves
    st.close()
    rec = mutable.MutableStore.recover(str(tmp_path))
    _assert_matches_model(rec, model)     # the acked appends survived
    rec.close()


def test_crash_at_compact_build_keeps_acked_deletes(tmp_path):
    rng, inj, st, model = _crash_env(tmp_path, 12)
    victims = np.arange(0, 40, dtype=np.int64)
    st.delete(victims)                    # acked + durable
    for v in victims:
        del model[int(v)]
    inj.p["compact_build"] = 1.0
    with pytest.raises(faults_mod.InjectedFault):
        st.compact()
    st.audit()                            # old arena left fully intact
    st.close()
    rec = mutable.MutableStore.recover(str(tmp_path))
    _assert_matches_model(rec, model)
    rec.close()


def test_torn_wal_tail_drops_exactly_the_torn_record(tmp_path):
    rng = np.random.default_rng(13)
    st, codes0 = _mk(rng, n=48, root=str(tmp_path))
    model = {int(i): (codes0[i], i) for i in range(48)}
    c1 = _codes(rng, 4)
    for j, ext in enumerate(st.append(c1)):
        model[int(ext)] = (c1[j], 0)
    st.append(_codes(rng, 4))             # this record will be torn
    st.close()
    # tear the last record mid-payload: on a real crash the fsync never
    # returned, so the mutation was never acknowledged
    size = os.path.getsize(st.wal_path)
    with open(st.wal_path, "r+b") as f:
        f.truncate(size - 7)
    rec = mutable.MutableStore.recover(str(tmp_path))
    _assert_matches_model(rec, model)
    # strict WAL iteration still flags the torn tail as corruption
    with pytest.raises(wal_mod.WalCorrupt):
        list(wal_mod.iter_records(st.wal_path, strict=True))
    rec.close()


def test_recovery_is_idempotent(tmp_path):
    rng = np.random.default_rng(14)
    st, _ = _mk(rng, n=96, root=str(tmp_path))
    model = _churn(st, rng, rounds=2)
    st.close()
    rec1 = mutable.MutableStore.recover(str(tmp_path))
    state1 = _logical(rec1)
    rec1.close()
    rec2 = mutable.MutableStore.recover(str(tmp_path))
    state2 = _logical(rec2)
    for a, b in zip(state1, state2):
        assert np.array_equal(a, b)
    _assert_matches_model(rec2, model)
    rec2.close()


def test_snapshot_truncates_wal_and_covers_recovery(tmp_path):
    rng = np.random.default_rng(15)
    st, codes0 = _mk(rng, n=48, root=str(tmp_path))
    model = {int(i): (codes0[i], i) for i in range(48)}
    c1 = _codes(rng, 6)
    for j, ext in enumerate(st.append(c1)):
        model[int(ext)] = (c1[j], 0)
    st.snapshot()
    # everything acked so far is snapshot-covered: the WAL is empty again
    assert wal_mod.last_seq(st.wal_path) == -1
    c2 = _codes(rng, 5)                   # lands in the post-snapshot WAL
    for j, ext in enumerate(st.append(c2)):
        model[int(ext)] = (c2[j], 0)
    st.close()
    rec = mutable.MutableStore.recover(str(tmp_path))
    _assert_matches_model(rec, model)
    rec.close()


# ---------------------------------------------------------------------------
# audit detects real corruption
# ---------------------------------------------------------------------------

def test_audit_detects_duplicate_live_ids():
    rng = np.random.default_rng(16)
    st, _ = _mk(rng, n=64)
    slots = sorted(st._id_map.values())[:2]
    st.arena.ids[slots[1]] = st.arena.ids[slots[0]]   # scribble a dup
    report = st.audit(strict=False)
    assert not report["ok"]
    with pytest.raises(mutable.AuditError):
        st.audit()


def test_audit_detects_epoch_checksum_mismatch():
    rng = np.random.default_rng(17)
    st, _ = _mk(rng, n=64)
    ep = st.flush()
    st._epoch = ep._replace(checksum=ep.checksum ^ 1)
    with pytest.raises(mutable.AuditError, match="checksum"):
        st.audit()


# ---------------------------------------------------------------------------
# server integration: admission, view refresh, periodic audit
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def serve_env():
    from repro import compat
    from repro.configs import get_config, scaled_down
    from repro.core import retrieval
    from repro.models import lm
    cfg = scaled_down(get_config("gemma-2b"), d_model=64, d_ff=128,
                      vocab_size=256)
    cfg = dataclasses.replace(cfg, retrieval=dataclasses.replace(
        cfg.retrieval, datastore_size=128, code_bits=64, k=8,
        chunk_size=128))
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    ds = retrieval.synthetic_datastore(cfg)
    return cfg, mesh, params, ds


def _mstore_from(ds, **kw):
    return mutable.MutableStore.create(
        np.asarray(ds.codes), D, values=np.asarray(ds.values), itq=ds.itq,
        **kw)


def test_server_mutations_refresh_view_and_audit(serve_env):
    from repro.runtime import server as server_mod
    cfg, mesh, params, ds = serve_env
    rng = np.random.default_rng(20)
    mstore = _mstore_from(ds)
    srv = server_mod.Server(cfg, mesh, params, max_batch=2, max_len=16,
                            store=mstore, audit_every=3,
                            mutate_flush_every=2)
    assert srv.mstore is mstore
    epoch0 = srv.stats()["store_epoch"]
    assert srv.submit_append(_codes(rng, 4))
    assert srv.submit_delete(np.asarray([0, 1], np.int64))
    srv.submit(server_mod.Request(
        uid=0, prompt=np.asarray([1, 2], np.int32), max_new_tokens=4))
    for _ in range(8):
        srv.tick()
    while srv.has_work and srv.ticks < 40:
        srv.tick()
    s = srv.stats()
    assert s["mutations_applied"] == 6 and s["mutations_shed"] == 0
    # maintenance flushed the pending mutations and refreshed the view
    assert s["store_epoch"] > epoch0 and s["pending_mutations"] == 0
    assert srv.store.codes.shape[0] == mstore.n_live
    assert srv.store.key_positions is not None
    # periodic audits ran and all passed
    assert s["audits"] >= 2 and s["audit_failures"] == 0
    assert s["done"] == 1 and s["lost"] == 0


def test_server_sheds_appends_when_backlog_full(serve_env):
    from repro.runtime import server as server_mod
    cfg, mesh, params, ds = serve_env
    rng = np.random.default_rng(21)
    # zero slack + tiny backlog: appends overflow immediately and the
    # server must shed rather than grow the backlog unboundedly
    mstore = _mstore_from(ds, slack_frac=0.0, min_slack=0, max_pending=8)
    srv = server_mod.Server(cfg, mesh, params, max_batch=2, max_len=16,
                            store=mstore)
    assert srv.submit_append(_codes(rng, 8))      # fills the backlog
    assert mstore.backlog_full
    assert not srv.submit_append(_codes(rng, 4))  # shed, NOT acked
    s = srv.stats()
    assert s["mutations_applied"] == 8 and s["mutations_shed"] == 4
    srv.tick()          # maintenance compacts the backlog away
    assert not mstore.backlog_full
    assert srv.submit_append(_codes(rng, 2))      # admission reopens
