"""Data pipeline determinism, optimizer behaviour, checkpoint atomicity."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import manager as ckpt
from repro.configs import TrainConfig
from repro.data import pipeline
from repro.optim import optimizer


def test_data_deterministic_by_step():
    dc = pipeline.DataConfig(vocab_size=100, seq_len=16, global_batch=8, seed=3)
    a = pipeline.make_batch(dc, step=7)
    b = pipeline.make_batch(dc, step=7)
    c = pipeline.make_batch(dc, step=8)
    assert (a["tokens"] == b["tokens"]).all()
    assert not (a["tokens"] == c["tokens"]).all()
    assert (a["labels"][:, :-1] == a["tokens"][:, 1:]).all()


def test_data_host_sharding_partitions_global_batch():
    dc = pipeline.DataConfig(vocab_size=100, seq_len=8, global_batch=8)
    full = pipeline.make_batch(dc, 0, 0, 1)
    parts = [pipeline.make_batch(dc, 0, i, 4)["tokens"] for i in range(4)]
    assert all(p.shape == (2, 8) for p in parts)
    # disjoint slices: each host's slice is independent of host count layout
    assert len({p.tobytes() for p in parts}) == 4


def test_prefetcher_yields_in_order():
    dc = pipeline.DataConfig(vocab_size=50, seq_len=8, global_batch=4)
    pf = pipeline.Prefetcher(dc, start_step=5, depth=2)
    steps = [next(iter(pf))[0] for _ in range(3)]
    pf.stop()
    assert steps == [5, 6, 7]


def test_adamw_optimizes_quadratic():
    tc = TrainConfig(learning_rate=0.1, warmup_steps=0, total_steps=100,
                     weight_decay=0.0, grad_clip=10.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = optimizer.init(params, tc)
    for step in range(60):
        grads = {"w": 2 * params["w"]}
        params, state, m = optimizer.update(grads, state, params, tc,
                                            jnp.asarray(step))
    assert float(jnp.abs(params["w"]).max()) < 0.2


def test_int8_adam_optimizes_quadratic():
    tc = TrainConfig(learning_rate=0.1, warmup_steps=0, total_steps=100,
                     weight_decay=0.0, grad_clip=10.0, opt_int8=True)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = optimizer.init(params, tc)
    assert state.mu["w"].dtype == jnp.int8          # 4x smaller residency
    for step in range(80):
        grads = {"w": 2 * params["w"]}
        params, state, _ = optimizer.update(grads, state, params, tc,
                                            jnp.asarray(step))
    assert float(jnp.abs(params["w"]).max()) < 0.3


def test_grad_clip_bounds_norm():
    g = {"a": jnp.full((10,), 100.0)}
    clipped, norm = optimizer.clip_by_global_norm(g, 1.0)
    assert float(optimizer.global_norm(clipped)) <= 1.0 + 1e-5
    assert float(norm) > 100


def test_int8_compression_error_feedback():
    g = jnp.asarray(np.random.default_rng(0).normal(size=(256,)), jnp.float32)
    ef = jnp.zeros_like(g)
    total_deq = jnp.zeros_like(g)
    # accumulated dequantized grads + residual == accumulated true grads
    for _ in range(4):
        deq, ef = optimizer.compress_int8(g, ef)
        total_deq = total_deq + deq
    np.testing.assert_allclose(np.asarray(total_deq + ef), np.asarray(4 * g),
                               rtol=1e-5, atol=1e-5)


def test_schedule_warmup_and_decay():
    tc = TrainConfig(learning_rate=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(optimizer.schedule(tc, jnp.asarray(s))) for s in (0, 5, 10, 50, 100)]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(5e-4)
    assert lrs[2] == pytest.approx(1e-3)
    assert lrs[3] < lrs[2] and lrs[4] < lrs[3]
    assert lrs[4] >= 0.1e-3 * 0.99


def test_checkpoint_roundtrip_bf16_and_atomicity():
    tree = {"a": jnp.ones((4, 3), jnp.bfloat16) * 1.5,
            "b": {"c": jnp.arange(5, dtype=jnp.int32)},
            "s": jnp.asarray(7, jnp.int32)}
    with tempfile.TemporaryDirectory() as tmp:
        assert ckpt.latest_step(tmp) is None
        ckpt.save(tmp, 3, tree)
        ckpt.save(tmp, 6, tree)
        assert ckpt.latest_step(tmp) == 6
        got = ckpt.restore(tmp, 3, tree)
        assert got["a"].dtype == jnp.bfloat16
        np.testing.assert_array_equal(np.asarray(got["a"], np.float32),
                                      np.asarray(tree["a"], np.float32))
        np.testing.assert_array_equal(got["b"]["c"], tree["b"]["c"])
        # uncommitted dirs are invisible
        os.makedirs(os.path.join(tmp, "step_00000009"))
        assert ckpt.latest_step(tmp) == 6
        ckpt.garbage_collect(tmp, keep=1)
        assert ckpt.latest_step(tmp) == 6
        with pytest.raises(FileNotFoundError):
            ckpt.restore(tmp, 3, tree)


def test_async_checkpoint_save():
    tree = {"x": jnp.ones((128, 128))}
    with tempfile.TemporaryDirectory() as tmp:
        t = ckpt.save(tmp, 1, tree, blocking=False)
        t.join()
        assert ckpt.latest_step(tmp) == 1
