"""Checkpoint manager hardening: async-save errors must surface at the
join point, a kill mid-write must leave the previous COMMITTED step
restorable, and garbage_collect must sweep the orphaned tmp dirs crashed
saves leave behind."""
import os

import numpy as np
import pytest

from repro.checkpoint import manager as ckpt
from repro.runtime import faults as faults_mod


def _tree(x):
    return {"a": np.arange(6, dtype=np.float32) + x,
            "b": {"c": np.full((2, 3), x, np.int32)}}


def _crash():
    raise faults_mod.InjectedFault("ckpt_save")


def test_async_save_failure_reraised_on_result(tmp_path):
    root = str(tmp_path)
    ckpt.save(root, 1, _tree(1.0), blocking=True)

    handle = ckpt.save(root, 2, _tree(2.0), blocking=False, fault_hook=_crash)
    with pytest.raises(faults_mod.InjectedFault):
        handle.result()
    # join() is the alias trainer-style callers use — same re-raise
    with pytest.raises(faults_mod.InjectedFault):
        handle.join()


def test_kill_mid_write_leaves_previous_step_restorable(tmp_path):
    root = str(tmp_path)
    ckpt.save(root, 1, _tree(1.0), blocking=True)
    with pytest.raises(faults_mod.InjectedFault):
        ckpt.save(root, 2, _tree(2.0), blocking=True, fault_hook=_crash)

    # the crashed save left an orphan tmp dir and NO committed step 2
    assert os.path.isdir(os.path.join(root, "step_00000002.tmp0"))
    assert ckpt.latest_step(root) == 1
    restored = ckpt.restore(root, 1, _tree(0.0))
    assert (restored["a"] == _tree(1.0)["a"]).all()
    assert (restored["b"]["c"] == _tree(1.0)["b"]["c"]).all()
    # restore_latest lands on the surviving step too
    step, tree = ckpt.restore_latest(root, _tree(0.0))
    assert step == 1 and (tree["a"] == _tree(1.0)["a"]).all()


def test_gc_sweeps_orphan_tmp_dirs(tmp_path):
    root = str(tmp_path)
    ckpt.save(root, 1, _tree(1.0), blocking=True)
    with pytest.raises(faults_mod.InjectedFault):
        ckpt.save(root, 2, _tree(2.0), blocking=True, fault_hook=_crash)
    orphan = os.path.join(root, "step_00000002.tmp0")
    assert os.path.isdir(orphan)

    # newer than every committed step: could be an in-flight async save,
    # so the sweep must NOT touch it yet
    ckpt.garbage_collect(root, keep=3)
    assert os.path.isdir(orphan)

    # once a newer step commits, the orphan is provably stale and goes
    ckpt.save(root, 3, _tree(3.0), blocking=True)
    ckpt.garbage_collect(root, keep=3)
    assert not os.path.exists(orphan)
    assert ckpt.latest_step(root) == 3


def test_async_save_success_commits_and_result_is_clean(tmp_path):
    root = str(tmp_path)
    handle = ckpt.save(root, 5, _tree(5.0), blocking=False)
    handle.result()
    assert handle.done()
    assert ckpt.latest_step(root) == 5
    restored = ckpt.restore(root, 5, _tree(0.0))
    assert (restored["a"] == _tree(5.0)["a"]).all()


def test_restore_fault_hook_seam(tmp_path):
    root = str(tmp_path)
    ckpt.save(root, 1, _tree(1.0), blocking=True)
    with pytest.raises(faults_mod.InjectedFault):
        ckpt.restore(root, 1, _tree(0.0),
                     fault_hook=faults_mod.FaultInjector(
                         seed=0, p={"ckpt_restore": 1.0}).hook("ckpt_restore"))
    # the data itself is untouched by a failed read
    assert (ckpt.restore(root, 1, _tree(0.0))["a"] == _tree(1.0)["a"]).all()


# ---------------------------------------------------------------------------
# restore verification: a committed-but-damaged newest step must fall back
# to the previous COMMITTED step instead of crashing or returning garbage
# ---------------------------------------------------------------------------

def _truncate_leaves(root, step, nbytes=200):
    path = os.path.join(root, f"step_{step:08d}", "proc_0.npz")
    size = os.path.getsize(path)
    with open(path, "r+b") as f:
        f.truncate(max(0, size - nbytes))


def test_truncated_leaf_raises_corrupt_and_falls_back(tmp_path):
    root = str(tmp_path)
    ckpt.save(root, 1, _tree(1.0), blocking=True)
    ckpt.save(root, 2, _tree(2.0), blocking=True)
    _truncate_leaves(root, 2)           # step 2 is COMMITTED but damaged

    # direct restore of the damaged step refuses to return garbage
    with pytest.raises(ckpt.CheckpointCorrupt):
        ckpt.restore(root, 2, _tree(0.0))
    # restore_latest silently falls back to the intact previous step
    step, tree = ckpt.restore_latest(root, _tree(0.0))
    assert step == 1
    assert (tree["a"] == _tree(1.0)["a"]).all()
    assert (tree["b"]["c"] == _tree(1.0)["b"]["c"]).all()


def test_leaf_count_mismatch_detected(tmp_path):
    root = str(tmp_path)
    ckpt.save(root, 1, _tree(1.0), blocking=True)
    # a caller expecting a DIFFERENT structure must get a verification
    # error, not a silent partial unflatten
    with pytest.raises(ckpt.CheckpointCorrupt, match="leaves"):
        ckpt.restore(root, 1, {"a": np.zeros(6, np.float32)})


def test_shape_drift_detected(tmp_path):
    root = str(tmp_path)
    ckpt.save(root, 1, _tree(1.0), blocking=True)
    sdir = os.path.join(root, "step_00000001")
    import json
    meta = json.load(open(os.path.join(sdir, "meta.json")))
    meta["leaves"][0]["shape"] = [7]    # drift: meta no longer matches
    json.dump(meta, open(os.path.join(sdir, "meta.json"), "w"))
    with pytest.raises(ckpt.CheckpointCorrupt, match="leaf 0"):
        ckpt.restore(root, 1, _tree(0.0))


def test_restore_latest_arrays_fallback_and_shapes(tmp_path):
    root = str(tmp_path)
    # shape-changing state across steps (the mutable-store arena case:
    # no `like` template can exist ahead of the load)
    ckpt.save(root, 1, [np.arange(4, dtype=np.int64)], blocking=True)
    ckpt.save(root, 2, [np.arange(9, dtype=np.int64)], blocking=True)
    step, leaves = ckpt.restore_latest_arrays(root)
    assert step == 2 and len(leaves) == 1 and leaves[0].shape == (9,)

    _truncate_leaves(root, 2, nbytes=50)
    step, leaves = ckpt.restore_latest_arrays(root)
    assert step == 1 and (leaves[0] == np.arange(4)).all()

    _truncate_leaves(root, 1, nbytes=50)
    assert ckpt.restore_latest_arrays(root) == (None, None)


def test_unreadable_meta_json_falls_back(tmp_path):
    root = str(tmp_path)
    ckpt.save(root, 1, _tree(1.0), blocking=True)
    ckpt.save(root, 2, _tree(2.0), blocking=True)
    with open(os.path.join(root, "step_00000002", "meta.json"), "w") as f:
        f.write("{ not json")
    step, tree = ckpt.restore_latest(root, _tree(0.0))
    assert step == 1 and (tree["a"] == _tree(1.0)["a"]).all()
