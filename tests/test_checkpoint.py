"""Checkpoint manager hardening: async-save errors must surface at the
join point, a kill mid-write must leave the previous COMMITTED step
restorable, and garbage_collect must sweep the orphaned tmp dirs crashed
saves leave behind."""
import os

import numpy as np
import pytest

from repro.checkpoint import manager as ckpt
from repro.runtime import faults as faults_mod


def _tree(x):
    return {"a": np.arange(6, dtype=np.float32) + x,
            "b": {"c": np.full((2, 3), x, np.int32)}}


def _crash():
    raise faults_mod.InjectedFault("ckpt_save")


def test_async_save_failure_reraised_on_result(tmp_path):
    root = str(tmp_path)
    ckpt.save(root, 1, _tree(1.0), blocking=True)

    handle = ckpt.save(root, 2, _tree(2.0), blocking=False, fault_hook=_crash)
    with pytest.raises(faults_mod.InjectedFault):
        handle.result()
    # join() is the alias trainer-style callers use — same re-raise
    with pytest.raises(faults_mod.InjectedFault):
        handle.join()


def test_kill_mid_write_leaves_previous_step_restorable(tmp_path):
    root = str(tmp_path)
    ckpt.save(root, 1, _tree(1.0), blocking=True)
    with pytest.raises(faults_mod.InjectedFault):
        ckpt.save(root, 2, _tree(2.0), blocking=True, fault_hook=_crash)

    # the crashed save left an orphan tmp dir and NO committed step 2
    assert os.path.isdir(os.path.join(root, "step_00000002.tmp0"))
    assert ckpt.latest_step(root) == 1
    restored = ckpt.restore(root, 1, _tree(0.0))
    assert (restored["a"] == _tree(1.0)["a"]).all()
    assert (restored["b"]["c"] == _tree(1.0)["b"]["c"]).all()
    # restore_latest lands on the surviving step too
    step, tree = ckpt.restore_latest(root, _tree(0.0))
    assert step == 1 and (tree["a"] == _tree(1.0)["a"]).all()


def test_gc_sweeps_orphan_tmp_dirs(tmp_path):
    root = str(tmp_path)
    ckpt.save(root, 1, _tree(1.0), blocking=True)
    with pytest.raises(faults_mod.InjectedFault):
        ckpt.save(root, 2, _tree(2.0), blocking=True, fault_hook=_crash)
    orphan = os.path.join(root, "step_00000002.tmp0")
    assert os.path.isdir(orphan)

    # newer than every committed step: could be an in-flight async save,
    # so the sweep must NOT touch it yet
    ckpt.garbage_collect(root, keep=3)
    assert os.path.isdir(orphan)

    # once a newer step commits, the orphan is provably stale and goes
    ckpt.save(root, 3, _tree(3.0), blocking=True)
    ckpt.garbage_collect(root, keep=3)
    assert not os.path.exists(orphan)
    assert ckpt.latest_step(root) == 3


def test_async_save_success_commits_and_result_is_clean(tmp_path):
    root = str(tmp_path)
    handle = ckpt.save(root, 5, _tree(5.0), blocking=False)
    handle.result()
    assert handle.done()
    assert ckpt.latest_step(root) == 5
    restored = ckpt.restore(root, 5, _tree(0.0))
    assert (restored["a"] == _tree(5.0)["a"]).all()


def test_restore_fault_hook_seam(tmp_path):
    root = str(tmp_path)
    ckpt.save(root, 1, _tree(1.0), blocking=True)
    with pytest.raises(faults_mod.InjectedFault):
        ckpt.restore(root, 1, _tree(0.0),
                     fault_hook=faults_mod.FaultInjector(
                         seed=0, p={"ckpt_restore": 1.0}).hook("ckpt_restore"))
    # the data itself is untouched by a failed read
    assert (ckpt.restore(root, 1, _tree(0.0))["a"] == _tree(1.0)["a"]).all()
