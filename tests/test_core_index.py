"""Spatial indexes: recall floors vs exact scan on clustered data."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import binary, engine, index


@pytest.fixture(scope="module")
def clustered():
    rng = np.random.default_rng(0)
    centers = rng.normal(size=(8, 64)) * 5
    x = (centers[rng.integers(0, 8, 3000)] + rng.normal(size=(3000, 64))).astype(np.float32)
    bits = (x > 0).astype(np.uint8)
    codes = binary.pack_bits(jnp.asarray(bits))
    q = jnp.asarray(x[:32])
    q_codes = binary.pack_bits(jnp.asarray(bits[:32]))
    exact_d, exact_i = engine.search_chunked(codes, q_codes, 10, 64)
    return x, codes, q, q_codes, exact_i


def _recall(ids, exact):
    return float(jnp.mean(jnp.any(jnp.asarray(ids)[:, :, None] ==
                                  exact[:, None, :], axis=1)))


def test_kmeans_index_recall(clustered):
    x, codes, q, q_codes, exact = clustered
    km = index.kmeans_build(jnp.asarray(x), codes, 64, 16, iters=8)
    _, ids = index.kmeans_search(km, q, q_codes, 10, nprobe=4)
    assert _recall(ids, exact) > 0.6


def test_kmeans_masked_recall_not_below_gather(clustered):
    """The masked fused path scans probed buckets in FULL and rounds them
    outward to block boundaries — its candidate set is a superset of the
    gather path's capped buckets, so recall must not drop."""
    x, codes, q, q_codes, exact = clustered
    km = index.kmeans_build(jnp.asarray(x), codes, 64, 16, iters=8)
    _, ids_m = index.kmeans_search(km, q, q_codes, 10, nprobe=4)
    _, ids_g = index.kmeans_search(km, q, q_codes, 10, nprobe=4,
                                   use_layout=False)
    assert _recall(ids_m, exact) >= _recall(ids_g, exact) - 1e-9


def test_kmeans_reorder_false_keeps_gather_only():
    rng = np.random.default_rng(3)
    x = rng.normal(size=(500, 64)).astype(np.float32)
    codes = binary.pack_bits(jnp.asarray((x > 0).astype(np.uint8)))
    km = index.kmeans_build(jnp.asarray(x), codes, 64, 8, iters=4,
                            reorder=False)
    assert km.layout is None
    dd, ids = index.kmeans_search(km, jnp.asarray(x[:4]), codes[:4], 5,
                                  nprobe=2)
    assert dd.shape == (4, 5)


def test_kmeans_nprobe_monotone(clustered):
    """More probes -> no worse recall; probing everything recovers the exact
    *distances* (ids can differ inside Hamming tie groups)."""
    x, codes, q, q_codes, exact = clustered
    km = index.kmeans_build(jnp.asarray(x), codes, 64, 16, iters=8,
                            capacity_factor=8.0)
    recalls = []
    for nprobe in (1, 4, 16):
        dd, ids = index.kmeans_search(km, q, q_codes, 10, nprobe=nprobe)
        recalls.append(_recall(ids, exact))
    assert recalls[0] <= recalls[1] + 0.02 <= recalls[2] + 0.04
    exact_d, _ = engine.search_chunked(codes, q_codes, 10, 64)
    dd, _ = index.kmeans_search(km, q, q_codes, 10, nprobe=16)
    assert (jnp.asarray(dd) == exact_d).all()    # all buckets == exact scan


def test_lsh_index_recall(clustered):
    x, codes, q, q_codes, exact = clustered
    lsh = index.lsh_build(codes, 64, n_tables=8, bits_per_table=4)
    _, ids = index.lsh_search(lsh, q_codes, 10)
    assert _recall(ids, exact) > 0.25


def test_lsh_gather_dedup_regression(clustered):
    """Querying with datastore members: the query's own code lands in its
    bucket in EVERY table, so pre-dedup the same id could occupy several
    top-k slots and evict real neighbors. After the fix, no id repeats
    among the valid results of the gather path (or any path)."""
    x, codes, q, q_codes, exact = clustered
    lsh = index.lsh_build(codes, 64, n_tables=8, bits_per_table=4)
    for use_layout in (False, True):
        dd, ids = index.lsh_search(lsh, q_codes, 10, use_layout=use_layout)
        ids = np.asarray(ids)
        for r in range(ids.shape[0]):
            valid = ids[r][ids[r] >= 0]
            assert len(valid) == len(set(valid.tolist())), \
                f"duplicate ids in row {r} (use_layout={use_layout})"
        # self-query: each query is datastore row r, distance 0 -> slot 0
        assert (np.asarray(dd)[:, 0] == 0).all()


def test_dedup_candidates_keeps_first_occurrence():
    cand = jnp.asarray([[7, 3, 7, -1, 3, 9], [1, 1, 1, 2, -1, -1]], jnp.int32)
    out = np.asarray(index._dedup_candidates(cand))
    assert (out == np.array([[7, 3, -1, -1, -1, 9],
                             [1, -1, -1, 2, -1, -1]])).all()


def test_lsh_masked_matches_gather_distance_quality(clustered):
    """Masked LSH candidates are a superset of the (deduped) gather
    candidates: per-slot distances can only improve (ascending lists,
    element-wise <=)."""
    x, codes, q, q_codes, exact = clustered
    lsh = index.lsh_build(codes, 64, n_tables=4, bits_per_table=5)
    md, _ = index.lsh_search(lsh, q_codes, 10)
    gd, _ = index.lsh_search(lsh, q_codes, 10, use_layout=False)
    assert (jnp.asarray(md) <= jnp.asarray(gd)).all()


def test_kdtree_index_recall(clustered):
    x, codes, q, q_codes, exact = clustered
    kt = index.KDTreeIndex(x, codes, 64, n_trees=4, leaf_size=256)
    _, ids = kt.search(np.asarray(q), q_codes, 10)
    assert _recall(ids, exact) > 0.5
