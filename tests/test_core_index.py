"""Spatial indexes: recall floors vs exact scan on clustered data."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import binary, engine, index


@pytest.fixture(scope="module")
def clustered():
    rng = np.random.default_rng(0)
    centers = rng.normal(size=(8, 64)) * 5
    x = (centers[rng.integers(0, 8, 3000)] + rng.normal(size=(3000, 64))).astype(np.float32)
    bits = (x > 0).astype(np.uint8)
    codes = binary.pack_bits(jnp.asarray(bits))
    q = jnp.asarray(x[:32])
    q_codes = binary.pack_bits(jnp.asarray(bits[:32]))
    exact_d, exact_i = engine.search_chunked(codes, q_codes, 10, 64)
    return x, codes, q, q_codes, exact_i


def _recall(ids, exact):
    return float(jnp.mean(jnp.any(jnp.asarray(ids)[:, :, None] ==
                                  exact[:, None, :], axis=1)))


def test_kmeans_index_recall(clustered):
    x, codes, q, q_codes, exact = clustered
    km = index.kmeans_build(jnp.asarray(x), codes, 64, 16, iters=8)
    _, ids = index.kmeans_search(km, q, q_codes, 10, nprobe=4)
    assert _recall(ids, exact) > 0.6


def test_kmeans_nprobe_monotone(clustered):
    """More probes -> no worse recall; probing everything recovers the exact
    *distances* (ids can differ inside Hamming tie groups)."""
    x, codes, q, q_codes, exact = clustered
    km = index.kmeans_build(jnp.asarray(x), codes, 64, 16, iters=8,
                            capacity_factor=8.0)
    recalls = []
    for nprobe in (1, 4, 16):
        dd, ids = index.kmeans_search(km, q, q_codes, 10, nprobe=nprobe)
        recalls.append(_recall(ids, exact))
    assert recalls[0] <= recalls[1] + 0.02 <= recalls[2] + 0.04
    exact_d, _ = engine.search_chunked(codes, q_codes, 10, 64)
    dd, _ = index.kmeans_search(km, q, q_codes, 10, nprobe=16)
    assert (jnp.asarray(dd) == exact_d).all()    # all buckets == exact scan


def test_lsh_index_recall(clustered):
    x, codes, q, q_codes, exact = clustered
    lsh = index.lsh_build(codes, 64, n_tables=8, bits_per_table=4)
    _, ids = index.lsh_search(lsh, q_codes, 10)
    assert _recall(ids, exact) > 0.25


def test_kdtree_index_recall(clustered):
    x, codes, q, q_codes, exact = clustered
    kt = index.KDTreeIndex(x, codes, 64, n_trees=4, leaf_size=256)
    _, ids = kt.search(np.asarray(q), q_codes, 10)
    assert _recall(ids, exact) > 0.5
