"""retry_call backoff contract: full-jitter draws stay inside the capped
exponential envelope, the legacy deterministic mode still doubles (now
capped), and the max-delay cap actually binds. Sleeps are captured, never
slept."""
import numpy as np
import pytest

from repro.runtime import faults as faults_mod


def _failing(n_failures):
    state = {"calls": 0}

    def fn():
        state["calls"] += 1
        if state["calls"] <= n_failures:
            raise faults_mod.InjectedFault("unit")
        return "ok"

    return fn, state


def test_full_jitter_delays_stay_inside_capped_envelope():
    base, cap, retries = 1e-3, 0.05, 12
    slept = []
    out = faults_mod.retry_call(
        _failing(retries)[0], retries=retries, backoff_s=base,
        max_backoff_s=cap, sleep=slept.append, rng=0)
    assert out == "ok" and len(slept) == retries
    for i, s in enumerate(slept):
        hi = min(cap, base * 2 ** i)
        assert 0.0 <= s <= hi, (i, s, hi)
    # the envelope is genuinely random, not the deterministic ladder
    ladder = [min(cap, base * 2 ** i) for i in range(retries)]
    assert slept != ladder
    # late attempts are capped strictly below the uncapped exponential
    assert max(slept) <= cap < base * 2 ** (retries - 1)


def test_full_jitter_is_seeded_and_reproducible():
    kw = dict(retries=5, backoff_s=1e-3, max_backoff_s=0.05)
    runs = []
    for _ in range(2):
        slept = []
        faults_mod.retry_call(_failing(5)[0], sleep=slept.append, rng=7,
                              **kw)
        runs.append(slept)
    assert runs[0] == runs[1]
    # a Generator works as the rng too
    slept = []
    faults_mod.retry_call(_failing(5)[0], sleep=slept.append,
                          rng=np.random.default_rng(7), **kw)
    assert slept == runs[0]


def test_jitter_none_keeps_legacy_doubling_with_cap():
    base, cap, retries = 1e-3, 4e-3, 5
    slept = []
    faults_mod.retry_call(_failing(retries)[0], retries=retries,
                          backoff_s=base, max_backoff_s=cap,
                          sleep=slept.append, jitter="none")
    # deterministic doubling, clamped at the cap from the first hit on
    assert slept == [1e-3, 2e-3, 4e-3, 4e-3, 4e-3]


def test_cap_binds_even_when_base_exceeds_it():
    slept = []
    faults_mod.retry_call(_failing(3)[0], retries=3, backoff_s=1.0,
                          max_backoff_s=2e-3, sleep=slept.append,
                          jitter="none")
    assert slept == [2e-3, 2e-3, 2e-3]


def test_last_error_reraises_after_exhaustion():
    fn, state = _failing(10)
    slept = []
    with pytest.raises(faults_mod.InjectedFault):
        faults_mod.retry_call(fn, retries=2, backoff_s=1e-4,
                              sleep=slept.append, rng=0)
    assert state["calls"] == 3 and len(slept) == 2


def test_invalid_jitter_mode_rejected():
    with pytest.raises(AssertionError):
        faults_mod.retry_call(lambda: "ok", jitter="half")


# ---------------------------------------------------------------------------
# deadline budget: the retry envelope can never outlive the request
# ---------------------------------------------------------------------------

class _FakeClock:
    """Injectable monotonic clock: sleeps advance it, so the deadline
    accounting is exact and the test never really waits."""

    def __init__(self):
        self.t = 100.0

    def __call__(self):
        return self.t

    def sleep(self, s):
        self.t += s


def test_deadline_clamps_every_sleep_to_remaining_budget():
    clk = _FakeClock()
    slept = []

    def sleep(s):
        slept.append(s)
        clk.sleep(s)

    out = faults_mod.retry_call(_failing(3)[0], retries=4, backoff_s=0.04,
                                max_backoff_s=0.04, jitter="none",
                                sleep=sleep, deadline_s=0.1, clock=clk)
    # the schedule wants 0.04 each time, but the budget has only 0.02 left
    # by the third sleep: it is clamped to exactly what remains
    assert out == "ok"
    assert slept[:2] == [0.04, 0.04]
    assert len(slept) == 3 and abs(slept[2] - 0.02) < 1e-9
    assert sum(slept) <= 0.1 + 1e-12


def test_deadline_exhaustion_reraises_instead_of_sleeping():
    clk = _FakeClock()
    slept = []

    def sleep(s):
        slept.append(s)
        clk.sleep(s)

    fn, state = _failing(10)
    with pytest.raises(faults_mod.InjectedFault):
        faults_mod.retry_call(fn, retries=10, backoff_s=0.05,
                              max_backoff_s=0.05, jitter="none",
                              sleep=sleep, deadline_s=0.12, clock=clk)
    # 0.05 + 0.05 spends the budget; the next transient error re-raises
    # immediately — the envelope ends BEFORE the retries run out
    assert state["calls"] < 11
    assert sum(slept) <= 0.12 + 1e-12


def test_deadline_none_keeps_unbounded_envelope():
    slept = []
    faults_mod.retry_call(_failing(3)[0], retries=3, backoff_s=1e-3,
                          max_backoff_s=1e-3, jitter="none",
                          sleep=slept.append, deadline_s=None)
    assert slept == [1e-3, 1e-3, 1e-3]


def test_deadline_composes_with_full_jitter():
    clk = _FakeClock()
    slept = []

    def sleep(s):
        slept.append(s)
        clk.sleep(s)

    faults_mod.retry_call(_failing(5)[0], retries=5, backoff_s=0.02,
                          max_backoff_s=0.08, sleep=sleep, rng=0,
                          deadline_s=0.05, clock=clk)
    assert sum(slept) <= 0.05 + 1e-12
