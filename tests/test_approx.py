"""Approximate peak-FLOP/s tier (kernels/approx_select.py + the measured
autotune cache in kernels/tuning.py).

Pins: (a) the MXU bit-plane scoring is EXACT (matmul Hamming == popcount
Hamming); (b) at recall_target=1.0 the partial-reduce select is
bit-identical to the fused/counting contract (dists AND ids, n_valid and
block-mask edges included); (c) at recall_target<1 the measured recall
meets the analytical bound's target on seeded data; (d) the sharded
candidate-pool hist merge matches ops.hamming_topk_sharded at rt=1.0;
(e) the autotune cache is deterministic under tests — seeded defaults with
an empty cache, measured-beats-default with a fake timer, never a
wall-clock assertion.
"""
import json
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import binary, layout as layout_mod, plan, quantize, topk
from repro.kernels import approx_select as ax, ops, tuning


@pytest.fixture(autouse=True)
def _fresh_cache():
    """Every test sees an empty in-memory autotune cache (seeded defaults)
    and leaves no state behind."""
    tuning.configure("")
    yield
    tuning.configure("")


def _codes(seed, n, q, d):
    rng = np.random.default_rng(seed)
    xb = jnp.asarray(rng.integers(0, 2, (n, d)), jnp.uint8)
    qb = jnp.asarray(rng.integers(0, 2, (q, d)), jnp.uint8)
    return binary.pack_bits(xb), binary.pack_bits(qb), xb, qb


# ---------------------------------------------------------------------------
# MXU scoring: bit planes
# ---------------------------------------------------------------------------

def test_plane_scores_equal_popcount_hamming():
    xp, qp, xb, qb = _codes(0, 300, 9, 96)
    got = ax.hamming_scores_planes(ax.bit_planes(qp, 96),
                                   ax.bit_planes(xp, 96), 96)
    ref = binary.hamming_ref(qb, xb)
    assert got.dtype == jnp.int32
    assert (np.asarray(got) == np.asarray(ref)).all()


def test_recall_bound_math():
    # L = k -> certain recall; L = 0 -> none; monotone in L and in blocks
    assert ax.expected_recall(10, 8, 10) == 1.0
    assert ax.expected_recall(10, 8, 0) == 0.0
    rs = [ax.expected_recall(16, 16, l) for l in range(1, 8)]
    assert all(a < b for a, b in zip(rs, rs[1:]))
    assert ax.expected_recall(16, 32, 2) > ax.expected_recall(16, 4, 2)
    # one block holds everything: recall = min(l, k)/k
    assert ax.expected_recall(10, 1, 4) == pytest.approx(0.4)
    # the inverse: smallest L meeting the target, full block at rt=1
    l = ax.l_for_recall(16, 16, 64, 0.9)
    assert ax.expected_recall(16, 16, l) >= 0.9
    assert l == 1 or ax.expected_recall(16, 16, l - 1) < 0.9
    assert ax.l_for_recall(16, 16, 64, 1.0) == 64


# ---------------------------------------------------------------------------
# the partial-reduce select: exactness edges
# ---------------------------------------------------------------------------

def test_bit_identity_to_fused_at_full_recall():
    n, q, d, k = 700, 7, 64, 11
    xp, qp, _, _ = _codes(1, n, q, d)
    rd, ri = ops.hamming_topk(qp, xp, k, d + 1)
    for bn in (64, 96, 512, 1024):      # incl. bn > N and N % bn != 0
        dd, ii = ax.approx_topk(qp, xp, k, d + 1, recall_target=1.0, bn=bn)
        assert (np.asarray(dd) == np.asarray(rd)).all(), bn
        assert (np.asarray(ii) == np.asarray(ri)).all(), bn


def test_n_valid_and_k_gt_n_edges():
    n, q, d, k = 256, 5, 64, 12
    xp, qp, _, _ = _codes(2, n, q, d)
    for nv in (3, 17, n):               # k > n_valid included
        rd, ri = ops.hamming_topk(qp, xp, k, d + 1, n_valid=nv)
        dd, ii = ax.approx_topk(qp, xp, k, d + 1, recall_target=1.0,
                                bn=64, n_valid=nv)
        assert (np.asarray(dd) == np.asarray(rd)).all(), nv
        assert (np.asarray(ii) == np.asarray(ri)).all(), nv
    # k > N entirely: all-sentinel tail, never an exception
    dd, ii = ax.approx_topk(qp, xp[:4], 9, d + 1, recall_target=1.0)
    assert (np.asarray(dd[:, 4:]) == d + 1).all()
    assert (np.asarray(ii[:, 4:]) == 4).all()


def test_block_mask_and_all_masked_edges():
    n, q, d, k, bn = 320, 6, 64, 8, 64
    xp, qp, xb, qb = _codes(3, n, q, d)
    nb = -(-n // bn)
    rng = np.random.default_rng(7)
    bm = jnp.asarray(rng.integers(0, 2, (q, nb)), jnp.int32)
    dd, ii = ax.approx_topk(qp, xp, k, d + 1, recall_target=1.0, bn=bn,
                            block_mask=bm)
    # reference: distances of disabled rows forced past the clamp
    dist = binary.hamming_ref(qb, xb)
    rowmask = np.repeat(np.asarray(bm), bn, axis=1)[:, :n]
    dm = jnp.asarray(np.where(rowmask > 0, np.asarray(dist), d + 1))
    rd, ri = topk.composite_topk(dm, k, d + 1)
    ri = jnp.where(rd <= d, ri, n)
    assert (np.asarray(dd) == np.asarray(rd)).all()
    assert (np.asarray(ii) == np.asarray(ri)).all()
    # every block masked for every query: pure sentinels
    dd0, ii0 = ax.approx_topk(qp, xp, k, d + 1, recall_target=1.0, bn=bn,
                              block_mask=jnp.zeros((q, nb), jnp.int32))
    assert (np.asarray(dd0) == d + 1).all() and (np.asarray(ii0) == n).all()


def test_recall_meets_target_on_seeded_data():
    """The analytical bound sizes L; measured DISTANCE recall (an approx
    hit counts when its distance is within the exact k-th distance — tie
    robust) must meet the target on every seeded draw."""
    n, q, d, k, bn = 2048, 16, 64, 10, 128
    for target in (0.9, 0.99):
        recalls = []
        for seed in range(5):
            xp, qp, _, _ = _codes(seed, n, q, d)
            rd, _ = ops.hamming_topk(qp, xp, k, d + 1)
            dd, _ = ax.approx_topk(qp, xp, k, d + 1, recall_target=target,
                                   bn=bn)
            kth = np.asarray(rd)[:, k - 1:k]
            recalls.append(float((np.asarray(dd) <= kth).mean()))
        assert min(recalls) >= target - 0.02, (target, recalls)
        assert float(np.mean(recalls)) >= target, (target, recalls)


def test_masked_approx_matches_masked_reference():
    """Index-probed approx at rt=1.0 == a composite select over exactly
    the rows the per-query block mask enables (original-id mapping and -1
    sentinels included)."""
    n, q, d, k, bn = 512, 5, 64, 9, 64
    xp, qp, _, _ = _codes(4, n, q, d)
    lay = layout_mod.build_layout(xp, d, n_buckets=8)
    rng = np.random.default_rng(11)
    probe = jnp.asarray(rng.integers(0, 8, (q, 2)), jnp.int32)
    dd, ii = ax.masked_approx_topk(lay, qp, k, d, probe=probe,
                                   recall_target=1.0, bn=bn)
    nb = -(-n // bn)
    mask = layout_mod.probe_block_mask(lay, probe, 1, bn, q, nb)
    dist = np.asarray(binary.hamming_xor(qp, lay.codes))
    rowmask = np.repeat(np.asarray(mask), bn, axis=1)[:, :n]
    dm = jnp.asarray(np.where(rowmask > 0, dist, d + 1))
    rd, rpos = topk.composite_topk(dm, k, d + 1)
    rids = layout_mod.original_ids(lay, jnp.minimum(rd, d + 1),
                                   jnp.where(rd <= d, rpos, n), d)
    assert (np.asarray(dd) == np.asarray(jnp.minimum(rd, d + 1))).all()
    assert (np.asarray(ii) == np.asarray(rids)).all()


def test_asymmetric_scores_exact_and_topk():
    """The float-query/int8-datastore path: scores equal the dense float
    product against ±1 planes; at rt=1.0 the select equals exact top-k."""
    n, q, d, k = 400, 6, 64, 7
    xp, _, _, _ = _codes(5, n, q, d)
    rng = np.random.default_rng(5)
    v = jnp.asarray(rng.normal(size=(q, d)), jnp.float32)
    planes = ax.bit_planes(xp, d)
    full = np.asarray(v) @ np.asarray(planes, np.float32).T
    got = ax.asymmetric_scores(v, planes)
    assert np.allclose(np.asarray(got), full, atol=1e-4)
    sv, si = ax.asymmetric_topk(v, xp, k, d, recall_target=1.0, bn=128)
    rv, _ = jax.lax.top_k(jnp.asarray(full), k)
    assert np.allclose(np.asarray(sv), np.asarray(rv), atol=1e-4)
    # itq_project is the continuous pre-sign value itq_encode thresholds
    p = quantize.ITQParams(mean=jnp.zeros((d,), jnp.float32),
                           proj=jnp.eye(d, d, dtype=jnp.float32),
                           rot=jnp.eye(d, dtype=jnp.float32))
    h = jnp.asarray(rng.normal(size=(3, d)), jnp.float32)
    assert (np.asarray(quantize.itq_encode(h, p))
            == (np.asarray(quantize.itq_project(h, p)) > 0)).all()


# ---------------------------------------------------------------------------
# planner integration
# ---------------------------------------------------------------------------

def test_plan_executes_approx_identically_at_full_recall():
    n, q, d, k = 900, 6, 64, 8
    xp, qp, _, _ = _codes(6, n, q, d)
    stats = plan.stats_of(xp, qp, d)
    pa = plan.plan_local(stats, k, select="approx")
    pf = plan.plan_local(stats, k, select="fused")
    ad, ai = plan.execute(pa, qp, codes=xp)
    fd, fi = plan.execute(pf, qp, codes=xp)
    assert (np.asarray(ad) == np.asarray(fd)).all()
    assert (np.asarray(ai) == np.asarray(fi)).all()
    assert pa.compact() == "probe:none|cand:full|select:approx@r1|merge:none"


def test_plan_explain_reports_recall_and_flops():
    stats = plan.StoreStats(n=1 << 16, d=128, w=4, q=64, backend="cpu")
    p = plan.plan_local(stats, 16, select="approx", recall_target=0.9)
    g = p.explain()["geometry"]
    assert g["kind"] == "approx"
    assert g["recall_target"] == 0.9
    assert g["predicted_recall"] >= 0.9
    assert g["cand_per_query"] == g["n_blocks"] * g["l_per_block"]
    assert g["scores_flops"] == 2 * 64 * (1 << 16) * 128
    assert g["flops_per_byte"] > 1
    assert g["hint_source"] == "default"
    assert "@r0.9" in p.compact()
    # rt=1.0 predicts exactly 1 and keeps the full block
    p1 = plan.plan_local(stats, 16, select="approx")
    g1 = p1.explain()["geometry"]
    assert g1["predicted_recall"] == 1.0 and g1["l_per_block"] == g1["bn"]


def test_force_keys_and_invariants():
    stats = plan.StoreStats(n=1 << 14, d=64, w=2, q=32, backend="cpu")
    p = plan.plan_local(stats, 8, force="select=approx,recall_target=0.85")
    assert p.select.path == "approx"
    assert p.select.recall_target == 0.85
    # recall_target on an exact select is recorded as ignored, not applied
    p2 = plan.plan_local(stats, 8, select="fused", force="recall_target=0.5")
    assert p2.select.recall_target == 1.0 and "ignored" in p2.reason
    with pytest.raises(ValueError):
        plan.plan_local(stats, 8, force="recall_target=1.5")
    # sharded: approx rides hist_merge (pool histograms still psum)
    sst = dataclasses_replace(stats, n_shards=8)
    ps = plan.plan_sharded(sst, 8, axes=("data",), select="approx",
                           recall_target=0.95)
    assert ps.merge.strategy == "hist_merge"
    # forcing a materializing select off an approx plan demotes the merge
    pd = plan.plan_sharded(sst, 8, axes=("data",), select="approx",
                           force="select=counting")
    assert pd.merge.strategy == "concat_sort"
    # block_mask plans accept a forced approx select (the mask feeds the
    # partial reduce), unlike other non-fused selects
    lay_stats = dataclasses_replace(stats, has_layout=True,
                                    mean_bucket_rows=128, n_buckets=64,
                                    index="kmeans")
    pm = plan.plan_index(lay_stats, 8, kind="kmeans", nprobe=2,
                         force="select=approx,recall_target=0.9")
    assert pm.select.path == "approx"
    assert pm.candidates.kind == "block_mask"
    assert pm.select.recall_target == 0.9


def dataclasses_replace(stats, **kw):
    import dataclasses
    return dataclasses.replace(stats, **kw)


def test_plan_index_approx_masked_execution():
    n, q, d, k = 512, 4, 64, 8
    xp, qp, _, _ = _codes(7, n, q, d)
    lay = layout_mod.build_layout(xp, d, n_buckets=8)
    stats = plan.stats_of(xp, qp, d, layout=lay)
    p = plan.plan_index(stats, k, kind="kmeans", nprobe=8, select="approx")
    pf = plan.plan_index(stats, k, kind="kmeans", nprobe=8)
    probe = jnp.broadcast_to(jnp.arange(8, dtype=jnp.int32), (q, 8))
    dd, ii = plan.execute(p, qp, layout=lay, probe=probe)
    # probing EVERY bucket at rt=1.0 == the exact masked fused plan
    # (ties break by layout position on both, per the masked contract)
    rd, ri = plan.execute(pf, qp, layout=lay, probe=probe)
    assert (np.asarray(dd) == np.asarray(rd)).all()
    assert (np.asarray(ii) == np.asarray(ri)).all()
    # and distance-identical to the exact full scan
    ed, _ = ops.hamming_topk(qp, xp, k, d + 1)
    assert (np.asarray(dd) == np.asarray(ed)).all()


def test_sharded_approx(multidevice):
    """approx_topk_sharded under shard_map: rt=1.0 bit-identical to the
    exact hist_merge (even and uneven shards); rt<1 meets the distance
    recall target; the planner path (engine-level execute) agrees."""
    multidevice("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from repro.compat import shard_map
from repro.core import binary, plan
from repro.kernels import approx_select as ax, ops

rng = np.random.default_rng(0)
d, Q, N, k = 64, 6, 1024, 9
xb = jnp.asarray(rng.integers(0, 2, (N, d)), jnp.uint8)
qb = jnp.asarray(rng.integers(0, 2, (Q, d)), jnp.uint8)
xp, qp = binary.pack_bits(xb), binary.pack_bits(qb)
mesh = Mesh(np.array(jax.devices()).reshape(4), ("data",))
xs = xp.reshape(4, N // 4, -1)

def run(fn, *extra):
    sp = (P(), P("data")) + (P("data"),) * len(extra)
    f = shard_map(fn, mesh=mesh, in_specs=sp, out_specs=(P(), P()))
    return f(qp, xs, *extra)

ref = run(lambda q, x: ops.hamming_topk_sharded(q, x[0], k, d + 1,
                                                ("data",), n_shards=4))
got = run(lambda q, x: ax.approx_topk_sharded(q, x[0], k, d + 1, ("data",),
                                              n_shards=4, recall_target=1.0,
                                              bn=64))
assert (np.asarray(ref[0]) == np.asarray(got[0])).all()
assert (np.asarray(ref[1]) == np.asarray(got[1])).all()

nv = jnp.asarray([256, 200, 256, 100], jnp.int32).reshape(4, 1)
refu = run(lambda q, x, v: ops.hamming_topk_sharded(
    q, x[0], k, d + 1, ("data",), n_shards=4, n_valid=v[0]), nv)
gotu = run(lambda q, x, v: ax.approx_topk_sharded(
    q, x[0], k, d + 1, ("data",), n_shards=4, recall_target=1.0,
    n_valid=v[0], bn=64), nv)
assert (np.asarray(refu[0]) == np.asarray(gotu[0])).all()
assert (np.asarray(refu[1]) == np.asarray(gotu[1])).all()

lo = run(lambda q, x: ax.approx_topk_sharded(q, x[0], k, d + 1, ("data",),
                                             n_shards=4, recall_target=0.9,
                                             bn=64))
kth = np.asarray(ref[0])[:, k - 1:k]
rec = float((np.asarray(lo[0]) <= kth).mean())
assert rec >= 0.9, rec

# the planner-built sharded approx plan executes through the same kernel
stats = plan.StoreStats(n=N, d=d, w=xp.shape[1], q=Q, n_shards=4)
pa = plan.plan_sharded(stats, k, axes=("data",), select="approx")
assert pa.merge.strategy == "hist_merge"
pd, pi = plan.execute(pa, qp, codes=xp, mesh=mesh)
assert (np.asarray(pd) == np.asarray(ref[0])).all()
assert (np.asarray(pi) == np.asarray(ref[1])).all()
print("OK")
""", n_devices=4)


# ---------------------------------------------------------------------------
# the measured autotune cache
# ---------------------------------------------------------------------------

def test_seeded_defaults_without_cache_are_deterministic():
    a = tuning.topk_blocks(64, 1 << 16, 4, 129, backend="cpu")
    b = tuning.topk_blocks(64, 1 << 16, 4, 129, backend="cpu")
    assert a == b == tuning._topk_blocks_default(64, 1 << 16, 4, 129, "cpu")
    assert tuning.approx_blocks(64, 1 << 16, 4, backend="cpu") \
        == tuning.approx_blocks(64, 1 << 16, 4, backend="cpu")
    assert tuning.hint_source("cpu", "topk", 64, 1 << 16, 4, 129) == "default"


def test_measured_entry_overrides_default_and_reports_source():
    cache = tuning.autotune_cache()
    cache.put("cpu", "topk", 64, 1 << 16, 4, 129,
              {"bq": 16, "bn": 1024, "sub": 64, "us": 12.0})
    assert tuning.topk_blocks(64, 1 << 16, 4, 129, backend="cpu") \
        == (16, 1024, 64)
    assert tuning.hint_source("cpu", "topk", 64, 1 << 16, 4, 129) \
        == "measured"
    # geometry bucketing: any shape in the same pow2 bucket hits the entry
    assert tuning.topk_blocks(40, (1 << 16) - 5, 4, 129, backend="cpu") \
        == (16, 1024, 64)
    # the exact-tier cost hints carry the source (the cost-hint seam)
    h = tuning.cost_hints(64, 1 << 16, 4, 129, path="fused", backend="cpu")
    assert h["hint_source"] == "measured"
    # approx kind is keyed independently
    assert tuning.hint_source("cpu", "approx", 64, 1 << 16, 4, 1) \
        == "default"
    cache.put("cpu", "approx", 64, 1 << 16, 4, 1, {"bn": 999, "us": 5.0})
    assert tuning.approx_blocks(64, 1 << 16, 4, backend="cpu") == 1024
    assert tuning.hint_source("cpu", "approx", 64, 1 << 16, 4, 1) \
        == "measured"


def test_insane_cached_entries_fall_back_to_defaults():
    cache = tuning.autotune_cache()
    default = tuning.topk_blocks(8, 4096, 2, 65, backend="cpu")
    for bad in ({"bq": 0, "bn": 64, "sub": 8}, {"bq": "x"}, {}):
        cache.clear()
        cache.put("cpu", "topk", 8, 4096, 2, 65, bad)
        assert tuning.topk_blocks(8, 4096, 2, 65, backend="cpu") == default
        assert tuning.hint_source("cpu", "topk", 8, 4096, 2, 65) == "default"
    # off-grid but positive shapes are sanitized, not rejected
    cache.clear()
    cache.put("cpu", "topk", 8, 4096, 2, 65, {"bq": 9, "bn": 100, "sub": 9})
    bq, bn, sub = tuning.topk_blocks(8, 4096, 2, 65, backend="cpu")
    assert bq % 8 == 0 and sub % 8 == 0 and bn % sub == 0


def test_measure_with_fake_timer_and_disk_roundtrip(tmp_path):
    path = os.fspath(tmp_path / "autotune.json")
    tuning.configure(path)
    calls = []
    # fake clock: candidate bn=512 is "fast", everything else "slow" —
    # fully deterministic, no wall-time in any assertion
    t = [0.0]

    def fake_timer():
        return t[0]

    def runner(cand):
        calls.append(dict(cand))
        t[0] += 1e-6 if cand["bn"] == 512 else 1e-3

    cands = [{"bq": 16, "bn": 256, "sub": 64},
             {"bq": 16, "bn": 512, "sub": 64},
             {"bq": 16, "bn": 1024, "sub": 64}]
    ent = tuning.measure(runner, cands, backend="cpu", kind="topk",
                         Q=64, N=1 << 15, W=4, lanes=129, timer=fake_timer)
    assert ent["bn"] == 512 and len(calls) == 4 * len(cands)
    assert tuning.topk_blocks(64, 1 << 15, 4, 129, backend="cpu") \
        == (16, 512, 64)
    with open(path) as f:
        on_disk = json.load(f)
    assert list(on_disk.values())[0]["bn"] == 512
    # a fresh cache object reloads the measurement from disk
    tuning.configure(path)
    assert tuning.topk_blocks(64, 1 << 15, 4, 129, backend="cpu") \
        == (16, 512, 64)
    assert tuning.hint_source("cpu", "topk", 64, 1 << 15, 4, 129) \
        == "measured"
    # corrupt file degrades to seeded defaults, never raises
    with open(path, "w") as f:
        f.write("{ not json")
    tuning.configure(path)
    assert tuning.topk_blocks(64, 1 << 15, 4, 129, backend="cpu") \
        == tuning._topk_blocks_default(64, 1 << 15, 4, 129, "cpu")


def test_measure_feeds_explain_hint_source():
    """explain() flips measured/default through the cost-hint seam for
    BOTH tiers."""
    stats = plan.StoreStats(n=1 << 15, d=128, w=4, q=64, backend="cpu")
    pf = plan.plan_local(stats, 16, select="fused")
    pa = plan.plan_local(stats, 16, select="approx", recall_target=0.9)
    assert pf.explain()["geometry"]["hint_source"] == "default"
    assert pa.explain()["geometry"]["hint_source"] == "default"
    cache = tuning.autotune_cache()
    cache.put("cpu", "topk", 64, 1 << 15, 4,
              max(129, 16), {"bq": 16, "bn": 512, "sub": 64, "us": 1.0})
    cache.put("cpu", "approx", 64, 1 << 15, 4, 1, {"bn": 2048, "us": 1.0})
    assert pf.explain()["geometry"]["hint_source"] == "measured"
    ga = pa.explain()["geometry"]
    assert ga["hint_source"] == "measured" and ga["bn"] == 2048


def test_topk_candidates_are_sane_and_include_default():
    cands = tuning.topk_candidates(64, 1 << 15, 4, 129, backend="cpu")
    default = tuning._topk_blocks_default(64, 1 << 15, 4, 129, "cpu")
    assert dict(zip(("bq", "bn", "sub"), default)) in cands
    for c in cands:
        assert c["bq"] % 8 == 0 and c["sub"] % 8 == 0
        assert c["bn"] % c["sub"] == 0
