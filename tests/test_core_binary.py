"""Property tests: packing roundtrip, Hamming path agreement, metric axioms."""
import jax
import jax.numpy as jnp
import numpy as np
from _propcheck import given, settings, st

from repro.core import binary

settings.register_profile("ci", deadline=None, max_examples=25)
settings.load_profile("ci")


def _bits(rng, n, d):
    return jnp.asarray(rng.integers(0, 2, size=(n, d)), jnp.uint8)


@given(st.integers(1, 40), st.integers(1, 300), st.integers(0, 2**31 - 1))
def test_pack_unpack_roundtrip(n, d, seed):
    rng = np.random.default_rng(seed)
    bits = _bits(rng, n, d)
    assert (binary.unpack_bits(binary.pack_bits(bits), d) == bits).all()


@given(st.integers(1, 12), st.integers(1, 60), st.integers(1, 257),
       st.integers(0, 2**31 - 1))
def test_hamming_paths_agree(q, n, d, seed):
    rng = np.random.default_rng(seed)
    qb, xb = _bits(rng, q, d), _bits(rng, n, d)
    ref = binary.hamming_ref(qb, xb)
    assert (binary.hamming_xor(binary.pack_bits(qb), binary.pack_bits(xb)) == ref).all()
    assert (binary.hamming_mxu(qb, xb, d) == ref).all()


@given(st.integers(1, 20), st.integers(1, 128), st.integers(0, 2**31 - 1))
def test_metric_axioms(n, d, seed):
    rng = np.random.default_rng(seed)
    x = _bits(rng, n, d)
    xp = binary.pack_bits(x)
    dist = binary.hamming_xor(xp, xp)
    assert (jnp.diag(dist) == 0).all()                       # identity
    assert (dist == dist.T).all()                            # symmetry
    assert (dist >= 0).all() and (dist <= d).all()           # bounded domain
    # triangle inequality on a sample
    if n >= 3:
        i, j, k = 0, n // 2, n - 1
        assert int(dist[i, k]) <= int(dist[i, j]) + int(dist[j, k])


def test_mxu_exact_at_256_bits():
    rng = np.random.default_rng(0)
    qb, xb = _bits(rng, 64, 256), _bits(rng, 512, 256)
    assert (binary.hamming_mxu(qb, xb) == binary.hamming_ref(qb, xb)).all()
