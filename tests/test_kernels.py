"""Pallas kernels vs pure-jnp oracles (interpret=True on CPU): shape sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

SHAPES = [(8, 128, 1), (16, 300, 2), (128, 2048, 8), (7, 100, 4),
          (1, 5000, 8), (33, 999, 3), (64, 64, 6)]


def _codes(seed, n, w, dtype):
    rng = np.random.default_rng(seed)
    a = rng.integers(0, 2**31 - 1, size=(n, w), dtype=np.int64)
    return jnp.asarray(a, dtype)


@pytest.mark.parametrize("q,n,w", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.int32, jnp.uint32])
def test_hamming_distance_kernel(q, n, w, dtype):
    qp, xp = _codes(0, q, w, dtype), _codes(1, n, w, dtype)
    out = ops.hamming_distance(qp, xp)
    expect = ref.hamming_distance_ref(qp.astype(jnp.int32), xp.astype(jnp.int32))
    assert out.dtype == jnp.int32
    assert (out == expect).all()


@pytest.mark.parametrize("q,n,w", SHAPES)
def test_hamming_hist_kernel(q, n, w):
    qp, xp = _codes(2, q, w, jnp.int32), _codes(3, n, w, jnp.int32)
    bins = w * 32 + 1
    out = ops.hamming_hist(qp, xp, bins)
    expect = ref.hamming_hist_ref(qp, xp, bins)
    assert (out == expect).all()
    assert int(out.sum()) == q * n           # every pair lands in one bin


def test_hist_then_radius_select_equals_topk():
    """Two-pass temporal-sort: kernel histogram -> radius -> emit == oracle."""
    from repro.core import binary, topk
    rng = np.random.default_rng(4)
    d, n, q, k = 128, 4096, 8, 16
    xb = jnp.asarray(rng.integers(0, 2, (n, d)), jnp.uint8)
    qb = jnp.asarray(rng.integers(0, 2, (q, d)), jnp.uint8)
    xp, qp = binary.pack_bits(xb), binary.pack_bits(qb)
    hist = ops.hamming_hist(qp.astype(jnp.int32), xp.astype(jnp.int32), d + 1)
    cum = jnp.cumsum(hist, axis=1)
    r_star = jnp.argmax(cum >= k, axis=1)
    dist = binary.hamming_ref(qb, xb)
    rd, _ = topk.topk_ref(dist, k)
    assert (r_star == rd[:, -1]).all()       # radius == k-th smallest distance


@pytest.mark.parametrize("shape", [(2, 256, 4, 2, 64, 64, 64),
                                   (2, 256, 4, 2, 64, 128, 64),
                                   (1, 192, 4, 4, 64, 64, 128),
                                   (2, 200, 2, 1, 32, 64, 64)])
def test_flash_attention_kernel(shape):
    """Pallas flash fwd vs the XLA blockwise oracle (exact in f32)."""
    from repro.kernels import ops
    from repro.models import attention
    B, S, H, KV, hd, bq, bk = shape
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (B, S, H, hd), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, KV, hd), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, KV, hd), jnp.float32)
    truth = attention.blockwise_causal_attention(q, k, v, chunk=64)
    out = ops.flash_attention(q, k, v, bq=bq, bk=bk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(truth),
                               atol=3e-6, rtol=1e-5)
    # bf16 within quantization error of the f32 truth
    ob = ops.flash_attention(q.astype(jnp.bfloat16), k.astype(jnp.bfloat16),
                             v.astype(jnp.bfloat16), bq=bq, bk=bk)
    assert float(jnp.max(jnp.abs(ob.astype(jnp.float32) - truth))) < 0.05
