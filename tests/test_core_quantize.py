import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quantize


def test_itq_objective_monotone_improvement():
    x = jnp.asarray(np.random.default_rng(0).normal(size=(1500, 48)), jnp.float32)
    objs = [float(quantize.itq_objective(x, quantize.itq_train(x, 24, iters=i)))
            for i in (1, 5, 30)]
    assert objs[2] <= objs[0] + 1e-3


def test_itq_rotation_orthogonal():
    x = jnp.asarray(np.random.default_rng(1).normal(size=(500, 32)), jnp.float32)
    p = quantize.itq_train(x, 16, iters=10)
    eye = p.rot @ p.rot.T
    np.testing.assert_allclose(np.asarray(eye), np.eye(16), atol=1e-4)


def test_itq_encode_shapes_and_binary():
    x = jnp.asarray(np.random.default_rng(2).normal(size=(100, 32)), jnp.float32)
    p = quantize.itq_train(x, 16, iters=3)
    codes = quantize.itq_encode(x, p)
    assert codes.shape == (100, 16)
    assert set(np.unique(np.asarray(codes))) <= {0, 1}


def test_itq_preserves_neighborhoods_better_than_random_projection():
    """ITQ recall@10 beats plain LSH on low-rank data (smooth distance
    structure; tight clusters would tie at the code level and say nothing)."""
    rng = np.random.default_rng(3)
    z = rng.normal(size=(3000, 8)).astype(np.float32)
    w = rng.normal(size=(8, 64)).astype(np.float32)
    x = (z @ w + 0.05 * rng.normal(size=(3000, 64))).astype(np.float32)
    xq = jnp.asarray(x)
    from repro.core import binary
    q = xq[:64]
    d2 = jnp.sum((q[:, None] - xq[None]) ** 2, -1)
    exact = jnp.argsort(d2, axis=1)[:, 1:11]

    def recall(codes):
        packed = binary.pack_bits(codes)
        dist = binary.hamming_xor(packed[:64], packed)
        dist = dist.at[jnp.arange(64), jnp.arange(64)].set(codes.shape[1] + 1)
        ids = jnp.argsort(dist, axis=1)[:, :10]
        return float(jnp.mean(jnp.any(ids[:, :, None] == exact[:, None, :], 1)))

    itq = quantize.itq_train(xq, 32, iters=25)
    lsh = quantize.lsh_train(64, 32, key=jax.random.PRNGKey(5))
    r_itq = recall(quantize.itq_encode(xq, itq))
    r_lsh = recall(quantize.lsh_encode(xq, lsh))
    assert r_itq > 0.25, r_itq
    assert r_itq >= r_lsh - 0.02, (r_itq, r_lsh)
