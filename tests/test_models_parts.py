"""Layer-level oracles: chunked scans == sequential recurrences; blockwise
attention == naive; MoE reference mass conservation."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, scaled_down
from repro.models import attention, lm, mamba2, moe, rwkv6
from repro.models.layers import apply_rope


def test_blockwise_attention_matches_naive():
    key = jax.random.PRNGKey(0)
    B, S, H, KV, hd = 2, 96, 4, 2, 16
    q = jax.random.normal(key, (B, S, H, hd), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, KV, hd), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, KV, hd), jnp.float32)

    # naive causal GQA
    kk = jnp.repeat(k, H // KV, axis=2)
    vv = jnp.repeat(v, H // KV, axis=2)
    s = jnp.einsum("bqhk,bshk->bhqs", q * hd ** -0.5, kk)
    mask = jnp.tril(jnp.ones((S, S), bool))
    s = jnp.where(mask[None, None], s, -1e30)
    naive = jnp.einsum("bhqs,bshk->bqhk", jax.nn.softmax(s, -1), vv)

    for causal_skip in (False, True):
        out = attention.blockwise_causal_attention(
            q, k, v, chunk=32, causal_skip=causal_skip)
        np.testing.assert_allclose(np.asarray(out), np.asarray(naive),
                                   atol=2e-5, rtol=1e-4)


@pytest.mark.parametrize("chunk", [8, 32, 128])
def test_ssd_chunked_equals_sequential(chunk):
    key = jax.random.PRNGKey(0)
    B, S, H, P, N = 2, 50, 3, 8, 4
    x = jax.random.normal(key, (B, S, H, P))
    b = jax.random.normal(jax.random.PRNGKey(1), (B, S, N))
    c = jax.random.normal(jax.random.PRNGKey(2), (B, S, N))
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(3), (B, S, H)))
    a_log = jnp.zeros((H,))

    y, h_fin = mamba2._ssd_chunked(x, b, c, dt, a_log, chunk)

    # sequential recurrence oracle
    a = -jnp.exp(a_log)
    h = jnp.zeros((B, H, P, N))
    ys = []
    for t in range(S):
        at = jnp.exp(dt[:, t] * a)                            # (B,H)
        h = h * at[:, :, None, None] + jnp.einsum(
            "bh,bn,bhp->bhpn", dt[:, t], b[:, t], x[:, t])
        ys.append(jnp.einsum("bn,bhpn->bhp", c[:, t], h))
    y_seq = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_seq), atol=1e-4,
                               rtol=1e-3)
    np.testing.assert_allclose(np.asarray(h_fin), np.asarray(h), atol=1e-4,
                               rtol=1e-3)


@pytest.mark.parametrize("chunk", [8, 64])
def test_wkv_chunked_equals_sequential(chunk):
    key = jax.random.PRNGKey(0)
    B, S, H, hd = 2, 40, 2, 8
    r = jax.random.normal(key, (B, S, H, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, H, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, H, hd))
    logw = -jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(3), (B, S, H, hd)))
    u = jax.random.normal(jax.random.PRNGKey(4), (H, hd)) * 0.1

    y, s_fin = rwkv6._wkv_chunked(r, k, v, logw, u, chunk)

    S_state = jnp.zeros((B, H, hd, hd))
    ys = []
    for t in range(S):
        y_t = jnp.einsum("bhi,bhij->bhj", r[:, t], S_state) + jnp.einsum(
            "bhi,hi,bhi,bhj->bhj", r[:, t], u, k[:, t], v[:, t])
        S_state = jnp.exp(logw[:, t])[..., None] * S_state + jnp.einsum(
            "bhi,bhj->bhij", k[:, t], v[:, t])
        ys.append(y_t)
    y_seq = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_seq), atol=1e-4,
                               rtol=1e-3)
    np.testing.assert_allclose(np.asarray(s_fin), np.asarray(S_state),
                               atol=1e-4, rtol=1e-3)


def test_moe_reference_weight_mass():
    cfg = dataclasses.replace(scaled_down(get_config("kimi-k2-1t-a32b")),
                              dtype="float32")
    params = moe.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (32, cfg.d_model)) * 0.1
    w, idx, probs = moe._route(params["router"], x, cfg.moe.experts_per_token)
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, atol=1e-5)
    assert (idx >= 0).all() and (idx < cfg.moe.num_experts).all()
    aux = moe._aux_loss(probs, idx, cfg.moe.num_experts)
    assert float(aux) >= 1.0 - 1e-3              # >= 1 by Cauchy-Schwarz


def test_rope_rotation_preserves_norm_and_relativity():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (1, 8, 2, 16))
    pos = jnp.arange(8)[None]
    y = apply_rope(x, pos, 10000.0)
    np.testing.assert_allclose(np.asarray(jnp.linalg.norm(y, axis=-1)),
                               np.asarray(jnp.linalg.norm(x, axis=-1)),
                               rtol=1e-5)
    # relative property: <rope(q,m), rope(k,n)> depends only on m-n
    q = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 16))
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 1, 16))
    def dot_at(m, n):
        qm = apply_rope(q, jnp.asarray([[m]]), 10000.0)
        kn = apply_rope(k, jnp.asarray([[n]]), 10000.0)
        return float(jnp.sum(qm * kn))
    assert abs(dot_at(3, 1) - dot_at(7, 5)) < 1e-4
