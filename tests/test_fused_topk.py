"""Fused two-pass Pallas top-k (hamming_topk + engine select="fused"):
equivalence with the oracle and the materialized-distance paths, including
the padding/masking edges the kernels handle internally; the single-shot
contract (one hist + one emit pallas_call over the whole datastore, no
scan, no merge) and block-min pruning on clustered datastores."""
import numpy as np

import jax.numpy as jnp
import pytest

from repro.core import binary, engine, topk
from repro.kernels import ops, ref, tuning

# shapes chosen to hit: N/Q multiples of the default blocks, N NOT a
# multiple of any block (pad masking), W from 1 to 8 words, Q below one
# sublane tile
SHAPES = [(8, 1024, 64), (5, 999, 96), (16, 300, 32), (1, 4097, 256),
          (33, 130, 160)]


def _data(seed, n, q, d):
    rng = np.random.default_rng(seed)
    xb = jnp.asarray(rng.integers(0, 2, (n, d)), jnp.uint8)
    qb = jnp.asarray(rng.integers(0, 2, (q, d)), jnp.uint8)
    return xb, qb


@pytest.mark.parametrize("q,n,d", SHAPES)
@pytest.mark.parametrize("k", [1, 10, 64])
def test_hamming_topk_matches_oracle(q, n, d, k):
    xb, qb = _data(0, n, q, d)
    xp, qp = binary.pack_bits(xb), binary.pack_bits(qb)
    dist = binary.hamming_ref(qb, xb)
    rd, _ = topk.topk_ref(dist, min(k, n))
    cd, ci = topk.counting_topk(dist, k, d)
    fd, fi = ops.hamming_topk(qp, xp, k, d + 1)
    assert (fd[:, :min(k, n)] == rd).all()          # distances == sorted oracle
    assert (fd == cd).all() and (fi == ci).all()    # bit-identical tie semantics


def test_heavy_ties_at_r_star():
    """d=8 over 4096 rows: hundreds of ties at every radius; the emit pass
    must fill the tie slots in index order exactly like counting_topk."""
    xb, qb = _data(1, 4096, 4, 8)
    xp, qp = binary.pack_bits(xb), binary.pack_bits(qb)
    dist = binary.hamming_ref(qb, xb)
    for k in (3, 50, 512):
        cd, ci = topk.counting_topk(dist, k, 8)
        fd, fi = ops.hamming_topk(qp, xp, k, 9)
        assert (fd == cd).all() and (fi == ci).all()


def test_k_exceeds_rows():
    """k > N: real rows first, then (bins, N) padding, same as counting."""
    xb, qb = _data(2, 37, 3, 64)
    xp, qp = binary.pack_bits(xb), binary.pack_bits(qb)
    dist = binary.hamming_ref(qb, xb)
    cd, ci = topk.counting_topk(dist, 50, 64)
    fd, fi = ops.hamming_topk(qp, xp, 50, 65)
    assert (fd == cd).all() and (fi == ci).all()
    assert (fd[:, 37:] == 65).all() and (fi[:, 37:] == 37).all()


def test_n_valid_masks_tail_rows():
    """Rows >= n_valid must be invisible to both passes (the engine's
    chunk-padding contract)."""
    xb, qb = _data(3, 512, 4, 64)
    xp, qp = binary.pack_bits(xb), binary.pack_bits(qb)
    nv = 300
    dist = binary.hamming_ref(qb, xb[:nv])
    cd, ci = topk.counting_topk(dist, 16, 64)
    fd, fi = ops.hamming_topk(qp, xp, 16, 65, n_valid=nv)
    assert (fd == cd).all() and (fi == ci).all()


@pytest.mark.parametrize("q,n,d", SHAPES)
def test_hamming_hist_pad_path(q, n, d):
    """Direct test of ops.hamming_hist pad handling: block-alignment rows
    added by the wrapper must contribute nothing, even when their (zero)
    codes would land in bin 0 and silently corrupt r*. The ragged SHAPES
    force padding; the aligned ones cover the no-pad path."""
    xb, qb = _data(4, n, q, d)
    xp = binary.pack_bits(xb).astype(jnp.int32)
    qp = binary.pack_bits(qb).astype(jnp.int32)
    hist = ops.hamming_hist(qp, xp, d + 1)
    expect = ref.hamming_hist_ref(qp, xp, d + 1)
    assert (hist == expect).all()
    assert int(hist.sum()) == q * n


def test_hamming_hist_clamp_bin():
    """Distances >= bins must clamp into the top bin, matching the ref."""
    qp = jnp.zeros((2, 2), jnp.int32)
    xp = jnp.full((70, 2), -1, jnp.int32)          # distance 64 everywhere
    hist = ops.hamming_hist(qp, xp, 5)
    assert (hist[:, 4] == 70).all() and int(hist.sum()) == 2 * 70


@pytest.mark.parametrize("n,q,d,k,chunk", [
    (500, 6, 64, 10, 130),      # ragged chunks: last chunk mostly padding
    (2048, 16, 128, 16, 512),   # aligned chunks
    (300, 4, 32, 400, 128),     # k > N through the scan merge
    (17, 2, 32, 5, 16),         # tiny: N barely above one chunk
])
def test_engine_fused_bit_identical(n, q, d, k, chunk):
    xb, qb = _data(5, n, q, d)
    xp, qp = binary.pack_bits(xb), binary.pack_bits(qb)
    ad, ai = engine.search_chunked(xp, qp, k, d, chunk=chunk, select="auto")
    fd, fi = engine.search_chunked(xp, qp, k, d, chunk=chunk, select="fused")
    assert (ad == fd).all() and (ai == fi).all()


def test_single_shot_one_hist_one_emit(monkeypatch):
    """select='fused' on N >> chunk must issue exactly one hist and one emit
    pallas_call — no lax.scan over chunks, no merge_topk — and stay
    bit-identical to counting_topk."""
    from repro.kernels import ops as ops_mod

    calls = {"hist": 0, "emit": 0}
    real_hist, real_emit = ops_mod.hamming_hist_pallas, ops_mod.hamming_emit_pallas
    monkeypatch.setattr(ops_mod, "hamming_hist_pallas",
                        lambda *a, **kw: (calls.__setitem__("hist", calls["hist"] + 1),
                                          real_hist(*a, **kw))[1])
    monkeypatch.setattr(ops_mod, "hamming_emit_pallas",
                        lambda *a, **kw: (calls.__setitem__("emit", calls["emit"] + 1),
                                          real_emit(*a, **kw))[1])

    def no_merge(*a, **kw):
        raise AssertionError("merge_topk must not run on the fused path")

    monkeypatch.setattr(topk, "merge_topk", no_merge)
    xb, qb = _data(7, 3000, 4, 64)
    xp, qp = binary.pack_bits(xb), binary.pack_bits(qb)
    fd, fi = engine.search_chunked(xp, qp, 8, 64, chunk=256, select="fused")
    assert calls == {"hist": 1, "emit": 1}
    cd, ci = topk.counting_topk(binary.hamming_ref(qb, xb), 8, 64)
    assert (fd == cd).all() and (fi == ci).all()


def test_fused_scan_matches_single_shot():
    """The retained chunk-scanned variant stays bit-identical to the
    single-shot path (and hence to every other select)."""
    xb, qb = _data(11, 700, 4, 64)
    xp, qp = binary.pack_bits(xb), binary.pack_bits(qb)
    fd, fi = engine.search_chunked(xp, qp, 9, 64, chunk=128, select="fused")
    sd, si = engine.search_chunked(xp, qp, 9, 64, chunk=128,
                                   select="fused_scan")
    assert (fd == sd).all() and (fi == si).all()


def test_clustered_prunes_most_blocks():
    """Clustered/sorted datastore: one near cluster owns the top-k, so the
    block-min guard must skip most pass-2 blocks — and results stay
    bit-identical to counting_topk."""
    rng = np.random.default_rng(8)
    d, n, k = 128, 4096, 10
    near = (rng.random((64, d)) < 0.05).astype(np.uint8)
    far = (rng.random((n - 64, d)) < 0.9).astype(np.uint8)
    xb = jnp.asarray(np.concatenate([near, far]), jnp.uint8)
    qb = jnp.zeros((4, d), jnp.uint8)
    xp, qp = binary.pack_bits(xb), binary.pack_bits(qb)
    fd, fi, stats = ops.hamming_topk(qp, xp, k, d + 1, return_stats=True)
    cd, ci = topk.counting_topk(binary.hamming_ref(qb, xb), k, d)
    assert (fd == cd).all() and (fi == ci).all()
    frac = float(stats["blocks_skipped"]) / stats["blocks_total"]
    assert frac >= 0.5, f"pruned only {frac:.2f} of {stats['blocks_total']}"


def test_uniform_data_prunes_nothing_and_stays_exact():
    """Uniform random data: nothing is provably loser-only, so the guard
    must pass (almost) every block through — exactness is the contract."""
    xb, qb = _data(12, 1024, 8, 64)
    xp, qp = binary.pack_bits(xb), binary.pack_bits(qb)
    fd, fi, stats = ops.hamming_topk(qp, xp, 16, 65, return_stats=True)
    cd, ci = topk.counting_topk(binary.hamming_ref(qb, xb), 16, 64)
    assert (fd == cd).all() and (fi == ci).all()
    # every block of diverse uniform data holds some near row for some
    # query: the guard must not skip a single tile (no over-pruning)
    assert int(stats["blocks_skipped"]) == 0
    assert int(stats["p1_blocks_skipped"]) == 0


def test_block_mask_restricts_candidate_set():
    """An explicit enable mask must make the result the exact top-k over the
    enabled blocks only — candidate-set semantics, not post-filtering: r*
    derives from the masked histogram, so a query seeing < k rows emits
    sentinels rather than stealing rows from disabled blocks."""
    xb, qb = _data(13, 1024, 8, 64)
    xp, qp = binary.pack_bits(xb), binary.pack_bits(qb)
    bq, bn, sub, q_pad, n_pad = ops.topk_geometry(8, 1024, 2, 65, bn=256)
    nblk = n_pad // bn
    assert nblk == 4
    # enable blocks 1 and 3 -> rows [256, 512) u [768, 1024)
    mask = jnp.asarray([[0, 1, 0, 1]], jnp.int32)
    md, mi = ops.hamming_topk(qp, xp, 10, 65, block_mask=mask,
                              bq=bq, bn=bn, sub=sub)
    rows = np.r_[256:512, 768:1024]
    dist = binary.hamming_ref(qb, xb[rows])
    rd, ri = topk.counting_topk(dist, 10, 64)
    ri = jnp.asarray(rows, jnp.int32)[ri]       # candidate slot -> global id
    assert (md == rd).all() and (mi == ri).all()


def test_block_mask_below_k_candidates_sentinels():
    """Mask leaves fewer than k rows: live slots are the full enabled set,
    the rest are (bins, N) sentinels — same contract as n_valid < k."""
    xb, qb = _data(14, 1024, 4, 64)
    xp, qp = binary.pack_bits(xb), binary.pack_bits(qb)
    bq, bn, sub, q_pad, n_pad = ops.topk_geometry(4, 1024, 2, 65, bn=256)
    mask = jnp.zeros((q_pad // bq, n_pad // bn), jnp.int32).at[:, 2].set(1)
    k = 300                                     # > 256 enabled rows
    md, mi = ops.hamming_topk(qp, xp, k, 65, block_mask=mask,
                              bq=bq, bn=bn, sub=sub)
    dist = binary.hamming_ref(qb, xb[512:768])
    rd, ri = topk.counting_topk(dist, k, 64)
    assert (md[:, :256] == rd[:, :256]).all()
    assert (mi[:, :256] == ri[:, :256] + 512).all()
    assert (md[:, 256:] == 65).all() and (mi[:, 256:] == 1024).all()


def test_block_mask_stats_report_both_passes():
    xb, qb = _data(15, 2048, 8, 64)
    xp, qp = binary.pack_bits(xb), binary.pack_bits(qb)
    bq, bn, sub, q_pad, n_pad = ops.topk_geometry(8, 2048, 2, 65, bn=256)
    nblk = n_pad // bn
    mask = jnp.ones((q_pad // bq, nblk), jnp.int32).at[:, :nblk // 2].set(0)
    _, _, stats = ops.hamming_topk(qp, xp, 8, 65, block_mask=mask,
                                   bq=bq, bn=bn, sub=sub, return_stats=True)
    assert int(stats["p1_blocks_skipped"]) == nblk // 2
    # pass 2 composes the mask with block-min: at least the masked tiles
    assert int(stats["blocks_skipped"]) >= nblk // 2
    assert stats["blocks_total"] == nblk


def test_k_exceeds_n_valid():
    """k > n_valid < N: live slots match counting_topk over the valid
    prefix; the rest are (bins, N) sentinels."""
    xb, qb = _data(9, 256, 3, 64)
    xp, qp = binary.pack_bits(xb), binary.pack_bits(qb)
    nv, k = 20, 32
    cd, ci = topk.counting_topk(binary.hamming_ref(qb, xb[:nv]), k, 64)
    fd, fi = ops.hamming_topk(qp, xp, k, 65, n_valid=nv)
    assert (fd[:, :nv] == cd[:, :nv]).all() and (fi[:, :nv] == ci[:, :nv]).all()
    assert (fd[:, nv:] == 65).all() and (fi[:, nv:] == 256).all()


def test_engine_class_select_knob():
    xb, qb = _data(6, 400, 3, 64)
    eng = engine.KNNEngine(codes=binary.pack_bits(xb), d=64)
    ad, ai = eng.search(binary.pack_bits(qb), k=7)
    fd, fi = eng.search(binary.pack_bits(qb), k=7, select="fused")
    assert (ad == fd).all() and (ai == fi).all()


def test_sharded_fused_bit_identical(multidevice):
    """search_sharded(select='fused') under shard_map on 4 fake devices —
    the traced n_valid scalar and the SMEM BlockSpec must survive SPMD."""
    multidevice("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.core import binary, engine

rng = np.random.default_rng(0)
xb = jnp.asarray(rng.integers(0, 2, (1024, 64)), jnp.uint8)
qb = jnp.asarray(rng.integers(0, 2, (8, 64)), jnp.uint8)
xp, qp = binary.pack_bits(xb), binary.pack_bits(qb)
mesh = Mesh(np.array(jax.devices()).reshape(4), ("data",))
with mesh:
    ad, ai = engine.search_sharded(xp, qp, 10, 64, mesh, ("data",), chunk=256)
    fd, fi = engine.search_sharded(xp, qp, 10, 64, mesh, ("data",), chunk=256,
                                   select="fused")
assert (ad == fd).all() and (ai == fi).all()
print("OK")
""", n_devices=4)


def test_topk_blocks_divisibility():
    """The heuristic must return kernel-legal shapes: bq | Q_pad, sub | bn,
    sublane/lane alignment."""
    for (Q, N, W, lanes) in [(1, 100, 1, 9), (256, 1 << 17, 8, 257),
                             (64, 4096, 4, 129), (7, 50, 2, 33)]:
        bq, bn, sub = tuning.topk_blocks(Q, N, W, lanes, backend="cpu")
        assert bq % 8 == 0 and bn % sub == 0 and sub % 8 == 0
        bq_t, bn_t, sub_t = tuning.topk_blocks(Q, N, W, lanes, backend="tpu")
        assert bn_t % sub_t == 0
        # one-hot intermediate respects the VMEM budget
        assert 4 * bq_t * sub_t * lanes <= (2 << 20)
