"""Shard-fault tolerance: degraded-but-exact answers, health states,
replica placement, and the host-orchestrated fault-tolerant search.

The load-bearing pin is BIT-IDENTITY: excluding a dead shard via the
participation mask (SPMD path) or serving a range from a replica after a
mid-stream kill (host path) must produce exactly the answer a from-scratch
search over only the surviving rows would — dists AND ids, including the
k > survivors and zero-coverage edges — while the CoverageReport says
precisely what was searched.
"""
import numpy as np
import pytest

from repro.dist.health import (CoverageReport, HealthRegistry, DEAD,
                               HEALTHY, RECOVERING, SUSPECT)
from repro.dist.sharding import ReplicaMap


# ---------------------------------------------------------------------------
# SPMD participation mask: every single-dead pattern over uneven shards
# ---------------------------------------------------------------------------

def test_participation_mask_single_dead_patterns(multidevice):
    """Uneven 4-device shards; for EVERY single-dead-shard pattern the
    masked sharded answer equals a rebuilt store of only surviving rows
    (dists and ids, ids renumbered over the masked scan), with k larger
    than one shard and k larger than all survivors; the all-dead mask
    yields pure sentinels. hist_tree agrees bit-for-bit throughout."""
    multidevice("""
import warnings
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.core import binary, engine
from repro.kernels import ops

rng = np.random.default_rng(7)
d, Q, n_loc = 64, 6, 512
nv = np.array([300, 512, 11, 201], np.int32)
xb = rng.integers(0, 2, (4 * n_loc, d)).astype(np.uint8)
qp = binary.pack_bits(jnp.asarray(rng.integers(0, 2, (Q, d)), jnp.uint8))
xp_full = np.asarray(binary.pack_bits(jnp.asarray(xb)))
parts, valid = [], []
for s in range(4):
    blk = xp_full[s * n_loc:(s + 1) * n_loc].copy()
    valid.append(blk[:nv[s]].copy())
    blk[nv[s]:] = 0xFFFFFFFF
    parts.append(blk)
xpad = jnp.asarray(np.concatenate(parts))
mesh = Mesh(np.array(jax.devices()).reshape(4), ("data",))

for dead in range(4):
    part = np.ones(4, np.int32); part[dead] = 0
    surv = jnp.asarray(np.concatenate(
        [valid[s] for s in range(4) if s != dead]))
    for k in (64, 1200):       # 64 > nv[2]=11; 1200 > any survivor total
        rd, ri = ops.hamming_topk(qp, surv, k, d + 1)
        with mesh, warnings.catch_warnings():
            warnings.simplefilter("ignore")
            hd, hi = engine.search_sharded(
                xpad, qp, k, d, mesh, ("data",),
                shard_n_valid=jnp.asarray(nv),
                shard_participate=jnp.asarray(part))
            td, ti = engine.search_sharded(
                xpad, qp, k, d, mesh, ("data",), merge="hist_tree",
                fanout=2, shard_n_valid=jnp.asarray(nv),
                shard_participate=jnp.asarray(part))
        assert (hd == rd).all() and (hi == ri).all(), ("mask", dead, k)
        assert (td == hd).all() and (ti == hi).all(), ("tree", dead, k)

# all shards dead: nothing to search -> pure (bins, 0) sentinels
with mesh, warnings.catch_warnings():
    warnings.simplefilter("ignore")
    zd, zi = engine.search_sharded(
        xpad, qp, 16, d, mesh, ("data",), shard_n_valid=jnp.asarray(nv),
        shard_participate=jnp.zeros(4, jnp.int32))
assert (zd == d + 1).all() and (zi == 0).all(), "all-dead sentinels"
print("OK")
""", n_devices=4)


def test_hist_tree_identity_and_even_masks(multidevice):
    """Even shards, no n_valid: hist_tree (every fanout, including a
    non-dividing one) is bit-identical to flat hist_merge, healthy and
    with a participation mask (derived id bases over the masked scan)."""
    multidevice("""
import warnings
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.core import binary, engine
from repro.kernels import ops

rng = np.random.default_rng(8)
d, N, Q, k = 64, 2048, 8, 16
xp = binary.pack_bits(jnp.asarray(rng.integers(0, 2, (N, d)), jnp.uint8))
qp = binary.pack_bits(jnp.asarray(rng.integers(0, 2, (Q, d)), jnp.uint8))
mesh = Mesh(np.array(jax.devices()).reshape(4), ("data",))

rd, ri = ops.hamming_topk(qp, xp, k, d + 1)
with mesh:
    hd, hi = engine.search_sharded(xp, qp, k, d, mesh, ("data",))
assert (hd == rd).all() and (hi == ri).all()
for fanout in (2, 3, 4):       # 3 does not divide 4: remainder round
    with mesh:
        td, ti = engine.search_sharded(xp, qp, k, d, mesh, ("data",),
                                       merge="hist_tree", fanout=fanout)
    assert (td == hd).all() and (ti == hi).all(), fanout

# masked + even shards (no shard_n_valid): id bases derive from the
# masked scan, so ids renumber exactly as the surviving-rows rebuild
part = np.array([1, 0, 1, 1], np.int32)
surv = jnp.asarray(np.concatenate([np.asarray(xp)[:512],
                                   np.asarray(xp)[1024:]]))
rd2, ri2 = ops.hamming_topk(qp, surv, k, d + 1)
with mesh, warnings.catch_warnings():
    warnings.simplefilter("ignore")
    md, mi = engine.search_sharded(xp, qp, k, d, mesh, ("data",),
                                   shard_participate=jnp.asarray(part))
    ud, ui = engine.search_sharded(xp, qp, k, d, mesh, ("data",),
                                   merge="hist_tree", fanout=2,
                                   shard_participate=jnp.asarray(part))
assert (md == rd2).all() and (mi == ri2).all(), "masked even"
assert (ud == md).all() and (ui == mi).all(), "masked tree"
print("OK")
""", n_devices=4)


# ---------------------------------------------------------------------------
# planner: hist_tree strategy selection + participation plumbing guards
# ---------------------------------------------------------------------------

def test_planner_hist_tree_selection():
    from repro.core import plan

    # auto: many shards -> hist_tree with a tuned fanout; few -> hist_merge
    big = plan.plan_sharded(plan.stats_for(1 << 20, 64, 2, 8, n_shards=64),
                            16, axes=("data",))
    assert big.merge.strategy == "hist_tree" and big.merge.fanout >= 2
    assert "hist_tree" in big.compact() and f"@f{big.merge.fanout}" in \
        big.compact()
    small = plan.plan_sharded(plan.stats_for(1 << 14, 64, 2, 8, n_shards=4),
                              16, axes=("data",))
    assert small.merge.strategy == "hist_merge" and small.merge.fanout == 0

    # forced hist_tree at few shards gets a defaulted fanout; forced
    # fanout must be >= 2 and only applies to hist_tree
    forced = plan.plan_sharded(plan.stats_for(1 << 14, 64, 2, 8, n_shards=4),
                               16, axes=("data",), merge="hist_tree")
    assert forced.merge.strategy == "hist_tree" and forced.merge.fanout >= 2
    with pytest.raises(ValueError):
        plan.plan_sharded(plan.stats_for(1 << 14, 64, 2, 8, n_shards=4),
                          16, axes=("data",), force="merge=hist_tree,fanout=1")
    f4 = plan.plan_sharded(plan.stats_for(1 << 20, 64, 2, 8, n_shards=8),
                           16, axes=("data",),
                           force="merge=hist_tree,fanout=4")
    assert f4.merge.fanout == 4

    # geometry() predicts both tree levels' traffic
    g = big.geometry()["merge"]
    assert g["strategy"] == "hist_tree"
    assert g["tree_levels"] >= 2
    assert g["hist_tree_bytes"] <= g["merge_bytes"] * 1.001
    assert "merge-levels" in big.explain_str() or \
        "levels" in big.explain_str()


def test_participation_requires_hist_family():
    """shard_participate through a concat_sort merge would silently search
    dead rows — the executor must refuse, not guess."""
    import jax, jax.numpy as jnp
    from jax.sharding import Mesh
    from repro.core import plan

    stats = plan.stats_for(1024, 64, 2, 4, n_shards=1)
    p = plan.plan_sharded(stats, 8, axes=("data",), merge="concat_sort")
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1), ("data",))
    q = jnp.zeros((4, 2), jnp.uint32)
    x = jnp.zeros((1024, 2), jnp.uint32)
    with pytest.raises(ValueError, match="hist"):
        with mesh:
            plan.execute(p, q, codes=x, mesh=mesh,
                         shard_participate=jnp.ones(1, jnp.int32))


# ---------------------------------------------------------------------------
# health registry state machine
# ---------------------------------------------------------------------------

def test_health_state_machine_walk():
    reg = HealthRegistry(["a", "b"], deadline_s=0.05, suspect_after=1,
                        dead_after=3, recover_probes=2)
    assert reg.state("a") == HEALTHY
    assert reg.observe("a", False) == SUSPECT        # 1 failure -> suspect
    assert reg.observe("a", True, 0.01) == HEALTHY   # success recovers
    for _ in range(3):
        st = reg.observe("a", False)
    assert st == DEAD and reg.state("a") == DEAD
    assert sorted(reg.serving()) == ["b"]
    assert reg.not_serving() == ["a"]

    reg.revive("a")
    assert reg.state("a") == RECOVERING
    assert "a" not in reg.serving()                  # recovering ≠ serving
    assert reg.observe("a", True, 0.0) == RECOVERING # 1 of 2 probes
    assert reg.observe("a", True, 0.0) == HEALTHY    # 2nd probe promotes
    # recovering + a failure drops straight back to dead
    reg.kill("a"); reg.revive("a")
    assert reg.observe("a", False) == DEAD


def test_health_deadline_miss_is_failure():
    """ok=True over the deadline counts as a failure — a stalled shard is
    as gone as a crashed one."""
    reg = HealthRegistry(["a"], deadline_s=0.01, suspect_after=1,
                        dead_after=2)
    assert reg.observe("a", True, latency_s=0.5) == SUSPECT
    assert reg.observe("a", True, latency_s=0.5) == DEAD
    snap = reg.snapshot()
    assert snap["counters"]["a"]["deadline_misses"] == 2
    assert snap["n_dead"] == 1
    assert ("a", SUSPECT, DEAD) in snap["transitions"]


def test_health_unknown_unit_and_bad_thresholds():
    reg = HealthRegistry(["a"])
    with pytest.raises(KeyError):
        reg.observe("nope", True)
    with pytest.raises(ValueError):
        HealthRegistry(["a"], suspect_after=2, dead_after=1)


def test_coverage_report_accounting():
    r = CoverageReport(covered_rows=750, total_rows=1000,
                       dead_shards=("unit2",))
    assert r.coverage_frac == 0.75 and not r.complete
    assert r.as_dict()["dead_shards"] == ["unit2"]
    assert CoverageReport(5, 5).complete
    assert CoverageReport(0, 0).coverage_frac == 1.0      # empty store
    assert CoverageReport(0, 0, ("u",)).coverage_frac == 0.0


# ---------------------------------------------------------------------------
# replica placement arithmetic
# ---------------------------------------------------------------------------

def test_replica_map_placement_properties():
    m = ReplicaMap((10, 20, 30, 40), ("u0", "u1", "u2", "u3"), factor=2)
    assert m.total_rows == 100
    assert m.holders(0) == ("u0", "u1")                  # ring, primary 1st
    assert m.holders(3) == ("u3", "u0")                  # wraps
    assert m.held_by("u0") == (0, 3)
    assert m.range_bounds(2) == (30, 60)
    # healthy fleet: every range served by its primary
    alive = ("u0", "u1", "u2", "u3")
    assert m.assignment(alive) == {0: "u0", 1: "u1", 2: "u2", 3: "u3"}
    # one death: replica serves, nothing uncovered
    assert m.owner(1, ("u0", "u2", "u3")) == "u2"
    assert m.uncovered(("u0", "u2", "u3")) == []
    assert m.covered_rows(("u0", "u2", "u3")) == 100
    # both holders of range 1 dead: the range is lost, others survive
    assert m.uncovered(("u0", "u3")) == [1]
    assert m.covered_rows(("u0", "u3")) == 80
    # held overrides nominal possession (revived-empty unit)
    held = {"u0": {0, 3}, "u1": set(), "u2": {1, 2}, "u3": {2, 3}}
    assert m.owner(1, alive, held=held) == "u2"          # u1 empty
    assert m.owner(0, alive, held=held) == "u0"


def test_replica_map_rebuild_targets():
    m = ReplicaMap((1, 1, 1, 1), ("u0", "u1", "u2", "u3"), factor=2)
    # u1 died and came back empty: both its ranges refill, nominal first
    held = {"u0": {0, 3}, "u1": set(), "u2": {1, 2}, "u3": {2, 3}}
    work = m.rebuild_targets(("u0", "u1", "u2", "u3"), held=held)
    assert (0, "u0", "u1") in work and (1, "u2", "u1") in work
    # applying the work restores factor everywhere
    for i, _src, tgt in work:
        held[tgt].add(i)
    assert m.rebuild_targets(("u0", "u1", "u2", "u3"), held=held) == []
    # a fully lost range yields no work (nothing to copy from): range 0's
    # holders are u0+u1, both dead here
    lost = m.rebuild_targets(("u2", "u3"))
    assert all(i != 0 for i, _s, _t in lost)
    with pytest.raises(ValueError):
        ReplicaMap((1, 1), ("a", "b"), factor=3)
    with pytest.raises(ValueError):
        ReplicaMap((1,), ("a", "b"))


# ---------------------------------------------------------------------------
# host-orchestrated fault-tolerant search
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def corpus():
    rng = np.random.default_rng(0)
    counts = [300, 512, 11, 201]
    N = sum(counts)
    codes = rng.integers(0, 2 ** 32, (N, 2), dtype=np.uint32)
    q = rng.integers(0, 2 ** 32, (5, 2), dtype=np.uint32)
    return codes, q, counts, N


def _fts(codes, counts, **kw):
    from repro.dist.search import FaultTolerantSearch
    return FaultTolerantSearch(codes, 64, counts=counts, **kw)


def test_fts_healthy_equals_reference(corpus):
    from repro.dist.search import reference_over_covered
    codes, q, counts, N = corpus
    fts = _fts(codes, counts)
    dd, ii, rep = fts.search(q, 16)
    rd, ri = reference_over_covered(codes, q, 16, 64, np.arange(N))
    assert np.array_equal(dd, rd) and np.array_equal(ii, ri)
    assert rep.complete and rep.coverage_frac == 1.0


@pytest.mark.parametrize("dead", [0, 1, 2, 3])
def test_fts_single_dead_is_degraded_but_exact(corpus, dead):
    from repro.dist.search import reference_over_covered
    codes, q, counts, N = corpus
    bounds = np.cumsum([0] + counts)
    for k in (16, 1200):           # 1200 > every survivor total
        fts = _fts(codes, counts)
        fts.kill(f"unit{dead}")
        dd, ii, rep = fts.search(q, k)
        m = np.concatenate([np.arange(bounds[i], bounds[i + 1])
                            for i in range(4) if i != dead])
        rd, ri = reference_over_covered(codes, q, k, 64, m)
        assert np.array_equal(dd, rd), (dead, k)
        assert np.array_equal(ii, ri), (dead, k)
        assert rep.covered_rows == N - counts[dead]
        assert rep.dead_shards == (f"unit{dead}",)
        assert np.isclose(rep.coverage_frac, (N - counts[dead]) / N)


def test_fts_replica_keeps_full_coverage(corpus):
    from repro.dist.search import reference_over_covered
    codes, q, counts, N = corpus
    fts = _fts(codes, counts, factor=2)
    fts.kill("unit1")
    dd, ii, rep = fts.search(q, 16)
    rd, ri = reference_over_covered(codes, q, 16, 64, np.arange(N))
    assert np.array_equal(dd, rd) and np.array_equal(ii, ri)
    assert rep.coverage_frac == 1.0 and rep.dead_shards == ("unit1",)


def test_fts_rereplication_restores_coverage(corpus):
    """R=2, both holders of range 1 die -> degraded-but-exact; a warm
    revive + maintain() returns coverage to exactly 1.0."""
    from repro.dist.search import reference_over_covered
    codes, q, counts, N = corpus
    bounds = np.cumsum([0] + counts)
    fts = _fts(codes, counts, factor=2)
    fts.kill("unit1"); fts.kill("unit2")
    dd, ii, rep = fts.search(q, 16)
    m = np.concatenate([np.arange(bounds[i], bounds[i + 1])
                        for i in (0, 2, 3)])   # range 2 survives via unit3
    rd, ri = reference_over_covered(codes, q, 16, 64, m)
    assert np.array_equal(dd, rd) and np.array_equal(ii, ri)
    assert rep.covered_rows == N - counts[1]
    fts.revive("unit1", with_data=True)
    out = fts.maintain()
    assert fts.registry.state("unit1") == HEALTHY
    assert out["recovered"] == ["unit1"]
    assert fts.coverage().coverage_frac == 1.0
    dd, ii, rep = fts.search(q, 16)
    rd, ri = reference_over_covered(codes, q, 16, 64, np.arange(N))
    assert np.array_equal(dd, rd) and np.array_equal(ii, ri)
    assert rep.coverage_frac == 1.0


def test_fts_cold_revive_refills_from_replicas(corpus):
    codes, q, counts, N = corpus
    fts = _fts(codes, counts, factor=2)
    fts.kill("unit1")
    assert fts.coverage().coverage_frac == 1.0    # replica holds range 1
    fts.revive("unit1", with_data=False)          # disk gone
    out = fts.maintain()
    assert out["copied"] >= 2 and fts.registry.state("unit1") == HEALTHY
    assert fts.coverage().coverage_frac == 1.0
    assert set(fts.covered_ranges()) == {0, 1, 2, 3}


def test_fts_injected_faults_drive_failover(corpus):
    from repro.dist.search import reference_over_covered
    from repro.runtime import faults
    codes, q, counts, N = corpus
    inj = faults.FaultInjector(seed=1, p={"shard_hist@unit0": 1.0,
                                          "shard_emit@unit0": 1.0})
    fts = _fts(codes, counts, factor=2, injector=inj)
    dd, ii, rep = fts.search(q, 16)
    rd, ri = reference_over_covered(codes, q, 16, 64, np.arange(N))
    assert np.array_equal(dd, rd) and np.array_equal(ii, ri)
    assert rep.coverage_frac == 1.0               # replica covered it
    assert fts.registry.state("unit0") == DEAD    # driven by observations
    assert fts.counters["failovers"] >= 1
    assert inj.fired.get("shard_hist@unit0", 0) >= 1


def test_fts_merge_faults_retry_exactly(corpus):
    from repro.dist.search import reference_over_covered
    from repro.runtime import faults
    codes, q, counts, N = corpus
    inj = faults.FaultInjector(seed=2, p={"merge_psum": 0.5})
    fts = _fts(codes, counts, injector=inj)
    dd, ii, _ = fts.search(q, 16)
    rd, ri = reference_over_covered(codes, q, 16, 64, np.arange(N))
    assert np.array_equal(dd, rd) and np.array_equal(ii, ri)
    assert sum(v for s, v in inj.calls.items()
               if s.startswith("merge_psum")) >= 2


def test_fts_all_dead_and_zero_k_edges(corpus):
    codes, q, counts, N = corpus
    fts = _fts(codes, counts)
    for u in fts.map.units:
        fts.kill(u)
    dd, ii, rep = fts.search(q, 7)
    assert (dd == 65).all() and (ii == N).all()
    assert rep.covered_rows == 0 and rep.coverage_frac == 0.0
    assert len(rep.dead_shards) == 4
