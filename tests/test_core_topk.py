"""Counting-select (temporal-sort analogue) vs sorted oracle."""
import jax
import jax.numpy as jnp
import numpy as np
from _propcheck import given, settings, st

from repro.core import topk

settings.register_profile("ci", deadline=None, max_examples=30)
settings.load_profile("ci")


@given(st.integers(1, 8), st.integers(1, 400), st.integers(1, 32),
       st.integers(1, 64), st.integers(0, 2**31 - 1))
def test_counting_topk_matches_oracle(q, n, k, d_max, seed):
    rng = np.random.default_rng(seed)
    dist = jnp.asarray(rng.integers(0, d_max + 1, size=(q, n)), jnp.int32)
    rd, ri = topk.topk_ref(dist, min(k, n))
    for fn in (topk.counting_topk, topk.counting_topk_bisect,
               topk.composite_topk):
        cd, ci = fn(dist, min(k, n), d_max)
        assert (rd == cd[:, :min(k, n)]).all(), fn.__name__
        # identical tie-break (index order) across all three selects
        assert (ri == ci[:, :min(k, n)]).all(), fn.__name__


@given(st.integers(1, 4), st.integers(2, 50), st.integers(1, 10),
       st.integers(0, 2**31 - 1))
def test_merge_is_topk_of_union(q, n, k, seed):
    rng = np.random.default_rng(seed)
    d_max = 64
    d1 = jnp.asarray(rng.integers(0, d_max, (q, n)), jnp.int32)
    d2 = jnp.asarray(rng.integers(0, d_max, (q, n)), jnp.int32)
    a_d, a_i = topk.counting_topk(d1, min(k, n), d_max)
    b_d, b_i = topk.counting_topk(d2, min(k, n), d_max)
    md, _ = topk.merge_topk(a_d, a_i, b_d, b_i + n, min(k, n))
    full = jnp.concatenate([d1, d2], axis=1)
    fd, _ = topk.topk_ref(full, min(k, n))
    assert (md == fd).all()


def test_counting_topk_k_larger_than_n():
    dist = jnp.asarray([[3, 1, 2]], jnp.int32)
    cd, ci = topk.counting_topk(dist, 5, 8)
    assert list(cd[0][:3]) == [1, 2, 3]
    assert (cd[0][3:] == 9).all()                # sentinel d_max+1
    assert (ci[0][3:] == 3).all()                # sentinel id n


def test_bucketed_topk_recovers_exact_when_separated():
    vals = jnp.asarray(np.random.default_rng(0).normal(size=(4, 256)), jnp.float32)
    bv, bi = topk.bucketed_topk(vals, 4, n_bins=4096)
    tv, ti = jax.lax.top_k(vals, 4)
    assert (bi == ti).all()
    np.testing.assert_allclose(np.asarray(bv), np.asarray(tv), rtol=1e-6)
