"""Decode == full forward (f32), per-slot active masks, state continuation."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_config, scaled_down
from repro.models import frontends, lm

DECODE_ARCHS = ["gemma-2b", "deepseek-67b", "zamba2-2.7b", "rwkv6-1.6b",
                "kimi-k2-1t-a32b", "arctic-480b", "musicgen-medium",
                "llava-next-mistral-7b", "internlm2-20b", "granite-20b"]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_decode_matches_forward_f32(arch):
    cfg = dataclasses.replace(scaled_down(get_config(arch)), dtype="float32")
    key = jax.random.PRNGKey(0)
    params = lm.init_params(key, cfg)
    B, S = 2, 33
    tokens = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)
    pre = frontends.synthetic_prefix(cfg, B) if cfg.frontend != "none" else None
    full_logits, _ = lm.forward(params, cfg, tokens, pre)
    logits_p, state = lm.prefill(params, cfg, tokens[:, :S], pre)
    state = lm.pad_decode_state(cfg, state, S + 8 + cfg.frontend_positions)
    dec_logits, state2 = lm.decode_step(params, cfg, tokens[:, S:S + 1], state)
    err = float(jnp.max(jnp.abs(full_logits[:, -1] - dec_logits[:, 0])))
    assert err < 1e-3, err
    assert (np.asarray(state2["pos"]) == S + 1 + cfg.frontend_positions).all()


def test_active_mask_freezes_inactive_rows():
    cfg = dataclasses.replace(scaled_down(get_config("gemma-2b")), dtype="float32")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    B = 3
    state = lm.init_decode_state(cfg, B, 16)
    tok = jnp.asarray([[1], [2], [3]], jnp.int32)
    active = jnp.asarray([True, False, True])
    _, new_state = lm.decode_step(params, cfg, tok, state, active=active)
    assert list(np.asarray(new_state["pos"])) == [1, 0, 1]
    # inactive row's cache slot 0 untouched (still zeros)
    k = np.asarray(new_state["cache"].k)
    assert np.abs(k[:, 1, 0]).sum() == 0.0          # row 1 wrote nothing
    assert np.abs(k[:, 0, 0]).sum() > 0.0           # row 0 wrote


def test_incremental_decode_matches_prefill():
    """Decoding a sequence token-by-token == prefilling it whole (f32)."""
    cfg = dataclasses.replace(scaled_down(get_config("rwkv6-1.6b")), dtype="float32")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    B, S = 1, 12
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    full_logits, _ = lm.forward(params, cfg, tokens)
    state = lm.init_decode_state(cfg, B, S + 2)
    outs = []
    for t in range(S):
        lg, state = lm.decode_step(params, cfg, tokens[:, t:t + 1], state)
        outs.append(lg[:, 0])
    err = float(jnp.max(jnp.abs(jnp.stack(outs, 1) - full_logits)))
    assert err < 1e-3, err
