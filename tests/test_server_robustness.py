"""Serving hardening: admission control, deadlines, degradation ladder,
fault-injected soak. The pinned acceptance run is
``test_soak_with_faults_no_lost_requests`` — 500 ticks, search + checkpoint
save failures at p=0.05, every request terminates, recovery to the exact
plan after load drops."""
import dataclasses
import tempfile

import numpy as np
import pytest

import jax

from repro import compat
from repro.configs import get_config, scaled_down
from repro.core import retrieval
from repro.models import lm
from repro.runtime import faults as faults_mod, server as server_mod


@pytest.fixture(scope="module")
def env():
    cfg = scaled_down(get_config("gemma-2b"), d_model=64, d_ff=128,
                      vocab_size=256)
    cfg = dataclasses.replace(cfg, retrieval=dataclasses.replace(
        cfg.retrieval, datastore_size=512, code_bits=64, k=8, chunk_size=512))
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    store = retrieval.synthetic_datastore(cfg)
    return cfg, mesh, params, store


def _req(uid, rng, vocab, n_new=6, deadline=None, plen=None):
    plen = int(rng.integers(1, 4)) if plen is None else plen
    return server_mod.Request(
        uid=uid, prompt=rng.integers(0, vocab, plen).astype(np.int32),
        max_new_tokens=n_new, deadline_ticks=deadline)


def _drain(srv, guard):
    while srv.has_work and srv.ticks < guard:
        srv.tick()


# ---------------------------------------------------------------------------
# the pinned soak (acceptance criterion)
# ---------------------------------------------------------------------------

def test_soak_with_faults_no_lost_requests(env):
    cfg, mesh, params, store = env
    inj = faults_mod.FaultInjector(
        seed=7, p={"store_search": 0.05, "ckpt_save": 0.05,
                   "ckpt_restore": 0.05})
    with tempfile.TemporaryDirectory() as tmp:
        srv = server_mod.Server(
            cfg, mesh, params, max_batch=4, max_len=24, store=store,
            max_queue=6, default_deadline_ticks=50,
            degradation=server_mod.DegradationPolicy(
                queue_high=3, queue_low=1, cooldown_ticks=4),
            fault_injector=inj, snapshot_dir=tmp, snapshot_every=10)
        rng = np.random.default_rng(11)
        uid = 0
        saw_degraded_under_load = False
        for t in range(500):
            # overload for the first 300 ticks, then a light trickle so the
            # policy has live ticks to recover through
            rate = 2.0 if t < 300 else 0.1
            for _ in range(rng.poisson(rate)):
                srv.submit(_req(uid, rng, cfg.vocab_size))
                uid += 1
            srv.tick()
            if t < 300 and srv.rung > 0:
                saw_degraded_under_load = True
        _drain(srv, guard=800)          # bounded: deadlines forbid deadlock

        s = srv.stats()
        # no lost requests: done + shed + timed_out == submitted
        assert s["lost"] == 0, s
        assert s["in_flight"] == 0, s
        assert s["submitted"] == s["done"] + s["shed"] + s["timed_out"]
        assert s["submitted"] > 100
        # overload actually degraded the plan, and pressure-clear recovered
        # it back to the full exact rung
        assert saw_degraded_under_load
        assert s["degraded_ticks"] > 0
        assert s["transitions"] >= 2
        assert s["rung"] == "exact"
        # the injector really exercised the search + checkpoint-save paths
        assert inj.fired.get("store_search", 0) > 0
        assert inj.calls.get("ckpt_save", 0) > 0
        assert s["search_retries"] > 0


# ---------------------------------------------------------------------------
# degradation ladder
# ---------------------------------------------------------------------------

def test_degradation_policy_walks_one_rung_per_tick():
    pol = server_mod.DegradationPolicy(queue_high=4, queue_low=1,
                                       cooldown_ticks=3)
    r = 0
    r = pol.update(r, 4, queue_depth=10, tick_s=0.01)
    assert r == 1                       # pressure: one rung down
    r = pol.update(r, 4, queue_depth=10, tick_s=0.01)
    assert r == 2                       # still pressured
    r = pol.update(r, 4, queue_depth=2, tick_s=0.01)
    assert r == 2                       # neither pressured nor calm: hold
    for _ in range(2):
        r = pol.update(r, 4, queue_depth=0, tick_s=0.01)
        assert r == 2                   # calm but inside cooldown
    r = pol.update(r, 4, queue_depth=0, tick_s=0.01)
    assert r == 1                       # cooldown satisfied: one rung up
    r = pol.update(r, 4, queue_depth=10, tick_s=0.01)
    assert r == 2                       # relapse resets the climb


def test_latency_ewma_pressure_triggers_downshift():
    pol = server_mod.DegradationPolicy(queue_high=100, tick_high_s=0.01,
                                       alpha=1.0)
    assert pol.update(0, 3, queue_depth=0, tick_s=0.5) == 1
    assert pol.ewma_s == 0.5


def test_ladder_has_probe_rungs_and_serves_through_them(env):
    cfg, mesh, params, _ = env
    cfg2 = dataclasses.replace(cfg, retrieval=dataclasses.replace(
        cfg.retrieval, layout="hamming_prefix", layout_buckets=16))
    store2 = retrieval.synthetic_datastore(cfg2)
    srv = server_mod.Server(
        cfg2, mesh, params, max_batch=2, max_len=16, store=store2,
        degradation=server_mod.DegradationPolicy(queue_high=2, queue_low=0,
                                                 cooldown_ticks=2))
    names = [r.name for r in srv.rungs]
    assert names[0] == "exact" and names[-1] == "retrieval_off"
    assert any(n.startswith("probe") for n in names), names

    rng = np.random.default_rng(3)
    for uid in range(8):                # burst >> capacity: forces descent
        srv.submit(_req(uid, rng, cfg2.vocab_size, n_new=3))
    _drain(srv, guard=120)
    s = srv.stats()
    assert s["lost"] == 0 and s["in_flight"] == 0
    visited = {t[2] for t in srv.transitions}
    assert any(n.startswith("probe") for n in visited), srv.transitions
    # every transition re-logged an active plan (recorded via transitions
    # list); recovery: feed calm ticks until the ladder climbs back
    uid = 100
    while srv.rung != 0 and srv.ticks < 400:
        if not srv.has_work:
            srv.submit(_req(uid, rng, cfg2.vocab_size, n_new=2))
            uid += 1
        srv.tick()
    assert srv.rung == 0, srv.transitions


def test_top_rung_bit_identical_to_unhardened_server(env):
    cfg, mesh, params, store = env
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab_size, 3).astype(np.int32)
               for _ in range(3)]

    def serve(hardened):
        kw = {}
        if hardened:
            kw = dict(max_queue=16, default_deadline_ticks=500,
                      degradation=server_mod.DegradationPolicy(
                          queue_high=10**6),   # never pressured
                      fault_injector=faults_mod.FaultInjector(seed=0, p={}))
        srv = server_mod.Server(cfg, mesh, params, max_batch=2, max_len=16,
                                store=store, **kw)
        for uid, pr in enumerate(prompts):
            srv.submit(server_mod.Request(uid=uid, prompt=pr.copy(),
                                          max_new_tokens=5))
        srv.run(max_ticks=100)
        return {r.uid: r.out_tokens for r in srv.done}

    assert serve(False) == serve(True)


# ---------------------------------------------------------------------------
# admission control: shed, deadline, capacity, empty prompt
# ---------------------------------------------------------------------------

def test_bounded_queue_sheds_beyond_capacity(env):
    cfg, mesh, params, store = env
    srv = server_mod.Server(cfg, mesh, params, max_batch=1, max_len=16,
                            store=store, max_queue=2)
    rng = np.random.default_rng(0)
    accepted = [srv.submit(_req(u, rng, cfg.vocab_size, n_new=2))
                for u in range(5)]
    assert accepted == [True, True, False, False, False]
    assert all(r.status == "shed" and r.finish_reason == "queue_full"
               for r in srv.shed)
    _drain(srv, guard=60)
    s = srv.stats()
    assert s["shed"] == 3 and s["done"] == 2 and s["lost"] == 0


def test_deadline_evicts_queued_and_active_requests(env):
    cfg, mesh, params, store = env
    srv = server_mod.Server(cfg, mesh, params, max_batch=1, max_len=30,
                            store=store)
    rng = np.random.default_rng(1)
    hog = _req(0, rng, cfg.vocab_size, n_new=25)       # occupies the slot
    starved = _req(1, rng, cfg.vocab_size, n_new=2, deadline=4)
    slow = _req(2, rng, cfg.vocab_size, n_new=25, deadline=8)
    srv.submit(hog), srv.submit(starved), srv.submit(slow)
    _drain(srv, guard=100)
    assert starved.status == "timed_out"      # died waiting in the queue
    assert slow.status == "timed_out"         # evicted from its slot
    assert slow.finish_reason == "deadline"
    assert hog.status == "done"
    assert srv.stats()["lost"] == 0


def test_capacity_eviction_retires_and_reuses_slot(env):
    cfg, mesh, params, store = env
    max_len, plen = 12, 4
    srv = server_mod.Server(cfg, mesh, params, max_batch=1, max_len=max_len,
                            store=store)
    rng = np.random.default_rng(2)
    capped = _req(0, rng, cfg.vocab_size, n_new=100, plen=plen)
    follower = _req(1, rng, cfg.vocab_size, n_new=2, plen=1)
    srv.submit(capped), srv.submit(follower)
    _drain(srv, guard=60)
    # the pos < max_len - 1 guard retires the runaway request with exactly
    # the tokens decoded before the cache filled
    assert capped.status == "done" and capped.finish_reason == "capacity"
    assert len(capped.out_tokens) == max_len - 1 - plen
    # and its slot was reused: the follower completed in the same slot pool
    assert follower.status == "done" and follower.finish_reason == "complete"
    assert len(follower.out_tokens) == 2


def test_empty_prompt_admitted_via_bos_fallback(env):
    cfg, mesh, params, store = env
    srv = server_mod.Server(cfg, mesh, params, max_batch=1, max_len=16,
                            store=store)
    req = server_mod.Request(uid=0, prompt=np.zeros((0,), np.int32),
                             max_new_tokens=3)
    srv.submit(req)
    _drain(srv, guard=30)
    assert req.status == "done"
    assert len(req.out_tokens) == 3


# ---------------------------------------------------------------------------
# faults: injector, retry, snapshot fallback
# ---------------------------------------------------------------------------

def test_retry_call_retries_then_succeeds_and_reraises():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise faults_mod.InjectedFault("x")
        return "ok"

    slept = []
    assert faults_mod.retry_call(flaky, retries=3, backoff_s=0.01,
                                 sleep=slept.append, rng=0) == "ok"
    assert calls["n"] == 3 and len(slept) == 2
    # full-jitter default: each delay draws inside the doubling envelope
    # (the exact envelope/cap contract is pinned in tests/test_faults.py)
    assert 0.0 <= slept[0] <= 0.01 and 0.0 <= slept[1] <= 0.02

    with pytest.raises(faults_mod.InjectedFault):
        faults_mod.retry_call(lambda: (_ for _ in ()).throw(
            faults_mod.InjectedFault("y")), retries=1, sleep=lambda _: None)


def test_injector_is_seeded_and_counts():
    a = faults_mod.FaultInjector(seed=4, p={"s": 0.5})
    b = faults_mod.FaultInjector(seed=4, p={"s": 0.5})

    def trace(inj):
        out = []
        for _ in range(50):
            try:
                inj.check("s")
                out.append(0)
            except faults_mod.InjectedFault:
                out.append(1)
        return out

    ta = trace(a)
    assert ta == trace(b)               # same seed, same fault sequence
    assert a.fired["s"] == sum(ta) and a.calls["s"] == 50


def test_search_fault_falls_over_and_recovers(env):
    cfg, mesh, params, store = env
    # p=1 on the search site: every retrieval attempt fails, so each tick
    # must fail over to retrieval-off decode; requests still finish
    inj = faults_mod.FaultInjector(seed=0, p={"store_search": 1.0})
    srv = server_mod.Server(
        cfg, mesh, params, max_batch=1, max_len=16, store=store,
        degradation=server_mod.DegradationPolicy(queue_high=10**6,
                                                 cooldown_ticks=1),
        fault_injector=inj, search_retries=1)
    rng = np.random.default_rng(6)
    req = _req(0, rng, cfg.vocab_size, n_new=3)
    srv.submit(req)
    _drain(srv, guard=40)
    assert req.status == "done"
    s = srv.stats()
    assert s["failover_ticks"] > 0 and s["search_failures"] > 0
    assert s["lost"] == 0
    # the failover transition was logged
    assert any(t[2] == "retrieval_off" for t in srv.transitions)
    # once the fault clears, calm ticks walk back to the exact plan
    inj.p["store_search"] = 0.0
    uid = 1
    while srv.rung != 0 and srv.ticks < 200:
        if not srv.has_work:
            srv.submit(_req(uid, rng, cfg.vocab_size, n_new=2))
            uid += 1
        srv.tick()
    assert srv.rung == 0


class _OneShotFault(faults_mod.FaultInjector):
    """Raises exactly once, on the first check of ``site`` — deterministic
    trigger for the snapshot-restore path."""

    def __init__(self, site):
        super().__init__(seed=0, p={})
        self._site = site

    def check(self, s):
        super().check(s)            # keeps the call counters honest
        if s == self._site and self.calls[s] == 1:
            self.fired[s] = self.fired.get(s, 0) + 1
            raise faults_mod.InjectedFault(s)


def test_snapshot_restore_fallback(env):
    cfg, mesh, params, store = env
    with tempfile.TemporaryDirectory() as tmp:
        inj = _OneShotFault("store_search")
        srv = server_mod.Server(cfg, mesh, params, max_batch=1, max_len=16,
                                store=store, fault_injector=inj,
                                search_retries=0, snapshot_dir=tmp)
        # last-good snapshot was written at startup
        assert srv.counters["snapshot_saves"] == 1
        rng = np.random.default_rng(8)
        req = _req(0, rng, cfg.vocab_size, n_new=2)
        srv.submit(req)
        _drain(srv, guard=30)
        # the single fault exhausted retries (retries=0), restored the
        # store from the snapshot, and completed the step at the SAME rung
        # — no retrieval-off failover transition
        assert srv.counters["snapshot_restores"] == 1
        assert srv.counters["failover_ticks"] == 0
        assert srv.transitions == []
        assert req.status == "done"
        assert srv.stats()["lost"] == 0


def test_stats_percentiles_present(env):
    cfg, mesh, params, store = env
    srv = server_mod.Server(cfg, mesh, params, max_batch=2, max_len=16,
                            store=store)
    rng = np.random.default_rng(9)
    for uid in range(3):
        srv.submit(_req(uid, rng, cfg.vocab_size, n_new=2))
    _drain(srv, guard=50)
    s = srv.stats()
    assert s["p50_token_s"] > 0 and s["p99_token_s"] >= s["p50_token_s"]
    assert s["p99_queue_ticks"] >= s["p50_queue_ticks"] >= 0
    assert s["done"] == 3 and s["lost"] == 0
