"""Layout subsystem (core/layout.py) + block-masked probing through the
fused kernels: permutation round-trips, full-scan equivalence, masked-probe
bit-identity vs the gather reference, and the two pruning pins of this PR —
nonzero pass-2 pruning on REORDERED UNIFORM data, and >= 50% of pass-1
blocks skipped by a masked IVF probe at nprobe < n_clusters."""
import numpy as np

import jax
import jax.numpy as jnp

from repro.core import binary, engine, index, layout, topk
from repro.core.index import _scan_candidates
from repro.kernels import ops, tuning


def _uniform(seed, n, q, d):
    rng = np.random.default_rng(seed)
    xb = rng.integers(0, 2, (n, d)).astype(np.uint8)
    qb = rng.integers(0, 2, (q, d)).astype(np.uint8)
    return jnp.asarray(xb), jnp.asarray(qb)


def _query_cluster(rng, q, d, flip=0.03):
    """Locality-coherent query batch (decode-time batches are consecutive
    hidden states): q perturbations of one point."""
    c = rng.integers(0, 2, d)
    return jnp.asarray((c[None] ^ (rng.random((q, d)) < flip)).astype(np.uint8))


# ---------------------------------------------------------------------------
# layout invariants
# ---------------------------------------------------------------------------

def test_permutation_roundtrip_and_bucket_contiguity():
    xb, _ = _uniform(0, 1000, 1, 64)
    xp = binary.pack_bits(xb)
    assign, _ = layout.hamming_prefix_assign(xp, 64, 4)
    lay = layout.reorder_by_assignment(xp, assign, 16)
    n = 1000
    assert (lay.perm[lay.inv] == jnp.arange(n)).all()
    assert (lay.inv[lay.perm] == jnp.arange(n)).all()
    assert (lay.codes == xp[lay.perm]).all()
    assert int(lay.starts[0]) == 0 and int(lay.starts[-1]) == n
    # bucket b's contiguous range holds exactly the rows assigned to b
    a = np.asarray(assign)[np.asarray(lay.perm)]
    starts = np.asarray(lay.starts)
    for b in range(16):
        assert (a[starts[b]:starts[b + 1]] == b).all()
    # stable within buckets: original ids ascend
    perm = np.asarray(lay.perm)
    for b in range(16):
        seg = perm[starts[b]:starts[b + 1]]
        assert (np.diff(seg) > 0).all()


def test_prefix_assign_positions_reusable():
    """Queries keyed with the datastore's positions land in comparable
    buckets; a second call with explicit positions is deterministic."""
    xb, qb = _uniform(1, 512, 8, 64)
    xp, qp = binary.pack_bits(xb), binary.pack_bits(qb)
    a1, pos = layout.hamming_prefix_assign(xp, 64, 5)
    a2, pos2 = layout.hamming_prefix_assign(xp, 64, 5, pos)
    assert (a1 == a2).all() and (pos == pos2).all()
    aq, _ = layout.hamming_prefix_assign(qp, 64, 5, pos)
    assert int(aq.max()) < 32 and int(aq.min()) >= 0


# ---------------------------------------------------------------------------
# full-scan equivalence through the engine
# ---------------------------------------------------------------------------

def test_full_scan_layout_bit_identical_at_k_equals_n():
    """k = N: both layouts return ALL rows, so after the composite
    (dist, id) re-sort the reordered engine is bit-identical to the
    unreordered fused select — no tie freedom left."""
    n, q, d = 200, 6, 64
    xb, qb = _uniform(2, n, q, d)
    xp, qp = binary.pack_bits(xb), binary.pack_bits(qb)
    plain = engine.KNNEngine(codes=xp, d=d)
    eng = plain.with_layout(n_buckets=8)
    ad, ai = plain.search(qp, n, select="fused")
    ld, li = eng.search(qp, n, select="fused")

    def canon(dd, ii):
        key = dd * (n + 1) + ii
        return jnp.sort(key, axis=-1)

    assert (canon(ad, ai) == canon(ld, li)).all()


def test_full_scan_layout_distances_and_strict_winners():
    """k < N: the top-k DISTANCE vector is layout-invariant bit-for-bit;
    strict winners (dist < r*) are a uniquely-determined id set; every
    returned id really has its reported distance. (Which r*-ties fill the
    last slots is scan-order freedom, same as any candidate-list scan.)"""
    n, q, d, k = 3000, 8, 128, 10
    xb, qb = _uniform(3, n, q, d)
    xp, qp = binary.pack_bits(xb), binary.pack_bits(qb)
    eng = engine.KNNEngine(codes=xp, d=d).with_layout()
    cd, ci = topk.counting_topk(binary.hamming_ref(qb, xb), k, d)
    ld, li = eng.search(qp, k, select="fused")
    assert (ld == cd).all()
    ref = np.asarray(binary.hamming_ref(qb, xb))
    got = ref[np.arange(q)[:, None], np.asarray(li)]
    assert (got == np.asarray(ld)).all()
    for r in range(q):
        r_star = int(cd[r, k - 1])
        want = set(np.asarray(ci[r])[np.asarray(cd[r]) < r_star].tolist())
        have = set(np.asarray(li[r])[np.asarray(ld[r]) < r_star].tolist())
        assert want == have


# ---------------------------------------------------------------------------
# masked probing: bit-identical to the gather reference over enabled rows
# ---------------------------------------------------------------------------

def _mask_reference(lay, qp, probe, k, d):
    """The gather-path reference on the EXACT candidate set the mask
    enables, in the exact (layout-position) scan order: _scan_candidates
    then breaks ties identically, so the comparison is bit-for-bit."""
    q, W = qp.shape
    n = lay.n
    lanes = max(d + 1, min(k, n))
    bq, bn, sub = tuning.layout_blocks(q, n, W, lanes, lay.mean_bucket_rows)
    bq, bn, sub, qpad, npad = ops.topk_geometry(q, n, W, lanes, bq, bn, sub)
    mask = np.asarray(layout.probe_block_mask(lay, probe, bq, bn,
                                              qpad // bq, npad // bn))
    perm = np.asarray(lay.perm)
    cap = max(1, max(int(m.sum()) for m in mask) * bn)
    cand = np.full((q, cap), -1, np.int32)
    for r in range(q):
        pos = layout.enabled_positions(lay, mask[r // bq], bn)
        cand[r, :pos.size] = perm[pos]
    # lay.codes[inv] reconstructs the original code order
    return _scan_candidates(lay.codes[lay.inv], qp, jnp.asarray(cand), k, d)


def test_masked_probe_bit_identical_to_gather_reference():
    rng = np.random.default_rng(4)
    d, n, q, k = 64, 4096, 8, 10
    xb = jnp.asarray(rng.integers(0, 2, (n, d)).astype(np.uint8))
    qb = jnp.asarray(rng.integers(0, 2, (q, d)).astype(np.uint8))
    xp, qp = binary.pack_bits(xb), binary.pack_bits(qb)
    lay = layout.build_layout(xp, d)
    bits = (lay.n_buckets - 1).bit_length()
    _, pos = layout.hamming_prefix_assign(xp, d, bits)
    aq, _ = layout.hamming_prefix_assign(qp, d, bits, pos)
    probe = jnp.stack([aq, (aq + 3) % lay.n_buckets], axis=1)
    md, mi = layout.masked_topk(lay, qp, k, d, probe=probe)
    rd, ri = _mask_reference(lay, qp, probe, k, d)
    assert (md == rd).all() and (mi == ri).all()


def test_masked_probe_empty_candidates_sentinels():
    """A query whose probed buckets are all empty gets (d+1, -1) rows."""
    xb, qb = _uniform(5, 256, 4, 64)
    xp, qp = binary.pack_bits(xb), binary.pack_bits(qb)
    assign = jnp.zeros((256,), jnp.int32)       # everything in bucket 0
    lay = layout.reorder_by_assignment(xp, assign, 4)
    probe = jnp.full((4, 1), 2, jnp.int32)      # bucket 2 is empty
    dd, ii = layout.masked_topk(lay, qp, 5, 64, probe=probe)
    assert (dd == 65).all() and (ii == -1).all()


# ---------------------------------------------------------------------------
# the two acceptance pins
# ---------------------------------------------------------------------------

def test_reordered_uniform_prunes_pass2():
    """UNIFORM random codes, locality-coherent query batch: the
    bucket-clustered reorder makes pass-2 block-min pruning bite on a full
    fused scan (no mask), and strictly beats the unordered layout on the
    same inputs — the 'universal win' this PR exists for."""
    rng = np.random.default_rng(0)
    d, n, k = 128, 1 << 14, 16
    xb = jnp.asarray(rng.integers(0, 2, (n, d)).astype(np.uint8))
    qp = binary.pack_bits(_query_cluster(rng, 8, d))
    xp = binary.pack_bits(xb)
    geom = dict(bq=8, bn=512, sub=256)

    _, _, s0 = ops.hamming_topk(qp, xp, k, d + 1, return_stats=True, **geom)
    f0 = float(s0["blocks_skipped"]) / s0["blocks_total"]
    lay = layout.build_layout(xp, d, n_buckets=16)
    fd, fi, s1 = ops.hamming_topk(qp, lay.codes, k, d + 1, return_stats=True,
                                  **geom)
    f1 = float(s1["blocks_skipped"]) / s1["blocks_total"]
    assert f1 > 0, "reordered uniform data must prune"
    assert f1 >= 0.1, f"pruned only {f1:.3f}"
    # seed-0 values: unordered 0.031, reordered 0.156 — a 5x lift
    assert f1 >= f0 + 0.05, f"reorder must beat unordered ({f1:.3f} vs {f0:.3f})"
    # and stays exact: distance vector matches the oracle
    cd, _ = topk.counting_topk(
        binary.hamming_ref(binary.unpack_bits(qp, d), xb), k, d)
    assert (fd == cd).all()


def test_masked_ivf_probe_skips_half_pass1_blocks():
    """k-means index, nprobe < n_clusters: the probe mask must skip >= 50%
    of PASS-1 blocks (the tiles never streamed at all), and the results
    must be bit-identical to the gather reference over the enabled rows."""
    rng = np.random.default_rng(6)
    d, n, q, k, n_clusters, nprobe = 64, 1 << 14, 8, 10, 32, 2
    centers = rng.normal(size=(n_clusters, d)) * 4
    which = rng.integers(0, n_clusters, n)
    x = (centers[which] + rng.normal(size=(n, d))).astype(np.float32)
    xb = jnp.asarray((x > 0).astype(np.uint8))
    xp = binary.pack_bits(xb)
    # queries from two generator clusters: realistic locality, probes overlap
    qsel = np.flatnonzero(which < 2)[:q]
    queries = jnp.asarray(x[qsel])
    qp = binary.pack_bits(xb[qsel])

    km = index.kmeans_build(jnp.asarray(x), xp, d, n_clusters, iters=8)
    assert km.layout is not None
    dd, ids, stats = index.kmeans_search(km, queries, qp, k, nprobe=nprobe,
                                         return_stats=True)
    frac1 = float(stats["p1_blocks_skipped"]) / stats["blocks_total"]
    assert frac1 >= 0.5, f"pass 1 skipped only {frac1:.3f}"
    # pass 2 skips at least as much (mask composes with block-min)
    assert float(stats["blocks_skipped"]) >= float(stats["p1_blocks_skipped"])

    # bit-identical to the gather-path reference on the probed candidate set
    qf = queries.astype(jnp.float32)
    cent = km.centroids
    d2 = (jnp.sum(qf**2, 1)[:, None] - 2 * qf @ cent.T
          + jnp.sum(cent**2, 1)[None])
    _, probe = jax.lax.top_k(-d2, nprobe)
    rd, ri = _mask_reference(km.layout, qp, probe, k, d)
    assert (dd == rd).all() and (ids == ri).all()


def test_nprobe_equals_all_recovers_exact_distances():
    """Probing every cluster through the mask == the exact full scan."""
    rng = np.random.default_rng(7)
    d, n, q, k = 64, 2048, 8, 10
    xb = jnp.asarray(rng.integers(0, 2, (n, d)).astype(np.uint8))
    xp = binary.pack_bits(xb)
    qb = jnp.asarray(rng.integers(0, 2, (q, d)).astype(np.uint8))
    qp = binary.pack_bits(qb)
    lay = layout.build_layout(xp, d, n_buckets=8)
    probe = jnp.broadcast_to(jnp.arange(8, dtype=jnp.int32), (q, 8))
    md, mi = layout.masked_topk(lay, qp, k, d, probe=probe)
    cd, _ = topk.counting_topk(binary.hamming_ref(qb, xb), k, d)
    assert (md == cd).all()
    ref = np.asarray(binary.hamming_ref(qb, xb))
    got = ref[np.arange(q)[:, None], np.asarray(mi)]
    assert (got == np.asarray(md)).all()


# ---------------------------------------------------------------------------
# sharded per-slice reorder
# ---------------------------------------------------------------------------

def test_local_sort_is_a_permutation():
    xb, _ = _uniform(8, 777, 1, 64)
    xp = binary.pack_bits(xb)
    codes_s, perm = layout.local_sort(xp, 64)
    assert (jnp.sort(perm) == jnp.arange(777)).all()
    assert (codes_s == xp[perm]).all()


def test_sharded_reorder_local_exact(multidevice):
    """search_sharded(reorder_local=True): distances bit-identical to the
    unordered sharded fused search; every returned id's true distance
    matches its reported distance (tie-order-free exactness)."""
    multidevice("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.core import binary, engine

rng = np.random.default_rng(0)
xb = jnp.asarray(rng.integers(0, 2, (1024, 64)), jnp.uint8)
qb = jnp.asarray(rng.integers(0, 2, (8, 64)), jnp.uint8)
xp, qp = binary.pack_bits(xb), binary.pack_bits(qb)
mesh = Mesh(np.array(jax.devices()).reshape(4), ("data",))
with mesh:
    ad, ai = engine.search_sharded(xp, qp, 10, 64, mesh, ("data",),
                                   chunk=256, select="fused")
    rd, ri = engine.search_sharded(xp, qp, 10, 64, mesh, ("data",),
                                   chunk=256, select="fused",
                                   reorder_local=True)
assert (ad == rd).all()
ref = np.asarray(binary.hamming_ref(qb, xb))
got = ref[np.arange(8)[:, None], np.asarray(ri)]
assert (got == np.asarray(rd)).all()
print("OK")
""", n_devices=4)


def test_invert_permutation_scatter():
    """The O(N) scatter inverse equals the argsort inverse, and the layout
    builder's inv field is exactly it."""
    rng = np.random.default_rng(11)
    perm = jnp.asarray(rng.permutation(513), jnp.int32)
    inv = layout.invert_permutation(perm)
    assert (inv == jnp.argsort(perm)).all()
    assert (perm[inv] == jnp.arange(513)).all()
    xb, _ = _uniform(8, 300, 1, 64)
    lay = layout.build_layout(binary.pack_bits(xb), 64, n_buckets=8)
    assert (lay.inv == layout.invert_permutation(lay.perm)).all()


def test_local_sort_n_valid_pins_padding_last():
    """The distributed path's uneven-shard contract: rows at id >= n_valid
    keep positions >= n_valid after the sort (so in-kernel masking by
    position stays exact), while valid rows sort exactly like a plain
    local_sort of the valid prefix."""
    xb, _ = _uniform(9, 256, 1, 64)
    xp = binary.pack_bits(xb)
    nv = 150
    # make padding rows all-zero: they would sort FIRST if not pinned
    xp = xp.at[nv:].set(0)
    codes_s, perm = layout.local_sort(xp, 64, n_valid=nv)
    assert (jnp.sort(perm) == jnp.arange(256)).all()
    assert (perm[nv:] >= nv).all(), "padding leaked into the valid prefix"
    assert (perm[:nv] < nv).all()
    ref_codes, ref_perm = layout.local_sort(xp[:nv], 64,
                                            bits=layout.default_bits(256))
    assert (codes_s[:nv] == ref_codes).all()
    assert (perm[:nv] == ref_perm).all()


def test_position_mask_from_inv_matches_layout_mask():
    """The distributed path's per-shard mask hook: a bare
    (invert_permutation(perm), cand) pair must build exactly the mask the
    BucketLayout-keyed helper builds (same scatter, no argsort)."""
    xb, _ = _uniform(10, 1000, 6, 64)
    xp = binary.pack_bits(xb)
    lay = layout.build_layout(xp, 64, n_buckets=8)
    rng = np.random.default_rng(12)
    cand = jnp.asarray(rng.integers(-1, 1000, (6, 17)), jnp.int32)
    a = layout.position_block_mask(lay, cand, 8, 128, 1, 8)
    b = layout.position_block_mask_from_inv(
        layout.invert_permutation(lay.perm), cand, 8, 128, 1, 8)
    assert (a == b).all()


# ---------------------------------------------------------------------------
# arena / mutable-epoch edge cases (empty buckets, n_valid=0 tails,
# all-tombstoned buckets) — the fused+masked paths must stay bit-identical
# to the reference even when buckets vanish
# ---------------------------------------------------------------------------

def test_build_arena_empty_input_and_empty_buckets():
    # zero rows: a valid arena whose every bucket is pure slack
    a0 = layout.build_arena(np.zeros((0, 2), np.uint32), 64,
                            ids=np.zeros(0, np.int64), n_buckets=4)
    assert a0.n_live == 0 and a0.n_buckets == 4
    assert a0.capacity == int(np.diff(a0.cap_starts).sum())
    assert (a0.ids == -1).all() and (a0.n_used == 0).all()

    # all rows identical -> one bucket holds everything, the rest are
    # empty but still reserve min_slack capacity for future appends
    codes = np.zeros((32, 2), np.uint32)
    a = layout.build_arena(codes, 64, ids=np.arange(32, dtype=np.int64),
                           n_buckets=8, slack_frac=0.5, min_slack=4)
    key = int(layout.hamming_key_host(codes[:1], a.positions)[0])
    assert int(a.n_used[key]) == 32 and int(a.n_used.sum()) == 32
    assert (np.diff(a.cap_starts) >= 4).all()
    # the occupied segment is exactly the input, in input (id) order
    s = int(a.cap_starts[key])
    assert (a.ids[s:s + 32] == np.arange(32)).all()
    assert (a.codes[s:s + 32] == codes).all()


def test_arena_skewed_build_matches_dense_layout_scan():
    """An arena epoch with EMPTY buckets (skewed keys) searched fused must
    equal the plain unbucketed fused scan bit-for-bit at k=n (ties
    exhausted), n chosen so the padded tail gives the kernels an
    n_valid=0-style all-pad block to mask."""
    from repro.core import mutable
    rng = np.random.default_rng(40)
    d, n = 64, 210          # not a multiple of any block shape
    xb = rng.integers(0, 2, (n, d)).astype(np.uint8)
    qb = rng.integers(0, 2, (4, d)).astype(np.uint8)
    xp = np.asarray(binary.pack_bits(jnp.asarray(xb)))
    # 16 buckets over 210 uniform rows: some buckets come out tiny; the
    # padded grid tail past row 210 is an all-pad block the kernels must
    # mask via the n_valid contract
    st = mutable.MutableStore.create(xp, d, n_buckets=16)
    ep = st.flush()
    counts = np.diff(np.asarray(ep.layout.starts))
    assert counts.min() < counts.max()      # genuinely skewed buckets
    qp = binary.pack_bits(jnp.asarray(qb))
    ld, li = engine.KNNEngine.from_epoch(ep, d).search(qp, n)
    ad, ai = engine.KNNEngine(codes=ep.layout.codes, d=d).search(qp, n)
    key = ld * (n + 1) + jnp.asarray(ep.store_ids)[li]
    key_ref = ad * (n + 1) + jnp.asarray(ep.store_ids)[ai]
    assert (jnp.sort(key, -1) == jnp.sort(key_ref, -1)).all()


def test_all_tombstoned_bucket_masked_probe_bit_identical():
    """Delete EVERY row of one bucket: the installed epoch has a genuinely
    empty bucket (starts[b] == starts[b+1]); masked probes that include it
    stay bit-identical to the gather reference, and probing ONLY it yields
    pure sentinels."""
    from repro.core import mutable
    rng = np.random.default_rng(41)
    d, n, q, k = 64, 512, 4, 6
    xb = rng.integers(0, 2, (n, d)).astype(np.uint8)
    xp = np.asarray(binary.pack_bits(jnp.asarray(xb)))
    # tombstone_frac=1.0 suppresses auto-compaction so the empty bucket
    # SURVIVES into the epoch instead of being re-clustered away
    st = mutable.MutableStore.create(xp, d, n_buckets=8, tombstone_frac=1.0)
    a = st.arena
    victim = int(np.argmax(a.n_used))
    s = int(a.cap_starts[victim])
    doomed = np.sort(a.ids[s:s + int(a.n_used[victim])])
    assert doomed.size > 0
    st.delete(doomed)
    ep = st.flush()
    starts = np.asarray(ep.layout.starts)
    assert starts[victim] == starts[victim + 1], "bucket must be empty"
    assert ep.n == n - doomed.size
    st.audit()

    qb = rng.integers(0, 2, (q, d)).astype(np.uint8)
    qp = binary.pack_bits(jnp.asarray(qb))
    aq, _ = layout.hamming_prefix_assign(qp, d, 3,
                                         jnp.asarray(a.positions))
    # probe mix: the query's own bucket + the tombstoned one
    probe = jnp.stack([aq, jnp.full_like(aq, victim)], axis=1)
    md, mi = layout.masked_topk(ep.layout, qp, k, d, probe=probe)
    rd, ri = _mask_reference(ep.layout, qp, probe, k, d)
    assert (md == rd).all() and (mi == ri).all()
    # probing only the dead bucket: sentinel rows, no phantom hits
    dead = jnp.full((q, 1), victim, jnp.int32)
    dd, di = layout.masked_topk(ep.layout, qp, k, d, probe=dead)
    assert (dd == d + 1).all() and (di == -1).all()
