"""Write-ahead log framing (checkpoint/wal.py): roundtrip fidelity, torn
tails end iteration cleanly (strict mode flags them), interior corruption
is caught by the CRC, rewrite is an atomic truncation, and the fault hook
fires before any byte lands."""
import os

import pytest

from repro.checkpoint import wal
from repro.runtime import faults as faults_mod


def _fill(path, n=5):
    with wal.WriteAheadLog(path) as w:
        for i in range(n):
            w.append(wal.APPEND if i % 2 == 0 else wal.DELETE,
                     bytes([i]) * (i * 7 + 1), seq=i)


def test_roundtrip_preserves_seq_kind_payload(tmp_path):
    path = str(tmp_path / "wal.log")
    _fill(path)
    recs = list(wal.iter_records(path, strict=True))
    assert [r.seq for r in recs] == [0, 1, 2, 3, 4]
    assert [r.kind for r in recs] == [wal.APPEND, wal.DELETE] * 2 + [
        wal.APPEND]
    for i, r in enumerate(recs):
        assert r.payload == bytes([i]) * (i * 7 + 1)
    assert wal.last_seq(path) == 4
    assert [r.seq for r in wal.replay(path, after_seq=2)] == [3, 4]


def test_missing_and_empty_logs_are_clean(tmp_path):
    path = str(tmp_path / "nope.log")
    assert list(wal.iter_records(path)) == []
    assert wal.last_seq(path) == -1
    open(path, "wb").close()
    assert list(wal.iter_records(path, strict=True)) == []


def test_torn_tail_keeps_whole_prefix(tmp_path):
    path = str(tmp_path / "wal.log")
    _fill(path)
    with open(path, "r+b") as f:
        f.truncate(os.path.getsize(path) - 3)   # tear the final record
    recs = list(wal.iter_records(path))         # tolerant: clean stop
    assert [r.seq for r in recs] == [0, 1, 2, 3]
    with pytest.raises(wal.WalCorrupt):         # strict: flagged
        list(wal.iter_records(path, strict=True))
    assert wal.last_seq(path) == 3


def test_interior_corruption_caught_by_crc(tmp_path):
    path = str(tmp_path / "wal.log")
    _fill(path)
    with open(path, "r+b") as f:                # flip a byte in record 0's
        f.seek(wal._HEADER.size)                # payload
        b = f.read(1)
        f.seek(wal._HEADER.size)
        f.write(bytes([b[0] ^ 0xFF]))
    # replay must not yield the poisoned record OR anything after it
    assert list(wal.iter_records(path)) == []
    with pytest.raises(wal.WalCorrupt, match="crc"):
        list(wal.iter_records(path, strict=True))


def test_rewrite_truncates_atomically(tmp_path):
    path = str(tmp_path / "wal.log")
    _fill(path)
    wal.rewrite(path, wal.replay(path, after_seq=2))
    assert [r.seq for r in wal.iter_records(path, strict=True)] == [3, 4]
    assert not os.path.exists(path + ".tmp")
    wal.rewrite(path, [])
    assert wal.last_seq(path) == -1


# ---------------------------------------------------------------------------
# exhaustive tail-damage property: truncation or a single bit-flip at EVERY
# byte offset must replay cleanly or stop at the last valid record — never
# raise out of tolerant replay, never yield a phantom record
# ---------------------------------------------------------------------------

def _rec_bounds(n=5):
    """[start, end) byte ranges of the records _fill writes."""
    bounds, off = [], 0
    for i in range(n):
        end = off + wal._HEADER.size + (i * 7 + 1) + wal._CRC.size
        bounds.append((off, end))
        off = end
    return bounds


def _assert_prefix(recs, n_expected):
    """recs must be EXACTLY the first n_expected originals — same seq,
    kind, payload; anything else is a phantom or a lost whole record."""
    assert len(recs) == n_expected
    for i, r in enumerate(recs):
        assert r.seq == i
        assert r.kind == (wal.APPEND if i % 2 == 0 else wal.DELETE)
        assert r.payload == bytes([i]) * (i * 7 + 1)


def test_truncation_at_every_offset_stops_at_last_whole_record(tmp_path):
    path = str(tmp_path / "wal.log")
    _fill(path)
    with open(path, "rb") as f:
        data = f.read()
    bounds = _rec_bounds()
    assert bounds[-1][1] == len(data)
    for cut in range(len(data) + 1):
        with open(path, "wb") as f:
            f.write(data[:cut])
        whole = sum(1 for (_s, e) in bounds if e <= cut)
        _assert_prefix(wal.replay(path), whole)
        v = wal.verify(path)
        assert v["records"] == whole
        assert v["status"] == ("ok" if cut in (0, *[e for _s, e in bounds])
                               else "torn_tail")


def test_bit_flip_anywhere_in_tail_record_never_replays_a_phantom(tmp_path):
    path = str(tmp_path / "wal.log")
    _fill(path)
    with open(path, "rb") as f:
        data = f.read()
    start, end = _rec_bounds()[-1]
    for off in range(start, end):
        for bit in range(8):
            bad = bytearray(data)
            bad[off] ^= 1 << bit
            with open(path, "wb") as f:
                f.write(bytes(bad))
            # the damaged tail record must vanish — whole prefix intact,
            # nothing invented, no exception out of tolerant replay
            _assert_prefix(wal.replay(path), 4)
            v = wal.verify(path)
            assert v["status"] == "torn_tail" and v["records"] == 4


def test_verify_triage_ok_torn_corrupt(tmp_path):
    path = str(tmp_path / "wal.log")
    _fill(path)
    assert wal.verify(path) == {"status": "ok", "records": 5,
                                "last_seq": 4, "bad_offset": -1}
    assert wal.verify(str(tmp_path / "missing.log"))["status"] == "ok"
    with open(path, "rb") as f:
        data = f.read()
    # interior damage: records past the bad frame are stranded acked data
    bad = bytearray(data)
    bad[_rec_bounds()[1][0] + wal._HEADER.size] ^= 0x40
    with open(path, "wb") as f:
        f.write(bytes(bad))
    v = wal.verify(path)
    assert v["status"] == "corrupt"
    assert v["records"] == 1 and v["last_seq"] == 0
    assert v["bad_offset"] == _rec_bounds()[1][0]


def test_store_recover_survives_tail_damage(tmp_path):
    import numpy as np
    from repro.core.mutable import MutableStore
    rng = np.random.default_rng(0)
    root = str(tmp_path / "store")
    st = MutableStore.create(
        rng.integers(0, 2 ** 32, size=(32, 2), dtype=np.uint32), 64,
        root=root, min_slack=4)
    first = st.append(rng.integers(0, 2 ** 32, size=(3, 2), dtype=np.uint32))
    st.append(rng.integers(0, 2 ** 32, size=(2, 2), dtype=np.uint32))
    st.close()
    wal_path = os.path.join(root, "wal.log")
    with open(wal_path, "r+b") as f:          # damage the LAST record
        f.seek(os.path.getsize(wal_path) - 2)
        b = f.read(1)
        f.seek(-1, os.SEEK_CUR)
        f.write(bytes([b[0] ^ 0x01]))
    rec = MutableStore.recover(root)          # must not raise
    got = set(int(i) for i in rec.epoch.store_ids)
    assert set(range(32)) | set(int(i) for i in first) <= got
    assert rec.audit(strict=False)["ok"]
    rec.close()


def test_fault_hook_fires_before_any_byte(tmp_path):
    path = str(tmp_path / "wal.log")
    inj = faults_mod.FaultInjector(seed=0, p={"wal_append": 1.0})
    w = wal.WriteAheadLog(path, fault_hook=inj.hook("wal_append"))
    with pytest.raises(faults_mod.InjectedFault):
        w.append(wal.APPEND, b"never", seq=0)
    w.close()
    # the fault preceded the write: the log holds NOTHING — "never acked,
    # never durable" is exactly the recovery contract
    assert os.path.getsize(path) == 0
    assert wal.last_seq(path) == -1
