"""Write-ahead log framing (checkpoint/wal.py): roundtrip fidelity, torn
tails end iteration cleanly (strict mode flags them), interior corruption
is caught by the CRC, rewrite is an atomic truncation, and the fault hook
fires before any byte lands."""
import os

import pytest

from repro.checkpoint import wal
from repro.runtime import faults as faults_mod


def _fill(path, n=5):
    with wal.WriteAheadLog(path) as w:
        for i in range(n):
            w.append(wal.APPEND if i % 2 == 0 else wal.DELETE,
                     bytes([i]) * (i * 7 + 1), seq=i)


def test_roundtrip_preserves_seq_kind_payload(tmp_path):
    path = str(tmp_path / "wal.log")
    _fill(path)
    recs = list(wal.iter_records(path, strict=True))
    assert [r.seq for r in recs] == [0, 1, 2, 3, 4]
    assert [r.kind for r in recs] == [wal.APPEND, wal.DELETE] * 2 + [
        wal.APPEND]
    for i, r in enumerate(recs):
        assert r.payload == bytes([i]) * (i * 7 + 1)
    assert wal.last_seq(path) == 4
    assert [r.seq for r in wal.replay(path, after_seq=2)] == [3, 4]


def test_missing_and_empty_logs_are_clean(tmp_path):
    path = str(tmp_path / "nope.log")
    assert list(wal.iter_records(path)) == []
    assert wal.last_seq(path) == -1
    open(path, "wb").close()
    assert list(wal.iter_records(path, strict=True)) == []


def test_torn_tail_keeps_whole_prefix(tmp_path):
    path = str(tmp_path / "wal.log")
    _fill(path)
    with open(path, "r+b") as f:
        f.truncate(os.path.getsize(path) - 3)   # tear the final record
    recs = list(wal.iter_records(path))         # tolerant: clean stop
    assert [r.seq for r in recs] == [0, 1, 2, 3]
    with pytest.raises(wal.WalCorrupt):         # strict: flagged
        list(wal.iter_records(path, strict=True))
    assert wal.last_seq(path) == 3


def test_interior_corruption_caught_by_crc(tmp_path):
    path = str(tmp_path / "wal.log")
    _fill(path)
    with open(path, "r+b") as f:                # flip a byte in record 0's
        f.seek(wal._HEADER.size)                # payload
        b = f.read(1)
        f.seek(wal._HEADER.size)
        f.write(bytes([b[0] ^ 0xFF]))
    # replay must not yield the poisoned record OR anything after it
    assert list(wal.iter_records(path)) == []
    with pytest.raises(wal.WalCorrupt, match="crc"):
        list(wal.iter_records(path, strict=True))


def test_rewrite_truncates_atomically(tmp_path):
    path = str(tmp_path / "wal.log")
    _fill(path)
    wal.rewrite(path, wal.replay(path, after_seq=2))
    assert [r.seq for r in wal.iter_records(path, strict=True)] == [3, 4]
    assert not os.path.exists(path + ".tmp")
    wal.rewrite(path, [])
    assert wal.last_seq(path) == -1


def test_fault_hook_fires_before_any_byte(tmp_path):
    path = str(tmp_path / "wal.log")
    inj = faults_mod.FaultInjector(seed=0, p={"wal_append": 1.0})
    w = wal.WriteAheadLog(path, fault_hook=inj.hook("wal_append"))
    with pytest.raises(faults_mod.InjectedFault):
        w.append(wal.APPEND, b"never", seq=0)
    w.close()
    # the fault preceded the write: the log holds NOTHING — "never acked,
    # never durable" is exactly the recovery contract
    assert os.path.getsize(path) == 0
    assert wal.last_seq(path) == -1
