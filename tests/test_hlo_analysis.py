"""The loop-aware HLO parser vs analytically known programs."""
import jax
import jax.numpy as jnp

from repro.launch import hlo


def test_scan_flops_loop_expanded():
    def scanned(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    stats = hlo.analyze(jax.jit(scanned).lower(x, w).compile().as_text())
    expect = 2 * 128**3 * 10
    assert abs(stats["flops"] - expect) / expect < 0.01


def test_nested_scan_flops():
    def nested(x, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            ci, _ = jax.lax.scan(inner, c, None, length=3)
            return ci @ w, None
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    stats = hlo.analyze(jax.jit(nested).lower(x, w).compile().as_text())
    expect = 2 * 64**3 * 5 * 4
    assert abs(stats["flops"] - expect) / expect < 0.01


def test_cost_analysis_is_loop_blind_motivation():
    """Documents the measured fact that motivates the custom parser."""
    def scanned(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    compiled = jax.jit(scanned).lower(x, w).compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):  # older jax: list of per-program dicts
        cost = cost[0] if cost else {}
    blind = float((cost or {}).get("flops", 0.0))
    aware = hlo.analyze(compiled.as_text())["flops"]
    assert aware > 5 * blind                     # ~10x here


def test_type_bytes_handles_tuple_comments():
    assert hlo._type_bytes("(s32[], bf16[18,2048]{1,0}, /*index=5*/f32[4])") \
        == 4 + 18 * 2048 * 2 + 16
    name, t, op = hlo._parse_def(
        "  %while.367 = (s32[], bf16[16,4096]{1,0}, /*index=5*/bf16[2]{0}) "
        "while(%tuple.1), condition=%c, body=%b")
    assert name == "while.367" and op == "while"
