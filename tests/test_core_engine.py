"""Chunked-scan engine ("partial reconfiguration") correctness."""
import jax
import jax.numpy as jnp
import numpy as np
from _propcheck import given, settings, st

from repro.core import binary, engine, topk

settings.register_profile("ci", deadline=None, max_examples=15)
settings.load_profile("ci")


def _data(seed, n, q, d):
    rng = np.random.default_rng(seed)
    xb = jnp.asarray(rng.integers(0, 2, (n, d)), jnp.uint8)
    qb = jnp.asarray(rng.integers(0, 2, (q, d)), jnp.uint8)
    return xb, qb


@given(st.integers(10, 500), st.integers(1, 6), st.sampled_from([32, 64, 96]),
       st.integers(1, 16), st.integers(7, 130), st.integers(0, 2**31 - 1))
def test_chunked_equals_oracle(n, q, d, k, chunk, seed):
    xb, qb = _data(seed, n, q, d)
    xp, qp = binary.pack_bits(xb), binary.pack_bits(qb)
    ref = binary.hamming_ref(qb, xb)
    rd, ri = topk.topk_ref(ref, min(k, n))
    ed, ei = engine.search_chunked(xp, qp, min(k, n), d, chunk=chunk)
    assert (ed == rd).all()
    assert (jnp.take_along_axis(ref, ei, 1) == ed).all()


def test_methods_agree():
    xb, qb = _data(0, 2048, 16, 128)
    xp, qp = binary.pack_bits(xb), binary.pack_bits(qb)
    d1, _ = engine.search_chunked(xp, qp, 10, 128, chunk=512, method="xor")
    d2, _ = engine.search_chunked(xp, qp, 10, 128, chunk=512, method="mxu")
    d3, _ = engine.search_chunked(xp, qp, 10, 128, chunk=512, method="pallas")
    assert (d1 == d2).all() and (d1 == d3).all()


def test_engine_class_api():
    xb, qb = _data(1, 300, 4, 64)
    eng = engine.KNNEngine(codes=binary.pack_bits(xb), d=64)
    dd, ii = eng.search(binary.pack_bits(qb), k=5)
    assert dd.shape == (4, 5) and ii.shape == (4, 5)
    assert (dd[:, :-1] <= dd[:, 1:]).all()       # sorted ascending
