"""Per-arch smoke: reduced config of the same family, one forward + one
train step on CPU; asserts output shapes and finiteness (no NaNs)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ALL_ARCHS, get_config, scaled_down
from repro.models import frontends, lm


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_and_train_step(arch):
    cfg = scaled_down(get_config(arch))
    key = jax.random.PRNGKey(0)
    params = lm.init_params(key, cfg)
    B, S = 2, 64
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.frontend != "none":
        batch["prefix_emb"] = frontends.synthetic_prefix(cfg, B)

    logits, aux = lm.forward(params, cfg, batch["tokens"], batch.get("prefix_emb"))
    assert logits.shape == (B, S + cfg.frontend_positions, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())

    (loss, metrics), grads = jax.value_and_grad(lm.loss_fn, has_aux=True)(
        params, cfg, batch)
    assert bool(jnp.isfinite(loss))
    gnorm = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32))))
                for g in jax.tree_util.tree_leaves(grads))
    assert gnorm > 0 and jnp.isfinite(gnorm)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_param_count_matches_pytree(arch):
    cfg = scaled_down(get_config(arch))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    actual = sum(l.size for l in jax.tree_util.tree_leaves(params))
    assert lm.param_count(cfg) == actual
    if cfg.moe is not None:
        assert lm.param_count(cfg, active_only=True) < actual


def test_full_config_param_counts_sane():
    """Full (not reduced) configs match their nameplates within tolerance."""
    expectations = {
        "internlm2-20b": (20e9, 0.15),
        "deepseek-67b": (67e9, 0.15),
        "gemma-2b": (2.5e9, 0.25),
        "granite-20b": (20e9, 0.15),
        "kimi-k2-1t-a32b": (1.0e12, 0.15),
        "arctic-480b": (480e9, 0.15),
        "rwkv6-1.6b": (1.6e9, 0.25),
    }
    for arch, (target, tol) in expectations.items():
        n = lm.param_count(get_config(arch))
        assert abs(n - target) / target < tol, (arch, n, target)
    active = lm.param_count(get_config("kimi-k2-1t-a32b"), active_only=True)
    assert abs(active - 32e9) / 32e9 < 0.35, active
