"""Correctness of the §Perf optimization paths (they must not change math)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import TrainConfig, get_config, scaled_down
from repro.models import lm


def _tiny(name, **kw):
    return dataclasses.replace(scaled_down(get_config(name), **kw),
                               dtype="float32")


def test_causal_skip_and_pbf16_match_baseline():
    cfg = _tiny("internlm2-20b")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, cfg.vocab_size)
    base, _ = lm.forward(params, cfg, tokens, ctx=lm.RunCtx(attn_chunk=32))
    tri, _ = lm.forward(params, cfg, tokens,
                        ctx=lm.RunCtx(attn_chunk=32, causal_skip=True))
    np.testing.assert_allclose(np.asarray(tri), np.asarray(base),
                               atol=1e-4, rtol=1e-4)
    # p_bf16 in an f32 model: small quantization error only
    pb, _ = lm.forward(params, cfg, tokens,
                       ctx=lm.RunCtx(attn_chunk=32, attn_p_bf16=True))
    assert float(jnp.max(jnp.abs(pb - base))) < 0.05


def test_flash_prefill_matches_xla_prefill():
    cfg = _tiny("llava-next-mistral-7b")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, cfg.vocab_size)
    from repro.models import frontends
    pre = frontends.synthetic_prefix(cfg, 2)
    lx, _ = lm.prefill(params, cfg, tokens, pre, ctx=lm.RunCtx(attn_chunk=32))
    lf, _ = lm.prefill(params, cfg, tokens, pre,
                       ctx=lm.RunCtx(attn_chunk=32, attn_impl="flash"))
    np.testing.assert_allclose(np.asarray(lf), np.asarray(lx),
                               atol=1e-3, rtol=1e-3)


@pytest.mark.slow
def test_int8_a2a_and_pure_dp_multidevice(multidevice):
    multidevice("""
import dataclasses, jax, jax.numpy as jnp
from repro import compat
from repro.configs import get_config, scaled_down, TrainConfig
from repro.models import moe as moe_mod, lm
from repro.dist import steps, sharding
from repro.optim import optimizer

mesh = compat.make_mesh((2, 2, 2), ("pod", "data", "model"))
# 1) int8 a2a ~= exact EP
cfg = scaled_down(get_config("kimi-k2-1t-a32b"))
cfg = dataclasses.replace(cfg, dtype="float32",
    moe=dataclasses.replace(cfg.moe, num_experts=8, experts_per_token=2, capacity_factor=8.0))
params = moe_mod.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model), jnp.float32) * 0.1
y_ref, _ = moe_mod.moe_forward(params, cfg, x, mesh=None)
with mesh:
    y_q, _ = jax.jit(lambda p, xx: moe_mod.moe_forward(p, cfg, xx, mesh=mesh,
        dp_axes=("pod","data"), strategy="a2a", a2a_int8=True))(params, x)
err = float(jnp.max(jnp.abs(y_q - y_ref)))
assert err < 0.05, err

# 2) pure-DP training: loss decreases, all params replicated
cfg2 = scaled_down(get_config("musicgen-medium"), d_model=64, d_ff=128, vocab_size=256)
tc = TrainConfig(total_steps=6, warmup_steps=1, learning_rate=1e-2)
with mesh:
    step_fn, pspecs, ospecs = steps.make_train_step(cfg2, mesh, tc, pure_dp=True)
    params2 = jax.jit(lambda: lm.init_params(jax.random.PRNGKey(0), cfg2),
                      out_shardings=sharding.named(mesh, pspecs))()
    opt = jax.jit(lambda p: optimizer.init(p, tc),
                  out_shardings=sharding.named(mesh, ospecs))(params2)
    from repro.models import frontends
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, 256),
             "labels": jax.random.randint(jax.random.PRNGKey(2), (8, 32), 0, 256),
             "prefix_emb": frontends.synthetic_prefix(cfg2, 8)}
    losses = []
    for i in range(4):
        params2, opt, m = step_fn(params2, opt, batch, jnp.asarray(i))
        losses.append(float(m["loss"]))
assert losses[-1] < losses[0], losses
print("OK")
""")


def test_int8_adam_trains_tiny_lm():
    """End-to-end: 8-bit moments still reduce loss on a tiny model."""
    cfg = _tiny("gemma-2b", d_model=32, d_ff=64, vocab_size=128)
    tc = TrainConfig(total_steps=30, warmup_steps=2, learning_rate=5e-3,
                     opt_int8=True)
    from repro.optim import optimizer
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    state = optimizer.init(params, tc)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    losses = []
    step = jax.jit(lambda p, s, i: _one_step(p, s, i, cfg, tc, batch))
    for i in range(12):
        params, state, loss = step(params, state, jnp.asarray(i))
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses


def _one_step(params, state, i, cfg, tc, batch):
    from repro.optim import optimizer
    (loss, _), grads = jax.value_and_grad(lm.loss_fn, has_aux=True)(
        params, cfg, batch)
    params, state, _ = optimizer.update(grads, state, params, tc, i)
    return params, state, loss
