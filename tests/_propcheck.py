"""Property-test compat layer: uses ``hypothesis`` when installed, otherwise
falls back to a tiny deterministic sampler with the same decorator shape.

The fallback covers exactly the API surface the suite uses — ``given``,
``settings.register_profile/load_profile`` and the ``st.integers`` /
``st.sampled_from`` strategies — drawing ``max_examples`` pseudo-random
examples per test from a fixed seed, always including the strategy's
boundary values, so a clean environment (no hypothesis) still exercises
the properties instead of erroring at collection.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on clean environments
    import functools
    import random
    import zlib

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, draw, boundary=()):
            self.draw = draw
            self.boundary = tuple(boundary)

    class st:  # noqa: N801 - mimics hypothesis.strategies module
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value),
                             boundary=(min_value, max_value))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda rng: rng.choice(elements),
                             boundary=(elements[0], elements[-1]))

    class settings:  # noqa: N801 - mimics hypothesis.settings
        _profiles: dict = {}
        _active: dict = {"max_examples": 25}

        def __init__(self, **kwargs):
            pass

        @classmethod
        def register_profile(cls, name, **kwargs):
            cls._profiles[name] = kwargs

        @classmethod
        def load_profile(cls, name):
            cls._active = {"max_examples": 25, **cls._profiles.get(name, {})}

    def given(*strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper():
                n = int(settings._active.get("max_examples") or 25)
                # crc32, not hash(): hash of str is randomized per process,
                # which would make failing examples unreproducible
                rng = random.Random(zlib.crc32(fn.__name__.encode()))
                # boundary case first: every strategy at its min, then max
                for pick in ("lo", "hi"):
                    args = [s.boundary[0 if pick == "lo" else -1]
                            for s in strategies]
                    fn(*args)
                for _ in range(max(0, n - 2)):
                    fn(*[s.draw(rng) for s in strategies])
            # pytest must see the zero-arg signature, not fn's via __wrapped__
            del wrapper.__wrapped__
            return wrapper
        return deco
