"""Multi-tenant packed arena (core/tenant.py): bit-identity of the
mixed-tenant single-kernel batch to per-tenant searches, WAL-namespace
blast-radius containment (interior corruption quarantines exactly one
tenant), tenant-scoped fault sites, and the server's per-tenant admission
ladder (quota_exceeded vs backlog_full vs rate_limited) with a starvation
check."""
import dataclasses
import os

import numpy as np
import pytest

import jax

from repro.checkpoint import wal as wal_mod
from repro.core import tenant as tenant_mod
from repro.core.tenant import TenantArena, TenantQuota
from repro.runtime import faults as faults_mod

D = 64
W = 2


def _codes(rng, n):
    return rng.integers(0, 2 ** 32, size=(n, W), dtype=np.uint32)


def _mk_arena(rng, sizes, root=None, inj=None, bn=64, **kw):
    ar = TenantArena(D, root=root, bn=bn, fault_injector=inj,
                     min_slack=4, **kw)
    for tid, n in sizes.items():
        ar.create_tenant(tid, _codes(rng, n) if n else None,
                         values=np.arange(n, dtype=np.int32) if n else None)
    return ar


def _assert_identical(ar, queries, k):
    res = ar.search(queries, k)
    for tid, q in queries.items():
        dd, ee = res[tid]
        sd, se = ar.tenant(tid).store.search(q, k)
        assert np.array_equal(np.asarray(dd), np.asarray(sd)), tid
        assert np.array_equal(np.asarray(ee), np.asarray(se)), tid


# ---------------------------------------------------------------------------
# the central pin: one pallas_call batch == per-tenant searches, bit for bit
# ---------------------------------------------------------------------------

def test_mixed_batch_bit_identical_to_per_tenant():
    rng = np.random.default_rng(0)
    # skewed sizes incl. an empty tenant and sizes that are not bn-aligned
    ar = _mk_arena(rng, {"big": 300, "small": 17, "empty": 0, "mid": 130})
    queries = {"big": _codes(rng, 33), "small": _codes(rng, 5),
               "empty": _codes(rng, 3), "mid": _codes(rng, 1)}
    # adversarial all-ones query: its distance to every pad row is 0, so a
    # single missed pad-correction bin would corrupt its whole top-k
    queries["big"][0] = np.full(W, 0xFFFFFFFF, np.uint32)
    _assert_identical(ar, queries, k=10)
    # k beyond the smallest tenant: surplus slots must sentinel identically
    _assert_identical(ar, queries, k=40)


def test_mixed_batch_identity_survives_churn_and_repack():
    rng = np.random.default_rng(1)
    ar = _mk_arena(rng, {"a": 150, "b": 40})
    seq0 = ar.pack().seq
    ar.append("a", _codes(rng, 30))
    ar.delete("a", np.arange(0, 60, 4))
    ar.append("b", _codes(rng, 90))       # overflows slack -> compaction
    ar.delete("b", np.arange(0, 10))
    ar.maintain(compact_budget=8)
    assert ar.pack().seq > seq0           # epochs moved -> repacked
    _assert_identical(ar, {"a": _codes(rng, 9), "b": _codes(rng, 6)}, k=7)
    # unchanged epochs -> the packed view is reused, not rebuilt
    seq1 = ar.pack().seq
    assert ar.pack().seq == seq1


def test_packed_regions_are_aligned_and_isolated():
    rng = np.random.default_rng(2)
    ar = _mk_arena(rng, {"a": 100, "b": 37}, bn=64)
    ep = ar.pack()
    own = {}
    for tid, (start, n_real, cap) in ep.regions.items():
        assert start % 64 == 0 and cap % 64 == 0 and n_real <= cap
        assert np.all(ep.ext_ids[start + n_real:start + cap] == -1)
        own[tid] = set(int(i) for i in ep.ext_ids[start:start + n_real])
    # every returned id belongs to the query's OWN tenant
    res = ar.search({"a": _codes(rng, 8), "b": _codes(rng, 8)}, k=50)
    for tid in ("a", "b"):
        got = set(int(i) for i in res[tid][1].ravel() if int(i) >= 0)
        assert got <= own[tid]
        assert len(got) > 0


# ---------------------------------------------------------------------------
# blast radius: one corrupt namespace quarantines one tenant, never the arena
# ---------------------------------------------------------------------------

def _churned_disk_arena(tmp_path, rng, tids=("t0", "t1", "t2")):
    ar = _mk_arena(rng, {t: 48 for t in tids}, root=str(tmp_path))
    models = {}
    for t in tids:
        st = ar.tenant(t).store
        models[t] = {int(i): st.arena.codes[st._id_map[int(i)]].copy()
                     for i in st._id_map}
        c = _codes(rng, 6)
        for j, ext in enumerate(ar.append(t, c)):
            models[t][int(ext)] = c[j]
        ar.delete(t, np.arange(0, 8, np.int64(2)))
        for v in range(0, 8, 2):
            models[t].pop(v, None)
    ar.maintain(compact_budget=8)
    return ar, models


def _assert_matches(store, model):
    ep = store.epoch
    ids = np.asarray(ep.store_ids)
    codes = np.asarray(ep.layout.codes)
    assert set(int(i) for i in ids) == set(model)
    for i in range(ids.shape[0]):
        assert np.array_equal(codes[i], model[int(ids[i])])


def test_interior_wal_corruption_quarantines_only_that_tenant(tmp_path):
    rng = np.random.default_rng(3)
    ar, models = _churned_disk_arena(tmp_path, rng)
    ar.close()
    # flip one bit in the FIRST record of t1's log: acked records now sit
    # past the bad frame — tolerant replay would silently drop them, so
    # recovery must quarantine instead
    sick = wal_mod.namespace_root(str(tmp_path), "t1")
    wal_path = os.path.join(sick, "wal.log")
    with open(wal_path, "r+b") as f:
        f.seek(wal_mod._HEADER.size + 2)
        b = f.read(1)
        f.seek(-1, os.SEEK_CUR)
        f.write(bytes([b[0] ^ 0x10]))
    assert wal_mod.verify(wal_path)["status"] == "corrupt"

    rec = TenantArena.recover(D, str(tmp_path))
    assert rec.tenant("t1").status == tenant_mod.QUARANTINED
    assert "corruption" in rec.tenant("t1").error
    assert rec.healthy_tids() == ["t0", "t2"]
    # healthy tenants lost nothing and keep serving
    for t in ("t0", "t2"):
        _assert_matches(rec.tenant(t).store, models[t])
    _assert_identical(rec, {"t0": _codes(rng, 4), "t2": _codes(rng, 4)}, 5)
    with pytest.raises(tenant_mod.TenantQuarantined):
        rec.search({"t1": _codes(rng, 2)}, 5)
    # the sick namespace is untouched on disk for offline repair
    assert wal_mod.verify(wal_path)["status"] == "corrupt"
    rec.close()


def test_torn_tail_recovers_normally(tmp_path):
    rng = np.random.default_rng(4)
    ar, models = _churned_disk_arena(tmp_path, rng, tids=("t0", "t1"))
    ar.close()
    wal_path = os.path.join(wal_mod.namespace_root(str(tmp_path), "t0"),
                            "wal.log")
    with open(wal_path, "r+b") as f:
        f.truncate(os.path.getsize(wal_path) - 3)
    assert wal_mod.verify(wal_path)["status"] == "torn_tail"
    rec = TenantArena.recover(D, str(tmp_path))
    assert rec.healthy_tids() == ["t0", "t1"]
    # t0 recovers to the last whole record: everything before the torn
    # tail survives, and the only divergence is the tail record itself
    # (here the delete of {0,2,4,6}, whose rows resurrect)
    got = set(int(i) for i in rec.tenant("t0").store.epoch.store_ids)
    assert set(models["t0"]) <= got
    assert got - set(models["t0"]) <= {0, 2, 4, 6}
    _assert_matches(rec.tenant("t1").store, models["t1"])
    rec.close()


def test_transient_recovery_faults_retry_not_quarantine(tmp_path):
    rng = np.random.default_rng(5)
    ar, models = _churned_disk_arena(tmp_path, rng, tids=("t0",))
    ar.close()
    inj = faults_mod.FaultInjector(seed=5, p={"epoch_install@t0": 0.9})
    rec = TenantArena.recover(D, str(tmp_path), fault_injector=inj)
    assert rec.healthy_tids() == ["t0"]
    _assert_matches(rec.tenant("t0").store, models["t0"])
    assert inj.fired.get("epoch_install@t0", 0) > 0   # retries were real
    rec.close()


def test_scoped_fault_sites_hit_one_tenant_only(tmp_path):
    rng = np.random.default_rng(6)
    inj = faults_mod.FaultInjector(seed=6, p={"wal_append@t1": 1.0})
    ar = _mk_arena(rng, {"t0": 16, "t1": 16}, root=str(tmp_path), inj=inj)
    ar.append("t0", _codes(rng, 2))               # base rate 0: fine
    with pytest.raises(faults_mod.InjectedFault):
        ar.append("t1", _codes(rng, 2))           # scoped rate 1: fires
    assert inj.fired["wal_append@t1"] >= 1
    assert inj.fired.get("wal_append@t0", 0) == 0
    ar.close()


# ---------------------------------------------------------------------------
# server admission: quota / fairness edges (satellite)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def serve_env():
    from repro import compat
    from repro.configs import get_config, scaled_down
    from repro.models import lm
    cfg = scaled_down(get_config("gemma-2b"), d_model=64, d_ff=128,
                      vocab_size=256)
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, mesh, params


def _srv(serve_env, ar, **kw):
    from repro.runtime import server as server_mod
    cfg, mesh, params = serve_env
    return server_mod.Server(cfg, mesh, params, max_batch=1, max_len=8,
                             tenants=ar, **kw)


def test_append_at_exact_row_quota_then_shed(serve_env):
    rng = np.random.default_rng(7)
    ar = _mk_arena(rng, {"q": 30})
    ar.tenant("q").quota = TenantQuota(max_rows=32)
    srv = _srv(serve_env, ar)
    assert srv.submit_append(_codes(rng, 2), tenant="q")   # lands AT quota
    assert ar.tenant("q").store.n_live == 32
    assert not srv.submit_append(_codes(rng, 1), tenant="q")
    tc = srv.stats()["tenants"]["q"]
    assert tc["shed_quota_exceeded"] == 1 and tc["mutations_applied"] == 2
    # deletes are never quota-shed — they are how the tenant gets back
    # under its ceiling
    assert srv.submit_delete(np.arange(4, dtype=np.int64), tenant="q")
    assert srv.submit_append(_codes(rng, 1), tenant="q")


def test_quota_exceeded_vs_backlog_full_reasons(serve_env):
    rng = np.random.default_rng(8)
    # zero slack + tiny pending cap: appends overflow to the compaction
    # backlog immediately and backpressure must surface as backlog_full,
    # NOT quota_exceeded (the row ceiling is far away)
    ar = TenantArena(D, bn=64, slack_frac=0.0, min_slack=0, max_pending=4)
    ar.create_tenant("b", _codes(rng, 64),
                     quota=TenantQuota(max_rows=1000))
    srv = _srv(serve_env, ar)
    assert srv.submit_append(_codes(rng, 4), tenant="b")   # fills backlog
    assert not srv.submit_append(_codes(rng, 1), tenant="b")
    tc = srv.stats()["tenants"]["b"]
    assert tc["shed_backlog_full"] == 1
    assert tc.get("shed_quota_exceeded", 0) == 0
    srv.tick()                       # maintenance compacts the backlog
    assert srv.submit_append(_codes(rng, 1), tenant="b")   # reopens


def test_saturating_tenant_cannot_starve_quiet_tenant(serve_env):
    rng = np.random.default_rng(9)
    ar = _mk_arena(rng, {"noisy": 16, "quiet": 16})
    ar.tenant("noisy").quota = TenantQuota(max_mutations_per_tick=4)
    ar.tenant("quiet").quota = TenantQuota(max_mutations_per_tick=4)
    srv = _srv(serve_env, ar)
    quiet_ok = quiet_try = 0
    for _ in range(12):
        for _ in range(10):          # noisy slams far past its fair share
            srv.submit_append(_codes(rng, 1), tenant="noisy")
        quiet_try += 1
        quiet_ok += int(srv.submit_append(_codes(rng, 1), tenant="quiet"))
        srv.tick()                   # budgets refresh per tick
    tn = srv.stats()["tenants"]["noisy"]
    tq = srv.stats()["tenants"]["quiet"]
    # the saturating tenant throttles ITSELF (rate_limited shed)...
    assert tn["shed_rate_limited"] > 0
    assert tn["mutations_applied"] <= 4 * 12
    # ...and the quiet tenant's shed rate stays ~0: nothing starved it
    assert quiet_ok == quiet_try
    assert tq.get("mutations_shed", 0) == 0


def test_rate_budget_refreshes_each_tick(serve_env):
    rng = np.random.default_rng(10)
    ar = _mk_arena(rng, {"r": 8})
    ar.tenant("r").quota = TenantQuota(max_mutations_per_tick=3)
    srv = _srv(serve_env, ar)
    assert srv.submit_append(_codes(rng, 3), tenant="r")
    assert not srv.submit_append(_codes(rng, 1), tenant="r")
    assert srv.stats()["tenants"]["r"]["shed_rate_limited"] == 1
    srv.tick()
    assert srv.submit_append(_codes(rng, 3), tenant="r")


def test_server_sheds_for_quarantined_tenant(serve_env):
    rng = np.random.default_rng(11)
    ar = _mk_arena(rng, {"ok": 16, "sick": 16})
    ar.quarantine("sick", "test-induced")
    srv = _srv(serve_env, ar)
    assert not srv.submit_append(_codes(rng, 1), tenant="sick")
    assert srv.stats()["tenants"]["sick"]["shed_quarantined"] == 1
    assert srv.submit_append(_codes(rng, 1), tenant="ok")
    assert srv.stats()["n_quarantined"] == 1
    # maintenance and packed search keep working over the healthy set
    srv.tick()
    res = srv.tenant_search({"ok": _codes(rng, 2)}, k=3)
    assert res["ok"][0].shape == (2, 3)
