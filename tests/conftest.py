"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches must
see one device; multi-device tests spawn subprocesses that set the flag
before importing jax."""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_multidevice(script: str, n_devices: int = 8, timeout: int = 900):
    """Run a python snippet in a subprocess with n fake devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=timeout)
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed:\nSTDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}")
    return proc.stdout


@pytest.fixture(scope="session")
def multidevice():
    return run_multidevice
