"""Multi-device integration (subprocesses with 8 fake devices — XLA_FLAGS
must precede jax import, so these cannot run in the pytest process)."""
import pytest

pytestmark = pytest.mark.slow


def test_sharded_search_exact_and_statistical(multidevice):
    multidevice("""
import jax, jax.numpy as jnp
from repro import compat
from repro.core import binary, engine
mesh = compat.make_mesh((2, 2, 2), ("pod", "data", "model"))
key = jax.random.PRNGKey(0)
d, N, Q, k = 128, 4096, 8, 16
bits = jax.random.bernoulli(key, 0.5, (N, d)).astype(jnp.uint8)
qbits = jax.random.bernoulli(jax.random.PRNGKey(1), 0.5, (Q, d)).astype(jnp.uint8)
packed, qp = binary.pack_bits(bits), binary.pack_bits(qbits)
ed, ei = engine.search_chunked(packed, qp, k, d)
cs = engine.shard_datastore(packed, mesh, ("pod", "data", "model"))
with mesh:
    sd, si = jax.jit(lambda c, q: engine.search_sharded(c, q, k, d, mesh, ("pod","data","model")))(cs, qp)
assert (sd == ed).all() and (si == ei).all(), "exact sharded mismatch"
with mesh:
    ad, ai = jax.jit(lambda c, q: engine.search_sharded(c, q, k, d, mesh, ("pod","data","model"), k_local=4))(cs, qp)
recall = float(jnp.mean(jnp.any(ai[:, :, None] == ei[:, None, :], axis=1)))
assert recall > 0.9, recall
print("OK", recall)
""")


def test_moe_ep_matches_reference(multidevice):
    multidevice("""
import dataclasses, jax, jax.numpy as jnp
from repro import compat
from repro.configs import get_config, scaled_down
from repro.models import moe as moe_mod
mesh = compat.make_mesh((2, 2, 2), ("pod", "data", "model"))
cfg = scaled_down(get_config("kimi-k2-1t-a32b"))
cfg = dataclasses.replace(cfg, dtype="float32",
    moe=dataclasses.replace(cfg.moe, num_experts=8, experts_per_token=2, capacity_factor=8.0))
params = moe_mod.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model), jnp.float32) * 0.1
y_ref, _ = moe_mod.moe_forward(params, cfg, x, mesh=None)
with mesh:
    y_a2a, _ = jax.jit(lambda p, xx: moe_mod.moe_forward(p, cfg, xx, mesh=mesh,
        dp_axes=("pod","data"), strategy="a2a"))(params, x)
    y_ag, _ = jax.jit(lambda p, xx: moe_mod.moe_forward(p, cfg, xx, mesh=mesh,
        dp_axes=("pod","data"), strategy="allgather"))(params, x)
assert float(jnp.max(jnp.abs(y_a2a - y_ref))) < 1e-5
assert float(jnp.max(jnp.abs(y_ag - y_ref))) < 1e-5
print("OK")
""")


def test_train_loss_decreases_and_ckpt_resume(multidevice):
    multidevice("""
import tempfile, jax, jax.numpy as jnp
from repro import compat
from repro.configs import get_config, scaled_down, TrainConfig
from repro.runtime import trainer
mesh = compat.make_mesh((2, 2, 2), ("pod", "data", "model"))
cfg = scaled_down(get_config("internlm2-20b"), d_model=64, d_ff=128, vocab_size=256)
tc = TrainConfig(total_steps=10, warmup_steps=1, learning_rate=1e-2)
with tempfile.TemporaryDirectory() as tmp:
    try:
        trainer.train(cfg, tc, mesh, seq_len=32, global_batch=8,
                      ckpt_dir=tmp, ckpt_every=2, log_every=100, preempt_at=5)
        raise SystemExit("expected preemption")
    except trainer.PreemptionError:
        pass
    rep = trainer.train(cfg, tc, mesh, seq_len=32, global_batch=8,
                        ckpt_dir=tmp, ckpt_every=2, log_every=100)
    assert rep.resumed_from == 5, rep.resumed_from
    assert rep.final_loss < 5.55, rep.final_loss
print("OK")
""")


def test_serve_step_with_retrieval_all_archs(multidevice):
    multidevice("""
import jax, jax.numpy as jnp
from repro import compat
from repro.configs import get_config, scaled_down
from repro.models import lm
from repro.dist import steps, sharding
from repro.core import retrieval
mesh = compat.make_mesh((2, 2, 2), ("pod", "data", "model"))
for name in ["gemma-2b", "zamba2-2.7b", "rwkv6-1.6b", "arctic-480b"]:
    cfg = scaled_down(get_config(name), d_model=64, d_ff=128, vocab_size=256)
    S = 64
    with mesh:
        serve_fn, pspecs, sspecs = steps.make_serve_step(cfg, mesh, S)
        params = jax.jit(lambda: lm.init_params(jax.random.PRNGKey(0), cfg),
                         out_shardings=sharding.named(mesh, pspecs))()
        state = jax.jit(lambda: lm.init_decode_state(cfg, 8, S),
                        out_shardings=sharding.named(mesh, sspecs))()
    store = retrieval.synthetic_datastore(cfg)
    store = jax.device_put(store, sharding.named(mesh, sharding.datastore_specs(mesh)))
    token = jnp.zeros((8, 1), jnp.int32)
    active = jnp.ones((8,), bool)
    logits, state = serve_fn(params, token, state, active, store)
    assert bool(jnp.isfinite(logits).all()), name
print("OK")
""")


def test_elastic_restore_different_mesh(multidevice):
    multidevice("""
import tempfile, jax, jax.numpy as jnp
from repro import compat
from repro.configs import get_config, scaled_down
from repro.models import lm
from repro.dist import sharding
from repro.checkpoint import manager as ckpt
cfg = scaled_down(get_config("gemma-2b"), d_model=64, d_ff=128, vocab_size=256)
mesh_a = compat.make_mesh((4, 2), ("data", "model"))
mesh_b = compat.make_mesh((2, 4), ("data", "model"))
pa = sharding.named(mesh_a, sharding.param_specs(cfg, mesh_a))
pb = sharding.named(mesh_b, sharding.param_specs(cfg, mesh_b))
with mesh_a:
    params = jax.jit(lambda: lm.init_params(jax.random.PRNGKey(0), cfg),
                     out_shardings=pa)()
with tempfile.TemporaryDirectory() as tmp:
    ckpt.save(tmp, 0, params)
    restored = ckpt.restore(tmp, 0, params, pb)   # elastic: new mesh layout
    a = jnp.asarray(jax.tree_util.tree_leaves(params)[0], jnp.float32)
    b = jnp.asarray(jax.tree_util.tree_leaves(restored)[0], jnp.float32)
    assert (a == b).all()
print("OK")
""")
