"""QueryPlan IR (core/plan.py): planner/executor equivalence matrix.

Every legacy entry point is now a thin plan-builder; these tests pin that
(a) the planner-built execution is bit-identical to the legacy forced
paths on the same inputs, across (select path x layout on/off x
indexed/full-scan x sharded/local), (b) ``select="auto"`` resolves BEFORE
the layout check (the regression this PR fixes: the literal-string test
silently dropped reordering+pruning), and (c) ``explain()`` /
``force_plan`` / the generated decision table behave.
"""
import dataclasses
import json
import warnings

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import RetrievalConfig
from repro.core import binary, engine, index, layout, plan, retrieval, topk

SELECTS = ("auto", "counting", "bisect", "fused", "fused_scan")


def _data(seed, n, q, d):
    rng = np.random.default_rng(seed)
    xb = jnp.asarray(rng.integers(0, 2, (n, d)), jnp.uint8)
    qb = jnp.asarray(rng.integers(0, 2, (q, d)), jnp.uint8)
    return xb, qb


def _oracle(xb, qb, k, d):
    return topk.counting_topk(binary.hamming_ref(qb, xb), k, d)


def _quiet(fn, *a, **kw):
    """Run a legacy forced-knob call without its deprecation nudge."""
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        return fn(*a, **kw)


# ---------------------------------------------------------------------------
# the equivalence matrix: full scan, layout on/off, every select
# ---------------------------------------------------------------------------

def test_matrix_full_scan_no_layout():
    """Layout off: every select (planner-auto included) is bit-identical —
    dists AND ids (all paths break ties by index order)."""
    n, q, d, k = 1500, 6, 64, 8
    xb, qb = _data(0, n, q, d)
    xp, qp = binary.pack_bits(xb), binary.pack_bits(qb)
    rd, ri = _oracle(xb, qb, k, d)
    eng = engine.KNNEngine(codes=xp, d=d)
    for select in SELECTS:
        dd, ii = _quiet(eng.search, qp, k, chunk=257, select=select)
        assert (dd == rd).all(), select
        assert (ii == ri).all(), select
        # and the function-style entry point agrees bit-for-bit
        fd, fi = _quiet(engine.search_chunked, xp, qp, k, d, chunk=257,
                        select=select)
        assert (dd == fd).all() and (ii == fi).all(), select


def test_matrix_full_scan_with_layout():
    """Layout on: planner-auto == forced fused (both stream the reordered
    codes, bit-identical); materializing selects still scan the original
    order and stay bit-identical to their no-layout outputs; the top-k
    DISTANCE vector is layout-invariant everywhere."""
    n, q, d, k = 1500, 6, 64, 8
    xb, qb = _data(1, n, q, d)
    xp, qp = binary.pack_bits(xb), binary.pack_bits(qb)
    rd, _ = _oracle(xb, qb, k, d)
    plain = engine.KNNEngine(codes=xp, d=d)
    eng = plain.with_layout(n_buckets=8)

    ad, ai = eng.search(qp, k, chunk=257)                     # planner auto
    fd, fi = _quiet(eng.search, qp, k, chunk=257, select="fused")
    assert (ad == fd).all() and (ai == fi).all()
    assert (ad == rd).all()
    # every returned id really has its reported distance (original ids)
    ref = np.asarray(binary.hamming_ref(qb, xb))
    assert (ref[np.arange(q)[:, None], np.asarray(ai)]
            == np.asarray(ad)).all()

    for select in ("counting", "bisect", "fused_scan"):
        ld, li = _quiet(eng.search, qp, k, chunk=257, select=select)
        pd_, pi = _quiet(plain.search, qp, k, chunk=257, select=select)
        assert (ld == pd_).all() and (li == pi).all(), select
        assert (ld == rd).all(), select


def test_engine_auto_layout_regression():
    """The satellite fix: ``select="auto"`` RESOLVES first, so an auto that
    lands on the fused path sees the layout. Before, the literal-string
    check (`select == "fused"` pre-resolution) silently dropped the
    reorder+pruning; now the plan must say so explicitly."""
    n, q, d, k = 1200, 4, 64, 5
    xb, qb = _data(2, n, q, d)
    xp, qp = binary.pack_bits(xb), binary.pack_bits(qb)
    eng = engine.KNNEngine(codes=xp, d=d).with_layout(n_buckets=8)

    p = eng.query_plan(qp, k)                                 # select="auto"
    assert p.select.path == "fused"
    assert p.candidates.layout == "prebuilt"
    # without a layout, auto stays on the composite materializing path
    p0 = engine.KNNEngine(codes=xp, d=d).query_plan(qp, k)
    assert p0.select.path == "composite"
    assert p0.candidates.layout == "none"

    ad, ai = eng.search(qp, k)
    fd, fi = _quiet(eng.search, qp, k, select="fused")
    assert (ad == fd).all() and (ai == fi).all()


# ---------------------------------------------------------------------------
# indexed: masked (planner default) vs forced gather
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def clustered():
    rng = np.random.default_rng(3)
    centers = rng.normal(size=(8, 64)) * 5
    x = (centers[rng.integers(0, 8, 3000)]
         + rng.normal(size=(3000, 64))).astype(np.float32)
    bits = (x > 0).astype(np.uint8)
    codes = binary.pack_bits(jnp.asarray(bits))
    q = jnp.asarray(x[:16])
    q_codes = binary.pack_bits(jnp.asarray(bits[:16]))
    return x, codes, q, q_codes


def test_matrix_indexed_kmeans(clustered):
    x, codes, q, q_codes = clustered
    km = index.kmeans_build(jnp.asarray(x), codes, 64, 16, iters=6)
    # the planner's default (use_layout=None) must equal the forced masked
    # path bit-for-bit, and its plan must say block_mask
    p = index.kmeans_plan(km, q.shape[0], 10, nprobe=4)
    assert p.candidates.kind == "block_mask"
    assert p.probe.kind == "kmeans" and p.probe.nprobe == 4
    ad, ai = index.kmeans_search(km, q, q_codes, 10, nprobe=4)
    fd, fi = _quiet(index.kmeans_search, km, q, q_codes, 10, nprobe=4,
                    use_layout=True)
    assert (ad == fd).all() and (ai == fi).all()
    # forced gather is the legacy reference: per-slot distances can only
    # improve on the masked superset candidate set
    gd, _ = _quiet(index.kmeans_search, km, q, q_codes, 10, nprobe=4,
                   use_layout=False)
    pg = index.kmeans_plan(km, q.shape[0], 10, nprobe=4, use_layout=False)
    assert pg.candidates.kind == "gather"
    assert (jnp.asarray(ad) <= jnp.asarray(gd)).all()


def test_matrix_indexed_no_layout_falls_back(clustered):
    x, codes, q, q_codes = clustered
    km = index.kmeans_build(jnp.asarray(x), codes, 64, 16, iters=4,
                            reorder=False)
    p = index.kmeans_plan(km, q.shape[0], 10, nprobe=4)
    assert p.candidates.kind == "gather"
    dd, ids = index.kmeans_search(km, q, q_codes, 10, nprobe=4)
    assert dd.shape == (16, 10)


def test_matrix_indexed_lsh(clustered):
    x, codes, q, q_codes = clustered
    lsh = index.lsh_build(codes, 64, n_tables=4, bits_per_table=5)
    p = index.lsh_plan(lsh, q_codes.shape[0], 10)
    assert p.candidates.kind == "block_mask" and p.probe.n_tables == 4
    ad, ai = index.lsh_search(lsh, q_codes, 10)
    fd, fi = _quiet(index.lsh_search, lsh, q_codes, 10, use_layout=True)
    assert (ad == fd).all() and (ai == fi).all()


# ---------------------------------------------------------------------------
# sharded vs local (subprocess with fake devices)
# ---------------------------------------------------------------------------

def test_matrix_sharded(multidevice):
    """Sharded planner-built execution == local full scan at k_local = k
    (exact), for both the planner-auto and the forced fused select, with
    and without reorder_local — the merge stage is lossless."""
    multidevice("""
import warnings
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.core import binary, engine

rng = np.random.default_rng(0)
xb = jnp.asarray(rng.integers(0, 2, (1024, 64)), jnp.uint8)
qb = jnp.asarray(rng.integers(0, 2, (8, 64)), jnp.uint8)
xp, qp = binary.pack_bits(xb), binary.pack_bits(qb)
ed, ei = engine.search_chunked(xp, qp, 10, 64)
mesh = Mesh(np.array(jax.devices()).reshape(4), ("data",))
with mesh, warnings.catch_warnings():
    warnings.simplefilter("ignore", DeprecationWarning)
    sd, si = engine.search_sharded(xp, qp, 10, 64, mesh, ("data",), chunk=256)
    fd, fi = engine.search_sharded(xp, qp, 10, 64, mesh, ("data",),
                                   chunk=256, select="fused")
    rd, ri = engine.search_sharded(xp, qp, 10, 64, mesh, ("data",),
                                   chunk=256, select="fused",
                                   reorder_local=True)
assert (sd == ed).all() and (si == ei).all()
assert (fd == ed).all() and (fi == ei).all()
assert (rd == ed).all()
ref = np.asarray(binary.hamming_ref(qb, xb))
got = ref[np.arange(8)[:, None], np.asarray(ri)]
assert (got == np.asarray(rd)).all()
print("OK")
""", n_devices=4)


def test_plan_sharded_stages():
    stats = plan.StoreStats(n=1 << 12, d=64, w=2, q=8, n_shards=4)
    p = plan.plan_sharded(stats, 10, axes=("data",), k_local=4,
                          select="fused", reorder_local=True)
    assert p.merge.kind == "sharded" and p.merge.k_local == 4
    assert p.merge.reorder_local and p.candidates.layout == "local_sort"
    # reorder_local is fused-only: the planner drops it elsewhere
    p2 = plan.plan_sharded(stats, 10, axes=("data",), select="counting",
                           reorder_local=True)
    assert not p2.merge.reorder_local
    assert p2.candidates.layout == "none"
    assert "ignored" in p2.reason


def test_plan_sharded_merge_strategy():
    """The merge-strategy rule: every exact sharded plan rides the
    distributed counting select (auto resolves to fused FOR the merge);
    the statistical reduction and non-fused selects keep concat_sort."""
    stats = plan.StoreStats(n=1 << 12, d=64, w=2, q=8, n_shards=4)
    p = plan.plan_sharded(stats, 10, axes=("data",))
    assert p.select.path == "fused"
    assert p.merge.strategy == "hist_merge"
    assert p.compact().endswith("merge:hist_merge")
    # k_local < k is the statistical reduction: concat_sort only
    ps = plan.plan_sharded(stats, 10, axes=("data",), k_local=4)
    assert ps.merge.strategy == "concat_sort"
    assert "@k4" in ps.compact()
    # merge=hist_merge on a statistical plan is noted-ignored, not honored
    psf = plan.plan_sharded(stats, 10, axes=("data",), k_local=4,
                            merge="hist_merge")
    assert psf.merge.strategy == "concat_sort"
    assert "ignored" in psf.reason
    # a non-fused select cannot race histograms
    pc = plan.plan_sharded(stats, 10, axes=("data",), select="counting")
    assert pc.merge.strategy == "concat_sort"
    # forcing the legacy merge keeps legacy auto-resolution (composite)
    pl = plan.plan_sharded(stats, 10, axes=("data",), merge="concat_sort")
    assert pl.merge.strategy == "concat_sort"
    assert pl.select.path == "composite"
    # uneven shards (per-shard n_valid coming) force the fused local
    # select whatever the merge — only it masks padding exactly
    pu = plan.plan_sharded(stats, 10, axes=("data",), k_local=4, uneven=True)
    assert pu.select.path == "fused"
    assert pu.merge.strategy == "concat_sort"
    assert "uneven" in pu.reason
    with pytest.raises(ValueError):
        plan.plan_sharded(stats, 10, axes=("data",), merge="bogus")


def test_force_merge_overrides():
    """force_plan merge= key: demotions are recorded, never silent."""
    stats = plan.StoreStats(n=1 << 12, d=64, w=2, q=8, n_shards=4)
    # forced non-fused select on a hist_merge plan demotes the merge
    p = plan.plan_sharded(stats, 10, axes=("data",), force="select=counting")
    assert p.select.path == "counting"
    assert p.merge.strategy == "concat_sort"
    assert "demoted" in p.reason
    # forced k_local < k likewise
    p2 = plan.plan_sharded(stats, 10, axes=("data",), force="k_local=2")
    assert p2.merge.strategy == "concat_sort" and p2.merge.k_local == 2
    assert "demoted" in p2.reason
    # forced concat_sort via the override string
    p3 = plan.plan_sharded(stats, 10, axes=("data",), force="merge=concat_sort")
    assert p3.merge.strategy == "concat_sort"
    # merge on a local plan: noted, not applied
    p4 = plan.plan_local(plan.StoreStats(n=512, d=32, w=1, q=2), 4,
                         force="merge=hist_merge")
    assert p4.merge.kind == "none"
    assert "forced merge ignored" in p4.reason
    with pytest.raises(ValueError):
        plan.plan_sharded(stats, 10, axes=("data",), force="merge=bogus")


def test_shard_hints_merge_traffic():
    """explain() reports the predicted cross-device merge traffic: the
    planner-chosen sharded plan moves O(Q*bins) histogram counts, not the
    legacy O(shards*Q*k) candidates, and both predictions are exposed."""
    from repro.kernels import tuning

    q, k, d, s = 256, 16, 128, 8
    stats = plan.StoreStats(n=1 << 17, d=d, w=4, q=q, n_shards=s,
                            backend="cpu")
    p = plan.plan_sharded(stats, k, axes=("data",))
    m = p.explain()["geometry"]["merge"]
    assert m["strategy"] == "hist_merge" and m["n_shards"] == s
    bins = d + 1
    assert m["hist_psum_bytes"] == 4 * q * bins
    assert m["counts_gather_bytes"] == 2 * 4 * q * s
    assert m["output_psum_bytes"] == 2 * 4 * q * k
    assert m["merge_bytes"] == m["hist_merge_bytes"]
    assert m["concat_sort_bytes"] == 2 * 4 * q * k * s
    # the headline drop: O(Q*bins) counts beat O(shards*Q*k) candidates
    assert m["merge_bytes"] < m["concat_sort_bytes"]
    # concat bytes scale with shards; hist_merge's psum payload does not
    m2 = tuning.shard_hints(q, k, bins, 2 * s, k_local=k)
    assert m2["concat_sort_bytes"] == 2 * m["concat_sort_bytes"]
    assert m2["hist_psum_bytes"] == m["hist_psum_bytes"]
    # the forced legacy plan reports its own (bigger) prediction
    pc = plan.plan_sharded(stats, k, axes=("data",), merge="concat_sort")
    mc = pc.explain()["geometry"]["merge"]
    assert mc["merge_bytes"] == mc["concat_sort_bytes"]
    assert "merge:" in pc.explain_str() or "merge" in pc.explain_str()


# ---------------------------------------------------------------------------
# retrieval: config-driven planning + force_plan overrides
# ---------------------------------------------------------------------------

def _store(rcfg, n=256, seed=4):
    rng = np.random.default_rng(seed)
    hidden = jnp.asarray(rng.normal(size=(n, 64)), jnp.float32)
    values = jnp.asarray(rng.integers(0, 64, n), jnp.int32)
    return hidden, retrieval.build_datastore(
        hidden, values, rcfg.code_bits, itq_iters=2, layout=rcfg.layout)


def test_knn_logits_routes_through_planner():
    rcfg = RetrievalConfig(enabled=True, code_bits=32, k=8, chunk_size=100)
    hidden, store = _store(rcfg)
    base = retrieval.knn_logits(store, hidden[:3], rcfg, vocab=64)
    for select in ("counting", "fused", "fused_scan"):
        got = _quiet(retrieval.knn_logits, store, hidden[:3], rcfg, vocab=64,
                     select=select)
        np.testing.assert_allclose(np.asarray(got), np.asarray(base),
                                   rtol=1e-6, atol=1e-6)
    # force_plan == the equivalent per-call forced select, bit-for-bit
    r2 = dataclasses.replace(rcfg, force_plan="select=fused")
    f = retrieval.knn_logits(store, hidden[:3], r2, vocab=64)
    np.testing.assert_array_equal(np.asarray(f), np.asarray(base))
    assert retrieval.plan_for_store(store, r2, 3).select.path == "fused"


def test_store_layout_resolves_to_fused_prebuilt():
    """A store built with a layout makes auto resolve to fused+prebuilt
    (the knn_logits twin of the engine regression). The staged execution
    returns the unreordered scan's top-k DISTANCES bit-for-bit and maps
    every winner back to a valid original id (tie ids may legitimately
    differ by layout position — the documented report-order freedom)."""
    rcfg = RetrievalConfig(enabled=True, code_bits=32, k=8,
                           layout="hamming_prefix")
    hidden, store = _store(rcfg)
    p = retrieval.plan_for_store(store, rcfg, 3)
    assert p.select.path == "fused" and p.candidates.layout == "prebuilt"
    from repro.core import quantize
    q_codes = binary.pack_bits(quantize.itq_encode(hidden[:3], store.itq))
    dd, ii = plan.execute(p, q_codes, codes=store.codes, layout=store.layout)
    rd, _ = engine.search_chunked(store.codes, q_codes, rcfg.k, 32)
    assert (dd == rd).all()
    ref = np.asarray(binary.hamming_ref(
        binary.unpack_bits(q_codes, 32), binary.unpack_bits(store.codes, 32)))
    assert (ref[np.arange(3)[:, None], np.asarray(ii)]
            == np.asarray(dd)).all()
    # and the end-to-end mixture still finds the planted neighbor
    logp = retrieval.knn_logits(store, hidden[7:8], rcfg, vocab=64,
                                temperature=1.0)
    assert int(jnp.argmax(logp[0])) == int(store.values[7])


def test_rcfg_plan_field_forces_path():
    rcfg = RetrievalConfig(enabled=True, code_bits=32, k=8,
                           plan="fused_scan", chunk_size=64)
    hidden, store = _store(rcfg)
    p = retrieval.plan_for_store(store, rcfg, 2)
    assert p.select.path == "fused_scan" and p.select.chunk == 64


def test_force_sharded_keys_on_local_plan_noted_not_silent():
    """k_local/reorder_local are sharded-only: forcing them on a local
    plan must not pretend to apply — the drop is recorded in the reason."""
    stats = plan.StoreStats(n=512, d=32, w=1, q=2)
    p = plan.plan_local(stats, 4, force="k_local=2,reorder_local=1")
    assert p.merge.kind == "none"
    assert "k_local ignored" in p.reason
    assert "reorder_local ignored" in p.reason


def test_log_store_plan_is_the_server_startup_line():
    """The runtime server's per-store startup log (the serving-side
    explain()) — exercised here because the server module itself sits
    behind the not-yet-built dist layer."""
    import logging

    rcfg = RetrievalConfig(enabled=True, code_bits=32, k=4)
    _, store = _store(rcfg)
    logger = logging.getLogger("test_plan.server")
    records = []
    handler = logging.Handler()
    handler.emit = records.append
    logger.addHandler(handler)
    logger.setLevel(logging.INFO)
    try:
        p = retrieval.log_store_plan(store, rcfg, q=4, logger=logger)
    finally:
        logger.removeHandler(handler)
    assert p.compact() == retrieval.plan_for_store(store, rcfg, 4).compact()
    assert any("active plan" in r.getMessage() and p.compact()
               in r.getMessage() for r in records)


def test_force_select_rebinds_layout_invariant():
    """A forced non-fused select on a layout engine must DROP the layout
    (only the fused select consumes one): ids stay bit-identical to the
    legacy per-call forced path, which scans the original order."""
    n, q, d, k = 900, 4, 64, 6
    xb, qb = _data(7, n, q, d)
    xp, qp = binary.pack_bits(xb), binary.pack_bits(qb)
    eng = engine.KNNEngine(codes=xp, d=d).with_layout(n_buckets=8)
    p = eng.query_plan(qp, k, force="select=counting")
    assert p.select.path == "counting"
    assert p.candidates.layout == "none"
    assert "layout dropped" in p.reason
    dd, ii = plan.execute(p, qp, codes=xp, layout=eng.layout)
    ld, li = _quiet(eng.search, qp, k, select="counting")
    assert (dd == ld).all() and (ii == li).all()
    # block_mask plans run the fused kernels by construction: a forced
    # select cannot rebind them and must say so, not silently comply
    stats = plan.StoreStats(n=512, d=32, w=1, q=2, has_layout=True,
                            mean_bucket_rows=64, n_buckets=8)
    pm = plan.plan_index(stats, 4, kind="kmeans", nprobe=2,
                         force="select=counting")
    assert pm.select.path == "fused"
    assert "ignored (block_mask runs fused)" in pm.reason


def test_parse_force_rejects_garbage():
    with pytest.raises(ValueError):
        plan.parse_force("select")
    with pytest.raises(ValueError):
        plan._apply_force(plan.plan_local(
            plan.StoreStats(n=128, d=32, w=1, q=1), 4), "select=nope")
    with pytest.raises(ValueError):
        plan._apply_force(plan.plan_local(
            plan.StoreStats(n=128, d=32, w=1, q=1), 4), "turbo=on")
    with pytest.raises(ValueError):
        plan._apply_force(plan.plan_local(
            plan.StoreStats(n=128, d=32, w=1, q=1), 4), "candidates=bogus")


def test_force_candidates_transitions():
    """Only block_mask->gather is executable from the public call sites
    (they build gather operands whenever the plan says gather); every
    other rebinding lacks operands and must be noted, not crash later."""
    idx_stats = plan.StoreStats(n=512, d=32, w=1, q=2, has_layout=True,
                                mean_bucket_rows=64, n_buckets=8)
    pg = plan.plan_index(idx_stats, 4, kind="kmeans", nprobe=2,
                         force="candidates=gather")
    assert pg.candidates.kind == "gather"
    assert pg.select.path == "counting"
    flat = plan.StoreStats(n=512, d=32, w=1, q=2)
    pf = plan.plan_local(flat, 4, force="candidates=gather")
    assert pf.candidates.kind == "full"
    assert "ignored" in pf.reason


def test_force_layout_notes_do_not_self_contradict():
    """Overriding the layout must scrub the planner's stale layout note
    (no 'streams the prebuilt BucketLayout; forced layout=none'), and on
    block_mask plans the override is recorded as ignored."""
    lay_stats = plan.StoreStats(n=512, d=32, w=1, q=2, has_layout=True,
                                mean_bucket_rows=64, n_buckets=8)
    p = plan.plan_local(lay_stats, 4, force="layout=off")
    assert p.candidates.layout == "none"
    assert "streams the prebuilt" not in p.reason
    assert "forced layout=none" in p.reason
    pm = plan.plan_index(lay_stats, 4, kind="kmeans", nprobe=2,
                         force="layout=off")
    assert pm.candidates.kind == "block_mask"
    assert "forced layout ignored" in pm.reason


def test_geometry_mirrors_executor_chunk_resolution():
    """explain() geometry must resolve a falsy chunk exactly like the
    executor (0 -> DEFAULT_CHUNK), not report an impossible 0-chunk scan."""
    stats = plan.StoreStats(n=1 << 17, d=128, w=4, q=16)
    p = plan.plan_local(stats, 8, select="counting", force="chunk=0")
    g = p.geometry()
    assert g["chunk"] == min(plan.DEFAULT_CHUNK, 1 << 17)
    assert g["n_chunks"] == (1 << 17) // g["chunk"]


# ---------------------------------------------------------------------------
# explain / compact / the generated decision table
# ---------------------------------------------------------------------------

def test_explain_is_jsonable_and_compact_is_row_safe():
    xb, qb = _data(5, 600, 4, 64)
    xp, qp = binary.pack_bits(xb), binary.pack_bits(qb)
    eng = engine.KNNEngine(codes=xp, d=64).with_layout(n_buckets=4)
    p = eng.query_plan(qp, 5)
    e = json.loads(json.dumps(p.explain()))
    assert e["stages"]["select"]["path"] == "fused"
    assert e["stages"]["candidates"]["layout"] == "prebuilt"
    assert e["shape"] == {"n": 600, "d": 64, "w": 2, "q": 4, "k": 5}
    assert {"bq", "bn", "sub", "grid"} <= set(e["geometry"])
    assert e["compact"] == p.compact()
    # benchmark derived fields split on ';' and '=' and ',' — the compact
    # form must never collide with that grammar
    for ch in ";,=":
        assert ch not in p.compact()
    assert "QueryPlan[" in p.explain_str()


def test_decision_table_covers_rules_and_matches_design():
    table = plan.decision_table()
    for needle in ("auto->composite", "auto->fused", "block_mask",
                   "gather", "reorder_local", "forced select=fused_scan"):
        assert needle in table, needle
    # the committed DESIGN.md section must track the planner (CI's
    # plan-smoke gate, pinned here too so drift fails tier-1 first)
    import os
    design = os.path.join(os.path.dirname(__file__), "..", "DESIGN.md")
    assert plan.check_design(design) == 0


def test_legacy_knobs_deprecation_nudge():
    xb, qb = _data(6, 300, 2, 32)
    xp, qp = binary.pack_bits(xb), binary.pack_bits(qb)
    plan._WARNED.clear()
    with pytest.warns(DeprecationWarning, match="forced-plan override"):
        engine.search_chunked(xp, qp, 4, 32, select="bisect")
    # once per process per knob value: a repeat stays silent
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        engine.search_chunked(xp, qp, 4, 32, select="bisect")


# ---------------------------------------------------------------------------
# the approx tier's planner rows (kernel behavior lives in test_approx.py)
# ---------------------------------------------------------------------------

def test_matrix_approx_resolution_and_force():
    """select="approx" is planner-resolvable and force-selectable but NEVER
    an auto target; its recall knob rides the force grammar."""
    stats = plan.StoreStats(n=4096, d=64, w=2, q=8)
    path, reason = plan.resolve_select("approx", stats)
    assert path == "approx" and "forced" in reason
    # auto stays exact with and without a layout
    assert plan.resolve_select("auto", stats)[0] == "composite"
    lay_stats = dataclasses.replace(stats, has_layout=True,
                                    mean_bucket_rows=64, n_buckets=64)
    assert plan.resolve_select("auto", lay_stats)[0] == "fused"
    # force grammar: select + recall_target together
    p = plan.plan_local(stats, 5, force="select=approx,recall_target=0.9")
    assert (p.select.path, p.select.recall_target) == ("approx", 0.9)
    assert p.compact() == "probe:none|cand:full|select:approx@r0.9|merge:none"
    for ch in ";,=":                    # bench-row grammar safety
        assert ch not in p.compact()


def test_matrix_approx_engine_exact_at_full_recall():
    """Engine-level select="approx" (default recall_target=1.0) joins the
    bit-identity matrix: dists AND ids equal the oracle, layout on or off."""
    n, q, d, k = 1200, 5, 64, 7
    xb, qb = _data(7, n, q, d)
    xp, qp = binary.pack_bits(xb), binary.pack_bits(qb)
    rd, ri = _oracle(xb, qb, k, d)
    eng = engine.KNNEngine(codes=xp, d=d)
    dd, ii = _quiet(eng.search, qp, k, select="approx")
    assert (dd == rd).all() and (ii == ri).all()
    # prebuilt layout streams through the approx scan like fused
    engl = eng.with_layout(n_buckets=4)
    pl = engl.query_plan(qp, k, select="approx")
    assert pl.candidates.layout == "prebuilt"
    ld, li = _quiet(engl.search, qp, k, select="approx")
    fd, fi = _quiet(engl.search, qp, k, select="fused")
    assert (ld == fd).all() and (li == fi).all()


def test_decision_table_has_approx_rows():
    table = plan.decision_table()
    for needle in ("approx", "rt=0.9", "rt=1", "hist_merge",
                   "retrieval_off"):
        assert needle in table, needle
