"""kNN-LM retrieval layer: mixing math, datastore round-trip, sentinel
masking when fewer than k valid neighbors exist."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, scaled_down
from repro.configs.base import RetrievalConfig
from repro.core import retrieval


def test_interpolate_is_log_mixture():
    lm_logits = jnp.asarray(np.random.default_rng(0).normal(size=(3, 7)), jnp.float32)
    knn_logp = jax.nn.log_softmax(
        jnp.asarray(np.random.default_rng(1).normal(size=(3, 7)), jnp.float32))
    lam = 0.3
    mixed = retrieval.interpolate(lm_logits, knn_logp, lam)
    expect = jnp.log((1 - lam) * jax.nn.softmax(lm_logits) + lam * jnp.exp(knn_logp))
    np.testing.assert_allclose(np.asarray(mixed), np.asarray(expect),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(jnp.exp(mixed).sum(-1)), 1.0, rtol=1e-5)


def test_datastore_retrieves_planted_neighbor():
    """A hidden state identical to a datastore entry must dominate p_knn."""
    cfg = scaled_down(get_config("gemma-2b"))
    rcfg = cfg.retrieval
    rng = np.random.default_rng(0)
    hidden = jnp.asarray(rng.normal(size=(512, cfg.d_model)), jnp.float32)
    values = jnp.asarray(rng.integers(0, cfg.vocab_size, 512), jnp.int32)
    store = retrieval.build_datastore(hidden, values, rcfg.code_bits, itq_iters=5)
    q = hidden[7:8]
    logp = retrieval.knn_logits(store, q, rcfg, cfg.vocab_size, temperature=1.0)
    assert int(jnp.argmax(logp[0])) == int(values[7])


def test_knn_logits_sentinel_padding_gets_no_weight():
    """k > N: the engine pads with sentinels (dist = d+1, id = N). Before
    the validity mask they received softmax weight and ALL voted for
    values[N-1]; now each real neighbor must get exactly its share."""
    rcfg = RetrievalConfig(enabled=True, code_bits=32, k=16)
    rng = np.random.default_rng(2)
    hidden = jnp.asarray(rng.normal(size=(4, 64)), jnp.float32)
    values = jnp.asarray([5, 6, 7, 8], jnp.int32)
    store = retrieval.build_datastore(hidden, values, rcfg.code_bits,
                                      itq_iters=2)
    # near-infinite temperature -> uniform weight over every slot that
    # counts: with the 12 sentinel slots masked out, each of the 4 real
    # (distinct-valued) neighbors gets 1/4 — before the fix values[N-1]
    # soaked up 13/16
    for select in ("auto", "fused"):
        logp = retrieval.knn_logits(store, hidden[:1], rcfg, vocab=16,
                                    temperature=1e9, select=select)
        p = np.asarray(jnp.exp(logp[0]))
        np.testing.assert_allclose(p[[5, 6, 7, 8]], 0.25, atol=1e-4)
        np.testing.assert_allclose(p.sum(), 1.0, atol=1e-3)


def test_synthetic_datastore_shapes():
    cfg = scaled_down(get_config("gemma-2b"))
    store = retrieval.synthetic_datastore(cfg, n=1024)
    assert store.codes.shape == (1024, cfg.retrieval.code_bits // 32)
    assert store.values.shape == (1024,)
    assert store.codes.dtype == jnp.uint32
