"""Statistical activation reduction accuracy model (paper Fig. 11)."""
from _propcheck import given, settings, st

from repro.core import hierarchy

settings.register_profile("ci", deadline=None, max_examples=20)
settings.load_profile("ci")


@given(st.integers(2, 32), st.integers(2, 128))
def test_bound_dominates_monte_carlo(k, r):
    kprime = max(1, k // 4)
    bound = hierarchy.failure_bound(k, r, kprime)
    mc = hierarchy.failure_exact_mc(k, r, kprime, trials=2000)
    assert bound >= mc - 0.03


@given(st.integers(2, 32), st.integers(2, 64))
def test_failure_decreases_in_kprime(k, r):
    probs = [hierarchy.failure_bound(k, r, kp) for kp in range(1, k + 1)]
    assert all(a >= b - 1e-12 for a, b in zip(probs, probs[1:]))
    assert probs[-1] == 0.0          # k'=k is exact


def test_mc_matches_per_trial_loop():
    """The batched-bincount MC must be draw-for-draw identical to the
    per-trial loop it replaced (same rng stream, same estimate)."""
    import numpy as np

    for (k, r, kp, seed) in [(16, 8, 2, 0), (4, 2, 1, 3), (32, 64, 3, 7),
                             (2, 2, 2, 1)]:
        rng = np.random.default_rng(seed)
        groups = rng.integers(0, r, size=(500, k))
        want = sum(1 for t in range(500)
                   if np.bincount(groups[t], minlength=r).max() > kp) / 500
        got = hierarchy.failure_exact_mc(k, r, kp, trials=500, seed=seed)
        assert got == want, (k, r, kp, got, want)


def test_recommended_kprime_meets_target():
    k, r = 16, 64
    kp = hierarchy.recommended_kprime(k, r, max_failure=0.01)
    assert hierarchy.failure_bound(k, r, kp) <= 0.01
    assert kp < k                    # reduction is actually possible
    assert hierarchy.bandwidth_reduction(1024, kp) > 100
