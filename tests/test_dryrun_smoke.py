"""CI-scale dry-run: lower + compile FULL configs on a small fake mesh in a
subprocess (proves the 512-chip path's sharding logic end-to-end)."""
import pytest

pytestmark = pytest.mark.slow


def test_dryrun_cells_on_host_mesh(multidevice):
    multidevice("""
import jax
from repro import compat
from repro.launch import dryrun
mesh = compat.make_mesh((2, 2, 2), ("pod", "data", "model"))
for arch, shape in [("gemma-2b", "train_4k"), ("rwkv6-1.6b", "long_500k"),
                    ("musicgen-medium", "decode_32k")]:
    rec = dryrun.run_cell(arch, shape, mesh=mesh)
    assert rec["flops_per_device"] > 0, (arch, shape)
    assert rec["dominant"] in ("compute", "memory", "collective")
    print(arch, shape, rec["dominant"], "OK")
""", n_devices=8, timeout=1200)


def test_input_specs_cover_all_runnable_cells():
    from repro.configs import ALL_ARCHS, get_config, get_shape, runnable_cells
    from repro.launch.specs import input_specs
    cells, skipped = runnable_cells([get_config(a) for a in ALL_ARCHS])
    assert len(cells) == 32 and len(skipped) == 8
    for arch, shape in cells:
        args = input_specs(get_config(arch), get_shape(shape))
        assert len(args) >= 2
