"""Distributed counting select (merge="hist_merge"): the sharded
equivalence matrix, run in subprocesses with 4 fake host devices
(XLA_FLAGS=--xla_force_host_platform_device_count=4 must precede the jax
import, hence the multidevice fixture).

Pins that the sharded fused search via hist_merge is BIT-IDENTICAL to the
single-device fused reference and to the legacy concat/sort merge across
the matrix the distributed path must cover: uniform shards, layout-sorted
shards (reorder_local), per-shard enable masks, uneven shard sizes
(per-shard n_valid), and k larger than one shard's valid rows.
"""


def test_hist_merge_uniform_matrix(multidevice):
    """Even shards: planner picks hist_merge, results == single-device
    fused reference == forced legacy concat/sort merge, dists AND ids."""
    multidevice("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.core import binary, engine, plan
from repro.kernels import ops

rng = np.random.default_rng(0)
d, N, Q, k = 64, 2048, 8, 16
xb = jnp.asarray(rng.integers(0, 2, (N, d)), jnp.uint8)
qb = jnp.asarray(rng.integers(0, 2, (Q, d)), jnp.uint8)
xp, qp = binary.pack_bits(xb), binary.pack_bits(qb)
mesh = Mesh(np.array(jax.devices()).reshape(4), ("data",))

rd, ri = ops.hamming_topk(qp, xp, k, d + 1)

# the planner picks the distributed counting select for the sharded store
stats = plan.stats_for(N, d, xp.shape[1], Q, n_shards=4)
p = plan.plan_sharded(stats, k, axes=("data",))
assert p.merge.strategy == "hist_merge", p.merge
assert p.select.path == "fused", p.select
assert "hist_merge" in p.compact()
with mesh:
    hd, hi = plan.execute(p, qp, codes=xp, mesh=mesh)
assert (hd == rd).all() and (hi == ri).all(), "hist_merge != fused reference"

# the legacy concat/sort merge stays available as a forced fallback and
# agrees bit-for-bit
pc = plan.plan_sharded(stats, k, axes=("data",), merge="concat_sort")
assert pc.merge.strategy == "concat_sort"
with mesh:
    cd, ci = plan.execute(pc, qp, codes=xp, mesh=mesh)
assert (cd == hd).all() and (ci == hi).all(), "concat_sort != hist_merge"

# ... and through the force_plan override string
pf = plan.plan_sharded(stats, k, axes=("data",), force="merge=concat_sort")
assert pf.merge.strategy == "concat_sort"
with mesh:
    fd, fi = plan.execute(pf, qp, codes=xp, mesh=mesh)
assert (fd == hd).all() and (fi == hi).all()

# the engine entry point is a thin builder over the same plan
with mesh:
    sd, si = engine.search_sharded(xp, qp, k, d, mesh, ("data",))
assert (sd == rd).all() and (si == ri).all()

# statistical concat merge with fewer gathered candidates than k must
# still honor the (Q, k) contract, padding with (d+1, N) sentinels
with mesh:
    td, ti = engine.search_sharded(xp, qp, k, d, mesh, ("data",), k_local=2)
assert td.shape == (Q, k) and ti.shape == (Q, k), (td.shape, k)
assert (td[:, 8:] == d + 1).all() and (ti[:, 8:] == N).all()
# the 8 gathered candidates are real rows with their true distances
# (statistical, so not necessarily the global top-8)
ref = np.asarray(binary.hamming_ref(qb, xb))
assert (ref[np.arange(Q)[:, None], np.asarray(ti[:, :8])]
        == np.asarray(td[:, :8])).all()
print("OK")
""", n_devices=4)


def test_hist_merge_uneven_and_k_exceeds_shard(multidevice):
    """Uneven shards padded to a common slice (per-shard n_valid), with k
    larger than one shard's valid rows and k larger than the global valid
    total: bit-identical (sentinels included) to the single-device fused
    reference over the concatenated VALID rows, on both merge paths."""
    multidevice("""
import warnings
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.core import binary, engine
from repro.kernels import ops

rng = np.random.default_rng(1)
d, Q, n_loc = 64, 6, 512
nv = np.array([300, 512, 11, 201], np.int32)      # shard 2: 11 valid rows
xb = rng.integers(0, 2, (4 * n_loc, d)).astype(np.uint8)
qb = jnp.asarray(rng.integers(0, 2, (Q, d)), jnp.uint8)
xp_full = np.asarray(binary.pack_bits(jnp.asarray(xb)))
parts, valid = [], []
for s in range(4):
    blk = xp_full[s * n_loc:(s + 1) * n_loc].copy()
    valid.append(blk[:nv[s]].copy())
    blk[nv[s]:] = 0xFFFFFFFF                       # padding rows: worst case
    parts.append(blk)
xpad = jnp.asarray(np.concatenate(parts))
xval = jnp.asarray(np.concatenate(valid))
qp = binary.pack_bits(qb)
mesh = Mesh(np.array(jax.devices()).reshape(4), ("data",))

for k in (64, 1200):          # 64 > nv[2]; 1200 > sum(nv) = 1024
    rd, ri = ops.hamming_topk(qp, xval, k, d + 1)
    with mesh, warnings.catch_warnings():
        warnings.simplefilter("ignore")
        hd, hi = engine.search_sharded(xpad, qp, k, d, mesh, ("data",),
                                       shard_n_valid=jnp.asarray(nv))
        cd, ci = engine.search_sharded(xpad, qp, k, d, mesh, ("data",),
                                       select="fused", merge="concat_sort",
                                       shard_n_valid=jnp.asarray(nv))
    assert (hd == rd).all() and (hi == ri).all(), ("hist_merge", k)
    assert (cd == rd).all() and (ci == ri).all(), ("concat_sort", k)

# statistical reduction over uneven shards: auto resolves to the fused
# local select (only it masks per-shard padding), merge stays concat_sort
with mesh:
    pd_, pi_ = engine.search_sharded(xpad, qp, 16, d, mesh, ("data",),
                                     k_local=4,
                                     shard_n_valid=jnp.asarray(nv))
rd16, _ = ops.hamming_topk(qp, xval, 16, d + 1)
recall = float(jnp.mean(jnp.any(
    np.asarray(pi_)[:, :, None] == np.asarray(ops.hamming_topk(qp, xval, 16, d + 1)[1])[:, None, :], axis=1)))
assert recall > 0.5, recall

# a forced materializing select cannot mask per-shard padding: refused
# with guidance, not a bare AssertionError
try:
    with mesh, warnings.catch_warnings():
        warnings.simplefilter("ignore")
        engine.search_sharded(xpad, qp, 16, d, mesh, ("data",),
                              select="counting",
                              shard_n_valid=jnp.asarray(nv))
    raise SystemExit("expected ValueError")
except ValueError as e:
    assert "fused" in str(e)
print("OK")
""", n_devices=4)


def test_hist_merge_reorder_local_layout(multidevice):
    """Per-shard local_sort layout composes with hist_merge: the top-k
    DISTANCE vector is layout-invariant (bit-identical to the reference)
    and every returned id really has its reported distance — including on
    uneven shards, where the sort must pin padding rows last."""
    multidevice("""
import warnings
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.core import binary, engine, plan
from repro.kernels import ops

rng = np.random.default_rng(2)
d, N, Q, k = 64, 2048, 8, 16
xb = jnp.asarray(rng.integers(0, 2, (N, d)), jnp.uint8)
qb = jnp.asarray(rng.integers(0, 2, (Q, d)), jnp.uint8)
xp, qp = binary.pack_bits(xb), binary.pack_bits(qb)
mesh = Mesh(np.array(jax.devices()).reshape(4), ("data",))

stats = plan.stats_for(N, d, xp.shape[1], Q, n_shards=4)
p = plan.plan_sharded(stats, k, axes=("data",), reorder_local=True)
assert p.merge.strategy == "hist_merge"
assert p.candidates.layout == "local_sort"
rd, _ = ops.hamming_topk(qp, xp, k, d + 1)
with mesh:
    sd, si = plan.execute(p, qp, codes=xp, mesh=mesh)
assert (sd == rd).all()
ref = np.asarray(binary.hamming_ref(qb, xb))
assert (ref[np.arange(Q)[:, None], np.asarray(si)] == np.asarray(sd)).all()

# uneven + reorder_local
n_loc = 512
nv = np.array([300, 512, 11, 201], np.int32)
xp_np = np.asarray(xp)
parts, valid = [], []
for s in range(4):
    blk = xp_np[s * n_loc:(s + 1) * n_loc].copy()
    valid.append(blk[:nv[s]].copy())
    blk[nv[s]:] = 0                                # near-zero padding: would
    parts.append(blk)                              # sort FIRST if unpinned
xpad = jnp.asarray(np.concatenate(parts))
xval = jnp.asarray(np.concatenate(valid))
k2 = 64
rd2, _ = ops.hamming_topk(qp, xval, k2, d + 1)
with mesh, warnings.catch_warnings():
    warnings.simplefilter("ignore")
    ud, ui = engine.search_sharded(xpad, qp, k2, d, mesh, ("data",),
                                   reorder_local=True,
                                   shard_n_valid=jnp.asarray(nv))
assert (ud == rd2).all()
refv = np.asarray(binary.hamming_ref(qb, binary.unpack_bits(xval, d)))
assert (refv[np.arange(Q)[:, None], np.asarray(ui)] == np.asarray(ud)).all()
print("OK")
""", n_devices=4)


def test_hist_merge_masked_shards(multidevice):
    """Per-shard enable masks (core/layout.py contract) through the
    distributed select: with pinned geometry, per-shard masks concatenate
    into the single-device global mask, and hamming_topk_sharded must be
    bit-identical to the masked single-device reference — r* derives from
    the globally-merged MASKED histogram."""
    multidevice("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from repro.compat import shard_map
from repro.core import binary
from repro.kernels import ops

rng = np.random.default_rng(3)
d, Q, k, n_loc = 64, 8, 16, 1024
N = 4 * n_loc
xb = jnp.asarray(rng.integers(0, 2, (N, d)), jnp.uint8)
qb = jnp.asarray(rng.integers(0, 2, (Q, d)), jnp.uint8)
xp, qp = binary.pack_bits(xb), binary.pack_bits(qb)
geom = dict(bq=8, bn=256, sub=64)      # local grid (1, 4); global (1, 16)
mask_g = jnp.asarray(rng.integers(0, 2, (1, 16)), jnp.int32)
mask_g = mask_g.at[0, 5].set(1)        # keep at least one tile enabled
rd, ri = ops.hamming_topk(qp, xp, k, d + 1, block_mask=mask_g, **geom)

mesh = Mesh(np.array(jax.devices()).reshape(4), ("data",))
def local(x_loc, q, m_loc):
    return ops.hamming_topk_sharded(q, x_loc, k, d + 1, ("data",),
                                    n_shards=4, block_mask=m_loc, **geom)
fn = shard_map(local, mesh=mesh,
               in_specs=(P("data", None), P(None, None), P(None, "data")),
               out_specs=(P(None, None), P(None, None)))
with mesh:
    sd, si = fn(xp, qp, mask_g)
assert (sd == rd).all() and (si == ri).all(), "masked shards != masked ref"

# a query whose enabled rows number fewer than k gets the same sentinel
# treatment as the single-device masked kernel
mask_one = jnp.zeros((1, 16), jnp.int32).at[0, 3].set(1)
rd1, ri1 = ops.hamming_topk(qp, xp, 300, d + 1, block_mask=mask_one, **geom)
def local1(x_loc, q, m_loc):
    return ops.hamming_topk_sharded(q, x_loc, 300, d + 1, ("data",),
                                    n_shards=4, block_mask=m_loc, **geom)
fn1 = shard_map(local1, mesh=mesh,
                in_specs=(P("data", None), P(None, None), P(None, "data")),
                out_specs=(P(None, None), P(None, None)))
with mesh:
    sd1, si1 = fn1(xp, qp, mask_one)
assert (sd1 == rd1).all() and (si1 == ri1).all(), "k > enabled rows"
print("OK")
""", n_devices=4)
